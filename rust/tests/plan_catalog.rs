//! The committed `PLANS.json` artifact (repo root): schema validation,
//! canonical-format byte round-trip, and the blessed regeneration flow —
//! the plan-catalog mirror of `calibration_json.rs`.
//!
//! Unlike `CALIBRATION.json` (whose fitted constants legitimately move
//! under re-profiling), the committed catalog pins *content* as well as
//! schema: it is a hand-picked exhibit of the serialization surface
//! (simple, dgsparse-with-float, nested hybrid, tensor scenario), built
//! programmatically by [`committed_catalog`] so the bytes on disk are
//! reproducible. Refreshing after a deliberate schema change is still a
//! blessed operation: `SGAP_BLESS=1 cargo test --test plan_catalog`.

use std::path::PathBuf;

use sgap::algos::catalog::{Algo, BandAlgo, CompositeConfig};
use sgap::algos::{DgConfig, MttkrpConfig};
use sgap::bench_util::validate_plan_catalog_json;
use sgap::coordinator::{
    CatalogEntry, CoordinatorConfig, OpKind, Plan, PlanCache, PlanCatalog, PlanOrigin, Session,
    ShapeKey, PLAN_CATALOG_SCHEMA_VERSION,
};
use sgap::sparse::erdos_renyi;

fn committed() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("PLANS.json")
}

/// The exact catalog the committed artifact holds, in canonical order:
/// a plain compiler-family plan, a dgsparse plan (the one family with a
/// float field, pinning the `{:.17e}` format), a nested hybrid plan,
/// and a tensor-scenario plan.
fn committed_catalog() -> PlanCatalog {
    let entries = vec![
        CatalogEntry {
            key: ShapeKey::from_parts(OpKind::Spmm, 512, 512, 8192, 8, 12, 3, 0),
            plan: Plan { kind: Algo::SgapNnzGroup { c: 4, r: 32 }, origin: PlanOrigin::Tuned },
        },
        CatalogEntry {
            key: ShapeKey::from_parts(OpKind::Spmm, 1024, 1024, 16384, 16, 6, 3, 1),
            plan: Plan {
                kind: Algo::Dg(DgConfig {
                    n: 16,
                    group_sz: 32,
                    block_sz: 8,
                    tile_sz: 256,
                    worker_dim_r_frac: 0.5,
                    worker_sz: 32,
                    coarsen_sz: 4,
                }),
                origin: PlanOrigin::Selector,
            },
        },
        CatalogEntry {
            key: ShapeKey::from_parts(OpKind::Spmm, 4096, 4096, 131072, 4, 25, 4, 2),
            plan: Plan {
                kind: Algo::Composite(CompositeConfig {
                    bands: 3,
                    cuts: [2, 5],
                    plans: [
                        BandAlgo::TacoRowSerial { x: 1, c: 4 },
                        BandAlgo::SgapRowGroup { g: 8, c: 4, r: 8 },
                        BandAlgo::SgapNnzGroup { c: 4, r: 32 },
                    ],
                }),
                origin: PlanOrigin::Tuned,
            },
        },
        CatalogEntry {
            key: ShapeKey::from_parts(OpKind::Mttkrp, 1024, 64, 20000, 8, 10, 2, 0),
            plan: Plan {
                kind: Algo::Mttkrp(MttkrpConfig { j_dim: 8, c: 4, p: 256, r: 16 }),
                origin: PlanOrigin::Tuned,
            },
        },
    ];
    PlanCatalog { version: PLAN_CATALOG_SCHEMA_VERSION, entries }
}

#[test]
fn committed_plans_match_schema() {
    let path = committed();
    if std::env::var_os("SGAP_BLESS").is_some() {
        let cat = committed_catalog();
        cat.save(&path).unwrap_or_else(|e| panic!("bless {}: {e}", path.display()));
    }
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing committed {}: {e}\n(regenerate with `SGAP_BLESS=1 cargo test --test \
             plan_catalog`)",
            path.display()
        )
    });
    validate_plan_catalog_json(&src).unwrap_or_else(|e| {
        panic!("committed {} fails the documented schema: {e}", path.display())
    });
}

#[test]
fn committed_plans_round_trip_byte_identically() {
    if std::env::var_os("SGAP_BLESS").is_some() {
        return; // the blessing test above rewrites the file this run
    }
    let src = std::fs::read_to_string(committed()).unwrap();
    let cat = PlanCatalog::from_json(&src).unwrap();
    assert_eq!(cat.version, PLAN_CATALOG_SCHEMA_VERSION);
    // the committed artifact must be in canonical `to_json` format, so a
    // coordinator that loads and re-saves it produces the same bytes
    assert_eq!(cat.to_json(), src, "committed PLANS.json is not in canonical format");
    // and it holds exactly the pinned exhibit (content drift is a
    // deliberate, blessed act — not an accident)
    assert_eq!(cat, committed_catalog(), "committed PLANS.json content drifted");
    // warming a sharded cache and re-snapshotting reproduces the same
    // bytes: canonical order survives hash-sharded storage
    let cache = PlanCache::with_shards(64, 8);
    assert_eq!(cat.warm(&cache), cat.len());
    assert_eq!(PlanCatalog::from_cache(&cache).to_json(), src);
}

#[test]
fn emitted_catalog_passes_its_own_schema_gate() {
    validate_plan_catalog_json(&committed_catalog().to_json()).unwrap();
    // the empty catalog is also schema-valid (a cold coordinator's save)
    let empty = PlanCatalog { version: PLAN_CATALOG_SCHEMA_VERSION, entries: vec![] };
    validate_plan_catalog_json(&empty.to_json()).unwrap();
    assert_eq!(PlanCatalog::from_json(&empty.to_json()).unwrap().to_json(), empty.to_json());
}

/// Truncated, corrupted, or version-skewed artifacts fail the load with
/// a *typed* error — and the serving policy on that error is a clean
/// cold start, exactly what `serve --plans` does: the coordinator comes
/// up plan-less and serves from the selector.
#[test]
fn damaged_artifacts_are_typed_errors_and_cold_start_cleanly() {
    let src = committed_catalog().to_json();

    // truncation: a parse error, not a panic
    let err = PlanCatalog::from_json(&src[..src.len() / 2]).unwrap_err();
    assert!(format!("{err:#}").contains("JSON"), "{err:#}");
    // version skew: names both versions
    let skewed = src.replace("\"schema_version\": 1", "\"schema_version\": 99");
    let err = PlanCatalog::from_json(&skewed).unwrap_err().to_string();
    assert!(err.contains("99") && err.contains('1'), "{err}");
    // corrupted enum tag: the bad value is named in the error chain
    let bad = src.replace("\"origin\": \"selector\"", "\"origin\": \"oracle\"");
    let err = PlanCatalog::from_json(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("oracle"), "{err:#}");
    // lost field: reported against the entry that lost it
    let lost = src.replace("      \"nnz\": 8192,\n", "");
    let err = PlanCatalog::from_json(&lost).unwrap_err();
    assert!(format!("{err:#}").contains("nnz"), "{err:#}");

    // cold start after a failed load: the `serve --plans` policy is to
    // warn and start plan-less — serving must be unaffected
    let dir = std::env::temp_dir().join(format!("sgap-plan-catalog-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("PLANS.json");
    std::fs::write(&path, &src[..src.len() / 2]).unwrap();
    let plans = PlanCatalog::load(&path).ok(); // None: damaged artifact dropped
    assert!(plans.is_none());
    let session = Session::start(CoordinatorConfig {
        workers: 1,
        background_tune: false,
        plans,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let a = session.register_matrix(erdos_renyi(32, 32, 160, 3).to_csr());
    let b = session.register_dense(vec![1.0; 32 * 4]);
    let resp = session.spmm(&a, &b, 4).wait().unwrap();
    assert_eq!(resp.c.len(), 32 * 4);
    let snap = session.coordinator().metrics.snapshot();
    assert_eq!((snap.warm_hits, snap.cache_misses), (0, 1), "cold start serves from the selector");
    session.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
