//! Differential property tests for the fused SDDMM→SpMM kernel: every
//! legal fused launch shape must match the *materialized two-stage
//! oracle* (`sddmm_serial` into `spmm_serial`, i.e. `fused_serial`)
//! within 5e-4 — fusion is a pure scheduling transform and must never
//! change the computed values.
//!
//! Covered: the matrix families the selector distinguishes (uniform ER,
//! power-law skew, banded, empty-row corners) × (j, n) width pairs
//! bracketing the grouped-reduction and coarsening grids, plus the
//! plan-cache path (a cached fused plan reproduces fresh selection
//! bit-for-bit).

use sgap::algos::cpu_ref::max_rel_err;
use sgap::algos::fused::fused_serial;
use sgap::coordinator::{PlanCache, ShapeKey};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{banded, erdos_renyi, power_law, Coo, Csr, MatrixStats, SplitMix64};
use sgap::tuner::{fused_candidates, Selector};

const TOL: f32 = 5e-4;

/// (j, n) pairs: j = 20 exercises the non-power-of-two dot tail, n = 1
/// the narrowest coarsening, n = 16 the widest committed fused grid.
const WIDTHS: [(usize, usize); 3] = [(1, 4), (20, 16), (32, 1)];

/// One matrix per family the selector distinguishes, plus the empty-row
/// corners that stress zero extension and the hoisted row-advance scan.
fn families(seed: u64) -> Vec<(&'static str, Csr)> {
    // hub: one full row, everything else empty except a tail entry
    let mut hub: Vec<(u32, u32, f32)> = (0..64u32).map(|c| (0u32, c, 1.0 - c as f32)).collect();
    hub.push((63, 0, 2.5));
    // comb: only every fourth row populated (interior + trailing empties)
    let comb: Vec<(u32, u32, f32)> =
        (0..96u32).step_by(4).flat_map(|r| [(r, r % 37, 1.5), (r, 40 + r % 23, -0.5)]).collect();
    vec![
        ("erdos_renyi", erdos_renyi(96, 80, 900, seed).to_csr()),
        ("power_law", power_law(96, 96, 1100, 1.8, seed).to_csr()),
        ("banded", banded(96, 7, seed).to_csr()),
        ("corner_hub", Coo::new(64, 64, hub).to_csr()),
        ("corner_empty_rows", Coo::new(96, 64, comb).to_csr()),
    ]
}

/// Dense operand triple (X1 [rows×j], X2 [j×cols], B [cols×n]).
fn operands(a: &Csr, j: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let x1 = (0..a.rows * j).map(|_| rng.value()).collect();
    let x2 = (0..j * a.cols).map(|_| rng.value()).collect();
    let b = (0..a.cols * n).map(|_| rng.value()).collect();
    (x1, x2, b)
}

/// Every legal fused launch shape matches the materialized two-stage
/// oracle across the family × width grid.
#[test]
fn every_fused_candidate_matches_two_stage_oracle() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &(j, n) in &WIDTHS {
        for (fam, a) in families(0xF05E ^ (j * 37 + n) as u64) {
            let (x1, x2, b) = operands(&a, j, n, 11 + (j + n) as u64);
            let want = fused_serial(&a, &x1, &x2, &b, j, n);
            let cands = fused_candidates(j as u32, n as u32);
            assert!(!cands.is_empty(), "no fused candidates for j={j} n={n}");
            for alg in cands {
                assert!(alg.is_fused(), "{}", alg.name());
                let res = alg.run_fused(&machine, &a, &x1, &x2, &b).unwrap_or_else(|e| {
                    panic!("{fam} j={j} n={n}: {} failed: {e}", alg.name())
                });
                let err = max_rel_err(&res.run.c, &want);
                assert!(
                    err < TOL,
                    "{fam} j={j} n={n}: {} err {err} (matrix {}x{} nnz {})",
                    alg.name(),
                    a.rows,
                    a.cols,
                    a.nnz()
                );
            }
        }
    }
}

/// The fused plan-cache path is result-identical to fresh selection, and
/// fused keys never collide into the SpMM scenario for the same matrix
/// and packed width.
#[test]
fn fused_plan_cache_path_equals_fresh_selection() {
    let machine = Machine::new(HwProfile::rtx3090());
    let selector = Selector::default();
    let cache = PlanCache::new(64);
    for &(j, n) in &WIDTHS {
        for (fam, a) in families(0xFCA5 ^ (j * 37 + n) as u64) {
            let stats = MatrixStats::of(&a);
            let packed = ((j as u32) << 16) | n as u32;
            let key = ShapeKey::fused(&stats, packed);
            assert_ne!(
                key,
                ShapeKey::spmm(&stats, packed),
                "{fam} j={j} n={n}: scenario must separate the keys"
            );
            let fresh = selector
                .select_fused(&stats, j as u32, n as u32)
                .unwrap_or_else(|| panic!("{fam} j={j} n={n}: no fused plan"));
            assert!(fresh.is_fused(), "{fam} j={j} n={n}: selector returned {}", fresh.name());
            let (plan, hit) = cache.get_or_insert_with(key, || fresh);
            assert!(!hit, "{fam} j={j} n={n}: first sight must miss");
            let (plan2, hit2) = cache.get_or_insert_with(key, || unreachable!("hit expected"));
            assert!(hit2 && plan2 == plan, "{fam} j={j} n={n}: repeat must hit the same plan");
            assert_eq!(plan2.kind, fresh, "cached plan must be the selector's choice");

            let (x1, x2, b) = operands(&a, j, n, 29 + (j + n) as u64);
            let via_cache = plan2.kind.run_fused(&machine, &a, &x1, &x2, &b).unwrap();
            let via_fresh = fresh.run_fused(&machine, &a, &x1, &x2, &b).unwrap();
            assert_eq!(
                via_cache.run.c, via_fresh.run.c,
                "{fam} j={j} n={n}: cache path diverged from fresh selection"
            );
            let want = fused_serial(&a, &x1, &x2, &b, j, n);
            let err = max_rel_err(&via_cache.run.c, &want);
            assert!(err < TOL, "{fam} j={j} n={n}: selected {} err {err}", fresh.name());
        }
    }
    let s = cache.stats();
    assert_eq!(s.misses as usize, WIDTHS.len() * 5);
    assert_eq!(s.hits, s.misses);
}
