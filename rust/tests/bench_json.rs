//! The `BENCH_*.json` perf trajectory: schema validation of the committed
//! files (repo root) and of a live `sgap bench --quick` run, plus the
//! blessed regeneration flow.
//!
//! The committed files pin the *schema and invariants*, not the exact
//! simulated numbers — cost-model calibration legitimately moves the
//! times, so refreshing them is a blessed operation:
//! `SGAP_BLESS=1 cargo test --test bench_json` (equivalently
//! `cargo run --release -- bench --quick --out ..` from `rust/`).

use std::path::PathBuf;

use sgap::bench_util::{
    fused_suite, run_spmm_bench, run_tensor_bench, skew_suite, validate_bench_json,
    BENCH_SCHEMA_VERSION,
};
use sgap::sim::{HwProfile, Machine};
use sgap::tuner::DEFAULT_TOP_K;

fn committed(name: &str) -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

/// With `SGAP_BLESS=1`, regenerate the committed file from a live quick
/// run; otherwise validate what is committed.
fn check_or_bless(suite: &'static str) {
    let path = committed(&format!("BENCH_{suite}.json"));
    let machine = Machine::new(HwProfile::rtx3090());
    if std::env::var_os("SGAP_BLESS").is_some() {
        let report = match suite {
            "spmm" => run_spmm_bench(&machine, true, DEFAULT_TOP_K).unwrap(),
            "tensor" => run_tensor_bench(&machine, true, DEFAULT_TOP_K).unwrap(),
            other => panic!("unknown suite {other}"),
        };
        report.write(&path).unwrap_or_else(|e| panic!("bless {}: {e}", path.display()));
        return;
    }
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing committed {}: {e}\n(regenerate with `SGAP_BLESS=1 cargo test --test \
             bench_json` or `sgap bench --quick`)",
            path.display()
        )
    });
    validate_bench_json(&src, suite).unwrap_or_else(|e| {
        panic!("committed {} fails the documented schema: {e}", path.display())
    });
}

#[test]
fn committed_spmm_report_matches_schema() {
    check_or_bless("spmm");
}

#[test]
fn committed_tensor_report_matches_schema() {
    check_or_bless("tensor");
}

#[test]
fn committed_reports_cover_the_quick_suites() {
    if std::env::var_os("SGAP_BLESS").is_some() {
        return; // the blessing tests above rewrite the files this run
    }
    let spmm = std::fs::read_to_string(committed("BENCH_spmm.json")).unwrap();
    // every quick-suite matrix appears, in both the families and the
    // dgsparse tables
    for d in sgap::sparse::dataset::mini_suite() {
        assert_eq!(
            spmm.matches(&format!("\"{}\"", d.name)).count(),
            2,
            "{} must appear once per spmm table",
            d.name
        );
    }
    for bench in ["\"families\"", "\"dgsparse\"", "\"skew\"", "\"fused\""] {
        assert!(spmm.contains(bench), "missing {bench} rows");
    }
    // every fused-suite matrix has its fused row committed
    for d in fused_suite() {
        assert!(spmm.contains(&format!("\"{}\"", d.name)), "{} missing a fused row", d.name);
    }
    let tensor = std::fs::read_to_string(committed("BENCH_tensor.json")).unwrap();
    for bench in ["\"mttkrp\"", "\"ttm\""] {
        assert!(tensor.contains(bench), "missing {bench} rows");
    }
}

#[test]
fn live_quick_bench_round_trips_through_the_schema_gate() {
    let machine = Machine::new(HwProfile::rtx3090());
    let report = run_spmm_bench(&machine, true, DEFAULT_TOP_K).unwrap();
    // two tables per quick-suite matrix, plus the analytic skew and
    // fused tables (emitted in quick mode too)
    assert_eq!(
        report.rows.len(),
        2 * sgap::sparse::dataset::mini_suite().len() + skew_suite().len() + fused_suite().len()
    );
    let json = report.to_json();
    validate_bench_json(&json, "spmm").unwrap();
    assert!(json.contains(&format!("\"schema_version\": {BENCH_SCHEMA_VERSION}")));
    // pruning really happened: every families row simulated at most K of
    // its grid
    for row in report.rows.iter().filter(|r| r.bench == "families") {
        assert!(row.survivors <= DEFAULT_TOP_K && row.grid > row.survivors, "{row:?}");
    }
    // the tuned winner never loses to the stock baseline by definition of
    // a sweep that contains near-stock points — allow the documented
    // prune ratio of slack
    for row in &report.rows {
        assert!(
            row.speedup_vs_baseline > 1.0 / 1.5,
            "{}: tuned kernel {}x slower than stock",
            row.matrix,
            1.0 / row.speedup_vs_baseline
        );
    }
    // the fused table's own invariants: one row per fused-suite matrix,
    // fusion never prices above the two-stage pipeline, and the
    // footprint-amortization point clears the 1.5x headline
    let fused: Vec<_> = report.rows.iter().filter(|r| r.bench == "fused").collect();
    assert_eq!(fused.len(), fused_suite().len());
    for row in &fused {
        assert!(
            row.speedup_vs_baseline >= 1.0,
            "{}: fused priced above the two-stage pipeline",
            row.matrix
        );
        assert!(row.baseline.contains(" + "), "{}: baseline is not a pipeline", row.matrix);
    }
    assert!(
        fused.iter().any(|r| r.speedup_vs_baseline >= 1.5),
        "no fused row at >= 1.5x over the two-stage pipeline"
    );

    let tensor = run_tensor_bench(&machine, true, DEFAULT_TOP_K).unwrap();
    validate_bench_json(&tensor.to_json(), "tensor").unwrap();
    assert!(tensor.rows.iter().any(|r| r.bench == "mttkrp"));
    assert!(tensor.rows.iter().any(|r| r.bench == "ttm"));
}

#[test]
fn validator_rejects_drift() {
    let machine = Machine::new(HwProfile::rtx3090());
    let report = run_tensor_bench(&machine, true, 4).unwrap();
    let json = report.to_json();
    validate_bench_json(&json, "tensor").unwrap();
    // wrong suite name
    assert!(validate_bench_json(&json, "spmm").is_err());
    // dropped field
    let dropped = json.replacen("      \"gflops\"", "      \"gflopz\"", 1);
    assert!(validate_bench_json(&dropped, "tensor").is_err(), "renamed row field accepted");
    // injected top-level field
    let injected = json.replacen("  \"suite\"", "  \"extra\": 1,\n  \"suite\"", 1);
    assert!(validate_bench_json(&injected, "tensor").is_err(), "extra top-level field accepted");
    // corrupted speedup ratio
    let bad = json.replacen("\"speedup_vs_baseline\": ", "\"speedup_vs_baseline\": 99", 1);
    assert!(validate_bench_json(&bad, "tensor").is_err(), "inconsistent speedup accepted");
}
