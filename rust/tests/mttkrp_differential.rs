//! Differential property tests for the COO-3 tensor kernels that complete
//! the §2.1 quartet: every MTTKRP/TTM candidate the tuner sweeps matches
//! the serial oracle over tensor shapes × dense widths, and the
//! coordinator's plan-cache path is result-identical to fresh selection —
//! mirroring `spmm_differential.rs` for the two new scenarios.

use sgap::algos::cpu_ref::max_rel_err;
use sgap::algos::mttkrp::{mttkrp_serial, ttm_serial};
use sgap::coordinator::{PlanCache, ShapeKey};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{Coo3, SplitMix64};
use sgap::tuner::{mttkrp_candidates, ttm_candidates, Selector};

const TOL: f32 = 5e-4;

/// j = 1 is the degenerate single-column case; 8 and 32 bracket the
/// grouped reduction widths (32 forces r = npb-capped groups at c = 1).
const WIDTHS: [usize; 3] = [1, 8, 32];

/// Tensor shapes spanning the structures the selector keys on: uniform,
/// tall-skinny (long segments), wide-flat (short fibers), and a hub
/// tensor with every non-zero in one output row (the skew corner).
fn tensors(seed: u64) -> Vec<(&'static str, Coo3)> {
    let hub: Vec<(u32, u32, u32, f32)> =
        (0..300u32).map(|p| (0, p % 24, (p * 7 + p / 24) % 16, 1.0 - p as f32 * 0.01)).collect();
    vec![
        ("uniform", Coo3::random((40, 30, 20), 600, seed)),
        ("tall", Coo3::random((8, 32, 32), 700, seed ^ 1)),
        ("flat", Coo3::random((64, 48, 4), 500, seed ^ 2)),
        ("hub", Coo3::new((32, 24, 16), hub)),
    ]
}

fn dense(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.value()).collect()
}

#[test]
fn every_mttkrp_candidate_matches_oracle_across_tensors_j() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &j in &WIDTHS {
        for (fam, a) in tensors(0x3AA ^ j as u64) {
            let x1 = dense(a.dim1 * j, 5 + j as u64);
            let x2 = dense(a.dim2 * j, 9 + j as u64);
            let want = mttkrp_serial(&a, &x1, &x2, j);
            let cands = mttkrp_candidates(j as u32);
            assert!(!cands.is_empty(), "no candidates for j={j}");
            for alg in cands {
                let res = alg.run_mttkrp(&machine, &a, &x1, &x2).unwrap_or_else(|e| {
                    panic!("{fam} j={j}: {} failed: {e}", alg.name())
                });
                let err = max_rel_err(&res.run.c, &want);
                assert!(
                    err < TOL,
                    "{fam} j={j}: {} err {err} (tensor {}x{}x{} nnz {})",
                    alg.name(),
                    a.dim0,
                    a.dim1,
                    a.dim2,
                    a.nnz()
                );
            }
        }
    }
}

#[test]
fn every_ttm_candidate_matches_oracle_across_tensors_l() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &l in &WIDTHS {
        for (fam, a) in tensors(0x77A ^ l as u64) {
            let x1 = dense(a.dim2 * l, 13 + l as u64);
            let want = ttm_serial(&a, &x1, l);
            let cands = ttm_candidates(l as u32);
            assert!(!cands.is_empty(), "no candidates for l={l}");
            for alg in cands {
                let res = alg.run_ttm(&machine, &a, &x1).unwrap_or_else(|e| {
                    panic!("{fam} l={l}: {} failed: {e}", alg.name())
                });
                let err = max_rel_err(&res.run.c, &want);
                assert!(
                    err < TOL,
                    "{fam} l={l}: {} err {err} (tensor {}x{}x{} nnz {})",
                    alg.name(),
                    a.dim0,
                    a.dim1,
                    a.dim2,
                    a.nnz()
                );
            }
        }
    }
}

/// The tensor plan-cache path is result-identical to fresh selection, and
/// the two tensor scenarios never collide into each other (or into SpMM).
#[test]
fn tensor_plan_cache_path_equals_fresh_selection() {
    let machine = Machine::new(HwProfile::rtx3090());
    let selector = Selector::default();
    let cache = PlanCache::new(64);
    for &j in &WIDTHS {
        for (fam, a) in tensors(0xCAFE ^ j as u64) {
            let mkey = ShapeKey::mttkrp(&a, j as u32);
            let tkey = ShapeKey::ttm(&a, j as u32);
            assert_ne!(mkey, tkey, "{fam} j={j}: scenario must separate the keys");

            let fresh = selector.select_mttkrp(&a, j as u32).expect("legal width");
            assert!(fresh.is_mttkrp(), "{fam} j={j}: selector returned {}", fresh.name());
            let (plan, hit) = cache.get_or_insert_with(mkey, || fresh);
            assert!(!hit, "{fam} j={j}: first sight must miss");
            let (plan2, hit2) = cache.get_or_insert_with(mkey, || unreachable!("hit expected"));
            assert!(hit2 && plan2 == plan, "{fam} j={j}: repeat must hit the same plan");
            assert_eq!(plan2.kind, fresh, "cached plan must be the selector's choice");

            let x1 = dense(a.dim1 * j, 17 + j as u64);
            let x2 = dense(a.dim2 * j, 19 + j as u64);
            let via_cache = plan2.kind.run_mttkrp(&machine, &a, &x1, &x2).unwrap();
            let via_fresh = fresh.run_mttkrp(&machine, &a, &x1, &x2).unwrap();
            assert_eq!(
                via_cache.run.c, via_fresh.run.c,
                "{fam} j={j}: cache path diverged from fresh selection"
            );
            let want = mttkrp_serial(&a, &x1, &x2, j);
            assert!(max_rel_err(&via_cache.run.c, &want) < TOL, "{fam} j={j}");

            let tfresh = selector.select_ttm(&a, j as u32).expect("legal width");
            assert!(tfresh.is_ttm());
            let (tplan, thit) = cache.get_or_insert_with(tkey, || tfresh);
            assert!(!thit, "{fam} j={j}: ttm first sight must miss");
            let lx1 = dense(a.dim2 * j, 23 + j as u64);
            let via_cache = tplan.kind.run_ttm(&machine, &a, &lx1).unwrap();
            let want = ttm_serial(&a, &lx1, j);
            assert!(max_rel_err(&via_cache.run.c, &want) < TOL, "{fam} j={j} (ttm)");
        }
    }
    let s = cache.stats();
    assert_eq!(s.misses as usize, WIDTHS.len() * 4 * 2);
    assert_eq!(s.hits as usize, WIDTHS.len() * 4);
}
