//! Pruning-fidelity properties of the analytic cost model
//! (`tuner::model`) — the invariants DESIGN.md §cost-model-vs-analytic
//! documents:
//!
//! 1. **Containment-or-ratio**: over the dataset suite, the model-pruned
//!    top-K shortlist (K = `DEFAULT_TOP_K`) either contains the
//!    exhaustive-search winner, or the pruned winner's simulated time is
//!    within `PRUNE_RATIO` of the exhaustive winner's.
//! 2. **Rank correlation**: the model's candidate ranking correlates
//!    positively with the simulator's (mean Spearman ρ over the suite at
//!    least `MIN_MEAN_SPEARMAN`).

use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{dataset, Coo3, MatrixStats, SplitMix64};
use sgap::tuner::{self, CostModel, Workload, DEFAULT_TOP_K};

/// The stated time ratio of invariant 1 (conservative bound; the
/// coordinator's `tune_model_agree / tunes` counter tracks the typical
/// case, which is exact agreement).
const PRUNE_RATIO: f64 = 1.5;

/// The stated rank-correlation floor of invariant 2.
const MIN_MEAN_SPEARMAN: f64 = 0.2;

fn b_for(cols: usize, n: u32, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..cols * n as usize).map(|_| rng.value()).collect()
}

#[test]
fn pruned_spmm_winner_matches_or_stays_within_ratio() {
    let machine = Machine::new(HwProfile::rtx3090());
    let n = 4u32;
    let mut cands = tuner::taco_candidates(n);
    cands.extend(tuner::sgap_candidates(n));
    for d in dataset::mini_suite() {
        let a = d.matrix.to_csr();
        let b = b_for(a.cols, n, 17);
        let full = tuner::tune(&machine, &cands, &a, &b, n).unwrap();
        let (winner, t_full) = full.best().unwrap();
        let pruned = tuner::tune_pruned(&machine, &cands, &a, &b, n, DEFAULT_TOP_K).unwrap();
        assert_eq!(pruned.grid, cands.len(), "{}", d.name);
        assert!(pruned.survivors <= DEFAULT_TOP_K, "{}", d.name);
        let (_, t_pruned) = pruned.best().unwrap();
        let contained = pruned.outcome.ranked.iter().any(|(a, _, _)| *a == winner);
        assert!(
            contained || t_pruned <= PRUNE_RATIO * t_full + 1e-15,
            "{}: winner {} pruned away and shortlist best {:.3}us > {PRUNE_RATIO}x \
             exhaustive best {:.3}us",
            d.name,
            winner.name(),
            t_pruned * 1e6,
            t_full * 1e6,
        );
    }
}

#[test]
fn pruned_dg_winner_matches_or_stays_within_ratio() {
    let machine = Machine::new(HwProfile::rtx3090());
    let n = 4u32;
    let cands = tuner::space::dg_candidates_small(n);
    for d in dataset::mini_suite().into_iter().take(2) {
        let a = d.matrix.to_csr();
        let b = b_for(a.cols, n, 41);
        let full = tuner::tune(&machine, &cands, &a, &b, n).unwrap();
        let (winner, t_full) = full.best().unwrap();
        let pruned = tuner::tune_pruned(&machine, &cands, &a, &b, n, DEFAULT_TOP_K).unwrap();
        let (_, t_pruned) = pruned.best().unwrap();
        let contained = pruned.outcome.ranked.iter().any(|(a, _, _)| *a == winner);
        assert!(
            contained || t_pruned <= PRUNE_RATIO * t_full + 1e-15,
            "{}: dg winner {} pruned away ({:.3}us vs {:.3}us)",
            d.name,
            winner.name(),
            t_pruned * 1e6,
            t_full * 1e6,
        );
    }
}

#[test]
fn pruned_tensor_winners_match_or_stay_within_ratio() {
    let machine = Machine::new(HwProfile::rtx3090());
    let j = 8u32;
    let mut rng = SplitMix64::new(5);
    for (name, t) in [
        ("uniform", Coo3::random((64, 48, 32), 2000, 1)),
        ("sparse-rows", Coo3::random((256, 32, 32), 600, 2)),
    ] {
        let x1: Vec<f32> = (0..t.dim1 * j as usize).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..t.dim2 * j as usize).map(|_| rng.value()).collect();
        let cands = tuner::mttkrp_candidates(j);
        let full = tuner::tune_mttkrp_ranked(&machine, &cands, &t, &x1, &x2).unwrap();
        let (winner, t_full) = full.best().unwrap();
        let pruned =
            tuner::tune_mttkrp_pruned(&machine, &cands, &t, &x1, &x2, DEFAULT_TOP_K).unwrap();
        let (_, t_pruned) = pruned.best().unwrap();
        let contained = pruned.outcome.ranked.iter().any(|(a, _, _)| *a == winner);
        assert!(
            contained || t_pruned <= PRUNE_RATIO * t_full + 1e-15,
            "mttkrp {name}: winner {} pruned away",
            winner.name()
        );

        let lx1: Vec<f32> = (0..t.dim2 * j as usize).map(|_| rng.value()).collect();
        let cands = tuner::ttm_candidates(j);
        let full = tuner::tune_ttm_ranked(&machine, &cands, &t, &lx1).unwrap();
        let (winner, t_full) = full.best().unwrap();
        let pruned = tuner::tune_ttm_pruned(&machine, &cands, &t, &lx1, DEFAULT_TOP_K).unwrap();
        let (_, t_pruned) = pruned.best().unwrap();
        let contained = pruned.outcome.ranked.iter().any(|(a, _, _)| *a == winner);
        assert!(
            contained || t_pruned <= PRUNE_RATIO * t_full + 1e-15,
            "ttm {name}: winner {} pruned away",
            winner.name()
        );
    }
}

#[test]
fn pruned_sddmm_winner_matches_or_stays_within_ratio() {
    let machine = Machine::new(HwProfile::rtx3090());
    let j = 16usize;
    let a = sgap::sparse::erdos_renyi(96, 96, 700, 5).to_csr();
    let mut rng = SplitMix64::new(4);
    let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
    let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
    let cands = tuner::sddmm_candidates(j as u32);
    let full = tuner::tune_sddmm_ranked(&machine, &cands, &a, &x1, &x2).unwrap();
    let (winner, t_full) = full.best().unwrap();
    let pruned =
        tuner::tune_sddmm_pruned(&machine, &cands, &a, &x1, &x2, DEFAULT_TOP_K).unwrap();
    let (_, t_pruned) = pruned.best().unwrap();
    let contained = pruned.outcome.ranked.iter().any(|(c, _, _)| *c == winner);
    assert!(
        contained || t_pruned <= PRUNE_RATIO * t_full + 1e-15,
        "sddmm winner {} pruned away",
        winner.name()
    );
}

/// Spearman rank correlation between two equally-long samples.
fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    fn ranks(v: &[f64]) -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut r = vec![0.0; v.len()];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    }
    let (rx, ry) = (ranks(xs), ranks(ys));
    let n = xs.len() as f64;
    let mean = (n - 1.0) / 2.0;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..xs.len() {
        cov += (rx[i] - mean) * (ry[i] - mean);
        vx += (rx[i] - mean).powi(2);
        vy += (ry[i] - mean).powi(2);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

#[test]
fn model_ranking_correlates_with_the_simulator() {
    let machine = Machine::new(HwProfile::rtx3090());
    let model = CostModel::new(&machine);
    let n = 4u32;
    let mut cands = tuner::taco_candidates(n);
    cands.extend(tuner::sgap_candidates(n));
    let mut rhos = Vec::new();
    for d in dataset::mini_suite() {
        let a = d.matrix.to_csr();
        let stats = MatrixStats::of(&a);
        let b = b_for(a.cols, n, 17);
        let sim = tuner::tune(&machine, &cands, &a, &b, n).unwrap();
        let workload = Workload::Spmm { stats: &stats, n };
        let (mut model_t, mut sim_t) = (Vec::new(), Vec::new());
        for c in &cands {
            model_t.push(model.price(c, &workload).unwrap());
            sim_t.push(sim.time_of(c).unwrap());
        }
        let rho = spearman(&model_t, &sim_t);
        println!("{:<26} spearman {:.3}", d.name, rho);
        rhos.push(rho);
    }
    let mean = rhos.iter().sum::<f64>() / rhos.len() as f64;
    assert!(
        mean >= MIN_MEAN_SPEARMAN,
        "mean Spearman {mean:.3} below the documented floor {MIN_MEAN_SPEARMAN} ({rhos:?})"
    );
}
