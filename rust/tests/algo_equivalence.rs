//! Property tests (in-house, seeded — proptest is not in the offline
//! dependency set): every algorithm point computes the same SpMM as the
//! serial oracle over randomized matrices, shapes and configurations;
//! format round-trips preserve the matrix.

use sgap::algos::catalog::Algo;
use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::algos::dgsparse::DgConfig;
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{erdos_renyi, power_law, Coo, SplitMix64};

const CASES: usize = 30;

fn random_matrix(rng: &mut SplitMix64) -> sgap::sparse::Csr {
    let rows = 16 + rng.below(200) as usize;
    let cols = 16 + rng.below(200) as usize;
    let density = 0.002 + rng.uniform() * 0.2;
    let nnz = ((rows * cols) as f64 * density) as usize;
    if rng.below(2) == 0 {
        erdos_renyi(rows, cols, nnz.max(1), rng.next_u64()).to_csr()
    } else {
        power_law(rows, cols, nnz.max(1), 1.2 + rng.uniform(), rng.next_u64()).to_csr()
    }
}

#[test]
fn prop_compiler_kernels_match_oracle() {
    let machine = Machine::new(HwProfile::rtx3090());
    let mut rng = SplitMix64::new(0xA11CE);
    for case in 0..CASES {
        let a = random_matrix(&mut rng);
        let n = [1usize, 2, 4, 8][rng.below(4) as usize] as u32;
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, n as usize);

        let c_opts: Vec<u32> =
            [1u32, 2, 4].into_iter().filter(|c| n % c == 0 && 256 % (n / c) == 0).collect();
        let c = c_opts[rng.below(c_opts.len() as u64) as usize];
        let r = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
        let g = [2u32, 4, 8, 16, 32][rng.below(5) as usize];

        let mut algos = vec![
            Algo::SgapNnzGroup { c, r },
            Algo::TacoNnzSerial { g, c },
            Algo::TacoRowSerial { x: 1 + rng.below(3) as u32, c },
        ];
        if r <= g && 256 % (g * (n / c)) == 0 {
            algos.push(Algo::SgapRowGroup { g, c, r });
        }
        for alg in algos {
            let res = alg.run(&machine, &a, &b, n).unwrap_or_else(|e| {
                panic!("case {case}: {} failed: {e}", alg.name())
            });
            let err = max_rel_err(&res.run.c, &want);
            assert!(
                err < 5e-4,
                "case {case}: {} err {err} (matrix {}x{} nnz {} n {n})",
                alg.name(),
                a.rows,
                a.cols,
                a.nnz()
            );
        }
    }
}

#[test]
fn prop_dgsparse_matches_oracle() {
    let machine = Machine::new(HwProfile::v100());
    let mut rng = SplitMix64::new(0xD6);
    for case in 0..CASES {
        let a = random_matrix(&mut rng);
        let n = [4u32, 16][rng.below(2) as usize];
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, n as usize);
        let group_sz = [2u32, 4, 8, 16, 32][rng.below(5) as usize];
        let tile_sz = [8u32, 16, 32, 64][rng.below(4) as usize].max(group_sz);
        let cfg = DgConfig {
            n,
            group_sz,
            block_sz: [128u32, 256, 512][rng.below(3) as usize],
            tile_sz,
            worker_dim_r_frac: [0.25, 0.5, 1.0, 2.0][rng.below(4) as usize],
            worker_sz: 32,
            coarsen_sz: if n.min(tile_sz) % 4 == 0 { 4 } else { 2 },
        };
        if cfg.validate().is_err() {
            continue;
        }
        let res = Algo::Dg(cfg).run(&machine, &a, &b, n).unwrap();
        let err = max_rel_err(&res.run.c, &want);
        assert!(err < 5e-4, "case {case}: dg cfg {cfg:?} err {err}");
    }
}

#[test]
fn prop_format_round_trips() {
    let mut rng = SplitMix64::new(0xF0);
    for _ in 0..CASES {
        let a = random_matrix(&mut rng);
        a.check_invariants().unwrap();
        // CSR -> COO -> CSR
        assert_eq!(a.to_coo().to_csr(), a);
        // CSR -> ELL -> dense equals CSR -> dense
        let slots = a.max_row_degree().max(1);
        assert_eq!(a.to_ell(slots).to_dense(), a.to_dense());
        // MatrixMarket round trip
        let mut buf = Vec::new();
        sgap::sparse::mtx::write_mtx(&mut buf, &a.to_coo()).unwrap();
        let back = sgap::sparse::mtx::read_mtx(buf.as_slice()).unwrap();
        assert_eq!(back.to_csr(), a);
    }
}

#[test]
fn prop_simulated_time_is_positive_and_deterministic() {
    let machine = Machine::new(HwProfile::rtx2080());
    let mut rng = SplitMix64::new(0x7E57);
    for _ in 0..10 {
        let a = random_matrix(&mut rng);
        let b: Vec<f32> = (0..a.cols * 4).map(|_| rng.value()).collect();
        let alg = Algo::SgapNnzGroup { c: 4, r: 8 };
        let r1 = alg.run(&machine, &a, &b, 4).unwrap();
        let r2 = alg.run(&machine, &a, &b, 4).unwrap();
        assert!(r1.time_s > 0.0);
        assert_eq!(r1.time_s, r2.time_s, "simulated time must be deterministic");
        assert_eq!(r1.run.c, r2.run.c);
    }
}

#[test]
fn prop_identity_matrix_copies_b() {
    let mut rng = SplitMix64::new(0x1D);
    for _ in 0..5 {
        let n_rows = 32 + rng.below(100) as usize;
        let eye = Coo::new(
            n_rows,
            n_rows,
            (0..n_rows as u32).map(|i| (i, i, 1.0f32)).collect(),
        )
        .to_csr();
        let b: Vec<f32> = (0..n_rows * 4).map(|_| rng.value()).collect();
        let machine = Machine::new(HwProfile::rtx3090());
        let res = Algo::SgapNnzGroup { c: 4, r: 32 }.run(&machine, &eye, &b, 4).unwrap();
        assert!(max_rel_err(&res.run.c, &b) < 1e-6);
    }
}
