//! Serving at scale: the concurrency suite behind DESIGN.md
//! §serving-at-scale.
//!
//! Three scenarios against one shared [`Coordinator`]:
//!
//! * a **64-session mixed-quartet soak** — every ticket resolves (no
//!   deadlock, no lost `Ticket`), cross-session coalescing actually
//!   fires (`coalesced > 0`), the sharded plan cache fingerprints each
//!   distinct shape exactly once, the device pool uploads each operand
//!   handle exactly once (steady-state resubmits re-upload nothing), and
//!   the final snapshot carries per-`OpKind` p50/p99 SLO gauges;
//! * **admission control under an undersized queue** — `try_submit`
//!   sheds load with the typed `OpError::Overloaded { depth, cap }`,
//!   depth stays bounded by the cap throughout the storm, and every
//!   *accepted* ticket still completes;
//! * **warm start end-to-end** — a second coordinator started from the
//!   first one's persisted [`PlanCatalog`] replays the same trace with
//!   zero selector misses and `warm_hits > 0`.
//!
//! `SGAP_SOAK_QUICK=1` shrinks the soak for CI's quick lane; the
//! default sizes are the ones the issue's acceptance bullet names.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use sgap::coordinator::{Coordinator, CoordinatorConfig, Op, OpError, OpKind, PlanCatalog, Session};
use sgap::sparse::{erdos_renyi, power_law, Coo3, SplitMix64};

fn dense(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.value()).collect()
}

fn quick() -> bool {
    std::env::var_os("SGAP_SOAK_QUICK").is_some()
}

/// The shared mixed workload: the §2.1 quartet plus the fused chain,
/// over handles registered once — so ops built by different sessions
/// carry *identical* `ShapeKey`s and are eligible for cross-session
/// coalescing and cache sharing. Returns six distinct-shape ops.
fn mixed_workload(session: &Session) -> Vec<Op> {
    let a1 = session.register_matrix(erdos_renyi(64, 56, 500, 11).to_csr());
    let b1 = session.register_dense(dense(56 * 4, 1));
    let a2 = session.register_matrix(power_law(96, 96, 1400, 1.9, 3).to_csr());
    let b2 = session.register_dense(dense(96 * 4, 2));
    let a3 = session.register_matrix(erdos_renyi(48, 40, 320, 12).to_csr());
    let x1 = session.register_dense(dense(48 * 8, 3));
    let x2 = session.register_dense(dense(8 * 40, 4));
    let t = session.register_tensor(Coo3::random((28, 20, 14), 350, 13));
    let f1 = session.register_dense(dense(20 * 8, 5));
    let f2 = session.register_dense(dense(14 * 8, 6));
    let tx = session.register_dense(dense(14 * 4, 7));
    let fa = session.register_dense(dense(64 * 8, 8));
    let fb = session.register_dense(dense(8 * 56, 9));
    vec![
        Op::spmm(&a1, &b1, 4),
        Op::spmm(&a2, &b2, 4),
        Op::sddmm(&a3, &x1, &x2, 8),
        Op::mttkrp(&t, &f1, &f2, 8),
        Op::ttm(&t, &tx, 4),
        Op::fused(&a1, &fa, &fb, &b1, 8, 4),
    ]
}

/// 64 concurrent sessions sharing one coordinator, each burst-submitting
/// mixed-quartet traffic built from shared registrations. Every ticket
/// resolves `Ok` (no deadlock, no lost ticket), same-shape ops from
/// different sessions coalesce into shared batches, each distinct shape
/// fingerprints exactly once across all 64 sessions, and the final
/// snapshot reports per-`OpKind` latency quantiles.
#[test]
fn soak_64_sessions_mixed_quartet() {
    let sessions = 64usize;
    let per_session = if quick() { 4 } else { 16 };
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            workers: 4,
            max_batch: 8,
            queue_cap: 256,
            background_tune: false,
            ..CoordinatorConfig::default()
        })
        .unwrap(),
    );
    let root = Session::with(coord.clone());
    let ops = mixed_workload(&root);
    let shapes = ops.len();

    let mut handles = Vec::new();
    for s in 0..sessions {
        let session = Session::with(coord.clone());
        let ops = ops.clone();
        handles.push(std::thread::spawn(move || {
            // burst-submit first (tickets pile up in the queue and the
            // shared batcher, where same-shape traffic coalesces), then
            // wait — a lost ticket would hang here, a dropped one errors
            let mut tickets = Vec::new();
            for i in 0..per_session {
                tickets.push(session.submit(ops[(s + i) % ops.len()].clone()));
            }
            for (i, t) in tickets.into_iter().enumerate() {
                let resp = t.wait().unwrap_or_else(|e| panic!("session {s} op {i}: {e}"));
                assert!(!resp.c.is_empty(), "session {s} op {i}: empty output");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.submitted, (sessions * per_session) as u64);
    assert_eq!(snap.completed, snap.submitted, "no ticket lost, none served twice");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rejected, 0, "blocking submit never sheds load");
    assert!(
        snap.coalesced > 0,
        "64 sessions x shared shapes must coalesce at least once (got {})",
        snap.coalesced
    );
    assert_eq!(
        snap.cache_misses, shapes as u64,
        "each distinct shape fingerprints exactly once across all sessions"
    );
    assert!(snap.cache_hits + snap.warm_hits > 0);
    // per-OpKind SLO gauges: every kind served, quantiles ordered
    for kind in OpKind::ALL {
        let o = snap
            .ops
            .iter()
            .find(|o| o.op == kind.label())
            .unwrap_or_else(|| panic!("no per-op gauge for {kind}"));
        assert!(o.count > 0, "{kind}: empty gauge");
        assert!(o.p50_us <= o.p99_us, "{kind}: p50 {} > p99 {}", o.p50_us, o.p99_us);
    }
    // device pool: the 13 registered operand handles upload exactly once
    // across all 64 sessions; every resubmit pins the staged image
    assert_eq!(snap.pool_misses, 13, "one upload per distinct operand handle");
    assert!(snap.uploads_skipped > 0, "steady-state resubmits must skip the upload");
    assert_eq!(snap.pool_hits, snap.uploads_skipped);
    assert!(snap.pool_bytes_live <= 64u64 << 20, "residency stays inside the default budget");
    assert_eq!(coord.queue_depth(), 0, "drained queue");

    root.shutdown();
    Arc::try_unwrap(coord).ok().expect("all sessions released the pool").shutdown();
}

/// Admission control: against a deliberately undersized queue, a storm
/// of non-blocking submits is shed with the typed overload error (depth
/// bounded by the cap — observed both in the error payload and by
/// sampling live queue depth), while every accepted ticket still
/// completes and the books balance exactly.
#[test]
fn try_submit_sheds_load_with_bounded_depth() {
    let cap = 2usize;
    let threads = 16usize;
    let attempts = if quick() { 30 } else { 120 };
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: cap,
            background_tune: false,
            ..CoordinatorConfig::default()
        })
        .unwrap(),
    );
    let root = Session::with(coord.clone());
    let a = root.register_matrix(power_law(64, 64, 900, 1.8, 5).to_csr());
    let b = root.register_dense(dense(64 * 4, 21));

    let accepted = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let storming = Arc::new(AtomicBool::new(true));

    // main thread samples live depth throughout the storm: structurally
    // bounded by the cap, never by luck
    let sampler = {
        let (coord, storming) = (coord.clone(), storming.clone());
        std::thread::spawn(move || {
            let mut max_seen = 0;
            while storming.load(Ordering::Acquire) {
                let d = coord.queue_depth();
                assert!(d <= cap, "live queue depth {d} exceeds cap {cap}");
                max_seen = max_seen.max(d);
                std::thread::yield_now();
            }
            max_seen
        })
    };

    let mut handles = Vec::new();
    for s in 0..threads {
        let session = Session::with(coord.clone());
        let (a, b) = (a.clone(), b.clone());
        let (accepted, rejected) = (accepted.clone(), rejected.clone());
        handles.push(std::thread::spawn(move || {
            let mut tickets = Vec::new();
            for i in 0..attempts {
                match session.try_submit(Op::spmm(&a, &b, 4)) {
                    Ok(t) => {
                        accepted.fetch_add(1, Ordering::Relaxed);
                        tickets.push(t);
                    }
                    Err(OpError::Overloaded { depth, cap: seen_cap }) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        assert_eq!(seen_cap, cap, "thread {s} attempt {i}");
                        assert!(depth <= cap, "thread {s} attempt {i}: depth {depth} > cap {cap}");
                    }
                    Err(e) => panic!("thread {s} attempt {i}: unexpected error {e}"),
                }
            }
            // accepted work is never dropped: each ticket resolves Ok
            for (i, t) in tickets.into_iter().enumerate() {
                t.wait().unwrap_or_else(|e| panic!("thread {s} accepted ticket {i}: {e}"));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    storming.store(false, Ordering::Release);
    let max_depth = sampler.join().unwrap();
    assert!(max_depth <= cap);

    let (accepted, rejected) = (accepted.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
    assert_eq!(accepted + rejected, threads * attempts, "every attempt accounted for");
    assert!(accepted > 0, "an empty queue must admit");
    assert!(rejected > 0, "a cap-{cap} queue under {threads}-thread storm must shed load");

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.rejected, rejected as u64, "one typed error per rejection");
    assert_eq!(snap.submitted, accepted as u64, "rejected ops never enter the books");
    assert_eq!(snap.completed, accepted as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(coord.queue_depth(), 0);

    root.shutdown();
    Arc::try_unwrap(coord).ok().expect("all sessions released the pool").shutdown();
}

/// Warm start end-to-end: serve a trace, persist the plan catalog to
/// disk, start a *second* coordinator from the file, replay the trace —
/// zero selector misses, `warm_hits > 0`, byte-identical re-save.
#[test]
fn plan_catalog_warm_start_round_trip() {
    // first life: cold coordinator serves the mixed trace
    let first = Session::start(CoordinatorConfig {
        workers: 2,
        background_tune: false,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let ops = mixed_workload(&first);
    for op in &ops {
        first.submit(op.clone()).wait().unwrap();
    }
    let catalog = PlanCatalog::from_cache(&first.coordinator().plan_cache);
    assert_eq!(catalog.len(), ops.len(), "one persisted plan per distinct shape");
    let snap1 = first.coordinator().metrics.snapshot();
    assert_eq!(snap1.cache_misses, ops.len() as u64);
    assert_eq!(snap1.warm_hits, 0, "a cold coordinator has nothing to be warm about");

    let dir = std::env::temp_dir().join(format!("sgap-serving-scale-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("PLANS.json");
    catalog.save(&path).unwrap();
    first.shutdown();

    // second life: warm-started from the persisted catalog
    let loaded = PlanCatalog::load(&path).unwrap();
    assert_eq!(loaded, catalog, "save → load is lossless");
    assert_eq!(loaded.to_json(), catalog.to_json(), "and byte-identical");
    let second = Session::start(CoordinatorConfig {
        workers: 2,
        background_tune: false,
        plans: Some(loaded),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for op in &ops {
        let resp = second.submit(op.clone()).wait().unwrap();
        assert!(resp.cache_hit, "replayed {} must hit the warmed cache", op.kind);
    }
    let snap2 = second.coordinator().metrics.snapshot();
    assert_eq!(snap2.cache_misses, 0, "zero selector misses on the replayed trace");
    assert_eq!(snap2.warm_hits, ops.len() as u64, "every replayed op hit a persisted plan");
    assert_eq!(snap2.cache_hits, ops.len() as u64);

    // the warmed cache re-persists to the same bytes (catalog order is
    // canonical, not arrival order)
    let resaved = PlanCatalog::from_cache(&second.coordinator().plan_cache);
    assert_eq!(resaved.to_json(), catalog.to_json());
    second.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
