//! Property tests for the device-buffer pool (DESIGN.md §memory-pool):
//! page recycling, LRU eviction under a byte budget, fingerprint
//! invalidation, leak accounting — and the coordinator-level guarantee
//! the pool exists for: resubmitting a registered handle skips the
//! upload (`uploads_skipped` grows, `pool_misses` does not).

use sgap::coordinator::{CoordinatorConfig, Op, Session};
use sgap::runtime::{DeviceImage, DevicePool, PoolKey};
use sgap::sparse::{erdos_renyi, SplitMix64};

fn key(uid: u64) -> PoolKey {
    PoolKey { uid, fp: uid.wrapping_mul(0x9e37_79b9_7f4a_7c15) }
}

/// A dense image of `words` f32 values (`4 * words` payload bytes).
fn dense(words: usize) -> DeviceImage {
    DeviceImage::Dense(vec![0.5; words])
}

/// alloc → release → alloc of a *different* key in the same size class
/// recycles the freed page instead of growing the pool, and the
/// displaced key is unmapped — re-acquiring it rebuilds rather than
/// aliasing the recycled page.
#[test]
fn realloc_recycles_the_freed_page() {
    let pool = DevicePool::new(1 << 20);
    drop(pool.acquire(key(1), || dense(100))); // 400 B -> 512 class
    let s0 = pool.stats();
    assert_eq!((s0.pages, s0.bytes_resident), (1, 512));

    let b = pool.acquire(key(2), || dense(120)); // 480 B -> same 512 class
    assert!(!b.hit());
    let s1 = pool.stats();
    assert_eq!(s1.pages, 1, "same-class realloc must reuse the free page");
    assert_eq!(s1.bytes_resident, 512, "no growth");
    assert_eq!(s1.evictions, 0, "recycling is not an eviction");
    drop(b);

    let a = pool.acquire(key(1), || dense(100));
    assert!(!a.hit(), "the displaced key must rebuild, never alias the recycled page");
    assert!(matches!(a.image(), DeviceImage::Dense(v) if v.len() == 100));
}

/// Budget overflow evicts *free* pages oldest-first, and only until the
/// budget fits again. The three images land in pairwise-distinct size
/// classes so same-class recycling cannot mask the eviction path.
#[test]
fn budget_overflow_evicts_lru_first() {
    let pool = DevicePool::new(3072);
    drop(pool.acquire(key(1), || dense(100))); // 512 class, oldest free
    drop(pool.acquire(key(2), || dense(200))); // 1024 class
    assert_eq!(pool.stats().bytes_resident, 1536);

    let c = pool.acquire(key(3), || dense(300)); // 2048 class -> 3584 resident
    let s = pool.stats();
    assert_eq!(s.evictions, 1, "evict only until the budget fits");
    assert_eq!(s.bytes_resident, 3072);
    drop(c);

    assert!(pool.acquire(key(2), || dense(200)).hit(), "the younger free page survived");
    assert!(!pool.acquire(key(1), || dense(100)).hit(), "the oldest free page was the victim");
}

/// Invalidation unmaps every page of the uid: the next acquire rebuilds
/// and re-uploads. A page invalidated while pinned stays resident until
/// its ref drops, then frees its bytes instead of going back on the
/// free list.
#[test]
fn invalidation_forces_reupload() {
    let pool = DevicePool::new(1 << 20);
    drop(pool.acquire(key(9), || dense(64)));
    assert_eq!(pool.invalidate(9), 1);
    let s = pool.stats();
    assert_eq!((s.pages, s.invalidations), (0, 1), "a free invalidated page leaves at once");

    let mut rebuilt = false;
    let pinned = pool.acquire(key(9), || {
        rebuilt = true;
        dense(64)
    });
    assert!(rebuilt && !pinned.hit(), "the unmapped key must re-upload");

    // invalidate while referenced: unmapped now, bytes freed on release
    assert_eq!(pool.invalidate(9), 1);
    assert_eq!(pool.stats().pages, 1, "the pinned page stays resident until released");
    let fresh = pool.acquire(key(9), || dense(64));
    assert!(!fresh.hit(), "a dead page can never satisfy a hit");
    assert_eq!(pool.stats().pages, 2);
    drop(pinned);
    assert_eq!(pool.stats().pages, 1, "the dead page frees on release instead of going free");
    drop(fresh);
    assert_eq!(pool.stats().bytes_live, 0);
}

/// Live-byte accounting balances: salted variants of one handle get
/// their own pages, and once every ref drops, `bytes_live` returns to
/// exactly zero while the images stay resident for the next submit.
#[test]
fn accounting_balances_to_zero_live_bytes() {
    let pool = DevicePool::new(1 << 20);
    let base = key(5);
    let keys = [base, base.salted(0xb0c), key(6)];
    let refs: Vec<_> = keys.into_iter().map(|k| pool.acquire(k, || dense(32))).collect();
    assert!(refs.iter().all(|r| !r.hit()), "three distinct keys, three uploads");
    let s = pool.stats();
    assert_eq!((s.pages, s.bytes_live), (3, 3 * 256));
    assert_eq!(s.bytes_live, s.bytes_resident, "every page is pinned");
    drop(refs);
    let s = pool.stats();
    assert_eq!(s.bytes_live, 0, "no leaked refs");
    assert_eq!((s.pages, s.bytes_resident), (3, 3 * 256), "images stay warm for the next submit");
}

/// End to end through the coordinator: the second submit of the same op
/// pins both operand images the first one staged — `uploads_skipped`
/// grows while `pool_misses` stays put.
#[test]
fn resubmit_skips_the_upload_through_the_coordinator() {
    let session = Session::start(CoordinatorConfig {
        workers: 1,
        background_tune: false,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let a = session.register_matrix(erdos_renyi(48, 40, 320, 7).to_csr());
    let mut rng = SplitMix64::new(3);
    let b = session.register_dense((0..40 * 4).map(|_| rng.value()).collect());
    let op = Op::spmm(&a, &b, 4);

    session.submit(op.clone()).wait().unwrap();
    let cold = session.coordinator().metrics.snapshot();
    assert_eq!(cold.pool_misses, 2, "first submit uploads the matrix and the dense operand");
    assert_eq!(cold.uploads_skipped, 0, "a cold pool has nothing staged");

    session.submit(op).wait().unwrap();
    let warm = session.coordinator().metrics.snapshot();
    assert_eq!(warm.pool_misses, cold.pool_misses, "steady state re-uploads nothing");
    assert_eq!(warm.uploads_skipped, 2, "both operand images were already on device");

    let pool = session.coordinator().pool.as_ref().expect("default config enables the pool");
    let ps = pool.stats();
    assert_eq!((ps.hits, ps.misses), (2, 2));
    assert!(ps.bytes_resident <= pool.budget_bytes(), "residency bounded by the budget");
    assert_eq!(ps.bytes_live, 0, "no refs outlive a run");
    session.shutdown();
}

/// `pool_budget_bytes: 0` disables pooling entirely: the coordinator
/// builds no pool and the counters stay at zero.
#[test]
fn zero_budget_disables_the_pool() {
    let session = Session::start(CoordinatorConfig {
        workers: 1,
        background_tune: false,
        pool_budget_bytes: 0,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    assert!(session.coordinator().pool.is_none());
    let a = session.register_matrix(erdos_renyi(32, 32, 200, 9).to_csr());
    let b = session.register_dense(vec![0.25; 32 * 4]);
    session.submit(Op::spmm(&a, &b, 4)).wait().unwrap();
    let snap = session.coordinator().metrics.snapshot();
    assert_eq!((snap.pool_hits, snap.pool_misses, snap.uploads_skipped), (0, 0, 0));
    session.shutdown();
}
