__global__ void fused_sddmm_spmm_c4_r16(int* __restrict__ i_blockStarts, int* __restrict__ A2_pos, int* __restrict__ A2_crd, float* __restrict__ A_vals, float* __restrict__ X1_vals, float* __restrict__ X2_vals, float* __restrict__ B_vals, float* __restrict__ C_vals, int A1_dimension, int A2_dimension, int B2_dimension, int J_dimension) {
  // fused sddmm→spmm {<1 nnz, 4 col>, 16} — in-register dot, one pos/crd pass
  int fpos1 = (threadIdx.x % 256);
  int ko = (threadIdx.x / 256);
  int fposA = ((blockIdx.x * 256) + fpos1);
  int pA2_begin = i_blockStarts[blockIdx.x];
  int pA2_end = i_blockStarts[(blockIdx.x + 1)];
  int i_pos = taco_binarySearchBefore(A2_pos, pA2_begin, pA2_end, fposA);
  int i = i_pos;
  float tlaneY = 0.0f;
  if ((fposA < A2_pos[A1_dimension])) {
    while ((fposA == A2_pos[(i_pos + 1)])) {
      i_pos = (i_pos + 1);
      i = i_pos;
    }
    int f = A2_crd[fposA];
    int l = 0;
    while ((l < J_dimension)) {
      tlaneY = (tlaneY + (X1_vals[((i * J_dimension) + l)] * X2_vals[((l * A2_dimension) + f)]));
      l = (l + 1);
    }
    tlaneY = (tlaneY * A_vals[fposA]);
  }
  for (int ki = 0; ki < 4; ki += 1) {
    int k = ((ko * 4) + ki);
    float val = 0.0f;
    if ((fposA >= A2_pos[A1_dimension])) {
      val = 0.0f;
    } else {
      int f = A2_crd[fposA];
      int kB = ((f * B2_dimension) + k);
      val = (tlaneY * B_vals[kB]);
    }
    int kC = ((i * B2_dimension) + k);
    segReduceGroup<float,16>(C_vals, kC, val);
  }
}
