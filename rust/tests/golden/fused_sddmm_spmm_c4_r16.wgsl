enable subgroups;
requires unrestricted_pointer_parameters;

// --- sgap macro instructions (§5.3), WGSL spelling ----------------------
// atomicAddF32: WGSL has no float atomics — emulate atomicAdd on an
// f32 cell stored as atomic<u32> with a bitcast compare-exchange loop.
fn atomicAddF32(a: ptr<storage, array<atomic<u32>>, read_write>, idx: i32, value: f32) {
  var bits: u32 = atomicLoad(&(*a)[idx]);
  loop {
    let updated: u32 = bitcast<u32>(bitcast<f32>(bits) + value);
    let r = atomicCompareExchangeWeak(&(*a)[idx], bits, updated);
    if (r.exchanged) { break; }
    bits = r.old_value;
  }
}

// segReduceGroup_16: segmented inclusive scan over each aligned 16-lane
// group keyed by `idx`; segment-end lanes write back. Lane guards window
// the un-widthed subgroup shuffles (requires subgroup_size % 16 == 0).
fn segReduceGroup_16(a: ptr<storage, array<atomic<u32>>, read_write>, idx: i32, value: f32, tid: i32) {
  let lane: i32 = tid % 16;
  var v: f32 = value;
  for (var offset: i32 = 1; offset < 16; offset *= 2) {
    let up: f32 = subgroupShuffleUp(v, u32(offset));
    let upIdx: i32 = subgroupShuffleUp(idx, u32(offset));
    if (lane >= offset && upIdx == idx) { v += up; }
  }
  let dnIdx: i32 = subgroupShuffleDown(idx, 1u);
  if (lane == 16 - 1 || dnIdx != idx) { atomicAddF32(a, idx, v); }
}

// taco_binarySearchBefore: largest i in [lo, hi] with a[i] <= target
// (TACO's device helper, Listing 1's row search).
fn taco_binarySearchBefore(a: ptr<storage, array<i32>, read>, lo: i32, hi: i32, target: i32) -> i32 {
  if ((*a)[hi] <= target) { return hi; }
  var lowerBound: i32 = lo;
  var upperBound: i32 = hi;
  while (upperBound - lowerBound > 1) {
    let mid: i32 = (upperBound + lowerBound) / 2;
    let midValue: i32 = (*a)[mid];
    if (midValue < target) { lowerBound = mid; }
    else if (midValue > target) { upperBound = mid; }
    else { return mid; }
  }
  return lowerBound;
}
// ------------------------------------------------------------------------

@group(0) @binding(0) var<storage, read> i_blockStarts: array<i32>;
@group(0) @binding(1) var<storage, read> A2_pos: array<i32>;
@group(0) @binding(2) var<storage, read> A2_crd: array<i32>;
@group(0) @binding(3) var<storage, read> A_vals: array<f32>;
@group(0) @binding(4) var<storage, read> X1_vals: array<f32>;
@group(0) @binding(5) var<storage, read> X2_vals: array<f32>;
@group(0) @binding(6) var<storage, read> B_vals: array<f32>;
@group(0) @binding(7) var<storage, read_write> C_vals: array<atomic<u32>>;
override A1_dimension: i32;
override A2_dimension: i32;
override B2_dimension: i32;
override J_dimension: i32;

@compute @workgroup_size(256)
fn fused_sddmm_spmm_c4_r16(@builtin(workgroup_id) wgid: vec3<u32>, @builtin(local_invocation_id) lid: vec3<u32>) {
  // fused sddmm→spmm {<1 nnz, 4 col>, 16} — in-register dot, one pos/crd pass
  var fpos1: i32 = (i32(lid.x) % 256);
  var ko: i32 = (i32(lid.x) / 256);
  var fposA: i32 = ((i32(wgid.x) * 256) + fpos1);
  var pA2_begin: i32 = i_blockStarts[i32(wgid.x)];
  var pA2_end: i32 = i_blockStarts[(i32(wgid.x) + 1)];
  var i_pos: i32 = taco_binarySearchBefore(&A2_pos, pA2_begin, pA2_end, fposA);
  var i: i32 = i_pos;
  var tlaneY: f32 = 0.0;
  if ((fposA < A2_pos[A1_dimension])) {
    while ((fposA == A2_pos[(i_pos + 1)])) {
      i_pos = (i_pos + 1);
      i = i_pos;
    }
    var f: i32 = A2_crd[fposA];
    var l: i32 = 0;
    while ((l < J_dimension)) {
      tlaneY = (tlaneY + (X1_vals[((i * J_dimension) + l)] * X2_vals[((l * A2_dimension) + f)]));
      l = (l + 1);
    }
    tlaneY = (tlaneY * A_vals[fposA]);
  }
  for (var ki: i32 = 0; ki < 4; ki += 1) {
    var k: i32 = ((ko * 4) + ki);
    var val: f32 = 0.0;
    if ((fposA >= A2_pos[A1_dimension])) {
      val = 0.0;
    } else {
      var f: i32 = A2_crd[fposA];
      var kB: i32 = ((f * B2_dimension) + k);
      val = (tlaneY * B_vals[kB]);
    }
    var kC: i32 = ((i * B2_dimension) + k);
    segReduceGroup_16(&C_vals, kC, val, i32(lid.x));
  }
}
