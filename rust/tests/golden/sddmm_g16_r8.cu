__global__ void sddmm_g16_r8(int* __restrict__ A2_pos, int* __restrict__ A2_crd, int* __restrict__ A_rowidx, float* __restrict__ A_vals, float* __restrict__ X1_vals, float* __restrict__ X2_vals, float* __restrict__ Y_vals, int A1_dimension, int A2_dimension, int J_dimension, int A_nnz) {
  // sddmm {<1/16 nnz>, 8} — grouped dot-product reduction
  int lane = (threadIdx.x % 16);
  int e = (threadIdx.x / 16);
  int pos = ((blockIdx.x * 16) + e);
  if ((pos < A_nnz)) {
    int i = A_rowidx[pos];
    int k = A2_crd[pos];
    float val = 0.0f;
    int j = lane;
    while ((j < J_dimension)) {
      val = (val + (X1_vals[((i * J_dimension) + j)] * X2_vals[((j * A2_dimension) + k)]));
      j = (j + 16);
    }
    val = (val * A_vals[pos]);
    atomicAddGroup<float,8>(Y_vals, pos, val);
  }
}
