__global__ void dg_rb_pr_rm_g8_b256_t8_w0p5(int* __restrict__ A2_pos, int* __restrict__ A2_crd, float* __restrict__ A_vals, float* __restrict__ B_vals, float* __restrict__ C_vals, int A1_dimension, int B2_dimension, int workerDimR) {
  // dgSPARSE RB+PR+RM <groupSz=8, blockSz=256, tileSz=8, workerDimR=0.5x rows>
  int lane = (threadIdx.x % 32);
  int vcol = ((threadIdx.x / 32) % 2);
  int rowb = (threadIdx.x / 64);
  int col_block = (blockIdx.x % 2);
  int row_block = (blockIdx.x / 2);
  int i = ((row_block * 4) + rowb);
  while ((i < A1_dimension)) {
    for (int cc = 0; cc < 4; cc += 1) {
      int k = ((col_block * 8) + ((vcol * 4) + cc));
      if ((k < B2_dimension)) {
        float val = 0.0f;
        int jpos = (A2_pos[i] + lane);
        while ((jpos < A2_pos[(i + 1)])) {
          val = (val + (A_vals[jpos] * B_vals[((A2_crd[jpos] * B2_dimension) + k)]));
          jpos = (jpos + 32);
        }
        atomicAddGroup<float,8>(C_vals, ((i * B2_dimension) + k), val);
      }
    }
    i = (i + workerDimR);
  }
}
