// --- sgap macro instructions (§5.3) ------------------------------------
// atomicAddGroup<T,G>: tree-reduce `value` over each aligned G-lane group
// with __shfl_down_sync, then lane 0 of the group issues one atomicAdd.
template <typename T, int G>
__device__ __forceinline__ void atomicAddGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  #pragma unroll
  for (int offset = G / 2; offset > 0; offset /= 2)
    value += __shfl_down_sync(mask, value, offset, G);
  if ((threadIdx.x % G) == 0) atomicAdd(&array[idx], value);
}

// segReduceGroup<T,G>: segmented inclusive scan over each aligned G-lane
// group keyed by `idx`; segment-end lanes write back (runtime-decided
// writeback threads — segment reduction).
template <typename T, int G>
__device__ __forceinline__ void segReduceGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  int lane = threadIdx.x % G;
  #pragma unroll
  for (int offset = 1; offset < G; offset *= 2) {
    T up = __shfl_up_sync(mask, value, offset, G);
    int upIdx = __shfl_up_sync(mask, idx, offset, G);
    if (lane >= offset && upIdx == idx) value += up;
  }
  int dnIdx = __shfl_down_sync(mask, idx, 1, G);
  if (lane == G - 1 || dnIdx != idx) atomicAdd(&array[idx], value);
}
// ------------------------------------------------------------------------
