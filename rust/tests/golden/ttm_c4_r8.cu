__global__ void ttm_c4_r8(int* __restrict__ seg_ids, int* __restrict__ f1_idx, float* __restrict__ A_vals, float* __restrict__ X1_vals, float* __restrict__ Y_vals, int N_dimension, int A_nnz, int A_nnz_pad) {
  // ttm {<1 nnz, 4 col>, 8} — COO-3 grouped segment reduction
  int e = (threadIdx.x % 256);
  int ko = (threadIdx.x / 256);
  int pos = ((blockIdx.x * 256) + e);
  int seg = seg_ids[min(pos, (A_nnz_pad - 1))];
  for (int ki = 0; ki < 4; ki += 1) {
    int jcol = ((ko * 4) + ki);
    float val = 0.0f;
    if ((pos >= A_nnz)) {
      val = 0.0f;
    } else {
      val = (A_vals[pos] * X1_vals[((f1_idx[pos] * N_dimension) + jcol)]);
    }
    int out = ((seg * N_dimension) + jcol);
    segReduceGroup<float,8>(Y_vals, out, val);
  }
}
