__global__ void mttkrp_c4_r16(int* __restrict__ seg_ids, int* __restrict__ f1_idx, int* __restrict__ f2_idx, float* __restrict__ A_vals, float* __restrict__ X1_vals, float* __restrict__ X2_vals, float* __restrict__ Y_vals, int N_dimension, int A_nnz, int A_nnz_pad) {
  // mttkrp {<1 nnz, 4 col>, 16} — COO-3 grouped segment reduction
  int e = (threadIdx.x % 128);
  int ko = (threadIdx.x / 128);
  int pos = ((blockIdx.x * 128) + e);
  int seg = seg_ids[min(pos, (A_nnz_pad - 1))];
  for (int ki = 0; ki < 4; ki += 1) {
    int jcol = ((ko * 4) + ki);
    float val = 0.0f;
    if ((pos >= A_nnz)) {
      val = 0.0f;
    } else {
      val = ((A_vals[pos] * X1_vals[((f1_idx[pos] * N_dimension) + jcol)]) * X2_vals[((f2_idx[pos] * N_dimension) + jcol)]);
    }
    int out = ((seg * N_dimension) + jcol);
    segReduceGroup<float,16>(Y_vals, out, val);
  }
}
