__global__ void spmm_nnz_group_c4_r8(int* __restrict__ i_blockStarts, int* __restrict__ A2_pos, int* __restrict__ A2_crd, float* __restrict__ A_vals, float* __restrict__ B_vals, float* __restrict__ C_vals, int A1_dimension, int B2_dimension) {
  // {<1 nnz, 4 col>, 8} — grouped segment reduction
  int fpos1 = (threadIdx.x % 256);
  int ko = (threadIdx.x / 256);
  int fposA = ((blockIdx.x * 256) + fpos1);
  int pA2_begin = i_blockStarts[blockIdx.x];
  int pA2_end = i_blockStarts[(blockIdx.x + 1)];
  int i_pos = taco_binarySearchBefore(A2_pos, pA2_begin, pA2_end, fposA);
  int i = i_pos;
  for (int ki = 0; ki < 4; ki += 1) {
    int k = ((ko * 4) + ki);
    float val = 0.0f;
    if ((fposA >= A2_pos[A1_dimension])) {
      val = 0.0f;
    } else {
      int f = A2_crd[fposA];
      int kB = ((f * B2_dimension) + k);
      while ((fposA == A2_pos[(i_pos + 1)])) {
        i_pos = (i_pos + 1);
        i = i_pos;
      }
      val = (A_vals[fposA] * B_vals[kB]);
    }
    int kC = ((i * B2_dimension) + k);
    segReduceGroup<float,8>(C_vals, kC, val);
  }
}
