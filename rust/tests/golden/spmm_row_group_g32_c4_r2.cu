__global__ void spmm_row_group_g32_c4_r2(int* __restrict__ A2_pos, int* __restrict__ A2_crd, float* __restrict__ A_vals, float* __restrict__ B_vals, float* __restrict__ C_vals, int A1_dimension, int B2_dimension) {
  // {<1/32 row, 4 col>, 2} — grouped parallel reduction
  int jpos1 = (threadIdx.x % 32);
  int ko = ((threadIdx.x / 32) % 1);
  int rowb = (threadIdx.x / 32);
  int i = ((blockIdx.x * 8) + rowb);
  if ((i < A1_dimension)) {
    for (int ki = 0; ki < 4; ki += 1) {
      int k = ((ko * 4) + ki);
      float tjpos1C = 0.0f;
      int jpos = (A2_pos[i] + jpos1);
      while ((jpos < A2_pos[(i + 1)])) {
        tjpos1C = (tjpos1C + (A_vals[jpos] * B_vals[((A2_crd[jpos] * B2_dimension) + k)]));
        jpos = (jpos + 32);
      }
      atomicAddGroup<float,2>(C_vals, ((i * B2_dimension) + k), tjpos1C);
    }
  }
}
