//! End-to-end integration over the whole L3 stack: schedule → CIN → LLIR
//! → simulator on the evaluation suite, the codegen golden path, and the
//! tuner/selector loop.

use sgap::algos::catalog::Algo;
use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::compiler::codegen_cuda::emit_kernel;
use sgap::compiler::schedule::{Schedule, SpmmConfig};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{dataset, MatrixStats, SplitMix64};
use sgap::tuner::{self, Selector};

#[test]
fn mini_suite_all_algorithms_correct() {
    let machine = Machine::new(HwProfile::rtx3090());
    let n = 4u32;
    for d in dataset::mini_suite() {
        let a = d.matrix.to_csr();
        let mut rng = SplitMix64::new(1);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, n as usize);
        for alg in [
            Algo::TacoNnzSerial { g: 16, c: 4 },
            Algo::TacoRowSerial { x: 1, c: 4 },
            Algo::SgapRowGroup { g: 32, c: 4, r: 8 },
            Algo::SgapNnzGroup { c: 4, r: 16 },
        ] {
            let res = alg.run(&machine, &a, &b, n).unwrap();
            let err = max_rel_err(&res.run.c, &want);
            assert!(err < 5e-4, "{} on {}: err {err}", alg.name(), d.name);
        }
    }
}

#[test]
fn full_pipeline_schedule_to_cuda_text() {
    // the user story from the paper: schedule commands in, CUDA out
    let cfg = SpmmConfig { n: 4, c: 4, p: 256, g: 32, r: 8, x: 1 };
    let sched = Schedule::sgap_nnz_group(cfg, 8);
    assert!(sched.to_cin().to_string().contains("GPUGroup[8,Segment]"));
    let kernel = sgap::compiler::lower(&sched).unwrap();
    let cuda = emit_kernel(&kernel);
    assert!(cuda.contains("segReduceGroup<float,8>"));
    // the same kernel executes on the simulator
    let a = sgap::sparse::erdos_renyi(64, 64, 256, 3).to_csr();
    let b = vec![1.0f32; 64 * 4];
    let machine = Machine::new(HwProfile::rtx2080());
    let run = sgap::algos::runner::run_schedule(&machine, &sched, &a, &b).unwrap();
    assert_eq!(run.c.len(), 64 * 4);
}

#[test]
fn tuner_beats_or_matches_any_fixed_choice() {
    let machine = Machine::new(HwProfile::rtx3090());
    let n = 4u32;
    let d = &dataset::mini_suite()[0];
    let a = d.matrix.to_csr();
    let mut rng = SplitMix64::new(2);
    let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
    let cands = tuner::space::sgap_candidates(n);
    let out = tuner::tune(&machine, &cands, &a, &b, n).unwrap();
    let (_, best_t) = out.best().unwrap();
    for (_, t, _) in &out.ranked {
        assert!(best_t <= *t + 1e-15);
    }
}

#[test]
fn selector_on_suite_has_sane_regret() {
    let machine = Machine::new(HwProfile::rtx3090());
    let sel = Selector::default();
    let n = 4u32;
    let mut worst: f64 = 1.0;
    for d in dataset::mini_suite() {
        let a = d.matrix.to_csr();
        let mut rng = SplitMix64::new(3);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let r = sel.regret(&machine, &a, &b, n).unwrap();
        worst = worst.max(r);
    }
    assert!(worst < 6.0, "selector regret {worst} too high on the mini suite");
}

#[test]
fn stats_drive_expected_selector_families() {
    let sel = Selector::default();
    for d in dataset::suite() {
        let stats = MatrixStats::of(&d.matrix.to_csr());
        let algo = sel.select(&stats, 4);
        if d.family == "banded" {
            assert!(
                matches!(algo, Algo::SgapRowGroup { .. }),
                "banded {} should be row-balanced, got {}",
                d.name,
                algo.name()
            );
        }
        if d.name == "corner_hub_1024" {
            assert!(
                matches!(algo, Algo::SgapNnzGroup { .. }),
                "hub matrix should be nnz-balanced, got {}",
                algo.name()
            );
        }
    }
}

#[test]
fn hardware_profiles_order_memory_bound_kernels() {
    // a memory-bound kernel must run slower on the 2080 (448 GB/s) than
    // the 3090 (936 GB/s)
    let n = 4u32;
    let d = dataset::suite().into_iter().find(|d| d.name == "er_4096_d5e-3").unwrap();
    let a = d.matrix.to_csr();
    let mut rng = SplitMix64::new(4);
    let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
    let alg = Algo::TacoRowSerial { x: 1, c: 4 };
    let t3090 = alg.run(&Machine::new(HwProfile::rtx3090()), &a, &b, n).unwrap().time_s;
    let t2080 = alg.run(&Machine::new(HwProfile::rtx2080()), &a, &b, n).unwrap().time_s;
    assert!(t2080 >= t3090, "2080 {t2080} should not beat 3090 {t3090}");
}
