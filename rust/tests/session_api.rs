//! Integration tests for the Session/Op serving API:
//!
//! * multi-threaded shared-handle stress — N workers submitting against
//!   one registered matrix, with `Arc::strong_count`-based proof that no
//!   submit clones the operand;
//! * handle-path ≡ legacy-path response equivalence across the quartet;
//! * typed validation errors (including `checked_mul` overflow) through
//!   the serving path;
//! * a custom [`Executor`] plugged in through the registry.

use std::sync::Arc;

use sgap::coordinator::{
    factory, Admission, BackendKind, Coordinator, CoordinatorConfig, Executor, ExecutorRegistry,
    Op, OpKind, Session,
};
use sgap::sparse::{erdos_renyi, power_law, Coo3, SplitMix64};

fn dense(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| rng.value()).collect()
}

/// 8 threads × 60 submits against ONE registered matrix: every response
/// is correct, every submit moves an `Arc` (pointer-identical operand,
/// bounded refcount), and after shutdown the registration is the sole
/// owner again — no per-submit operand clone ever escaped.
#[test]
fn shared_handle_stress_is_zero_copy() {
    let session = Session::start(CoordinatorConfig {
        workers: 4,
        background_tune: false,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let a = session.register_matrix(power_law(96, 96, 1400, 1.9, 3).to_csr());
    let b = session.register_dense(dense(96 * 4, 7));
    assert_eq!((a.strong_count(), b.strong_count()), (1, 1));
    let want = Op::spmm(&a, &b, 4).run_serial();

    let threads = 8usize;
    let per_thread = 60usize;
    let mut handles = Vec::new();
    for t in 0..threads {
        let session = session.clone();
        let (a, b, want) = (a.clone(), b.clone(), want.clone());
        handles.push(std::thread::spawn(move || {
            for i in 0..per_thread {
                let op = Op::spmm(&a, &b, 4);
                // structural zero-copy: the op shares the registration
                assert!(op.a.ptr_eq(&a) && op.dense[0].ptr_eq(&b), "thread {t} op {i}");
                let resp = session.submit(op).wait().expect("serve failed");
                assert_eq!(resp.c.len(), want.len(), "thread {t} op {i}");
                // one blocking submit in flight per thread: the live
                // references are the registration + per-thread clones +
                // at most two op handles per thread (one being built, one
                // not yet dropped by its worker) — never O(submits)
                assert!(
                    a.strong_count() <= 1 + 3 * threads,
                    "thread {t} op {i}: refcount {} implies handle leak",
                    a.strong_count()
                );
            }
            // responses match this thread's own oracle copy
            let resp = session.submit(Op::spmm(&a, &b, 4)).wait().unwrap();
            let err = sgap::algos::cpu_ref::max_rel_err(&resp.c, &want);
            assert!(err < 5e-4, "thread {t}: max rel err {err}");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = session.coordinator().metrics.snapshot();
    assert_eq!(snap.completed, (threads * (per_thread + 1)) as u64);
    assert_eq!(snap.errors, 0);
    assert!(snap.cache_hits > 0, "repeat submits of one handle must hit the plan cache");
    assert_eq!(
        snap.cache_misses, 1,
        "one registered shape fingerprints once; repeats skip re-fingerprinting"
    );
    session.shutdown(); // joins workers: every in-flight op handle dropped
    assert_eq!((a.strong_count(), b.strong_count()), (1, 1), "serving cloned an operand");
}

/// The handle path and the legacy value-owning path produce identical
/// responses for all four algebras of the quartet (same coordinator, so
/// the second submit of each shape is a plan-cache hit with the same
/// plan — results must match bit for bit).
#[test]
fn handle_path_matches_legacy_path_across_quartet() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() })
            .unwrap(),
    );
    let session = Session::with(coord.clone());

    // SpMM
    let a = erdos_renyi(64, 56, 500, 11).to_csr();
    let b = dense(56 * 4, 1);
    let legacy = coord.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
    let (ha, hb) = (session.register_matrix(a), session.register_dense(b));
    let handled = session.spmm(&ha, &hb, 4).wait().unwrap();
    assert_eq!(legacy.c, handled.c, "spmm");
    assert_eq!(legacy.plan, handled.plan, "spmm plan");
    assert!(handled.cache_hit, "same shape must hit the legacy submit's plan");

    // SDDMM
    let a = erdos_renyi(48, 40, 320, 12).to_csr();
    let (x1, x2) = (dense(48 * 8, 2), dense(8 * 40, 3));
    let legacy = coord.sddmm_blocking(a.clone(), x1.clone(), x2.clone(), 8).unwrap();
    let ha = session.register_matrix(a);
    let (h1, h2) = (session.register_dense(x1), session.register_dense(x2));
    let handled = session.sddmm(&ha, &h1, &h2, 8).wait().unwrap();
    assert_eq!(legacy.c, handled.c, "sddmm");
    assert_eq!(legacy.plan, handled.plan, "sddmm plan");

    // MTTKRP
    let t = Coo3::random((28, 20, 14), 350, 13);
    let (x1, x2) = (dense(t.dim1 * 8, 4), dense(t.dim2 * 8, 5));
    let legacy = coord.mttkrp_blocking(t.clone(), x1.clone(), x2.clone(), 8).unwrap();
    let ht = session.register_tensor(t.clone());
    let (h1, h2) = (session.register_dense(x1), session.register_dense(x2));
    let handled = session.mttkrp(&ht, &h1, &h2, 8).wait().unwrap();
    assert_eq!(legacy.c, handled.c, "mttkrp");
    assert_eq!(legacy.plan, handled.plan, "mttkrp plan");

    // TTM (same registered tensor: the fiber fingerprint is cached too)
    let x1 = dense(t.dim2 * 4, 6);
    let legacy = coord.ttm_blocking(t, x1.clone(), 4).unwrap();
    let h1 = session.register_dense(x1);
    let handled = session.ttm(&ht, &h1, 4).wait().unwrap();
    assert_eq!(legacy.c, handled.c, "ttm");
    assert_eq!(legacy.plan, handled.plan, "ttm plan");

    session.shutdown();
    Arc::try_unwrap(coord).ok().expect("session released the pool").shutdown();
}

/// Absurd dims are rejected with the typed overflow error (checked_mul),
/// not a debug-build multiply panic — via both submit surfaces.
#[test]
fn absurd_dims_are_typed_errors_not_overflows() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let a = erdos_renyi(16, 16, 40, 1).to_csr();
    let err = coord.spmm_blocking(a.clone(), vec![0.0; 4], usize::MAX / 2).unwrap_err();
    assert!(err.to_string().contains("overflows"), "{err}");
    let err =
        coord.sddmm_blocking(a.clone(), vec![0.0; 4], vec![0.0; 4], usize::MAX / 2).unwrap_err();
    assert!(err.to_string().contains("overflows"), "{err}");
    // handle path reports the same typed error
    let session = Session::with(Arc::new(coord));
    let h = session.register_matrix(a);
    let d = session.register_dense(vec![0.0; 4]);
    let err = session.spmm(&h, &d, usize::MAX / 2).wait().unwrap_err();
    assert!(err.to_string().contains("spmm") && err.to_string().contains("overflows"), "{err}");
    let snap = session.coordinator().metrics.snapshot();
    assert_eq!(snap.errors, 3);
    session.shutdown();
}

/// A user-defined executor plugs in at the head of the registry: it
/// outbids the standard stack for the ops it admits, carries its own
/// typed backend label, and everything it declines flows down unchanged.
#[test]
fn custom_executor_plugs_into_the_registry() {
    struct ConstExecutor;
    impl Executor for ConstExecutor {
        fn name(&self) -> &'static str {
            "const"
        }
        fn admit(&mut self, op: &Op) -> Option<Admission> {
            if op.kind != OpKind::Spmm {
                return None;
            }
            Some(Admission {
                backend: BackendKind::Custom("const:42".into()),
                plan: None,
                cache_hit: false,
            })
        }
        fn execute(&mut self, op: &Op, _adm: &Admission) -> Result<Vec<f32>, String> {
            Ok(vec![42.0; op.output_len().ok_or("no output size")?])
        }
    }

    let session = Session::start(CoordinatorConfig {
        workers: 2,
        executors: ExecutorRegistry::standard()
            .with_front(factory(|_env| Some(Box::new(ConstExecutor) as Box<dyn Executor>))),
        ..CoordinatorConfig::default()
    })
    .unwrap();

    let a = session.register_matrix(erdos_renyi(24, 24, 80, 2).to_csr());
    let b = session.register_dense(dense(24 * 4, 8));
    let resp = session.spmm(&a, &b, 4).wait().unwrap();
    assert_eq!(resp.backend, BackendKind::Custom("const:42".into()));
    assert_eq!(resp.backend.to_string(), "const:42");
    assert!(resp.c.iter().all(|&v| v == 42.0) && resp.c.len() == 24 * 4);
    assert!(resp.plan.is_none());

    // declined kinds fall through to the standard stack
    let x1 = session.register_dense(dense(24 * 8, 9));
    let x2 = session.register_dense(dense(8 * 24, 10));
    let resp = session.sddmm(&a, &x1, &x2, 8).wait().unwrap();
    assert_eq!(resp.backend, BackendKind::Sim { family: "sddmm-group" });

    let snap = session.coordinator().metrics.snapshot();
    assert!(snap.backends.iter().any(|b| b.backend == "const:42"), "{:?}", snap.backends);
    session.shutdown();
}
