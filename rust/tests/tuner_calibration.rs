//! The calibration loop's acceptance properties (ISSUE 8):
//!
//! * fitting `CostParams` to measurements taken under a *drifted* ground
//!   truth strictly improves the analytic model's mean Spearman rank
//!   fidelity over the mini suite — `model_rank_agree` moves from an
//!   asserted floor to a metric the fit provably pushes up;
//! * the [`Calibration`] artifact round-trips byte-identically through
//!   `to_json` → `from_json` → `to_json` and through disk, so a
//!   restarted coordinator warm-starts from exactly the constants it
//!   saved.
//!
//! The drift fixture (multipliers, suite, candidate grid) is
//! transliterated in `python/tools/seed_bench.py`, which verifies the
//! same inequalities numerically when seeding the committed
//! `CALIBRATION.json`. Keep the two in sync.

use sgap::algos::catalog::Algo;
use sgap::sim::{CostParams, HwProfile, Machine};
use sgap::sparse::{dataset, MatrixStats};
use sgap::tuner::calibrate::{fit, spearman, Calibration, Sample, WorkloadSpec};
use sgap::tuner::space::{sgap_candidates, taco_candidates};
use sgap::tuner::{calibrated_machine, CostModel, Workload};

/// The drifted constants the fixture treats as ground truth — the same
/// per-coordinate multipliers `python/tools/seed_bench.py` applies.
const DRIFT: [f64; CostParams::N] = [1.8, 0.55, 1.6, 2.4, 0.45, 1.5, 2.0];
const OVERHEAD_DRIFT: f64 = 4.0;

fn base() -> Machine {
    Machine::new(HwProfile::rtx3090())
}

fn drifted_truth(base: &Machine) -> CostModel {
    let mut m = base.clone();
    let arr = base.params.to_array();
    let mut v = [0.0; CostParams::N];
    for i in 0..CostParams::N {
        v[i] = arr[i] * DRIFT[i];
    }
    m.params = CostParams::from_array(v);
    m.hw.launch_overhead_s *= OVERHEAD_DRIFT;
    CostModel::new(&m)
}

/// Mini suite × the SpMM candidate grid, priced under `truth` — the
/// "measured" latencies the fitter sees.
fn fixture(truth: &CostModel) -> (Vec<Sample>, Vec<(MatrixStats, Vec<(Algo, f64)>)>) {
    let mut cands = taco_candidates(4);
    cands.extend(sgap_candidates(4));
    let mut samples = Vec::new();
    let mut per_matrix = Vec::new();
    for d in dataset::mini_suite() {
        let a = d.matrix.to_csr();
        let stats = MatrixStats::of(&a);
        let mut measured = Vec::new();
        for c in &cands {
            let spec = WorkloadSpec::Spmm { stats: stats.clone(), n: 4 };
            let t = truth
                .price(c, &spec.workload())
                .unwrap_or_else(|| panic!("{}: {} must price", d.name, c.name()));
            samples.push(Sample::new(*c, spec, t));
            measured.push((*c, t));
        }
        per_matrix.push((stats, measured));
    }
    (samples, per_matrix)
}

fn mean_spearman(model: &CostModel, per_matrix: &[(MatrixStats, Vec<(Algo, f64)>)]) -> f64 {
    let mut acc = 0.0;
    for (stats, measured) in per_matrix {
        let wl = Workload::Spmm { stats, n: 4 };
        let (mut preds, mut times) = (Vec::new(), Vec::new());
        for (alg, t) in measured {
            preds.push(model.price(alg, &wl).expect("fixture candidates price"));
            times.push(*t);
        }
        acc += spearman(&preds, &times);
    }
    acc / per_matrix.len() as f64
}

#[test]
fn fit_strictly_improves_mean_rank_fidelity_on_the_mini_suite() {
    let base = base();
    let truth = drifted_truth(&base);
    let (samples, per_matrix) = fixture(&truth);

    let cal = fit(&base, &samples);
    assert_eq!(cal.samples, samples.len(), "every drift sample is usable");
    assert!(
        cal.loss_after < cal.loss_before * 0.9,
        "fit must cut the drift loss by >= 10% ({:.4} -> {:.4})",
        cal.loss_before,
        cal.loss_after
    );

    let before = mean_spearman(&CostModel::new(&base), &per_matrix);
    let fitted = calibrated_machine(&base, Some(&cal));
    let after = mean_spearman(&CostModel::new(&fitted), &per_matrix);
    assert!(
        after > before,
        "fit must strictly improve mean Spearman rank fidelity ({before:.4} -> {after:.4})"
    );
    // and the improvement is not a degenerate both-at-1.0 tie
    assert!(before < 1.0, "drift fixture too easy: defaults already rank perfectly");
}

#[test]
fn fitted_artifact_round_trips_byte_identically_through_disk() {
    let base = base();
    let truth = drifted_truth(&base);
    let (samples, _) = fixture(&truth);
    let cal = fit(&base, &samples);

    // in-memory byte identity
    let s1 = cal.to_json();
    let reparsed = Calibration::from_json(&s1).unwrap();
    assert_eq!(reparsed, cal);
    assert_eq!(reparsed.to_json(), s1, "to_json . from_json must be the identity on bytes");

    // and through disk, as a restarted coordinator would read it
    let dir = std::env::temp_dir().join(format!("sgap_calib_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("CALIBRATION.json");
    cal.save(&path).unwrap();
    let loaded = Calibration::load(&path).unwrap();
    assert_eq!(loaded, cal);
    assert_eq!(loaded.to_json(), s1);
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}

#[test]
fn warm_started_machine_prices_like_the_saved_fit() {
    let base = base();
    let truth = drifted_truth(&base);
    let (samples, per_matrix) = fixture(&truth);
    let cal = fit(&base, &samples);

    // save → load → apply must reproduce the fitted machine exactly
    let round = Calibration::from_json(&cal.to_json()).unwrap();
    let m1 = calibrated_machine(&base, Some(&cal));
    let m2 = calibrated_machine(&base, Some(&round));
    assert_eq!(m1.params.to_array(), m2.params.to_array());
    assert_eq!(m1.hw.launch_overhead_s, m2.hw.launch_overhead_s);
    let (model1, model2) = (CostModel::new(&m1), CostModel::new(&m2));
    let (stats, measured) = &per_matrix[0];
    let wl = Workload::Spmm { stats, n: 4 };
    for (alg, _) in measured {
        assert_eq!(model1.price(alg, &wl), model2.price(alg, &wl));
    }
}
