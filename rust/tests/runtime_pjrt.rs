//! Integration: PJRT runtime executes the AOT artifacts and matches the
//! rust CPU oracle — proving the three layers (Pallas kernel → jax graph →
//! rust runtime) compose numerically.
//!
//! Requires `make artifacts`. Tests are skipped (with a loud message) if
//! the manifest is missing, so `cargo test` works on a fresh clone.

use std::path::PathBuf;

use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::runtime::Runtime;
use sgap::sparse::{erdos_renyi, gen, SplitMix64};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("SGAP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts`", dir.display());
        None
    }
}

#[test]
fn spmm_nnz_sr_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let name = "spmm_nnz_sr_r512_z4096_n4_g32";
    let a = erdos_renyi(500, 500, 3500, 42).to_csr();
    let mut rng = SplitMix64::new(1);
    let b: Vec<f32> = (0..500 * 4).map(|_| rng.value()).collect();
    let got = rt.run_spmm_nnz(name, &a, &b).unwrap();
    let want = spmm_serial(&a, &b, 4);
    let err = max_rel_err(&got, &want);
    assert!(err < 1e-4, "pjrt vs oracle err {err}");
    assert!(rt.is_cached(name));
}

#[test]
fn spmm_nnz_sr_group8_variant_matches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let a = erdos_renyi(300, 400, 2000, 7).to_csr();
    let mut rng = SplitMix64::new(2);
    let b: Vec<f32> = (0..400 * 4).map(|_| rng.value()).collect();
    let got = rt.run_spmm_nnz("spmm_nnz_sr_r512_z4096_n4_g8", &a, &b).unwrap();
    let want = spmm_serial(&a, &b, 4);
    assert!(max_rel_err(&got, &want) < 1e-4);
}

#[test]
fn spmm_row_pr_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    // keep max row degree <= 32 slots: banded matrix
    let a = gen::banded(400, 9, 3).to_csr();
    let mut rng = SplitMix64::new(3);
    let b: Vec<f32> = (0..400 * 4).map(|_| rng.value()).collect();
    let got = rt.run_spmm_ell("spmm_row_pr_r512_s32_n4_g32", &a, &b).unwrap();
    let want = spmm_serial(&a, &b, 4);
    assert!(max_rel_err(&got, &want) < 1e-4);
}

#[test]
fn gcn2_artifact_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let spec = rt.registry.get("gcn2").unwrap().clone();
    let (fi, hd, fo) = (spec.in_feat, spec.hidden, spec.out_feat);

    let nodes = 2708; // Cora-scale
    let graph = gen::normalize_adjacency(&erdos_renyi(nodes, nodes, 10_000, 5));
    let a = graph.to_csr();
    let mut rng = SplitMix64::new(4);
    let h: Vec<f32> = (0..nodes * fi).map(|_| rng.value()).collect();
    let w1: Vec<f32> = (0..fi * hd).map(|_| rng.value()).collect();
    let w2: Vec<f32> = (0..hd * fo).map(|_| rng.value()).collect();

    let got = rt.run_gcn2("gcn2", &a, &h, &w1, &w2).unwrap();

    // rust reference: relu(A * relu(A * (H W1)) W2)
    let matmul = |x: &[f32], y: &[f32], m: usize, k: usize, n: usize| -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                if xv != 0.0 {
                    for j in 0..n {
                        out[i * n + j] += xv * y[kk * n + j];
                    }
                }
            }
        }
        out
    };
    let relu = |v: &mut Vec<f32>| v.iter_mut().for_each(|x| *x = x.max(0.0));
    let hw1 = matmul(&h, &w1, nodes, fi, hd);
    let mut z1 = spmm_serial(&a, &hw1, hd);
    relu(&mut z1);
    let z1w2 = matmul(&z1, &w2, nodes, hd, fo);
    let mut want = spmm_serial(&a, &z1w2, fo);
    relu(&mut want);

    let err = max_rel_err(&got, &want);
    assert!(err < 5e-4, "gcn2 pjrt vs rust reference err {err}");
}

#[test]
fn routing_picks_admitting_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    use sgap::runtime::ArtifactKind;
    let spec = rt.registry.route(ArtifactKind::SpmmNnzSr, 100, 100, 500).unwrap();
    assert!(spec.admits(100, 100, 500));
    // too big for every bucket
    assert!(rt.registry.route(ArtifactKind::SpmmNnzSr, 100_000, 10, 10).is_none());
}

#[test]
fn oversized_matrix_rejected_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let a = erdos_renyi(600, 600, 100, 9).to_csr(); // rows > 512 bucket
    let b = vec![0f32; 600 * 4];
    let err = rt.run_spmm_nnz("spmm_nnz_sr_r512_z4096_n4_g32", &a, &b).unwrap_err();
    assert!(err.to_string().contains("exceeds bucket"), "{err}");
}
