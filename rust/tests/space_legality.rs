//! Fig. 7/8 reproduction: exhaustive enumeration of the atomic-parallelism
//! space with the three pruning rules, plus the DA-SpMM embedding claim.

use sgap::compiler::spaces::{
    enumerate_all, enumerate_legal, AtomicPoint, DataKind, Factor, Illegality,
};

const GS: [u32; 5] = [2, 4, 8, 16, 32];
const CS: [u32; 3] = [2, 4, 8];
const RS: [u32; 6] = [1, 2, 4, 8, 16, 32];

#[test]
fn every_point_classified_exactly_once() {
    let all = enumerate_all(&GS, &CS, &RS);
    // factors: One + 2 per g (5 gs) = 11; cols: One + 2 per c (3 cs) = 7
    assert_eq!(all.len(), 2 * 11 * 7 * RS.len());
    let legal = enumerate_legal(&GS, &CS, &RS);
    let illegal = all.len() - legal.len();
    assert!(illegal > 0 && !legal.is_empty());
}

#[test]
fn rule1_prunes_exactly_fractional_nnz_and_cols() {
    for (p, l) in enumerate_all(&GS, &CS, &RS) {
        let frac_x = matches!(p.x, Factor::Inv(_));
        let frac_col = matches!(p.col, Factor::Inv(_));
        if p.kind == DataKind::Nnz && (frac_x || frac_col) {
            assert_eq!(l, Err(Illegality::Rule1FractionalNnzOrCol), "{p}");
        }
    }
}

#[test]
fn rule3_prunes_double_fractions() {
    for (p, l) in enumerate_all(&GS, &CS, &RS) {
        if p.kind == DataKind::Row
            && matches!(p.x, Factor::Inv(_))
            && matches!(p.col, Factor::Inv(_))
        {
            assert_eq!(l, Err(Illegality::Rule3DoubleFraction), "{p}");
        }
    }
}

#[test]
fn rule2_boundary_is_r_equals_g() {
    for g in GS {
        for r in RS {
            let p = AtomicPoint::new(DataKind::Row, Factor::Inv(g), Factor::One, r);
            if r < g {
                assert_eq!(p.legality(), Err(Illegality::Rule2ParallelReductionWriteback), "{p}");
                // …but legal under Atomics (the Table-1 configuration)
                assert!(p.is_legal_with_atomics(), "{p} should be legal with atomics");
            } else {
                assert!(p.is_legal(), "{p} should be legal");
            }
        }
    }
}

#[test]
fn da_spmm_space_strictly_contained() {
    // all four DA-SpMM points are legal…
    let legal = enumerate_legal(&GS, &[4], &RS);
    for (name, p) in AtomicPoint::da_spmm_embedding(4) {
        assert!(legal.contains(&p), "{name} = {p} missing from the legal space");
    }
    // …and the legal space is strictly larger (Fig. 2's Venn diagram)
    let da: Vec<AtomicPoint> =
        AtomicPoint::da_spmm_embedding(4).into_iter().map(|(_, p)| p).collect();
    let beyond: Vec<_> = legal.iter().filter(|p| !da.contains(p)).collect();
    assert!(
        beyond.len() > da.len() * 2,
        "atomic parallelism should open much more space than DA-SpMM: {} extra points",
        beyond.len()
    );
}

#[test]
fn sgap_new_algorithms_are_in_the_extension() {
    // the two §6.2 algorithm families occupy points outside DA-SpMM
    let da: Vec<AtomicPoint> =
        AtomicPoint::da_spmm_embedding(4).into_iter().map(|(_, p)| p).collect();
    for r in [2u32, 4, 8, 16] {
        let p = AtomicPoint::sgap_nnz(4, r);
        assert!(p.is_legal(), "{p}");
        assert!(!da.contains(&p), "{p} should extend DA-SpMM");
    }
    for (g, r) in [(8u32, 8u32), (16, 16), (8, 32)] {
        let p = AtomicPoint::sgap_row(g, 4, r);
        assert!(p.is_legal(), "{p}");
        assert!(!da.contains(&p), "{p} should extend DA-SpMM");
    }
}
