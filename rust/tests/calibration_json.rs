//! The committed `CALIBRATION.json` artifact (repo root): schema
//! validation, canonical-format byte round-trip, and the blessed
//! regeneration flow — the calibration mirror of `bench_json.rs`.
//!
//! The committed file pins the *schema and invariants*, not the exact
//! fitted constants — re-profiling legitimately moves them, so
//! refreshing is a blessed operation:
//! `SGAP_BLESS=1 cargo test --test calibration_json` (equivalently
//! `cargo run --release -- profile --quick --out ..` from `rust/`).

use std::path::PathBuf;

use sgap::bench_util::{run_profile, validate_calibration_json};
use sgap::sim::{HwProfile, Machine};
use sgap::tuner::calibrate::{Calibration, CALIBRATION_SCHEMA_VERSION};

fn committed() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("CALIBRATION.json")
}

#[test]
fn committed_calibration_matches_schema() {
    let path = committed();
    if std::env::var_os("SGAP_BLESS").is_some() {
        let machine = Machine::new(HwProfile::rtx3090());
        let report = run_profile(&machine, true).unwrap();
        report.calibration.save(&path).unwrap_or_else(|e| panic!("bless {}: {e}", path.display()));
    }
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing committed {}: {e}\n(regenerate with `SGAP_BLESS=1 cargo test --test \
             calibration_json` or `sgap profile --quick`)",
            path.display()
        )
    });
    validate_calibration_json(&src).unwrap_or_else(|e| {
        panic!("committed {} fails the documented schema: {e}", path.display())
    });
}

#[test]
fn committed_calibration_round_trips_byte_identically() {
    if std::env::var_os("SGAP_BLESS").is_some() {
        return; // the blessing test above rewrites the file this run
    }
    let src = std::fs::read_to_string(committed()).unwrap();
    let cal = Calibration::from_json(&src).unwrap();
    assert_eq!(cal.version, CALIBRATION_SCHEMA_VERSION);
    // the committed artifact must be in canonical `to_json` format, so a
    // coordinator that loads and re-saves it produces the same bytes
    assert_eq!(cal.to_json(), src, "committed CALIBRATION.json is not in canonical format");
    // and it applies cleanly to the profile it was fitted on
    let mut m = Machine::new(HwProfile::rtx3090());
    cal.apply(&mut m);
    for (i, p) in m.params.to_array().iter().enumerate() {
        assert!(*p > 0.0, "applied param {} must stay positive", sgap::sim::CostParams::NAMES[i]);
    }
    assert!(m.hw.launch_overhead_s >= 0.0);
}

#[test]
fn live_quick_profile_round_trips_through_the_schema_gate() {
    let machine = Machine::new(HwProfile::rtx3090());
    let report = run_profile(&machine, true).unwrap();
    // the emitted artifact passes its own schema gate
    validate_calibration_json(&report.calibration.to_json()).unwrap();
    // one fidelity row per quick-suite matrix, each sweeping > 1 candidate
    assert_eq!(report.rows.len(), sgap::sparse::dataset::mini_suite().len());
    for row in &report.rows {
        assert!(row.samples > 1, "{}: degenerate sweep", row.matrix);
        assert!(row.spearman_before.abs() <= 1.0 && row.spearman_after.abs() <= 1.0);
    }
    // the fit never makes the training loss worse (monotone descent)
    assert!(report.calibration.loss_after <= report.calibration.loss_before);
    // fitting to the simulator keeps rank fidelity at least competitive:
    // the fit minimises magnitude error, so don't demand strict rank
    // improvement here (the drift fixture in tuner_calibration.rs does);
    // a collapse would mean the fitter broke
    assert!(
        report.mean_spearman_after() >= report.mean_spearman_before() - 0.1,
        "fit collapsed rank fidelity: {:.4} -> {:.4}",
        report.mean_spearman_before(),
        report.mean_spearman_after()
    );
}
