//! Codegen golden tests: `emit_kernel` output for the canonical Sgap
//! schedules is pinned against committed golden text, covering
//! `segReduceGroup<float,r>` (SegmentReduction) and `atomicAddGroup
//! <float,r>` (ParallelReduction) emission plus the zero-extension
//! predicate; the §5.3 macro-instruction header is pinned too, and the
//! HIP/WGSL translation units the same LLIR walk emits.
//!
//! Regenerate after an intentional codegen change with
//! `SGAP_BLESS=1 cargo test --test codegen_golden`.

use sgap::compiler::codegen_cuda::{emit_kernel, macro_header};
use sgap::compiler::schedule::{
    DgConfig, FusedConfig, MttkrpConfig, Schedule, SddmmConfig, SpmmConfig, TtmConfig,
};
use sgap::compiler::{compile, flatten_fused, DialectKind, FusedAlgebra, TensorAlgebra};

fn check_golden(name: &str, got: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    if std::env::var_os("SGAP_BLESS").is_some() {
        std::fs::write(&path, got).unwrap_or_else(|e| panic!("bless {}: {e}", path.display()));
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden {}: {e}\n(run `SGAP_BLESS=1 cargo test --test codegen_golden`)",
            path.display()
        )
    });
    if got == want {
        return;
    }
    for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
        assert_eq!(
            g,
            w,
            "golden `{name}` differs at line {} (SGAP_BLESS=1 regenerates)",
            i + 1
        );
    }
    panic!(
        "golden `{name}` differs in length: got {} lines, want {} (SGAP_BLESS=1 regenerates)",
        got.lines().count(),
        want.lines().count()
    );
}

/// Listing 6 shape: `{<1 nnz, 4 col>, r}` — SegmentReduction strategy.
/// Pins the `segReduceGroup<float,r>` macro call and the §5.2
/// zero-extension predicate for both a wide and a narrow group.
#[test]
fn nnz_group_segment_reduction_golden() {
    for r in [32u32, 8] {
        let sched = Schedule::sgap_nnz_group(SpmmConfig::default(), r);
        let kernel = sgap::compiler::lower(&sched).unwrap();
        let src = emit_kernel(&kernel);
        assert!(
            src.contains(&format!("segReduceGroup<float,{r}>(C_vals, kC, val);")),
            "{src}"
        );
        assert!(
            src.contains("if ((fposA >= A2_pos[A1_dimension])) {"),
            "zero-extension predicate missing:\n{src}"
        );
        assert!(!src.contains("atomicAdd(&"), "segment reduction must not use plain atomics");
        check_golden(&format!("spmm_nnz_group_c4_r{r}.cu"), &src);
    }
}

/// Listing 5 shape: `{<1/32 row, 4 col>, r}` — ParallelReduction strategy.
/// Pins the `atomicAddGroup<float,r>` macro call.
#[test]
fn row_group_parallel_reduction_golden() {
    for r in [8u32, 2] {
        let sched = Schedule::sgap_row_group(SpmmConfig::default(), r);
        let kernel = sgap::compiler::lower(&sched).unwrap();
        let src = emit_kernel(&kernel);
        assert!(src.contains(&format!("atomicAddGroup<float,{r}>(C_vals,")), "{src}");
        assert!(!src.contains("segReduceGroup"), "row-group must not segment-reduce");
        check_golden(&format!("spmm_row_group_g32_c4_r{r}.cu"), &src);
    }
}

/// The §5.3 macro-instruction header (the device functions both goldens
/// call into) is itself pinned.
#[test]
fn macro_header_golden() {
    let h = macro_header();
    assert!(h.contains("template <typename T, int G>"));
    assert!(h.contains("__shfl_down_sync") && h.contains("__shfl_up_sync"));
    check_golden("macro_header.cu", h);
}

/// §4.3 SDDMM `{<1/g nnz>, r}` — now schedule-lowered, so its CUDA text
/// is pinned like every SpMM family. Covers the `atomicAddGroup<float,r>`
/// writeback over the per-nnz output slots.
#[test]
fn sddmm_group_golden() {
    let sched = Schedule::sddmm_group(SddmmConfig::new(64, 16, 8));
    let kernel = sgap::compiler::lower(&sched).unwrap();
    let src = emit_kernel(&kernel);
    assert!(src.contains("__global__ void sddmm_g16_r8"), "{src}");
    assert!(src.contains("atomicAddGroup<float,8>(Y_vals, pos, val);"), "{src}");
    assert!(!src.contains("segReduceGroup"), "sddmm reduces over the dense j: no segments");
    check_golden("sddmm_g16_r8.cu", &src);
}

/// MTTKRP (Eq. 2a) — the COO-3 nnz-split segment kernel, compiled through
/// the `compiler::compile` front door from its stated algebra. Pins the
/// `segReduceGroup<float,r>` writeback (the same macro instruction as
/// SpMM's Listing 6 — §2.1's claim in generated text) and the
/// zero-extension predicate over `A_nnz`.
#[test]
fn mttkrp_group_golden() {
    let sched = Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16));
    let kernel = compile(&TensorAlgebra::mttkrp(), &sched).unwrap();
    let src = emit_kernel(&kernel);
    assert!(src.contains("__global__ void mttkrp_c4_r16"), "{src}");
    assert!(src.contains("segReduceGroup<float,16>(Y_vals, out, val);"), "{src}");
    assert!(src.contains("if ((pos >= A_nnz)) {"), "zero-extension predicate missing:\n{src}");
    assert!(src.contains("X2_vals"), "Khatri-Rao gather missing:\n{src}");
    assert!(!src.contains("atomicAdd(&"), "segment reduction must not use plain atomics");
    check_golden("mttkrp_c4_r16.cu", &src);
}

/// TTM (Eq. 2b) — same COO-3 shape without the second factor gather.
#[test]
fn ttm_group_golden() {
    let sched = Schedule::ttm_group(TtmConfig::new(4, 4, 8));
    let kernel = compile(&TensorAlgebra::ttm(), &sched).unwrap();
    let src = emit_kernel(&kernel);
    assert!(src.contains("__global__ void ttm_c4_r8"), "{src}");
    assert!(src.contains("segReduceGroup<float,8>(Y_vals, out, val);"), "{src}");
    assert!(!src.contains("X2_vals") && !src.contains("f2_idx"), "{src}");
    check_golden("ttm_c4_r8.cu", &src);
}

/// Fused SDDMM→SpMM `{<1 nnz, 4 col>, 16}` — compiled through the front
/// door from the flattened producer→consumer pair. The producer's dot
/// lives in the register `tlaneY` and is consumed by the same lane's
/// segment-group reduction: exactly ONE `pos/crd` traversal (one binary
/// search) and no `Y_vals` intermediate anywhere in the generated text.
#[test]
fn fused_sddmm_spmm_golden() {
    let pair = FusedAlgebra::sddmm_spmm();
    let algebra = flatten_fused(&pair).unwrap();
    let sched = Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16));
    let kernel = compile(&algebra, &sched).unwrap();
    let src = emit_kernel(&kernel);
    assert!(src.contains("__global__ void fused_sddmm_spmm_c4_r16"), "{src}");
    assert!(src.contains("float tlaneY = 0.0f;"), "in-register producer value missing:\n{src}");
    assert!(src.contains("segReduceGroup<float,16>(C_vals, kC, val);"), "{src}");
    assert!(!src.contains("Y_vals"), "fusion must not materialize the SDDMM output:\n{src}");
    assert_eq!(
        src.matches("taco_binarySearchBefore").count(),
        1,
        "the sparse operand must be traversed exactly once:\n{src}"
    );
    assert!(!src.contains("atomicAdd(&"), "segment reduction must not use plain atomics");
    check_golden("fused_sddmm_spmm_c4_r16.cu", &src);
}

/// The same LLIR walk behind every `.cu` golden also emits HIP and WGSL:
/// both representative kernels (the Listing 6 nnz-group SpMM and the
/// fused SDDMM→SpMM) are pinned per dialect. HIP shares the CUDA kernel
/// body byte-for-byte (only the prologue differs: maskless shuffles, no
/// `__activemask()`); WGSL respells declarations, builtins, and the
/// group macros as monomorphized subgroup helpers.
#[test]
fn dialect_translation_unit_goldens() {
    let nnz = sgap::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap();
    let sched = Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16));
    let fused = compile(&flatten_fused(&FusedAlgebra::sddmm_spmm()).unwrap(), &sched).unwrap();
    for (stem, kernel) in [("spmm_nnz_group_c4_r32", &nnz), ("fused_sddmm_spmm_c4_r16", &fused)] {
        let cuda_kernel = sgap::compiler::codegen_cuda::emit_kernel(kernel);
        for dialect in [DialectKind::Hip, DialectKind::Wgsl] {
            let tu = dialect.emit_translation_unit(kernel);
            if dialect == DialectKind::Hip {
                assert!(tu.ends_with(&cuda_kernel), "HIP body must be the CUDA bytes:\n{tu}");
                assert!(!tu.contains("__shfl_up_sync"), "HIP must not use masked shuffles");
            } else {
                assert!(tu.starts_with("enable subgroups;"), "{tu}");
                assert!(!tu.contains("__restrict__"), "CUDA qualifier leaked into WGSL:\n{tu}");
            }
            check_golden(&format!("{stem}.{}", dialect.file_ext()), &tu);
        }
    }
}

/// dgSPARSE's RB+PR point `<8, 256, 8, 1/2>` (a paper best-static shape)
/// — the row-balanced strategy strides rows by the launch-bound
/// `workerDimR` scalar and writes back with `atomicAddGroup<float,g>`.
#[test]
fn dgsparse_rb_pr_golden() {
    let cfg = DgConfig {
        n: 16,
        group_sz: 8,
        block_sz: 256,
        tile_sz: 8,
        worker_dim_r_frac: 0.5,
        worker_sz: 32,
        coarsen_sz: 4,
    };
    let kernel = sgap::compiler::lower(&Schedule::dgsparse_rb_pr(cfg)).unwrap();
    let src = emit_kernel(&kernel);
    // the fraction is encoded `0p5` so the kernel name is a C identifier
    assert!(src.contains("__global__ void dg_rb_pr_rm_g8_b256_t8_w0p5("), "{src}");
    assert!(src.contains("atomicAddGroup<float,8>(C_vals,"), "{src}");
    assert!(src.contains("i = (i + workerDimR);"), "row-balance stride missing:\n{src}");
    check_golden("dg_rb_pr_rm_g8_b256_t8_w0p5.cu", &src);
}
