//! Property tests for the coordinator's batcher invariants plus
//! concurrency stress tests of the full multi-worker service: mixed
//! SpMM/SDDMM/MTTKRP/TTM traffic (the full §2.1 quartet), plan-cache
//! behaviour under repetition, the metrics accounting identity, and
//! graceful shutdown under in-flight load.

use std::sync::Arc;

use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::algos::mttkrp::{mttkrp_serial, ttm_serial};
use sgap::algos::sddmm::sddmm_serial;
use sgap::coordinator::{Batcher, CalibConfig, Coordinator, CoordinatorConfig, Request};
use sgap::sparse::{erdos_renyi, power_law, Coo3, Csr, SplitMix64};

/// Random push/drain interleavings: FIFO per key, no loss, batch bound.
#[test]
fn prop_batcher_invariants() {
    let mut rng = SplitMix64::new(0xBA7C4);
    for case in 0..50 {
        let max_batch = 1 + rng.below(8) as usize;
        let mut b: Batcher<u32, (u32, u64)> = Batcher::new(max_batch);
        let keys = 1 + rng.below(5) as u32;
        let n_items = rng.below(100) as usize;
        let mut pushed_per_key: Vec<Vec<u64>> = vec![vec![]; keys as usize];
        let mut seq = 0u64;
        let mut drained_per_key: Vec<Vec<u64>> = vec![vec![]; keys as usize];
        let mut drained_total = 0usize;

        for _ in 0..n_items {
            // random interleave: mostly pushes, some drains
            if rng.below(4) == 0 {
                if let Some((k, items)) = b.next_batch() {
                    assert!(items.len() <= max_batch, "case {case}: batch too big");
                    drained_total += items.len();
                    for (key, s) in items {
                        assert_eq!(key, k);
                        drained_per_key[k as usize].push(s);
                    }
                }
            }
            let k = rng.below(keys as u64) as u32;
            b.push(k, (k, seq));
            pushed_per_key[k as usize].push(seq);
            seq += 1;
        }
        // drain the rest
        while let Some((k, items)) = b.next_batch() {
            assert!(items.len() <= max_batch);
            drained_total += items.len();
            for (key, s) in items {
                assert_eq!(key, k);
                drained_per_key[k as usize].push(s);
            }
        }
        assert!(b.is_empty());
        assert_eq!(drained_total, n_items, "case {case}: lost items");
        for k in 0..keys as usize {
            assert_eq!(drained_per_key[k], pushed_per_key[k], "case {case}: key {k} not FIFO");
        }
    }
}

/// Model-based fairness check of the shared batcher: `next_ready` must
/// always serve the ripe bucket with the oldest head (no bucket starves
/// behind hot shapes), and — with a drain loop after every push — no head
/// ever outlives its coalescing window.
#[test]
fn prop_batcher_oldest_ripe_head_is_always_served_first() {
    use std::collections::VecDeque;
    let mut rng = SplitMix64::new(0xFA1C);
    for case in 0..40 {
        let max_batch = 1 + rng.below(6) as usize;
        let age_bound = rng.below(12);
        let keys = 1 + rng.below(5) as u32;
        let mut b: Batcher<u32, u64> = Batcher::with_age_bound(max_batch, age_bound);
        // external model: per-key queue of push seqs plus the push counter
        let mut model: Vec<VecDeque<u64>> = vec![VecDeque::new(); keys as usize];
        let mut counter = 0u64;
        for _ in 0..200 {
            let k = rng.below(keys as u64) as u32;
            b.push(k, counter);
            model[k as usize].push_back(counter);
            counter += 1;
            // drain everything ripe, checking the fairness order each time
            loop {
                let ripe_heads: Vec<(u64, usize)> = model
                    .iter()
                    .enumerate()
                    .filter_map(|(key, q)| {
                        let head = *q.front()?;
                        (q.len() >= max_batch || counter - head >= age_bound)
                            .then_some((head, key))
                    })
                    .collect();
                match b.next_ready() {
                    None => {
                        assert!(ripe_heads.is_empty(), "case {case}: ready bucket held back");
                        break;
                    }
                    Some((key, items)) => {
                        let (oldest, want_key) =
                            *ripe_heads.iter().min().expect("drained an unripe bucket");
                        assert_eq!(key as usize, want_key, "case {case}: fairness violated");
                        assert_eq!(items.first(), Some(&oldest), "case {case}: wrong head");
                        assert!(items.len() <= max_batch);
                        // served promptly: a head never ages past the
                        // coalescing window when drains follow every push
                        assert!(
                            counter - oldest <= age_bound.max(1) + max_batch as u64,
                            "case {case}: head waited {} pushes (bound {age_bound})",
                            counter - oldest,
                        );
                        let q = &mut model[key as usize];
                        for it in items {
                            assert_eq!(q.pop_front(), Some(it), "case {case}: not FIFO");
                        }
                    }
                }
            }
        }
        // a final unconditional flush drains the model dry, oldest head first
        let mut last_head = 0u64;
        while let Some((key, items)) = b.next_batch() {
            let head = *items.first().unwrap();
            assert!(head >= last_head, "case {case}: flush not oldest-first");
            last_head = head;
            let q = &mut model[key as usize];
            for it in items {
                assert_eq!(q.pop_front(), Some(it), "case {case}: flush not FIFO");
            }
        }
        assert!(model.iter().all(VecDeque::is_empty), "case {case}: flush lost items");
    }
}

/// Differential: the sharded plan cache must behave exactly like the
/// single-lock cache — same hit/miss/upgrade/invalidation counts and the
/// same served plan per key — whenever eviction pressure is off (per-
/// shard FIFO eviction order is the one sanctioned divergence under
/// pressure, so capacity here exceeds the working set).
#[test]
fn prop_sharded_cache_matches_single_lock_reference() {
    use sgap::algos::Algo;
    use sgap::coordinator::{OpKind, Plan, PlanCache, PlanOrigin, ShapeKey};

    let keys: Vec<ShapeKey> = (0..48usize)
        .map(|i| {
            let scenario = OpKind::ALL[i % OpKind::ALL.len()];
            ShapeKey::from_parts(scenario, 16 + i, 24, 100 + 3 * i, 4, (i % 9) as u16, 2, 1)
        })
        .collect();
    let plan_for = |i: usize| Plan {
        kind: Algo::TacoNnzSerial { g: 32 + (i as u32 % 4) * 32, c: 4 },
        origin: PlanOrigin::Selector,
    };

    let single = PlanCache::new(256);
    let sharded = PlanCache::with_shards(256, 8);
    assert_eq!(sharded.shard_count(), 8);
    let mut rng = SplitMix64::new(0x5AFD);
    for step in 0..600 {
        let i = rng.below(keys.len() as u64) as usize;
        let k = keys[i];
        match rng.below(4) {
            0 | 1 => {
                let a = single.get_or_insert_with(k, || plan_for(i).kind);
                let b = sharded.get_or_insert_with(k, || plan_for(i).kind);
                assert_eq!(a, b, "step {step}: divergent consult");
            }
            2 => {
                let a = single.upgrade(k, plan_for(i).kind);
                let b = sharded.upgrade(k, plan_for(i).kind);
                assert_eq!(a, b, "step {step}: divergent upgrade");
            }
            _ => {
                let scen = OpKind::ALL[rng.below(OpKind::ALL.len() as u64) as usize];
                let a = single.invalidate_scenario(scen);
                let b = sharded.invalidate_scenario(scen);
                assert_eq!(a, b, "step {step}: divergent invalidation sweep");
            }
        }
        assert_eq!(single.get(&k), sharded.get(&k), "step {step}: divergent entry");
    }
    let (a, b) = (single.stats(), sharded.stats());
    assert_eq!(a.entries, b.entries);
    assert_eq!(a.hits, b.hits);
    assert_eq!(a.misses, b.misses);
    assert_eq!(a.upgrades, b.upgrades);
    assert_eq!(a.invalidations, b.invalidations);
    assert_eq!(a.evictions, 0, "capacity must exceed the working set");
    assert_eq!(b.evictions, 0);
    // final contents agree key-by-key, and so do the serialized catalogs
    for k in &keys {
        assert_eq!(single.get(k), sharded.get(k));
    }
    let single_cat = sgap::coordinator::PlanCatalog::from_cache(&single);
    let sharded_cat = sgap::coordinator::PlanCatalog::from_cache(&sharded);
    assert_eq!(single_cat.to_json(), sharded_cat.to_json(), "catalogs must serialize identically");
}

/// The number of repeated request shapes in the stress mix.
const SHAPES: usize = 8;

/// The eight repeated request shapes of the stress mix (four SpMM, two
/// SDDMM, one MTTKRP, one TTM — the full quartet through one pool).
/// Matrices are deterministic, so repeats across all submitter threads
/// share plan-cache fingerprints.
fn shape_matrix(shape: usize) -> Csr {
    match shape {
        0 => erdos_renyi(32, 32, 100, 1).to_csr(),
        1 => erdos_renyi(48, 40, 220, 2).to_csr(),
        2 => power_law(40, 40, 260, 2.0, 3).to_csr(),
        3 => erdos_renyi(24, 24, 60, 4).to_csr(),
        4 => erdos_renyi(32, 32, 120, 5).to_csr(),
        _ => power_law(36, 36, 200, 1.8, 6).to_csr(),
    }
}

fn build_request(shape: usize, rng: &mut SplitMix64) -> Request {
    if shape == 6 {
        let a = Coo3::random((24, 16, 12), 250, 7);
        let j = 8usize;
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        return Request::Mttkrp { a, x1, x2, j_dim: j };
    }
    if shape == 7 {
        let a = Coo3::random((20, 12, 16), 300, 8);
        let l = 4usize;
        let x1: Vec<f32> = (0..a.dim2 * l).map(|_| rng.value()).collect();
        return Request::Ttm { a, x1, l_dim: l };
    }
    let a = shape_matrix(shape);
    if shape < 4 {
        let n = if shape % 2 == 0 { 4 } else { 2 };
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        Request::Spmm { a, b, n }
    } else {
        let j = if shape == 4 { 8 } else { 16 };
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        Request::Sddmm { a, x1, x2, j_dim: j }
    }
}

/// Serial oracle for a request (to prove responses are not cross-wired).
fn oracle(req: &Request) -> Vec<f32> {
    match req {
        Request::Spmm { a, b, n } => spmm_serial(a, b, *n),
        Request::Sddmm { a, x1, x2, j_dim } => sddmm_serial(a, x1, x2, *j_dim),
        Request::Mttkrp { a, x1, x2, j_dim } => mttkrp_serial(a, x1, x2, *j_dim),
        Request::Ttm { a, x1, l_dim } => ttm_serial(a, x1, *l_dim),
    }
}

/// 8 submitter threads × 100 mixed quartet jobs through the pooled
/// coordinator: every request is answered exactly once with *its own*
/// result, the metrics identity `completed + errors == submitted` holds,
/// and repeated shapes are served via plan-cache hits with a
/// selector-chosen plan.
#[test]
fn coordinator_stress_mixed_traffic() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig { workers: 4, ..CoordinatorConfig::default() })
            .unwrap(),
    );
    let clients = 8usize;
    let per_client = 100usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x57E55 + t as u64);
            let mut answered = 0usize;
            let mut hits = 0usize;
            for i in 0..per_client {
                let req = build_request((t + i) % SHAPES, &mut rng);
                let want = oracle(&req);
                let is_spmm = matches!(req, Request::Spmm { .. });
                let rx = c.submit(req);
                let resp = rx.recv().expect("worker gone").expect("request failed");
                assert_eq!(resp.c.len(), want.len(), "client {t} job {i}: wrong shape");
                assert!(
                    max_rel_err(&resp.c, &want) < 5e-4,
                    "client {t} job {i}: response is not this request's result"
                );
                // exactly-once: the one-shot channel has nothing further
                assert!(rx.try_recv().is_err(), "client {t} job {i}: duplicate response");
                if resp.cache_hit {
                    hits += 1;
                    assert!(resp.plan.is_some(), "cache hit must carry its plan");
                }
                if is_spmm {
                    assert!(
                        resp.backend.is_sim() || resp.backend.is_cpu(),
                        "unexpected backend {}",
                        resp.backend
                    );
                }
                answered += 1;
            }
            (answered, hits)
        }));
    }
    let mut answered = 0usize;
    let mut hits = 0usize;
    for h in handles {
        let (a, hi) = h.join().unwrap();
        answered += a;
        hits += hi;
    }
    assert_eq!(answered, clients * per_client, "lost responses");
    assert!(hits > 0, "repeated shapes must hit the plan cache");

    let s = coord.metrics.snapshot();
    assert_eq!(s.submitted, (clients * per_client) as u64);
    assert_eq!(s.completed + s.errors, s.submitted, "metrics identity");
    assert_eq!(s.errors, 0);
    assert!(s.batches >= 1);
    assert!(s.cache_hits > 0, "metrics must see plan-cache hits");
    assert_eq!(s.cache_hits + s.cache_misses, s.submitted, "every job consulted the cache");
    // each distinct (shape, width) pair fingerprints once — misses stay
    // bounded by the number of distinct shapes (not the request count)
    assert!(
        s.cache_misses <= SHAPES as u64,
        "cache misses {} exceed distinct shapes",
        s.cache_misses
    );
    // both scenarios flowed through the same pool: sim backends for spmm
    // families and sddmm must all be present
    assert!(s.backends.iter().any(|b| b.backend == "sim:sddmm-group"), "{:?}", s.backends);
    assert!(s.backends.iter().any(|b| b.backend.starts_with("sim:sgap")), "{:?}", s.backends);
    assert!(s.backends.iter().any(|b| b.backend == "sim:mttkrp-group"), "{:?}", s.backends);
    assert!(s.backends.iter().any(|b| b.backend == "sim:ttm-group"), "{:?}", s.backends);
    let served: u64 = s.backends.iter().map(|b| b.count).sum();
    assert_eq!(served, s.completed, "per-backend counts sum to completed");
    // per-op quantiles: the mix exercises the full quartet, so each op
    // label has a populated histogram with ordered quantiles, and the
    // per-op counts partition completed
    for want in ["spmm", "sddmm", "mttkrp", "ttm"] {
        let o = s
            .ops
            .iter()
            .find(|o| o.op == want)
            .unwrap_or_else(|| panic!("missing per-op snapshot for {want}: {:?}", s.ops));
        assert!(o.count > 0, "{want}: empty op histogram");
        assert!(o.p50_us <= o.p99_us, "{want}: p50 {} > p99 {}", o.p50_us, o.p99_us);
    }
    let op_total: u64 = s.ops.iter().map(|o| o.count).sum();
    assert_eq!(op_total, s.completed, "per-op counts sum to completed");

    let cache = coord.plan_cache.stats();
    assert!(cache.hits > 0 && cache.entries >= 2);
    Arc::try_unwrap(coord).ok().expect("all clients done").shutdown();
}

/// `shutdown()` with jobs still queued joins cleanly (no deadlock) and —
/// because shutdown drains accepted work — every already-submitted job
/// still gets its response.
#[test]
fn shutdown_under_inflight_load_is_clean_and_lossless() {
    let coord =
        Coordinator::start(CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() })
            .unwrap();
    let mut rng = SplitMix64::new(0x5D);
    let mut rxs = Vec::new();
    for i in 0..120usize {
        let req = build_request(i % SHAPES, &mut rng);
        rxs.push((oracle(&req), coord.submit(req)));
    }
    // shut down while most of those jobs are still in the queue
    coord.shutdown();
    for (i, (want, rx)) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap_or_else(|_| panic!("job {i} dropped during shutdown")).unwrap();
        assert!(max_rel_err(&resp.c, &want) < 5e-4, "job {i} wrong result after shutdown");
    }
}

/// Submissions racing shutdown never hang: they either get served or see a
/// disconnected channel.
#[test]
fn submit_racing_shutdown_never_deadlocks() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            workers: 2,
            queue_cap: 4, // small queue: exercises the backpressure path too
            ..CoordinatorConfig::default()
        })
        .unwrap(),
    );
    let mut submitters = Vec::new();
    for t in 0..4u64 {
        let c = coord.clone();
        submitters.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(t);
            let mut served = 0usize;
            for i in 0..30usize {
                let rx = c.submit(build_request(i % SHAPES, &mut rng));
                match rx.recv() {
                    Ok(Ok(_)) => served += 1,
                    Ok(Err(e)) => panic!("unexpected serve error: {e}"),
                    Err(_) => break, // pool shut down mid-stream: fine
                }
            }
            served
        }));
    }
    // let some traffic through, then stop accepting out from under them
    std::thread::sleep(std::time::Duration::from_millis(30));
    coord.close();
    let total: usize = submitters.into_iter().map(|h| h.join().unwrap()).sum();
    let cache = coord.plan_cache.clone();
    Arc::try_unwrap(coord).ok().expect("submitters joined").shutdown();
    assert!(total > 0, "some requests must have been served");
    assert!(cache.stats().misses > 0);
}

/// Drift injection: with online calibration enabled and the drift
/// threshold forced to zero, a stream of sim-served SpMM jobs must trip
/// at least one refit — new constants go live (generation advances), the
/// affected plan-cache scenario is invalidated, and the calibration
/// metrics advance.
#[test]
fn online_drift_triggers_refit_and_cache_invalidation() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        calib: CalibConfig {
            enabled: true,
            drift_threshold: 0.0, // every observation counts as drift
            min_samples: 8,
            ..CalibConfig::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    assert_eq!(coord.calibrator.generation(), 0, "no warm start configured");

    // one repeated sim-admitted shape: repeats hit the plan cache, so the
    // invalidation provably dropped a live entry
    let mut rng = SplitMix64::new(0xD21F7);
    let a = erdos_renyi(32, 32, 100, 1).to_csr();
    let n = 4usize;
    let mut sim_served = 0usize;
    for _ in 0..60 {
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let resp = coord.spmm_blocking(a.clone(), b, n).unwrap();
        if resp.backend.is_sim() {
            sim_served += 1;
        }
    }
    // premise: the shape is sim-admitted, so the calibrator saw samples
    assert!(sim_served >= 8, "only {sim_served}/60 jobs were sim-served");

    let s = coord.metrics.snapshot();
    assert!(s.calib_samples >= 8, "calibrator observed {} samples", s.calib_samples);
    assert!(s.calib_refits >= 1, "zero drift threshold must force a refit");
    assert!(s.calib_residual >= 0.0 && s.calib_residual.is_finite());
    assert!(
        coord.calibrator.generation() >= 1,
        "a refit must advance the calibrator generation"
    );
    let cache = coord.plan_cache.stats();
    assert!(
        cache.invalidations >= 1,
        "refit must invalidate the spmm scenario's cached plans"
    );
    // the service kept answering correctly across refits (checked by
    // spmm_blocking's Ok), and the loop converges rather than thrashing:
    // after a refit the EWMA resets, so refits stay bounded by samples
    assert!(s.calib_refits <= s.calib_samples / 8 + 1);
    coord.shutdown();
}

/// Metrics quantiles are ordered and the global/identity counters agree.
#[test]
fn metrics_quantiles_ordered() {
    let coord = Coordinator::start(CoordinatorConfig::default()).unwrap();
    for i in 0..30u64 {
        let a = erdos_renyi(32, 32, 64, i).to_csr();
        let b = vec![1.0f32; 32 * 2];
        let _ = coord.spmm_blocking(a, b, 2).unwrap();
    }
    let s = coord.metrics.snapshot();
    assert!(s.p50_us <= s.p99_us);
    assert!(s.mean_us > 0.0);
    for b in &s.backends {
        assert!(b.p50_us <= b.p99_us, "{}: p50 {} > p99 {}", b.backend, b.p50_us, b.p99_us);
    }
    coord.shutdown();
}
