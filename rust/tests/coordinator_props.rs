//! Property tests for the coordinator's batcher invariants plus a
//! concurrency stress test of the full service (CPU fallback path).

use sgap::coordinator::{Batcher, Coordinator, Request};
use sgap::sparse::{erdos_renyi, SplitMix64};

/// Random push/drain interleavings: FIFO per key, no loss, batch bound.
#[test]
fn prop_batcher_invariants() {
    let mut rng = SplitMix64::new(0xBA7C4);
    for case in 0..50 {
        let max_batch = 1 + rng.below(8) as usize;
        let mut b: Batcher<u32, (u32, u64)> = Batcher::new(max_batch);
        let keys = 1 + rng.below(5) as u32;
        let n_items = rng.below(100) as usize;
        let mut pushed_per_key: Vec<Vec<u64>> = vec![vec![]; keys as usize];
        let mut seq = 0u64;
        let mut drained_per_key: Vec<Vec<u64>> = vec![vec![]; keys as usize];
        let mut drained_total = 0usize;

        for _ in 0..n_items {
            // random interleave: mostly pushes, some drains
            if rng.below(4) == 0 {
                if let Some((k, items)) = b.next_batch() {
                    assert!(items.len() <= max_batch, "case {case}: batch too big");
                    drained_total += items.len();
                    for (key, s) in items {
                        assert_eq!(key, k);
                        drained_per_key[k as usize].push(s);
                    }
                }
            }
            let k = rng.below(keys as u64) as u32;
            b.push(k, (k, seq));
            pushed_per_key[k as usize].push(seq);
            seq += 1;
        }
        // drain the rest
        while let Some((k, items)) = b.next_batch() {
            assert!(items.len() <= max_batch);
            drained_total += items.len();
            for (key, s) in items {
                assert_eq!(key, k);
                drained_per_key[k as usize].push(s);
            }
        }
        assert!(b.is_empty());
        assert_eq!(drained_total, n_items, "case {case}: lost items");
        for k in 0..keys as usize {
            assert_eq!(drained_per_key[k], pushed_per_key[k], "case {case}: key {k} not FIFO");
        }
    }
}

/// Many threads submitting concurrently: every request is answered and
/// the metrics agree.
#[test]
fn coordinator_stress_concurrent_clients() {
    let coord = std::sync::Arc::new(Coordinator::start(None).unwrap());
    let clients = 8;
    let per_client = 12;
    let mut handles = Vec::new();
    for t in 0..clients {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(t as u64);
            for i in 0..per_client {
                let a = erdos_renyi(48, 48, 200, t * 100 + i).to_csr();
                let b: Vec<f32> = (0..48 * 2).map(|_| rng.value()).collect();
                let rx = c.submit(Request { a, b, n: 2 });
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(resp.c.len(), 48 * 2);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = coord.metrics.snapshot();
    assert_eq!(s.submitted, (clients * per_client) as u64);
    assert_eq!(s.completed, (clients * per_client) as u64);
    assert_eq!(s.errors, 0);
    assert!(s.batches >= 1);
}

/// Metrics quantiles are ordered.
#[test]
fn metrics_quantiles_ordered() {
    let coord = Coordinator::start(None).unwrap();
    for i in 0..30u64 {
        let a = erdos_renyi(32, 32, 64, i).to_csr();
        let b = vec![1.0f32; 32 * 2];
        let _ = coord.spmm_blocking(a, b, 2).unwrap();
    }
    let s = coord.metrics.snapshot();
    assert!(s.p50_us <= s.p99_us);
    assert!(s.mean_us > 0.0);
    coord.shutdown();
}
