//! Differential property tests: every simulated kernel the unified
//! catalog exposes matches the serial CPU oracle within 5e-4.
//!
//! * SpMM: the compiler-family sweep (TACO + Sgap) across the
//!   reduction-width grid r ∈ {2,4,8,16,32}, the matrix families the
//!   selector keys on (uniform ER, power-law skew, banded, empty-row
//!   corner cases), and dense widths n ∈ {1, 4, 32}.
//! * SDDMM: every scheduled candidate in `tuner::space::sddmm_candidates`
//!   against `sddmm_serial` over the matrix-family × j_dim grid.
//! * The plan-cache path for both scenarios: a cached plan must reproduce
//!   the fresh-selection result bit-for-bit.

use sgap::algos::catalog::compiler_family_sweep;
use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::algos::sddmm::sddmm_serial;
use sgap::algos::{Algo, BandAlgo, CompositeConfig};
use sgap::coordinator::{PlanCache, ShapeKey};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{
    banded, choose_cuts, erdos_renyi, power_law, Coo, Csr, MatrixStats, SplitMix64, CUT_SENTINEL,
};
use sgap::tuner::{sddmm_candidates, Selector};

const TOL: f32 = 5e-4;
const RS: [u32; 5] = [2, 4, 8, 16, 32];
const NS: [usize; 3] = [1, 4, 32];

fn b_for(a: &Csr, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..a.cols * n).map(|_| rng.value()).collect()
}

/// One matrix per family the selector distinguishes, plus the empty-row
/// corners that stress zero extension and the row-advance loops.
fn families(seed: u64) -> Vec<(&'static str, Csr)> {
    // hub: one full row, everything else empty except a tail entry
    let mut hub: Vec<(u32, u32, f32)> = (0..64u32).map(|c| (0u32, c, 1.0 - c as f32)).collect();
    hub.push((63, 0, 2.5));
    // comb: only every fourth row populated (interior + trailing empties)
    let comb: Vec<(u32, u32, f32)> =
        (0..96u32).step_by(4).flat_map(|r| [(r, r % 37, 1.5), (r, 40 + r % 23, -0.5)]).collect();
    vec![
        ("erdos_renyi", erdos_renyi(96, 80, 900, seed).to_csr()),
        ("power_law", power_law(96, 96, 1100, 1.8, seed).to_csr()),
        ("banded", banded(96, 7, seed).to_csr()),
        ("corner_hub", Coo::new(64, 64, hub).to_csr()),
        ("corner_empty_rows", Coo::new(96, 64, comb).to_csr()),
    ]
}

#[test]
fn every_catalog_kernel_matches_oracle_across_r_families_n() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &n in &NS {
        for (fam, a) in families(0xD1FF ^ n as u64) {
            let b = b_for(&a, n, 7 + n as u64);
            let want = spmm_serial(&a, &b, n);
            for r in RS {
                for alg in compiler_family_sweep(n as u32, r) {
                    let res = alg.run(&machine, &a, &b, n as u32).unwrap_or_else(|e| {
                        panic!("{fam} n={n} r={r}: {} failed: {e}", alg.name())
                    });
                    let err = max_rel_err(&res.run.c, &want);
                    assert!(
                        err < TOL,
                        "{fam} n={n} r={r}: {} err {err} (matrix {}x{} nnz {})",
                        alg.name(),
                        a.rows,
                        a.cols,
                        a.nnz()
                    );
                }
            }
        }
    }
}

/// The plan-cache path is result-identical to fresh selection: a cache hit
/// hands back the same `Algo`, and running it reproduces the miss-path
/// output bit-for-bit (and both match the oracle).
#[test]
fn plan_cache_path_equals_fresh_selection() {
    let machine = Machine::new(HwProfile::rtx3090());
    let selector = Selector::default();
    let cache = PlanCache::new(64);
    for &n in &NS {
        for (fam, a) in families(0xCAC4E ^ n as u64) {
            let stats = MatrixStats::of(&a);
            let key = ShapeKey::spmm(&stats, n as u32);
            let fresh = selector.select(&stats, n as u32);
            let (plan, hit) = cache.get_or_insert_with(key, || fresh);
            assert!(!hit, "{fam} n={n}: first sight must miss");
            let (plan2, hit2) = cache.get_or_insert_with(key, || unreachable!("hit expected"));
            assert!(hit2, "{fam} n={n}: repeat must hit");
            assert_eq!(plan2, plan);
            let cached = plan2.kind;
            assert!(!cached.is_sddmm(), "{fam} n={n}: spmm key yielded an SDDMM plan");
            assert_eq!(cached, fresh, "cached plan must be the selector's choice");

            let b = b_for(&a, n, 21 + n as u64);
            let via_cache = cached.run(&machine, &a, &b, n as u32).unwrap();
            let via_fresh = fresh.run(&machine, &a, &b, n as u32).unwrap();
            assert_eq!(
                via_cache.run.c, via_fresh.run.c,
                "{fam} n={n}: cache path diverged from fresh selection"
            );
            let want = spmm_serial(&a, &b, n);
            let err = max_rel_err(&via_cache.run.c, &want);
            assert!(err < TOL, "{fam} n={n}: selected {} err {err}", cached.name());
        }
    }
    let s = cache.stats();
    assert_eq!(s.misses as usize, NS.len() * 5);
    assert_eq!(s.hits, s.misses);
}

/// Composite (per-band hybrid) plans across the generator families ×
/// widths. Two properties:
///
/// * a mixed-plan composite (a different catalog kernel per band) matches
///   the serial oracle within the usual tolerance, and
/// * a composite whose bands all run the *row-serial* kernel is bitwise
///   identical to that kernel on the unpartitioned matrix — banding is a
///   pure re-association of independent rows, so with a fixed per-row
///   reduction order the partition cannot change a single bit.
///
/// When the partitioner declines a low-skew family (one occupied degree
/// bucket), the test still exercises the composite path with a fixed
/// 2-band cut — `Algo::run` must be correct for *any* cuts, because a
/// `ShapeKey` collision can hand a composite to a matrix it was not
/// selected for.
#[test]
fn composite_plans_match_oracle_across_families_n() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &n in &NS {
        for (fam, a) in families(0xBA4D ^ n as u64) {
            let stats = MatrixStats::of(&a);
            let (bands, cuts) =
                choose_cuts(&stats).unwrap_or((2, [2, CUT_SENTINEL]));
            let b = b_for(&a, n, 43 + n as u64);
            let want = spmm_serial(&a, &b, n);

            let mixed = Algo::Composite(CompositeConfig {
                bands: bands as u8,
                cuts,
                plans: [
                    BandAlgo::TacoRowSerial { x: 1, c: 1 },
                    BandAlgo::SgapRowGroup { g: 8, c: 1, r: 4 },
                    BandAlgo::SgapNnzGroup { c: 1, r: 8 },
                ],
            });
            let res = mixed.run(&machine, &a, &b, n as u32).unwrap_or_else(|e| {
                panic!("{fam} n={n}: {} failed: {e}", mixed.name())
            });
            let err = max_rel_err(&res.run.c, &want);
            assert!(err < TOL, "{fam} n={n}: {} err {err}", mixed.name());

            let serial = BandAlgo::TacoRowSerial { x: 1, c: 1 };
            let uniform = Algo::Composite(CompositeConfig {
                bands: bands as u8,
                cuts,
                plans: [serial; 3],
            });
            let via_bands = uniform.run(&machine, &a, &b, n as u32).unwrap();
            let single = serial.to_algo().run(&machine, &a, &b, n as u32).unwrap();
            assert_eq!(
                via_bands.run.c, single.run.c,
                "{fam} n={n}: banding changed the row-serial result bitwise"
            );
        }
    }
}

/// Dense factor pair for an SDDMM differential run.
fn x_for(a: &Csr, j: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let x1 = (0..a.rows * j).map(|_| rng.value()).collect();
    let x2 = (0..j * a.cols).map(|_| rng.value()).collect();
    (x1, x2)
}

/// j = 20 exercises the non-power-of-two tail (idle lanes in the last
/// stride); 1 and 32 bracket the grouped reduction widths.
const JS: [usize; 3] = [1, 20, 32];

/// Every scheduled SDDMM candidate matches the serial oracle over the
/// matrix-family × j_dim grid — the §4.3 differential sweep, now
/// reachable because SDDMM lowers through the shared compile pipeline.
#[test]
fn every_sddmm_candidate_matches_oracle_across_families_j() {
    let machine = Machine::new(HwProfile::rtx3090());
    for &j in &JS {
        for (fam, a) in families(0x5DD ^ j as u64) {
            let (x1, x2) = x_for(&a, j, 31 + j as u64);
            let want = sddmm_serial(&a, &x1, &x2, j);
            for alg in sddmm_candidates(j as u32) {
                let res = alg.run_sddmm(&machine, &a, &x1, &x2).unwrap_or_else(|e| {
                    panic!("{fam} j={j}: {} failed: {e}", alg.name())
                });
                let err = max_rel_err(&res.run.c, &want);
                assert!(
                    err < TOL,
                    "{fam} j={j}: {} err {err} (matrix {}x{} nnz {})",
                    alg.name(),
                    a.rows,
                    a.cols,
                    a.nnz()
                );
            }
        }
    }
}

/// The SDDMM plan-cache path is result-identical to fresh selection, and
/// SpMM/SDDMM keys for the same matrix never collide into each other's
/// scenario.
#[test]
fn sddmm_plan_cache_path_equals_fresh_selection() {
    let machine = Machine::new(HwProfile::rtx3090());
    let selector = Selector::default();
    let cache = PlanCache::new(64);
    for &j in &JS {
        for (fam, a) in families(0xCA5E ^ j as u64) {
            let stats = MatrixStats::of(&a);
            let key = ShapeKey::sddmm(&stats, j as u32);
            assert_ne!(
                key,
                ShapeKey::spmm(&stats, j as u32),
                "{fam} j={j}: scenario must separate the keys"
            );
            let fresh = selector.select_sddmm(&stats, j as u32);
            assert!(fresh.is_sddmm(), "{fam} j={j}: selector returned {}", fresh.name());
            let (plan, hit) = cache.get_or_insert_with(key, || fresh);
            assert!(!hit, "{fam} j={j}: first sight must miss");
            let (plan2, hit2) = cache.get_or_insert_with(key, || unreachable!("hit expected"));
            assert!(hit2 && plan2 == plan, "{fam} j={j}: repeat must hit the same plan");
            assert_eq!(plan2.kind, fresh, "cached plan must be the selector's choice");

            let (x1, x2) = x_for(&a, j, 57 + j as u64);
            let via_cache = plan2.kind.run_sddmm(&machine, &a, &x1, &x2).unwrap();
            let via_fresh = fresh.run_sddmm(&machine, &a, &x1, &x2).unwrap();
            assert_eq!(
                via_cache.run.c, via_fresh.run.c,
                "{fam} j={j}: cache path diverged from fresh selection"
            );
            let want = sddmm_serial(&a, &x1, &x2, j);
            let err = max_rel_err(&via_cache.run.c, &want);
            assert!(err < TOL, "{fam} j={j}: selected {} err {err}", fresh.name());
        }
    }
    let s = cache.stats();
    assert_eq!(s.misses as usize, JS.len() * 5);
    assert_eq!(s.hits, s.misses);
}
