//! **Table 3** — new algorithms vs original TACO SpMM.
//!
//! Paper: best of `{<1/g row, c col>, r}` / `{<1 nnz, c col>, r}` vs best
//! of TACO's `{<g nnz, c col>, 1}` / `{<x row, c col>, 1}` per dataset,
//! tuned over reasonable g, c, x, r. Normalized speedups: 1.191 (3090),
//! 1.098 (2080), 1.223 (V100).
//!
//! Reproduction target: geomean normalized speedup in the 1.1–2 band on
//! every profile (segment group strictly extends the TACO space, so ≥ 1
//! by construction; > 1.05 shows it matters).

use sgap::bench_util::{bench_suite, geomean, normalized_speedup, random_b, Table};
use sgap::sim::{HwProfile, Machine};
use sgap::tuner::{self, tune};

fn main() {
    let n = 4u32;
    let suite = bench_suite();
    println!("Table 3 — normalized performance of new algorithms ({} matrices, N={n})", suite.len());
    println!("paper: RTX 3090 1.191, RTX 2080 1.098, Tesla V100 1.223\n");

    let taco = tuner::space::taco_candidates(n);
    let sgap_c = tuner::space::sgap_candidates(n);

    let mut table = Table::new(&["", "RTX 3090", "RTX 2080", "Tesla V100"]);
    let mut cells = vec!["Speedup".to_string()];
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let mut vals = Vec::new();
        for d in &suite {
            let a = d.matrix.to_csr();
            let b = random_b(a.cols, n as usize, 31);
            let best_taco = tune(&machine, &taco, &a, &b, n).unwrap().best().expect("taco sweep").1;
            let best_new = tune(&machine, &sgap_c, &a, &b, n).unwrap().best().expect("sgap sweep").1;
            vals.push(normalized_speedup(best_new, best_taco));
        }
        let gm = geomean(&vals);
        cells.push(format!("{gm:.3}"));
        assert!(gm > 1.03, "{}: new algorithms bring only {gm:.3}", hw.name);
    }
    table.row(&cells);
    table.print();
    println!("\nshape check passed: segment group beats stock TACO on every profile");
}
