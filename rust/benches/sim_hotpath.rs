//! Wall-clock bench of the simulator hot path itself (L3 §Perf target):
//! warp-interpretation throughput in simulated-nnz per wall-second.
//! Used by the performance pass to measure interpreter optimizations.

use std::time::Instant;

use sgap::algos::catalog::Algo;
use sgap::bench_util::random_b;
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::power_law;

fn main() {
    let machine = Machine::new(HwProfile::rtx3090());
    let a = power_law(4096, 4096, 65536, 1.6, 77).to_csr();
    let n = 4u32;
    let b = random_b(a.cols, n as usize, 3);

    println!("sim_hotpath — interpreter wall-clock throughput (4096x4096, {} nnz)", a.nnz());
    for (label, algo) in [
        ("nnz-group r=32", Algo::SgapNnzGroup { c: 4, r: 32 }),
        ("row-group g=32 r=8", Algo::SgapRowGroup { g: 32, c: 4, r: 8 }),
        ("nnz-serial g=16", Algo::TacoNnzSerial { g: 16, c: 4 }),
        ("row-serial", Algo::TacoRowSerial { x: 1, c: 4 }),
    ] {
        // warmup
        algo.run(&machine, &a, &b, n).unwrap();
        let iters = 3;
        let start = Instant::now();
        for _ in 0..iters {
            algo.run(&machine, &a, &b, n).unwrap();
        }
        let dt = start.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{label:<22} {:>8.1} ms/launch   {:>8.2} Mnnz/s",
            dt * 1e3,
            a.nnz() as f64 / dt / 1e6
        );
    }
}
