//! **Table 1** — flexible group size speedup.
//!
//! Paper: `{<1/g row, c col>, r}` with g = 32 fixed (stock TACO's split)
//! and r ∈ {8, 4} vs the stock r = 32, on RTX 3090 / RTX 2080 / V100,
//! N = 4. Paper numbers: 2.09–2.46× raw, 2.14–2.48× normalized.
//!
//! Reproduction target (DESIGN.md §5): r < 32 wins on average, with the
//! biggest margins on short-row / skewed matrices; normalized ≈ raw.

use sgap::algos::catalog::Algo;
use sgap::bench_util::{bench_suite, geomean, normalized_speedup, random_b, speedup, Table};
use sgap::sim::{HwProfile, Machine};

fn main() {
    let n = 4u32;
    let c = 4u32;
    let suite = bench_suite();
    println!("Table 1 — flexible group size speedup ({} matrices, N={n})", suite.len());
    println!("paper: r=8 ~2.09-2.45x, r=4 ~2.09-2.46x\n");

    let mut table = Table::new(&["Hardware", "r=8", "r=8 norm", "r=4", "r=4 norm"]);
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let mut sp = vec![vec![]; 2];
        let mut nsp = vec![vec![]; 2];
        for d in &suite {
            let a = d.matrix.to_csr();
            let b = random_b(a.cols, n as usize, 17);
            let base = Algo::SgapRowGroup { g: 32, c, r: 32 }
                .run(&machine, &a, &b, n)
                .expect("baseline")
                .time_s;
            for (i, r) in [8u32, 4].into_iter().enumerate() {
                let t = Algo::SgapRowGroup { g: 32, c, r }
                    .run(&machine, &a, &b, n)
                    .expect("variant")
                    .time_s;
                sp[i].push(speedup(t, base));
                nsp[i].push(normalized_speedup(t, base));
            }
        }
        table.row(&[
            hw.name.to_string(),
            format!("{:.3}", geomean(&sp[0])),
            format!("{:.3}", geomean(&nsp[0])),
            format!("{:.3}", geomean(&sp[1])),
            format!("{:.3}", geomean(&nsp[1])),
        ]);
        // shape assertions: flexible group size must win on average
        assert!(
            geomean(&nsp[0]) > 1.1,
            "{}: r=8 normalized speedup {} not > 1.1",
            hw.name,
            geomean(&nsp[0])
        );
    }
    table.print();
    println!("\nshape check passed: r<32 beats r=32 on average on all profiles");
}
