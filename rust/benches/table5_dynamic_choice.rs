//! **Table 5** — dynamic per-matrix choice vs best static configuration.
//!
//! Paper: within the tuned dgSPARSE space, compare the per-matrix best
//! configuration against the single configuration that is best *on
//! average* (the "best static"). Geomean speedups 1.09–1.41×, larger at
//! small N — the justification for a DA-SpMM-style dynamic selector.
//!
//! Reproduction target: dynamic ≥ static by construction; gain > 1.02
//! somewhere, reported per (hw, N) with the best-static config printed.

use sgap::algos::catalog::Algo;
use sgap::bench_util::{bench_suite_small as bench_suite, geomean, random_b, Table};
use sgap::sim::{HwProfile, Machine};
use sgap::tuner::space::dg_candidates_small;

fn main() {
    let suite = bench_suite();
    println!("Table 5 — dynamic choice over best static ({} matrices)", suite.len());
    println!("paper: geomean 1.095-1.406, best static like <8,256,8,1/2>\n");

    let mut table = Table::new(&["Hardware", "geomean", "N", "Best static"]);
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        for n in [128u32, 64, 16, 4] {
            let cands = dg_candidates_small(n);
            // times[config][matrix]
            let mut times = vec![vec![0f64; suite.len()]; cands.len()];
            for (mi, d) in suite.iter().enumerate() {
                let a = d.matrix.to_csr();
                let b = random_b(a.cols, n as usize, 53);
                let runs: Vec<f64> = std::thread::scope(|s| {
                    cands
                        .chunks(cands.len().div_ceil(8).max(1))
                        .map(|chunk| {
                            let a = &a;
                            let b = &b;
                            let machine = &machine;
                            s.spawn(move || {
                                chunk
                                    .iter()
                                    .map(|alg| alg.run(machine, a, b, n).unwrap().time_s)
                                    .collect::<Vec<_>>()
                            })
                        })
                        .collect::<Vec<_>>()
                        .into_iter()
                        .flat_map(|h| h.join().unwrap())
                        .collect()
                });
                for (ci, t) in runs.into_iter().enumerate() {
                    times[ci][mi] = t;
                }
            }
            // best static: minimizes geomean time across the suite
            let (static_idx, _) = times
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| geomean(a).partial_cmp(&geomean(b)).unwrap())
                .unwrap();
            // dynamic: per-matrix minimum
            let gains: Vec<f64> = (0..suite.len())
                .map(|mi| {
                    let dynamic = times.iter().map(|c| c[mi]).fold(f64::MAX, f64::min);
                    times[static_idx][mi] / dynamic
                })
                .collect();
            let gm = geomean(&gains);
            let static_name = match cands[static_idx] {
                Algo::Dg(d) => format!("<{},{},{},{}>", d.group_sz, d.block_sz, d.tile_sz, d.worker_dim_r_frac),
                ref other => other.name(),
            };
            table.row(&[hw.name.to_string(), format!("{gm:.3}"), n.to_string(), static_name]);
            assert!(gm >= 1.0 - 1e-9, "dynamic cannot lose to static: {gm}");
        }
    }
    table.print();
    println!("\nshape check passed: dynamic choice >= best static on every (hw, N)");
}
