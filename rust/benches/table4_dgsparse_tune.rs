//! **Table 4** — atomic-parallelism tuning of dgSPARSE RB+PR+RM.
//!
//! Paper: tune `<groupSz, blockSz, tileSz, workerDimR>` against the stock
//! configuration `<32, 256, 32, rows>` for N ∈ {4, 16, 64, 128}. Geomean
//! speedups 1.6–2.3×, max up to 8.6×, gains largest at small N (the
//! balance-bound regime).
//!
//! Reproduction target: geomean > 1.3 on every (hw, N); max ≥ 2; N = 4
//! geomean ≥ N = 128 geomean (balance-bound favours tuning).

use sgap::algos::catalog::Algo;
use sgap::algos::dgsparse::DgConfig;
use sgap::bench_util::{bench_suite_small as bench_suite, geomean, random_b, speedup, Table};
use sgap::sim::{HwProfile, Machine};
use sgap::tuner::{space::dg_candidates_small, tune};

fn main() {
    let suite = bench_suite();
    println!("Table 4 — dgSPARSE RB+PR+RM tuning speedup ({} matrices)", suite.len());
    println!("paper: geomean 1.69-2.31, max 3.39-8.58, N in {{4,16,64,128}}\n");

    let mut table = Table::new(&["Hardware", "geomean", "max", "N"]);
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let mut small_n_gm = 0.0;
        let mut large_n_gm = 0.0;
        for n in [128u32, 64, 16, 4] {
            let cands = dg_candidates_small(n);
            let stock = DgConfig::stock(n);
            let mut sp = Vec::new();
            for d in &suite {
                let a = d.matrix.to_csr();
                let b = random_b(a.cols, n as usize, 41);
                let t_stock = Algo::Dg(stock).run(&machine, &a, &b, n).unwrap().time_s;
                let t_best = tune(&machine, &cands, &a, &b, n).unwrap().best().expect("dg sweep").1;
                sp.push(speedup(t_best, t_stock));
            }
            let gm = geomean(&sp);
            let mx = sp.iter().cloned().fold(0.0, f64::max);
            if n == 4 {
                small_n_gm = gm;
            }
            if n == 128 {
                large_n_gm = gm;
            }
            table.row(&[hw.name.to_string(), format!("{gm:.3}"), format!("{mx:.3}"), n.to_string()]);
            if gm <= 1.2 {
                println!("SHAPE WARNING {} N={n}: tuning gains only {gm:.3}", hw.name);
            }
        }
        if small_n_gm < large_n_gm * 0.8 {
            println!(
                "SHAPE WARNING {}: N=4 gain {small_n_gm:.3} below N=128 gain {large_n_gm:.3}",
                hw.name
            );
        }
    }
    table.print();
    println!("\ndone: tuning-vs-stock table above (shape warnings, if any, printed inline)");
}
