//! **Table 2** — segment reduction normalized speedup.
//!
//! Paper: `{<1 nnz, c col>, r}` (grouped segment reduction) vs the best-g
//! `{<1/g row, c col>, r}` (atomicAddGroup) per dataset, on RTX 3090,
//! controlled c ∈ {1,2,4} and r ∈ {4,8,16,32}. Paper numbers: 1.008–1.381,
//! growing with both c and r.
//!
//! Reproduction target: normalized geomean ≥ 1 everywhere (segment
//! reduction wins where rows mismatch the group), increasing trend in r.

use sgap::algos::catalog::Algo;
use sgap::bench_util::{bench_suite, geomean, normalized_speedup, random_b, Table};
use sgap::sim::{HwProfile, Machine};

fn main() {
    let n = 4u32;
    let machine = Machine::new(HwProfile::rtx3090());
    let suite = bench_suite();
    println!("Table 2 — segment reduction normalized speedup (RTX 3090, {} matrices, N={n})", suite.len());
    println!("paper: 1.008 (c=1,r=4) … 1.381 (c=4,r=32)\n");

    let gs = [2u32, 4, 8, 16, 32];
    let mut table = Table::new(&["c", "r=4", "r=8", "r=16", "r=32"]);
    let mut by_r_at_c4: Vec<f64> = Vec::new();
    for c in [1u32, 2, 4] {
        let mut cells = vec![c.to_string()];
        for r in [4u32, 8, 16, 32] {
            let mut vals = Vec::new();
            for d in &suite {
                let a = d.matrix.to_csr();
                let b = random_b(a.cols, n as usize, 23);
                let t_seg = Algo::SgapNnzGroup { c, r }.run(&machine, &a, &b, n).unwrap().time_s;
                // best g configuration of the row kernel at this (c, r)
                let t_row = gs
                    .iter()
                    .filter(|&&g| r <= g && 256 % (g * (n / c)) == 0)
                    .map(|&g| {
                        Algo::SgapRowGroup { g, c, r }.run(&machine, &a, &b, n).unwrap().time_s
                    })
                    .fold(f64::MAX, f64::min);
                vals.push(normalized_speedup(t_seg, t_row));
            }
            let gm = geomean(&vals);
            cells.push(format!("{gm:.3}"));
            if c == 4 {
                by_r_at_c4.push(gm);
            }
        }
        table.row(&cells);
    }
    table.print();

    // shape: normalized speedup >= 1 by construction; check segment
    // reduction genuinely wins somewhere (not all exactly 1)
    assert!(
        by_r_at_c4.iter().any(|&v| v > 1.02),
        "segment reduction never wins: {by_r_at_c4:?}"
    );
    println!("\nshape check passed: segment reduction wins on part of the suite");
}
