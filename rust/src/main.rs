//! `sgap` — CLI for the Sgap reproduction.
//!
//! Subcommands:
//!   expr      — print each §2.1 algebra, its reduction dims, and the
//!               legal schedule families (the compile-API smoke test)
//!   codegen   — lower a scheduled kernel and print its source (CUDA, HIP, or WGSL)
//!   space     — print the atomic-parallelism legality map (Fig. 7/8)
//!   stats     — print the evaluation-suite matrix statistics
//!   spmm      — grid-search one suite matrix on the simulator (alias: tune)
//!   sddmm     — grid-search the scheduled SDDMM candidates likewise
//!   fused     — grid-search the fused SDDMM→SpMM candidates and compare
//!               against the tuned two-stage pipeline
//!   mttkrp    — grid-search the COO-3 MTTKRP candidates on a seeded tensor
//!   ttm       — grid-search the COO-3 TTM candidates likewise
//!   bench     — run the table-1/2/4 suites through the model-pruned
//!               tuner (plus the skew suite's hybrid-vs-single rows) and
//!               emit versioned BENCH_spmm.json / BENCH_tensor.json
//!   profile   — sweep the bench suite on the simulator, fit CostParams +
//!               launch overhead to the measurements, report before/after
//!               rank fidelity, and emit versioned CALIBRATION.json
//!   serve     — start the coordinator and push a demo workload
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — the offline
//! dependency set has no clap.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use sgap::bench_util::Table;
use sgap::compiler::codegen_cuda::macro_header;
use sgap::compiler::DialectKind;
use sgap::compiler::schedule::{
    DgConfig, FusedConfig, MttkrpConfig, Schedule, SddmmConfig, SpmmConfig, TtmConfig,
};
use sgap::compiler::{
    flatten_fused, spaces, Access, Expr, FusedAlgebra, ScheduleBuilder, TensorAlgebra,
};
use sgap::coordinator::{CoordinatorConfig, Op, Session};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{suite, Coo3, MatrixStats, SplitMix64};
use sgap::tuner;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_else(|| "true".into());
            m.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    m
}

fn flag_u32(flags: &HashMap<String, String>, key: &str, default: u32) -> Result<u32> {
    match flags.get(key) {
        Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        None => Ok(default),
    }
}

fn hw_by_name(name: &str) -> Result<HwProfile> {
    Ok(match name {
        "3090" | "rtx3090" => HwProfile::rtx3090(),
        "2080" | "rtx2080" => HwProfile::rtx2080(),
        "v100" => HwProfile::v100(),
        other => bail!("unknown hardware profile `{other}` (3090|2080|v100)"),
    })
}

fn cmd_codegen(flags: &HashMap<String, String>) -> Result<()> {
    let n = flag_u32(flags, "n", 4)?;
    let c = flag_u32(flags, "c", 4)?;
    let r = flag_u32(flags, "r", 32)?;
    let g = flag_u32(flags, "g", 32)?;
    let cfg = SpmmConfig { n, c, p: 256, g, r, x: 1 };
    let family = flags.get("family").map(String::as_str).unwrap_or("nnz-group");
    // flags map 1:1 onto each family's config — invalid combinations are
    // rejected by `lower` (KernelConfig::validate), never silently clamped
    let schedule = match family {
        "nnz-group" => Schedule::sgap_nnz_group(cfg, r),
        "row-group" => Schedule::sgap_row_group(cfg, r),
        "nnz-serial" => Schedule::taco_nnz_serial(cfg),
        "row-serial" => Schedule::taco_row_serial(cfg),
        // --n is the dense reduction width J here
        "sddmm" => Schedule::sddmm_group(SddmmConfig::new(n, g, r)),
        // --n is the dense factor/output width for the COO-3 kernels
        "mttkrp" => Schedule::mttkrp_group(MttkrpConfig::new(n, c, r)),
        "ttm" => Schedule::ttm_group(TtmConfig::new(n, c, r)),
        // --n is the consumer output width, --j the producer dot length
        "fused" => Schedule::fused_sddmm_spmm(FusedConfig::new(
            flag_u32(flags, "j", 16)?,
            n,
            c,
            r,
        )),
        // --g maps to workerSz, --r to groupSz, --c (if given) to coarsenSz
        "dgsparse" => {
            let stock = DgConfig::stock(n);
            Schedule::dgsparse_rb_pr(DgConfig {
                group_sz: r,
                worker_sz: g,
                coarsen_sz: if flags.contains_key("c") { c } else { stock.coarsen_sz },
                ..stock
            })
        }
        other => bail!("unknown family `{other}`"),
    };
    println!(
        "// schedule: {}",
        schedule.cmds.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(" and ")
    );
    println!("// algebra: {}", schedule.algebra());
    println!("// CIN: {}", schedule.to_cin());
    println!();
    let kernel = sgap::compiler::compile(&schedule.algebra(), &schedule)?;
    // --dialect picks the backend spelling; the same LLIR walk emits all
    // three, so every family/flag combination above works per dialect
    let dialect_name = flags.get("dialect").map(String::as_str).unwrap_or("cuda");
    let dialect = DialectKind::parse(dialect_name)
        .with_context(|| format!("unknown dialect `{dialect_name}` (cuda|hip|wgsl)"))?;
    print!("{}", dialect.emit_translation_unit(&kernel));
    Ok(())
}

fn cmd_space() -> Result<()> {
    println!("atomic parallelism space (g,c in {{2..32}}, r in {{1..32}}) — Fig. 7/8");
    println!("{:<34} {:<10} reason", "point", "legal");
    for (p, l) in spaces::enumerate_all(&[2, 8, 32], &[4], &[1, 4, 8, 32]) {
        match l {
            Ok(()) => println!("{:<34} {:<10}", p.to_string(), "yes"),
            Err(e) => println!("{:<34} {:<10} {:?}", p.to_string(), "no", e),
        }
    }
    println!("\nDA-SpMM embedding (c = 4):");
    for (name, p) in spaces::AtomicPoint::da_spmm_embedding(4) {
        println!("  {name:<8} = {p}");
    }
    Ok(())
}

fn cmd_stats() -> Result<()> {
    println!(
        "{:<26} {:>8} {:>10} {:>10} {:>8} {:>8} {:>6}",
        "name", "rows", "nnz", "density", "deg", "cv", "gini"
    );
    for d in suite() {
        let s = MatrixStats::of(&d.matrix.to_csr());
        println!(
            "{:<26} {:>8} {:>10} {:>10.2e} {:>8.1} {:>8.2} {:>6.2}",
            d.name, s.rows, s.nnz, s.density, s.row_degree_mean, s.row_degree_cv, s.gini
        );
    }
    Ok(())
}

fn cmd_tune(flags: &HashMap<String, String>) -> Result<()> {
    let n = flag_u32(flags, "n", 4)?;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let name = flags.get("dataset").cloned().unwrap_or_else(|| "er_1024_d5e-3".into());
    let ds = suite()
        .into_iter()
        .find(|d| d.name == name)
        .with_context(|| format!("dataset `{name}` not in suite (try `sgap stats` for names)"))?;
    let a = ds.matrix.to_csr();
    let mut rng = SplitMix64::new(7);
    let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
    let machine = Machine::new(hw);

    let mut cands = tuner::space::taco_candidates(n);
    cands.extend(tuner::space::sgap_candidates(n));
    println!("tuning {} on {} ({} candidates, N={n})", name, hw.name, cands.len());
    let out = tuner::tune(&machine, &cands, &a, &b, n)?;
    println!("{:<34} {:>12} {:>10}", "algorithm", "time (us)", "GFLOP/s");
    for (alg, t, gf) in out.ranked.iter().take(12) {
        println!("{:<34} {:>12.2} {:>10.2}", alg.name(), t * 1e6, gf);
    }
    let (best, t) = out.best().context("empty sweep")?;
    println!("\nbest: {} at {:.2} us", best.name(), t * 1e6);
    Ok(())
}

fn cmd_sddmm(flags: &HashMap<String, String>) -> Result<()> {
    let j = flag_u32(flags, "j", 16)?;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let name = flags.get("dataset").cloned().unwrap_or_else(|| "er_1024_d5e-3".into());
    let ds = suite()
        .into_iter()
        .find(|d| d.name == name)
        .with_context(|| format!("dataset `{name}` not in suite (try `sgap stats` for names)"))?;
    let a = ds.matrix.to_csr();
    let mut rng = SplitMix64::new(7);
    let x1: Vec<f32> = (0..a.rows * j as usize).map(|_| rng.value()).collect();
    let x2: Vec<f32> = (0..j as usize * a.cols).map(|_| rng.value()).collect();
    let machine = Machine::new(hw);

    let cands = tuner::space::sddmm_candidates(j);
    println!("sddmm-tuning {} on {} ({} candidates, J={j})", name, hw.name, cands.len());
    let out = tuner::tune_sddmm_ranked(&machine, &cands, &a, &x1, &x2)?;
    println!("{:<34} {:>12} {:>10}", "plan", "time (us)", "GFLOP/s");
    for (alg, t, gf) in out.ranked.iter().take(12) {
        println!("{:<34} {:>12.2} {:>10.2}", alg.name(), t * 1e6, gf);
    }
    let (best, t) = out.best().context("empty sweep")?;
    println!("\nbest: {} at {:.2} us", best.name(), t * 1e6);
    let selected = tuner::Selector::default().select_sddmm(&MatrixStats::of(&a), j);
    match out.time_of(&selected) {
        Some(ts) => println!(
            "selector fast path: {} at {:.2} us ({:.2}x of best)",
            selected.name(),
            ts * 1e6,
            ts / t
        ),
        None => println!("selector fast path: {} (outside the sweep grid)", selected.name()),
    }
    Ok(())
}

/// The compile-API smoke test: every quartet algebra in, its reduction
/// dims and legal schedule families out — all through the public
/// `ScheduleBuilder` front door. The fused SDDMM→SpMM pair rides along:
/// its legality check runs before any schedule, and an illegal pair is a
/// typed `CompileError`, not a panic.
fn cmd_expr() -> Result<()> {
    let statements = [
        ("spmm", TensorAlgebra::spmm()),
        ("sddmm", TensorAlgebra::sddmm()),
        ("mttkrp", TensorAlgebra::mttkrp()),
        ("ttm", TensorAlgebra::ttm()),
        ("fused", TensorAlgebra::fused_sddmm_spmm()),
    ];
    for (name, algebra) in statements {
        let builder = ScheduleBuilder::new(&algebra)?;
        let dims: Vec<String> =
            algebra.reduction_dims().iter().map(|d| d.to_string()).collect();
        println!("{name:<8} {algebra}");
        if name == "fused" {
            println!("         producer/consumer pair: {}", FusedAlgebra::sddmm_spmm());
        }
        println!("         reduction dims: {{{}}}", dims.join(", "));
        println!("         legal schedule families:");
        for family in builder.legal_families() {
            println!("           {family}");
        }
        println!();
    }
    // an illegal pair — the consumer reading the intermediate transposed,
    // at coordinates the producer never wrote — is a typed error
    let mut bad = FusedAlgebra::sddmm_spmm();
    bad.consumer.rhs = Expr::Mul(
        Box::new(Expr::Access(Access::new("Y", &["j", "i"]))),
        Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
    );
    match flatten_fused(&bad) {
        Err(e) => println!("rejected (typed): {e}"),
        Ok(_) => bail!("transposed intermediate read must be rejected"),
    }
    Ok(())
}

/// `sgap fused` — sweep the fused SDDMM→SpMM grid on one suite matrix and
/// report the best fused plan against the tuned two-stage pipeline
/// (best SDDMM sweep time + best SpMM sweep time on the same operands).
fn cmd_fused(flags: &HashMap<String, String>) -> Result<()> {
    let j = flag_u32(flags, "j", 16)?;
    let n = flag_u32(flags, "n", 4)?;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let name = flags.get("dataset").cloned().unwrap_or_else(|| "er_1024_d5e-3".into());
    let ds = suite()
        .into_iter()
        .find(|d| d.name == name)
        .with_context(|| format!("dataset `{name}` not in suite (try `sgap stats` for names)"))?;
    let a = ds.matrix.to_csr();
    let mut rng = SplitMix64::new(7);
    let x1: Vec<f32> = (0..a.rows * j as usize).map(|_| rng.value()).collect();
    let x2: Vec<f32> = (0..j as usize * a.cols).map(|_| rng.value()).collect();
    let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
    let machine = Machine::new(hw);

    let cands = tuner::fused_candidates(j, n);
    anyhow::ensure!(
        !cands.is_empty(),
        "no legal fused launch shape for N={n}; run `sgap sddmm` + `sgap spmm` separately"
    );
    println!("fused-tuning {} on {} ({} candidates, J={j}, N={n})", name, hw.name, cands.len());
    let out = tuner::tune_fused_ranked(&machine, &cands, &a, &x1, &x2, &b)?;
    print_ranked(&out)?;
    let (_, t_fused) = out.best().context("empty fused sweep")?;
    match tuner::Selector::default().select_fused(&MatrixStats::of(&a), j, n) {
        Some(selected) => match out.time_of(&selected) {
            Some(ts) => println!(
                "selector fast path: {} at {:.2} us ({:.2}x of best)",
                selected.name(),
                ts * 1e6,
                ts / t_fused
            ),
            None => println!("selector fast path: {} (outside the sweep grid)", selected.name()),
        },
        None => println!("selector fast path: none (two-stage fallback)"),
    }
    // the two-stage baseline: best SDDMM sweep + best SpMM sweep on the
    // same operands (the SpMM stage's timing is value-independent, so the
    // unscaled matrix stands in for the materialized intermediate)
    let sddmm_out =
        tuner::tune_sddmm(&machine, &tuner::sddmm_candidates(j), &a, &x1, &x2)?;
    let mut spmm_cands = tuner::taco_candidates(n);
    spmm_cands.extend(tuner::sgap_candidates(n));
    let (_, t_spmm) = tuner::tune(&machine, &spmm_cands, &a, &b, n)?
        .best()
        .context("empty spmm sweep")?;
    let t_two_stage = sddmm_out.1 + t_spmm;
    println!(
        "\ntwo-stage pipeline: {:.2} us (sddmm {:.2} + spmm {:.2}); fused is {:.2}x",
        t_two_stage * 1e6,
        sddmm_out.1 * 1e6,
        t_spmm * 1e6,
        t_two_stage / t_fused
    );
    Ok(())
}

/// Seeded random COO-3 tensor from the --d0/--d1/--d2/--nnz flags.
fn tensor_from_flags(flags: &HashMap<String, String>) -> Result<Coo3> {
    let d0 = flag_u32(flags, "d0", 128)? as usize;
    let d1 = flag_u32(flags, "d1", 96)? as usize;
    let d2 = flag_u32(flags, "d2", 64)? as usize;
    let nnz = flag_u32(flags, "nnz", 4000)? as usize;
    let seed = flag_u32(flags, "seed", 7)? as u64;
    Ok(Coo3::random((d0, d1, d2), nnz, seed))
}

fn print_ranked(out: &tuner::TuneOutcome) -> Result<()> {
    println!("{:<34} {:>12} {:>10}", "plan", "time (us)", "GFLOP/s");
    for (alg, t, gf) in out.ranked.iter().take(12) {
        println!("{:<34} {:>12.2} {:>10.2}", alg.name(), t * 1e6, gf);
    }
    let (best, t) = out.best().context("empty sweep")?;
    println!("\nbest: {} at {:.2} us", best.name(), t * 1e6);
    Ok(())
}

fn cmd_mttkrp(flags: &HashMap<String, String>) -> Result<()> {
    let j = flag_u32(flags, "j", 16)?;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let a = tensor_from_flags(flags)?;
    let mut rng = SplitMix64::new(11);
    let x1: Vec<f32> = (0..a.dim1 * j as usize).map(|_| rng.value()).collect();
    let x2: Vec<f32> = (0..a.dim2 * j as usize).map(|_| rng.value()).collect();
    let machine = Machine::new(hw);
    let cands = tuner::mttkrp_candidates(j);
    anyhow::ensure!(!cands.is_empty(), "no legal MTTKRP launch shape for J={j}");
    println!(
        "mttkrp-tuning {}x{}x{} nnz={} on {} ({} candidates, J={j})",
        a.dim0, a.dim1, a.dim2, a.nnz(), hw.name, cands.len()
    );
    let out = tuner::tune_mttkrp_ranked(&machine, &cands, &a, &x1, &x2)?;
    print_ranked(&out)?;
    let (_, t) = out.best().context("empty sweep")?;
    match tuner::Selector::default().select_mttkrp(&a, j) {
        Some(selected) => match out.time_of(&selected) {
            Some(ts) => println!(
                "selector fast path: {} at {:.2} us ({:.2}x of best)",
                selected.name(),
                ts * 1e6,
                ts / t
            ),
            None => println!("selector fast path: {} (outside the sweep grid)", selected.name()),
        },
        None => println!("selector fast path: none (width {j} served on the CPU)"),
    }
    Ok(())
}

fn cmd_ttm(flags: &HashMap<String, String>) -> Result<()> {
    let l = flag_u32(flags, "l", 16)?;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let a = tensor_from_flags(flags)?;
    let mut rng = SplitMix64::new(13);
    let x1: Vec<f32> = (0..a.dim2 * l as usize).map(|_| rng.value()).collect();
    let machine = Machine::new(hw);
    let cands = tuner::ttm_candidates(l);
    anyhow::ensure!(!cands.is_empty(), "no legal TTM launch shape for L={l}");
    println!(
        "ttm-tuning {}x{}x{} nnz={} on {} ({} candidates, L={l})",
        a.dim0, a.dim1, a.dim2, a.nnz(), hw.name, cands.len()
    );
    let out = tuner::tune_ttm_ranked(&machine, &cands, &a, &x1)?;
    print_ranked(&out)?;
    let (_, t) = out.best().context("empty sweep")?;
    match tuner::Selector::default().select_ttm(&a, l) {
        Some(selected) => match out.time_of(&selected) {
            Some(ts) => println!(
                "selector fast path: {} at {:.2} us ({:.2}x of best)",
                selected.name(),
                ts * 1e6,
                ts / t
            ),
            None => println!("selector fast path: {} (outside the sweep grid)", selected.name()),
        },
        None => println!("selector fast path: none (width {l} served on the CPU)"),
    }
    Ok(())
}

/// `sgap bench` — the reproducible benchmark pipeline: run the table-1/2
/// compiler-family grid and the table-4 dgSPARSE grid (SpMM report, which
/// also carries the skew suite's hybrid-vs-single rows) plus
/// the MTTKRP/TTM tensor report through the model-pruned tuner, and emit
/// versioned `BENCH_spmm.json` / `BENCH_tensor.json` (schema: see
/// EXPERIMENTS.md §BENCH; each emitted file is validated against it
/// before being written).
fn cmd_bench(flags: &HashMap<String, String>) -> Result<()> {
    let quick = flags.contains_key("quick");
    let top_k = flag_u32(flags, "k", sgap::tuner::DEFAULT_TOP_K as u32)? as usize;
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let out_dir = std::path::PathBuf::from(
        flags.get("out").cloned().unwrap_or_else(|| ".".to_string()),
    );
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let machine = Machine::new(hw);

    println!(
        "sgap bench: {} suites on {}, top-K {} ({})",
        if quick { "quick" } else { "full" },
        hw.name,
        top_k,
        if top_k == 0 { "exhaustive escape hatch" } else { "model-pruned" },
    );
    let mut table = Table::new(&["report", "rows", "geomean speedup", "rank agree", "prune"]);
    for report in [
        sgap::bench_util::run_spmm_bench(&machine, quick, top_k)?,
        sgap::bench_util::run_tensor_bench(&machine, quick, top_k)?,
    ] {
        let path = out_dir.join(format!("BENCH_{}.json", report.suite));
        report.write(&path)?;
        let (grid, survivors) = report
            .rows
            .iter()
            .fold((0usize, 0usize), |(g, s), r| (g + r.grid, s + r.survivors));
        table.row(&[
            path.display().to_string(),
            report.rows.len().to_string(),
            format!("{:.3}", report.geomean_speedup()),
            format!("{:.0}%", report.rank_agreement() * 100.0),
            format!("{grid} -> {survivors}"),
        ]);
    }
    table.print();
    println!("\nschema v{} validated on both files", sgap::bench_util::BENCH_SCHEMA_VERSION);
    Ok(())
}

/// `sgap profile` — the offline half of the calibration loop: measure the
/// SpMM candidate grid over the bench suite on the warp simulator, fit
/// `CostParams` + `launch_overhead_s` to the measurements
/// (`tuner::calibrate::fit`), report per-matrix Spearman rank fidelity
/// before vs after, and emit the versioned `CALIBRATION.json` artifact
/// `sgap serve --calib` warm-starts from.
fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    let quick = flags.contains_key("quick");
    let hw = hw_by_name(flags.get("hw").map(String::as_str).unwrap_or("3090"))?;
    let out_dir = std::path::PathBuf::from(
        flags.get("out").cloned().unwrap_or_else(|| ".".to_string()),
    );
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let machine = Machine::new(hw);

    println!(
        "sgap profile: {} suite on {} (SpMM grid, N=4)",
        if quick { "quick" } else { "full" },
        hw.name
    );
    let report = sgap::bench_util::run_profile(&machine, quick)?;
    let mut table = Table::new(&["matrix", "samples", "spearman before", "spearman after"]);
    for r in &report.rows {
        table.row(&[
            r.matrix.clone(),
            r.samples.to_string(),
            format!("{:.3}", r.spearman_before),
            format!("{:.3}", r.spearman_after),
        ]);
    }
    table.print();
    let cal = &report.calibration;
    println!(
        "\nfit: {} samples, loss {:.4} -> {:.4}; mean spearman {:.3} -> {:.3}",
        cal.samples,
        cal.loss_before,
        cal.loss_after,
        report.mean_spearman_before(),
        report.mean_spearman_after(),
    );
    let path = out_dir.join("CALIBRATION.json");
    cal.save(&path)?;
    let written = std::fs::read_to_string(&path)?;
    sgap::bench_util::validate_calibration_json(&written)
        .map_err(|e| anyhow::anyhow!("emitted calibration fails its own schema: {e}"))?;
    println!("wrote {} (schema v{}, validated)", path.display(), cal.version);
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let dir = sgap::runtime::Runtime::default_dir();
    let use_artifacts = dir.join("manifest.json").exists()
        && sgap::runtime::Runtime::available()
        && !flags.contains_key("cpu-only");
    // --calib FILE warm-starts the cost model from an `sgap profile`
    // artifact; --calibrate additionally turns on the online drift loop
    let calibration = match flags.get("calib") {
        Some(path) => Some(sgap::tuner::calibrate::Calibration::load(std::path::Path::new(path))?),
        None => None,
    };
    // --plans FILE warm-starts the plan cache from a previous run's
    // catalog and saves the (possibly tuner-upgraded) catalog back on
    // shutdown. A missing file is a cold start; an unreadable or
    // corrupted one is reported and also cold-starts — yesterday's
    // artifact must never be able to take today's serving down.
    let plans_path = flags.get("plans").map(std::path::PathBuf::from);
    let plans = match &plans_path {
        Some(path) if path.exists() => {
            match sgap::coordinator::PlanCatalog::load(path) {
                Ok(catalog) => Some(catalog),
                Err(e) => {
                    eprintln!("warning: ignoring plan catalog {}: {e:#}", path.display());
                    None
                }
            }
        }
        _ => None,
    };
    let cfg = CoordinatorConfig {
        workers: flag_u32(flags, "workers", 2)? as usize,
        queue_cap: flag_u32(flags, "queue-cap", 256)?.max(1) as usize,
        artifacts_dir: if use_artifacts { Some(dir) } else { None },
        background_tune: flags.contains_key("tune"),
        calibration,
        plans,
        calib: sgap::coordinator::CalibConfig {
            enabled: flags.contains_key("calibrate"),
            ..sgap::coordinator::CalibConfig::default()
        },
        // --pool-mb sizes the device-buffer pool (0 disables pooling)
        pool_budget_bytes: (flag_u32(flags, "pool-mb", 64)? as usize) << 20,
        ..CoordinatorConfig::default()
    };
    println!(
        "starting session: {} workers, queue cap {}, {} artifacts, background tune {}, \
         calibration {}, {} warm plans",
        cfg.workers,
        cfg.queue_cap,
        if use_artifacts { "PJRT" } else { "no" },
        if cfg.background_tune { "on" } else { "off" },
        match (&cfg.calibration, cfg.calib.enabled) {
            (Some(_), true) => "warm + online",
            (Some(_), false) => "warm",
            (None, true) => "online",
            (None, false) => "off",
        },
        cfg.plans.as_ref().map_or(0, sgap::coordinator::PlanCatalog::len),
    );
    let session = Session::start(cfg)?;
    let requests = flag_u32(flags, "requests", 32)?;
    let mut rng = SplitMix64::new(123);
    // a handful of repeated shapes (so the plan cache pays off), mixed
    // SpMM / SDDMM traffic — each operand registered once, fingerprinted
    // once, and shared zero-copy across every repeat submit
    let mats: Vec<_> = (0..4u64)
        .map(|seed| {
            session.register_matrix(sgap::sparse::erdos_renyi(256, 256, 2000, seed).to_csr())
        })
        .collect();
    let b = session.register_dense((0..256 * 4).map(|_| rng.value()).collect());
    let j = 16usize;
    let x1 = session.register_dense((0..256 * j).map(|_| rng.value()).collect());
    let x2 = session.register_dense((0..j * 256).map(|_| rng.value()).collect());
    let mut tickets = Vec::new();
    for i in 0..requests {
        let a = &mats[(i % 4) as usize];
        let op = if i % 5 == 4 { Op::sddmm(a, &x1, &x2, j) } else { Op::spmm(a, &b, 4) };
        tickets.push(session.submit(op));
    }
    for t in tickets {
        t.wait()?;
    }
    let coord = session.coordinator();
    let s = coord.metrics.snapshot();
    println!(
        "served {} requests in {} batches: p50 {} us, p99 {} us, mean {:.1} us",
        s.completed, s.batches, s.p50_us, s.p99_us, s.mean_us
    );
    println!(
        "plan cache: {} hits ({} warm) / {} misses; {} fallbacks",
        s.cache_hits, s.warm_hits, s.cache_misses, s.fallbacks
    );
    println!(
        "scale: {} ops coalesced into shared batches, {} submissions rejected (overload)",
        s.coalesced, s.rejected
    );
    for b in &s.backends {
        println!(
            "  {:<24} {:>5} reqs  p50 {:>8} us  p99 {:>8} us  mean {:>10.1} us",
            b.backend, b.count, b.p50_us, b.p99_us, b.mean_us
        );
    }
    let cs = coord.plan_cache.stats();
    println!(
        "plan-cache entries {} (upgrades {}, evictions {}, invalidations {})",
        cs.entries, cs.upgrades, cs.evictions, cs.invalidations
    );
    if let Some(pool) = &coord.pool {
        let ps = pool.stats();
        println!(
            "device pool: {} hits / {} misses, {} uploads skipped, {} evictions, \
             {} KiB resident (budget {} KiB)",
            ps.hits,
            ps.misses,
            s.uploads_skipped,
            ps.evictions,
            ps.bytes_resident / 1024,
            pool.budget_bytes() / 1024
        );
    }
    if coord.calibrator.config().enabled {
        println!(
            "calibration: {} samples, {} refits, worst EWMA residual {:.4} (generation {})",
            s.calib_samples,
            s.calib_refits,
            s.calib_residual,
            coord.calibrator.generation()
        );
    }
    // persist the (possibly tuner-upgraded) plans for the next run's
    // warm start, with the same write→validate discipline as profile
    if let Some(path) = &plans_path {
        let catalog = sgap::coordinator::PlanCatalog::from_cache(&coord.plan_cache);
        catalog.save(path)?;
        let written = std::fs::read_to_string(path)?;
        sgap::bench_util::validate_plan_catalog_json(&written)
            .map_err(|e| anyhow::anyhow!("emitted plan catalog fails its own schema: {e}"))?;
        println!(
            "wrote {} ({} plans, schema v{}, validated)",
            path.display(),
            catalog.len(),
            catalog.version
        );
    }
    session.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "expr" => cmd_expr(),
        "codegen" => cmd_codegen(&flags),
        "space" => cmd_space(),
        "stats" => cmd_stats(),
        // `spmm` is the quartet-consistent name; `tune` the historical one
        "tune" | "spmm" => cmd_tune(&flags),
        "sddmm" => cmd_sddmm(&flags),
        "fused" => cmd_fused(&flags),
        "mttkrp" => cmd_mttkrp(&flags),
        "ttm" => cmd_ttm(&flags),
        "bench" => cmd_bench(&flags),
        "profile" => cmd_profile(&flags),
        "serve" => cmd_serve(&flags),
        "macros" => {
            print!("{}", macro_header());
            Ok(())
        }
        _ => {
            println!("sgap — segment group & atomic parallelism (Sgap reproduction)");
            println!();
            println!("usage: sgap <command> [--flag value ...]");
            println!("  expr     (print the §2.1 quartet + the fused SDDMM→SpMM pair: algebra,");
            println!("            reduction dims, legal families, and the typed illegal-fusion error)");
            println!("  codegen  --family nnz-group|row-group|nnz-serial|row-serial|sddmm|dgsparse|mttkrp|ttm|fused --n 4 --c 4 --g 32 --r 32 [--dialect cuda|hip|wgsl]");
            println!("           (sddmm/mttkrp/ttm: --n is the dense width; fused: --j is the dot length; dgsparse: --g=workerSz --r=groupSz --c=coarsenSz;");
            println!("            --dialect respells the same LLIR walk for CUDA, HIP, or WGSL)");
            println!("  space    (print the Fig. 7/8 legality map)");
            println!("  stats    (print the evaluation-suite statistics)");
            println!("  spmm     --dataset er_1024_d5e-3 --n 4 --hw 3090|2080|v100 (alias: tune)");
            println!("  sddmm    --dataset er_1024_d5e-3 --j 16 --hw 3090|2080|v100");
            println!("  fused    --dataset er_1024_d5e-3 --j 16 --n 4 --hw 3090|2080|v100");
            println!("           (fused SDDMM→SpMM sweep vs the tuned two-stage pipeline)");
            println!("  mttkrp   --d0 128 --d1 96 --d2 64 --nnz 4000 --j 16 --hw 3090|2080|v100");
            println!("  ttm      --d0 128 --d1 96 --d2 64 --nnz 4000 --l 16 --hw 3090|2080|v100");
            println!("  bench    [--quick] [--out DIR] [--k 8] [--hw 3090|2080|v100]");
            println!("           (emits BENCH_spmm.json + BENCH_tensor.json incl. the skew");
            println!("            hybrid-vs-single rows; --k 0 = exhaustive)");
            println!("  profile  [--quick] [--out DIR] [--hw 3090|2080|v100]");
            println!("           (measure -> fit CostParams -> CALIBRATION.json; the offline");
            println!("            half of the calibration loop, see DESIGN.md §calibration)");
            println!("  serve    --requests 32 --workers 2 [--queue-cap 256] [--tune] [--cpu-only]");
            println!("           [--calib FILE] [--calibrate] [--plans FILE] [--pool-mb 64]");
            println!("           (--calib warm-starts from an `sgap profile` artifact; --calibrate");
            println!("            turns on online drift-triggered refits; --plans warm-starts the");
            println!("            plan cache from PLANS.json and saves it back on shutdown;");
            println!("            --queue-cap bounds the admission queue; --pool-mb budgets the");
            println!("            device-buffer pool (0 disables); SGAP_ARTIFACTS overrides artifacts dir)");
            println!("  macros   (print the §5.3 macro-instruction header)");
            Ok(())
        }
    }
}
