//! CSR (compressed sparse row) — the compute format for every kernel.

use super::coo::Coo;
use super::ell::Ell;

/// CSR sparse matrix. Invariants: `indptr` is monotone, starts at 0 and
/// ends at `nnz`; `indices` within each row are strictly increasing.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    pub fn row_degree(&self, i: usize) -> usize {
        (self.indptr[i + 1] - self.indptr[i]) as usize
    }

    pub fn max_row_degree(&self) -> usize {
        (0..self.rows).map(|i| self.row_degree(i)).max().unwrap_or(0)
    }

    /// Validate all structural invariants (used by proptest round-trips).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err(format!("indptr len {} != rows+1 {}", self.indptr.len(), self.rows + 1));
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() as usize != self.nnz() {
            return Err("indptr endpoints wrong".into());
        }
        for i in 0..self.rows {
            if self.indptr[i] > self.indptr[i + 1] {
                return Err(format!("indptr not monotone at {i}"));
            }
            let (lo, hi) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
            for k in lo..hi {
                if self.indices[k] as usize >= self.cols {
                    return Err(format!("col index {} out of range", self.indices[k]));
                }
                if k > lo && self.indices[k] <= self.indices[k - 1] {
                    return Err(format!("row {i} columns not strictly increasing"));
                }
            }
        }
        Ok(())
    }

    pub fn to_coo(&self) -> Coo {
        let mut row_idx = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            for _ in self.indptr[i]..self.indptr[i + 1] {
                row_idx.push(i as u32);
            }
        }
        Coo {
            rows: self.rows,
            cols: self.cols,
            row_idx,
            col_idx: self.indices.clone(),
            vals: self.data.clone(),
        }
    }

    /// Convert to ELL with `slots >= max_row_degree`, padding with
    /// `(col=0, val=0)` — zero extension at the data level.
    pub fn to_ell(&self, slots: usize) -> Ell {
        assert!(slots >= self.max_row_degree(), "slots < max row degree");
        let mut cols = vec![0u32; self.rows * slots];
        let mut vals = vec![0f32; self.rows * slots];
        for i in 0..self.rows {
            let (lo, hi) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
            for (s, k) in (lo..hi).enumerate() {
                cols[i * slots + s] = self.indices[k];
                vals[i * slots + s] = self.data[k];
            }
        }
        Ell { rows: self.rows, cols_dim: self.cols, slots, cols, vals }
    }

    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.cols]; self.rows];
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                d[i][self.indices[k] as usize] += self.data[k];
            }
        }
        d
    }

    /// `blockStarts` for nnz-split algorithms: for each block of `nnz_per_block`
    /// non-zeros, the row containing its first nnz — the binary-search
    /// precomputation TACO emits for `pos` splits (Listing 1).
    pub fn block_starts(&self, nnz_per_block: usize) -> Vec<u32> {
        assert!(nnz_per_block > 0);
        let nblocks = self.nnz().div_ceil(nnz_per_block);
        let mut starts = Vec::with_capacity(nblocks + 1);
        for b in 0..=nblocks {
            let fpos = (b * nnz_per_block).min(self.nnz()) as u32;
            // binary search: last i with indptr[i] <= fpos
            let mut lo = 0usize;
            let mut hi = self.rows;
            while lo < hi {
                let mid = (lo + hi + 1) / 2;
                if self.indptr[mid] <= fpos {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            starts.push(lo as u32);
        }
        starts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Coo::new(
            4,
            5,
            vec![(0, 1, 1.0), (0, 3, 2.0), (1, 0, 3.0), (3, 2, 4.0), (3, 4, 5.0), (3, 0, 6.0)],
        )
        .to_csr()
    }

    #[test]
    fn invariants_hold() {
        sample().check_invariants().unwrap();
    }

    #[test]
    fn coo_round_trip() {
        let csr = sample();
        assert_eq!(csr.to_coo().to_csr(), csr);
    }

    #[test]
    fn ell_round_trip_dense() {
        let csr = sample();
        let ell = csr.to_ell(4);
        assert_eq!(ell.to_dense(), csr.to_dense());
    }

    #[test]
    fn degrees() {
        let csr = sample();
        assert_eq!(csr.row_degree(0), 2);
        assert_eq!(csr.row_degree(2), 0);
        assert_eq!(csr.max_row_degree(), 3);
    }

    #[test]
    fn block_starts_match_linear_scan() {
        let csr = sample(); // indptr = [0,2,3,3,6]
        // entries are the row containing each block's first nnz; the final
        // entry (fpos == nnz) is the search-window terminator, == rows.
        assert_eq!(csr.block_starts(2), vec![0, 1, 3, 4]);
        assert_eq!(csr.block_starts(4), vec![0, 3, 4]);
    }

    #[test]
    fn block_starts_single_block() {
        let csr = sample();
        let s = csr.block_starts(100);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], 0);
    }
}
