//! Sparse-matrix substrate: formats, IO, generators, datasets, statistics.
//!
//! Everything downstream (compiler-generated kernels, the simulator, the
//! dgSPARSE re-implementation, the PJRT marshaller) consumes these types.
//! All generators are seeded and deterministic so every experiment in
//! `EXPERIMENTS.md` is reproducible bit-for-bit.

pub mod coo;
pub mod coo3;
pub mod csr;
pub mod dataset;
pub mod ell;
pub mod gen;
pub mod mtx;
pub mod partition;
pub mod rng;
pub mod stats;

pub use coo::Coo;
pub use coo3::Coo3;
pub use csr::Csr;
pub use dataset::{suite, DatasetSpec};
pub use ell::Ell;
pub use gen::{banded, block_community, erdos_renyi, power_law};
pub use partition::{
    band_csr, band_of, band_stats, choose_cuts, partition_rows, BandPartition, CUT_SENTINEL,
};
pub use rng::SplitMix64;
pub use stats::{MatrixStats, SegStats};
