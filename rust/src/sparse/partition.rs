//! nnz-balanced band partitioning for skewed matrices.
//!
//! Power-law inputs defeat any *single* schedule: short rows want a
//! row-parallel kernel, hub rows want an nnz-split one (§3's adaptive
//! group-size argument, and Chougule et al.'s load-balanced partitioning
//! in PAPERS.md). This module classifies rows into up to [`MAX_BANDS`]
//! bands — short-row, mid, hub — by log2 row-degree bucket, choosing the
//! cut buckets so each band carries roughly `nnz / bands` non-zeros.
//!
//! Key properties:
//! * **No data copy at plan time.** A [`BandPartition`] is a permutation
//!   plus band boundaries over the original CSR; sub-CSR gathering
//!   ([`band_csr`]) happens only when a composite plan actually runs.
//! * **Matrix-independent cuts.** Cuts are log2-bucket indices, so a
//!   composite plan cached under a [`ShapeKey`](crate::coordinator) stays
//!   valid for any matrix that collides into the key: re-deriving the
//!   bands from the cuts on the colliding matrix is always legal, and a
//!   collision can only cost performance, never accuracy.
//! * **Balance bound by construction.** [`choose_cuts`] guarantees every
//!   band's nnz is at most `total/bands + max_bucket_nnz` (the granularity
//!   limit of cutting on bucket boundaries); it degrades 3 → 2 bands when
//!   the 3-way cut cannot meet the bound, and returns `None` when fewer
//!   than two degree buckets are occupied (nothing to split).

use super::csr::Csr;
use super::stats::{degree_bucket, MatrixStats, DEGREE_BUCKETS};

/// Maximum number of bands: short-row, mid, hub.
pub const MAX_BANDS: usize = 3;

/// Sentinel for an unused cut slot (no bucket reaches it).
pub const CUT_SENTINEL: u8 = DEGREE_BUCKETS as u8;

/// Band of a row with the given degree under `cuts`. Empty rows belong to
/// band 0 (they cost a thread slot exactly like a short row).
#[inline]
pub fn band_of(degree: usize, cuts: [u8; 2]) -> usize {
    if degree == 0 {
        return 0;
    }
    let b = degree_bucket(degree) as u8;
    (b >= cuts[0]) as usize + (b >= cuts[1]) as usize
}

/// Choose nnz-balancing cut buckets from a matrix's degree histogram.
///
/// Returns `(bands, cuts)` with `2 <= bands <= MAX_BANDS`; unused cut
/// slots hold [`CUT_SENTINEL`]. Returns `None` when the histogram has
/// fewer than two occupied buckets — all rows look alike, banding cannot
/// help. The result satisfies the balance bound
/// `band_nnz[b] <= total/bands + max(hist_nnz)` for every band.
pub fn choose_cuts(stats: &MatrixStats) -> Option<(usize, [u8; 2])> {
    let total: u64 = stats.hist_nnz.iter().sum();
    if total == 0 {
        return None;
    }
    let occupied: Vec<usize> =
        (0..DEGREE_BUCKETS).filter(|&b| stats.hist_rows[b] > 0).collect();
    if occupied.len() < 2 {
        return None;
    }
    let (lowest, top) = (occupied[0], *occupied.last().unwrap());
    let max_bucket = *stats.hist_nnz.iter().max().unwrap();
    // prefix[c] = nnz in buckets < c
    let mut prefix = [0u64; DEGREE_BUCKETS + 1];
    for b in 0..DEGREE_BUCKETS {
        prefix[b + 1] = prefix[b] + stats.hist_nnz[b];
    }
    // smallest cut c with prefix[c] * bands >= k * total, clamped so both
    // sides of the cut keep at least one occupied bucket
    let cut_at = |k: u64, bands: u64| -> u8 {
        let c = (1..=DEGREE_BUCKETS)
            .find(|&c| prefix[c] * bands >= k * total)
            .unwrap_or(DEGREE_BUCKETS);
        c.clamp(lowest + 1, top) as u8
    };
    let band_nnz_of = |lo: u8, hi: u8| -> u64 { prefix[hi as usize] - prefix[lo as usize] };

    if occupied.len() >= MAX_BANDS {
        let c1 = cut_at(1, 3);
        let c2 = cut_at(2, 3);
        if c1 < c2 {
            let cuts = [c1, c2];
            let widths = [(0u8, c1), (c1, c2), (c2, DEGREE_BUCKETS as u8)];
            let bound = total / 3 + max_bucket;
            let balanced = widths.iter().all(|&(lo, hi)| band_nnz_of(lo, hi) <= bound);
            let populated = widths.iter().all(|&(lo, hi)| {
                (lo as usize..hi as usize).any(|b| stats.hist_rows[b] > 0)
            });
            if balanced && populated {
                return Some((3, cuts));
            }
        }
    }
    // 2-band fallback always meets the bound: the cut is the smallest
    // bucket boundary at or past the nnz midpoint, so the low band holds
    // < total/2 + max_bucket and the high band <= total/2 (or, when one
    // bucket dominates, exactly that bucket).
    Some((2, [cut_at(1, 2), CUT_SENTINEL]))
}

/// A band partition: rows grouped by band, original indices preserved.
#[derive(Debug, Clone, PartialEq)]
pub struct BandPartition {
    pub bands: usize,
    pub cuts: [u8; 2],
    /// Row indices grouped by band, ascending within each band.
    pub perm: Vec<u32>,
    /// `perm[starts[b]..starts[b+1]]` is band `b`; trailing entries of an
    /// unused band repeat `rows`.
    pub starts: [usize; MAX_BANDS + 1],
    /// Non-zeros per band.
    pub band_nnz: [usize; MAX_BANDS],
}

impl BandPartition {
    /// The original row indices of band `b` (ascending).
    pub fn rows_of(&self, band: usize) -> &[u32] {
        &self.perm[self.starts[band]..self.starts[band + 1]]
    }
}

/// Partition a CSR's rows into bands under `cuts`. Stable: within a band,
/// rows keep ascending original order, so a serial sweep over the bands
/// visits each row exactly once and band outputs scatter back disjointly.
pub fn partition_rows(a: &Csr, bands: usize, cuts: [u8; 2]) -> BandPartition {
    debug_assert!((2..=MAX_BANDS).contains(&bands));
    let mut counts = [0usize; MAX_BANDS];
    let mut band_nnz = [0usize; MAX_BANDS];
    for i in 0..a.rows {
        let d = a.row_degree(i);
        let b = band_of(d, cuts).min(bands - 1);
        counts[b] += 1;
        band_nnz[b] += d;
    }
    let mut starts = [0usize; MAX_BANDS + 1];
    for b in 0..MAX_BANDS {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut cursor = [starts[0], starts[1], starts[2]];
    let mut perm = vec![0u32; a.rows];
    for i in 0..a.rows {
        let b = band_of(a.row_degree(i), cuts).min(bands - 1);
        perm[cursor[b]] = i as u32;
        cursor[b] += 1;
    }
    BandPartition { bands, cuts, perm, starts, band_nnz }
}

/// Gather the sub-CSR of the given rows (renumbered `0..rows.len()`,
/// same column space). Used by the composite runner right before kernel
/// launch; plans themselves never hold copied data.
pub fn band_csr(a: &Csr, rows: &[u32]) -> Csr {
    let mut indptr = Vec::with_capacity(rows.len() + 1);
    indptr.push(0u32);
    let mut indices = Vec::new();
    let mut data = Vec::new();
    for &r in rows {
        let (lo, hi) = (a.indptr[r as usize] as usize, a.indptr[r as usize + 1] as usize);
        indices.extend_from_slice(&a.indices[lo..hi]);
        data.extend_from_slice(&a.data[lo..hi]);
        indptr.push(indices.len() as u32);
    }
    Csr { rows: rows.len(), cols: a.cols, indptr, indices, data }
}

/// Synthetic per-band [`MatrixStats`], derived from the histogram alone —
/// no matrix walk, so the cost model can price a composite plan from the
/// same `MatrixStats` the selector already holds (and the Python
/// transliteration can reproduce it). Bucket `b`'s rows are represented
/// by degree `1.5 * 2^b` (the bucket midpoint) for the variance estimate;
/// means and nnz are exact. Empty rows are charged to band 0.
pub fn band_stats(stats: &MatrixStats, bands: usize, cuts: [u8; 2]) -> Vec<MatrixStats> {
    let empty_rows = (stats.empty_row_frac * stats.rows as f64).round() as usize;
    let mut out = Vec::with_capacity(bands);
    for band in 0..bands {
        let lo = if band == 0 { 0 } else { cuts[band - 1] as usize };
        let hi = if band + 1 < bands { cuts[band] as usize } else { DEGREE_BUCKETS };
        let mut hist_rows = [0u32; DEGREE_BUCKETS];
        let mut hist_nnz = [0u64; DEGREE_BUCKETS];
        let mut rows_b = 0usize;
        let mut nnz_b = 0u64;
        let mut hi_occ = None;
        for b in lo..hi {
            hist_rows[b] = stats.hist_rows[b];
            hist_nnz[b] = stats.hist_nnz[b];
            rows_b += stats.hist_rows[b] as usize;
            nnz_b += stats.hist_nnz[b];
            if stats.hist_rows[b] > 0 {
                hi_occ = Some(b);
            }
        }
        let empties = if band == 0 { empty_rows } else { 0 };
        let rows_total = (rows_b + empties).max(1);
        let mean = nnz_b as f64 / rows_total as f64;
        let mut var = (empties as f64) * mean * mean; // degree-0 rows
        for b in lo..hi {
            let rep = 1.5 * (1u64 << b) as f64;
            var += stats.hist_rows[b] as f64 * (rep - mean) * (rep - mean);
        }
        var /= rows_total as f64;
        let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
        let max_deg = match hi_occ {
            Some(b) => ((1u64 << (b + 1)) - 1).min(stats.row_degree_max as u64) as usize,
            None => 0,
        };
        out.push(MatrixStats {
            rows: rows_total,
            cols: stats.cols,
            nnz: nnz_b as usize,
            density: if stats.cols == 0 {
                0.0
            } else {
                nnz_b as f64 / (rows_total as f64 * stats.cols as f64)
            },
            row_degree_mean: mean,
            row_degree_cv: cv,
            row_degree_max: max_deg,
            gini: 0.0,
            empty_row_frac: empties as f64 / rows_total as f64,
            hist_rows,
            hist_nnz,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{erdos_renyi, power_law};

    #[test]
    fn every_row_in_exactly_one_band() {
        let a = power_law(512, 512, 8192, 1.8, 21).to_csr();
        let stats = MatrixStats::of(&a);
        let (bands, cuts) = choose_cuts(&stats).expect("power-law must band");
        let p = partition_rows(&a, bands, cuts);
        let mut seen = vec![false; a.rows];
        for b in 0..bands {
            for &r in p.rows_of(b) {
                assert!(!seen[r as usize], "row {r} in two bands");
                seen[r as usize] = true;
                assert_eq!(band_of(a.row_degree(r as usize), cuts).min(bands - 1), b);
            }
        }
        assert!(seen.iter().all(|&s| s), "some row missing from all bands");
        assert_eq!(p.band_nnz.iter().sum::<usize>(), a.nnz());
    }

    #[test]
    fn band_nnz_within_balance_bound() {
        for (alpha, seed) in [(1.6, 5u64), (2.0, 9), (1.2, 13)] {
            let a = power_law(1024, 1024, 16384, alpha, seed).to_csr();
            let stats = MatrixStats::of(&a);
            let (bands, cuts) = choose_cuts(&stats).unwrap();
            let p = partition_rows(&a, bands, cuts);
            let total = a.nnz() as u64;
            let max_bucket = *stats.hist_nnz.iter().max().unwrap();
            let bound = total / bands as u64 + max_bucket;
            for b in 0..bands {
                assert!(
                    p.band_nnz[b] as u64 <= bound,
                    "alpha {alpha}: band {b} nnz {} > bound {bound}",
                    p.band_nnz[b]
                );
            }
        }
    }

    #[test]
    fn uniform_degrees_decline_to_band() {
        // every row degree 4 → a single occupied bucket → None
        let coo = crate::sparse::coo::Coo::new(
            16,
            16,
            (0..16u32).flat_map(|r| (0..4u32).map(move |c| (r, c, 1.0f32))).collect(),
        );
        let s = MatrixStats::of(&coo.to_csr());
        assert!(choose_cuts(&s).is_none());
    }

    #[test]
    fn er_still_bands_when_buckets_spread() {
        // choose_cuts is mechanical; the *selector's* CV gate is what
        // keeps ER on the single-plan path. Here we only require that a
        // returned partition is well-formed.
        let a = erdos_renyi(256, 256, 1300, 17).to_csr();
        let stats = MatrixStats::of(&a);
        if let Some((bands, cuts)) = choose_cuts(&stats) {
            let p = partition_rows(&a, bands, cuts);
            assert_eq!(p.perm.len(), a.rows);
            assert_eq!(p.starts[bands], a.rows);
        }
    }

    #[test]
    fn band_csr_preserves_rows_and_invariants() {
        let a = power_law(128, 96, 1500, 1.7, 4).to_csr();
        let stats = MatrixStats::of(&a);
        let (bands, cuts) = choose_cuts(&stats).unwrap();
        let p = partition_rows(&a, bands, cuts);
        let mut total = 0;
        for b in 0..bands {
            let rows = p.rows_of(b);
            let sub = band_csr(&a, rows);
            sub.check_invariants().unwrap();
            assert_eq!(sub.nnz(), p.band_nnz[b]);
            total += sub.nnz();
            for (local, &orig) in rows.iter().enumerate() {
                let (lo, hi) =
                    (a.indptr[orig as usize] as usize, a.indptr[orig as usize + 1] as usize);
                let (slo, shi) = (sub.indptr[local] as usize, sub.indptr[local + 1] as usize);
                assert_eq!(&a.indices[lo..hi], &sub.indices[slo..shi]);
                assert_eq!(&a.data[lo..hi], &sub.data[slo..shi]);
            }
        }
        assert_eq!(total, a.nnz());
    }

    #[test]
    fn band_stats_conserve_rows_and_nnz() {
        let a = power_law(512, 512, 6000, 1.9, 8).to_csr();
        let stats = MatrixStats::of(&a);
        let (bands, cuts) = choose_cuts(&stats).unwrap();
        let per = band_stats(&stats, bands, cuts);
        assert_eq!(per.len(), bands);
        let rows: usize = per.iter().map(|s| s.rows).sum();
        let nnz: usize = per.iter().map(|s| s.nnz).sum();
        assert_eq!(rows, stats.rows);
        assert_eq!(nnz, stats.nnz);
        // hub band has larger mean degree than short band
        assert!(per[bands - 1].row_degree_mean > per[0].row_degree_mean);
        // per-band maxima never exceed the global max
        for s in &per {
            assert!(s.row_degree_max <= stats.row_degree_max);
        }
    }

    #[test]
    fn empty_rows_land_in_band_zero() {
        assert_eq!(band_of(0, [3, 7]), 0);
        assert_eq!(band_of(1, [1, 7]), 1);
        assert_eq!(band_of(200, [3, 7]), 2);
    }
}
