//! COO (coordinate) format — the interchange and generation format.

use super::csr::Csr;

/// Coordinate-format sparse matrix, entries sorted by `(row, col)`,
/// coordinates unique. The invariants are enforced by [`Coo::new`].
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    /// Build from unsorted, possibly-duplicated triplets; duplicates are
    /// summed (the MatrixMarket convention).
    pub fn new(rows: usize, cols: usize, mut triplets: Vec<(u32, u32, f32)>) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut row_idx = Vec::with_capacity(triplets.len());
        let mut col_idx = Vec::with_capacity(triplets.len());
        let mut vals: Vec<f32> = Vec::with_capacity(triplets.len());
        for (r, c, v) in triplets {
            assert!((r as usize) < rows && (c as usize) < cols, "coordinate out of range");
            if let (Some(&lr), Some(&lc)) = (row_idx.last(), col_idx.last()) {
                if lr == r && lc == c {
                    *vals.last_mut().unwrap() += v;
                    continue;
                }
            }
            row_idx.push(r);
            col_idx.push(c);
            vals.push(v);
        }
        Self { rows, cols, row_idx, col_idx, vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Convert to CSR (the compute format).
    pub fn to_csr(&self) -> Csr {
        let mut indptr = vec![0u32; self.rows + 1];
        for &r in &self.row_idx {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices: self.col_idx.clone(),
            data: self.vals.clone(),
        }
    }

    /// Dense materialization (tests only — O(rows·cols)).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.cols]; self.rows];
        for k in 0..self.nnz() {
            d[self.row_idx[k] as usize][self.col_idx[k] as usize] += self.vals[k];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_sums_duplicates() {
        let m = Coo::new(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.vals, vec![3.0, 3.0]);
    }

    #[test]
    fn sorts_by_row_then_col() {
        let m = Coo::new(3, 3, vec![(2, 1, 1.0), (0, 2, 1.0), (2, 0, 1.0)]);
        assert_eq!(m.row_idx, vec![0, 2, 2]);
        assert_eq!(m.col_idx, vec![2, 0, 1]);
    }

    #[test]
    fn csr_round_trip_dense() {
        let m = Coo::new(3, 4, vec![(0, 1, 2.0), (1, 0, -1.0), (2, 3, 5.0), (2, 0, 4.0)]);
        let csr = m.to_csr();
        assert_eq!(csr.indptr, vec![0, 1, 2, 4]);
        assert_eq!(m.to_dense(), csr.to_dense());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        Coo::new(2, 2, vec![(2, 0, 1.0)]);
    }

    #[test]
    fn density_empty() {
        let m = Coo::new(10, 10, vec![]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.density(), 0.0);
    }
}
