//! Third-order sparse tensors in coordinate format — the substrate for
//! MTTKRP and TTM (Eq. 2a/2b).

use super::rng::SplitMix64;

/// Order-3 COO tensor, entries sorted by `(i, j, k)`, coordinates unique.
#[derive(Debug, Clone, PartialEq)]
pub struct Coo3 {
    pub dim0: usize,
    pub dim1: usize,
    pub dim2: usize,
    pub idx0: Vec<u32>,
    pub idx1: Vec<u32>,
    pub idx2: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo3 {
    pub fn new(
        dims: (usize, usize, usize),
        mut entries: Vec<(u32, u32, u32, f32)>,
    ) -> Self {
        entries.sort_unstable_by_key(|&(a, b, c, _)| (a, b, c));
        let (dim0, dim1, dim2) = dims;
        let mut t = Coo3 {
            dim0,
            dim1,
            dim2,
            idx0: Vec::with_capacity(entries.len()),
            idx1: Vec::with_capacity(entries.len()),
            idx2: Vec::with_capacity(entries.len()),
            vals: Vec::with_capacity(entries.len()),
        };
        for (a, b, c, v) in entries {
            assert!(
                (a as usize) < dim0 && (b as usize) < dim1 && (c as usize) < dim2,
                "coordinate out of range"
            );
            if let (Some(&la), Some(&lb), Some(&lc)) = (t.idx0.last(), t.idx1.last(), t.idx2.last())
            {
                if (la, lb, lc) == (a, b, c) {
                    *t.vals.last_mut().unwrap() += v;
                    continue;
                }
            }
            t.idx0.push(a);
            t.idx1.push(b);
            t.idx2.push(c);
            t.vals.push(v);
        }
        t
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Uniform random order-3 tensor with exactly `nnz` entries.
    pub fn random(dims: (usize, usize, usize), nnz: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let cap = dims.0 * dims.1 * dims.2;
        let nnz = nnz.min(cap);
        let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
        let mut entries = Vec::with_capacity(nnz);
        while entries.len() < nnz {
            let a = rng.below(dims.0 as u64) as u32;
            let b = rng.below(dims.1 as u64) as u32;
            let c = rng.below(dims.2 as u64) as u32;
            if seen.insert((a, b, c)) {
                entries.push((a, b, c, rng.value()));
            }
        }
        Coo3::new(dims, entries)
    }

    /// Fiber ids over the leading two modes: `fiber[p] = i*dim1 + j` —
    /// the segment key for reductions over the trailing mode. Computed in
    /// `u64` so tensors with `dim0 * dim1 > u32::MAX` get the same key as
    /// [`SegStats::ttm`](super::SegStats::ttm) instead of a wrapped one.
    pub fn leading_fiber_ids(&self) -> Vec<u64> {
        (0..self.nnz())
            .map(|p| self.idx0[p] as u64 * self.dim1 as u64 + self.idx1[p] as u64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_and_deduped() {
        let t = Coo3::new(
            (2, 2, 2),
            vec![(1, 1, 1, 1.0), (0, 0, 0, 2.0), (0, 0, 0, 3.0), (0, 1, 0, 1.0)],
        );
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.vals[0], 5.0); // deduped (0,0,0)
        assert_eq!(t.idx0, vec![0, 0, 1]);
    }

    #[test]
    fn random_has_exact_nnz_and_valid_coords() {
        let t = Coo3::random((8, 9, 10), 200, 7);
        assert_eq!(t.nnz(), 200);
        for p in 0..t.nnz() {
            assert!((t.idx0[p] as usize) < 8);
            assert!((t.idx1[p] as usize) < 9);
            assert!((t.idx2[p] as usize) < 10);
        }
        // deterministic
        assert_eq!(t, Coo3::random((8, 9, 10), 200, 7));
    }

    #[test]
    fn fiber_ids_monotone_for_sorted_tensor() {
        let t = Coo3::random((6, 5, 4), 60, 3);
        let f = t.leading_fiber_ids();
        for w in f.windows(2) {
            assert!(w[0] <= w[1], "fiber ids must be sorted for segment reduction");
        }
    }

    #[test]
    fn fiber_ids_do_not_wrap_past_u32() {
        // dim0 * dim1 > u32::MAX: the u32 arithmetic this replaced wrapped
        // here, disagreeing with SegStats::ttm's u64 key on the same entry
        let dim0 = 1usize << 20;
        let dim1 = 1usize << 13; // dim0 * dim1 = 2^33 > u32::MAX
        let t = Coo3::new(
            (dim0, dim1, 4),
            vec![
                (0, 0, 0, 1.0),
                ((dim0 - 1) as u32, 0, 1, 2.0),
                ((dim0 - 1) as u32, (dim1 - 1) as u32, 2, 3.0),
            ],
        );
        let f = t.leading_fiber_ids();
        assert_eq!(f[0], 0);
        assert_eq!(f[1], (dim0 as u64 - 1) * dim1 as u64);
        assert_eq!(f[2], dim0 as u64 * dim1 as u64 - 1);
        assert!(f[2] > u32::MAX as u64, "the boundary case must exceed u32");
        for w in f.windows(2) {
            assert!(w[0] < w[1], "distinct fibers must stay ordered");
        }
    }
}
