//! Matrix statistics — the *input dynamics* features the DA-SpMM-style
//! selector keys on (density, mean/CV of row degree, Gini imbalance).

use super::csr::Csr;

/// Summary statistics of a sparse matrix's structure.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub density: f64,
    pub row_degree_mean: f64,
    /// Coefficient of variation of row degrees: std/mean. ~0 for ER/banded,
    /// >1 for power-law — the skew axis of the selector.
    pub row_degree_cv: f64,
    pub row_degree_max: usize,
    /// Gini coefficient of row degrees in [0,1): 0 = perfectly balanced.
    pub gini: f64,
    /// Fraction of empty rows (they still cost a thread in row-balanced kernels).
    pub empty_row_frac: f64,
}

impl MatrixStats {
    pub fn of(m: &Csr) -> Self {
        let degrees: Vec<usize> = (0..m.rows).map(|i| m.row_degree(i)).collect();
        let n = degrees.len().max(1) as f64;
        let mean = degrees.iter().sum::<usize>() as f64 / n;
        let var = degrees.iter().map(|&d| (d as f64 - mean).powi(2)).sum::<f64>() / n;
        let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };

        let mut sorted = degrees.clone();
        sorted.sort_unstable();
        let total: f64 = sorted.iter().sum::<usize>() as f64;
        let gini = if total > 0.0 {
            let weighted: f64 =
                sorted.iter().enumerate().map(|(i, &d)| (2.0 * (i as f64 + 1.0) - n - 1.0) * d as f64).sum();
            weighted / (n * total)
        } else {
            0.0
        };

        MatrixStats {
            rows: m.rows,
            cols: m.cols,
            nnz: m.nnz(),
            density: m.density(),
            row_degree_mean: mean,
            row_degree_cv: cv,
            row_degree_max: degrees.iter().copied().max().unwrap_or(0),
            gini,
            empty_row_frac: degrees.iter().filter(|&&d| d == 0).count() as f64 / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn uniform_degrees_have_zero_cv_and_gini() {
        let coo = Coo::new(
            4,
            4,
            (0..4).flat_map(|r| [(r as u32, 0u32, 1.0f32), (r as u32, 1, 1.0)]).collect(),
        );
        let s = MatrixStats::of(&coo.to_csr());
        assert_eq!(s.row_degree_mean, 2.0);
        assert!(s.row_degree_cv.abs() < 1e-12);
        assert!(s.gini.abs() < 1e-12);
        assert_eq!(s.empty_row_frac, 0.0);
    }

    #[test]
    fn single_hub_row_is_maximally_skewed() {
        let coo = Coo::new(4, 8, (0..8).map(|c| (0u32, c as u32, 1.0f32)).collect());
        let s = MatrixStats::of(&coo.to_csr());
        assert_eq!(s.row_degree_max, 8);
        assert_eq!(s.empty_row_frac, 0.75);
        assert!(s.gini > 0.7, "gini {} should be high", s.gini);
        assert!(s.row_degree_cv > 1.5);
    }

    #[test]
    fn density_matches() {
        let coo = Coo::new(10, 10, vec![(0, 0, 1.0), (5, 5, 1.0)]);
        let s = MatrixStats::of(&coo.to_csr());
        assert!((s.density - 0.02).abs() < 1e-12);
    }
}
