//! Matrix statistics — the *input dynamics* features the DA-SpMM-style
//! selector keys on (density, mean/CV of row degree, Gini imbalance) —
//! plus [`SegStats`], the segment-length summary the COO-3 kernels and
//! the analytic cost model (`tuner::model`) key on.

use super::coo3::Coo3;
use super::csr::Csr;

/// Number of log2 row-degree histogram buckets in [`MatrixStats`]:
/// bucket `b` counts rows with `floor(log2(degree)) == b` (degree >= 1),
/// saturating at the last bucket. 16 buckets cover degrees up to 2^16-1 —
/// beyond any row the simulator-scale suite produces.
pub const DEGREE_BUCKETS: usize = 16;

/// Log2 bucket of a (non-zero) row degree.
#[inline]
pub fn degree_bucket(degree: usize) -> usize {
    debug_assert!(degree > 0);
    ((usize::BITS - 1 - degree.leading_zeros()) as usize).min(DEGREE_BUCKETS - 1)
}

/// Summary statistics of a sparse matrix's structure.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub density: f64,
    pub row_degree_mean: f64,
    /// Coefficient of variation of row degrees: std/mean. ~0 for ER/banded,
    /// >1 for power-law — the skew axis of the selector.
    pub row_degree_cv: f64,
    pub row_degree_max: usize,
    /// Gini coefficient of row degrees in [0,1): 0 = perfectly balanced.
    pub gini: f64,
    /// Fraction of empty rows (they still cost a thread in row-balanced kernels).
    pub empty_row_frac: f64,
    /// Rows per log2 degree bucket ([`degree_bucket`]); empty rows are
    /// *not* histogrammed (they carry no nnz — the band partitioner
    /// assigns them to the short-row band separately).
    pub hist_rows: [u32; DEGREE_BUCKETS],
    /// Non-zeros per log2 degree bucket — the mass the nnz-balanced
    /// splitter (`sparse::partition`) cuts into bands.
    pub hist_nnz: [u64; DEGREE_BUCKETS],
}

impl MatrixStats {
    pub fn of(m: &Csr) -> Self {
        let degrees: Vec<usize> = (0..m.rows).map(|i| m.row_degree(i)).collect();
        let mut hist_rows = [0u32; DEGREE_BUCKETS];
        let mut hist_nnz = [0u64; DEGREE_BUCKETS];
        for &d in &degrees {
            if d > 0 {
                let b = degree_bucket(d);
                hist_rows[b] += 1;
                hist_nnz[b] += d as u64;
            }
        }
        let n = degrees.len().max(1) as f64;
        let mean = degrees.iter().sum::<usize>() as f64 / n;
        let var = degrees.iter().map(|&d| (d as f64 - mean).powi(2)).sum::<f64>() / n;
        let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };

        let mut sorted = degrees.clone();
        sorted.sort_unstable();
        let total: f64 = sorted.iter().sum::<usize>() as f64;
        let gini = if total > 0.0 {
            let weighted: f64 =
                sorted.iter().enumerate().map(|(i, &d)| (2.0 * (i as f64 + 1.0) - n - 1.0) * d as f64).sum();
            weighted / (n * total)
        } else {
            0.0
        };

        MatrixStats {
            rows: m.rows,
            cols: m.cols,
            nnz: m.nnz(),
            density: m.density(),
            row_degree_mean: mean,
            row_degree_cv: cv,
            row_degree_max: degrees.iter().copied().max().unwrap_or(0),
            gini,
            empty_row_frac: degrees.iter().filter(|&&d| d == 0).count() as f64 / n,
        }
    }
}

/// Summary statistics of a *segmented* reduction input: the distribution
/// of output-segment lengths (nnz per output row for MTTKRP, per leading
/// `(i,j)` fiber for TTM). The empty segments count toward the
/// mean/variance — an empty segment still costs a writeback slot in
/// row-balanced kernels, exactly like an empty CSR row (whose statistics
/// live in [`MatrixStats`]).
///
/// One definition shared by the coordinator's `ShapeKey` fingerprints and
/// the `tuner::model` pricing formulas, so the cache key and the cost
/// model see the same dynamics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SegStats {
    /// Total output segments, including empty ones.
    pub segments: usize,
    pub nnz: usize,
    /// Mean segment length `nnz / segments` (0 when there are no segments).
    pub mean_len: f64,
    /// Coefficient of variation of segment lengths: std/mean over *all*
    /// segments (empties included).
    pub cv: f64,
    /// Longest segment (the critical path of a segment-split kernel).
    pub max_len: usize,
    /// Fraction of segments with no non-zeros.
    pub empty_frac: f64,
}

impl SegStats {
    /// Build from a run-length view: positions `0..nnz` are sorted by
    /// segment, `seg_at(p)` maps a position to its segment id (contiguous
    /// runs). O(nnz), no allocation.
    pub fn from_runs(segments: usize, nnz: usize, seg_at: impl Fn(usize) -> u64) -> SegStats {
        let segs = segments.max(1);
        let mut used = 0usize;
        let mut sumsq = 0f64;
        let mut max_len = 0usize;
        let mut i = 0;
        while i < nnz {
            let seg = seg_at(i);
            let mut j = i + 1;
            while j < nnz && seg_at(j) == seg {
                j += 1;
            }
            let len = j - i;
            sumsq += (len as f64) * (len as f64);
            max_len = max_len.max(len);
            used += 1;
            i = j;
        }
        let mean = nnz as f64 / segs as f64;
        let var = (sumsq / segs as f64 - mean * mean).max(0.0);
        let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
        SegStats {
            segments,
            nnz,
            mean_len: mean,
            cv,
            max_len,
            empty_frac: 1.0 - used as f64 / segs as f64,
        }
    }

    /// MTTKRP segments: output rows (`idx0` runs).
    pub fn mttkrp(a: &Coo3) -> SegStats {
        SegStats::from_runs(a.dim0, a.nnz(), |p| a.idx0[p] as u64)
    }

    /// TTM segments: leading `(i, j)` fibers.
    pub fn ttm(a: &Coo3) -> SegStats {
        SegStats::from_runs(a.dim0 * a.dim1, a.nnz(), |p| {
            a.idx0[p] as u64 * a.dim1 as u64 + a.idx1[p] as u64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn uniform_degrees_have_zero_cv_and_gini() {
        let coo = Coo::new(
            4,
            4,
            (0..4).flat_map(|r| [(r as u32, 0u32, 1.0f32), (r as u32, 1, 1.0)]).collect(),
        );
        let s = MatrixStats::of(&coo.to_csr());
        assert_eq!(s.row_degree_mean, 2.0);
        assert!(s.row_degree_cv.abs() < 1e-12);
        assert!(s.gini.abs() < 1e-12);
        assert_eq!(s.empty_row_frac, 0.0);
    }

    #[test]
    fn single_hub_row_is_maximally_skewed() {
        let coo = Coo::new(4, 8, (0..8).map(|c| (0u32, c as u32, 1.0f32)).collect());
        let s = MatrixStats::of(&coo.to_csr());
        assert_eq!(s.row_degree_max, 8);
        assert_eq!(s.empty_row_frac, 0.75);
        assert!(s.gini > 0.7, "gini {} should be high", s.gini);
        assert!(s.row_degree_cv > 1.5);
    }

    #[test]
    fn histogram_buckets_by_log2_degree() {
        // rows with degrees 1, 2, 3, 8, 0 → buckets 0, 1, 1, 3; empty row skipped
        let mut entries = Vec::new();
        entries.push((0u32, 0u32, 1.0f32)); // deg 1
        for c in 0..2 {
            entries.push((1, c, 1.0)); // deg 2
        }
        for c in 0..3 {
            entries.push((2, c, 1.0)); // deg 3
        }
        for c in 0..8 {
            entries.push((3, c, 1.0)); // deg 8
        }
        let s = MatrixStats::of(&Coo::new(5, 8, entries).to_csr());
        assert_eq!(s.hist_rows[0], 1);
        assert_eq!(s.hist_rows[1], 2);
        assert_eq!(s.hist_rows[2], 0);
        assert_eq!(s.hist_rows[3], 1);
        assert_eq!(s.hist_nnz[0], 1);
        assert_eq!(s.hist_nnz[1], 5);
        assert_eq!(s.hist_nnz[3], 8);
        // conservation: histogram covers exactly the non-empty rows / all nnz
        let rows: u32 = s.hist_rows.iter().sum();
        let nnz: u64 = s.hist_nnz.iter().sum();
        assert_eq!(rows as usize, 4);
        assert_eq!(nnz as usize, s.nnz);
        assert_eq!(degree_bucket(1), 0);
        assert_eq!(degree_bucket(2), 1);
        assert_eq!(degree_bucket(usize::MAX), DEGREE_BUCKETS - 1);
    }

    #[test]
    fn density_matches() {
        let coo = Coo::new(10, 10, vec![(0, 0, 1.0), (5, 5, 1.0)]);
        let s = MatrixStats::of(&coo.to_csr());
        assert!((s.density - 0.02).abs() < 1e-12);
    }

    #[test]
    fn seg_stats_from_runs_counts_empties() {
        // 4 segments, nnz in segments 0 (3x) and 2 (1x): mean = 1, two empty
        let ids = [0u64, 0, 0, 2];
        let s = SegStats::from_runs(4, 4, |p| ids[p]);
        assert_eq!(s.segments, 4);
        assert_eq!(s.nnz, 4);
        assert!((s.mean_len - 1.0).abs() < 1e-12);
        assert_eq!(s.max_len, 3);
        assert!((s.empty_frac - 0.5).abs() < 1e-12);
        // var = (9 + 1)/4 - 1 = 1.5; cv = sqrt(1.5)
        assert!((s.cv - 1.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn seg_stats_tensor_views_match_their_keys() {
        let t = Coo3::random((16, 8, 4), 100, 3);
        let m = SegStats::mttkrp(&t);
        assert_eq!(m.segments, 16);
        assert_eq!(m.nnz, 100);
        assert!((m.mean_len - 100.0 / 16.0).abs() < 1e-12);
        let f = SegStats::ttm(&t);
        assert_eq!(f.segments, 16 * 8);
        assert!(f.mean_len < m.mean_len, "fibers are shorter than rows");
        assert!(f.max_len <= m.max_len);
    }

    #[test]
    fn seg_stats_from_runs_agrees_with_matrix_stats_on_a_row_view() {
        // the two statistic families share definitions: feeding a CSR's
        // rows through from_runs reproduces MatrixStats' skew features
        let coo = Coo::new(4, 8, (0..8).map(|c| (0u32, c as u32, 1.0f32)).collect());
        let csr = coo.to_csr();
        let ms = MatrixStats::of(&csr);
        let rows: Vec<u64> = (0..csr.rows as u32)
            .flat_map(|i| std::iter::repeat_n(i as u64, csr.row_degree(i as usize)))
            .collect();
        let ss = SegStats::from_runs(csr.rows, csr.nnz(), |p| rows[p]);
        assert_eq!(ss.segments, ms.rows);
        assert!((ss.mean_len - ms.row_degree_mean).abs() < 1e-12);
        assert!((ss.cv - ms.row_degree_cv).abs() < 1e-12);
        assert!((ss.empty_frac - ms.empty_row_frac).abs() < 1e-12);
        assert_eq!(ss.max_len, ms.row_degree_max);
    }
}
