//! Tiny deterministic PRNG (SplitMix64) used by all synthetic generators.
//!
//! In-house rather than the `rand` crate so that dataset bytes are stable
//! across dependency upgrades — the experiment tables in EXPERIMENTS.md
//! depend on the exact matrices.

/// SplitMix64: fast, full-period 64-bit generator (Steele et al., 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[-1, 1)` — the value distribution for matrix data.
    #[inline]
    pub fn value(&mut self) -> f32 {
        (self.uniform() * 2.0 - 1.0) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = SplitMix64::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
