//! The evaluation dataset suite — the stand-in for the DA-SpMM
//! SuiteSparse selection (DESIGN.md §2).
//!
//! The suite sweeps the two axes the paper's results key on:
//! * **density**: 1e-4 … 5e-2 (Fig. 11's x-axis),
//! * **row-degree skew**: uniform (ER, banded) vs power-law vs block,
//! at several sizes. Every matrix is seeded, so `suite()` is deterministic.

use super::coo::Coo;
use super::gen;

/// A named matrix in the evaluation suite.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    /// Generator family, for grouping in reports.
    pub family: &'static str,
    pub matrix: Coo,
}

fn spec(name: String, family: &'static str, matrix: Coo) -> DatasetSpec {
    DatasetSpec { name, family, matrix }
}

/// The full evaluation suite (~26 matrices, up to ~200k nnz).
///
/// Sizes are scaled to simulator throughput: large enough that warp
/// scheduling and imbalance effects dominate, small enough that the whole
/// Table-3 sweep runs in minutes.
pub fn suite() -> Vec<DatasetSpec> {
    let mut out = Vec::new();
    let mut seed = 1000u64;
    let mut next = || {
        seed += 1;
        seed
    };

    // Erdős–Rényi density sweep (uniform degrees) — Fig. 11's x-axis.
    for &(n, dens) in &[
        (1024usize, 1e-3f64),
        (1024, 5e-3),
        (1024, 2e-2),
        (2048, 5e-4),
        (2048, 2e-3),
        (2048, 1e-2),
        (4096, 1e-4),
        (4096, 1e-3),
        (4096, 5e-3),
    ] {
        let nnz = ((n * n) as f64 * dens) as usize;
        out.push(spec(format!("er_{n}_d{dens:.0e}"), "erdos_renyi", gen::erdos_renyi(n, n, nnz, next())));
    }

    // Power-law skew sweep — the workload-imbalance axis.
    for &(n, nnz, alpha) in &[
        (1024usize, 8192usize, 1.2f64),
        (1024, 8192, 1.8),
        (2048, 16384, 1.2),
        (2048, 16384, 1.6),
        (2048, 16384, 2.2),
        (4096, 32768, 1.5),
        (4096, 32768, 2.0),
    ] {
        out.push(spec(
            format!("pl_{n}_a{alpha}"),
            "power_law",
            gen::power_law(n, n, nnz, alpha, next()),
        ));
    }

    // Banded (scientific) matrices — perfect balance + locality.
    for &(n, band) in &[(1024usize, 5usize), (2048, 9), (4096, 27)] {
        out.push(spec(format!("band_{n}_w{band}"), "banded", gen::banded(n, band, next())));
    }

    // Block-community (GNN-ish) graphs.
    for &(n, blocks, dens, inter) in &[
        (1024usize, 8usize, 0.05f64, 1000usize),
        (2048, 16, 0.02, 4000),
        (4096, 32, 0.01, 8000),
    ] {
        out.push(spec(
            format!("block_{n}_b{blocks}"),
            "block_community",
            gen::block_community(n, blocks, dens, inter, next()),
        ));
    }

    // Extreme corners: near-empty and single-hub — the degenerate inputs
    // where static group size 32 wastes the most parallelism (Fig. 1b).
    out.push(spec("corner_sparse_4096".into(), "corner", gen::erdos_renyi(4096, 4096, 4096, next())));
    {
        let n = 1024usize;
        let mut triplets: Vec<(u32, u32, f32)> = (0..n as u32).map(|c| (0u32, c, 1.0f32)).collect();
        for i in 1..n as u32 {
            triplets.push((i, (i * 7) % n as u32, 0.5));
        }
        out.push(spec("corner_hub_1024".into(), "corner", Coo::new(n, n, triplets)));
    }
    // short rows: every row has exactly 2 nnz — group 32 wastes 30 lanes.
    {
        let n = 2048usize;
        let mut triplets = Vec::new();
        for i in 0..n as u32 {
            triplets.push((i, i % n as u32, 1.0));
            triplets.push((i, (i * 13 + 1) % n as u32, -1.0));
        }
        out.push(spec("corner_short_rows_2048".into(), "corner", Coo::new(n, n, triplets)));
    }

    out
}

/// A reduced suite for fast benches/tests (first ER, one PL, one banded,
/// one corner).
pub fn mini_suite() -> Vec<DatasetSpec> {
    suite()
        .into_iter()
        .filter(|s| {
            matches!(
                s.name.as_str(),
                "er_1024_d5e-3" | "pl_1024_a1.8" | "band_1024_w5" | "corner_short_rows_2048"
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    #[test]
    fn suite_is_nonempty_and_valid() {
        let s = suite();
        assert!(s.len() >= 20, "suite has {} entries", s.len());
        for d in &s {
            d.matrix.to_csr().check_invariants().unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert!(d.matrix.nnz() > 0, "{} empty", d.name);
        }
    }

    #[test]
    fn suite_names_unique() {
        let s = suite();
        let mut names: Vec<_> = s.iter().map(|d| d.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), s.len());
    }

    #[test]
    fn suite_spans_density_and_skew() {
        let s = suite();
        let stats: Vec<MatrixStats> = s.iter().map(|d| MatrixStats::of(&d.matrix.to_csr())).collect();
        let dmin = stats.iter().map(|t| t.density).fold(f64::MAX, f64::min);
        let dmax = stats.iter().map(|t| t.density).fold(0.0, f64::max);
        assert!(dmin < 5e-4 && dmax > 1e-2, "density span [{dmin}, {dmax}] too narrow");
        let cvmax = stats.iter().map(|t| t.row_degree_cv).fold(0.0, f64::max);
        let cvmin = stats.iter().map(|t| t.row_degree_cv).fold(f64::MAX, f64::min);
        assert!(cvmax > 1.0 && cvmin < 0.2, "skew span [{cvmin}, {cvmax}] too narrow");
    }

    #[test]
    fn mini_suite_subset() {
        assert_eq!(mini_suite().len(), 4);
    }

    #[test]
    fn suite_deterministic() {
        let a = suite();
        let b = suite();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix, y.matrix, "{} differs between calls", x.name);
        }
    }
}
