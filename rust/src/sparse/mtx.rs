//! MatrixMarket (`.mtx`) reader/writer — coordinate real general/symmetric.
//!
//! Lets users run the benchmarks on real SuiteSparse matrices when they
//! have them; the CI path uses the synthetic suite instead.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::coo::Coo;

/// Parse a MatrixMarket stream into COO. Supports `matrix coordinate
/// real|integer|pattern general|symmetric`.
pub fn read_mtx<R: Read>(reader: R) -> Result<Coo> {
    let mut lines = BufReader::new(reader).lines();

    let header = lines.next().context("empty mtx file")??;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 4 || !h[0].starts_with("%%MatrixMarket") {
        bail!("not a MatrixMarket file: {header}");
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        bail!("only `matrix coordinate` supported, got {header}");
    }
    let field = h[3];
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type {field}");
    }
    let symmetric = h.get(4).is_some_and(|&s| s == "symmetric");

    // skip comments, read size line
    let size_line = loop {
        let line = lines.next().context("missing size line")??;
        if !line.starts_with('%') && !line.trim().is_empty() {
            break line;
        }
    };
    let dims: Vec<usize> =
        size_line.split_whitespace().map(|t| t.parse().context("bad size line")).collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("size line must have 3 fields");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut triplets = Vec::with_capacity(if symmetric { nnz * 2 } else { nnz });
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        if toks.len() < 2 {
            bail!("bad entry line: {t}");
        }
        let r: usize = toks[0].parse().context("bad row")?;
        let c: usize = toks[1].parse().context("bad col")?;
        let v: f32 = if field == "pattern" { 1.0 } else { toks.get(2).context("missing value")?.parse()? };
        if r == 0 || c == 0 || r > rows || c > cols {
            bail!("entry ({r},{c}) out of 1-based range {rows}x{cols}");
        }
        triplets.push((r as u32 - 1, c as u32 - 1, v));
        if symmetric && r != c {
            triplets.push((c as u32 - 1, r as u32 - 1, v));
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("expected {nnz} entries, saw {seen}");
    }
    Ok(Coo::new(rows, cols, triplets))
}

pub fn read_mtx_file<P: AsRef<Path>>(path: P) -> Result<Coo> {
    let f = std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_mtx(f)
}

/// Write COO as `matrix coordinate real general`.
pub fn write_mtx<W: Write>(mut w: W, m: &Coo) -> Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by sgap")?;
    writeln!(w, "{} {} {}", m.rows, m.cols, m.nnz())?;
    for k in 0..m.nnz() {
        writeln!(w, "{} {} {}", m.row_idx[k] + 1, m.col_idx[k] + 1, m.vals[k])?;
    }
    Ok(())
}

pub fn write_mtx_file<P: AsRef<Path>>(path: P, m: &Coo) -> Result<()> {
    let f = std::fs::File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    write_mtx(std::io::BufWriter::new(f), m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n% comment\n3 4 2\n1 2 1.5\n3 4 -2.0\n";
        let m = read_mtx(src.as_bytes()).unwrap();
        assert_eq!((m.rows, m.cols, m.nnz()), (3, 4, 2));
        assert_eq!(m.vals, vec![1.5, -2.0]);
    }

    #[test]
    fn parse_symmetric_expands() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n2 1 3.0\n";
        let m = read_mtx(src.as_bytes()).unwrap();
        assert_eq!(m.nnz(), 3); // diagonal not duplicated
    }

    #[test]
    fn parse_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 2\n";
        let m = read_mtx(src.as_bytes()).unwrap();
        assert_eq!(m.vals, vec![1.0]);
    }

    #[test]
    fn round_trip() {
        let m = Coo::new(5, 5, vec![(0, 4, 1.0), (2, 2, -3.5), (4, 0, 2.25)]);
        let mut buf = Vec::new();
        write_mtx(&mut buf, &m).unwrap();
        let back = read_mtx(buf.as_slice()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_wrong_count() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_mtx(src.as_bytes()).is_err());
    }

    #[test]
    fn rejects_array_format() {
        let src = "%%MatrixMarket matrix array real general\n2 2\n1.0\n";
        assert!(read_mtx(src.as_bytes()).is_err());
    }
}
