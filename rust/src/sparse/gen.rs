//! Seeded synthetic sparse-matrix generators.
//!
//! The paper evaluates on the DA-SpMM SuiteSparse selection, which we do
//! not have; these generators sweep the two axes that selection varies —
//! **density** and **row-degree skew** — plus the banded/block structures
//! common in scientific matrices (DESIGN.md §2 substitution table).

use super::coo::Coo;
use super::rng::SplitMix64;

/// Erdős–Rényi: each of `nnz` entries uniform over the index space.
/// Row degrees are near-uniform (low CV) — the regime where row-balanced
/// kernels win.
pub fn erdos_renyi(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let cap = rows * cols;
    let nnz = nnz.min(cap);
    let mut triplets = Vec::with_capacity(nnz);
    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    while triplets.len() < nnz {
        let r = rng.below(rows as u64) as u32;
        let c = rng.below(cols as u64) as u32;
        if seen.insert((r, c)) {
            triplets.push((r, c, rng.value()));
        }
    }
    Coo::new(rows, cols, triplets)
}

/// Power-law (Zipf) row degrees — the graph-like, high-skew regime where
/// nnz-balanced kernels win. `alpha` is the Zipf exponent (1.0–2.5 typical);
/// larger `alpha` = heavier skew concentrated on fewer rows.
///
/// Delivers exactly `nnz` entries (clamped to `rows * cols`): per-rank
/// targets are the exact Zipf shares rounded by largest remainder (ties
/// to the lower rank, so realized degrees stay monotone nonincreasing in
/// Zipf rank), capped at `cols`, with capped overflow spilling to the
/// next ranks with headroom. Near-full hub rows draw their columns from a
/// shuffled pool instead of rejection sampling, so no entry is dropped.
pub fn power_law(rows: usize, cols: usize, nnz: usize, alpha: f64, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let nnz = nnz.min(rows * cols);
    // Zipf weights over a shuffled row order so hub rows are scattered.
    let mut order: Vec<u32> = (0..rows as u32).collect();
    rng.shuffle(&mut order);
    let weights: Vec<f64> = (1..=rows).map(|k| (k as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    let exact: Vec<f64> = weights.iter().map(|w| w / total * nnz as f64).collect();
    let mut degrees: Vec<usize> =
        exact.iter().map(|e| (e.floor() as usize).min(cols)).collect();
    let mut assigned: usize = degrees.iter().sum();
    // largest-remainder order: descending fractional part, ties to the
    // lower rank (exact[] is strictly decreasing, so equal floors order by
    // fraction the same way — realized degrees stay monotone in rank)
    let mut by_frac: Vec<usize> = (0..rows).collect();
    by_frac.sort_by(|&a, &b| {
        let fa = exact[a] - exact[a].floor();
        let fb = exact[b] - exact[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    let mut k = 0;
    while assigned < nnz {
        let rank = by_frac[k % rows];
        if degrees[rank] < cols {
            degrees[rank] += 1;
            assigned += 1;
        }
        k += 1;
    }
    let mut triplets = Vec::with_capacity(nnz);
    for (rank, &row) in order.iter().enumerate() {
        let want = degrees[rank];
        if want == 0 {
            continue;
        }
        if want * 2 >= cols {
            // hub row close to full: sample without replacement from a
            // shuffled column pool — rejection would stall near `cols`
            let mut pool: Vec<u32> = (0..cols as u32).collect();
            rng.shuffle(&mut pool);
            for i in 0..want {
                triplets.push((row, pool[i], rng.value()));
            }
        } else {
            let mut used = std::collections::HashSet::with_capacity(want * 2);
            while used.len() < want {
                let c = rng.below(cols as u64) as u32;
                if used.insert(c) {
                    triplets.push((row, c, rng.value()));
                }
            }
        }
    }
    Coo::new(rows, cols, triplets)
}

/// Banded matrix: `band` diagonals around the main diagonal — the
/// scientific-computing regime (perfect locality, uniform degrees).
pub fn banded(n: usize, band: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let half = band / 2;
    let mut triplets = Vec::new();
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half).min(n - 1);
        for j in lo..=hi {
            triplets.push((i as u32, j as u32, rng.value()));
        }
    }
    Coo::new(n, n, triplets)
}

/// Block-community matrix: `blocks` dense-ish diagonal communities plus
/// sparse inter-block noise — the GNN / social-graph regime.
pub fn block_community(
    n: usize,
    blocks: usize,
    intra_density: f64,
    inter_nnz: usize,
    seed: u64,
) -> Coo {
    assert!(blocks > 0 && n >= blocks);
    let mut rng = SplitMix64::new(seed);
    let bs = n / blocks;
    let mut triplets = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for b in 0..blocks {
        let base = b * bs;
        let size = if b == blocks - 1 { n - base } else { bs };
        // clamp to the block's cell count: intra_density >= 1.0 means a
        // fully dense block, not an unsatisfiable target
        let want = (((size * size) as f64 * intra_density) as usize).min(size * size);
        let mut got = 0;
        let mut attempts = 0;
        while got < want && attempts < want * 20 + 16 {
            let r = base as u64 + rng.below(size as u64);
            let c = base as u64 + rng.below(size as u64);
            if seen.insert((r as u32, c as u32)) {
                triplets.push((r as u32, c as u32, rng.value()));
                got += 1;
            }
            attempts += 1;
        }
        if got < want {
            // collisions exhausted the sampler (near-dense block): fill
            // the remainder from a shuffled pool of the free cells
            let mut free: Vec<(u32, u32)> = Vec::with_capacity(size * size - got);
            for r in 0..size {
                for c in 0..size {
                    let cell = ((base + r) as u32, (base + c) as u32);
                    if !seen.contains(&cell) {
                        free.push(cell);
                    }
                }
            }
            rng.shuffle(&mut free);
            for &(r, c) in free.iter().take(want - got) {
                seen.insert((r, c));
                triplets.push((r, c, rng.value()));
            }
        }
    }
    // inter-block noise cannot exceed the remaining free cells
    let inter_nnz = inter_nnz.min(n * n - seen.len());
    let mut got = 0;
    while got < inter_nnz {
        let r = rng.below(n as u64) as u32;
        let c = rng.below(n as u64) as u32;
        if seen.insert((r, c)) {
            triplets.push((r, c, rng.value()));
            got += 1;
        }
    }
    Coo::new(n, n, triplets)
}

/// Row-normalized GCN adjacency Â = D^{-1}(A + I) from any square pattern.
pub fn normalize_adjacency(m: &Coo) -> Coo {
    assert_eq!(m.rows, m.cols, "adjacency must be square");
    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(m.nnz() + m.rows);
    for k in 0..m.nnz() {
        triplets.push((m.row_idx[k], m.col_idx[k], 1.0));
    }
    for i in 0..m.rows as u32 {
        triplets.push((i, i, 1.0)); // self loop
    }
    let with_loops = Coo::new(m.rows, m.cols, triplets);
    let csr = with_loops.to_csr();
    let mut out = Vec::with_capacity(csr.nnz());
    for i in 0..csr.rows {
        let deg = csr.row_degree(i).max(1) as f32;
        for k in csr.indptr[i] as usize..csr.indptr[i + 1] as usize {
            out.push((i as u32, csr.indices[k], 1.0 / deg));
        }
    }
    Coo::new(m.rows, m.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    #[test]
    fn er_exact_nnz_and_valid() {
        let m = erdos_renyi(100, 80, 500, 1);
        assert_eq!(m.nnz(), 500);
        m.to_csr().check_invariants().unwrap();
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(50, 50, 200, 42), erdos_renyi(50, 50, 200, 42));
        assert_ne!(erdos_renyi(50, 50, 200, 42), erdos_renyi(50, 50, 200, 43));
    }

    #[test]
    fn power_law_is_skewed() {
        let er = erdos_renyi(512, 512, 4096, 7);
        let pl = power_law(512, 512, 4096, 1.6, 7);
        let cv_er = MatrixStats::of(&er.to_csr()).row_degree_cv;
        let cv_pl = MatrixStats::of(&pl.to_csr()).row_degree_cv;
        assert!(cv_pl > cv_er * 2.0, "power-law CV {cv_pl} not >> ER CV {cv_er}");
    }

    #[test]
    fn power_law_nnz_close() {
        let m = power_law(256, 256, 2048, 1.2, 3);
        assert_eq!(m.nnz(), 2048, "power_law must deliver exactly the requested nnz");
    }

    #[test]
    fn power_law_exact_nnz_even_with_near_full_hubs() {
        // alpha 2.5 on a narrow matrix concentrates the head ranks near
        // `cols` — the regime the old rejection loop silently dropped
        // entries in. Exact delivery must hold, and no row may exceed cols.
        let m = power_law(64, 32, 512, 2.5, 9);
        assert_eq!(m.nnz(), 512);
        let csr = m.to_csr();
        csr.check_invariants().unwrap();
        for i in 0..csr.rows {
            assert!(csr.row_degree(i) <= 32);
        }
        // a target beyond capacity clamps to the full matrix
        let full = power_law(8, 8, 1000, 1.5, 4);
        assert_eq!(full.nnz(), 64);
    }

    #[test]
    fn power_law_degrees_monotone_by_zipf_rank() {
        // largest-remainder with ties to the lower rank keeps realized
        // degrees monotone nonincreasing in Zipf rank; recover the rank
        // order by sorting row degrees descending and check the same
        // multiset arises from the deterministic target computation
        let (rows, cols, nnz, alpha) = (256usize, 256usize, 4096usize, 1.6f64);
        let m = power_law(rows, cols, nnz, alpha, 11);
        let csr = m.to_csr();
        let mut degs: Vec<usize> = (0..rows).map(|i| csr.row_degree(i)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // the sorted degree profile IS the by-rank profile (rank order is
        // a hidden permutation of rows); it must be monotone by construction
        for w in degs.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(degs.iter().sum::<usize>(), nnz);
        // head rank strictly dominates the tail (the skew is real)
        assert!(degs[0] > degs[rows - 1] + 4, "head {} vs tail {}", degs[0], degs[rows - 1]);
    }

    #[test]
    fn banded_structure() {
        let m = banded(64, 5, 1);
        let csr = m.to_csr();
        csr.check_invariants().unwrap();
        // interior rows have exactly band entries
        assert_eq!(csr.row_degree(32), 5);
        for k in 0..m.nnz() {
            let (r, c) = (m.row_idx[k] as i64, m.col_idx[k] as i64);
            assert!((r - c).abs() <= 2);
        }
    }

    #[test]
    fn block_community_shape() {
        let m = block_community(128, 4, 0.2, 100, 5);
        m.to_csr().check_invariants().unwrap();
        assert!(m.nnz() > 4 * (32 * 32 / 5) && m.nnz() < 128 * 128);
    }

    #[test]
    fn block_community_full_density_terminates() {
        // intra_density = 1.0 used to spin forever (want was never clamped
        // to the block's cell count and the loop had no attempt cap); now
        // every block comes out fully dense and the generator returns
        let m = block_community(64, 4, 1.0, 50, 7);
        let csr = m.to_csr();
        csr.check_invariants().unwrap();
        // 4 fully dense 16x16 blocks plus the inter-block noise
        assert_eq!(csr.nnz(), 4 * 16 * 16 + 50);
        for b in 0..4usize {
            for r in b * 16..(b + 1) * 16 {
                let row: std::collections::HashSet<u32> = (csr.indptr[r] as usize
                    ..csr.indptr[r + 1] as usize)
                    .map(|k| csr.indices[k])
                    .collect();
                for c in (b * 16) as u32..((b + 1) * 16) as u32 {
                    assert!(row.contains(&c), "block {b} row {r} missing col {c}");
                }
            }
        }
        // density > 1.0 clamps the same way instead of diverging
        let m2 = block_community(32, 2, 1.5, 0, 8);
        assert_eq!(m2.nnz(), 2 * 16 * 16);
    }

    #[test]
    fn normalized_adjacency_rows_sum_to_one() {
        let m = erdos_renyi(64, 64, 300, 11);
        let a = normalize_adjacency(&m);
        let csr = a.to_csr();
        for i in 0..csr.rows {
            let s: f32 =
                (csr.indptr[i] as usize..csr.indptr[i + 1] as usize).map(|k| csr.data[k]).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }
}
