//! Seeded synthetic sparse-matrix generators.
//!
//! The paper evaluates on the DA-SpMM SuiteSparse selection, which we do
//! not have; these generators sweep the two axes that selection varies —
//! **density** and **row-degree skew** — plus the banded/block structures
//! common in scientific matrices (DESIGN.md §2 substitution table).

use super::coo::Coo;
use super::rng::SplitMix64;

/// Erdős–Rényi: each of `nnz` entries uniform over the index space.
/// Row degrees are near-uniform (low CV) — the regime where row-balanced
/// kernels win.
pub fn erdos_renyi(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let cap = rows * cols;
    let nnz = nnz.min(cap);
    let mut triplets = Vec::with_capacity(nnz);
    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    while triplets.len() < nnz {
        let r = rng.below(rows as u64) as u32;
        let c = rng.below(cols as u64) as u32;
        if seen.insert((r, c)) {
            triplets.push((r, c, rng.value()));
        }
    }
    Coo::new(rows, cols, triplets)
}

/// Power-law (Zipf) row degrees — the graph-like, high-skew regime where
/// nnz-balanced kernels win. `alpha` is the Zipf exponent (1.0–2.5 typical);
/// larger `alpha` = heavier skew concentrated on fewer rows.
pub fn power_law(rows: usize, cols: usize, nnz: usize, alpha: f64, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    // Zipf weights over a shuffled row order so hub rows are scattered.
    let mut order: Vec<u32> = (0..rows as u32).collect();
    rng.shuffle(&mut order);
    let weights: Vec<f64> = (1..=rows).map(|k| (k as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    // per-row target degrees, largest remainder rounding, capped at `cols`
    // (overflow past a full row is redistributed to rows with headroom)
    let mut degrees: Vec<usize> =
        weights.iter().map(|w| (((w / total) * nnz as f64).floor() as usize).min(cols)).collect();
    let mut assigned: usize = degrees.iter().sum();
    let mut k = 0;
    let mut stall = 0;
    while assigned < nnz && stall < rows {
        let slot = k % rows;
        if degrees[slot] < cols {
            degrees[slot] += 1;
            assigned += 1;
            stall = 0;
        } else {
            stall += 1;
        }
        k += 1;
    }
    let mut triplets = Vec::with_capacity(nnz);
    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    for (rank, &row) in order.iter().enumerate() {
        let want = degrees[rank].min(cols);
        let mut got = 0;
        let mut attempts = 0;
        while got < want && attempts < want * 20 + 16 {
            let c = rng.below(cols as u64) as u32;
            if seen.insert((row, c)) {
                triplets.push((row, c, rng.value()));
                got += 1;
            }
            attempts += 1;
        }
    }
    Coo::new(rows, cols, triplets)
}

/// Banded matrix: `band` diagonals around the main diagonal — the
/// scientific-computing regime (perfect locality, uniform degrees).
pub fn banded(n: usize, band: usize, seed: u64) -> Coo {
    let mut rng = SplitMix64::new(seed);
    let half = band / 2;
    let mut triplets = Vec::new();
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half).min(n - 1);
        for j in lo..=hi {
            triplets.push((i as u32, j as u32, rng.value()));
        }
    }
    Coo::new(n, n, triplets)
}

/// Block-community matrix: `blocks` dense-ish diagonal communities plus
/// sparse inter-block noise — the GNN / social-graph regime.
pub fn block_community(
    n: usize,
    blocks: usize,
    intra_density: f64,
    inter_nnz: usize,
    seed: u64,
) -> Coo {
    assert!(blocks > 0 && n >= blocks);
    let mut rng = SplitMix64::new(seed);
    let bs = n / blocks;
    let mut triplets = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for b in 0..blocks {
        let base = b * bs;
        let size = if b == blocks - 1 { n - base } else { bs };
        let want = ((size * size) as f64 * intra_density) as usize;
        let mut got = 0;
        while got < want {
            let r = base as u64 + rng.below(size as u64);
            let c = base as u64 + rng.below(size as u64);
            if seen.insert((r as u32, c as u32)) {
                triplets.push((r as u32, c as u32, rng.value()));
                got += 1;
            }
        }
    }
    let mut got = 0;
    while got < inter_nnz {
        let r = rng.below(n as u64) as u32;
        let c = rng.below(n as u64) as u32;
        if seen.insert((r, c)) {
            triplets.push((r, c, rng.value()));
            got += 1;
        }
    }
    Coo::new(n, n, triplets)
}

/// Row-normalized GCN adjacency Â = D^{-1}(A + I) from any square pattern.
pub fn normalize_adjacency(m: &Coo) -> Coo {
    assert_eq!(m.rows, m.cols, "adjacency must be square");
    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(m.nnz() + m.rows);
    for k in 0..m.nnz() {
        triplets.push((m.row_idx[k], m.col_idx[k], 1.0));
    }
    for i in 0..m.rows as u32 {
        triplets.push((i, i, 1.0)); // self loop
    }
    let with_loops = Coo::new(m.rows, m.cols, triplets);
    let csr = with_loops.to_csr();
    let mut out = Vec::with_capacity(csr.nnz());
    for i in 0..csr.rows {
        let deg = csr.row_degree(i).max(1) as f32;
        for k in csr.indptr[i] as usize..csr.indptr[i + 1] as usize {
            out.push((i as u32, csr.indices[k], 1.0 / deg));
        }
    }
    Coo::new(m.rows, m.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    #[test]
    fn er_exact_nnz_and_valid() {
        let m = erdos_renyi(100, 80, 500, 1);
        assert_eq!(m.nnz(), 500);
        m.to_csr().check_invariants().unwrap();
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(50, 50, 200, 42), erdos_renyi(50, 50, 200, 42));
        assert_ne!(erdos_renyi(50, 50, 200, 42), erdos_renyi(50, 50, 200, 43));
    }

    #[test]
    fn power_law_is_skewed() {
        let er = erdos_renyi(512, 512, 4096, 7);
        let pl = power_law(512, 512, 4096, 1.6, 7);
        let cv_er = MatrixStats::of(&er.to_csr()).row_degree_cv;
        let cv_pl = MatrixStats::of(&pl.to_csr()).row_degree_cv;
        assert!(cv_pl > cv_er * 2.0, "power-law CV {cv_pl} not >> ER CV {cv_er}");
    }

    #[test]
    fn power_law_nnz_close() {
        let m = power_law(256, 256, 2048, 1.2, 3);
        assert!(m.nnz() as f64 > 2048.0 * 0.9, "nnz {} too far below target", m.nnz());
    }

    #[test]
    fn banded_structure() {
        let m = banded(64, 5, 1);
        let csr = m.to_csr();
        csr.check_invariants().unwrap();
        // interior rows have exactly band entries
        assert_eq!(csr.row_degree(32), 5);
        for k in 0..m.nnz() {
            let (r, c) = (m.row_idx[k] as i64, m.col_idx[k] as i64);
            assert!((r - c).abs() <= 2);
        }
    }

    #[test]
    fn block_community_shape() {
        let m = block_community(128, 4, 0.2, 100, 5);
        m.to_csr().check_invariants().unwrap();
        assert!(m.nnz() > 4 * (32 * 32 / 5) && m.nnz() < 128 * 128);
    }

    #[test]
    fn normalized_adjacency_rows_sum_to_one() {
        let m = erdos_renyi(64, 64, 300, 11);
        let a = normalize_adjacency(&m);
        let csr = a.to_csr();
        for i in 0..csr.rows {
            let s: f32 =
                (csr.indptr[i] as usize..csr.indptr[i + 1] as usize).map(|k| csr.data[k]).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }
}
