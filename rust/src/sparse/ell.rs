//! ELL (ELLPACK) format: fixed `slots` entries per row, zero-padded.
//!
//! This is the staging format for the row-balanced parallel-reduction
//! kernels (and the layout the Pallas `spmm_row_pr` artifact expects).

/// ELL matrix. `cols`/`vals` are row-major `[rows * slots]`; padding slots
/// hold `(col=0, val=0)` so they are numerically inert (zero extension).
#[derive(Debug, Clone, PartialEq)]
pub struct Ell {
    pub rows: usize,
    /// Number of columns of the logical matrix (not the slot count).
    pub cols_dim: usize,
    pub slots: usize,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Ell {
    #[inline]
    pub fn slot(&self, row: usize, s: usize) -> (u32, f32) {
        let k = row * self.slots + s;
        (self.cols[k], self.vals[k])
    }

    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.cols_dim]; self.rows];
        for i in 0..self.rows {
            for s in 0..self.slots {
                let (c, v) = self.slot(i, s);
                d[i][c as usize] += v;
            }
        }
        d
    }

    /// Fraction of slots that are padding — the ELL memory-overhead metric
    /// that makes row-balanced kernels lose on skewed matrices.
    pub fn padding_ratio(&self) -> f64 {
        if self.rows == 0 || self.slots == 0 {
            return 0.0;
        }
        let pad = self.vals.iter().filter(|&&v| v == 0.0).count();
        pad as f64 / (self.rows * self.slots) as f64
    }
}

#[cfg(test)]
mod tests {
    use crate::sparse::coo::Coo;

    #[test]
    fn padding_ratio_reflects_skew() {
        // one dense-ish row + three empty rows -> high padding
        let coo = Coo::new(4, 8, (0..8).map(|c| (0u32, c as u32, 1.0f32)).collect());
        let ell = coo.to_csr().to_ell(8);
        assert!(ell.padding_ratio() >= 0.74);
    }

    #[test]
    fn slot_accessor() {
        let coo = Coo::new(2, 4, vec![(0, 2, 5.0), (1, 0, 1.0), (1, 3, 2.0)]);
        let ell = coo.to_csr().to_ell(2);
        assert_eq!(ell.slot(0, 0), (2, 5.0));
        assert_eq!(ell.slot(0, 1), (0, 0.0)); // padding
        assert_eq!(ell.slot(1, 1), (3, 2.0));
    }
}
