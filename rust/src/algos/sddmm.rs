//! SDDMM with segment group — the §4.3 generalization claim.
//!
//! SDDMM (Eq. 2c): `Y(i,k) = A(i,k) · Σ_j X1(i,j) · X2(j,k)` with `Y`
//! sharing `A`'s sparsity. Its reduction (over the dense `j`) "behaves the
//! same" as SpMM's (§2.1, Fig. 4/5) — so the *same* `atomicAddGroup`
//! macro instruction and the same GroupSize tuning apply. The kernel is
//! **schedule-generated**: [`Schedule::sddmm_group`] describes the
//! `{<1/g nnz>, r}` shape and [`crate::compiler::lower`](mod@crate::compiler::lower) emits it through
//! the same reduction pipeline as SpMM — this module only binds buffers,
//! picks the grid, and launches, demonstrating that segment group is not
//! SpMM-specific.
//!
//! Layout: `g` lanes cooperate on one non-zero; each lane strides the
//! dense `j` dimension by `g`; an r-wide grouped tree reduction combines
//! the partial dot products; lane 0 of each r-group writes back
//! atomically (one output slot per nnz, group-uniform index).

use anyhow::Result;

use crate::compiler::schedule::Schedule;
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::Csr;

use super::runner::SpmmRun;

pub use crate::compiler::schedule::SddmmConfig;

/// Serial oracle: `y[pos] = a.data[pos] * dot(X1[i,:], X2[:,k])`.
///
/// `x1` is row-major `[a.rows × j_dim]`, `x2` row-major `[j_dim × a.cols]`
/// (so `k` indexes `x2`'s columns, matching `A`'s column space).
pub fn sddmm_serial(a: &Csr, x1: &[f32], x2: &[f32], j_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.rows * j_dim);
    assert_eq!(x2.len(), j_dim * a.cols);
    let mut y = vec![0f32; a.nnz()];
    for i in 0..a.rows {
        for p in a.indptr[i] as usize..a.indptr[i + 1] as usize {
            let k = a.indices[p] as usize;
            let mut dot = 0f32;
            for j in 0..j_dim {
                dot += x1[i * j_dim + j] * x2[j * a.cols + k];
            }
            y[p] = a.data[p] * dot;
        }
    }
    y
}

/// FLOPs: 2·nnz·J for the dots + nnz scaling multiplies.
pub fn sddmm_flops(a: &Csr, j_dim: usize) -> u64 {
    (2 * j_dim as u64 + 1) * a.nnz() as u64
}

/// Run SDDMM on the simulator; returns per-nnz outputs + the report.
///
/// The kernel is produced by `compiler::lower` from
/// [`Schedule::sddmm_group`]; this function binds the buffers
/// (`A2_pos/A2_crd/A_vals` CSR, `A_rowidx` COO row per nnz, `X1_vals`,
/// `X2_vals`, `Y_vals` one slot per nnz; scalars `A1_dimension`,
/// `A2_dimension`, `J_dimension`, `A_nnz`), picks the grid, and launches.
pub fn run(
    machine: &Machine,
    cfg: &SddmmConfig,
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<SpmmRun> {
    assert_eq!(x1.len(), a.rows * cfg.j_dim as usize);
    assert_eq!(x2.len(), cfg.j_dim as usize * a.cols);
    let sched = Schedule::sddmm_group(*cfg);
    let kernel = crate::compiler::compile(&sched.algebra(), &sched)?;
    let grid = (a.nnz() as u32).div_ceil(cfg.npb()).max(1);
    let rowidx: Vec<i32> = a.to_coo().row_idx.iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    mem.bind_i32("A2_pos", a.indptr.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A2_crd", a.indices.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A_rowidx", rowidx);
    mem.bind_f32("A_vals", a.data.clone());
    mem.bind_f32("X1_vals", x1.to_vec());
    mem.bind_f32("X2_vals", x2.to_vec());
    mem.bind_f32("Y_vals", vec![0.0; a.nnz().max(1)]);
    mem.bind_scalar("A1_dimension", a.rows as i64);
    mem.bind_scalar("A2_dimension", a.cols as i64);
    mem.bind_scalar("J_dimension", cfg.j_dim as i64);
    mem.bind_scalar("A_nnz", a.nnz() as i64);
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let c = mem.take_f32("Y_vals").expect("Y_vals");
    Ok(SpmmRun { c, report, kernel_name: kernel.name })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len).map(|_| rng.value()).collect()
    }

    fn check(cfg: SddmmConfig, a: &Csr) -> SpmmRun {
        let j = cfg.j_dim as usize;
        let x1 = dense(a.rows * j, 1);
        let x2 = dense(j * a.cols, 2);
        let want = sddmm_serial(a, &x1, &x2, j);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run(&m, &cfg, a, &x1, &x2).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 5e-4, "{}: err {err}", run.kernel_name);
        run
    }

    #[test]
    fn matches_oracle_group_sweep() {
        let a = erdos_renyi(100, 80, 900, 11).to_csr();
        for (g, r) in [(32u32, 32u32), (32, 8), (16, 16), (8, 4), (4, 4), (2, 2)] {
            check(SddmmConfig::new(64, g, r), &a);
        }
    }

    #[test]
    fn matches_oracle_on_skewed_pattern() {
        let a = power_law(128, 128, 1800, 1.9, 13).to_csr();
        check(SddmmConfig::new(32, 16, 8), &a);
    }

    #[test]
    fn j_not_multiple_of_g() {
        // J = 50 with g = 16: tail lanes idle in the last stride
        let a = erdos_renyi(64, 64, 400, 5).to_csr();
        check(SddmmConfig::new(50, 16, 16), &a);
    }

    #[test]
    fn small_r_beats_r32_for_small_j() {
        // J = 8 with g = 32: 24 lanes carry nothing — exactly Fig. 1(b);
        // a narrower reduction group wins
        let a = erdos_renyi(256, 256, 4000, 21).to_csr();
        let wide = check(SddmmConfig::new(8, 32, 32), &a);
        let narrow = check(SddmmConfig::new(8, 32, 8), &a);
        assert!(
            narrow.report.time_s < wide.report.time_s,
            "narrow {} !< wide {}",
            narrow.report.time_s,
            wide.report.time_s
        );
    }

    #[test]
    fn validation() {
        assert!(SddmmConfig::new(64, 12, 4).validate().is_err());
        assert!(SddmmConfig::new(64, 8, 16).validate().is_err());
        assert!(SddmmConfig::new(64, 8, 8).validate().is_ok());
    }

    #[test]
    fn empty_matrix_ok() {
        let a = crate::sparse::Coo::new(8, 8, vec![]).to_csr();
        let m = Machine::new(HwProfile::v100());
        let cfg = SddmmConfig::new(16, 8, 8);
        let run = run(&m, &cfg, &a, &dense(8 * 16, 3), &dense(16 * 8, 4)).unwrap();
        assert!(run.c.iter().all(|&v| v == 0.0));
    }
}
