//! SDDMM with segment group — the §4.3 generalization claim.
//!
//! SDDMM (Eq. 2c): `Y(i,k) = A(i,k) · Σ_j X1(i,j) · X2(j,k)` with `Y`
//! sharing `A`'s sparsity. Its reduction (over the dense `j`) "behaves the
//! same" as SpMM's (§2.1, Fig. 4/5) — so the *same* `atomicAddGroup`
//! macro instruction and the same GroupSize tuning apply. This module
//! builds the `{<1/g nnz, ·>, r}`-style SDDMM kernel as LLIR and runs it
//! on the same simulator, demonstrating that segment group is not
//! SpMM-specific.
//!
//! Layout: `g` lanes cooperate on one non-zero; each lane strides the
//! dense `j` dimension by `g`; an r-wide grouped tree reduction combines
//! the partial dot products; lane 0 of each r-group writes back
//! atomically (one output slot per nnz, group-uniform index).

use anyhow::Result;

use crate::compiler::llir::{Kernel, Param, Stmt, Val};
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::Csr;

use super::runner::SpmmRun;

/// Serial oracle: `y[pos] = a.data[pos] * dot(X1[i,:], X2[:,k])`.
///
/// `x1` is row-major `[a.rows × j_dim]`, `x2` row-major `[j_dim × a.cols]`
/// (so `k` indexes `x2`'s columns, matching `A`'s column space).
pub fn sddmm_serial(a: &Csr, x1: &[f32], x2: &[f32], j_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.rows * j_dim);
    assert_eq!(x2.len(), j_dim * a.cols);
    let mut y = vec![0f32; a.nnz()];
    for i in 0..a.rows {
        for p in a.indptr[i] as usize..a.indptr[i + 1] as usize {
            let k = a.indices[p] as usize;
            let mut dot = 0f32;
            for j in 0..j_dim {
                dot += x1[i * j_dim + j] * x2[j * a.cols + k];
            }
            y[p] = a.data[p] * dot;
        }
    }
    y
}

/// FLOPs: 2·nnz·J for the dots + nnz scaling multiplies.
pub fn sddmm_flops(a: &Csr, j_dim: usize) -> u64 {
    (2 * j_dim as u64 + 1) * a.nnz() as u64
}

/// Tunable SDDMM configuration: `g` lanes per nnz, reduction width `r`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SddmmConfig {
    pub j_dim: u32,
    /// Lanes cooperating per non-zero (power of 2, ≤ 32).
    pub g: u32,
    /// Reduction parallelism (GroupSize), `r <= g`.
    pub r: u32,
    /// Threads per block.
    pub p: u32,
}

impl SddmmConfig {
    pub fn new(j_dim: u32, g: u32, r: u32) -> Self {
        SddmmConfig { j_dim, g, r, p: 256 }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.g.is_power_of_two() && self.g <= 32, "g must be a power of 2 <= 32");
        anyhow::ensure!(self.r.is_power_of_two() && self.r <= self.g, "r must be a power of 2 <= g");
        anyhow::ensure!(self.p % self.g == 0, "p must be divisible by g");
        Ok(())
    }

    /// Non-zeros per block.
    pub fn npb(&self) -> u32 {
        self.p / self.g
    }
}

/// Build the grouped SDDMM kernel.
///
/// Buffers: `A2_pos/A2_crd/A_vals` (CSR), `A_rowidx` (COO row per nnz),
/// `X1_vals`, `X2_vals`, `Y_vals` (one slot per nnz); scalars
/// `A1_dimension` (rows), `A2_dimension` (cols), `J_dimension`, `A_nnz`.
pub fn build_kernel(cfg: &SddmmConfig) -> Kernel {
    let i = Val::ConstI;
    let g = cfg.g as i64;
    let npb = cfg.npb() as i64;
    let body = vec![
        Stmt::Comment(format!("sddmm {{<1/{g} nnz>, {}}} — grouped dot-product reduction", cfg.r)),
        Stmt::Decl { var: "lane".into(), init: Val::rem(Val::ThreadIdx, i(g)), float: false },
        Stmt::Decl { var: "e".into(), init: Val::div(Val::ThreadIdx, i(g)), float: false },
        Stmt::Decl {
            var: "pos".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(npb)), Val::var("e")),
            float: false,
        },
        Stmt::If {
            cond: Val::lt(Val::var("pos"), Val::param("A_nnz")),
            then: vec![
                Stmt::Decl { var: "i".into(), init: Val::load("A_rowidx", Val::var("pos")), float: false },
                Stmt::Decl { var: "k".into(), init: Val::load("A2_crd", Val::var("pos")), float: false },
                Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                Stmt::Decl { var: "j".into(), init: Val::var("lane"), float: false },
                Stmt::While {
                    cond: Val::lt(Val::var("j"), Val::param("J_dimension")),
                    body: vec![
                        Stmt::Assign {
                            var: "val".into(),
                            val: Val::add(
                                Val::var("val"),
                                Val::mul(
                                    Val::load(
                                        "X1_vals",
                                        Val::add(
                                            Val::mul(Val::var("i"), Val::param("J_dimension")),
                                            Val::var("j"),
                                        ),
                                    ),
                                    Val::load(
                                        "X2_vals",
                                        Val::add(
                                            Val::mul(Val::var("j"), Val::param("A2_dimension")),
                                            Val::var("k"),
                                        ),
                                    ),
                                ),
                            ),
                        },
                        Stmt::Assign { var: "j".into(), val: Val::add(Val::var("j"), i(g)) },
                    ],
                },
                // scale the partial by A's value up front (distributes over +)
                Stmt::Assign {
                    var: "val".into(),
                    val: Val::mul(Val::var("val"), Val::load("A_vals", Val::var("pos"))),
                },
                // the same macro instruction as SpMM's row kernel (§4.3):
                Stmt::AtomicAddGroup {
                    array: "Y_vals".into(),
                    idx: Val::var("pos"),
                    val: Val::var("val"),
                    group: cfg.r,
                },
            ],
            els: vec![],
        },
    ];
    Kernel {
        name: format!("sddmm_g{}_r{}", cfg.g, cfg.r),
        params: vec![
            Param::i32_array("A2_pos"),
            Param::i32_array("A2_crd"),
            Param::i32_array("A_rowidx"),
            Param::f32_array("A_vals"),
            Param::f32_array("X1_vals"),
            Param::f32_array("X2_vals"),
            Param::f32_array("Y_vals"),
            Param::i32_scalar("A1_dimension"),
            Param::i32_scalar("A2_dimension"),
            Param::i32_scalar("J_dimension"),
            Param::i32_scalar("A_nnz"),
        ],
        body,
        block_dim: cfg.p,
    }
}

/// Run SDDMM on the simulator; returns per-nnz outputs + the report.
pub fn run(
    machine: &Machine,
    cfg: &SddmmConfig,
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<SpmmRun> {
    cfg.validate()?;
    assert_eq!(x1.len(), a.rows * cfg.j_dim as usize);
    assert_eq!(x2.len(), cfg.j_dim as usize * a.cols);
    let kernel = build_kernel(cfg);
    let grid = (a.nnz() as u32).div_ceil(cfg.npb()).max(1);
    let rowidx: Vec<i32> = a.to_coo().row_idx.iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    mem.bind_i32("A2_pos", a.indptr.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A2_crd", a.indices.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A_rowidx", rowidx);
    mem.bind_f32("A_vals", a.data.clone());
    mem.bind_f32("X1_vals", x1.to_vec());
    mem.bind_f32("X2_vals", x2.to_vec());
    mem.bind_f32("Y_vals", vec![0.0; a.nnz().max(1)]);
    mem.bind_scalar("A1_dimension", a.rows as i64);
    mem.bind_scalar("A2_dimension", a.cols as i64);
    mem.bind_scalar("J_dimension", cfg.j_dim as i64);
    mem.bind_scalar("A_nnz", a.nnz() as i64);
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let c = mem.take_f32("Y_vals").expect("Y_vals");
    Ok(SpmmRun { c, report, kernel_name: kernel.name })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len).map(|_| rng.value()).collect()
    }

    fn check(cfg: SddmmConfig, a: &Csr) -> SpmmRun {
        let j = cfg.j_dim as usize;
        let x1 = dense(a.rows * j, 1);
        let x2 = dense(j * a.cols, 2);
        let want = sddmm_serial(a, &x1, &x2, j);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run(&m, &cfg, a, &x1, &x2).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 5e-4, "{}: err {err}", run.kernel_name);
        run
    }

    #[test]
    fn matches_oracle_group_sweep() {
        let a = erdos_renyi(100, 80, 900, 11).to_csr();
        for (g, r) in [(32u32, 32u32), (32, 8), (16, 16), (8, 4), (4, 4), (2, 2)] {
            check(SddmmConfig::new(64, g, r), &a);
        }
    }

    #[test]
    fn matches_oracle_on_skewed_pattern() {
        let a = power_law(128, 128, 1800, 1.9, 13).to_csr();
        check(SddmmConfig::new(32, 16, 8), &a);
    }

    #[test]
    fn j_not_multiple_of_g() {
        // J = 50 with g = 16: tail lanes idle in the last stride
        let a = erdos_renyi(64, 64, 400, 5).to_csr();
        check(SddmmConfig::new(50, 16, 16), &a);
    }

    #[test]
    fn small_r_beats_r32_for_small_j() {
        // J = 8 with g = 32: 24 lanes carry nothing — exactly Fig. 1(b);
        // a narrower reduction group wins
        let a = erdos_renyi(256, 256, 4000, 21).to_csr();
        let wide = check(SddmmConfig::new(8, 32, 32), &a);
        let narrow = check(SddmmConfig::new(8, 32, 8), &a);
        assert!(
            narrow.report.time_s < wide.report.time_s,
            "narrow {} !< wide {}",
            narrow.report.time_s,
            wide.report.time_s
        );
    }

    #[test]
    fn validation() {
        assert!(SddmmConfig::new(64, 12, 4).validate().is_err());
        assert!(SddmmConfig::new(64, 8, 16).validate().is_err());
        assert!(SddmmConfig::new(64, 8, 8).validate().is_ok());
    }

    #[test]
    fn empty_matrix_ok() {
        let a = crate::sparse::Coo::new(8, 8, vec![]).to_csr();
        let m = Machine::new(HwProfile::v100());
        let cfg = SddmmConfig::new(16, 8, 8);
        let run = run(&m, &cfg, &a, &dense(8 * 16, 3), &dense(16 * 8, 4)).unwrap();
        assert!(run.c.iter().all(|&v| v == 0.0));
    }
}
