//! Fused SDDMM→SpMM — the graph-attention chain as one kernel.
//!
//! Graph attention computes `Y = A ⊙ (X1 · X2ᵀ)` (SDDMM, the attention
//! scores on `A`'s sparsity) and immediately `C = Y · B` (SpMM, the
//! aggregation). Run as two kernels, that costs a full materialization of
//! the nnz-sized `Y` plus a *second* traversal of `pos/crd`. The fused
//! schedule ([`Schedule::fused_sddmm_spmm`]) lowers the pair to **one**
//! nnz-split kernel: each nnz-owning lane computes its attention score
//! in-register and feeds it straight into the SpMM segment-group
//! reduction — one pass over the sparse structure, zero intermediate
//! traffic.
//!
//! This module is launch glue only (the kernel is schedule-generated
//! through `compiler::compile`, like every family): a two-stage serial
//! oracle, a FLOP count, and the simulator run path.

use anyhow::Result;

use crate::compiler::schedule::Schedule;
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::Csr;

use super::cpu_ref::spmm_serial;
use super::runner::SpmmRun;
use super::sddmm::sddmm_serial;

pub use crate::compiler::schedule::FusedConfig;

/// Two-stage serial oracle: materialize the SDDMM output
/// `y[pos] = a.data[pos] · dot(X1[i,:], X2[:,f])`, then SpMM the rescaled
/// matrix against `B`. This is exactly the computation the fused kernel
/// must reproduce without ever materializing `y`.
///
/// `x1` is row-major `[a.rows × j_dim]`, `x2` row-major `[j_dim × a.cols]`,
/// `b` row-major `[a.cols × n]`; the result is row-major `[a.rows × n]`.
pub fn fused_serial(a: &Csr, x1: &[f32], x2: &[f32], b: &[f32], j_dim: usize, n: usize) -> Vec<f32> {
    let y = sddmm_serial(a, x1, x2, j_dim);
    let scaled = Csr { data: y, ..a.clone() };
    spmm_serial(&scaled, b, n)
}

/// FLOPs of the fused chain: the SDDMM dots + scaling `(2J+1)·nnz` plus
/// the SpMM multiply-adds `2·nnz·n`.
pub fn fused_flops(a: &Csr, j_dim: usize, n: usize) -> u64 {
    (2 * j_dim as u64 + 1) * a.nnz() as u64 + 2 * a.nnz() as u64 * n as u64
}

/// Run the fused kernel on the simulator; returns row-major `[rows × n]`
/// output plus the report.
///
/// Binds the union of the two stages' buffers minus the intermediate:
/// `i_blockStarts/A2_pos/A2_crd/A_vals` (CSR + search windows),
/// `X1_vals/X2_vals` (the producer's dense factors), `B_vals/C_vals` (the
/// consumer's dense operand and padded output); scalars `A1_dimension`,
/// `A2_dimension`, `B2_dimension`, `J_dimension`. No `Y_vals` exists to
/// bind — the intermediate never touches memory.
pub fn run(
    machine: &Machine,
    cfg: &FusedConfig,
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
    b: &[f32],
) -> Result<SpmmRun> {
    let j = cfg.j_dim as usize;
    let n = cfg.n as usize;
    assert_eq!(x1.len(), a.rows * j, "X1 must be rows x j_dim");
    assert_eq!(x2.len(), j * a.cols, "X2 must be j_dim x cols");
    assert_eq!(b.len(), a.cols * n, "B must be cols x n");
    let sched = Schedule::fused_sddmm_spmm(*cfg);
    let kernel = crate::compiler::compile(&sched.algebra(), &sched)?;
    let nnzb = cfg.npb() as usize;
    let grid = a.nnz().div_ceil(nnzb).max(1) as u32;
    let starts: Vec<i32> = a.block_starts(nnzb).iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    mem.bind_i32("i_blockStarts", starts);
    mem.bind_i32("A2_pos", a.indptr.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A2_crd", a.indices.iter().map(|&x| x as i32).collect());
    mem.bind_f32("A_vals", a.data.clone());
    mem.bind_f32("X1_vals", x1.to_vec());
    mem.bind_f32("X2_vals", x2.to_vec());
    mem.bind_f32("B_vals", b.to_vec());
    // one pad row: zero extension can write to row index `rows`
    mem.bind_f32("C_vals", vec![0.0; (a.rows + 1) * n]);
    mem.bind_scalar("A1_dimension", a.rows as i64);
    mem.bind_scalar("A2_dimension", a.cols as i64);
    mem.bind_scalar("B2_dimension", n as i64);
    mem.bind_scalar("J_dimension", cfg.j_dim as i64);
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let mut c = mem.take_f32("C_vals").expect("C_vals");
    c.truncate(a.rows * n); // drop the zero-extension pad row
    Ok(SpmmRun { c, report, kernel_name: kernel.name })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len).map(|_| rng.value()).collect()
    }

    fn check(cfg: FusedConfig, a: &Csr) -> SpmmRun {
        let j = cfg.j_dim as usize;
        let n = cfg.n as usize;
        let x1 = dense(a.rows * j, 1);
        let x2 = dense(j * a.cols, 2);
        let b = dense(a.cols * n, 3);
        let want = fused_serial(a, &x1, &x2, &b, j, n);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run(&m, &cfg, a, &x1, &x2, &b).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 5e-4, "{}: err {err}", run.kernel_name);
        run
    }

    #[test]
    fn matches_two_stage_oracle_group_sweep() {
        let a = erdos_renyi(100, 80, 900, 11).to_csr();
        for r in [2u32, 4, 8, 16, 32] {
            check(FusedConfig::new(32, 4, 4, r), &a);
        }
    }

    #[test]
    fn matches_oracle_on_skewed_pattern() {
        let a = power_law(128, 128, 1800, 1.9, 13).to_csr();
        check(FusedConfig::new(16, 8, 4, 8), &a);
    }

    #[test]
    fn empty_rows_and_hubs_handled() {
        // hub matrix: row 0 has many nnz, most rows empty
        let mut triplets: Vec<(u32, u32, f32)> = (0..64u32).map(|c| (0u32, c, 1.0f32)).collect();
        triplets.push((63, 0, 2.0));
        let a = crate::sparse::Coo::new(64, 64, triplets).to_csr();
        check(FusedConfig::new(8, 4, 4, 32), &a);
    }

    #[test]
    fn empty_matrix_ok() {
        let a = crate::sparse::Coo::new(8, 8, vec![]).to_csr();
        let m = Machine::new(HwProfile::v100());
        let cfg = FusedConfig::new(16, 4, 4, 8);
        let run =
            run(&m, &cfg, &a, &dense(8 * 16, 3), &dense(16 * 8, 4), &dense(8 * 4, 5)).unwrap();
        assert!(run.c.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn flops_count_both_stages() {
        let a = erdos_renyi(32, 32, 100, 9).to_csr();
        let z = a.nnz() as u64;
        assert_eq!(fused_flops(&a, 16, 4), (2 * 16 + 1) * z + 2 * z * 4);
    }
}
