//! dgSPARSE re-implementation: the `RB+PR+RM` SpMM kernel family with the
//! full §7.2 parameter space. Historically this was hand-authored LLIR (a
//! "library kernel"); it is now **schedule-generated** — the row-balanced
//! /partial-result discipline is a first-class
//! [`ReductionStrategy::RowBalancedPartial`] and the kernel is produced by
//! [`crate::compiler::lower`](mod@crate::compiler::lower) from [`Schedule::dgsparse_rb_pr`]. This
//! module only binds buffers (including the launch-time `workerDimR`
//! scalar), picks the grid, and launches; it is priced by the same
//! simulator as every other compiler output.
//!
//! Parameters (§7.2): a block processes `tileSz` real columns; `workerSz`
//! threads process one vectorized column (of `coarsenSz` real columns) of
//! one sparse row; `groupSz` threads synchronize (the atomic-parallelism
//! tuning axis); `blockSz` threads per block; `workerDimR` is the total
//! row parallelism — when it is less than the number of rows each worker
//! loops over rows with stride `workerDimR`.
//!
//! Stock dgSPARSE configuration: `tileSz = workerSz = groupSz = 32`,
//! `blockSz = 256`, `workerDimR = #rows`, `coarsenSz` from N's divisibility.
//!
//! [`ReductionStrategy::RowBalancedPartial`]: crate::compiler::cin::ReductionStrategy::RowBalancedPartial

use anyhow::Result;

use crate::compiler::schedule::Schedule;
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::Csr;

use super::runner::{bind_spmm, SpmmRun};

pub use crate::compiler::schedule::DgConfig;

/// Run the dgSPARSE kernel on the simulator. The kernel comes from the
/// shared compile pipeline; `workerDimR` is resolved here from the
/// matrix's row count and bound as a scalar parameter.
pub fn run(machine: &Machine, cfg: &DgConfig, a: &Csr, b: &[f32]) -> Result<SpmmRun> {
    let n = cfg.n as usize;
    let sched = Schedule::dgsparse_rb_pr(*cfg);
    let kernel = crate::compiler::compile(&sched.algebra(), &sched)?;
    let grid = cfg.grid(a.rows);
    let mut mem = DeviceMemory::new();
    bind_spmm(&mut mem, a, b, n);
    mem.bind_scalar("workerDimR", cfg.worker_dim_r(a.rows) as i64);
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let mut c = mem.take_f32("C_vals").expect("C_vals");
    c.truncate(a.rows * n);
    Ok(SpmmRun { c, report, kernel_name: kernel.name })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::{max_rel_err, spmm_serial};
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn check(cfg: DgConfig, a: &Csr) -> SpmmRun {
        cfg.validate().unwrap();
        let n = cfg.n as usize;
        let mut rng = SplitMix64::new(11);
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let want = spmm_serial(a, &b, n);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run(&m, &cfg, a, &b).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 1e-4, "{}: err {err}", run.kernel_name);
        run
    }

    #[test]
    fn stock_config_correct_n4() {
        let a = erdos_renyi(128, 128, 1200, 21).to_csr();
        check(DgConfig::stock(4), &a);
    }

    #[test]
    fn stock_config_correct_n16_n64() {
        let a = erdos_renyi(96, 96, 800, 5).to_csr();
        check(DgConfig::stock(16), &a);
        check(DgConfig::stock(64), &a);
    }

    #[test]
    fn tuned_configs_correct() {
        let a = power_law(128, 128, 1500, 1.6, 9).to_csr();
        // paper's best-static shapes, e.g. <8, 256, 8, 1/2>
        for (g, b, t, w) in [(8u32, 256u32, 8u32, 0.5f64), (4, 256, 8, 0.5), (8, 512, 32, 1.0), (2, 128, 8, 0.25)] {
            let cfg = DgConfig {
                n: 16,
                group_sz: g,
                block_sz: b,
                tile_sz: t,
                worker_dim_r_frac: w,
                worker_sz: 32,
                coarsen_sz: 4,
            };
            check(cfg, &a);
        }
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = DgConfig::stock(4);
        c.group_sz = 12;
        assert!(c.validate().is_err());
        let mut c = DgConfig::stock(4);
        c.group_sz = 32;
        c.worker_sz = 8;
        assert!(c.validate().is_err(), "groupSz > workerSz must be rejected");
        let mut c = DgConfig::stock(4);
        c.block_sz = 2048;
        assert!(c.validate().is_err());
        let mut c = DgConfig::stock(4);
        c.tile_sz = 16;
        c.group_sz = 32;
        assert!(c.validate().is_err(), "tileSz < groupSz must be rejected");
    }

    #[test]
    fn derived_shapes_match_paper_formulas() {
        let c = DgConfig::stock(128);
        assert_eq!(c.coarsen_sz, 4);
        // blockDim.x = min(128,32)/4*32 = 256
        assert_eq!(c.block_dim_x(), 256);
        assert_eq!(c.rows_per_block(), 1);
        assert_eq!(c.col_tiles(), 4);
    }

    #[test]
    fn small_group_beats_stock_on_short_rows() {
        // every row has 2 nnz: stock groupSz=32 wastes the whole warp's
        // synchronization on 2 useful lanes (Fig. 1b)
        let n = 256usize;
        let mut triplets = Vec::new();
        for r in 0..n as u32 {
            triplets.push((r, r % n as u32, 1.0f32));
            triplets.push((r, (r * 7 + 1) % n as u32, -0.5f32));
        }
        let a = crate::sparse::Coo::new(n, n, triplets).to_csr();
        let stock = check(DgConfig::stock(4), &a);
        let tuned = check(
            DgConfig { group_sz: 4, tile_sz: 8, worker_dim_r_frac: 1.0, ..DgConfig::stock(4) },
            &a,
        );
        assert!(
            tuned.report.time_s < stock.report.time_s,
            "tuned {} !< stock {}",
            tuned.report.time_s,
            stock.report.time_s
        );
    }
}
