//! dgSPARSE re-implementation: the `RB+PR+RM` SpMM kernel family with the
//! full §7.2 parameter space, as hand-authored LLIR (a "library kernel",
//! not schedule-generated — mirroring how dgSPARSE is a hand-written CUDA
//! library). Priced by the same simulator as the compiler output.
//!
//! Parameters (§7.2): a block processes `tileSz` real columns; `workerSz`
//! threads process one vectorized column (of `coarsenSz` real columns) of
//! one sparse row; `groupSz` threads synchronize (the atomic-parallelism
//! tuning axis); `blockSz` threads per block; `workerDimR` is the total
//! row parallelism — when it is less than the number of rows each worker
//! loops over rows with stride `workerDimR`.
//!
//! Stock dgSPARSE configuration: `tileSz = workerSz = groupSz = 32`,
//! `blockSz = 256`, `workerDimR = #rows`, `coarsenSz` from N's divisibility.

use anyhow::{bail, Result};

use crate::compiler::llir::{Kernel, Param, Stmt, Val};
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::Csr;

use super::runner::{bind_spmm, SpmmRun};

/// One point in the dgSPARSE tuning space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DgConfig {
    pub n: u32,
    pub group_sz: u32,
    pub block_sz: u32,
    pub tile_sz: u32,
    /// Row parallelism as a fraction of #rows: `workerDimR = frac * rows`
    /// (the paper tunes powers/reciprocal-powers of 2 of the original).
    pub worker_dim_r_frac: f64,
    pub worker_sz: u32,
    pub coarsen_sz: u32,
}

impl DgConfig {
    /// The library's default configuration for a given N (§7.2).
    pub fn stock(n: u32) -> Self {
        DgConfig {
            n,
            group_sz: 32,
            block_sz: 256,
            tile_sz: 32,
            worker_dim_r_frac: 1.0,
            worker_sz: 32,
            coarsen_sz: if n % 4 == 0 { 4 } else if n % 2 == 0 { 2 } else { 1 },
        }
    }

    /// Vectorized columns per block.
    pub fn vcols(&self) -> u32 {
        self.n.min(self.tile_sz) / self.coarsen_sz
    }

    /// blockDim.x = min(N, tileSz)/coarsenSz * workerSz (§7.2).
    pub fn block_dim_x(&self) -> u32 {
        self.vcols() * self.worker_sz
    }

    pub fn rows_per_block(&self) -> u32 {
        (self.block_sz / self.block_dim_x()).max(1)
    }

    pub fn col_tiles(&self) -> u32 {
        self.n.div_ceil(self.tile_sz)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.group_sz.is_power_of_two() || self.group_sz > 32 {
            bail!("groupSz must be a power of 2 <= 32");
        }
        if self.group_sz > self.worker_sz {
            bail!("groupSz must be <= workerSz (a group must not straddle rows)");
        }
        if !self.tile_sz.is_power_of_two() || self.tile_sz < self.group_sz {
            bail!("tileSz must be a power of 2 >= groupSz");
        }
        if self.n.min(self.tile_sz) % self.coarsen_sz != 0 {
            bail!("coarsenSz must divide min(N, tileSz)");
        }
        if self.block_dim_x() > self.block_sz {
            bail!(
                "blockDim.x {} exceeds blockSz {}",
                self.block_dim_x(),
                self.block_sz
            );
        }
        if self.block_sz > 1024 {
            bail!("blockSz must be <= 1024");
        }
        if self.worker_dim_r_frac <= 0.0 {
            bail!("workerDimR fraction must be positive");
        }
        Ok(())
    }

    /// Total row-worker parallelism for a matrix with `rows` rows,
    /// rounded **up to whole blocks** — the row-loop stride must equal the
    /// number of actually-spawned workers or trailing workers would
    /// double-count rows.
    pub fn worker_dim_r(&self, rows: usize) -> u32 {
        let rpb = self.rows_per_block();
        let want = ((rows as f64 * self.worker_dim_r_frac).round() as u32).max(rpb);
        want.div_ceil(rpb) * rpb
    }

    /// Launch grid: row blocks × column tiles.
    pub fn grid(&self, rows: usize) -> u32 {
        let row_blocks = self.worker_dim_r(rows) / self.rows_per_block();
        row_blocks * self.col_tiles()
    }
}

/// Build the RB+PR+RM kernel for a config.
///
/// Thread decomposition (within a block of `blockSz` threads):
/// `lane = tid % workerSz`, `vcol = (tid / workerSz) % vcols`,
/// `rowb = tid / blockDim.x`. Block decomposition:
/// `col_block = blockIdx % colTiles`, `row_block = blockIdx / colTiles`.
/// Each worker strides its rows by `workerDimR` (RB = row balance) and its
/// nnz by `workerSz`; writeback is a grouped parallel reduction of width
/// `groupSz` (PR); B/C are row-major (RM).
pub fn build_kernel(cfg: &DgConfig, rows: usize) -> Kernel {
    let i = Val::ConstI;
    let worker_dim_r = cfg.worker_dim_r(rows) as i64;
    let vcols = cfg.vcols() as i64;
    let worker_sz = cfg.worker_sz as i64;
    let rpb = cfg.rows_per_block() as i64;
    let col_tiles = cfg.col_tiles() as i64;
    let coarsen = cfg.coarsen_sz as i64;
    let tile = cfg.tile_sz as i64;

    let body = vec![
        Stmt::Comment(format!(
            "dgSPARSE RB+PR+RM <groupSz={}, blockSz={}, tileSz={}, workerDimR={}x{}>",
            cfg.group_sz, cfg.block_sz, cfg.tile_sz, cfg.worker_dim_r_frac, rows
        )),
        Stmt::Decl { var: "lane".into(), init: Val::rem(Val::ThreadIdx, i(worker_sz)), float: false },
        Stmt::Decl {
            var: "vcol".into(),
            init: Val::rem(Val::div(Val::ThreadIdx, i(worker_sz)), i(vcols)),
            float: false,
        },
        Stmt::Decl {
            var: "rowb".into(),
            init: Val::div(Val::ThreadIdx, i(worker_sz * vcols)),
            float: false,
        },
        Stmt::Decl { var: "col_block".into(), init: Val::rem(Val::BlockIdx, i(col_tiles)), float: false },
        Stmt::Decl { var: "row_block".into(), init: Val::div(Val::BlockIdx, i(col_tiles)), float: false },
        Stmt::Decl {
            var: "i".into(),
            init: Val::add(Val::mul(Val::var("row_block"), i(rpb)), Val::var("rowb")),
            float: false,
        },
        // RB: loop rows with stride workerDimR until exhausted
        Stmt::While {
            cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
            body: vec![
                Stmt::For {
                    var: "cc".into(),
                    lo: i(0),
                    hi: i(coarsen),
                    step: i(1),
                    body: vec![
                        Stmt::Decl {
                            var: "k".into(),
                            init: Val::add(
                                Val::mul(Val::var("col_block"), i(tile)),
                                Val::add(Val::mul(Val::var("vcol"), i(coarsen)), Val::var("cc")),
                            ),
                            float: false,
                        },
                        Stmt::If {
                            cond: Val::lt(Val::var("k"), Val::param("B2_dimension")),
                            then: vec![
                                Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                                Stmt::Decl {
                                    var: "jpos".into(),
                                    init: Val::add(Val::load("A2_pos", Val::var("i")), Val::var("lane")),
                                    float: false,
                                },
                                Stmt::While {
                                    cond: Val::lt(
                                        Val::var("jpos"),
                                        Val::load("A2_pos", Val::add(Val::var("i"), i(1))),
                                    ),
                                    body: vec![
                                        Stmt::Assign {
                                            var: "val".into(),
                                            val: Val::add(
                                                Val::var("val"),
                                                Val::mul(
                                                    Val::load("A_vals", Val::var("jpos")),
                                                    Val::load(
                                                        "B_vals",
                                                        Val::add(
                                                            Val::mul(
                                                                Val::load("A2_crd", Val::var("jpos")),
                                                                Val::param("B2_dimension"),
                                                            ),
                                                            Val::var("k"),
                                                        ),
                                                    ),
                                                ),
                                            ),
                                        },
                                        Stmt::Assign {
                                            var: "jpos".into(),
                                            val: Val::add(Val::var("jpos"), i(worker_sz)),
                                        },
                                    ],
                                },
                                Stmt::AtomicAddGroup {
                                    array: "C_vals".into(),
                                    idx: Val::add(
                                        Val::mul(Val::var("i"), Val::param("B2_dimension")),
                                        Val::var("k"),
                                    ),
                                    val: Val::var("val"),
                                    group: cfg.group_sz,
                                },
                            ],
                            els: vec![],
                        },
                    ],
                },
                Stmt::Assign { var: "i".into(), val: Val::add(Val::var("i"), i(worker_dim_r)) },
            ],
        },
    ];

    Kernel {
        name: format!(
            "dg_rb_pr_rm_g{}_b{}_t{}_w{}",
            cfg.group_sz, cfg.block_sz, cfg.tile_sz, cfg.worker_dim_r_frac
        ),
        params: vec![
            Param::i32_array("A2_pos"),
            Param::i32_array("A2_crd"),
            Param::f32_array("A_vals"),
            Param::f32_array("B_vals"),
            Param::f32_array("C_vals"),
            Param::i32_scalar("A1_dimension"),
            Param::i32_scalar("B2_dimension"),
        ],
        body,
        block_dim: cfg.block_sz,
    }
}

/// Run the dgSPARSE kernel on the simulator.
pub fn run(machine: &Machine, cfg: &DgConfig, a: &Csr, b: &[f32]) -> Result<SpmmRun> {
    cfg.validate()?;
    let n = cfg.n as usize;
    let kernel = build_kernel(cfg, a.rows);
    let grid = cfg.grid(a.rows);
    let mut mem = DeviceMemory::new();
    bind_spmm(&mut mem, a, b, n);
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let mut c = mem.take_f32("C_vals").expect("C_vals");
    c.truncate(a.rows * n);
    Ok(SpmmRun { c, report, kernel_name: kernel.name })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::{max_rel_err, spmm_serial};
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn check(cfg: DgConfig, a: &Csr) -> SpmmRun {
        cfg.validate().unwrap();
        let n = cfg.n as usize;
        let mut rng = SplitMix64::new(11);
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let want = spmm_serial(a, &b, n);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run(&m, &cfg, a, &b).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 1e-4, "{}: err {err}", run.kernel_name);
        run
    }

    #[test]
    fn stock_config_correct_n4() {
        let a = erdos_renyi(128, 128, 1200, 21).to_csr();
        check(DgConfig::stock(4), &a);
    }

    #[test]
    fn stock_config_correct_n16_n64() {
        let a = erdos_renyi(96, 96, 800, 5).to_csr();
        check(DgConfig::stock(16), &a);
        check(DgConfig::stock(64), &a);
    }

    #[test]
    fn tuned_configs_correct() {
        let a = power_law(128, 128, 1500, 1.6, 9).to_csr();
        // paper's best-static shapes, e.g. <8, 256, 8, 1/2>
        for (g, b, t, w) in [(8u32, 256u32, 8u32, 0.5f64), (4, 256, 8, 0.5), (8, 512, 32, 1.0), (2, 128, 8, 0.25)] {
            let cfg = DgConfig {
                n: 16,
                group_sz: g,
                block_sz: b,
                tile_sz: t,
                worker_dim_r_frac: w,
                worker_sz: 32,
                coarsen_sz: 4,
            };
            check(cfg, &a);
        }
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = DgConfig::stock(4);
        c.group_sz = 12;
        assert!(c.validate().is_err());
        let mut c = DgConfig::stock(4);
        c.group_sz = 32;
        c.worker_sz = 8;
        assert!(c.validate().is_err(), "groupSz > workerSz must be rejected");
        let mut c = DgConfig::stock(4);
        c.block_sz = 2048;
        assert!(c.validate().is_err());
        let mut c = DgConfig::stock(4);
        c.tile_sz = 16;
        c.group_sz = 32;
        assert!(c.validate().is_err(), "tileSz < groupSz must be rejected");
    }

    #[test]
    fn derived_shapes_match_paper_formulas() {
        let c = DgConfig::stock(128);
        assert_eq!(c.coarsen_sz, 4);
        // blockDim.x = min(128,32)/4*32 = 256
        assert_eq!(c.block_dim_x(), 256);
        assert_eq!(c.rows_per_block(), 1);
        assert_eq!(c.col_tiles(), 4);
    }

    #[test]
    fn small_group_beats_stock_on_short_rows() {
        // every row has 2 nnz: stock groupSz=32 wastes the whole warp's
        // synchronization on 2 useful lanes (Fig. 1b)
        let n = 256usize;
        let mut triplets = Vec::new();
        for r in 0..n as u32 {
            triplets.push((r, r % n as u32, 1.0f32));
            triplets.push((r, (r * 7 + 1) % n as u32, -0.5f32));
        }
        let a = crate::sparse::Coo::new(n, n, triplets).to_csr();
        let stock = check(DgConfig::stock(4), &a);
        let tuned = check(
            DgConfig { group_sz: 4, tile_sz: 8, worker_dim_r_frac: 1.0, ..DgConfig::stock(4) },
            &a,
        );
        assert!(
            tuned.report.time_s < stock.report.time_s,
            "tuned {} !< stock {}",
            tuned.report.time_s,
            stock.report.time_s
        );
    }
}
