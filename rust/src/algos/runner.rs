//! Launch glue: bind a CSR matrix into simulator memory, pick the grid for
//! each algorithm family, run, and extract `C` plus the cost report.

use anyhow::Result;

use crate::compiler::compile;
use crate::compiler::llir::Kernel;
use crate::compiler::schedule::{Family, Schedule};
use crate::sim::{DeviceMemory, KernelReport, Machine};
use crate::sparse::Csr;

/// Result of one simulated SpMM launch.
#[derive(Debug, Clone)]
pub struct SpmmRun {
    /// Row-major `[rows × n]` output (the zero-extension pad row dropped).
    pub c: Vec<f32>,
    pub report: KernelReport,
    pub kernel_name: String,
}

/// Bind the standard TACO-named buffers. `C_vals` gets one pad row
/// (zero extension can write to row index `rows`).
pub fn bind_spmm(mem: &mut DeviceMemory, a: &Csr, b: &[f32], n: usize) {
    assert_eq!(b.len(), a.cols * n, "B must be cols x n");
    mem.bind_i32("A2_pos", a.indptr.iter().map(|&x| x as i32).collect());
    mem.bind_i32("A2_crd", a.indices.iter().map(|&x| x as i32).collect());
    mem.bind_f32("A_vals", a.data.clone());
    mem.bind_f32("B_vals", b.to_vec());
    mem.bind_f32("C_vals", vec![0.0; (a.rows + 1) * n]);
    mem.bind_scalar("A1_dimension", a.rows as i64);
    mem.bind_scalar("B2_dimension", n as i64);
}

/// Grid size + required `i_blockStarts` for an SpMM schedule family.
/// (SDDMM and dgSPARSE schedules bind different buffers and compute their
/// grids in their own run paths.)
pub fn launch_shape(schedule: &Schedule, a: &Csr) -> (u32, Option<Vec<i32>>) {
    let cfg = schedule.spmm_config().expect("launch_shape serves the SpMM families");
    let kchunks = cfg.kchunks();
    match schedule.classify().expect("classified") {
        Family::NnzGroup => {
            let nnzb = (cfg.p / kchunks) as usize;
            let grid = a.nnz().div_ceil(nnzb).max(1) as u32;
            let starts = a.block_starts(nnzb).iter().map(|&x| x as i32).collect();
            (grid, Some(starts))
        }
        Family::NnzSerial => {
            let nnzb = (cfg.g * cfg.p / kchunks) as usize;
            let grid = a.nnz().div_ceil(nnzb).max(1) as u32;
            let starts = a.block_starts(nnzb).iter().map(|&x| x as i32).collect();
            (grid, Some(starts))
        }
        Family::RowSerial => {
            let rpb = (cfg.x * cfg.p / kchunks) as usize;
            (a.rows.div_ceil(rpb).max(1) as u32, None)
        }
        Family::RowGroup => {
            let rpb = (cfg.p / (cfg.g * kchunks)) as usize;
            (a.rows.div_ceil(rpb.max(1)).max(1) as u32, None)
        }
        Family::SddmmGroup
        | Family::DgRowBalanced
        | Family::MttkrpGroup
        | Family::TtmGroup
        | Family::FusedSddmmSpmm => {
            unreachable!("spmm_config() above rejects non-SpMM schedules")
        }
    }
}

/// Compile the schedule against its stated algebra, launch it on
/// `machine`, return C + report.
pub fn run_schedule(machine: &Machine, schedule: &Schedule, a: &Csr, b: &[f32]) -> Result<SpmmRun> {
    let n = schedule.spmm_config().expect("run_schedule serves the SpMM families").n as usize;
    let kernel = compile(&schedule.algebra(), schedule)?;
    run_kernel(machine, &kernel, schedule, a, b, n)
}

/// Launch an already-lowered kernel (used by the tuner to cache lowering).
pub fn run_kernel(
    machine: &Machine,
    kernel: &Kernel,
    schedule: &Schedule,
    a: &Csr,
    b: &[f32],
    n: usize,
) -> Result<SpmmRun> {
    let (grid, starts) = launch_shape(schedule, a);
    let mut mem = DeviceMemory::new();
    bind_spmm(&mut mem, a, b, n);
    if let Some(s) = starts {
        mem.bind_i32("i_blockStarts", s);
    }
    let report = machine.launch(kernel, grid, &mut mem)?;
    let mut c = mem.take_f32("C_vals").expect("C_vals");
    c.truncate(a.rows * n); // drop the zero-extension pad row
    Ok(SpmmRun { c, report, kernel_name: kernel.name.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::{max_rel_err, spmm_serial};
    use crate::compiler::schedule::SpmmConfig;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn check(schedule: Schedule, a: &Csr) {
        let n = schedule.spmm_config().unwrap().n as usize;
        let mut rng = SplitMix64::new(99);
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let want = spmm_serial(a, &b, n);
        let m = Machine::new(HwProfile::rtx3090());
        let run = run_schedule(&m, &schedule, a, &b).unwrap();
        let err = max_rel_err(&run.c, &want);
        assert!(err < 1e-4, "{}: max rel err {err}", run.kernel_name);
    }

    fn cfg(n: u32, c: u32) -> SpmmConfig {
        SpmmConfig { n, c, p: 256, g: 32, r: 32, x: 1 }
    }

    #[test]
    fn all_families_match_oracle_on_er() {
        let a = erdos_renyi(200, 150, 1500, 42).to_csr();
        check(Schedule::taco_nnz_serial(cfg(4, 4)), &a);
        check(Schedule::taco_row_serial(cfg(4, 4)), &a);
        check(Schedule::sgap_row_group(cfg(4, 4), 8), &a);
        check(Schedule::sgap_nnz_group(cfg(4, 4), 32), &a);
    }

    #[test]
    fn families_match_oracle_on_skewed() {
        let a = power_law(256, 256, 4000, 1.8, 7).to_csr();
        for r in [2u32, 8, 32] {
            check(Schedule::sgap_nnz_group(cfg(4, 4), r), &a);
            check(Schedule::sgap_row_group(cfg(4, 4), r.min(32)), &a);
        }
    }

    #[test]
    fn wider_n_with_coarsening() {
        let a = erdos_renyi(128, 128, 1000, 3).to_csr();
        check(Schedule::taco_row_serial(cfg(16, 4)), &a);
        check(Schedule::sgap_nnz_group(cfg(16, 4), 16), &a);
        check(Schedule::sgap_row_group(cfg(16, 4), 4), &a);
        check(Schedule::taco_nnz_serial(cfg(16, 4)), &a);
    }

    #[test]
    fn empty_rows_handled() {
        // hub matrix: row 0 has many nnz, most rows empty
        let mut triplets: Vec<(u32, u32, f32)> = (0..64u32).map(|c| (0u32, c, 1.0f32)).collect();
        triplets.push((63, 0, 2.0));
        let a = crate::sparse::Coo::new(64, 64, triplets).to_csr();
        check(Schedule::sgap_nnz_group(cfg(4, 4), 32), &a);
        check(Schedule::taco_nnz_serial(cfg(4, 4)), &a);
        check(Schedule::sgap_row_group(cfg(4, 4), 32), &a);
    }

    #[test]
    fn tiny_matrix_single_block() {
        let a = erdos_renyi(8, 8, 12, 5).to_csr();
        check(Schedule::sgap_nnz_group(cfg(4, 4), 8), &a);
        check(Schedule::taco_row_serial(cfg(4, 4)), &a);
    }
}
