//! Serial CPU SpMM — the golden numeric oracle.

use crate::sparse::Csr;

/// `C = A · B` with `A` CSR `[rows × cols]`, `B` row-major `[cols × n]`.
/// Returns row-major `C [rows × n]`.
pub fn spmm_serial(a: &Csr, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(b.len(), a.cols * n, "B shape mismatch");
    let mut c = vec![0f32; a.rows * n];
    for i in 0..a.rows {
        for p in a.indptr[i] as usize..a.indptr[i + 1] as usize {
            let j = a.indices[p] as usize;
            let v = a.data[p];
            let brow = &b[j * n..(j + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for k in 0..n {
                crow[k] += v * brow[k];
            }
        }
    }
    c
}

/// FLOP count of SpMM (2 per nnz per dense column).
pub fn spmm_flops(a: &Csr, n: usize) -> u64 {
    2 * a.nnz() as u64 * n as u64
}

/// Max relative error between two row-major matrices (for tolerance checks).
pub fn max_rel_err(got: &[f32], want: &[f32]) -> f32 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(&g, &w)| {
            let denom = w.abs().max(1.0);
            (g - w).abs() / denom
        })
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn matches_dense_matmul() {
        let a = Coo::new(3, 4, vec![(0, 1, 2.0), (1, 3, -1.0), (2, 0, 0.5), (2, 3, 4.0)]).to_csr();
        let b: Vec<f32> = (0..8).map(|i| i as f32).collect(); // 4x2
        let c = spmm_serial(&a, &b, 2);
        // dense check
        let ad = a.to_dense();
        for i in 0..3 {
            for k in 0..2 {
                let want: f32 = (0..4).map(|j| ad[i][j] * b[j * 2 + k]).sum();
                assert_eq!(c[i * 2 + k], want);
            }
        }
    }

    #[test]
    fn flops_counts() {
        let a = Coo::new(2, 2, vec![(0, 0, 1.0), (1, 1, 1.0)]).to_csr();
        assert_eq!(spmm_flops(&a, 8), 32);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!(max_rel_err(&[1.0], &[1.1]) > 0.05);
    }
}
