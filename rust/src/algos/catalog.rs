//! The compiled-plan catalog — the one vocabulary shared by the tuner,
//! the benches, the CLI, and the coordinator's plan cache.
//!
//! An [`Algo`] names an executable kernel point of *any* kind the system
//! serves: the four SpMM schedule families, the dgSPARSE RB+PR library
//! shape, the grouped SDDMM of §4.3, and the COO-3 MTTKRP/TTM segment
//! kernels that complete the §2.1 quartet. Every variant resolves to a
//! [`Schedule`] and compiles through `compiler::compile` against its
//! stated algebra — there are no bespoke kernel constructions behind the
//! catalog.

use anyhow::Result;

use crate::compiler::schedule::{Schedule, SpmmConfig};
use crate::compiler::spaces::AtomicPoint;
use crate::sim::Machine;
use crate::sparse::coo3::Coo3;
use crate::sparse::Csr;

use super::cpu_ref::spmm_flops;
use super::dgsparse::{self, DgConfig};
use super::fused::{self, fused_flops, FusedConfig};
use super::mttkrp::{self, mttkrp_flops, ttm_flops, MttkrpConfig, TtmConfig};
use super::runner::{run_schedule, SpmmRun};
use super::sddmm::{self, sddmm_flops, SddmmConfig};

/// An executable compiled-plan point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algo {
    /// `{<g nnz, c col>, 1}` — original TACO (Listing 3).
    TacoNnzSerial { g: u32, c: u32 },
    /// `{<x row, c col>, 1}` — original TACO (Listing 4).
    TacoRowSerial { x: u32, c: u32 },
    /// `{<1/g row, c col>, r}` — Sgap grouped parallel reduction.
    SgapRowGroup { g: u32, c: u32, r: u32 },
    /// `{<1 nnz, c col>, r}` — Sgap grouped segment reduction.
    SgapNnzGroup { c: u32, r: u32 },
    /// dgSPARSE RB+PR+RM — schedule-generated row-balanced shape.
    Dg(DgConfig),
    /// Grouped SDDMM `{<1/g nnz>, r}` (§4.3) — the dense-`j` dot
    /// reduction per non-zero; runs via [`Algo::run_sddmm`].
    Sddmm(SddmmConfig),
    /// Grouped MTTKRP `{<1 nnz, c col>, r}` (Eq. 2a) — COO-3 segment
    /// reduction keyed by output row; runs via [`Algo::run_mttkrp`].
    Mttkrp(MttkrpConfig),
    /// Grouped TTM `{<1 nnz, c col>, r}` (Eq. 2b) — COO-3 segment
    /// reduction keyed by the leading fiber; runs via [`Algo::run_ttm`].
    Ttm(TtmConfig),
    /// Fused SDDMM→SpMM `{<1 nnz, c col>, r}` — the attention chain as
    /// one kernel: producer dot in-register, consumer segment reduction,
    /// one pass over `pos/crd`; runs via [`Algo::run_fused`].
    FusedSddmmSpmm(FusedConfig),
    /// Per-band hybrid SpMM: rows split into nnz-balanced degree bands
    /// (`sparse::partition`), each band served by its own compiler-family
    /// point — the non-uniform group-size application §3 implies but a
    /// single TACO-style plan can't express.
    Composite(CompositeConfig),
}

/// One band's plan inside a composite — restricted to the four SpMM
/// compiler families so [`Algo`] stays `Copy` (no recursive boxing) and a
/// band can never nest another composite or a non-SpMM kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BandAlgo {
    TacoNnzSerial { g: u32, c: u32 },
    TacoRowSerial { x: u32, c: u32 },
    SgapRowGroup { g: u32, c: u32, r: u32 },
    SgapNnzGroup { c: u32, r: u32 },
}

impl BandAlgo {
    pub fn to_algo(self) -> Algo {
        match self {
            BandAlgo::TacoNnzSerial { g, c } => Algo::TacoNnzSerial { g, c },
            BandAlgo::TacoRowSerial { x, c } => Algo::TacoRowSerial { x, c },
            BandAlgo::SgapRowGroup { g, c, r } => Algo::SgapRowGroup { g, c, r },
            BandAlgo::SgapNnzGroup { c, r } => Algo::SgapNnzGroup { c, r },
        }
    }

    /// Project an [`Algo`] into a band plan; `None` for kinds a band
    /// cannot carry (dgSPARSE, tensor kernels, nested composites).
    pub fn from_algo(a: Algo) -> Option<BandAlgo> {
        match a {
            Algo::TacoNnzSerial { g, c } => Some(BandAlgo::TacoNnzSerial { g, c }),
            Algo::TacoRowSerial { x, c } => Some(BandAlgo::TacoRowSerial { x, c }),
            Algo::SgapRowGroup { g, c, r } => Some(BandAlgo::SgapRowGroup { g, c, r }),
            Algo::SgapNnzGroup { c, r } => Some(BandAlgo::SgapNnzGroup { c, r }),
            _ => None,
        }
    }
}

/// A composite (banded) SpMM plan: up to three bands cut on log2
/// row-degree bucket boundaries, one [`BandAlgo`] per band. Cuts are
/// bucket indices — matrix-independent, so a cached composite re-derives
/// a valid partition on any matrix its `ShapeKey` collides with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompositeConfig {
    /// Active band count, `2..=3`.
    pub bands: u8,
    /// Cut buckets; `cuts[1]` holds the sentinel when `bands == 2`.
    pub cuts: [u8; 2],
    /// Per-band plans; trailing slots of unused bands repeat the last
    /// active plan (never launched).
    pub plans: [BandAlgo; 3],
}

impl CompositeConfig {
    pub fn plan(&self, band: usize) -> Algo {
        self.plans[band].to_algo()
    }
}

/// Outcome of running an algorithm on a matrix.
#[derive(Debug, Clone)]
pub struct AlgoResult {
    pub run: SpmmRun,
    pub time_s: f64,
    pub gflops: f64,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::TacoNnzSerial { g, c } => format!("taco{{<{g} nnz,{c} col>,1}}"),
            Algo::TacoRowSerial { x, c } => format!("taco{{<{x} row,{c} col>,1}}"),
            Algo::SgapRowGroup { g, c, r } => format!("sgap{{<1/{g} row,{c} col>,{r}}}"),
            Algo::SgapNnzGroup { c, r } => format!("sgap{{<1 nnz,{c} col>,{r}}}"),
            Algo::Dg(d) => format!(
                "dg<{},{},{},{}>",
                d.group_sz, d.block_sz, d.tile_sz, d.worker_dim_r_frac
            ),
            Algo::Sddmm(s) => format!("sddmm{{<1/{} nnz>,{}}}", s.g, s.r),
            Algo::Mttkrp(m) => format!("mttkrp{{<1 nnz,{} col>,{}}}", m.c, m.r),
            Algo::Ttm(t) => format!("ttm{{<1 nnz,{} col>,{}}}", t.c, t.r),
            Algo::FusedSddmmSpmm(f) => format!("fused{{<1 nnz,{} col>,{}}}", f.c, f.r),
            Algo::Composite(cc) => {
                let names: Vec<String> =
                    (0..cc.bands as usize).map(|b| cc.plan(b).name()).collect();
                format!("hybrid{{{} @cuts[{},{}]}}", names.join(" | "), cc.cuts[0], cc.cuts[1])
            }
        }
    }

    /// Coarse, stable family label — the metrics/batching key in the
    /// coordinator (one latency histogram per family, not per tuning
    /// point).
    pub fn family_label(&self) -> &'static str {
        match self {
            Algo::TacoNnzSerial { .. } => "taco-nnz-serial",
            Algo::TacoRowSerial { .. } => "taco-row-serial",
            Algo::SgapRowGroup { .. } => "sgap-row-group",
            Algo::SgapNnzGroup { .. } => "sgap-nnz-group",
            Algo::Dg(_) => "dgsparse",
            Algo::Sddmm(_) => "sddmm-group",
            Algo::Mttkrp(_) => "mttkrp-group",
            Algo::Ttm(_) => "ttm-group",
            Algo::FusedSddmmSpmm(_) => "fused-sddmm-spmm",
            Algo::Composite(_) => "hybrid",
        }
    }

    /// Whether this is a per-band composite (banded) plan.
    pub fn is_composite(&self) -> bool {
        matches!(self, Algo::Composite(_))
    }

    /// Whether this plan serves SDDMM traffic (vs SpMM).
    pub fn is_sddmm(&self) -> bool {
        matches!(self, Algo::Sddmm(_))
    }

    /// Whether this plan serves MTTKRP traffic.
    pub fn is_mttkrp(&self) -> bool {
        matches!(self, Algo::Mttkrp(_))
    }

    /// Whether this plan serves TTM traffic.
    pub fn is_ttm(&self) -> bool {
        matches!(self, Algo::Ttm(_))
    }

    /// Whether this plan serves the fused SDDMM→SpMM chain.
    pub fn is_fused(&self) -> bool {
        matches!(self, Algo::FusedSddmmSpmm(_))
    }

    /// The atomic-parallelism point this algorithm occupies. The dgSPARSE
    /// shape maps to `{<1/workerSz row, coarsenSz col>, groupSz}` (legal
    /// under the Atomics race strategy, which lifts Rule 2). `None` for
    /// SDDMM, whose reduction runs over the *dense* `j` — the §3 space
    /// models the sparse-axis decomposition only.
    pub fn to_point(&self) -> Option<AtomicPoint> {
        match *self {
            Algo::TacoNnzSerial { g, c } => Some(AtomicPoint::new(
                crate::compiler::spaces::DataKind::Nnz,
                crate::compiler::spaces::Factor::Times(g),
                crate::compiler::spaces::Factor::Times(c),
                1,
            )),
            Algo::TacoRowSerial { x, c } => Some(AtomicPoint::new(
                crate::compiler::spaces::DataKind::Row,
                if x > 1 {
                    crate::compiler::spaces::Factor::Times(x)
                } else {
                    crate::compiler::spaces::Factor::One
                },
                crate::compiler::spaces::Factor::Times(c),
                1,
            )),
            Algo::SgapRowGroup { g, c, r } => Some(AtomicPoint::sgap_row(g, c, r)),
            Algo::SgapNnzGroup { c, r } => Some(AtomicPoint::sgap_nnz(c, r)),
            Algo::Dg(d) => Some(AtomicPoint::dg_rb_pr(d.worker_sz, d.coarsen_sz, d.group_sz)),
            Algo::Sddmm(_) => None,
            // the COO-3 kernels occupy the same `{<1 nnz, c col>, r}`
            // point as SpMM's segment-reduction family — §2.1's claim made
            // literal
            Algo::Mttkrp(m) => Some(AtomicPoint::sgap_nnz(m.c, m.r)),
            Algo::Ttm(t) => Some(AtomicPoint::sgap_nnz(t.c, t.r)),
            // the fused chain's sparse-axis decomposition is the consumer's
            // — the same nnz-split segment point; the in-register dot adds
            // work per lane but no new decomposition axis
            Algo::FusedSddmmSpmm(f) => Some(AtomicPoint::sgap_nnz(f.c, f.r)),
            // a composite occupies one point *per band*; there is no
            // single point to report
            Algo::Composite(_) => None,
        }
    }

    /// Build the schedule this plan lowers from. `n`/`p` parameterize the
    /// SpMM schedule families; the dgSPARSE and SDDMM variants carry
    /// their full launch shape in their configs.
    pub fn schedule(&self, n: u32, p: u32) -> Schedule {
        let base = SpmmConfig { n, c: 1, p, g: 32, r: 32, x: 1 };
        match *self {
            Algo::TacoNnzSerial { g, c } => {
                Schedule::taco_nnz_serial(SpmmConfig { c, g, ..base })
            }
            Algo::TacoRowSerial { x, c } => {
                Schedule::taco_row_serial(SpmmConfig { c, x, ..base })
            }
            Algo::SgapRowGroup { g, c, r } => {
                Schedule::sgap_row_group(SpmmConfig { c, g, ..base }, r)
            }
            Algo::SgapNnzGroup { c, r } => {
                Schedule::sgap_nnz_group(SpmmConfig { c, ..base }, r)
            }
            Algo::Dg(cfg) => Schedule::dgsparse_rb_pr(cfg),
            Algo::Sddmm(cfg) => Schedule::sddmm_group(cfg),
            Algo::Mttkrp(cfg) => Schedule::mttkrp_group(cfg),
            Algo::Ttm(cfg) => Schedule::ttm_group(cfg),
            Algo::FusedSddmmSpmm(cfg) => Schedule::fused_sddmm_spmm(cfg),
            Algo::Composite(_) => {
                panic!("composite plans lower one schedule per band; use run()")
            }
        }
    }

    /// Execute an SpMM plan on the simulator. `b` must be `a.cols * n`
    /// row-major. Errors for [`Algo::Sddmm`], [`Algo::Mttkrp`], and
    /// [`Algo::Ttm`] plans, which carry different operands — use
    /// [`Algo::run_sddmm`] / [`Algo::run_mttkrp`] / [`Algo::run_ttm`].
    pub fn run(&self, machine: &Machine, a: &Csr, b: &[f32], n: u32) -> Result<AlgoResult> {
        if let Algo::Composite(cc) = self {
            return run_composite(machine, cc, a, b, n);
        }
        let run = match self {
            Algo::Dg(cfg) => {
                anyhow::ensure!(cfg.n == n, "DgConfig.n {} != n {}", cfg.n, n);
                dgsparse::run(machine, cfg, a, b)?
            }
            Algo::Sddmm(_) => {
                anyhow::bail!("{} is an SDDMM plan; use run_sddmm", self.name())
            }
            Algo::Mttkrp(_) => {
                anyhow::bail!("{} is an MTTKRP plan; use run_mttkrp", self.name())
            }
            Algo::Ttm(_) => {
                anyhow::bail!("{} is a TTM plan; use run_ttm", self.name())
            }
            Algo::FusedSddmmSpmm(_) => {
                anyhow::bail!("{} is a fused SDDMM\u{2192}SpMM plan; use run_fused", self.name())
            }
            _ => {
                let sched = self.schedule(n, 256);
                run_schedule(machine, &sched, a, b)?
            }
        };
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(spmm_flops(a, n as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }

    /// Execute an MTTKRP plan on the simulator. `x1` is row-major
    /// `[a.dim1 × j]`, `x2` row-major `[a.dim2 × j]`. Errors for every
    /// other plan kind.
    pub fn run_mttkrp(
        &self,
        machine: &Machine,
        a: &Coo3,
        x1: &[f32],
        x2: &[f32],
    ) -> Result<AlgoResult> {
        let Algo::Mttkrp(cfg) = self else {
            anyhow::bail!("{} is not an MTTKRP plan", self.name())
        };
        let run = mttkrp::run_mttkrp(machine, a, x1, x2, cfg)?;
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(mttkrp_flops(a, cfg.j_dim as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }

    /// Execute a TTM plan on the simulator. `x1` is row-major
    /// `[a.dim2 × l]`. Errors for every other plan kind.
    pub fn run_ttm(&self, machine: &Machine, a: &Coo3, x1: &[f32]) -> Result<AlgoResult> {
        let Algo::Ttm(cfg) = self else {
            anyhow::bail!("{} is not a TTM plan", self.name())
        };
        let run = mttkrp::run_ttm(machine, a, x1, cfg)?;
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(ttm_flops(a, cfg.l_dim as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }

    /// Execute a fused SDDMM→SpMM plan on the simulator. `x1` is
    /// row-major `[a.rows × j]`, `x2` row-major `[j × a.cols]`, `b`
    /// row-major `[a.cols × n]`. Errors for every other plan kind.
    pub fn run_fused(
        &self,
        machine: &Machine,
        a: &Csr,
        x1: &[f32],
        x2: &[f32],
        b: &[f32],
    ) -> Result<AlgoResult> {
        let Algo::FusedSddmmSpmm(cfg) = self else {
            anyhow::bail!("{} is not a fused SDDMM\u{2192}SpMM plan", self.name())
        };
        let run = fused::run(machine, cfg, a, x1, x2, b)?;
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(fused_flops(a, cfg.j_dim as usize, cfg.n as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }

    /// Execute an SDDMM plan on the simulator. `x1` is row-major
    /// `[a.rows × j]`, `x2` row-major `[j × a.cols]`. Errors for SpMM
    /// plans.
    pub fn run_sddmm(
        &self,
        machine: &Machine,
        a: &Csr,
        x1: &[f32],
        x2: &[f32],
    ) -> Result<AlgoResult> {
        let Algo::Sddmm(cfg) = self else {
            anyhow::bail!("{} is an SpMM plan; use run", self.name())
        };
        let run = sddmm::run(machine, cfg, a, x1, x2)?;
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(sddmm_flops(a, cfg.j_dim as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }
}

/// Launch a composite plan: re-derive the band partition from the cuts
/// (cheap: one degree sweep), gather each band's sub-CSR, run the band's
/// plan, and scatter band outputs into one merged `C`. The bands of one
/// logical op launch independently, so the composite's time is the
/// *slowest band's* — matching `CostModel::price`'s max-over-bands
/// roll-up — and the merged report is the slowest band's report.
fn run_composite(
    machine: &Machine,
    cc: &CompositeConfig,
    a: &Csr,
    b: &[f32],
    n: u32,
) -> Result<AlgoResult> {
    use crate::sparse::partition::{band_csr, partition_rows};
    anyhow::ensure!(a.rows > 0, "composite plan on an empty matrix");
    let bands = (cc.bands as usize).clamp(2, 3);
    let part = partition_rows(a, bands, cc.cuts);
    let nn = n as usize;
    let mut c = vec![0f32; a.rows * nn];
    let mut slowest: Option<SpmmRun> = None;
    let mut names: Vec<String> = Vec::with_capacity(bands);
    for band in 0..bands {
        let rows = part.rows_of(band);
        if rows.is_empty() {
            // legal under ShapeKey collisions: a cached cut may leave a
            // band unpopulated on this matrix — skip its launch
            continue;
        }
        let sub = band_csr(a, rows);
        let sched = cc.plan(band).schedule(n, 256);
        let run = run_schedule(machine, &sched, &sub, b)?;
        for (local, &orig) in rows.iter().enumerate() {
            c[orig as usize * nn..(orig as usize + 1) * nn]
                .copy_from_slice(&run.c[local * nn..(local + 1) * nn]);
        }
        names.push(run.kernel_name.clone());
        if slowest.as_ref().is_none_or(|s| run.report.time_s > s.report.time_s) {
            slowest = Some(run);
        }
    }
    let slowest = slowest.expect("at least one band is populated when rows > 0");
    let time_s = slowest.report.time_s;
    let gflops = slowest.report.gflops(spmm_flops(a, nn));
    Ok(AlgoResult {
        run: SpmmRun {
            c,
            report: slowest.report,
            kernel_name: format!("hybrid({})", names.join("+")),
        },
        time_s,
        gflops,
    })
}

/// Every launch-legal compiler-family point (TACO + Sgap, no dgSPARSE) at
/// dense width `n` with reduction width `r` — the sweep the differential
/// property tests (`rust/tests/spmm_differential.rs`) run against the
/// serial oracle.
pub fn compiler_family_sweep(n: u32, r: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(n) {
        let kch = n / c;
        out.push(Algo::SgapNnzGroup { c, r });
        for g in [4u32, 16] {
            out.push(Algo::TacoNnzSerial { g, c });
        }
        for x in [1u32, 2] {
            out.push(Algo::TacoRowSerial { x, c });
        }
        for g in [2u32, 4, 8, 16, 32] {
            // rule-2 analogue (r <= g) plus the launch-shape divisibility
            // (which also bounds g*kch <= 256: at least one row per block)
            if r <= g && 256 % (g * kch) == 0 {
                out.push(Algo::SgapRowGroup { g, c, r });
            }
        }
    }
    out
}

/// The default tuning grids (§7.1): `r` over powers of two, `c` dividing N.
pub fn r_values() -> [u32; 6] {
    [1, 2, 4, 8, 16, 32]
}

pub fn c_values(n: u32) -> Vec<u32> {
    [1u32, 2, 4].into_iter().filter(|c| n % c == 0 && 256 % (n / c) == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, SplitMix64};

    #[test]
    fn names_and_points() {
        let a = Algo::SgapNnzGroup { c: 4, r: 8 };
        assert_eq!(a.name(), "sgap{<1 nnz,4 col>,8}");
        assert!(a.to_point().unwrap().is_legal());
        let d = Algo::Dg(DgConfig::stock(4));
        let p = d.to_point().unwrap();
        assert!(p.is_legal_with_atomics(), "dg point {p} illegal under atomics");
        assert!(d.name().starts_with("dg<32,256,32,1>"));
        let s = Algo::Sddmm(SddmmConfig::new(64, 16, 8));
        assert_eq!(s.name(), "sddmm{<1/16 nnz>,8}");
        assert_eq!(s.family_label(), "sddmm-group");
        assert!(s.is_sddmm() && s.to_point().is_none());
    }

    #[test]
    fn every_variant_resolves_to_a_schedule() {
        use crate::compiler::schedule::Family;
        let cases = [
            (Algo::TacoNnzSerial { g: 16, c: 4 }, Family::NnzSerial),
            (Algo::TacoRowSerial { x: 1, c: 4 }, Family::RowSerial),
            (Algo::SgapRowGroup { g: 32, c: 4, r: 8 }, Family::RowGroup),
            (Algo::SgapNnzGroup { c: 4, r: 32 }, Family::NnzGroup),
            (Algo::Dg(DgConfig::stock(4)), Family::DgRowBalanced),
            (Algo::Sddmm(SddmmConfig::new(16, 8, 8)), Family::SddmmGroup),
            (Algo::Mttkrp(MttkrpConfig::new(8, 4, 16)), Family::MttkrpGroup),
            (Algo::Ttm(TtmConfig::new(4, 4, 8)), Family::TtmGroup),
            (Algo::FusedSddmmSpmm(FusedConfig::new(16, 4, 4, 8)), Family::FusedSddmmSpmm),
        ];
        for (alg, family) in cases {
            let sched = alg.schedule(4, 256);
            assert_eq!(sched.classify().unwrap(), family, "{}", alg.name());
            // every catalog plan is a lowering of its stated algebra —
            // the front-door contract
            crate::compiler::compile(&sched.algebra(), &sched).unwrap_or_else(|e| {
                panic!("{} failed to compile: {e}", alg.name())
            });
        }
    }

    #[test]
    fn tensor_plans_run_through_their_own_paths_only() {
        let m = Machine::new(HwProfile::rtx3090());
        let a = Coo3::random((24, 20, 16), 400, 3);
        let mut rng = SplitMix64::new(7);
        let j = 8usize;
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let plan = Algo::Mttkrp(MttkrpConfig::new(j as u32, 4, 8));
        assert_eq!(plan.name(), "mttkrp{<1 nnz,4 col>,8}");
        assert_eq!(plan.family_label(), "mttkrp-group");
        assert!(plan.is_mttkrp() && !plan.is_ttm() && !plan.is_sddmm());
        assert!(plan.to_point().unwrap().is_legal());
        let res = plan.run_mttkrp(&m, &a, &x1, &x2).unwrap();
        let want = crate::algos::mttkrp::mttkrp_serial(&a, &x1, &x2, j);
        assert!(crate::algos::cpu_ref::max_rel_err(&res.run.c, &want) < 5e-4);
        assert!(res.gflops > 0.0);

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let tplan = Algo::Ttm(TtmConfig::new(4, 4, 8));
        assert!(tplan.is_ttm());
        let res = tplan.run_ttm(&m, &a, &lx1).unwrap();
        let want = crate::algos::mttkrp::ttm_serial(&a, &lx1, 4);
        assert!(crate::algos::cpu_ref::max_rel_err(&res.run.c, &want) < 5e-4);

        // kind mismatches error instead of guessing a kernel
        let csr = erdos_renyi(16, 16, 40, 1).to_csr();
        let zeros = vec![0.0f32; 16 * 4];
        assert!(plan.run(&m, &csr, &zeros, 4).is_err());
        assert!(tplan.run(&m, &csr, &zeros, 4).is_err());
        assert!(plan.run_ttm(&m, &a, &lx1).is_err());
        assert!(tplan.run_mttkrp(&m, &a, &x1, &x2).is_err());
        assert!(Algo::TacoRowSerial { x: 1, c: 4 }.run_mttkrp(&m, &a, &x1, &x2).is_err());
    }

    #[test]
    fn all_catalog_entries_run_and_agree() {
        let a = erdos_renyi(128, 128, 1024, 17).to_csr();
        let n = 4u32;
        let mut rng = SplitMix64::new(1);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let algos = [
            Algo::TacoNnzSerial { g: 16, c: 4 },
            Algo::TacoRowSerial { x: 1, c: 4 },
            Algo::SgapRowGroup { g: 32, c: 4, r: 8 },
            Algo::SgapNnzGroup { c: 4, r: 32 },
            Algo::Dg(DgConfig::stock(4)),
        ];
        let want = crate::algos::cpu_ref::spmm_serial(&a, &b, 4);
        for alg in algos {
            let res = alg.run(&m, &a, &b, n).unwrap();
            let err = crate::algos::cpu_ref::max_rel_err(&res.run.c, &want);
            assert!(err < 1e-4, "{}: err {err}", alg.name());
            assert!(res.time_s > 0.0 && res.gflops > 0.0);
        }
    }

    #[test]
    fn sddmm_plans_run_through_run_sddmm_only() {
        let a = erdos_renyi(48, 40, 300, 9).to_csr();
        let m = Machine::new(HwProfile::rtx3090());
        let j = 16usize;
        let mut rng = SplitMix64::new(2);
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let plan = Algo::Sddmm(SddmmConfig::new(j as u32, 8, 4));
        let res = plan.run_sddmm(&m, &a, &x1, &x2).unwrap();
        let want = sddmm::sddmm_serial(&a, &x1, &x2, j);
        assert!(crate::algos::cpu_ref::max_rel_err(&res.run.c, &want) < 5e-4);
        assert!(res.gflops > 0.0);
        // kind mismatches error instead of guessing a kernel
        assert!(plan.run(&m, &a, &x1, 4).is_err());
        assert!(Algo::TacoRowSerial { x: 1, c: 4 }.run_sddmm(&m, &a, &x1, &x2).is_err());
    }

    #[test]
    fn fused_plans_run_through_run_fused_only() {
        let a = erdos_renyi(48, 40, 300, 9).to_csr();
        let m = Machine::new(HwProfile::rtx3090());
        let j = 16usize;
        let n = 4usize;
        let mut rng = SplitMix64::new(2);
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let plan = Algo::FusedSddmmSpmm(FusedConfig::new(j as u32, n as u32, 4, 8));
        assert_eq!(plan.name(), "fused{<1 nnz,4 col>,8}");
        assert_eq!(plan.family_label(), "fused-sddmm-spmm");
        assert!(plan.is_fused() && !plan.is_sddmm());
        assert!(plan.to_point().unwrap().is_legal());
        let res = plan.run_fused(&m, &a, &x1, &x2, &b).unwrap();
        let want = fused::fused_serial(&a, &x1, &x2, &b, j, n);
        assert!(crate::algos::cpu_ref::max_rel_err(&res.run.c, &want) < 5e-4);
        assert!(res.gflops > 0.0);
        // kind mismatches error instead of guessing a kernel
        assert!(plan.run(&m, &a, &b, n as u32).is_err());
        assert!(Algo::TacoRowSerial { x: 1, c: 4 }.run_fused(&m, &a, &x1, &x2, &b).is_err());
    }

    #[test]
    fn composite_matches_oracle_and_merges_metrics() {
        use crate::sparse::{choose_cuts, power_law, MatrixStats};
        let a = power_law(192, 192, 2600, 1.8, 13).to_csr();
        let stats = MatrixStats::of(&a);
        let (bands, cuts) = choose_cuts(&stats).expect("power-law bands");
        let short = BandAlgo::TacoRowSerial { x: 1, c: 4 };
        let hub = BandAlgo::SgapNnzGroup { c: 4, r: 32 };
        let mid = if bands == 3 { BandAlgo::SgapRowGroup { g: 8, c: 4, r: 8 } } else { hub };
        let plan = Algo::Composite(CompositeConfig {
            bands: bands as u8,
            cuts,
            plans: [short, mid, hub],
        });
        assert!(plan.is_composite());
        assert_eq!(plan.family_label(), "hybrid");
        assert!(plan.name().starts_with("hybrid{"));
        assert!(plan.to_point().is_none());

        let n = 4u32;
        let mut rng = SplitMix64::new(5);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let res = plan.run(&m, &a, &b, n).unwrap();
        // bitwise: each band runs the same compiled kernels over the same
        // per-row data the single-plan path would, so scattering band
        // outputs reproduces the serial oracle exactly as well as any
        // single plan does
        let want = crate::algos::cpu_ref::spmm_serial(&a, &b, 4);
        let err = crate::algos::cpu_ref::max_rel_err(&res.run.c, &want);
        assert!(err < 1e-4, "composite err {err}");
        assert!(res.time_s > 0.0 && res.gflops > 0.0);
        assert!(res.run.kernel_name.starts_with("hybrid("));

        // composite time is the max over its bands: strictly less than the
        // serial sum of band times, never more than running all rows with
        // the hub plan alone... (sanity: positive, bounded by single-plan)
        let single = hub.to_algo().run(&m, &a, &b, n).unwrap();
        assert!(res.time_s <= single.time_s * 1.5, "banding should not blow up runtime");
    }

    #[test]
    fn band_algo_round_trips() {
        for a in [
            Algo::TacoNnzSerial { g: 16, c: 4 },
            Algo::TacoRowSerial { x: 2, c: 2 },
            Algo::SgapRowGroup { g: 32, c: 4, r: 8 },
            Algo::SgapNnzGroup { c: 4, r: 32 },
        ] {
            assert_eq!(BandAlgo::from_algo(a).unwrap().to_algo(), a);
        }
        assert!(BandAlgo::from_algo(Algo::Dg(DgConfig::stock(4))).is_none());
        assert!(BandAlgo::from_algo(Algo::Sddmm(SddmmConfig::new(16, 8, 8))).is_none());
    }

    #[test]
    fn c_values_respect_divisibility() {
        assert_eq!(c_values(4), vec![1, 2, 4]);
        assert!(c_values(128).contains(&4));
    }

    #[test]
    fn family_sweep_nonempty_and_spans_families() {
        for n in [1u32, 4, 32] {
            for r in [2u32, 8, 32] {
                let sweep = compiler_family_sweep(n, r);
                assert!(!sweep.is_empty(), "empty sweep for n={n} r={r}");
                assert!(sweep.iter().any(|a| matches!(a, Algo::SgapNnzGroup { .. })));
                assert!(sweep.iter().any(|a| matches!(a, Algo::TacoRowSerial { .. })));
            }
        }
        let labels: std::collections::HashSet<&str> =
            compiler_family_sweep(4, 8).iter().map(|a| a.family_label()).collect();
        assert_eq!(labels.len(), 4, "labels {labels:?}");
    }
}
