//! Named algorithm points — the vocabulary shared by the tuner, the
//! benches, and the coordinator's kernel selector.

use anyhow::Result;

use crate::compiler::schedule::{Schedule, SpmmConfig};
use crate::compiler::spaces::AtomicPoint;
use crate::sim::Machine;
use crate::sparse::Csr;

use super::cpu_ref::spmm_flops;
use super::dgsparse::{self, DgConfig};
use super::runner::{run_schedule, SpmmRun};

/// An executable SpMM algorithm point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algo {
    /// `{<g nnz, c col>, 1}` — original TACO (Listing 3).
    TacoNnzSerial { g: u32, c: u32 },
    /// `{<x row, c col>, 1}` — original TACO (Listing 4).
    TacoRowSerial { x: u32, c: u32 },
    /// `{<1/g row, c col>, r}` — Sgap grouped parallel reduction.
    SgapRowGroup { g: u32, c: u32, r: u32 },
    /// `{<1 nnz, c col>, r}` — Sgap grouped segment reduction.
    SgapNnzGroup { c: u32, r: u32 },
    /// dgSPARSE RB+PR+RM library kernel.
    Dg(DgConfig),
}

/// Outcome of running an algorithm on a matrix.
#[derive(Debug, Clone)]
pub struct AlgoResult {
    pub run: SpmmRun,
    pub time_s: f64,
    pub gflops: f64,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::TacoNnzSerial { g, c } => format!("taco{{<{g} nnz,{c} col>,1}}"),
            Algo::TacoRowSerial { x, c } => format!("taco{{<{x} row,{c} col>,1}}"),
            Algo::SgapRowGroup { g, c, r } => format!("sgap{{<1/{g} row,{c} col>,{r}}}"),
            Algo::SgapNnzGroup { c, r } => format!("sgap{{<1 nnz,{c} col>,{r}}}"),
            Algo::Dg(d) => format!(
                "dg<{},{},{},{}>",
                d.group_sz, d.block_sz, d.tile_sz, d.worker_dim_r_frac
            ),
        }
    }

    /// Coarse, stable family label — the metrics/batching key in the
    /// coordinator (one latency histogram per family, not per tuning
    /// point).
    pub fn family_label(&self) -> &'static str {
        match self {
            Algo::TacoNnzSerial { .. } => "taco-nnz-serial",
            Algo::TacoRowSerial { .. } => "taco-row-serial",
            Algo::SgapRowGroup { .. } => "sgap-row-group",
            Algo::SgapNnzGroup { .. } => "sgap-nnz-group",
            Algo::Dg(_) => "dgsparse",
        }
    }

    /// The atomic-parallelism point this algorithm occupies (None for the
    /// dgSPARSE entries, which carry more launch detail than the model).
    pub fn to_point(&self) -> Option<AtomicPoint> {
        match *self {
            Algo::TacoNnzSerial { g, c } => Some(AtomicPoint::new(
                crate::compiler::spaces::DataKind::Nnz,
                crate::compiler::spaces::Factor::Times(g),
                crate::compiler::spaces::Factor::Times(c),
                1,
            )),
            Algo::TacoRowSerial { x, c } => Some(AtomicPoint::new(
                crate::compiler::spaces::DataKind::Row,
                if x > 1 {
                    crate::compiler::spaces::Factor::Times(x)
                } else {
                    crate::compiler::spaces::Factor::One
                },
                crate::compiler::spaces::Factor::Times(c),
                1,
            )),
            Algo::SgapRowGroup { g, c, r } => Some(AtomicPoint::sgap_row(g, c, r)),
            Algo::SgapNnzGroup { c, r } => Some(AtomicPoint::sgap_nnz(c, r)),
            Algo::Dg(_) => None,
        }
    }

    /// Build the schedule for compiler-generated families.
    pub fn schedule(&self, n: u32, p: u32) -> Option<Schedule> {
        let base = SpmmConfig { n, c: 1, p, g: 32, r: 32, x: 1 };
        match *self {
            Algo::TacoNnzSerial { g, c } => {
                Some(Schedule::taco_nnz_serial(SpmmConfig { c, g, ..base }))
            }
            Algo::TacoRowSerial { x, c } => {
                Some(Schedule::taco_row_serial(SpmmConfig { c, x, ..base }))
            }
            Algo::SgapRowGroup { g, c, r } => {
                Some(Schedule::sgap_row_group(SpmmConfig { c, g, ..base }, r))
            }
            Algo::SgapNnzGroup { c, r } => {
                Some(Schedule::sgap_nnz_group(SpmmConfig { c, ..base }, r))
            }
            Algo::Dg(_) => None,
        }
    }

    /// Execute on the simulator. `b` must be `a.cols * n` row-major.
    pub fn run(&self, machine: &Machine, a: &Csr, b: &[f32], n: u32) -> Result<AlgoResult> {
        let run = match self {
            Algo::Dg(cfg) => {
                anyhow::ensure!(cfg.n == n, "DgConfig.n {} != n {}", cfg.n, n);
                dgsparse::run(machine, cfg, a, b)?
            }
            _ => {
                let sched = self.schedule(n, 256).expect("compiler family");
                run_schedule(machine, &sched, a, b)?
            }
        };
        let time_s = run.report.time_s;
        let gflops = run.report.gflops(spmm_flops(a, n as usize));
        Ok(AlgoResult { run, time_s, gflops })
    }
}

/// Every launch-legal compiler-family point (TACO + Sgap, no dgSPARSE) at
/// dense width `n` with reduction width `r` — the sweep the differential
/// property tests (`rust/tests/spmm_differential.rs`) run against the
/// serial oracle.
pub fn compiler_family_sweep(n: u32, r: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(n) {
        let kch = n / c;
        out.push(Algo::SgapNnzGroup { c, r });
        for g in [4u32, 16] {
            out.push(Algo::TacoNnzSerial { g, c });
        }
        for x in [1u32, 2] {
            out.push(Algo::TacoRowSerial { x, c });
        }
        for g in [2u32, 4, 8, 16, 32] {
            // rule-2 analogue (r <= g) plus the launch-shape divisibility
            // (which also bounds g*kch <= 256: at least one row per block)
            if r <= g && 256 % (g * kch) == 0 {
                out.push(Algo::SgapRowGroup { g, c, r });
            }
        }
    }
    out
}

/// The default tuning grids (§7.1): `r` over powers of two, `c` dividing N.
pub fn r_values() -> [u32; 6] {
    [1, 2, 4, 8, 16, 32]
}

pub fn c_values(n: u32) -> Vec<u32> {
    [1u32, 2, 4].into_iter().filter(|c| n % c == 0 && 256 % (n / c) == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, SplitMix64};

    #[test]
    fn names_and_points() {
        let a = Algo::SgapNnzGroup { c: 4, r: 8 };
        assert_eq!(a.name(), "sgap{<1 nnz,4 col>,8}");
        assert!(a.to_point().unwrap().is_legal());
        let d = Algo::Dg(DgConfig::stock(4));
        assert!(d.to_point().is_none());
        assert!(d.name().starts_with("dg<32,256,32,1>"));
    }

    #[test]
    fn all_catalog_entries_run_and_agree() {
        let a = erdos_renyi(128, 128, 1024, 17).to_csr();
        let n = 4u32;
        let mut rng = SplitMix64::new(1);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let algos = [
            Algo::TacoNnzSerial { g: 16, c: 4 },
            Algo::TacoRowSerial { x: 1, c: 4 },
            Algo::SgapRowGroup { g: 32, c: 4, r: 8 },
            Algo::SgapNnzGroup { c: 4, r: 32 },
            Algo::Dg(DgConfig::stock(4)),
        ];
        let want = crate::algos::cpu_ref::spmm_serial(&a, &b, 4);
        for alg in algos {
            let res = alg.run(&m, &a, &b, n).unwrap();
            let err = crate::algos::cpu_ref::max_rel_err(&res.run.c, &want);
            assert!(err < 1e-4, "{}: err {err}", alg.name());
            assert!(res.time_s > 0.0 && res.gflops > 0.0);
        }
    }

    #[test]
    fn c_values_respect_divisibility() {
        assert_eq!(c_values(4), vec![1, 2, 4]);
        assert!(c_values(128).contains(&4));
    }

    #[test]
    fn family_sweep_nonempty_and_spans_families() {
        for n in [1u32, 4, 32] {
            for r in [2u32, 8, 32] {
                let sweep = compiler_family_sweep(n, r);
                assert!(!sweep.is_empty(), "empty sweep for n={n} r={r}");
                assert!(sweep.iter().any(|a| matches!(a, Algo::SgapNnzGroup { .. })));
                assert!(sweep.iter().any(|a| matches!(a, Algo::TacoRowSerial { .. })));
            }
        }
        let labels: std::collections::HashSet<&str> =
            compiler_family_sweep(4, 8).iter().map(|a| a.family_label()).collect();
        assert_eq!(labels.len(), 4, "labels {labels:?}");
    }
}
