//! Executable kernel implementations behind the compiled-plan catalog.
//!
//! * [`cpu_ref`] — the serial golden oracle every kernel is checked against.
//! * [`runner`] — binds a CSR matrix + dense B into simulator memory,
//!   computes the launch grid for each compiler family, launches, and
//!   extracts C with the cost report.
//! * [`dgsparse`] — the dgSPARSE-library RB+PR shape, schedule-generated
//!   through `compiler::compile` with the full §7.2 parameter space.
//! * [`sddmm`] — the §4.3 grouped SDDMM, schedule-generated likewise.
//! * [`mttkrp`] — the COO-3 MTTKRP/TTM segment kernels (Eq. 2a/2b), also
//!   schedule-generated: the §2.1 quartet is complete.
//! * [`fused`] — the fused SDDMM→SpMM attention chain: producer dot
//!   in-register, consumer segment reduction, one pass over `pos/crd`.
//! * [`catalog`] — the unified plan vocabulary ([`Algo`]) used by the
//!   tuner, the benches, the CLI, and the coordinator's plan cache.

pub mod catalog;
pub mod cpu_ref;
pub mod dgsparse;
pub mod fused;
pub mod runner;
pub mod mttkrp;
pub mod sddmm;

pub use catalog::{Algo, AlgoResult, BandAlgo, CompositeConfig};
pub use cpu_ref::{spmm_flops, spmm_serial};
pub use dgsparse::DgConfig;
pub use fused::FusedConfig;
pub use mttkrp::{MttkrpConfig, TtmConfig};
pub use runner::{run_schedule, SpmmRun};
pub use sddmm::SddmmConfig;
