//! SpMM algorithm implementations.
//!
//! * [`cpu_ref`] — the serial golden oracle every kernel is checked against.
//! * [`runner`] — binds a CSR matrix + dense B into simulator memory,
//!   computes the launch grid for each compiler family, launches, and
//!   extracts C with the cost report.
//! * [`dgsparse`] — the dgSPARSE-library re-implementation (hand-authored
//!   LLIR, not schedule-generated) with the full §7.2 parameter space.
//! * [`catalog`] — named algorithm points used by the tuner and benches.

pub mod catalog;
pub mod cpu_ref;
pub mod dgsparse;
pub mod runner;
pub mod mttkrp;
pub mod sddmm;

pub use catalog::{Algo, AlgoResult};
pub use cpu_ref::{spmm_flops, spmm_serial};
pub use dgsparse::DgConfig;
pub use runner::{run_schedule, SpmmRun};
