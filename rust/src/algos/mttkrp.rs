//! MTTKRP and TTM with segment group — completing the §2.1 quartet.
//!
//! The paper's key observation (Figs. 4/5): the reductions inside MTTKRP,
//! TTM, SDDMM and SpMM *behave the same*, so one grouped-reduction
//! abstraction serves them all. Both kernels here are nnz-split grouped
//! **segment reductions** keyed by output coordinate — literally the same
//! `segReduceGroup` macro instruction as the SpMM Listing-6 kernel:
//!
//! * **MTTKRP** (Eq. 2a) `Y(i,j) = Σ_{k,l} A(i,k,l)·X1(k,j)·X2(l,j)` —
//!   each non-zero contributes the elementwise product row
//!   `A·(X1[k,:] ∘ X2[l,:])`, segment id = `i` (the DF view: SpMM whose
//!   "B row" is the Khatri-Rao row).
//! * **TTM** (Eq. 2b) `Y(i,j,l) = Σ_k A(i,j,k)·X1(k,l)` — segment id =
//!   the leading `(i,j)` fiber.

use anyhow::Result;

use crate::compiler::llir::{Kernel, Param, Stmt, Val};
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::coo3::Coo3;

use super::runner::SpmmRun;

// ---------------------------------------------------------------------------
// serial oracles
// ---------------------------------------------------------------------------

/// `Y[i, j] = Σ_p:idx0=i A[p] · X1[k_p, j] · X2[l_p, j]`; X1 `[dim1 × j]`,
/// X2 `[dim2 × j]`, output row-major `[dim0 × j]`.
pub fn mttkrp_serial(a: &Coo3, x1: &[f32], x2: &[f32], j_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.dim1 * j_dim);
    assert_eq!(x2.len(), a.dim2 * j_dim);
    let mut y = vec![0f32; a.dim0 * j_dim];
    for p in 0..a.nnz() {
        let (i, k, l) = (a.idx0[p] as usize, a.idx1[p] as usize, a.idx2[p] as usize);
        let v = a.vals[p];
        for j in 0..j_dim {
            y[i * j_dim + j] += v * x1[k * j_dim + j] * x2[l * j_dim + j];
        }
    }
    y
}

/// `Y[i, j, l] = Σ_k A[i,j,k] · X1[k, l]`; X1 `[dim2 × l]`, output
/// row-major over leading fibers `[(dim0·dim1) × l]`.
pub fn ttm_serial(a: &Coo3, x1: &[f32], l_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.dim2 * l_dim);
    let mut y = vec![0f32; a.dim0 * a.dim1 * l_dim];
    for p in 0..a.nnz() {
        let fiber = a.idx0[p] as usize * a.dim1 + a.idx1[p] as usize;
        let k = a.idx2[p] as usize;
        let v = a.vals[p];
        for l in 0..l_dim {
            y[fiber * l_dim + l] += v * x1[k * l_dim + l];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// grouped segment-reduction kernels (shared shape)
// ---------------------------------------------------------------------------

/// Build the nnz-split grouped segment-reduction kernel shared by MTTKRP
/// and TTM. Buffers: `seg_ids[p]` (output segment per nnz), `f1_idx[p]` /
/// `f2_idx[p]` (factor-row gathers; `f2` unused for TTM), `A_vals`,
/// `X1_vals`, `X2_vals`, `Y_vals`; scalars `N_dimension` (dense cols),
/// `A_nnz`. Each thread owns one non-zero × `c` columns.
fn build_seg_kernel(name: &str, with_x2: bool, n: u32, c: u32, p: u32, r: u32) -> Kernel {
    let i = Val::ConstI;
    let kchunks = (n / c) as i64;
    let npb = p as i64 / kchunks;
    let mut inner = vec![
        Stmt::Decl {
            var: "jcol".into(),
            init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
            float: false,
        },
        Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
        Stmt::If {
            // zero extension: out-of-range lanes keep val = 0
            cond: Val::ge(Val::var("pos"), Val::param("A_nnz")),
            then: vec![Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) }],
            els: {
                let x1 = Val::load(
                    "X1_vals",
                    Val::add(
                        Val::mul(Val::load("f1_idx", Val::var("pos")), Val::param("N_dimension")),
                        Val::var("jcol"),
                    ),
                );
                let base = Val::mul(Val::load("A_vals", Val::var("pos")), x1);
                let product = if with_x2 {
                    Val::mul(
                        base,
                        Val::load(
                            "X2_vals",
                            Val::add(
                                Val::mul(
                                    Val::load("f2_idx", Val::var("pos")),
                                    Val::param("N_dimension"),
                                ),
                                Val::var("jcol"),
                            ),
                        ),
                    )
                } else {
                    base
                };
                vec![Stmt::Assign { var: "val".into(), val: product }]
            },
        },
        Stmt::Decl {
            var: "out".into(),
            init: Val::add(
                Val::mul(Val::var("seg"), Val::param("N_dimension")),
                Val::var("jcol"),
            ),
            float: false,
        },
        // the same macro instruction as SpMM's Listing-6 kernel (§2.1)
        Stmt::SegReduceGroup { array: "Y_vals".into(), idx: Val::var("out"), val: Val::var("val"), group: r },
    ];
    let body = vec![
        Stmt::Comment(format!("{name}: nnz-split grouped segment reduction (r={r})")),
        Stmt::Decl { var: "e".into(), init: Val::rem(Val::ThreadIdx, i(npb)), float: false },
        Stmt::Decl { var: "ko".into(), init: Val::div(Val::ThreadIdx, i(npb)), float: false },
        Stmt::Decl {
            var: "pos".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(npb)), Val::var("e")),
            float: false,
        },
        Stmt::Decl {
            var: "seg".into(),
            init: Val::load("seg_ids", Val::min(Val::var("pos"), Val::sub(Val::param("A_nnz_pad"), i(1)))),
            float: false,
        },
        Stmt::For { var: "ki".into(), lo: i(0), hi: i(c as i64), step: i(1), body: std::mem::take(&mut inner) },
    ];
    let mut params = vec![
        Param::i32_array("seg_ids"),
        Param::i32_array("f1_idx"),
        Param::f32_array("A_vals"),
        Param::f32_array("X1_vals"),
        Param::f32_array("Y_vals"),
        Param::i32_scalar("N_dimension"),
        Param::i32_scalar("A_nnz"),
        Param::i32_scalar("A_nnz_pad"),
    ];
    if with_x2 {
        params.insert(2, Param::i32_array("f2_idx"));
        params.insert(5, Param::f32_array("X2_vals"));
    }
    Kernel { name: format!("{name}_c{c}_r{r}"), params, body, block_dim: p }
}

fn launch_seg(
    machine: &Machine,
    kernel: &Kernel,
    mem: &mut DeviceMemory,
    nnz: usize,
    n: u32,
    c: u32,
    p: u32,
) -> Result<crate::sim::KernelReport> {
    let npb = (p / (n / c)) as usize;
    let grid = nnz.div_ceil(npb).max(1) as u32;
    machine.launch(kernel, grid, mem)
}

/// Run grouped MTTKRP on the simulator. `n` = factor columns (J).
pub fn run_mttkrp(
    machine: &Machine,
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
    n: u32,
    c: u32,
    r: u32,
) -> Result<SpmmRun> {
    anyhow::ensure!(n % c == 0 && 256 % (n / c) == 0, "c must divide N with 256 % (N/c) == 0");
    let p = 256u32;
    let kernel = build_seg_kernel("mttkrp", true, n, c, p, r);
    let seg: Vec<i32> = a.idx0.iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    bind_seg_common(&mut mem, &seg, a, n, a.dim0);
    mem.bind_i32("f1_idx", a.idx1.iter().map(|&x| x as i32).collect());
    mem.bind_i32("f2_idx", a.idx2.iter().map(|&x| x as i32).collect());
    mem.bind_f32("X1_vals", x1.to_vec());
    mem.bind_f32("X2_vals", x2.to_vec());
    let report = launch_seg(machine, &kernel, &mut mem, a.nnz(), n, c, p)?;
    let mut y = mem.take_f32("Y_vals").expect("Y_vals");
    y.truncate(a.dim0 * n as usize);
    Ok(SpmmRun { c: y, report, kernel_name: kernel.name })
}

/// Run grouped TTM on the simulator. `n` = dense output columns (L).
pub fn run_ttm(machine: &Machine, a: &Coo3, x1: &[f32], n: u32, c: u32, r: u32) -> Result<SpmmRun> {
    anyhow::ensure!(n % c == 0 && 256 % (n / c) == 0, "c must divide N with 256 % (N/c) == 0");
    let p = 256u32;
    let kernel = build_seg_kernel("ttm", false, n, c, p, r);
    let seg: Vec<i32> = a.leading_fiber_ids().iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    bind_seg_common(&mut mem, &seg, a, n, a.dim0 * a.dim1);
    mem.bind_i32("f1_idx", a.idx2.iter().map(|&x| x as i32).collect());
    mem.bind_f32("X1_vals", x1.to_vec());
    let report = launch_seg(machine, &kernel, &mut mem, a.nnz(), n, c, p)?;
    let mut y = mem.take_f32("Y_vals").expect("Y_vals");
    y.truncate(a.dim0 * a.dim1 * n as usize);
    Ok(SpmmRun { c: y, report, kernel_name: kernel.name })
}

fn bind_seg_common(mem: &mut DeviceMemory, seg: &[i32], a: &Coo3, n: u32, out_rows: usize) {
    // one pad segment for zero extension (out-of-range lanes land there)
    let mut seg_pad = seg.to_vec();
    seg_pad.push(out_rows as i32);
    mem.bind_i32("seg_ids", seg_pad);
    mem.bind_f32("A_vals", a.vals.clone());
    mem.bind_f32("Y_vals", vec![0.0; (out_rows + 1) * n as usize]);
    mem.bind_scalar("N_dimension", n as i64);
    mem.bind_scalar("A_nnz", a.nnz() as i64);
    mem.bind_scalar("A_nnz_pad", (a.nnz() + 1) as i64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sim::HwProfile;
    use crate::sparse::SplitMix64;

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len).map(|_| rng.value()).collect()
    }

    #[test]
    fn mttkrp_matches_oracle_group_sweep() {
        let a = Coo3::random((40, 30, 20), 600, 5);
        let n = 8u32;
        let x1 = dense(30 * 8, 1);
        let x2 = dense(20 * 8, 2);
        let want = mttkrp_serial(&a, &x1, &x2, 8);
        let m = Machine::new(HwProfile::rtx3090());
        for r in [2u32, 8, 32] {
            let run = run_mttkrp(&m, &a, &x1, &x2, n, 4, r).unwrap();
            let err = max_rel_err(&run.c, &want);
            assert!(err < 5e-4, "r={r}: err {err}");
        }
    }

    #[test]
    fn ttm_matches_oracle_group_sweep() {
        let a = Coo3::random((16, 24, 32), 800, 9);
        let n = 4u32;
        let x1 = dense(32 * 4, 3);
        let want = ttm_serial(&a, &x1, 4);
        let m = Machine::new(HwProfile::v100());
        for r in [4u32, 16, 32] {
            let run = run_ttm(&m, &a, &x1, n, 4, r).unwrap();
            let err = max_rel_err(&run.c, &want);
            assert!(err < 5e-4, "r={r}: err {err}");
        }
    }

    #[test]
    fn mttkrp_reduction_reuses_spmm_macro() {
        // structural check of the §2.1 claim: the MTTKRP kernel's reduction
        // is the same SegReduceGroup instruction as SpMM's Listing 6
        let k = build_seg_kernel("mttkrp", true, 4, 4, 256, 16);
        assert_eq!(
            k.count_matching(|s| matches!(s, crate::compiler::llir::Stmt::SegReduceGroup { group: 16, .. })),
            1
        );
    }

    #[test]
    fn empty_tensor_ok() {
        let a = Coo3::new((4, 4, 4), vec![]);
        let m = Machine::new(HwProfile::rtx2080());
        let run = run_ttm(&m, &a, &dense(4 * 4, 1), 4, 4, 8).unwrap();
        assert!(run.c.iter().all(|&v| v == 0.0));
    }
}
