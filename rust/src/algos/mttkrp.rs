//! MTTKRP and TTM with segment group — completing the §2.1 quartet.
//!
//! The paper's key observation (Figs. 4/5): the reductions inside MTTKRP,
//! TTM, SDDMM and SpMM *behave the same*, so one grouped-reduction
//! abstraction serves them all. Both kernels are nnz-split grouped
//! **segment reductions** keyed by output coordinate — literally the same
//! `segReduceGroup` macro instruction as the SpMM Listing-6 kernel — and
//! both are **schedule-generated**: `Schedule::{mttkrp_group, ttm_group}`
//! describe the COO-3 shape and `compiler::compile` checks each schedule
//! against its stated `TensorAlgebra` before lowering. This module only
//! binds buffers, picks the grid, and launches.
//!
//! * **MTTKRP** (Eq. 2a) `Y(i,j) = Σ_{k,l} A(i,k,l)·X1(k,j)·X2(l,j)` —
//!   each non-zero contributes the elementwise product row
//!   `A·(X1[k,:] ∘ X2[l,:])`, segment id = `i` (the DF view: SpMM whose
//!   "B row" is the Khatri-Rao row).
//! * **TTM** (Eq. 2b) `Y(i,j,l) = Σ_k A(i,j,k)·X1(k,l)` — segment id =
//!   the leading `(i,j)` fiber.

use anyhow::Result;

use crate::compiler::schedule::Schedule;
use crate::compiler::{compile, TensorAlgebra};
use crate::sim::{DeviceMemory, Machine};
use crate::sparse::coo3::Coo3;

use super::runner::SpmmRun;

pub use crate::compiler::schedule::{MttkrpConfig, TtmConfig};

// ---------------------------------------------------------------------------
// serial oracles
// ---------------------------------------------------------------------------

/// `Y[i, j] = Σ_p:idx0=i A[p] · X1[k_p, j] · X2[l_p, j]`; X1 `[dim1 × j]`,
/// X2 `[dim2 × j]`, output row-major `[dim0 × j]`.
pub fn mttkrp_serial(a: &Coo3, x1: &[f32], x2: &[f32], j_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.dim1 * j_dim);
    assert_eq!(x2.len(), a.dim2 * j_dim);
    let mut y = vec![0f32; a.dim0 * j_dim];
    for p in 0..a.nnz() {
        let (i, k, l) = (a.idx0[p] as usize, a.idx1[p] as usize, a.idx2[p] as usize);
        let v = a.vals[p];
        for j in 0..j_dim {
            y[i * j_dim + j] += v * x1[k * j_dim + j] * x2[l * j_dim + j];
        }
    }
    y
}

/// `Y[i, j, l] = Σ_k A[i,j,k] · X1[k, l]`; X1 `[dim2 × l]`, output
/// row-major over leading fibers `[(dim0·dim1) × l]`.
pub fn ttm_serial(a: &Coo3, x1: &[f32], l_dim: usize) -> Vec<f32> {
    assert_eq!(x1.len(), a.dim2 * l_dim);
    let mut y = vec![0f32; a.dim0 * a.dim1 * l_dim];
    for p in 0..a.nnz() {
        let fiber = a.idx0[p] as usize * a.dim1 + a.idx1[p] as usize;
        let k = a.idx2[p] as usize;
        let v = a.vals[p];
        for l in 0..l_dim {
            y[fiber * l_dim + l] += v * x1[k * l_dim + l];
        }
    }
    y
}

/// FLOPs per MTTKRP: each non-zero × column does `v·x1·x2` plus the
/// accumulate — 3 flops.
pub fn mttkrp_flops(a: &Coo3, j_dim: usize) -> u64 {
    3 * a.nnz() as u64 * j_dim as u64
}

/// FLOPs per TTM: multiply + accumulate per non-zero × column.
pub fn ttm_flops(a: &Coo3, l_dim: usize) -> u64 {
    2 * a.nnz() as u64 * l_dim as u64
}

// ---------------------------------------------------------------------------
// launch glue for the schedule-generated COO-3 segment kernels
// ---------------------------------------------------------------------------

/// Run grouped MTTKRP on the simulator. `x1` is row-major
/// `[a.dim1 × j_dim]`, `x2` row-major `[a.dim2 × j_dim]`; returns
/// row-major `[a.dim0 × j_dim]`.
pub fn run_mttkrp(
    machine: &Machine,
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
    cfg: &MttkrpConfig,
) -> Result<SpmmRun> {
    let n = cfg.j_dim;
    anyhow::ensure!(x1.len() == a.dim1 * n as usize, "X1 must be dim1 x J");
    anyhow::ensure!(x2.len() == a.dim2 * n as usize, "X2 must be dim2 x J");
    let kernel = compile(&TensorAlgebra::mttkrp(), &Schedule::mttkrp_group(*cfg))?;
    let seg: Vec<i32> = a.idx0.iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    bind_seg_common(&mut mem, &seg, a, n, a.dim0);
    mem.bind_i32("f1_idx", a.idx1.iter().map(|&x| x as i32).collect());
    mem.bind_i32("f2_idx", a.idx2.iter().map(|&x| x as i32).collect());
    mem.bind_f32("X1_vals", x1.to_vec());
    mem.bind_f32("X2_vals", x2.to_vec());
    let grid = a.nnz().div_ceil(cfg.npb() as usize).max(1) as u32;
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let mut y = mem.take_f32("Y_vals").expect("Y_vals");
    y.truncate(a.dim0 * n as usize);
    Ok(SpmmRun { c: y, report, kernel_name: kernel.name })
}

/// Run grouped TTM on the simulator. `x1` is row-major
/// `[a.dim2 × l_dim]`; returns row-major `[(a.dim0·a.dim1) × l_dim]`.
pub fn run_ttm(machine: &Machine, a: &Coo3, x1: &[f32], cfg: &TtmConfig) -> Result<SpmmRun> {
    let n = cfg.l_dim;
    anyhow::ensure!(x1.len() == a.dim2 * n as usize, "X1 must be dim2 x L");
    let kernel = compile(&TensorAlgebra::ttm(), &Schedule::ttm_group(*cfg))?;
    let seg: Vec<i32> = a.leading_fiber_ids().iter().map(|&x| x as i32).collect();
    let mut mem = DeviceMemory::new();
    bind_seg_common(&mut mem, &seg, a, n, a.dim0 * a.dim1);
    mem.bind_i32("f1_idx", a.idx2.iter().map(|&x| x as i32).collect());
    mem.bind_f32("X1_vals", x1.to_vec());
    let grid = a.nnz().div_ceil(cfg.npb() as usize).max(1) as u32;
    let report = machine.launch(&kernel, grid, &mut mem)?;
    let mut y = mem.take_f32("Y_vals").expect("Y_vals");
    y.truncate(a.dim0 * a.dim1 * n as usize);
    Ok(SpmmRun { c: y, report, kernel_name: kernel.name })
}

fn bind_seg_common(mem: &mut DeviceMemory, seg: &[i32], a: &Coo3, n: u32, out_rows: usize) {
    // one pad segment for zero extension (out-of-range lanes land there)
    let mut seg_pad = seg.to_vec();
    seg_pad.push(out_rows as i32);
    mem.bind_i32("seg_ids", seg_pad);
    mem.bind_f32("A_vals", a.vals.clone());
    mem.bind_f32("Y_vals", vec![0.0; (out_rows + 1) * n as usize]);
    mem.bind_scalar("N_dimension", n as i64);
    mem.bind_scalar("A_nnz", a.nnz() as i64);
    mem.bind_scalar("A_nnz_pad", (a.nnz() + 1) as i64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sim::HwProfile;
    use crate::sparse::SplitMix64;

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len).map(|_| rng.value()).collect()
    }

    #[test]
    fn mttkrp_matches_oracle_group_sweep() {
        let a = Coo3::random((40, 30, 20), 600, 5);
        let x1 = dense(30 * 8, 1);
        let x2 = dense(20 * 8, 2);
        let want = mttkrp_serial(&a, &x1, &x2, 8);
        let m = Machine::new(HwProfile::rtx3090());
        for r in [2u32, 8, 32] {
            let run = run_mttkrp(&m, &a, &x1, &x2, &MttkrpConfig::new(8, 4, r)).unwrap();
            let err = max_rel_err(&run.c, &want);
            assert!(err < 5e-4, "r={r}: err {err}");
        }
    }

    #[test]
    fn ttm_matches_oracle_group_sweep() {
        let a = Coo3::random((16, 24, 32), 800, 9);
        let x1 = dense(32 * 4, 3);
        let want = ttm_serial(&a, &x1, 4);
        let m = Machine::new(HwProfile::v100());
        for r in [4u32, 16, 32] {
            let run = run_ttm(&m, &a, &x1, &TtmConfig::new(4, 4, r)).unwrap();
            let err = max_rel_err(&run.c, &want);
            assert!(err < 5e-4, "r={r}: err {err}");
        }
    }

    #[test]
    fn mttkrp_reduction_reuses_spmm_macro() {
        // structural check of the §2.1 claim: the compiled MTTKRP kernel's
        // reduction is the same SegReduceGroup instruction as SpMM's
        // Listing 6 — and it now arrives through compiler::compile from a
        // stated algebra, not from a hand-assembled kernel
        let k = compile(&TensorAlgebra::mttkrp(), &Schedule::mttkrp_group(MttkrpConfig::new(4, 4, 16)))
            .unwrap();
        assert_eq!(
            k.count_matching(|s| matches!(s, crate::compiler::llir::Stmt::SegReduceGroup { group: 16, .. })),
            1
        );
    }

    #[test]
    fn empty_tensor_ok() {
        let a = Coo3::new((4, 4, 4), vec![]);
        let m = Machine::new(HwProfile::rtx2080());
        let run = run_ttm(&m, &a, &dense(4 * 4, 1), &TtmConfig::new(4, 4, 8)).unwrap();
        assert!(run.c.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn invalid_width_is_an_error_not_a_panic() {
        let a = Coo3::random((8, 8, 8), 50, 1);
        let m = Machine::new(HwProfile::rtx3090());
        // J = 20: no coarsening makes the chunks divide the block
        let err = run_mttkrp(&m, &a, &dense(8 * 20, 1), &dense(8 * 20, 2), &MttkrpConfig::new(20, 4, 8));
        assert!(err.is_err());
    }
}
