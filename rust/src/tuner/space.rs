//! Candidate grids: which algorithm points the tuner sweeps.
//!
//! All grids respect the legality rules of `compiler::spaces` plus the
//! launch-shape constraints (`p % (N/c) == 0`, at least one row per block,
//! `groupSz <= workerSz`, …).

use crate::algos::catalog::{c_values, Algo};
use crate::algos::dgsparse::DgConfig;
use crate::algos::fused::FusedConfig;
use crate::algos::mttkrp::{MttkrpConfig, TtmConfig};
use crate::algos::sddmm::SddmmConfig;

const P: u32 = 256;

fn kchunks_ok(n: u32, c: u32) -> bool {
    n % c == 0 && P % (n / c) == 0
}

/// Original-TACO candidates: `{<g nnz, c col>, 1}` and `{<x row, c col>, 1}`.
pub fn taco_candidates(n: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(n) {
        if !kchunks_ok(n, c) {
            continue;
        }
        for g in [4u32, 8, 16, 32] {
            out.push(Algo::TacoNnzSerial { g, c });
        }
        for x in [1u32, 2, 4] {
            out.push(Algo::TacoRowSerial { x, c });
        }
    }
    out
}

/// Sgap candidates: the two new families over (g, c, r).
pub fn sgap_candidates(n: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(n) {
        if !kchunks_ok(n, c) {
            continue;
        }
        let kch = n / c;
        for r in [2u32, 4, 8, 16, 32] {
            out.push(Algo::SgapNnzGroup { c, r });
            for g in [2u32, 4, 8, 16, 32] {
                // rule 2 analogue: r <= g; and at least one row per block
                if r <= g && P % (g * kch) == 0 && P / (g * kch) >= 1 {
                    out.push(Algo::SgapRowGroup { g, c, r });
                }
            }
        }
    }
    out
}

/// Per-band candidate grid for composite plans: the four compiler
/// families (TACO ∪ Sgap). dgSPARSE is excluded — its launch shape owns
/// the whole row space, which a row-subset band view breaks.
pub fn band_candidates(n: u32) -> Vec<Algo> {
    let mut out = taco_candidates(n);
    out.extend(sgap_candidates(n));
    out
}

/// Reduced dgSPARSE grid for the CI benches: one blockSz, two workerDimR
/// fractions, tileSz ∈ {groupSz, 8, 32}. Covers the paper's best-static
/// shapes (`<4-8, 256, 8, 1/2-1>`) at ~6× less sweep cost; the full grid
/// is `dg_candidates`.
pub fn dg_candidates_small(n: u32) -> Vec<Algo> {
    let stock = DgConfig::stock(n);
    let mut out = Vec::new();
    for group_sz in [2u32, 4, 8, 16, 32] {
        for tile_sz in [group_sz, 8, 32] {
            if tile_sz < group_sz || !tile_sz.is_power_of_two() {
                continue;
            }
            for frac in [0.5f64, 1.0] {
                let cfg = DgConfig {
                    n,
                    group_sz,
                    block_sz: 256,
                    tile_sz,
                    worker_dim_r_frac: frac,
                    worker_sz: stock.worker_sz,
                    coarsen_sz: stock.coarsen_sz.min(n.min(tile_sz)),
                };
                if cfg.validate().is_ok() && !out.contains(&Algo::Dg(cfg)) {
                    out.push(Algo::Dg(cfg));
                }
            }
        }
    }
    out
}

/// SDDMM candidate grid (§4.3): lanes-per-nnz `g` × reduction width `r`,
/// with the writeback-uniformity rule `r <= g`. Returns unified catalog
/// plans ([`Algo::Sddmm`]) so the tuner, selector, and plan cache handle
/// SDDMM points exactly like every other kernel kind.
pub fn sddmm_candidates(j_dim: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for g in [2u32, 4, 8, 16, 32] {
        for r in [2u32, 4, 8, 16, 32] {
            if r <= g {
                out.push(Algo::Sddmm(SddmmConfig::new(j_dim, g, r)));
            }
        }
    }
    out
}

/// Fused SDDMM→SpMM candidate grid: the consumer's launch axes
/// (coarsening `c` over the output width `n` × segment-reduction width
/// `r`) — the producer's dot is serial per lane, so `j_dim` adds work but
/// no tuning axis. Empty when no coarsening satisfies the launch
/// divisibility for `n` — callers fall back to the two-stage pipeline.
pub fn fused_candidates(j_dim: u32, n: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(n) {
        for r in [2u32, 4, 8, 16, 32] {
            let cfg = FusedConfig::new(j_dim, n, c, r);
            if cfg.validate().is_ok() {
                out.push(Algo::FusedSddmmSpmm(cfg));
            }
        }
    }
    out
}

/// MTTKRP candidate grid (Eq. 2a): coarsening `c` × reduction width `r`
/// over the COO-3 nnz-split segment family. Empty when no coarsening
/// satisfies the launch divisibility for `j_dim` — callers fall back to
/// the CPU path for such widths.
pub fn mttkrp_candidates(j_dim: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(j_dim) {
        for r in [2u32, 4, 8, 16, 32] {
            let cfg = MttkrpConfig::new(j_dim, c, r);
            if cfg.validate().is_ok() {
                out.push(Algo::Mttkrp(cfg));
            }
        }
    }
    out
}

/// TTM candidate grid (Eq. 2b), same shape as [`mttkrp_candidates`].
pub fn ttm_candidates(l_dim: u32) -> Vec<Algo> {
    let mut out = Vec::new();
    for c in c_values(l_dim) {
        for r in [2u32, 4, 8, 16, 32] {
            let cfg = TtmConfig::new(l_dim, c, r);
            if cfg.validate().is_ok() {
                out.push(Algo::Ttm(cfg));
            }
        }
    }
    out
}

/// dgSPARSE tuning grid (§7.2): `<groupSz, blockSz, tileSz, workerDimR>`.
pub fn dg_candidates(n: u32) -> Vec<Algo> {
    let stock = DgConfig::stock(n);
    let mut out = Vec::new();
    for group_sz in [2u32, 4, 8, 16, 32] {
        for block_sz in [128u32, 256, 512] {
            for tile_exp in 0..8u32 {
                let tile_sz = 1 << tile_exp;
                if tile_sz < group_sz || tile_sz > 128 {
                    continue;
                }
                for frac in [0.25f64, 0.5, 1.0, 2.0] {
                    let cfg = DgConfig {
                        n,
                        group_sz,
                        block_sz,
                        tile_sz,
                        worker_dim_r_frac: frac,
                        worker_sz: stock.worker_sz,
                        coarsen_sz: stock.coarsen_sz.min(n.min(tile_sz)),
                    };
                    if cfg.validate().is_ok() {
                        out.push(Algo::Dg(cfg));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgap_grid_nonempty_and_legal() {
        for n in [4u32, 16, 64, 128] {
            let cands = sgap_candidates(n);
            assert!(!cands.is_empty(), "no sgap candidates for N={n}");
            for a in &cands {
                if let Some(p) = a.to_point() {
                    // candidates lower with Atomics races, so Rule 2 is lifted
                    assert!(p.is_legal_with_atomics(), "{} illegal", a.name());
                }
            }
        }
    }

    #[test]
    fn dg_grid_valid() {
        let cands = dg_candidates(4);
        assert!(cands.len() > 20);
        for a in cands {
            if let Algo::Dg(c) = a {
                c.validate().unwrap();
            }
        }
    }

    #[test]
    fn sddmm_grid_valid_and_covers_widths() {
        let cands = sddmm_candidates(64);
        assert_eq!(cands.len(), 15); // pairs with r <= g over 5x5
        for c in &cands {
            let Algo::Sddmm(cfg) = c else { panic!("{} not an SDDMM plan", c.name()) };
            cfg.validate().unwrap();
        }
        assert!(cands
            .iter()
            .any(|c| matches!(c, Algo::Sddmm(cfg) if cfg.g == 32 && cfg.r == 2)));
    }

    #[test]
    fn band_grid_spans_all_four_families_and_stays_bandable() {
        use crate::algos::catalog::BandAlgo;
        for n in [1u32, 4, 32] {
            let cands = band_candidates(n);
            assert!(!cands.is_empty(), "no band candidates for N={n}");
            for a in &cands {
                assert!(
                    BandAlgo::from_algo(*a).is_some(),
                    "{} cannot serve a band",
                    a.name()
                );
            }
        }
        let labels: std::collections::HashSet<&str> =
            band_candidates(4).iter().map(|a| a.family_label()).collect();
        assert_eq!(labels.len(), 4, "labels {labels:?}");
    }

    #[test]
    fn taco_grid_has_both_families() {
        let c = taco_candidates(4);
        assert!(c.iter().any(|a| matches!(a, Algo::TacoNnzSerial { .. })));
        assert!(c.iter().any(|a| matches!(a, Algo::TacoRowSerial { .. })));
    }

    #[test]
    fn coo3_grids_valid_and_empty_only_for_illegal_widths() {
        for j in [1u32, 4, 8, 32] {
            let cands = mttkrp_candidates(j);
            assert!(!cands.is_empty(), "no MTTKRP candidates for J={j}");
            for a in &cands {
                let Algo::Mttkrp(cfg) = a else { panic!("{} not an MTTKRP plan", a.name()) };
                cfg.validate().unwrap();
                assert_eq!(cfg.j_dim, j);
            }
            let tcands = ttm_candidates(j);
            assert!(!tcands.is_empty(), "no TTM candidates for L={j}");
            for a in &tcands {
                let Algo::Ttm(cfg) = a else { panic!("{} not a TTM plan", a.name()) };
                cfg.validate().unwrap();
            }
        }
        // J = 20: no coarsening makes the chunks divide the block — the
        // grid is empty and the serving layer routes to the CPU
        assert!(mttkrp_candidates(20).is_empty());
        assert!(ttm_candidates(20).is_empty());
    }

    #[test]
    fn fused_grid_valid_and_keys_on_the_output_width() {
        for n in [1u32, 4, 32] {
            let cands = fused_candidates(16, n);
            assert!(!cands.is_empty(), "no fused candidates for N={n}");
            for a in &cands {
                let Algo::FusedSddmmSpmm(cfg) = a else {
                    panic!("{} not a fused plan", a.name())
                };
                cfg.validate().unwrap();
                assert_eq!((cfg.j_dim, cfg.n), (16, n));
            }
        }
        // the dot length adds work, not axes: same grid size either way
        assert_eq!(fused_candidates(8, 4).len(), fused_candidates(64, 4).len());
        // N = 20: no coarsening divides the block — empty grid, two-stage
        // fallback
        assert!(fused_candidates(16, 20).is_empty());
    }
}
