//! Calibration: close the loop from measured latency back to
//! [`CostParams`].
//!
//! The analytic model ([`CostModel`]) prices every candidate from the
//! hand-seeded [`CostParams`] constants plus the preset
//! `launch_overhead_s`. Those constants were chosen so the *rankings*
//! land right on the synthetic suite — but every executed `Response`
//! already carries a measured latency the tuner used to throw away. This
//! module fits the constants to observed `(plan, stats, measured
//! seconds)` triples:
//!
//! * [`Sample`] — one observation: an [`Algo`], the workload statistics
//!   it ran on (owned, so samples outlive the matrices), and the
//!   measured seconds.
//! * [`fit`] — a deterministic coordinate-descent fitter over the
//!   8-vector `θ = (7 CostParams, launch_overhead_s)`, minimising the
//!   mean squared log-ratio `(ln price − ln measured)²`. The model's
//!   charges (`par_reduce`/`seg_scan`/`atomic_chain`/`bsearch`) are
//!   monotone in each coordinate, so cyclic descent with a shrinking
//!   multiplicative step converges without gradients and — crucially for
//!   the Python transliteration (`python/tools/seed_bench.py`) — with a
//!   bit-reproducible trajectory.
//! * [`Calibration`] — the versioned fit artifact. Serialises via
//!   `runtime::json` with fixed key order and `{:.17e}` floats, so
//!   `to_json → parse → to_json` is byte-identical and a restarted
//!   coordinator warm-starts from yesterday's fit (`sgap serve --calib`).
//!
//! The online side (per-`OpKind` EWMA residual tracking + refit +
//! `PlanCache` invalidation) lives in `coordinator::calibrate`; it calls
//! [`fit`] on its sample ring whenever drift crosses the threshold.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::algos::catalog::Algo;
use crate::runtime::json::Json;
use crate::sim::{CostParams, Machine};
use crate::sparse::{MatrixStats, SegStats};

use super::model::{CostModel, Workload};

/// Bump when the artifact layout changes; `from_json` rejects mismatches.
pub const CALIBRATION_SCHEMA_VERSION: u64 = 1;

/// Length of the fitted vector: the 7 [`CostParams`] plus
/// `launch_overhead_s`.
pub const THETA: usize = CostParams::N + 1;

/// Fitted parameters never collapse to zero (a zero charge makes whole
/// cost terms vanish and the log-loss landscape degenerate).
const MIN_PARAM: f64 = 1e-6;

/// Multiplicative step schedule: coarse-to-fine, two cyclic passes per
/// factor. Deterministic — no randomness, no timestamps — so the Rust
/// fitter and its Python transliteration walk the same trajectory.
const FACTORS: [f64; 7] = [2.0, 1.5, 1.25, 1.1, 1.05, 1.02, 1.01];
const PASSES_PER_FACTOR: usize = 2;

/// An owned workload description — the same statistics
/// [`Workload`] borrows, captured so a [`Sample`] can be stored in a
/// ring buffer, serialised, or replayed long after the matrix is gone.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// SpMM `C = A·B` with dense width `n`.
    Spmm { stats: MatrixStats, n: u32 },
    /// SDDMM with inner dense width `j`.
    Sddmm { stats: MatrixStats, j: u32 },
    /// MTTKRP over row segments with factor width `j`.
    Mttkrp { seg: SegStats, j: u32 },
    /// TTM over leading-fiber segments with output width `l`.
    Ttm { seg: SegStats, l: u32 },
    /// Fused SDDMM→SpMM with inner width `j` and output width `n`.
    Fused { stats: MatrixStats, j: u32, n: u32 },
}

impl WorkloadSpec {
    /// Borrow as the [`Workload`] the model prices.
    pub fn workload(&self) -> Workload<'_> {
        match self {
            WorkloadSpec::Spmm { stats, n } => Workload::Spmm { stats, n: *n },
            WorkloadSpec::Sddmm { stats, j } => Workload::Sddmm { stats, j: *j },
            WorkloadSpec::Mttkrp { seg, j } => Workload::Mttkrp { seg, j: *j },
            WorkloadSpec::Ttm { seg, l } => Workload::Ttm { seg, l: *l },
            WorkloadSpec::Fused { stats, j, n } => Workload::Fused { stats, j: *j, n: *n },
        }
    }

    /// Scenario label, matching `coordinator::OpKind::label`.
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadSpec::Spmm { .. } => "spmm",
            WorkloadSpec::Sddmm { .. } => "sddmm",
            WorkloadSpec::Mttkrp { .. } => "mttkrp",
            WorkloadSpec::Ttm { .. } => "ttm",
            WorkloadSpec::Fused { .. } => "fused",
        }
    }
}

/// One observation: `algo` ran on `workload` and took `measured_s`
/// seconds (simulated or wall-clock).
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub algo: Algo,
    pub workload: WorkloadSpec,
    pub measured_s: f64,
}

impl Sample {
    pub fn new(algo: Algo, workload: WorkloadSpec, measured_s: f64) -> Sample {
        Sample { algo, workload, measured_s }
    }
}

/// A versioned fit artifact: the constants the fitter settled on, plus
/// enough provenance (hardware, sample count, loss before/after) to
/// judge whether it is worth applying.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Artifact layout version ([`CALIBRATION_SCHEMA_VERSION`]).
    pub version: u64,
    /// `HwProfile::name` the samples were collected on.
    pub hw: String,
    /// Usable samples the fit saw (finite price, positive measurement).
    pub samples: usize,
    /// Mean squared log-ratio loss at the starting constants.
    pub loss_before: f64,
    /// Loss at the fitted constants. Coordinate descent only ever
    /// accepts strict improvements, so `loss_after <= loss_before`.
    pub loss_after: f64,
    /// The fitted per-instruction charges.
    pub params: CostParams,
    /// The fitted fixed launch overhead (seconds).
    pub launch_overhead_s: f64,
}

impl Calibration {
    /// The do-nothing calibration: `machine`'s own constants, zero
    /// samples, zero loss. What a coordinator runs with before any fit.
    pub fn identity(machine: &Machine) -> Calibration {
        Calibration {
            version: CALIBRATION_SCHEMA_VERSION,
            hw: machine.hw.name.to_string(),
            samples: 0,
            loss_before: 0.0,
            loss_after: 0.0,
            params: machine.params,
            launch_overhead_s: machine.hw.launch_overhead_s,
        }
    }

    /// Install the fitted constants: both the warp interpreter and the
    /// analytic model read `machine.params` / `machine.hw`, so sim and
    /// model shift consistently.
    pub fn apply(&self, machine: &mut Machine) {
        machine.params = self.params;
        machine.hw.launch_overhead_s = self.launch_overhead_s;
    }

    /// The fitted vector in [`fit`]'s coordinate order.
    pub fn theta(&self) -> [f64; THETA] {
        let mut t = [0.0; THETA];
        t[..CostParams::N].copy_from_slice(&self.params.to_array());
        t[CostParams::N] = self.launch_overhead_s;
        t
    }

    /// Serialise with fixed key order and `{:.17e}` floats: 18
    /// significant digits round-trip f64 exactly, and the fixed format
    /// makes `to_json ∘ from_json` the identity on bytes — the
    /// round-trip contract `rust/tests/tuner_calibration.rs` pins.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {},\n", self.version));
        s.push_str(&format!("  \"hw\": \"{}\",\n", self.hw));
        s.push_str(&format!("  \"samples\": {},\n", self.samples));
        s.push_str(&format!("  \"loss_before\": {},\n", fmt_f64(self.loss_before)));
        s.push_str(&format!("  \"loss_after\": {},\n", fmt_f64(self.loss_after)));
        s.push_str(&format!(
            "  \"launch_overhead_s\": {},\n",
            fmt_f64(self.launch_overhead_s)
        ));
        s.push_str("  \"params\": {\n");
        let v = self.params.to_array();
        for (i, name) in CostParams::NAMES.iter().enumerate() {
            let comma = if i + 1 < CostParams::N { "," } else { "" };
            s.push_str(&format!("    \"{}\": {}{}\n", name, fmt_f64(v[i]), comma));
        }
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    pub fn from_json(src: &str) -> Result<Calibration> {
        let j = Json::parse(src).context("calibration artifact is not valid JSON")?;
        let version = req_f64(&j, "schema_version")? as u64;
        if version != CALIBRATION_SCHEMA_VERSION {
            bail!(
                "calibration schema version {version} (this build reads {})",
                CALIBRATION_SCHEMA_VERSION
            );
        }
        let hw = j
            .get("hw")
            .and_then(Json::as_str)
            .context("calibration: missing `hw`")?
            .to_string();
        let samples = req_f64(&j, "samples")? as usize;
        let loss_before = req_f64(&j, "loss_before")?;
        let loss_after = req_f64(&j, "loss_after")?;
        let launch_overhead_s = req_f64(&j, "launch_overhead_s")?;
        let pj = j.get("params").context("calibration: missing `params`")?;
        let mut v = [0.0; CostParams::N];
        for (i, name) in CostParams::NAMES.iter().enumerate() {
            v[i] = pj
                .get(name)
                .and_then(Json::as_f64)
                .with_context(|| format!("calibration: missing param `{name}`"))?;
        }
        Ok(Calibration {
            version,
            hw,
            samples,
            loss_before,
            loss_after,
            params: CostParams::from_array(v),
            launch_overhead_s,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing calibration to {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Calibration> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration from {}", path.display()))?;
        Self::from_json(&src)
    }
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("calibration: missing `{key}`"))
}

/// `{:.17e}` gives 18 significant digits — more than the 17 needed for
/// f64 round-trip — in a *fixed* format (`repr`-style shortest printing
/// would make byte-identity depend on the value).
fn fmt_f64(x: f64) -> String {
    format!("{x:.17e}")
}

/// Build the model priced at `theta` on `machine`'s hardware.
fn model_at(machine: &Machine, theta: &[f64; THETA]) -> CostModel {
    let mut m = machine.clone();
    let mut v = [0.0; CostParams::N];
    v.copy_from_slice(&theta[..CostParams::N]);
    m.params = CostParams::from_array(v);
    m.hw.launch_overhead_s = theta[CostParams::N];
    CostModel::new(&m)
}

/// Mean squared log-ratio between model price and measured seconds at
/// `theta`, over the usable subset of `samples`. Returns `(loss,
/// usable)`; `loss` is `f64::INFINITY` when nothing is usable.
pub fn fit_loss(machine: &Machine, theta: &[f64; THETA], samples: &[Sample]) -> (f64, usize) {
    let model = model_at(machine, theta);
    let mut acc = 0.0;
    let mut used = 0usize;
    for s in samples {
        if !(s.measured_s.is_finite() && s.measured_s > 0.0) {
            continue;
        }
        let Some(t) = model.price(&s.algo, &s.workload.workload()) else { continue };
        if !(t.is_finite() && t > 0.0) {
            continue;
        }
        let r = t.ln() - s.measured_s.ln();
        acc += r * r;
        used += 1;
    }
    if used == 0 {
        (f64::INFINITY, 0)
    } else {
        (acc / used as f64, used)
    }
}

/// Fit `θ = (CostParams, launch_overhead_s)` to `samples`, starting from
/// `machine`'s current constants.
///
/// Deterministic cyclic coordinate descent: for each factor in
/// [`FACTORS`] (coarse → fine), two passes over the coordinates in
/// order, trying `θᵢ·f` and `θᵢ/f` and accepting only strict loss
/// improvements. Params are clamped to [`MIN_PARAM`]; the overhead stays
/// positive because the steps are multiplicative. Monotone acceptance
/// guarantees `loss_after <= loss_before`; with no usable samples the
/// result is [`Calibration::identity`].
pub fn fit(machine: &Machine, samples: &[Sample]) -> Calibration {
    let mut theta = [0.0; THETA];
    theta[..CostParams::N].copy_from_slice(&machine.params.to_array());
    theta[CostParams::N] = machine.hw.launch_overhead_s;

    let (before, used) = fit_loss(machine, &theta, samples);
    if used == 0 {
        return Calibration::identity(machine);
    }

    let mut best = before;
    for &f in &FACTORS {
        for _pass in 0..PASSES_PER_FACTOR {
            for i in 0..THETA {
                for cand in [theta[i] * f, theta[i] / f] {
                    let cand = if i < CostParams::N { cand.max(MIN_PARAM) } else { cand.max(0.0) };
                    let mut trial = theta;
                    trial[i] = cand;
                    let (loss, _) = fit_loss(machine, &trial, samples);
                    if loss < best {
                        best = loss;
                        theta = trial;
                    }
                }
            }
        }
    }

    let mut v = [0.0; CostParams::N];
    v.copy_from_slice(&theta[..CostParams::N]);
    Calibration {
        version: CALIBRATION_SCHEMA_VERSION,
        hw: machine.hw.name.to_string(),
        samples: used,
        loss_before: before,
        loss_after: best,
        params: CostParams::from_array(v),
        launch_overhead_s: theta[CostParams::N],
    }
}

/// Spearman rank correlation (no tie correction — prices are continuous).
/// The same helper `rust/tests/tuner_pruning.rs` checks model fidelity
/// with; public here so `sgap profile` and the calibration tests report
/// rank agreement identically.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    fn ranks(v: &[f64]) -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut r = vec![0.0; v.len()];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    }
    let (rx, ry) = (ranks(xs), ranks(ys));
    let n = xs.len() as f64;
    let mean = (n - 1.0) / 2.0;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..xs.len() {
        cov += (rx[i] - mean) * (ry[i] - mean);
        vx += (rx[i] - mean).powi(2);
        vy += (ry[i] - mean).powi(2);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law};
    use crate::tuner::space::{sgap_candidates, taco_candidates};

    fn machine() -> Machine {
        Machine::new(HwProfile::rtx3090())
    }

    fn spmm_samples(truth: &CostModel) -> Vec<Sample> {
        let mats = [
            erdos_renyi(256, 256, 2000, 1).to_csr(),
            power_law(256, 256, 4000, 1.8, 2).to_csr(),
        ];
        let mut cands = taco_candidates(4);
        cands.extend(sgap_candidates(4));
        let mut out = Vec::new();
        for a in &mats {
            let stats = crate::sparse::MatrixStats::of(a);
            for c in &cands {
                let spec = WorkloadSpec::Spmm { stats: stats.clone(), n: 4 };
                let t = truth.price(c, &spec.workload()).unwrap();
                out.push(Sample::new(*c, spec, t));
            }
        }
        out
    }

    #[test]
    fn identity_is_a_fixed_point_of_apply() {
        let m = machine();
        let c = Calibration::identity(&m);
        let mut m2 = m.clone();
        c.apply(&mut m2);
        assert_eq!(m2.params.to_array(), m.params.to_array());
        assert_eq!(m2.hw.launch_overhead_s, m.hw.launch_overhead_s);
        assert_eq!(c.samples, 0);
        assert_eq!(c.theta()[CostParams::N], m.hw.launch_overhead_s);
    }

    #[test]
    fn json_round_trip_is_byte_identical() {
        let m = machine();
        let mut c = Calibration::identity(&m);
        // awkward floats on purpose: subnormal-ish, repeating binary
        c.loss_before = 0.1;
        c.loss_after = 0.05 / 3.0;
        c.params.load_issue = 4.0 * 1.1;
        c.samples = 316;
        let s1 = c.to_json();
        let c2 = Calibration::from_json(&s1).unwrap();
        assert_eq!(c2, c);
        assert_eq!(c2.to_json(), s1, "to_json ∘ from_json must be identity on bytes");
    }

    #[test]
    fn from_json_rejects_bad_artifacts() {
        assert!(Calibration::from_json("not json").is_err());
        assert!(Calibration::from_json("{}").is_err());
        let m = machine();
        let wrong = Calibration::identity(&m).to_json().replace(
            "\"schema_version\": 1",
            "\"schema_version\": 999",
        );
        assert!(Calibration::from_json(&wrong).is_err());
    }

    #[test]
    fn fit_recovers_a_perturbed_model_and_never_worsens() {
        let m = machine();
        // ground truth: same formulas, drifted constants
        let mut drifted = m.clone();
        let base = m.params.to_array();
        let mult = [1.8, 0.55, 1.6, 2.4, 0.45, 1.5, 2.0];
        let mut v = [0.0; CostParams::N];
        for i in 0..CostParams::N {
            v[i] = base[i] * mult[i];
        }
        drifted.params = CostParams::from_array(v);
        drifted.hw.launch_overhead_s *= 4.0;
        let truth = CostModel::new(&drifted);

        let samples = spmm_samples(&truth);
        assert!(samples.len() > 20);
        let cal = fit(&m, &samples);
        assert_eq!(cal.samples, samples.len());
        assert!(cal.loss_after <= cal.loss_before);
        assert!(
            cal.loss_after < cal.loss_before * 0.9,
            "descent should strictly reduce an out-of-fit loss: {} -> {}",
            cal.loss_before,
            cal.loss_after
        );
        for (i, p) in cal.params.to_array().iter().enumerate() {
            assert!(*p >= MIN_PARAM, "param {} collapsed: {p}", CostParams::NAMES[i]);
        }
        assert!(cal.launch_overhead_s >= 0.0);
    }

    #[test]
    fn fit_with_no_usable_samples_is_identity() {
        let m = machine();
        let cal = fit(&m, &[]);
        assert_eq!(cal, Calibration::identity(&m));
        // non-positive measurements are unusable too
        let a = erdos_renyi(64, 64, 300, 1).to_csr();
        let stats = crate::sparse::MatrixStats::of(&a);
        let bad = vec![Sample::new(
            crate::algos::catalog::Algo::SgapNnzGroup { c: 4, r: 8 },
            WorkloadSpec::Spmm { stats, n: 4 },
            0.0,
        )];
        assert_eq!(fit(&m, &bad), Calibration::identity(&m));
    }

    #[test]
    fn spearman_extremes() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((spearman(&xs, &[10.0, 20.0, 30.0, 40.0]) - 1.0).abs() < 1e-12);
        assert!((spearman(&xs, &[4.0, 3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }
}
