//! Input-dynamics selector — the DA-SpMM-style model that picks an
//! algorithm *without* running the full sweep (Table 5's "dynamic choice").
//!
//! A shallow decision tree over the matrix statistics the DA-SpMM paper
//! identifies as decisive: row-degree skew (CV) decides EB-vs-RB
//! (nnz-balanced kernels win on skewed inputs), mean row degree decides
//! the reduction granularity `r` (short rows want small groups), and N
//! decides the coarsening. Thresholds can be re-fit against a training
//! suite with [`Selector::fit`].

use anyhow::Context;

use crate::algos::catalog::{c_values, Algo};
use crate::algos::fused::FusedConfig;
use crate::algos::mttkrp::{MttkrpConfig, TtmConfig};
use crate::algos::sddmm::SddmmConfig;
use crate::sim::Machine;
use crate::sparse::coo3::Coo3;
use crate::sparse::{Csr, MatrixStats};

use super::model::{CostModel, Workload};
use super::search::tune;
use super::space::sgap_candidates;

/// Decision thresholds (defaults hand-calibrated on the synthetic suite).
#[derive(Debug, Clone, Copy)]
pub struct Selector {
    /// Row-degree CV above which nnz-balanced (EB) kernels are chosen.
    pub cv_eb_threshold: f64,
    /// Mean row degree below which a small group size is chosen.
    pub short_row_degree: f64,
    /// Group size used for short rows.
    pub r_short: u32,
    /// Group size used for long rows.
    pub r_long: u32,
}

impl Default for Selector {
    fn default() -> Self {
        Selector { cv_eb_threshold: 0.8, short_row_degree: 16.0, r_short: 4, r_long: 32 }
    }
}

impl Selector {
    /// Pick an algorithm from the matrix statistics (no simulation).
    pub fn select(&self, stats: &MatrixStats, n: u32) -> Algo {
        let c = *c_values(n).last().unwrap_or(&1);
        let short = stats.row_degree_mean < self.short_row_degree;
        let r = if short { self.r_short } else { self.r_long };
        if stats.row_degree_cv > self.cv_eb_threshold || stats.empty_row_frac > 0.4 {
            // skewed: nnz-balanced segment reduction
            Algo::SgapNnzGroup { c, r }
        } else {
            // balanced: row-split with grouped parallel reduction;
            // g tracks the mean degree (enough lanes to cover a row pass).
            // The divisibility filter also bounds g·(N/c) <= 256 (at least
            // one row per block); when no g satisfies it — wide N with
            // small c — the nnz-balanced kernel is the safe choice.
            match [2u32, 4, 8, 16, 32]
                .into_iter()
                .filter(|&g| r <= g && 256 % (g * (n / c)) == 0)
                .min_by_key(|&g| (g as f64 - stats.row_degree_mean).abs() as u64)
            {
                Some(g) => Algo::SgapRowGroup { g, c, r },
                None => Algo::SgapNnzGroup { c, r },
            }
        }
    }

    /// Pick an SpMM plan by *pricing the whole sgap grid* with the
    /// analytic [`CostModel`] and taking the argmin — still zero
    /// simulation (O(stats) per candidate), strictly better informed than
    /// the hand decision tree. Falls back to [`Selector::select`] when the
    /// width admits no sgap candidates. This is the coordinator's default
    /// fast path; the tree remains the model-free escape hatch.
    pub fn select_model(&self, model: &CostModel, stats: &MatrixStats, n: u32) -> Algo {
        let grid = sgap_candidates(n);
        if grid.is_empty() {
            return self.select(stats, n);
        }
        model.shortlist(&grid, &Workload::Spmm { stats, n }, 1)[0]
    }

    /// Per-band composite selection: `Some(Algo::Composite)` only when
    /// (a) the input is skewed enough to gate in (row-degree CV at or
    /// above `cv_eb_threshold` — the same axis that flips EB/RB in
    /// [`Selector::select`]), and (b) the model prices the composite
    /// *strictly below* the best single plan on the band grid. Low-CV
    /// inputs (ER, banded) return `None` without touching the partitioner,
    /// keeping the single-plan path byte-identical for them.
    pub fn select_banded(&self, model: &CostModel, stats: &MatrixStats, n: u32) -> Option<Algo> {
        if stats.row_degree_cv < self.cv_eb_threshold {
            return None;
        }
        self.banded_plan(model, stats, n)
    }

    /// Build the composite candidate without the CV gate and price it
    /// against the best single band-grid plan. Returns
    /// `(composite, t_composite, best_single, t_single)` whatever the
    /// comparison says — the bench path reports hybrid-vs-single rows
    /// from this even for matrices the gate would decline. `None` only
    /// when the histogram doesn't band
    /// ([`choose_cuts`](crate::sparse::choose_cuts) declines) or the
    /// width admits no band candidates.
    pub fn banded_report(
        &self,
        model: &CostModel,
        stats: &MatrixStats,
        n: u32,
    ) -> Option<(Algo, f64, Algo, f64)> {
        use crate::algos::catalog::{BandAlgo, CompositeConfig};
        let (bands, cuts) = crate::sparse::choose_cuts(stats)?;
        let grid = super::space::band_candidates(n);
        if grid.is_empty() {
            return None;
        }
        // best single plan per band, each priced on its synthetic stats
        let per = crate::sparse::band_stats(stats, bands, cuts);
        let mut plans = [BandAlgo::SgapNnzGroup { c: 1, r: 2 }; 3];
        for (band, bs) in per.iter().enumerate() {
            let w = Workload::Spmm { stats: bs, n };
            let top = model.shortlist(&grid, &w, 1)[0];
            plans[band] = BandAlgo::from_algo(top).expect("band grid is BandAlgo-closed");
        }
        if bands == 2 {
            plans[2] = plans[1]; // unused slot mirrors the last active plan
        }
        let composite = Algo::Composite(CompositeConfig { bands: bands as u8, cuts, plans });
        let full = Workload::Spmm { stats, n };
        let t_composite = model.price(&composite, &full)?;
        let best_single = model.shortlist(&grid, &full, 1)[0];
        let t_single = model.price(&best_single, &full)?;
        Some((composite, t_composite, best_single, t_single))
    }

    /// [`Selector::banded_report`] filtered to the serving contract:
    /// `Some` only when the composite prices *strictly below* the best
    /// single plan.
    pub fn banded_plan(&self, model: &CostModel, stats: &MatrixStats, n: u32) -> Option<Algo> {
        let (composite, t_composite, _, t_single) = self.banded_report(model, stats, n)?;
        (t_composite < t_single).then_some(composite)
    }

    /// SDDMM analogue of [`Selector::select_model`]: model-argmin over the
    /// §4.3 grid, tree fallback when the grid is empty.
    pub fn select_sddmm_model(&self, model: &CostModel, stats: &MatrixStats, j_dim: u32) -> Algo {
        let grid = super::space::sddmm_candidates(j_dim);
        if grid.is_empty() {
            return self.select_sddmm(stats, j_dim);
        }
        model.shortlist(&grid, &Workload::Sddmm { stats, j: j_dim }, 1)[0]
    }

    /// MTTKRP analogue of [`Selector::select_model`]: model-argmin over
    /// the COO-3 grid from the tensor's segment statistics. Like
    /// [`Selector::select_mttkrp`], `None` means no legal launch shape —
    /// the serving layer routes such widths to the CPU.
    pub fn select_mttkrp_model(&self, model: &CostModel, a: &Coo3, j_dim: u32) -> Option<Algo> {
        self.select_mttkrp_model_stats(model, &crate::sparse::SegStats::mttkrp(a), j_dim)
    }

    /// [`Selector::select_mttkrp_model`] from an already-computed segment
    /// fingerprint — the serving layer's handle path, where registration
    /// ran the [`SegStats`](crate::sparse::SegStats) pass once and every
    /// repeat submit reuses it.
    pub fn select_mttkrp_model_stats(
        &self,
        model: &CostModel,
        seg: &crate::sparse::SegStats,
        j_dim: u32,
    ) -> Option<Algo> {
        let grid = super::space::mttkrp_candidates(j_dim);
        if grid.is_empty() {
            return self.select_mttkrp_stats(seg, j_dim);
        }
        Some(model.shortlist(&grid, &Workload::Mttkrp { seg, j: j_dim }, 1)[0])
    }

    /// TTM analogue of [`Selector::select_mttkrp_model`] over the
    /// leading-fiber segments.
    pub fn select_ttm_model(&self, model: &CostModel, a: &Coo3, l_dim: u32) -> Option<Algo> {
        self.select_ttm_model_stats(model, &crate::sparse::SegStats::ttm(a), l_dim)
    }

    /// [`Selector::select_ttm_model`] from an already-computed fiber
    /// fingerprint (see [`Selector::select_mttkrp_model_stats`]).
    pub fn select_ttm_model_stats(
        &self,
        model: &CostModel,
        seg: &crate::sparse::SegStats,
        l_dim: u32,
    ) -> Option<Algo> {
        let grid = super::space::ttm_candidates(l_dim);
        if grid.is_empty() {
            return self.select_ttm_stats(seg, l_dim);
        }
        Some(model.shortlist(&grid, &Workload::Ttm { seg, l: l_dim }, 1)[0])
    }

    /// Pick an SDDMM plan from the matrix statistics (§4.3: the same
    /// GroupSize trade-off applies to SDDMM's dense-`j` reduction).
    /// Returns the unified catalog vocabulary ([`Algo::Sddmm`]) so the
    /// plan cache stores SDDMM choices like any other kernel kind.
    ///
    /// `g` lanes cooperate per non-zero, so `g` tracks `J` (idle lanes are
    /// exactly Fig. 1(b)'s waste); the reduction width `r` follows the same
    /// short-row rule as SpMM, capped at `g`.
    pub fn select_sddmm(&self, stats: &MatrixStats, j_dim: u32) -> Algo {
        let g = j_dim.next_power_of_two().clamp(2, 32);
        let r_cap =
            if stats.row_degree_mean < self.short_row_degree { self.r_short } else { self.r_long };
        Algo::Sddmm(SddmmConfig::new(j_dim, g, r_cap.min(g)))
    }

    /// Pick a fused SDDMM→SpMM plan from the matrix statistics. The
    /// consumer's launch axes choose exactly like SpMM — widest legal
    /// coarsening `c` of the output width, reduction width `r` by the
    /// short-row rule capped at the nnz range a block's lanes own — while
    /// the producer's dot length `j_dim` is serial per lane: it adds work
    /// but no tuning axis. `None` when no coarsening satisfies the launch
    /// divisibility for `n`; callers fall back to the two-stage pipeline.
    pub fn select_fused(&self, stats: &MatrixStats, j_dim: u32, n: u32) -> Option<Algo> {
        let c = *c_values(n).last()?;
        let mut cfg = FusedConfig::new(j_dim, n, c, 2);
        cfg.r = self.coo3_r(stats.row_degree_mean, cfg.npb());
        cfg.validate().ok()?;
        Some(Algo::FusedSddmmSpmm(cfg))
    }

    /// Fused analogue of [`Selector::select_model`]: model-argmin over the
    /// fused grid, tree fallback when the grid is empty. The `None`
    /// contract matches [`Selector::select_fused`] — no legal launch
    /// shape means the serving layer runs the two stages separately.
    pub fn select_fused_model(
        &self,
        model: &CostModel,
        stats: &MatrixStats,
        j_dim: u32,
        n: u32,
    ) -> Option<Algo> {
        let grid = super::space::fused_candidates(j_dim, n);
        if grid.is_empty() {
            return self.select_fused(stats, j_dim, n);
        }
        Some(model.shortlist(&grid, &Workload::Fused { stats, j: j_dim, n }, 1)[0])
    }

    /// Pick an MTTKRP plan from the tensor's segment dynamics: the widest
    /// coarsening that keeps the launch shape legal, reduction width by
    /// the mean segment length (short segments — few non-zeros per output
    /// row — want narrow groups, the Fig. 1(b) trade-off). Returns `None`
    /// when no coarsening satisfies the divisibility for `j_dim`; the
    /// serving layer routes such widths to the CPU path.
    pub fn select_mttkrp(&self, a: &Coo3, j_dim: u32) -> Option<Algo> {
        self.select_mttkrp_mean(a.nnz() as f64 / a.dim0.max(1) as f64, j_dim)
    }

    /// [`Selector::select_mttkrp`] from a cached segment fingerprint
    /// (`seg.mean_len` *is* `nnz / dim0`, so the choice is identical).
    pub fn select_mttkrp_stats(&self, seg: &crate::sparse::SegStats, j_dim: u32) -> Option<Algo> {
        self.select_mttkrp_mean(seg.mean_len, j_dim)
    }

    fn select_mttkrp_mean(&self, mean_seg: f64, j_dim: u32) -> Option<Algo> {
        let c = *c_values(j_dim).last()?;
        let mut cfg = MttkrpConfig::new(j_dim, c, 2);
        cfg.r = self.coo3_r(mean_seg, cfg.npb());
        cfg.validate().ok()?;
        Some(Algo::Mttkrp(cfg))
    }

    /// Pick a TTM plan; segments are the leading `(i,j)` fibers.
    pub fn select_ttm(&self, a: &Coo3, l_dim: u32) -> Option<Algo> {
        self.select_ttm_mean(a.nnz() as f64 / (a.dim0 * a.dim1).max(1) as f64, l_dim)
    }

    /// [`Selector::select_ttm`] from a cached fiber fingerprint.
    pub fn select_ttm_stats(&self, seg: &crate::sparse::SegStats, l_dim: u32) -> Option<Algo> {
        self.select_ttm_mean(seg.mean_len, l_dim)
    }

    fn select_ttm_mean(&self, mean_seg: f64, l_dim: u32) -> Option<Algo> {
        let c = *c_values(l_dim).last()?;
        let mut cfg = TtmConfig::new(l_dim, c, 2);
        cfg.r = self.coo3_r(mean_seg, cfg.npb());
        cfg.validate().ok()?;
        Some(Algo::Ttm(cfg))
    }

    /// The shared reduction-width rule of the COO-3 families, capped at
    /// the contiguous nnz range a block's lanes own.
    fn coo3_r(&self, mean_seg: f64, npb: u32) -> u32 {
        let r = if mean_seg < self.short_row_degree { self.r_short } else { self.r_long };
        r.min(npb)
    }

    /// Re-fit `cv_eb_threshold` on a training set by minimizing total
    /// simulated time of the selector's choices (simple 1-D grid fit —
    /// the DA-SpMM paper uses a decision tree trained the same spirit).
    pub fn fit(machine: &Machine, train: &[(Csr, Vec<f32>)], n: u32) -> anyhow::Result<Selector> {
        let mut best = Selector::default();
        let mut best_total = f64::MAX;
        for cv_t in [0.3, 0.5, 0.8, 1.2, 2.0] {
            for deg_t in [4.0, 16.0, 64.0] {
                let cand = Selector {
                    cv_eb_threshold: cv_t,
                    short_row_degree: deg_t,
                    ..Selector::default()
                };
                let mut total = 0.0;
                for (a, b) in train {
                    let stats = MatrixStats::of(a);
                    let algo = cand.select(&stats, n);
                    total += algo.run(machine, a, b, n)?.time_s;
                }
                if total < best_total {
                    best_total = total;
                    best = cand;
                }
            }
        }
        Ok(best)
    }

    /// Regret of the selector on a matrix: selected time / oracle-best
    /// time over the sgap candidate grid (1.0 = perfect).
    pub fn regret(&self, machine: &Machine, a: &Csr, b: &[f32], n: u32) -> anyhow::Result<f64> {
        let stats = MatrixStats::of(a);
        let chosen = self.select(&stats, n);
        let t_chosen = chosen.run(machine, a, b, n)?.time_s;
        let sweep = tune(machine, &sgap_candidates(n), a, b, n)?;
        let (_, t_best) = sweep.best().context("empty sgap grid")?;
        Ok(t_chosen / t_best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, power_law, SplitMix64};

    fn b_for(a: &Csr, n: u32, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..a.cols * n as usize).map(|_| rng.value()).collect()
    }

    #[test]
    fn skewed_inputs_get_nnz_balanced() {
        let s = Selector::default();
        let skew = power_law(512, 512, 8192, 2.0, 1).to_csr();
        let algo = s.select(&MatrixStats::of(&skew), 4);
        assert!(matches!(algo, Algo::SgapNnzGroup { .. }), "got {}", algo.name());
    }

    #[test]
    fn uniform_inputs_get_row_balanced() {
        let s = Selector::default();
        let er = crate::sparse::banded(512, 9, 2).to_csr();
        let algo = s.select(&MatrixStats::of(&er), 4);
        assert!(matches!(algo, Algo::SgapRowGroup { .. }), "got {}", algo.name());
    }

    #[test]
    fn short_rows_get_small_groups() {
        let s = Selector::default();
        let er = erdos_renyi(512, 512, 1024, 3).to_csr(); // mean degree 2
        let algo = s.select(&MatrixStats::of(&er), 4);
        match algo {
            Algo::SgapRowGroup { r, .. } | Algo::SgapNnzGroup { r, .. } => assert_eq!(r, 4),
            _ => panic!(),
        }
    }

    #[test]
    fn selected_algos_are_runnable() {
        let m = Machine::new(HwProfile::rtx3090());
        let s = Selector::default();
        for a in [
            erdos_renyi(128, 128, 512, 5).to_csr(),
            power_law(128, 128, 2000, 1.8, 6).to_csr(),
        ] {
            let algo = s.select(&MatrixStats::of(&a), 4);
            let b = b_for(&a, 4, 9);
            algo.run(&m, &a, &b, 4).unwrap();
        }
    }

    fn sddmm_cfg(algo: Algo) -> SddmmConfig {
        match algo {
            Algo::Sddmm(cfg) => cfg,
            other => panic!("selector returned non-SDDMM plan {}", other.name()),
        }
    }

    #[test]
    fn sddmm_config_is_valid_and_tracks_j() {
        let s = Selector::default();
        let short = erdos_renyi(512, 512, 1024, 3).to_csr(); // mean degree 2
        let long = crate::sparse::banded(512, 33, 2).to_csr(); // mean degree 33
        for j in [1u32, 8, 16, 50, 64] {
            for m in [&short, &long] {
                let cfg = sddmm_cfg(s.select_sddmm(&MatrixStats::of(m), j));
                cfg.validate().unwrap();
                assert_eq!(cfg.j_dim, j);
                assert!(cfg.g >= j.next_power_of_two().min(32).max(2) || cfg.g == 32);
            }
        }
        let cfg = sddmm_cfg(s.select_sddmm(&MatrixStats::of(&short), 64));
        assert_eq!((cfg.g, cfg.r), (32, 4), "short rows get the narrow reduction");
    }

    #[test]
    fn coo3_selection_tracks_segment_length_and_width() {
        let s = Selector::default();
        // 8000 nnz over 64 rows: long segments → wide reduction
        let dense_rows = Coo3::random((64, 32, 32), 8000, 1);
        let Some(Algo::Mttkrp(cfg)) = s.select_mttkrp(&dense_rows, 8) else {
            panic!("expected an MTTKRP plan")
        };
        assert_eq!((cfg.j_dim, cfg.r), (8, 32));
        cfg.validate().unwrap();
        // 100 nnz over 64 rows: short segments → narrow reduction
        let sparse_rows = Coo3::random((64, 32, 32), 100, 2);
        let Some(Algo::Mttkrp(cfg)) = s.select_mttkrp(&sparse_rows, 8) else {
            panic!("expected an MTTKRP plan")
        };
        assert_eq!(cfg.r, 4);
        // TTM segments are fibers: 8000 nnz over 64·32 fibers is short
        let Some(Algo::Ttm(cfg)) = s.select_ttm(&dense_rows, 8) else {
            panic!("expected a TTM plan")
        };
        assert_eq!(cfg.r, 4);
        cfg.validate().unwrap();
        // widths with no legal coarsening are declined, not mis-served
        assert!(s.select_mttkrp(&dense_rows, 20).is_none());
        assert!(s.select_ttm(&dense_rows, 20).is_none());
    }

    #[test]
    fn fused_selection_tracks_row_dynamics_and_width() {
        let machine = Machine::new(HwProfile::rtx3090());
        let model = CostModel::new(&machine);
        let s = Selector::default();
        let short = erdos_renyi(512, 512, 1024, 3).to_csr(); // mean degree 2
        let long = crate::sparse::banded(512, 65, 2).to_csr(); // mean degree 65
        let (short_stats, long_stats) = (MatrixStats::of(&short), MatrixStats::of(&long));
        let Some(Algo::FusedSddmmSpmm(cfg)) = s.select_fused(&short_stats, 16, 4) else {
            panic!("expected a fused plan")
        };
        cfg.validate().unwrap();
        assert_eq!((cfg.j_dim, cfg.n, cfg.r), (16, 4, 4), "short rows get the narrow reduction");
        let Some(Algo::FusedSddmmSpmm(cfg)) = s.select_fused(&long_stats, 16, 4) else {
            panic!("expected a fused plan")
        };
        assert_eq!(cfg.r, 32, "long rows get the wide reduction");
        // model path stays in the fused vocabulary and validates
        let Some(Algo::FusedSddmmSpmm(cfg)) = s.select_fused_model(&model, &short_stats, 16, 4)
        else {
            panic!("expected a fused plan from the model path")
        };
        cfg.validate().unwrap();
        assert_eq!((cfg.j_dim, cfg.n), (16, 4));
        // widths with no legal coarsening are declined on both paths
        assert!(s.select_fused(&short_stats, 16, 20).is_none());
        assert!(s.select_fused_model(&model, &short_stats, 16, 20).is_none());
    }

    #[test]
    fn model_selection_returns_runnable_sgap_plans() {
        let machine = Machine::new(HwProfile::rtx3090());
        let model = CostModel::new(&machine);
        let s = Selector::default();
        for a in [
            erdos_renyi(128, 128, 512, 5).to_csr(),
            power_law(128, 128, 2000, 1.8, 6).to_csr(),
        ] {
            let stats = MatrixStats::of(&a);
            let algo = s.select_model(&model, &stats, 4);
            assert!(
                matches!(algo, Algo::SgapNnzGroup { .. } | Algo::SgapRowGroup { .. }),
                "model pick {} outside the sgap grid",
                algo.name()
            );
            let b = b_for(&a, 4, 3);
            algo.run(&machine, &a, &b, 4).unwrap();
            // SDDMM pick stays in vocabulary and validates
            let Algo::Sddmm(cfg) = s.select_sddmm_model(&model, &stats, 16) else {
                panic!("expected an SDDMM plan")
            };
            cfg.validate().unwrap();
            assert_eq!(cfg.j_dim, 16);
        }
        // the tensor scenarios route through the model too, with the same
        // None contract for widths no launch shape covers
        let t = Coo3::random((32, 24, 16), 400, 3);
        let Some(Algo::Mttkrp(cfg)) = s.select_mttkrp_model(&model, &t, 8) else {
            panic!("expected an MTTKRP plan")
        };
        cfg.validate().unwrap();
        assert_eq!(cfg.j_dim, 8);
        let Some(Algo::Ttm(cfg)) = s.select_ttm_model(&model, &t, 8) else {
            panic!("expected a TTM plan")
        };
        cfg.validate().unwrap();
        assert!(s.select_mttkrp_model(&model, &t, 20).is_none());
        assert!(s.select_ttm_model(&model, &t, 20).is_none());
    }

    #[test]
    fn low_cv_inputs_decline_banding() {
        let machine = Machine::new(HwProfile::rtx3090());
        let model = CostModel::new(&machine);
        let s = Selector::default();
        for a in [
            crate::sparse::banded(512, 9, 2).to_csr(),
            erdos_renyi(512, 512, 4096, 5).to_csr(),
        ] {
            let stats = MatrixStats::of(&a);
            assert!(stats.row_degree_cv < s.cv_eb_threshold, "fixture must be low-CV");
            assert!(
                s.select_banded(&model, &stats, 4).is_none(),
                "low-CV input must stay on the single-plan path"
            );
        }
    }

    #[test]
    fn skewed_inputs_band_and_composite_beats_single_under_the_model() {
        let machine = Machine::new(HwProfile::rtx3090());
        let model = CostModel::new(&machine);
        let s = Selector::default();
        let a = power_law(2048, 2048, 16384, 1.6, 1013).to_csr();
        let stats = MatrixStats::of(&a);
        assert!(stats.row_degree_cv >= s.cv_eb_threshold, "fixture must be high-CV");
        let (composite, t_composite, best_single, t_single) =
            s.banded_report(&model, &stats, 4).expect("power-law must band");
        assert!(composite.is_composite());
        assert!(!best_single.is_composite());
        assert!(t_composite.is_finite() && t_single.is_finite());
        assert!(
            t_composite <= t_single,
            "composite {t_composite} must not price above best single {t_single}"
        );
        // the gated path agrees with the report
        match s.select_banded(&model, &stats, 4) {
            Some(p) => {
                assert_eq!(p, composite);
                assert!(t_composite < t_single);
            }
            None => assert!(t_composite >= t_single),
        }
        // a selected composite is runnable and matches the oracle
        let b = b_for(&a, 4, 77);
        let res = composite.run(&machine, &a, &b, 4).unwrap();
        let want = crate::algos::cpu_ref::spmm_serial(&a, &b, 4);
        let err = crate::algos::cpu_ref::max_rel_err(&res.run.c, &want);
        assert!(err < 5e-4, "composite err {err}");
    }

    #[test]
    fn stats_paths_agree_with_tensor_paths() {
        use crate::sparse::SegStats;
        let machine = Machine::new(HwProfile::rtx3090());
        let model = CostModel::new(&machine);
        let s = Selector::default();
        for (dims, nnz, seed) in [((64, 32, 32), 8000, 1), ((64, 32, 32), 100, 2)] {
            let t = Coo3::random(dims, nnz, seed);
            let (mseg, tseg) = (SegStats::mttkrp(&t), SegStats::ttm(&t));
            for w in [4u32, 8, 20] {
                assert_eq!(s.select_mttkrp(&t, w), s.select_mttkrp_stats(&mseg, w));
                assert_eq!(s.select_ttm(&t, w), s.select_ttm_stats(&tseg, w));
                assert_eq!(
                    s.select_mttkrp_model(&model, &t, w),
                    s.select_mttkrp_model_stats(&model, &mseg, w)
                );
                assert_eq!(
                    s.select_ttm_model(&model, &t, w),
                    s.select_ttm_model_stats(&model, &tseg, w)
                );
            }
        }
    }

    #[test]
    fn regret_is_bounded() {
        let m = Machine::new(HwProfile::rtx3090());
        let s = Selector::default();
        let a = erdos_renyi(96, 96, 700, 8).to_csr();
        let b = b_for(&a, 4, 10);
        let r = s.regret(&m, &a, &b, 4).unwrap();
        assert!(r >= 1.0 - 1e-9, "regret {r} below 1");
        assert!(r < 5.0, "selector badly mis-chooses: regret {r}");
    }
}
