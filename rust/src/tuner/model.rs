//! Analytic cost model — O(stats) pricing of every [`Algo`] candidate,
//! no warp interpretation.
//!
//! `sim::exec` interprets a candidate kernel warp-by-warp: exact, but the
//! dominant cost of the coordinator's background-tuning hot path. This
//! module prices the *same* candidates in closed form from structure
//! statistics ([`MatrixStats`] / [`SegStats`]) and the *same*
//! [`CostParams`] constants the interpreter charges — sectors touched,
//! `log2(r)` shuffle steps, the width-proportional `sync_per_lane`
//! convergence overhead of Fig. 1(b), atomic serialization by address
//! multiplicity — then applies the same roofline roll-up as
//! [`Machine::launch`](crate::sim::Machine::launch):
//! `max(compute, DRAM, critical warp)`.
//!
//! The model is a leading-order *expectation* of the interpreter's
//! account, not a replica: DESIGN.md §cost-model-vs-analytic documents
//! exactly where the two diverge. Its contract is **ranking**, not
//! absolute time — [`CostModel::shortlist`] prunes a candidate grid to a
//! top-K shortlist which `tuner::search::tune_pruned` then simulates, so
//! serving-time tuning pays O(stats) per candidate over the grid and full
//! interpretation only for K survivors. The pruning-fidelity invariant
//! (shortlist contains the exhaustive winner, or the pruned winner is
//! within the documented time ratio) is enforced by
//! `rust/tests/tuner_pruning.rs`.

use crate::algos::catalog::{Algo, CompositeConfig};
use crate::algos::dgsparse::DgConfig;
use crate::algos::fused::FusedConfig;
use crate::algos::mttkrp::{MttkrpConfig, TtmConfig};
use crate::algos::sddmm::SddmmConfig;
use crate::sim::{CostParams, HwProfile, Machine};
use crate::sparse::{MatrixStats, SegStats};

/// What a candidate would run on — the statistics the pricing formulas
/// key on, one variant per scenario of the §2.1 quartet.
#[derive(Debug, Clone, Copy)]
pub enum Workload<'a> {
    /// SpMM `C = A·B` with dense width `n`.
    Spmm { stats: &'a MatrixStats, n: u32 },
    /// SDDMM with inner dense width `j`.
    Sddmm { stats: &'a MatrixStats, j: u32 },
    /// MTTKRP over row segments with factor width `j`.
    Mttkrp { seg: &'a SegStats, j: u32 },
    /// TTM over leading-fiber segments with output width `l`.
    Ttm { seg: &'a SegStats, l: u32 },
    /// Fused SDDMM→SpMM with inner dense width `j` and output width `n`.
    Fused { stats: &'a MatrixStats, j: u32, n: u32 },
}

/// Intermediate estimate in [`Machine::launch`]'s own units.
#[derive(Debug, Clone, Copy)]
struct Estimate {
    /// Total compute cycles across all warps.
    cycles: f64,
    /// Total distinct 32-byte DRAM sectors.
    sectors: f64,
    /// The most expensive single warp (cycles) — the latency bound.
    critical: f64,
}

/// The analytic pricer: hardware profile + the shared cost constants.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub hw: HwProfile,
    pub params: CostParams,
    /// Which fit the constants came from: 0 = the hand-seeded defaults,
    /// `n > 0` = the coordinator's nth refit
    /// (`coordinator::calibrate::OnlineCalibrator` bumps this through
    /// [`CostModel::calibrated`]). Pricing ignores it; executors compare
    /// it against the calibrator's generation to know when their cached
    /// model is stale.
    pub calib_generation: u64,
}

const P: f64 = 256.0; // threads per block of every compiler family
const WARP: f64 = 32.0;

impl CostModel {
    /// Price with the same profile and constants a [`Machine`] charges.
    pub fn new(machine: &Machine) -> CostModel {
        CostModel { hw: machine.hw, params: machine.params, calib_generation: 0 }
    }

    /// Price with a fitted [`Calibration`] applied on top of `machine`:
    /// the fitted `CostParams` and `launch_overhead_s` replace the
    /// machine's own, and the model is tagged with `generation` so
    /// caches can tell fits apart.
    ///
    /// [`Calibration`]: crate::tuner::calibrate::Calibration
    pub fn calibrated(
        machine: &Machine,
        calib: &crate::tuner::calibrate::Calibration,
        generation: u64,
    ) -> CostModel {
        let mut m = machine.clone();
        calib.apply(&mut m);
        CostModel { hw: m.hw, params: m.params, calib_generation: generation }
    }

    /// Estimated execution time in seconds for `algo` on `workload`.
    /// `None` when the plan kind does not serve the workload's scenario
    /// (an SpMM plan priced against an SDDMM workload, …).
    pub fn price(&self, algo: &Algo, workload: &Workload) -> Option<f64> {
        let est = match (workload, *algo) {
            (Workload::Spmm { stats, n }, Algo::SgapNnzGroup { c, r }) => {
                self.est_nnz_group(stats, *n, c, r)
            }
            (Workload::Spmm { stats, n }, Algo::TacoNnzSerial { g, c }) => {
                self.est_nnz_serial(stats, *n, g, c)
            }
            (Workload::Spmm { stats, n }, Algo::TacoRowSerial { x, c }) => {
                self.est_row_serial(stats, *n, x, c)
            }
            (Workload::Spmm { stats, n }, Algo::SgapRowGroup { g, c, r }) => {
                self.est_row_group(stats, *n, g, c, r)
            }
            (Workload::Spmm { stats, n }, Algo::Dg(cfg)) => self.est_dg(stats, *n, &cfg),
            // composites price outside the Estimate pipeline: max over
            // per-band roll-ups, each band already a complete launch
            (Workload::Spmm { stats, n }, Algo::Composite(cc)) => {
                return self.price_composite(stats, *n, &cc)
            }
            (Workload::Sddmm { stats, .. }, Algo::Sddmm(cfg)) => self.est_sddmm(stats, &cfg),
            (Workload::Fused { stats, .. }, Algo::FusedSddmmSpmm(cfg)) => {
                self.est_fused(stats, &cfg)
            }
            (Workload::Mttkrp { seg, .. }, Algo::Mttkrp(cfg)) => self.est_coo3(seg, &cfg_m(&cfg)),
            (Workload::Ttm { seg, .. }, Algo::Ttm(cfg)) => self.est_coo3(seg, &cfg_t(&cfg)),
            _ => return None,
        };
        Some(self.rollup(est))
    }

    /// Price a per-band composite plan. The bands of one logical op
    /// launch independently, so the composite costs its *slowest band* —
    /// each band priced on synthetic [`MatrixStats`] derived from the
    /// full matrix's degree histogram
    /// ([`band_stats`](crate::sparse::band_stats)) — plus one extra
    /// launch overhead per additional band. `None` if any band plan
    /// cannot be priced (never happens for [`BandAlgo`]-backed bands, by
    /// construction).
    ///
    /// [`BandAlgo`]: crate::algos::BandAlgo
    pub fn price_composite(
        &self,
        stats: &MatrixStats,
        n: u32,
        cc: &CompositeConfig,
    ) -> Option<f64> {
        let bands = (cc.bands as usize).clamp(2, 3);
        let per = crate::sparse::band_stats(stats, bands, cc.cuts);
        let mut worst = 0f64;
        for (band, bs) in per.iter().enumerate() {
            let w = Workload::Spmm { stats: bs, n };
            worst = worst.max(self.price(&cc.plan(band), &w)?);
        }
        Some(worst + (bands as f64 - 1.0) * self.hw.launch_overhead_s)
    }

    /// Prune `candidates` to the `k` cheapest under the model, cheapest
    /// first (so `shortlist[0]` is the model's top-1 pick). Candidates
    /// the model cannot price (kind mismatch) sort last; `k >= len`
    /// returns the whole grid ranked — the exhaustive escape hatch.
    pub fn shortlist(&self, candidates: &[Algo], workload: &Workload, k: usize) -> Vec<Algo> {
        let mut priced: Vec<(f64, usize, Algo)> = candidates
            .iter()
            .enumerate()
            .map(|(i, a)| (self.price(a, workload).unwrap_or(f64::INFINITY), i, *a))
            .collect();
        // stable, total order: ties broken by grid position
        priced.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        priced.truncate(k.max(1));
        priced.into_iter().map(|(_, _, a)| a).collect()
    }

    /// The [`Machine::launch`] roll-up with balanced SMs:
    /// `max(cycles/SMs/issue, sectors·32B/BW, critical warp)`.
    fn rollup(&self, e: Estimate) -> f64 {
        let clock = self.hw.clock_ghz * 1e9;
        let t_compute = e.cycles / self.hw.sm_count as f64 / self.hw.issue_width / clock;
        let t_memory = e.sectors * 32.0 / (self.hw.dram_gbps * 1e9);
        let t_latency = e.critical / clock;
        t_compute.max(t_memory).max(t_latency) + self.hw.launch_overhead_s
    }

    // ---- shared sub-formulas (expectations of the exec.rs charges) ----

    /// Serial-dot iteration: loop bookkeeping + `A·B` product
    /// (2 loads, 2 ALU, 1 branch per iteration, as `strided_row_dot` and
    /// the row/nnz-serial inner loops charge).
    fn dot_iter(&self) -> f64 {
        let p = &self.params;
        2.0 * p.load_issue + 3.0 * p.alu + p.branch
    }

    /// Expected lockstep row degree across a warp's rows: the warp pays
    /// the slowest lane, so skew (CV) inflates the mean; bounded by the
    /// true maximum.
    fn lockstep_degree(d_mean: f64, cv: f64, d_max: f64) -> f64 {
        (d_mean * (1.0 + 2.0 * cv)).clamp(d_mean, d_max.max(d_mean))
    }

    /// Segment-boundary probability between adjacent non-zeros.
    fn boundary_prob(mean_seg_len: f64) -> f64 {
        (1.0 / mean_seg_len.max(1.0)).min(1.0)
    }

    /// Fresh B-gather sectors for `entries` scattered row reads, capped by
    /// the dense operand's total footprint (`rows·width` f32 = /8 sectors).
    fn gather_sectors(entries: f64, footprint_rows: f64, width: f64) -> f64 {
        entries.min((footprint_rows * width / 8.0).max(1.0))
    }

    // ---- family estimates ----

    /// `{<1 nnz, c col>, r}` — Listing 6, grouped segment reduction.
    fn est_nnz_group(&self, s: &MatrixStats, n: u32, c: u32, r: u32) -> Estimate {
        let p = &self.params;
        let z = s.nnz as f64;
        let d = s.row_degree_mean;
        let kch = (n / c).max(1) as f64;
        let nnzb = P / kch;
        let blocks = (z / nnzb).ceil().max(1.0);
        let warps = blocks * (P / WARP);
        let pb = Self::boundary_prob(d);

        let (bs_cy, bs_sec) = p.bsearch(nnzb / d.max(1.0) + 2.0);
        let prologue = 4.0 * p.alu + 2.0 * p.load_issue + bs_cy;
        // per coarsening step: bound check + crd/pos/vals/B loads + scan
        let per_ki = 8.0 * p.alu
            + 5.0 * p.load_issue
            + 2.0 * p.branch
            + (1.0 + pb) * (p.alu + p.load_issue) // row-boundary scan
            + p.seg_scan(r)
            + p.atomic_chain((d / r as f64).clamp(1.0, WARP / r as f64));
        let per_warp = prologue + c as f64 * per_ki;

        let a_sectors = 8.0 + bs_sec + 2.0; // crd+vals coalesced, search, window
        let b_sectors = Self::gather_sectors(WARP, s.cols as f64, n as f64);
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (a_sectors + b_sectors),
            critical: per_warp,
        }
    }

    /// `{<g nnz, c col>, 1}` — Listing 3, serial with atomic flushes.
    fn est_nnz_serial(&self, s: &MatrixStats, n: u32, g: u32, c: u32) -> Estimate {
        let p = &self.params;
        let z = s.nnz as f64;
        let d = s.row_degree_mean;
        let gf = g as f64;
        let kch = (n / c).max(1) as f64;
        let nnzt = P / kch;
        let blocks = (z / (gf * nnzt)).ceil().max(1.0);
        let warps = blocks * (P / WARP);
        let pb = Self::boundary_prob(d);
        let flushes = gf * pb + 1.0; // row crossings + final flush

        let (bs_cy, bs_sec) = p.bsearch(gf * nnzt / d.max(1.0) + 2.0);
        let prologue = 4.0 * p.alu + 2.0 * p.load_issue + bs_cy;
        let per_ki = gf * (3.0 * p.alu + 2.0 * p.load_issue + p.branch)
            + flushes * (2.0 * p.alu + p.load_issue)
            + flushes * p.atomic_chain((d / gf).clamp(1.0, WARP));
        let per_warp = prologue + c as f64 * per_ki;

        let a_sectors = 8.0 * gf + bs_sec + 2.0;
        let b_sectors = Self::gather_sectors(WARP * gf, s.cols as f64, n as f64);
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (a_sectors + b_sectors),
            critical: per_warp,
        }
    }

    /// `{<x row, c col>, 1}` — Listing 4, one thread per row, plain store.
    fn est_row_serial(&self, s: &MatrixStats, n: u32, x: u32, c: u32) -> Estimate {
        let p = &self.params;
        let m = s.rows as f64;
        let d = s.row_degree_mean;
        let d_lock = Self::lockstep_degree(d, s.row_degree_cv, s.row_degree_max as f64);
        let kch = (n / c).max(1) as f64;
        let rowt = P / kch;
        let blocks = (m / (x as f64 * rowt)).ceil().max(1.0);
        let warps = blocks * (P / WARP);

        // per (xi, ki): the whole row serially (lockstep max) + store
        let row_cy = d_lock * self.dot_iter() + p.load_issue + 4.0 * p.alu;
        let per_warp = 4.0 * p.alu + (x as f64 * c as f64) * row_cy;
        let critical =
            4.0 * p.alu + (x as f64 * c as f64) * (s.row_degree_max as f64 * self.dot_iter());

        // A entries of the warp's 32·x rows + scattered B + C stores
        let entries = WARP * x as f64 * d;
        let a_sectors = 2.0 * entries / 8.0 + 2.0;
        let b_sectors = Self::gather_sectors(entries, s.cols as f64, n as f64);
        let c_sectors = c as f64 * x as f64 * 4.0;
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (a_sectors + b_sectors + c_sectors),
            critical: critical.max(per_warp),
        }
    }

    /// `{<1/g row, c col>, r}` — Listing 5, grouped parallel reduction.
    fn est_row_group(&self, s: &MatrixStats, n: u32, g: u32, c: u32, r: u32) -> Estimate {
        let p = &self.params;
        let m = s.rows as f64;
        let d = s.row_degree_mean;
        let gf = g as f64;
        let kch = (n / c).max(1) as f64;
        let rpb = (P / (gf * kch)).max(1.0);
        let blocks = (m / rpb).ceil().max(1.0);
        let warps = blocks * (P / WARP);
        let d_lock = Self::lockstep_degree(d, s.row_degree_cv, s.row_degree_max as f64);
        let trips = (d_lock / gf).ceil();
        // g/r aligned subgroups share one output address — the partial
        // results serialize on it (max multiplicity in the interpreter)
        let wb_mult = (gf / r as f64).max(1.0);

        let per_ki = 4.0 * p.alu
            + 2.0 * p.load_issue // row window
            + trips * self.dot_iter()
            + p.par_reduce(r)
            + p.atomic_chain(wb_mult);
        let per_warp = 6.0 * p.alu + c as f64 * per_ki;
        let crit_trips = (s.row_degree_max as f64 / gf).ceil();
        let critical = 6.0 * p.alu
            + c as f64
                * (crit_trips * self.dot_iter() + p.par_reduce(r) + p.atomic_chain(wb_mult));

        let rows_in_warp = (WARP / (gf * kch)).max(1.0);
        let entries = rows_in_warp * d;
        let a_sectors = 2.0 * entries / 8.0 + 2.0;
        let b_sectors = Self::gather_sectors(entries, s.cols as f64, n as f64);
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (a_sectors + b_sectors),
            critical: critical.max(per_warp),
        }
    }

    /// dgSPARSE RB+PR+RM `<groupSz, blockSz, tileSz, workerDimR>`.
    fn est_dg(&self, s: &MatrixStats, _n: u32, cfg: &DgConfig) -> Estimate {
        let p = &self.params;
        let m = s.rows as f64;
        let d = s.row_degree_mean;
        let ws = cfg.worker_sz as f64;
        let coarsen = cfg.coarsen_sz as f64;
        let vcols = cfg.vcols().max(1) as f64;
        let col_tiles = cfg.col_tiles().max(1) as f64;
        let d_lock = Self::lockstep_degree(d, s.row_degree_cv, s.row_degree_max as f64);

        // one unit = one (row, vcol, col-tile) strided dot; the dot and the
        // grouped writeback repeat per coarsened column
        let unit_cy = coarsen
            * (2.0 * p.alu
                + (d_lock / ws).ceil() * self.dot_iter()
                + p.par_reduce(cfg.group_sz)
                + p.atomic_chain((ws / cfg.group_sz as f64).max(1.0)));
        let units = m * vcols * col_tiles;
        let cycles = units * unit_cy * (ws / WARP);

        // RB latency: a worker owning ceil(rows / workerDimR) visits of the
        // worst row is the critical path
        let visits = (m / cfg.worker_dim_r(s.rows).max(1) as f64).ceil().max(1.0);
        let critical = visits
            * coarsen
            * ((s.row_degree_max as f64 / ws).ceil() * self.dot_iter()
                + p.par_reduce(cfg.group_sz));

        // every (vcol, col-tile) warp re-reads its row's A entries; B is a
        // scattered gather per entry visit
        let a_sectors = units * (2.0 * d / 8.0 + 2.0);
        let b_sectors =
            Self::gather_sectors(units * d, s.cols as f64, cfg.n as f64).max(units * d / 8.0);
        Estimate { cycles, sectors: a_sectors + b_sectors, critical }
    }

    /// SDDMM `{<1/g nnz>, r}` — grouped dense-`j` dot per non-zero.
    fn est_sddmm(&self, s: &MatrixStats, cfg: &SddmmConfig) -> Estimate {
        let p = &self.params;
        let z = s.nnz as f64;
        let j = cfg.j_dim as f64;
        let gf = cfg.g as f64;
        let npb = cfg.npb() as f64;
        let blocks = (z / npb).ceil().max(1.0);
        let warps = blocks * (cfg.p as f64 / WARP);
        let iters = (j / gf).ceil().max(1.0);

        let per_warp = 6.0 * p.alu
            + 3.0 * p.load_issue // rowidx, crd, vals
            + iters * (2.0 * p.load_issue + 3.0 * p.alu + p.branch)
            + p.alu // scale by A
            + p.par_reduce(cfg.r)
            + p.atomic_chain((gf / cfg.r as f64).max(1.0));

        let groups = WARP / gf; // non-zeros per warp
        // rowidx/crd/vals: 32/g consecutive positions per warp, coalesced
        let meta_sectors = 3.0 * (groups / 8.0).max(1.0);
        // X1 row read coalesced across the group's lanes; X2 column reads
        // stride the row dimension — one sector per (j, k) touch
        let x1_sectors = groups * (j / 8.0).max(1.0);
        let x2_sectors = Self::gather_sectors(groups * j, j, s.cols as f64);
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (meta_sectors + x1_sectors + x2_sectors),
            critical: per_warp,
        }
    }

    /// Fused SDDMM→SpMM `{<1 nnz, c col>, r}` — the nnz-group skeleton
    /// with the producer's dense-`j` dot charged **once per non-zero**
    /// (hoisted out of the column loop, as the lowered kernel does) and
    /// the intermediate's write-then-reread traffic entirely absent: one
    /// traversal of `pos/crd`, one launch overhead. This one-traversal
    /// pricing is what makes the pruner prefer fusion over the two-stage
    /// pipeline whenever the dot cost doesn't dominate.
    fn est_fused(&self, s: &MatrixStats, cfg: &FusedConfig) -> Estimate {
        let p = &self.params;
        let z = s.nnz as f64;
        let d = s.row_degree_mean;
        let j = cfg.j_dim as f64;
        let (c, r, n) = (cfg.c, cfg.r, cfg.n);
        let kch = (n / c).max(1) as f64;
        let nnzb = P / kch;
        let blocks = (z / nnzb).ceil().max(1.0);
        let warps = blocks * (P / WARP);
        let pb = Self::boundary_prob(d);

        let (bs_cy, bs_sec) = p.bsearch(nnzb / d.max(1.0) + 2.0);
        // hoisted producer work: row-boundary scan, the dense-j dot, and
        // the A scaling — paid once per non-zero, not per coarsened column
        let prologue = 4.0 * p.alu
            + 2.0 * p.load_issue
            + bs_cy
            + (1.0 + pb) * (p.alu + p.load_issue) // row-boundary scan
            + j * self.dot_iter()
            + p.alu; // scale by A
        // per coarsening step: bound check + crd/B loads + segment scan
        let per_ki = 8.0 * p.alu
            + 4.0 * p.load_issue
            + 2.0 * p.branch
            + p.seg_scan(r)
            + p.atomic_chain((d / r as f64).clamp(1.0, WARP / r as f64));
        let per_warp = prologue + c as f64 * per_ki;

        let a_sectors = 8.0 + bs_sec + 2.0; // crd+vals coalesced, search, window
        let b_sectors = Self::gather_sectors(WARP, s.cols as f64, n as f64);
        // the producer's dense factors: each nnz lane reads one X1 row
        // (coalesced within the row) and gathers one X2 column
        let x1_sectors = Self::gather_sectors(WARP * (j / 8.0).max(1.0), s.rows as f64, j);
        let x2_sectors = Self::gather_sectors(WARP * j, j, s.cols as f64);
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (a_sectors + b_sectors + x1_sectors + x2_sectors),
            critical: per_warp,
        }
    }

    /// COO-3 `{<1 nnz, c col>, r}` — the shared MTTKRP/TTM segment shape.
    fn est_coo3(&self, seg: &SegStats, cfg: &Coo3Shape) -> Estimate {
        let p = &self.params;
        let z = seg.nnz as f64;
        // the atomic-serialization key is the *used*-segment mean: empty
        // segments never separate two adjacent stored non-zeros
        let used = (seg.segments as f64 * (1.0 - seg.empty_frac)).max(1.0);
        let d_used = z / used;
        let kch = (cfg.width / cfg.c).max(1) as f64;
        let npb = P / kch;
        let blocks = (z / npb).ceil().max(1.0);
        let warps = blocks * (P / WARP);
        let r = cfg.r;

        let factors = if cfg.with_x2 { 2.0 } else { 1.0 };
        let loads = 2.0 + 2.0 * factors; // bound check + vals + idx/X per factor
        let per_ki = 8.0 * p.alu
            + loads * p.load_issue
            + 2.0 * p.branch
            + p.seg_scan(r)
            + p.atomic_chain((d_used / r as f64).clamp(1.0, WARP / r as f64));
        let per_warp = 6.0 * p.alu + p.load_issue + cfg.c as f64 * per_ki;

        let meta_sectors = 8.0 + 4.0 * factors; // seg_ids/A_vals + f-idx, coalesced
        let x_sectors = factors * WARP; // factor-row gathers, scattered
        Estimate {
            cycles: warps * per_warp,
            sectors: warps * (meta_sectors + x_sectors),
            critical: per_warp,
        }
    }
}

/// The shared shape of the two COO-3 families.
struct Coo3Shape {
    width: u32,
    c: u32,
    r: u32,
    with_x2: bool,
}

fn cfg_m(cfg: &MttkrpConfig) -> Coo3Shape {
    Coo3Shape { width: cfg.j_dim, c: cfg.c, r: cfg.r, with_x2: true }
}

fn cfg_t(cfg: &TtmConfig) -> Coo3Shape {
    Coo3Shape { width: cfg.l_dim, c: cfg.c, r: cfg.r, with_x2: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{banded, erdos_renyi, power_law, Coo3};
    use crate::tuner::space::{mttkrp_candidates, sddmm_candidates, sgap_candidates, taco_candidates};

    fn model() -> CostModel {
        CostModel::new(&Machine::new(HwProfile::rtx3090()))
    }

    #[test]
    fn calibrated_model_prices_with_the_fitted_constants() {
        let machine = Machine::new(HwProfile::rtx3090());
        let mut cal = crate::tuner::calibrate::Calibration::identity(&machine);
        cal.params.load_issue *= 2.0;
        cal.launch_overhead_s *= 3.0;
        let m = CostModel::calibrated(&machine, &cal, 7);
        assert_eq!(m.calib_generation, 7);
        assert_eq!(m.params.load_issue, machine.params.load_issue * 2.0);
        assert_eq!(m.hw.launch_overhead_s, machine.hw.launch_overhead_s * 3.0);
        // and the applied constants change actual prices
        let a = erdos_renyi(256, 256, 2000, 1).to_csr();
        let stats = MatrixStats::of(&a);
        let w = Workload::Spmm { stats: &stats, n: 4 };
        let plan = Algo::SgapNnzGroup { c: 4, r: 8 };
        let base = CostModel::new(&machine).price(&plan, &w).unwrap();
        let fitted = m.price(&plan, &w).unwrap();
        assert!(fitted > base, "doubled load_issue must not price cheaper: {fitted} vs {base}");
    }

    #[test]
    fn prices_every_spmm_candidate_finite_and_positive() {
        let m = model();
        for a in [
            erdos_renyi(256, 256, 2000, 1).to_csr(),
            power_law(256, 256, 4000, 1.8, 2).to_csr(),
        ] {
            let stats = MatrixStats::of(&a);
            let w = Workload::Spmm { stats: &stats, n: 4 };
            let mut cands = taco_candidates(4);
            cands.extend(sgap_candidates(4));
            cands.extend(crate::tuner::space::dg_candidates_small(4));
            for c in &cands {
                let t = m.price(c, &w).unwrap();
                assert!(t.is_finite() && t > 0.0, "{}: {t}", c.name());
            }
        }
    }

    #[test]
    fn kind_mismatch_prices_none() {
        let m = model();
        let a = erdos_renyi(64, 64, 300, 1).to_csr();
        let stats = MatrixStats::of(&a);
        let spmm = Workload::Spmm { stats: &stats, n: 4 };
        let sddmm = Workload::Sddmm { stats: &stats, j: 16 };
        let plan = Algo::Sddmm(crate::algos::sddmm::SddmmConfig::new(16, 8, 4));
        assert!(m.price(&plan, &spmm).is_none());
        assert!(m.price(&plan, &sddmm).is_some());
        assert!(m.price(&Algo::SgapNnzGroup { c: 4, r: 8 }, &sddmm).is_none());
    }

    #[test]
    fn short_rows_prefer_narrow_groups() {
        // mean degree 2: the Fig. 1(b) trade-off — r=4 must price below
        // r=32 in both grouped families (the term is the shared
        // group_reduce, so this mirrors the simulator by construction)
        let m = model();
        let a = erdos_renyi(512, 512, 1024, 3).to_csr();
        let stats = MatrixStats::of(&a);
        let w = Workload::Spmm { stats: &stats, n: 4 };
        let t4 = m.price(&Algo::SgapNnzGroup { c: 4, r: 4 }, &w).unwrap();
        let t32 = m.price(&Algo::SgapNnzGroup { c: 4, r: 32 }, &w).unwrap();
        assert!(t4 < t32, "nnz-group: r=4 {t4} !< r=32 {t32}");
        let g4 = m.price(&Algo::SgapRowGroup { g: 32, c: 4, r: 4 }, &w).unwrap();
        let g32 = m.price(&Algo::SgapRowGroup { g: 32, c: 4, r: 32 }, &w).unwrap();
        assert!(g4 < g32, "row-group: r=4 {g4} !< r=32 {g32}");
    }

    #[test]
    fn skew_penalizes_row_split() {
        // same size/nnz, one uniform and one hub-heavy: the row-split
        // lockstep/critical terms must price the skewed input worse
        // relative to the nnz-balanced kernel
        let m = model();
        let uni = banded(1024, 9, 1).to_csr();
        let skew = power_law(1024, 1024, 9 * 1024, 2.2, 1).to_csr();
        let (su, ss) = (MatrixStats::of(&uni), MatrixStats::of(&skew));
        let wu = Workload::Spmm { stats: &su, n: 4 };
        let ws = Workload::Spmm { stats: &ss, n: 4 };
        let row = Algo::SgapRowGroup { g: 32, c: 4, r: 8 };
        let nnz = Algo::SgapNnzGroup { c: 4, r: 8 };
        let ratio_uni = m.price(&row, &wu).unwrap() / m.price(&nnz, &wu).unwrap();
        let ratio_skew = m.price(&row, &ws).unwrap() / m.price(&nnz, &ws).unwrap();
        assert!(
            ratio_skew > ratio_uni,
            "skew must hurt row-split: uniform {ratio_uni} vs skewed {ratio_skew}"
        );
    }

    #[test]
    fn composite_prices_finite_and_only_for_spmm() {
        use crate::algos::catalog::{BandAlgo, CompositeConfig};
        use crate::sparse::choose_cuts;
        let m = model();
        let a = power_law(512, 512, 8192, 1.8, 3).to_csr();
        let stats = MatrixStats::of(&a);
        let (bands, cuts) = choose_cuts(&stats).unwrap();
        let cc = CompositeConfig {
            bands: bands as u8,
            cuts,
            plans: [
                BandAlgo::TacoRowSerial { x: 1, c: 4 },
                BandAlgo::SgapRowGroup { g: 8, c: 4, r: 8 },
                BandAlgo::SgapNnzGroup { c: 4, r: 32 },
            ],
        };
        let plan = Algo::Composite(cc);
        let w = Workload::Spmm { stats: &stats, n: 4 };
        let t = m.price(&plan, &w).unwrap();
        assert!(t.is_finite() && t > 0.0);
        // max-over-bands: the composite costs at least one band's price
        // and at least the extra launch overheads
        assert!(t > m.hw.launch_overhead_s * bands as f64);
        // non-SpMM workloads cannot be served by a composite
        let sddmm = Workload::Sddmm { stats: &stats, j: 16 };
        assert!(m.price(&plan, &sddmm).is_none());
    }

    #[test]
    fn shortlist_is_sorted_truncated_and_keeps_model_top1_first() {
        let m = model();
        let a = erdos_renyi(256, 256, 2000, 5).to_csr();
        let stats = MatrixStats::of(&a);
        let w = Workload::Spmm { stats: &stats, n: 4 };
        let cands = sgap_candidates(4);
        let k = 6;
        let short = m.shortlist(&cands, &w, k);
        assert_eq!(short.len(), k.min(cands.len()));
        let prices: Vec<f64> = short.iter().map(|c| m.price(c, &w).unwrap()).collect();
        for p in prices.windows(2) {
            assert!(p[0] <= p[1], "shortlist not sorted: {p:?}");
        }
        // escape hatch: k >= grid returns everything, still ranked
        let all = m.shortlist(&cands, &w, cands.len() + 10);
        assert_eq!(all.len(), cands.len());
        assert_eq!(all[0], short[0], "top-1 stable across k");
        // every survivor is cheaper (or equal) than every pruned candidate
        let cutoff = prices.last().copied().unwrap();
        for c in cands.iter().filter(|c| !short.contains(c)) {
            assert!(m.price(c, &w).unwrap() >= cutoff, "{} pruned but cheap", c.name());
        }
    }

    #[test]
    fn sddmm_narrow_reduction_prices_below_wide() {
        // at fixed g in the compute-bound regime (small j), the
        // reduction-width axis mirrors the simulator's own par_reduce
        // charge: r=2 must price below r=32 (at wide j the X2 gather
        // makes every r memory-bound — ties, not inversions)
        let m = model();
        let a = erdos_renyi(128, 128, 1000, 7).to_csr();
        let stats = MatrixStats::of(&a);
        let w = Workload::Sddmm { stats: &stats, j: 4 };
        let narrow = m.price(&Algo::Sddmm(SddmmConfig::new(4, 32, 2)), &w).unwrap();
        let wide = m.price(&Algo::Sddmm(SddmmConfig::new(4, 32, 32)), &w).unwrap();
        assert!(narrow < wide, "j=4 g=32: r=2 {narrow} !< r=32 {wide}");
        let short = m.shortlist(&sddmm_candidates(4), &w, 4);
        assert_eq!(short.len(), 4);
        assert!(short.iter().all(|c| matches!(c, Algo::Sddmm(_))));
    }

    #[test]
    fn fused_prices_one_traversal_below_the_two_stage_pipeline() {
        let m = model();
        let a = power_law(2048, 2048, 40_000, 1.9, 11).to_csr();
        let stats = MatrixStats::of(&a);
        let (j, n) = (32u32, 32u32);
        let wf = Workload::Fused { stats: &stats, j, n };
        let fused = Algo::FusedSddmmSpmm(FusedConfig::new(j, n, 4, 8));
        let t_fused = m.price(&fused, &wf).unwrap();
        assert!(t_fused.is_finite() && t_fused > 0.0);
        // kind mismatches price None both ways
        assert!(m.price(&fused, &Workload::Spmm { stats: &stats, n }).is_none());
        assert!(m.price(&Algo::SgapNnzGroup { c: 4, r: 8 }, &wf).is_none());
        // the payoff the pruner sees: one traversal + one launch must not
        // exceed SDDMM-then-SpMM, which pays the intermediate and a second
        // pass over pos/crd
        let t_sddmm = m
            .price(
                &Algo::Sddmm(SddmmConfig::new(j, 32, 8)),
                &Workload::Sddmm { stats: &stats, j },
            )
            .unwrap();
        let t_spmm = m
            .price(&Algo::SgapNnzGroup { c: 4, r: 8 }, &Workload::Spmm { stats: &stats, n })
            .unwrap();
        assert!(
            t_fused <= t_sddmm + t_spmm,
            "fused {t_fused} !<= two-stage {}",
            t_sddmm + t_spmm
        );
    }

    #[test]
    fn coo3_pricing_keys_on_segment_length() {
        let m = model();
        // long segments (dense rows): wide r amortizes; short segments:
        // narrow r wins — same trade-off the sim shows in tuner tests
        let dense = Coo3::random((16, 32, 32), 8000, 1);
        let sparse = Coo3::random((512, 32, 32), 600, 2);
        let (sd, ss) = (crate::sparse::SegStats::mttkrp(&dense), crate::sparse::SegStats::mttkrp(&sparse));
        let wd = Workload::Mttkrp { seg: &sd, j: 8 };
        let wsp = Workload::Mttkrp { seg: &ss, j: 8 };
        let narrow = Algo::Mttkrp(MttkrpConfig::new(8, 4, 2));
        let wide = Algo::Mttkrp(MttkrpConfig::new(8, 4, 32));
        let gain_dense =
            m.price(&narrow, &wd).unwrap() / m.price(&wide, &wd).unwrap();
        let gain_sparse =
            m.price(&narrow, &wsp).unwrap() / m.price(&wide, &wsp).unwrap();
        assert!(
            gain_sparse < gain_dense,
            "short segments must favor narrow r more: dense {gain_dense} sparse {gain_sparse}"
        );
        let short = m.shortlist(&mttkrp_candidates(8), &wsp, 5);
        assert_eq!(short.len(), 5);
        assert!(short.iter().all(|c| c.is_mttkrp()));
    }
}
