//! Auto-tuning over the atomic-parallelism space (§7) and the
//! input-dynamics selector (the DA-SpMM-style "dynamic choice" of Table 5).

pub mod search;
pub mod selector;
pub mod space;

pub use search::{
    tune, tune_mttkrp, tune_mttkrp_ranked, tune_sddmm, tune_sddmm_ranked, tune_ttm,
    tune_ttm_ranked, TuneOutcome,
};
pub use selector::Selector;
pub use space::{
    dg_candidates, mttkrp_candidates, sddmm_candidates, sgap_candidates, taco_candidates,
    ttm_candidates,
};
