//! Auto-tuning over the atomic-parallelism space (§7) and the
//! input-dynamics selector (the DA-SpMM-style "dynamic choice" of Table 5).
//!
//! Two pricing tiers: [`model`] is the analytic cost model (O(stats) per
//! candidate, no warp interpretation) used to prune grids and drive the
//! selector's model-argmin fast path; [`search`] simulates — exhaustively
//! via `tune*`, or over a model-pruned shortlist via `tune*_pruned`.
//! [`calibrate`] closes the loop the other way: it fits the model's
//! constants to measured latencies (offline via `sgap profile`, online
//! via the coordinator's drift tracker).

pub mod calibrate;
pub mod model;
pub mod search;
pub mod selector;
pub mod space;

pub use calibrate::{fit, spearman, Calibration, Sample, WorkloadSpec, CALIBRATION_SCHEMA_VERSION};
pub use model::{CostModel, Workload};
pub use search::{
    calibrated_machine, tune, tune_banded, tune_fused, tune_fused_pruned, tune_fused_ranked,
    tune_mttkrp, tune_mttkrp_pruned, tune_mttkrp_ranked, tune_pruned, tune_sddmm, tune_sddmm_pruned,
    tune_sddmm_ranked, tune_ttm, tune_ttm_pruned, tune_ttm_ranked, PrunedOutcome, TuneOutcome,
    DEFAULT_TOP_K,
};
pub use selector::Selector;
pub use space::{
    band_candidates, dg_candidates, fused_candidates, mttkrp_candidates, sddmm_candidates,
    sgap_candidates, taco_candidates, ttm_candidates,
};
