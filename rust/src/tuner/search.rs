//! Grid-search driver: run a candidate list on a matrix, rank by simulated
//! time. Candidates are independent, so the sweep fans out across OS
//! threads (numerics stay deterministic — each run owns its memory).
//!
//! The `*_pruned` entry points are the cheap path: the analytic
//! [`CostModel`] prices the whole grid in O(stats) per candidate and only
//! the top-K shortlist is simulated. `top_k = 0` (or `>= grid`) is the
//! escape hatch back to exhaustive search.

use anyhow::{Context, Result};

use crate::algos::catalog::{Algo, AlgoResult};
use crate::sim::Machine;
use crate::sparse::coo3::Coo3;
use crate::sparse::{Csr, MatrixStats, SegStats};

use super::calibrate::Calibration;
use super::model::{CostModel, Workload};

/// Shortlist size the serving layer prunes candidate grids to by default
/// (the SpMM grid is ~4–8× larger; see DESIGN.md §cost-model-vs-analytic).
pub const DEFAULT_TOP_K: usize = 8;

/// The machine every `tune*` entry point should be handed when a fitted
/// [`Calibration`] is live: the fit's `CostParams` + `launch_overhead_s`
/// applied on top of `machine`. Both the analytic shortlist pricing and
/// the warp simulation of the survivors read the returned machine's
/// constants, so one call here keeps model and simulator consistent —
/// there is deliberately no per-call `calib` parameter on the `tune*`
/// family. `None` returns the machine unchanged.
pub fn calibrated_machine(machine: &Machine, calib: Option<&Calibration>) -> Machine {
    let mut m = machine.clone();
    if let Some(c) = calib {
        c.apply(&mut m);
    }
    m
}

/// Outcome of tuning one matrix: all results, sorted fastest-first.
#[derive(Debug)]
pub struct TuneOutcome {
    /// `(algo, time_s, gflops)` sorted ascending by time.
    pub ranked: Vec<(Algo, f64, f64)>,
}

impl TuneOutcome {
    /// The fastest plan and its simulated time; `None` for an empty sweep
    /// (every `tune*` constructor rejects empty candidate lists, so a
    /// `TuneOutcome` built by this module always has a winner — the
    /// `Option` guards hand-built or filtered outcomes).
    pub fn best(&self) -> Option<(Algo, f64)> {
        self.ranked.first().map(|&(a, t, _)| (a, t))
    }

    /// Time of a specific algorithm in this sweep, if present.
    pub fn time_of(&self, algo: &Algo) -> Option<f64> {
        self.ranked.iter().find(|(a, _, _)| a == algo).map(|&(_, t, _)| t)
    }
}

/// Outcome of a model-pruned sweep: the simulated ranking of the
/// survivors plus the pruning audit trail the metrics layer exposes.
#[derive(Debug)]
pub struct PrunedOutcome {
    /// Simulated results over the shortlist, fastest-first.
    pub outcome: TuneOutcome,
    /// Full grid size before pruning.
    pub grid: usize,
    /// Candidates actually simulated (`== grid` on the escape hatch).
    pub survivors: usize,
    /// Whether the model's top-1 pick also won the simulated shortlist —
    /// the prune-accuracy signal the coordinator's `Metrics::on_tune`
    /// counts.
    pub model_rank_agree: bool,
}

impl PrunedOutcome {
    pub fn best(&self) -> Option<(Algo, f64)> {
        self.outcome.best()
    }
}

/// Resolve the shortlist for a grid: `top_k == 0` or `top_k >= len` means
/// exhaustive (but still model-ranked, so `shortlist[0]` is the model's
/// pick and rank agreement stays meaningful).
fn shortlist_for(
    model: &CostModel,
    candidates: &[Algo],
    workload: &Workload,
    top_k: usize,
) -> Vec<Algo> {
    let k = if top_k == 0 { candidates.len() } else { top_k.min(candidates.len()) };
    model.shortlist(candidates, workload, k)
}

fn pruned_outcome(outcome: TuneOutcome, grid: usize, shortlist: &[Algo]) -> PrunedOutcome {
    let model_rank_agree = match (outcome.best(), shortlist.first()) {
        (Some((winner, _)), Some(top)) => winner == *top,
        _ => false,
    };
    PrunedOutcome { outcome, grid, survivors: shortlist.len(), model_rank_agree }
}

/// Number of worker threads for sweeps (bounded; sweeps are CPU-heavy).
fn workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run every candidate on `(a, b)`; errors in individual candidates are
/// propagated (the grids are pre-validated, so any failure is a bug).
pub fn tune(machine: &Machine, candidates: &[Algo], a: &Csr, b: &[f32], n: u32) -> Result<TuneOutcome> {
    let nw = workers().min(candidates.len().max(1));
    let results: Vec<Result<(Algo, AlgoResult)>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for chunk in candidates.chunks(candidates.len().div_ceil(nw).max(1)) {
            handles.push(scope.spawn(move || {
                chunk
                    .iter()
                    .map(|alg| alg.run(machine, a, b, n).map(|r| (*alg, r)))
                    .collect::<Vec<_>>()
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("tuner worker panicked")).collect()
    });

    let mut ranked = Vec::with_capacity(results.len());
    for r in results {
        let (alg, res) = r?;
        ranked.push((alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    anyhow::ensure!(!ranked.is_empty(), "no candidates supplied");
    Ok(TuneOutcome { ranked })
}

/// Model-pruned SpMM sweep: price the grid analytically, simulate only
/// the `top_k` cheapest (see [`DEFAULT_TOP_K`]; `0` = exhaustive).
pub fn tune_pruned(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    b: &[f32],
    n: u32,
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let stats = MatrixStats::of(a);
    let model = CostModel::new(machine);
    let short = shortlist_for(&model, candidates, &Workload::Spmm { stats: &stats, n }, top_k);
    let outcome = tune(machine, &short, a, b, n)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

/// [`tune_pruned`] plus the banded composite candidate: when the
/// selector's partitioner produces a composite that prices strictly below
/// the best single plan (`Selector::banded_plan`), it joins the shortlist
/// and competes in the simulated ranking like any other candidate — the
/// coordinator's background tuner can therefore *upgrade* a skewed key to
/// a composite, and low-CV inputs (where banding declines) follow exactly
/// the [`tune_pruned`] path.
pub fn tune_banded(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    b: &[f32],
    n: u32,
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let stats = MatrixStats::of(a);
    let model = CostModel::new(machine);
    let workload = Workload::Spmm { stats: &stats, n };
    let mut short = shortlist_for(&model, candidates, &workload, top_k);
    let selector = super::selector::Selector::default();
    if let Some(composite) = selector.select_banded(&model, &stats, n) {
        // model says banding pays: the composite leads the shortlist (it
        // priced below every single plan, so it is the model's top-1);
        // the worst single survivor drops so the simulated budget is
        // unchanged (survivors never exceeds the top_k contract)
        let cap = short.len();
        short.insert(0, composite);
        short.truncate(cap.max(1));
    }
    let outcome = tune(machine, &short, a, b, n)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

/// Sweep SDDMM plans (unified [`Algo::Sddmm`] vocabulary) on
/// `(a, x1, x2)`; returns all results sorted fastest-first. Serial on
/// purpose: this runs on the coordinator's single background-refinement
/// thread, where stealing cores from the serving workers would defeat the
/// point.
pub fn tune_sddmm_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_sddmm(machine, a, x1, x2)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest SDDMM plan and its simulated time.
pub fn tune_sddmm(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<(Algo, f64)> {
    tune_sddmm_ranked(machine, candidates, a, x1, x2)?
        .best()
        .context("empty SDDMM sweep")
}

/// Model-pruned SDDMM sweep (serial, like [`tune_sddmm_ranked`]).
pub fn tune_sddmm_pruned(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let stats = MatrixStats::of(a);
    let j = candidates
        .iter()
        .find_map(|c| match c {
            Algo::Sddmm(cfg) => Some(cfg.j_dim),
            _ => None,
        })
        .unwrap_or(1);
    let model = CostModel::new(machine);
    let short = shortlist_for(&model, candidates, &Workload::Sddmm { stats: &stats, j }, top_k);
    let outcome = tune_sddmm_ranked(machine, &short, a, x1, x2)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

/// Sweep fused SDDMM→SpMM plans ([`Algo::FusedSddmmSpmm`]) on
/// `(a, x1, x2, b)`; returns all results sorted fastest-first. Serial,
/// like every background-refinement sweep.
pub fn tune_fused_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
    b: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_fused(machine, a, x1, x2, b)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest fused SDDMM→SpMM plan and its simulated time.
pub fn tune_fused(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
    b: &[f32],
) -> Result<(Algo, f64)> {
    tune_fused_ranked(machine, candidates, a, x1, x2, b)?
        .best()
        .context("empty fused sweep")
}

/// Model-pruned fused sweep (serial, like [`tune_fused_ranked`]).
pub fn tune_fused_pruned(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
    b: &[f32],
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let stats = MatrixStats::of(a);
    let (j, n) = candidates
        .iter()
        .find_map(|c| match c {
            Algo::FusedSddmmSpmm(cfg) => Some((cfg.j_dim, cfg.n)),
            _ => None,
        })
        .unwrap_or((1, 1));
    let model = CostModel::new(machine);
    let short =
        shortlist_for(&model, candidates, &Workload::Fused { stats: &stats, j, n }, top_k);
    let outcome = tune_fused_ranked(machine, &short, a, x1, x2, b)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

/// Sweep MTTKRP plans ([`Algo::Mttkrp`]) on `(a, x1, x2)`; returns all
/// results sorted fastest-first. Serial for the same reason as
/// [`tune_sddmm_ranked`]: it runs on the coordinator's single
/// background-refinement thread.
pub fn tune_mttkrp_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_mttkrp(machine, a, x1, x2)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest MTTKRP plan and its simulated time.
pub fn tune_mttkrp(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
) -> Result<(Algo, f64)> {
    tune_mttkrp_ranked(machine, candidates, a, x1, x2)?
        .best()
        .context("empty MTTKRP sweep")
}

/// Model-pruned MTTKRP sweep over the COO-3 segment grid.
pub fn tune_mttkrp_pruned(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let seg = SegStats::mttkrp(a);
    let j = candidates
        .iter()
        .find_map(|c| match c {
            Algo::Mttkrp(cfg) => Some(cfg.j_dim),
            _ => None,
        })
        .unwrap_or(1);
    let model = CostModel::new(machine);
    let short = shortlist_for(&model, candidates, &Workload::Mttkrp { seg: &seg, j }, top_k);
    let outcome = tune_mttkrp_ranked(machine, &short, a, x1, x2)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

/// Sweep TTM plans ([`Algo::Ttm`]) on `(a, x1)`; fastest-first.
pub fn tune_ttm_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_ttm(machine, a, x1)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest TTM plan and its simulated time.
pub fn tune_ttm(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
) -> Result<(Algo, f64)> {
    tune_ttm_ranked(machine, candidates, a, x1)?.best().context("empty TTM sweep")
}

/// Model-pruned TTM sweep over the COO-3 fiber grid.
pub fn tune_ttm_pruned(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    top_k: usize,
) -> Result<PrunedOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let seg = SegStats::ttm(a);
    let l = candidates
        .iter()
        .find_map(|c| match c {
            Algo::Ttm(cfg) => Some(cfg.l_dim),
            _ => None,
        })
        .unwrap_or(1);
    let model = CostModel::new(machine);
    let short = shortlist_for(&model, candidates, &Workload::Ttm { seg: &seg, l }, top_k);
    let outcome = tune_ttm_ranked(machine, &short, a, x1)?;
    Ok(pruned_outcome(outcome, candidates.len(), &short))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, SplitMix64};
    use crate::tuner::space::{sddmm_candidates, sgap_candidates};

    #[test]
    fn calibrated_machine_applies_the_fit_to_sim_and_model_alike() {
        let machine = Machine::new(HwProfile::rtx3090());
        assert_eq!(
            calibrated_machine(&machine, None).params.to_array(),
            machine.params.to_array()
        );
        let mut cal = Calibration::identity(&machine);
        cal.params.shfl = 5.0;
        cal.launch_overhead_s = 1.0e-8;
        let m = calibrated_machine(&machine, Some(&cal));
        assert_eq!(m.params.shfl, 5.0);
        assert_eq!(m.hw.launch_overhead_s, 1.0e-8);
        // one machine feeds both tiers, so they see the same constants
        assert_eq!(CostModel::new(&m).params.shfl, 5.0);
    }

    #[test]
    fn tune_ranks_candidates() {
        let a = erdos_renyi(128, 128, 1024, 3).to_csr();
        let n = 4u32;
        let mut rng = SplitMix64::new(2);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands: Vec<Algo> = sgap_candidates(n).into_iter().take(8).collect();
        let out = tune(&m, &cands, &a, &b, n).unwrap();
        assert_eq!(out.ranked.len(), 8);
        // sorted ascending
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let (best, t) = out.best().unwrap();
        assert!(t > 0.0);
        assert!(out.time_of(&best).unwrap() <= out.ranked.last().unwrap().1);
        // the Option contract: a drained outcome has no winner
        assert!(TuneOutcome { ranked: vec![] }.best().is_none());
    }

    #[test]
    fn pruned_sweep_simulates_only_the_shortlist() {
        let a = erdos_renyi(128, 128, 1024, 3).to_csr();
        let n = 4u32;
        let mut rng = SplitMix64::new(2);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = sgap_candidates(n);
        let pruned = tune_pruned(&m, &cands, &a, &b, n, 5).unwrap();
        assert_eq!(pruned.grid, cands.len());
        assert_eq!(pruned.survivors, 5);
        assert_eq!(pruned.outcome.ranked.len(), 5);
        let (best, t) = pruned.best().unwrap();
        assert!(t > 0.0);
        assert!(cands.contains(&best));
        // escape hatch: top_k = 0 simulates everything
        let full = tune_pruned(&m, &cands, &a, &b, n, 0).unwrap();
        assert_eq!(full.survivors, cands.len());
        // the pruned winner can never beat the exhaustive winner
        let (_, t_full) = full.best().unwrap();
        assert!(t >= t_full - 1e-18);
    }

    #[test]
    fn banded_sweep_adds_composite_only_for_skewed_inputs() {
        use crate::tuner::space::band_candidates;
        let m = Machine::new(HwProfile::rtx3090());
        let n = 4u32;
        let mut rng = SplitMix64::new(8);

        // low CV: tune_banded must behave exactly like tune_pruned
        let er = erdos_renyi(128, 128, 1024, 3).to_csr();
        let b: Vec<f32> = (0..er.cols * n as usize).map(|_| rng.value()).collect();
        let cands = band_candidates(n);
        let banded = tune_banded(&m, &cands, &er, &b, n, 5).unwrap();
        let pruned = tune_pruned(&m, &cands, &er, &b, n, 5).unwrap();
        assert_eq!(banded.survivors, pruned.survivors);
        assert!(banded.outcome.ranked.iter().all(|(a, _, _)| !a.is_composite()));
        assert_eq!(banded.best().unwrap().0, pruned.best().unwrap().0);

        // high CV: if the model gates a composite in, it leads the
        // shortlist without growing the simulation budget
        let pl = crate::sparse::power_law(512, 512, 8192, 1.8, 21).to_csr();
        let bp: Vec<f32> = (0..pl.cols * n as usize).map(|_| rng.value()).collect();
        let out = tune_banded(&m, &cands, &pl, &bp, n, 5).unwrap();
        assert!(out.survivors <= 5, "banding must not inflate survivors");
        assert!(out.best().unwrap().1 > 0.0);
        for (a, t, _) in &out.outcome.ranked {
            assert!(*t > 0.0, "{} has nonpositive time", a.name());
        }
    }

    #[test]
    fn pruned_tensor_sweeps_cover_all_scenarios() {
        use crate::tuner::space::{mttkrp_candidates, ttm_candidates};
        let a = Coo3::random((32, 24, 16), 500, 11);
        let m = Machine::new(HwProfile::rtx3090());
        let mut rng = SplitMix64::new(6);
        let j = 8usize;
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let cands = mttkrp_candidates(j as u32);
        let pr = tune_mttkrp_pruned(&m, &cands, &a, &x1, &x2, 4).unwrap();
        assert_eq!(pr.survivors, 4.min(cands.len()));
        assert!(pr.best().unwrap().0.is_mttkrp());

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let tcands = ttm_candidates(4);
        let pt = tune_ttm_pruned(&m, &tcands, &a, &lx1, 4).unwrap();
        assert!(pt.survivors <= 4 && pt.best().unwrap().0.is_ttm());

        let csr = erdos_renyi(96, 96, 700, 5).to_csr();
        let sj = 16usize;
        let sx1: Vec<f32> = (0..csr.rows * sj).map(|_| rng.value()).collect();
        let sx2: Vec<f32> = (0..sj * csr.cols).map(|_| rng.value()).collect();
        let scands = crate::tuner::space::sddmm_candidates(sj as u32);
        let ps = tune_sddmm_pruned(&m, &scands, &csr, &sx1, &sx2, 4).unwrap();
        assert_eq!(ps.grid, scands.len());
        assert!(ps.best().unwrap().0.is_sddmm());
    }

    #[test]
    fn tune_sddmm_finds_a_valid_fastest_config() {
        let a = erdos_renyi(96, 96, 700, 5).to_csr();
        let j = 16usize;
        let mut rng = SplitMix64::new(4);
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = sddmm_candidates(j as u32);
        let (best, t) = tune_sddmm(&m, &cands, &a, &x1, &x2).unwrap();
        let Algo::Sddmm(cfg) = best else { panic!("winner {} not an SDDMM plan", best.name()) };
        cfg.validate().unwrap();
        assert!(t > 0.0);
        // the winner is no slower than the stock-est config in the grid
        let wide = Algo::Sddmm(crate::algos::sddmm::SddmmConfig::new(j as u32, 32, 32))
            .run_sddmm(&m, &a, &x1, &x2)
            .unwrap();
        assert!(t <= wide.time_s + 1e-15);
        // the ranked sweep is sorted ascending
        let out = tune_sddmm_ranked(&m, &cands, &a, &x1, &x2).unwrap();
        assert_eq!(out.ranked.len(), cands.len());
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn tune_fused_ranks_the_attention_grid() {
        use crate::tuner::space::fused_candidates;
        let a = erdos_renyi(96, 96, 700, 5).to_csr();
        let (j, n) = (16usize, 4usize);
        let mut rng = SplitMix64::new(4);
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = fused_candidates(j as u32, n as u32);
        let (best, t) = tune_fused(&m, &cands, &a, &x1, &x2, &b).unwrap();
        let Algo::FusedSddmmSpmm(cfg) = best else {
            panic!("winner {} not a fused plan", best.name())
        };
        cfg.validate().unwrap();
        assert!(t > 0.0);
        let out = tune_fused_ranked(&m, &cands, &a, &x1, &x2, &b).unwrap();
        assert_eq!(out.ranked.len(), cands.len());
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        // the pruned path survives with the same vocabulary
        let pf = tune_fused_pruned(&m, &cands, &a, &x1, &x2, &b, 4).unwrap();
        assert_eq!(pf.grid, cands.len());
        assert!(pf.survivors <= 4 && pf.best().unwrap().0.is_fused());
        // the pruned winner can never beat the exhaustive winner
        assert!(pf.best().unwrap().1 >= t - 1e-18);
    }

    #[test]
    fn tune_mttkrp_and_ttm_rank_the_coo3_grids() {
        use crate::tuner::space::{mttkrp_candidates, ttm_candidates};
        let a = Coo3::random((32, 24, 16), 500, 11);
        let j = 8usize;
        let mut rng = SplitMix64::new(6);
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = mttkrp_candidates(j as u32);
        let out = tune_mttkrp_ranked(&m, &cands, &a, &x1, &x2).unwrap();
        assert_eq!(out.ranked.len(), cands.len());
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let (best, t) = tune_mttkrp(&m, &cands, &a, &x1, &x2).unwrap();
        assert!(best.is_mttkrp() && t > 0.0);

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let tcands = ttm_candidates(4);
        let (tbest, tt) = tune_ttm(&m, &tcands, &a, &lx1).unwrap();
        assert!(tbest.is_ttm() && tt > 0.0);
        let out = tune_ttm_ranked(&m, &tcands, &a, &lx1).unwrap();
        assert_eq!(out.ranked.len(), tcands.len());
    }
}
