//! Grid-search driver: run a candidate list on a matrix, rank by simulated
//! time. Candidates are independent, so the sweep fans out across OS
//! threads (numerics stay deterministic — each run owns its memory).

use anyhow::Result;

use crate::algos::catalog::{Algo, AlgoResult};
use crate::sim::Machine;
use crate::sparse::coo3::Coo3;
use crate::sparse::Csr;

/// Outcome of tuning one matrix: all results, sorted fastest-first.
#[derive(Debug)]
pub struct TuneOutcome {
    /// `(algo, time_s, gflops)` sorted ascending by time.
    pub ranked: Vec<(Algo, f64, f64)>,
}

impl TuneOutcome {
    pub fn best(&self) -> (Algo, f64) {
        let (a, t, _) = self.ranked[0];
        (a, t)
    }

    /// Time of a specific algorithm in this sweep, if present.
    pub fn time_of(&self, algo: &Algo) -> Option<f64> {
        self.ranked.iter().find(|(a, _, _)| a == algo).map(|&(_, t, _)| t)
    }
}

/// Number of worker threads for sweeps (bounded; sweeps are CPU-heavy).
fn workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run every candidate on `(a, b)`; errors in individual candidates are
/// propagated (the grids are pre-validated, so any failure is a bug).
pub fn tune(machine: &Machine, candidates: &[Algo], a: &Csr, b: &[f32], n: u32) -> Result<TuneOutcome> {
    let nw = workers().min(candidates.len().max(1));
    let results: Vec<Result<(Algo, AlgoResult)>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for chunk in candidates.chunks(candidates.len().div_ceil(nw).max(1)) {
            handles.push(scope.spawn(move || {
                chunk
                    .iter()
                    .map(|alg| alg.run(machine, a, b, n).map(|r| (*alg, r)))
                    .collect::<Vec<_>>()
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("tuner worker panicked")).collect()
    });

    let mut ranked = Vec::with_capacity(results.len());
    for r in results {
        let (alg, res) = r?;
        ranked.push((alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    anyhow::ensure!(!ranked.is_empty(), "no candidates supplied");
    Ok(TuneOutcome { ranked })
}

/// Sweep SDDMM plans (unified [`Algo::Sddmm`] vocabulary) on
/// `(a, x1, x2)`; returns all results sorted fastest-first. Serial on
/// purpose: this runs on the coordinator's single background-refinement
/// thread, where stealing cores from the serving workers would defeat the
/// point.
pub fn tune_sddmm_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_sddmm(machine, a, x1, x2)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest SDDMM plan and its simulated time.
pub fn tune_sddmm(
    machine: &Machine,
    candidates: &[Algo],
    a: &Csr,
    x1: &[f32],
    x2: &[f32],
) -> Result<(Algo, f64)> {
    tune_sddmm_ranked(machine, candidates, a, x1, x2).map(|out| out.best())
}

/// Sweep MTTKRP plans ([`Algo::Mttkrp`]) on `(a, x1, x2)`; returns all
/// results sorted fastest-first. Serial for the same reason as
/// [`tune_sddmm_ranked`]: it runs on the coordinator's single
/// background-refinement thread.
pub fn tune_mttkrp_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_mttkrp(machine, a, x1, x2)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest MTTKRP plan and its simulated time.
pub fn tune_mttkrp(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
    x2: &[f32],
) -> Result<(Algo, f64)> {
    tune_mttkrp_ranked(machine, candidates, a, x1, x2).map(|out| out.best())
}

/// Sweep TTM plans ([`Algo::Ttm`]) on `(a, x1)`; fastest-first.
pub fn tune_ttm_ranked(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
) -> Result<TuneOutcome> {
    anyhow::ensure!(!candidates.is_empty(), "no candidates supplied");
    let mut ranked = Vec::with_capacity(candidates.len());
    for alg in candidates {
        let res = alg.run_ttm(machine, a, x1)?;
        ranked.push((*alg, res.time_s, res.gflops));
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(TuneOutcome { ranked })
}

/// The fastest TTM plan and its simulated time.
pub fn tune_ttm(
    machine: &Machine,
    candidates: &[Algo],
    a: &Coo3,
    x1: &[f32],
) -> Result<(Algo, f64)> {
    tune_ttm_ranked(machine, candidates, a, x1).map(|out| out.best())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, SplitMix64};
    use crate::tuner::space::{sddmm_candidates, sgap_candidates};

    #[test]
    fn tune_ranks_candidates() {
        let a = erdos_renyi(128, 128, 1024, 3).to_csr();
        let n = 4u32;
        let mut rng = SplitMix64::new(2);
        let b: Vec<f32> = (0..a.cols * n as usize).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands: Vec<Algo> = sgap_candidates(n).into_iter().take(8).collect();
        let out = tune(&m, &cands, &a, &b, n).unwrap();
        assert_eq!(out.ranked.len(), 8);
        // sorted ascending
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let (best, t) = out.best();
        assert!(t > 0.0);
        assert!(out.time_of(&best).unwrap() <= out.ranked.last().unwrap().1);
    }

    #[test]
    fn tune_sddmm_finds_a_valid_fastest_config() {
        let a = erdos_renyi(96, 96, 700, 5).to_csr();
        let j = 16usize;
        let mut rng = SplitMix64::new(4);
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = sddmm_candidates(j as u32);
        let (best, t) = tune_sddmm(&m, &cands, &a, &x1, &x2).unwrap();
        let Algo::Sddmm(cfg) = best else { panic!("winner {} not an SDDMM plan", best.name()) };
        cfg.validate().unwrap();
        assert!(t > 0.0);
        // the winner is no slower than the stock-est config in the grid
        let wide = Algo::Sddmm(crate::algos::sddmm::SddmmConfig::new(j as u32, 32, 32))
            .run_sddmm(&m, &a, &x1, &x2)
            .unwrap();
        assert!(t <= wide.time_s + 1e-15);
        // the ranked sweep is sorted ascending
        let out = tune_sddmm_ranked(&m, &cands, &a, &x1, &x2).unwrap();
        assert_eq!(out.ranked.len(), cands.len());
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn tune_mttkrp_and_ttm_rank_the_coo3_grids() {
        use crate::tuner::space::{mttkrp_candidates, ttm_candidates};
        let a = Coo3::random((32, 24, 16), 500, 11);
        let j = 8usize;
        let mut rng = SplitMix64::new(6);
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let m = Machine::new(HwProfile::rtx3090());
        let cands = mttkrp_candidates(j as u32);
        let out = tune_mttkrp_ranked(&m, &cands, &a, &x1, &x2).unwrap();
        assert_eq!(out.ranked.len(), cands.len());
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        let (best, t) = tune_mttkrp(&m, &cands, &a, &x1, &x2).unwrap();
        assert!(best.is_mttkrp() && t > 0.0);

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let tcands = ttm_candidates(4);
        let (tbest, tt) = tune_ttm(&m, &tcands, &a, &lx1).unwrap();
        assert!(tbest.is_ttm() && tt > 0.0);
        let out = tune_ttm_ranked(&m, &tcands, &a, &lx1).unwrap();
        assert_eq!(out.ranked.len(), tcands.len());
    }
}
