//! # Sgap — segment group & atomic parallelism for sparse tensor algebra
//!
//! Reproduction of *"Sgap: Towards Efficient Sparse Tensor Algebra
//! Compilation for GPU"* (Zhang et al., 2022) as a three-layer
//! rust + JAX + Pallas stack. See `DESIGN.md` for the system inventory and
//! `EXPERIMENTS.md` for the paper-vs-measured record.
//!
//! Crate layout:
//!
//! * [`sparse`] — sparse formats (COO/CSR/ELL), MatrixMarket IO, seeded
//!   synthetic generators and the evaluation dataset suite.
//! * [`compiler`] — the mini-TACO: tensor algebra expressions, the
//!   `compile(&TensorAlgebra, &Schedule)` front door (typed
//!   schedule/expression agreement errors), concrete index notation
//!   (CIN), schedule transformations (including the new
//!   `parallelize(.., GPUGroup, r, strategy)`), lowering with segment
//!   reduction + zero extension, LLIR, and CUDA-text / simulator codegen.
//! * [`sim`] — the SIMT cost simulator standing in for the paper's GPUs.
//! * [`algos`] — the §2.1 quartet behind the catalog: the four TACO SpMM
//!   families, SDDMM, the fused SDDMM→SpMM chain (one kernel, no
//!   intermediate), the dgSPARSE kernels, and the COO-3 MTTKRP/TTM
//!   segment kernels, each with numeric and simulated execution paths.
//! * [`tuner`] — atomic-parallelism space search (analytic cost-model
//!   pricing + model-pruned or exhaustive grid search) and the
//!   input-dynamics selector.
//! * [`runtime`] — PJRT artifact loading/execution (numeric hot path;
//!   gated behind the `pjrt` cargo feature).
//! * [`coordinator`] — the serving layer: a `Session` facade over a
//!   multi-worker pool, with `Arc`-backed operand handles (register
//!   once, fingerprint once, submit zero-copy), one generic `Op` path
//!   for the whole SpMM/SDDMM/MTTKRP/TTM quartet, a pluggable
//!   `Executor` backend stack, a tuner-aware plan cache, batching,
//!   backpressure and per-backend metrics.

pub mod algos;
pub mod compiler;
pub mod coordinator;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod tuner;
pub mod bench_util;
