//! Persistent plan catalog: the serving layer's tuned-plan memory,
//! serialized next to `CALIBRATION.json` so a restarted coordinator
//! warm-starts with yesterday's winners instead of re-selecting and
//! re-tuning every shape from scratch (`serve --plans FILE`).
//!
//! The artifact follows the same canonical-format discipline as
//! [`Calibration`](crate::tuner::calibrate::Calibration): fixed key
//! order, fixed `{:.17e}` float format, a `schema_version` gate that
//! rejects unknown layouts with a typed error, and the byte-round-trip
//! contract `to_json ∘ from_json = identity` (pinned by
//! `rust/tests/plan_catalog.rs` against the committed `PLANS.json`).
//! Entries are serialized **structurally** — one tagged object per
//! [`Algo`] family carrying its config fields verbatim — because the
//! human-readable `Algo::name` strings have no parser and never will:
//! display strings drift, field lists don't.
//!
//! A loaded catalog is installed via [`PlanCatalog::warm`], which
//! [`PlanCache::preload`]s each entry: preloaded entries keep their
//! persisted origin, are marked *warm*, and hits on them surface as
//! `Metrics::warm_hits` — the observable warm-start payoff the scale
//! suite asserts on.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::algos::catalog::{Algo, BandAlgo, CompositeConfig};
use crate::algos::{DgConfig, FusedConfig, MttkrpConfig, SddmmConfig, TtmConfig};
use crate::runtime::json::Json;

use super::op::OpKind;
use super::plan_cache::{Plan, PlanCache, PlanOrigin, ShapeKey};

/// Artifact layout version. Bump on any key or semantics change; loads
/// of other versions fail with a typed error (the coordinator then
/// cold-starts cleanly).
pub const PLAN_CATALOG_SCHEMA_VERSION: u64 = 1;

/// One persisted cache line: the shape fingerprint and the plan that
/// served it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CatalogEntry {
    pub key: ShapeKey,
    pub plan: Plan,
}

/// A versioned snapshot of the plan cache, in canonical order (scenario,
/// then exact shape, then quantized features) so `save → load → save` is
/// byte-identical regardless of shard layout or arrival order.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanCatalog {
    pub version: u64,
    pub entries: Vec<CatalogEntry>,
}

impl PlanCatalog {
    /// Snapshot `cache` into canonical order.
    pub fn from_cache(cache: &PlanCache) -> PlanCatalog {
        let mut entries: Vec<CatalogEntry> =
            cache.entries().into_iter().map(|(key, plan)| CatalogEntry { key, plan }).collect();
        entries.sort_by_key(|e| sort_key(&e.key));
        PlanCatalog { version: PLAN_CATALOG_SCHEMA_VERSION, entries }
    }

    /// Install every entry into `cache` via [`PlanCache::preload`].
    /// Returns how many entries actually landed (keys already cached by
    /// live traffic are skipped — live wins over yesterday's catalog).
    pub fn warm(&self, cache: &PlanCache) -> usize {
        self.entries.iter().filter(|e| cache.preload(e.key, e.plan)).count()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize with fixed key order and `{:.17e}` floats — the same
    /// byte-identity discipline as the calibration artifact. Entry order
    /// is emitted verbatim ([`PlanCatalog::from_cache`] canonicalizes).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {},\n", self.version));
        if self.entries.is_empty() {
            s.push_str("  \"entries\": []\n");
        } else {
            s.push_str("  \"entries\": [\n");
            for (i, e) in self.entries.iter().enumerate() {
                s.push_str(&entry_json(e));
                s.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
            }
            s.push_str("  ]\n");
        }
        s.push_str("}\n");
        s
    }

    pub fn from_json(src: &str) -> Result<PlanCatalog> {
        let j = Json::parse(src).context("plan catalog is not valid JSON")?;
        let version = j
            .get("schema_version")
            .and_then(Json::as_f64)
            .context("plan catalog: missing `schema_version`")? as u64;
        if version != PLAN_CATALOG_SCHEMA_VERSION {
            bail!(
                "plan catalog schema version {version} (this build reads {})",
                PLAN_CATALOG_SCHEMA_VERSION
            );
        }
        let entries_j =
            j.get("entries").and_then(Json::as_arr).context("plan catalog: missing `entries`")?;
        let mut entries = Vec::with_capacity(entries_j.len());
        for (i, ej) in entries_j.iter().enumerate() {
            entries.push(entry_from_json(ej).with_context(|| format!("plan catalog: entry {i}"))?);
        }
        Ok(PlanCatalog { version, entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing plan catalog to {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<PlanCatalog> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan catalog from {}", path.display()))?;
        Self::from_json(&src)
    }
}

/// Canonical entry order: scenario (in [`OpKind::ALL`] order), then the
/// exact-shape fields, then the quantized features.
fn sort_key(k: &ShapeKey) -> (usize, usize, usize, usize, u32, u16, u16, u16) {
    let (cv_q, mean_q, empty_q) = k.quantized_features();
    let sc = OpKind::ALL.iter().position(|s| *s == k.scenario).unwrap_or(usize::MAX);
    (sc, k.rows, k.cols, k.nnz, k.width, cv_q, mean_q, empty_q)
}

fn origin_label(o: PlanOrigin) -> &'static str {
    match o {
        PlanOrigin::Selector => "selector",
        PlanOrigin::Tuned => "tuned",
    }
}

fn origin_from_label(s: &str) -> Result<PlanOrigin> {
    match s {
        "selector" => Ok(PlanOrigin::Selector),
        "tuned" => Ok(PlanOrigin::Tuned),
        other => bail!("unknown plan origin `{other}`"),
    }
}

/// Same fixed float format as the calibration artifact: 18 significant
/// digits round-trip f64 exactly, and the fixed width keeps byte
/// identity independent of the value.
fn fmt_f64(x: f64) -> String {
    format!("{x:.17e}")
}

fn entry_json(e: &CatalogEntry) -> String {
    let (cv_q, mean_q, empty_q) = e.key.quantized_features();
    let mut s = String::new();
    s.push_str("    {\n");
    s.push_str(&format!("      \"scenario\": \"{}\",\n", e.key.scenario.label()));
    s.push_str(&format!("      \"rows\": {},\n", e.key.rows));
    s.push_str(&format!("      \"cols\": {},\n", e.key.cols));
    s.push_str(&format!("      \"nnz\": {},\n", e.key.nnz));
    s.push_str(&format!("      \"width\": {},\n", e.key.width));
    s.push_str(&format!("      \"cv_q\": {cv_q},\n"));
    s.push_str(&format!("      \"mean_q\": {mean_q},\n"));
    s.push_str(&format!("      \"empty_q\": {empty_q},\n"));
    s.push_str(&format!("      \"origin\": \"{}\",\n", origin_label(e.plan.origin)));
    s.push_str(&format!("      \"plan\": {}\n", algo_obj(&e.plan.kind, 6)));
    s.push_str("    }");
    s
}

fn entry_from_json(j: &Json) -> Result<CatalogEntry> {
    let scenario_s = j.get("scenario").and_then(Json::as_str).context("missing `scenario`")?;
    let scenario = OpKind::from_label(scenario_s)
        .with_context(|| format!("unknown scenario `{scenario_s}`"))?;
    let us = |key: &str| -> Result<usize> {
        j.get(key).and_then(Json::as_usize).with_context(|| format!("missing `{key}`"))
    };
    let key = ShapeKey::from_parts(
        scenario,
        us("rows")?,
        us("cols")?,
        us("nnz")?,
        us("width")? as u32,
        us("cv_q")? as u16,
        us("mean_q")? as u16,
        us("empty_q")? as u16,
    );
    let origin =
        origin_from_label(j.get("origin").and_then(Json::as_str).context("missing `origin`")?)?;
    let kind = algo_from_json(j.get("plan").context("missing `plan`")?)?;
    Ok(CatalogEntry { key, plan: Plan { kind, origin } })
}

/// Serialize one plan as a tagged object: `"algo"` is the stable
/// [`Algo::family_label`], the remaining keys are the family's config
/// fields verbatim. `base` is the indent of the line embedding the
/// opening brace; inner keys sit at `base + 2`.
fn algo_obj(a: &Algo, base: usize) -> String {
    let p = " ".repeat(base + 2);
    let mut s = String::from("{\n");
    s.push_str(&format!("{p}\"algo\": \"{}\",\n", a.family_label()));
    match *a {
        Algo::TacoNnzSerial { g, c } => {
            s.push_str(&format!("{p}\"g\": {g},\n{p}\"c\": {c}\n"));
        }
        Algo::TacoRowSerial { x, c } => {
            s.push_str(&format!("{p}\"x\": {x},\n{p}\"c\": {c}\n"));
        }
        Algo::SgapRowGroup { g, c, r } => {
            s.push_str(&format!("{p}\"g\": {g},\n{p}\"c\": {c},\n{p}\"r\": {r}\n"));
        }
        Algo::SgapNnzGroup { c, r } => {
            s.push_str(&format!("{p}\"c\": {c},\n{p}\"r\": {r}\n"));
        }
        Algo::Dg(d) => {
            s.push_str(&format!("{p}\"n\": {},\n", d.n));
            s.push_str(&format!("{p}\"group_sz\": {},\n", d.group_sz));
            s.push_str(&format!("{p}\"block_sz\": {},\n", d.block_sz));
            s.push_str(&format!("{p}\"tile_sz\": {},\n", d.tile_sz));
            s.push_str(&format!("{p}\"worker_dim_r_frac\": {},\n", fmt_f64(d.worker_dim_r_frac)));
            s.push_str(&format!("{p}\"worker_sz\": {},\n", d.worker_sz));
            s.push_str(&format!("{p}\"coarsen_sz\": {}\n", d.coarsen_sz));
        }
        Algo::Sddmm(c) => {
            s.push_str(&format!(
                "{p}\"j_dim\": {},\n{p}\"g\": {},\n{p}\"r\": {},\n{p}\"p\": {}\n",
                c.j_dim, c.g, c.r, c.p
            ));
        }
        Algo::Mttkrp(c) => {
            s.push_str(&format!(
                "{p}\"j_dim\": {},\n{p}\"c\": {},\n{p}\"p\": {},\n{p}\"r\": {}\n",
                c.j_dim, c.c, c.p, c.r
            ));
        }
        Algo::Ttm(c) => {
            s.push_str(&format!(
                "{p}\"l_dim\": {},\n{p}\"c\": {},\n{p}\"p\": {},\n{p}\"r\": {}\n",
                c.l_dim, c.c, c.p, c.r
            ));
        }
        Algo::FusedSddmmSpmm(c) => {
            s.push_str(&format!(
                "{p}\"j_dim\": {},\n{p}\"n\": {},\n{p}\"c\": {},\n{p}\"p\": {},\n{p}\"r\": {}\n",
                c.j_dim, c.n, c.c, c.p, c.r
            ));
        }
        Algo::Composite(cc) => {
            s.push_str(&format!("{p}\"bands\": {},\n", cc.bands));
            s.push_str(&format!("{p}\"cuts\": [{}, {}],\n", cc.cuts[0], cc.cuts[1]));
            s.push_str(&format!("{p}\"plans\": [\n"));
            for (i, bp) in cc.plans.iter().enumerate() {
                s.push_str(&format!("{p}  {}", algo_obj(&bp.to_algo(), base + 4)));
                s.push_str(if i + 1 < cc.plans.len() { ",\n" } else { "\n" });
            }
            s.push_str(&format!("{p}]\n"));
        }
    }
    s.push_str(&format!("{}}}", " ".repeat(base)));
    s
}

fn algo_from_json(j: &Json) -> Result<Algo> {
    let tag = j.get("algo").and_then(Json::as_str).context("plan: missing `algo`")?;
    let u = |key: &str| -> Result<u32> {
        j.get(key)
            .and_then(Json::as_f64)
            .map(|v| v as u32)
            .with_context(|| format!("plan `{tag}`: missing `{key}`"))
    };
    let f = |key: &str| -> Result<f64> {
        j.get(key)
            .and_then(Json::as_f64)
            .with_context(|| format!("plan `{tag}`: missing `{key}`"))
    };
    match tag {
        "taco-nnz-serial" => Ok(Algo::TacoNnzSerial { g: u("g")?, c: u("c")? }),
        "taco-row-serial" => Ok(Algo::TacoRowSerial { x: u("x")?, c: u("c")? }),
        "sgap-row-group" => Ok(Algo::SgapRowGroup { g: u("g")?, c: u("c")?, r: u("r")? }),
        "sgap-nnz-group" => Ok(Algo::SgapNnzGroup { c: u("c")?, r: u("r")? }),
        "dgsparse" => Ok(Algo::Dg(DgConfig {
            n: u("n")?,
            group_sz: u("group_sz")?,
            block_sz: u("block_sz")?,
            tile_sz: u("tile_sz")?,
            worker_dim_r_frac: f("worker_dim_r_frac")?,
            worker_sz: u("worker_sz")?,
            coarsen_sz: u("coarsen_sz")?,
        })),
        "sddmm-group" => Ok(Algo::Sddmm(SddmmConfig {
            j_dim: u("j_dim")?,
            g: u("g")?,
            r: u("r")?,
            p: u("p")?,
        })),
        "mttkrp-group" => Ok(Algo::Mttkrp(MttkrpConfig {
            j_dim: u("j_dim")?,
            c: u("c")?,
            p: u("p")?,
            r: u("r")?,
        })),
        "ttm-group" => Ok(Algo::Ttm(TtmConfig {
            l_dim: u("l_dim")?,
            c: u("c")?,
            p: u("p")?,
            r: u("r")?,
        })),
        "fused-sddmm-spmm" => Ok(Algo::FusedSddmmSpmm(FusedConfig {
            j_dim: u("j_dim")?,
            n: u("n")?,
            c: u("c")?,
            p: u("p")?,
            r: u("r")?,
        })),
        "hybrid" => {
            let bands = u("bands")? as u8;
            let cuts_j =
                j.get("cuts").and_then(Json::as_arr).context("plan `hybrid`: missing `cuts`")?;
            if cuts_j.len() != 2 {
                bail!("plan `hybrid`: `cuts` must hold exactly 2 buckets");
            }
            let cut = |i: usize| -> Result<u8> {
                cuts_j[i]
                    .as_f64()
                    .map(|v| v as u8)
                    .with_context(|| format!("plan `hybrid`: cuts[{i}] is not a number"))
            };
            let plans_j =
                j.get("plans").and_then(Json::as_arr).context("plan `hybrid`: missing `plans`")?;
            if plans_j.len() != 3 {
                bail!("plan `hybrid`: `plans` must hold exactly 3 band plans");
            }
            let mut plans = [BandAlgo::SgapNnzGroup { c: 1, r: 1 }; 3];
            for (i, pj) in plans_j.iter().enumerate() {
                let band = algo_from_json(pj).with_context(|| format!("plan `hybrid`: band {i}"))?;
                plans[i] = BandAlgo::from_algo(band).with_context(|| {
                    format!("plan `hybrid`: band {i} must be an SpMM compiler-family plan")
                })?;
            }
            Ok(Algo::Composite(CompositeConfig { bands, cuts: [cut(0)?, cut(1)?], plans }))
        }
        other => bail!("plan catalog: unknown algo family `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One entry per serializable family — the full structural surface.
    fn full_catalog() -> PlanCatalog {
        let k = |i: usize, scenario: OpKind| {
            ShapeKey::from_parts(scenario, 64 + i, 48, 400 + i, 4, 8, 2, 1)
        };
        let entries = vec![
            CatalogEntry {
                key: k(0, OpKind::Spmm),
                plan: Plan {
                    kind: Algo::TacoNnzSerial { g: 16, c: 4 },
                    origin: PlanOrigin::Selector,
                },
            },
            CatalogEntry {
                key: k(1, OpKind::Spmm),
                plan: Plan { kind: Algo::TacoRowSerial { x: 2, c: 2 }, origin: PlanOrigin::Tuned },
            },
            CatalogEntry {
                key: k(2, OpKind::Spmm),
                plan: Plan {
                    kind: Algo::SgapRowGroup { g: 8, c: 4, r: 8 },
                    origin: PlanOrigin::Tuned,
                },
            },
            CatalogEntry {
                key: k(3, OpKind::Spmm),
                plan: Plan { kind: Algo::SgapNnzGroup { c: 4, r: 8 }, origin: PlanOrigin::Tuned },
            },
            CatalogEntry {
                key: k(4, OpKind::Spmm),
                plan: Plan { kind: Algo::Dg(DgConfig::stock(4)), origin: PlanOrigin::Selector },
            },
            CatalogEntry {
                key: k(5, OpKind::Spmm),
                plan: Plan {
                    kind: Algo::Composite(CompositeConfig {
                        bands: 3,
                        cuts: [2, 5],
                        plans: [
                            BandAlgo::TacoRowSerial { x: 1, c: 4 },
                            BandAlgo::SgapRowGroup { g: 8, c: 4, r: 8 },
                            BandAlgo::SgapNnzGroup { c: 4, r: 32 },
                        ],
                    }),
                    origin: PlanOrigin::Tuned,
                },
            },
            CatalogEntry {
                key: k(0, OpKind::Sddmm),
                plan: Plan {
                    kind: Algo::Sddmm(SddmmConfig::new(16, 8, 4)),
                    origin: PlanOrigin::Selector,
                },
            },
            CatalogEntry {
                key: k(0, OpKind::Mttkrp),
                plan: Plan {
                    kind: Algo::Mttkrp(MttkrpConfig::new(8, 4, 8)),
                    origin: PlanOrigin::Tuned,
                },
            },
            CatalogEntry {
                key: k(0, OpKind::Ttm),
                plan: Plan { kind: Algo::Ttm(TtmConfig::new(4, 4, 8)), origin: PlanOrigin::Tuned },
            },
            CatalogEntry {
                key: k(0, OpKind::FusedSddmmSpmm),
                plan: Plan {
                    kind: Algo::FusedSddmmSpmm(FusedConfig::new(16, 4, 4, 8)),
                    origin: PlanOrigin::Selector,
                },
            },
        ];
        PlanCatalog { version: PLAN_CATALOG_SCHEMA_VERSION, entries }
    }

    #[test]
    fn every_family_round_trips_byte_identically() {
        let cat = full_catalog();
        let json = cat.to_json();
        let back = PlanCatalog::from_json(&json).unwrap();
        assert_eq!(back, cat, "structural round-trip");
        assert_eq!(back.to_json(), json, "byte round-trip");
    }

    #[test]
    fn empty_catalog_round_trips() {
        let cat = PlanCatalog { version: PLAN_CATALOG_SCHEMA_VERSION, entries: vec![] };
        let json = cat.to_json();
        assert!(json.contains("\"entries\": []"));
        let back = PlanCatalog::from_json(&json).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.to_json(), json);
    }

    #[test]
    fn version_gate_and_corruption_are_typed_errors() {
        let cat = full_catalog();
        let json = cat.to_json();
        // wrong version: typed bail naming both versions
        let bumped = json.replace("\"schema_version\": 1", "\"schema_version\": 99");
        let err = PlanCatalog::from_json(&bumped).unwrap_err().to_string();
        assert!(err.contains("99") && err.contains('1'), "{err}");
        // truncation: parse error, not a panic
        assert!(PlanCatalog::from_json(&json[..json.len() / 2]).is_err());
        // unknown family tag
        let bad = json.replace("\"algo\": \"sgap-nnz-group\"", "\"algo\": \"warp-magic\"");
        let err = PlanCatalog::from_json(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("warp-magic"), "{err:#}");
        // a band plan outside the four SpMM families is rejected: the
        // needle's 12-space indent matches only the composite's band 0,
        // not the top-level taco-row-serial entry (8-space indent)
        let needle = "\"algo\": \"taco-row-serial\",\n            \"x\"";
        let swap = "\"algo\": \"dgsparse\",\n            \"x\"";
        let bad_band = json.replace(needle, swap);
        assert_ne!(bad_band, json, "needle must match the band plan");
        assert!(PlanCatalog::from_json(&bad_band).is_err());
    }

    #[test]
    fn from_cache_is_canonically_sorted_and_warm_restores() {
        let cache = PlanCache::with_shards(64, 4);
        // insert in deliberately scrambled order
        for e in full_catalog().entries.iter().rev() {
            assert!(cache.preload(e.key, e.plan));
        }
        let cat = PlanCatalog::from_cache(&cache);
        assert_eq!(cat.len(), full_catalog().len());
        let keys: Vec<_> = cat.entries.iter().map(|e| sort_key(&e.key)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "from_cache emits canonical order");
        // the snapshot order is shard-independent: a 1-shard rebuild of
        // the same contents serializes to the same bytes
        let single = PlanCache::new(64);
        for e in cat.entries.iter() {
            assert!(single.preload(e.key, e.plan));
        }
        assert_eq!(PlanCatalog::from_cache(&single).to_json(), cat.to_json());
        // warm() installs everything into a cold cache, once
        let cold = PlanCache::with_shards(64, 8);
        assert_eq!(cat.warm(&cold), cat.len());
        assert_eq!(cold.len(), cat.len());
        assert_eq!(cat.warm(&cold), 0, "re-warming an already-warm cache is a no-op");
        for e in &cat.entries {
            assert_eq!(cold.get(&e.key), Some(e.plan), "plans and origins survive");
        }
    }

    #[test]
    fn save_load_save_is_byte_identical_on_disk() {
        let dir = std::env::temp_dir().join(format!("sgap-catalog-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("PLANS.json");
        let cat = full_catalog();
        cat.save(&path).unwrap();
        let first = std::fs::read(&path).unwrap();
        let loaded = PlanCatalog::load(&path).unwrap();
        loaded.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), first);
        std::fs::remove_dir_all(&dir).ok();
    }
}
