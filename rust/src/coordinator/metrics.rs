//! Service metrics: request counters, global latency quantiles,
//! per-backend latency histograms, and plan-cache hit/miss counters.
//!
//! The global quantiles come from a bounded reservoir (exact for the first
//! 64k requests); the per-backend histograms are log2-bucketed so they are
//! O(1) per sample and never grow — the shape a production scrape target
//! wants. Backends are keyed by coarse labels — the `Display` form of the
//! typed [`BackendKind`](super::BackendKind) (`sim:sgap-nnz-group`,
//! `pjrt:<artifact>`, `cpu-serial`, `cpu-fallback`, …) — so the map stays
//! small under diverse traffic and the scrape surface survived the typed
//! API redesign unchanged.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    /// Mirrors of the PlanCache's own hit/miss counters, kept here so one
    /// snapshot is the whole scrape surface. The coordinator worker is the
    /// only writer of both, via `note_cache`; `PlanCache::stats()` remains
    /// the source of truth for cache-internal events (upgrades, evictions).
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Requests that fell back to the serial CPU path after their planned
    /// backend failed.
    fallbacks: AtomicU64,
    /// Background-tuner sweeps completed.
    tunes: AtomicU64,
    /// Candidates in the full grids of those sweeps (before pruning).
    tune_grid: AtomicU64,
    /// Candidates actually simulated (the model-pruned shortlists).
    tune_survivors: AtomicU64,
    /// Sweeps where the analytic model's top-1 pick also won the
    /// simulation — the prune-accuracy counter.
    tune_model_agree: AtomicU64,
    /// Requests admitted with a per-band composite (hybrid) plan.
    banded: AtomicU64,
    /// Measured latencies fed to the online calibrator.
    calib_samples: AtomicU64,
    /// Times the drift tracker crossed its threshold and refit
    /// `CostParams` (invalidating the affected `PlanCache` entries).
    calib_refits: AtomicU64,
    /// Gauge: the worst per-`OpKind` EWMA |log(measured/predicted)|
    /// residual last reported by the calibrator (f64 bits).
    calib_residual: AtomicU64,
    /// Requests that rode another session's launch: for every cross-session
    /// batch of `k > 1` same-`ShapeKey` ops, `k - 1` are counted coalesced.
    coalesced: AtomicU64,
    /// Submissions refused by admission control (`OpError::Overloaded`).
    /// Rejected ops are *not* counted in `submitted`, so
    /// `completed + errors == submitted` still holds.
    rejected: AtomicU64,
    /// Plan-cache hits that landed on a catalog-preloaded (warm) entry —
    /// the `serve --plans` warm-start payoff.
    warm_hits: AtomicU64,
    /// Device-pool stagings that found the operand image resident (the
    /// upload was skipped) vs built it fresh.
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    /// Uploads skipped by pool hits — the resubmit payoff counter
    /// (tracks `pool_hits`; kept separate so a future partial-hit path
    /// can diverge).
    uploads_skipped: AtomicU64,
    /// Gauge: bytes resident in the device pool after the last staging.
    pool_bytes: AtomicU64,
    /// Latencies in microseconds (bounded reservoir).
    latencies_us: Mutex<Vec<u64>>,
    backends: Mutex<BTreeMap<String, Hist>>,
    /// Same histograms keyed by op label (`spmm`, `sddmm`, …) — the
    /// per-`OpKind` p50/p99 the stress test asserts on.
    ops: Mutex<BTreeMap<String, Hist>>,
}

/// Log2-bucketed latency histogram: bucket `i` counts samples with
/// `us < 2^i` (last bucket is open-ended).
#[derive(Debug, Default, Clone)]
struct Hist {
    count: u64,
    sum_us: u64,
    buckets: [u64; 32],
}

impl Hist {
    fn record(&mut self, us: u64) {
        self.count += 1;
        self.sum_us += us;
        // index of the first power of two strictly above `us`
        let idx = (64 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
    }

    /// Upper bound of the bucket where the cumulative count crosses `p`.
    fn quantile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * p).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return if i == 0 { 0 } else { 1u64 << (i - 1) };
            }
        }
        1u64 << (self.buckets.len() - 2)
    }
}

/// Per-backend latency summary.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendSnapshot {
    pub backend: String,
    pub count: u64,
    pub mean_us: f64,
    /// Log2-bucket quantiles: the value is the lower bound of the bucket
    /// the quantile falls in (0 for sub-microsecond).
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Per-`OpKind` latency summary (same log2-bucket quantiles as
/// [`BackendSnapshot`], keyed by `OpKind::label`).
#[derive(Debug, Clone, PartialEq)]
pub struct OpSnapshot {
    pub op: String,
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Point-in-time view.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub fallbacks: u64,
    /// Background-tuner sweeps, and how hard the model pruned them.
    pub tunes: u64,
    pub tune_grid: u64,
    pub tune_survivors: u64,
    /// Sweeps whose simulated winner was the model's top-1 pick.
    pub tune_model_agree: u64,
    /// Requests admitted with a per-band composite (hybrid) plan.
    pub banded: u64,
    /// Calibration loop: samples observed, refits triggered, and the
    /// worst current per-op EWMA residual (gauge, dimensionless log
    /// ratio).
    pub calib_samples: u64,
    pub calib_refits: u64,
    pub calib_residual: f64,
    /// Requests that rode another session's launch (per batch of `k`
    /// same-key ops, `k - 1` count as coalesced).
    pub coalesced: u64,
    /// Submissions refused by admission control; disjoint from
    /// `submitted`.
    pub rejected: u64,
    /// Plan-cache hits on catalog-preloaded entries (warm starts).
    pub warm_hits: u64,
    /// Device-pool staging: hits (image resident, upload skipped) and
    /// misses (image built and "uploaded").
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// Operand uploads skipped thanks to pool hits.
    pub uploads_skipped: u64,
    /// Gauge: bytes resident in the device pool (live + free pages).
    pub pool_bytes_live: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// One entry per backend label, sorted by label.
    pub backends: Vec<BackendSnapshot>,
    /// One entry per op label (`OpKind::label`), sorted by label.
    pub ops: Vec<OpSnapshot>,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one background-tuner sweep: grid size, how many candidates
    /// survived pruning into simulation, and whether the model's top-1
    /// pick won — prune accuracy is `tune_model_agree / tunes`, the
    /// effective speedup `tune_grid / tune_survivors`.
    pub fn on_tune(&self, grid: usize, survivors: usize, model_agree: bool) {
        self.tunes.fetch_add(1, Ordering::Relaxed);
        self.tune_grid.fetch_add(grid as u64, Ordering::Relaxed);
        self.tune_survivors.fetch_add(survivors as u64, Ordering::Relaxed);
        if model_agree {
            self.tune_model_agree.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a request admitted with a per-band composite (hybrid) plan —
    /// how often the skew path actually engages in production.
    pub fn on_banded(&self) {
        self.banded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a served request: global counters + the backend's and the
    /// op's histograms (`op` is an `OpKind::label` — `spmm`, `sddmm`, …).
    pub fn on_complete(&self, backend: &str, op: &str, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        {
            let mut l = self.latencies_us.lock().unwrap();
            if l.len() < RESERVOIR {
                l.push(us);
            }
        }
        {
            let mut b = self.backends.lock().unwrap();
            b.entry(backend.to_string()).or_default().record(us);
        }
        let mut o = self.ops.lock().unwrap();
        o.entry(op.to_string()).or_default().record(us);
    }

    /// One measured latency fed into the drift tracker; `ewma_residual`
    /// is the tracker's updated worst per-op EWMA |log ratio| gauge.
    pub fn on_calib_sample(&self, ewma_residual: f64) {
        self.calib_samples.fetch_add(1, Ordering::Relaxed);
        self.calib_residual.store(ewma_residual.to_bits(), Ordering::Relaxed);
    }

    /// The drift threshold tripped: the calibrator refit `CostParams`.
    pub fn on_calib_refit(&self) {
        self.calib_refits.fetch_add(1, Ordering::Relaxed);
    }

    /// `extra` requests rode a launch they didn't trigger — a
    /// cross-session batch of `k` same-key ops reports `k - 1`.
    pub fn on_coalesced(&self, extra: u64) {
        self.coalesced.fetch_add(extra, Ordering::Relaxed);
    }

    /// Admission control refused a submission (queue saturated). The op
    /// never entered the queue, so `on_submit` was not called for it.
    pub fn on_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A plan-cache hit landed on a catalog-preloaded (warm) entry.
    pub fn on_warm_hit(&self) {
        self.warm_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A device-pool staging found the operand image resident: the
    /// padded-buffer rebuild and upload were both skipped.
    pub fn on_pool_hit(&self) {
        self.pool_hits.fetch_add(1, Ordering::Relaxed);
        self.uploads_skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// A device-pool staging built (and "uploaded") a fresh image.
    pub fn on_pool_miss(&self) {
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the pool-residency gauge (bytes in live + free pages).
    pub fn set_pool_bytes(&self, bytes: u64) {
        self.pool_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn on_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut l = self.latencies_us.lock().unwrap().clone();
        l.sort_unstable();
        let q = |p: f64| -> u64 {
            if l.is_empty() {
                0
            } else {
                l[((l.len() - 1) as f64 * p) as usize]
            }
        };
        let mean = if l.is_empty() { 0.0 } else { l.iter().sum::<u64>() as f64 / l.len() as f64 };
        let backends = self
            .backends
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| BackendSnapshot {
                backend: name.clone(),
                count: h.count,
                mean_us: h.sum_us as f64 / h.count.max(1) as f64,
                p50_us: h.quantile_us(0.50),
                p99_us: h.quantile_us(0.99),
            })
            .collect();
        let ops = self
            .ops
            .lock()
            .unwrap()
            .iter()
            .map(|(name, h)| OpSnapshot {
                op: name.clone(),
                count: h.count,
                mean_us: h.sum_us as f64 / h.count.max(1) as f64,
                p50_us: h.quantile_us(0.50),
                p99_us: h.quantile_us(0.99),
            })
            .collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            tunes: self.tunes.load(Ordering::Relaxed),
            tune_grid: self.tune_grid.load(Ordering::Relaxed),
            tune_survivors: self.tune_survivors.load(Ordering::Relaxed),
            tune_model_agree: self.tune_model_agree.load(Ordering::Relaxed),
            banded: self.banded.load(Ordering::Relaxed),
            calib_samples: self.calib_samples.load(Ordering::Relaxed),
            calib_refits: self.calib_refits.load(Ordering::Relaxed),
            calib_residual: f64::from_bits(self.calib_residual.load(Ordering::Relaxed)),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            uploads_skipped: self.uploads_skipped.load(Ordering::Relaxed),
            pool_bytes_live: self.pool_bytes.load(Ordering::Relaxed),
            p50_us: q(0.50),
            p99_us: q(0.99),
            mean_us: mean,
            backends,
            ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.on_submit();
            m.on_complete("cpu-serial", "spmm", Duration::from_micros(i));
        }
        m.on_error();
        let s = m.snapshot();
        assert_eq!(s.submitted, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.errors, 1);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 {}", s.p99_us);
        assert!((s.mean_us - 50.5).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_us, 0.0);
        assert!(s.backends.is_empty());
        assert_eq!(s.cache_hits + s.cache_misses + s.fallbacks, 0);
    }

    #[test]
    fn per_backend_histograms_separate() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_complete("sim:sgap-nnz-group", "spmm", Duration::from_micros(100));
        }
        for _ in 0..5 {
            m.on_complete("cpu-serial", "sddmm", Duration::from_micros(3000));
        }
        let s = m.snapshot();
        assert_eq!(s.backends.len(), 2);
        let sim = s.backends.iter().find(|b| b.backend == "sim:sgap-nnz-group").unwrap();
        let cpu = s.backends.iter().find(|b| b.backend == "cpu-serial").unwrap();
        assert_eq!(sim.count, 10);
        assert_eq!(cpu.count, 5);
        assert!((sim.mean_us - 100.0).abs() < 1e-9);
        assert!(cpu.p50_us > sim.p50_us, "cpu {} !> sim {}", cpu.p50_us, sim.p50_us);
        // and the same traffic shows up keyed by op
        assert_eq!(s.ops.len(), 2);
        let spmm = s.ops.iter().find(|o| o.op == "spmm").unwrap();
        let sddmm = s.ops.iter().find(|o| o.op == "sddmm").unwrap();
        assert_eq!((spmm.count, sddmm.count), (10, 5));
        assert!(spmm.p50_us <= spmm.p99_us);
        assert!(sddmm.p50_us > spmm.p50_us);
    }

    #[test]
    fn hist_log2_bucket_boundaries() {
        // record() puts `us` in the bucket of the first power of two
        // strictly above it: 1 -> idx 1 (lower bound 1), 2 -> idx 2
        // (lower bound 2), 3 -> idx 2, 4 -> idx 3
        let cases = [(0u64, 0u64), (1, 1), (2, 2), (3, 2), (4, 4), (1023, 512), (1024, 1024)];
        for (us, lower) in cases {
            let mut h = Hist::default();
            h.record(us);
            assert_eq!(h.quantile_us(0.5), lower, "us={us}");
            assert_eq!(h.count, 1);
        }
    }

    #[test]
    fn hist_quantile_edge_cases() {
        // 0 samples: every quantile reads 0
        let empty = Hist::default();
        assert_eq!(empty.quantile_us(0.5), 0);
        assert_eq!(empty.quantile_us(0.99), 0);

        // 1 sample: every quantile reads that sample's bucket
        let mut one = Hist::default();
        one.record(77);
        assert_eq!(one.quantile_us(0.0), 64);
        assert_eq!(one.quantile_us(0.5), 64);
        assert_eq!(one.quantile_us(1.0), 64);

        // u64::MAX microseconds lands in the open-ended last bucket
        // without overflowing the shift
        let mut max = Hist::default();
        max.record(u64::MAX);
        assert_eq!(max.count, 1);
        assert_eq!(max.quantile_us(0.5), 1u64 << 30);
        assert_eq!(max.quantile_us(0.99), 1u64 << 30);
    }

    #[test]
    fn calib_counters_advance() {
        let m = Metrics::new();
        let s0 = m.snapshot();
        assert_eq!((s0.calib_samples, s0.calib_refits), (0, 0));
        assert_eq!(s0.calib_residual, 0.0);
        m.on_calib_sample(0.1);
        m.on_calib_sample(0.3);
        m.on_calib_refit();
        let s = m.snapshot();
        assert_eq!(s.calib_samples, 2);
        assert_eq!(s.calib_refits, 1);
        assert!((s.calib_residual - 0.3).abs() < 1e-12);
    }

    #[test]
    fn hist_quantiles_bracket_samples() {
        let mut h = Hist::default();
        for us in [1u64, 2, 4, 100, 1000] {
            h.record(us);
        }
        let p50 = h.quantile_us(0.5);
        assert!(p50 >= 2 && p50 <= 4, "p50 bucket {p50}");
        assert!(h.quantile_us(0.99) >= 512, "p99 bucket {}", h.quantile_us(0.99));
        assert_eq!(h.quantile_us(1.0), h.quantile_us(0.999));
    }

    #[test]
    fn cache_counters() {
        let m = Metrics::new();
        m.on_cache_miss();
        m.on_cache_hit();
        m.on_cache_hit();
        m.on_fallback();
        let s = m.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses, s.fallbacks), (2, 1, 1));
    }

    #[test]
    fn tune_counters_track_prune_accuracy() {
        let m = Metrics::new();
        m.on_tune(60, 8, true);
        m.on_tune(60, 8, false);
        m.on_tune(15, 15, true); // exhaustive escape hatch still counted
        let s = m.snapshot();
        assert_eq!(s.tunes, 3);
        assert_eq!(s.tune_grid, 135);
        assert_eq!(s.tune_survivors, 31);
        assert_eq!(s.tune_model_agree, 2);
        assert_eq!(Metrics::new().snapshot().tunes, 0);
    }

    #[test]
    fn serving_scale_trio_tracks_independently() {
        let m = Metrics::new();
        let s0 = m.snapshot();
        assert_eq!((s0.coalesced, s0.rejected, s0.warm_hits), (0, 0, 0));
        m.on_coalesced(3); // a 4-op cross-session batch
        m.on_coalesced(1); // a 2-op batch
        m.on_rejected();
        m.on_warm_hit();
        m.on_warm_hit();
        let s = m.snapshot();
        assert_eq!((s.coalesced, s.rejected, s.warm_hits), (4, 1, 2));
        // rejection never touches the submitted/completed identity
        assert_eq!((s.submitted, s.completed, s.errors), (0, 0, 0));
    }

    #[test]
    fn pool_counters_and_gauge() {
        let m = Metrics::new();
        let s0 = m.snapshot();
        assert_eq!((s0.pool_hits, s0.pool_misses, s0.uploads_skipped), (0, 0, 0));
        assert_eq!(s0.pool_bytes_live, 0);
        m.on_pool_miss();
        m.on_pool_hit();
        m.on_pool_hit();
        m.set_pool_bytes(4096);
        let s = m.snapshot();
        assert_eq!((s.pool_hits, s.pool_misses, s.uploads_skipped), (2, 1, 2));
        assert_eq!(s.pool_bytes_live, 4096);
        m.set_pool_bytes(1024); // gauge overwrites, never accumulates
        assert_eq!(m.snapshot().pool_bytes_live, 1024);
    }

    #[test]
    fn banded_counter_tracks_hybrid_admissions() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().banded, 0);
        m.on_banded();
        m.on_banded();
        assert_eq!(m.snapshot().banded, 2);
    }
}
