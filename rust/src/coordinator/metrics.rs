//! Service metrics: request counters and latency quantiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    /// Latencies in microseconds (bounded reservoir).
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        if l.len() < RESERVOIR {
            l.push(latency.as_micros() as u64);
        }
    }

    pub fn on_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut l = self.latencies_us.lock().unwrap().clone();
        l.sort_unstable();
        let q = |p: f64| -> u64 {
            if l.is_empty() {
                0
            } else {
                l[((l.len() - 1) as f64 * p) as usize]
            }
        };
        let mean = if l.is_empty() { 0.0 } else { l.iter().sum::<u64>() as f64 / l.len() as f64 };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            p50_us: q(0.50),
            p99_us: q(0.99),
            mean_us: mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.on_submit();
            m.on_complete(Duration::from_micros(i));
        }
        m.on_error();
        let s = m.snapshot();
        assert_eq!(s.submitted, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.errors, 1);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 {}", s.p99_us);
        assert!((s.mean_us - 50.5).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_us, 0.0);
    }
}
