//! The serving facade: a clonable [`Session`] (alias [`SgapClient`])
//! over a running [`Coordinator`], plus the [`Ticket`] response future.
//!
//! The intended call pattern for repeat traffic — register once, submit
//! many times, every submit an `Arc` bump:
//!
//! ```no_run
//! use sgap::coordinator::{CoordinatorConfig, Session};
//! use sgap::sparse::erdos_renyi;
//!
//! let session = Session::start(CoordinatorConfig::default())?;
//! let a = session.register_matrix(erdos_renyi(256, 256, 2000, 1).to_csr());
//! let b = session.register_dense(vec![1.0; 256 * 4]);
//! // first submit: one fingerprint pass + one selector decision …
//! let c = session.spmm(&a, &b, 4).wait()?.c;
//! // … every repeat: zero-copy submit, plan-cache hit
//! let c2 = session.spmm(&a, &b, 4).wait()?.c;
//! assert_eq!(c, c2);
//! # anyhow::Ok(())
//! ```

use std::sync::mpsc::{Receiver, RecvError, TryRecvError};
use std::sync::Arc;

use anyhow::Result;

use crate::sparse::coo3::Coo3;
use crate::sparse::Csr;

use super::op::{DenseHandle, Op, SparseHandle};
use super::server::{Coordinator, CoordinatorConfig, Response};

/// A one-shot response future. Exactly one message ever arrives: the
/// served [`Response`] or the validation/serving error string.
pub struct Ticket {
    rx: Receiver<Result<Response, String>>,
}

impl Ticket {
    pub(crate) fn new(rx: Receiver<Result<Response, String>>) -> Ticket {
        Ticket { rx }
    }

    /// Block until the response arrives. A disconnected channel (pool
    /// shut down before serving) is reported as an error.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Blocking receive with the raw channel contract (mirrors
    /// [`Receiver::recv`]; `Err` means the pool shut down unserved).
    pub fn recv(&self) -> Result<Result<Response, String>, RecvError> {
        self.rx.recv()
    }

    /// Non-blocking poll (mirrors [`Receiver::try_recv`]).
    pub fn try_recv(&self) -> Result<Result<Response, String>, TryRecvError> {
        self.rx.try_recv()
    }
}

/// A clonable client over a shared [`Coordinator`]: registers operands
/// into `Arc`-backed handles and submits generic [`Op`]s. Cloning a
/// `Session` shares the pool; the last one dropped (or explicitly
/// [`Session::shutdown`]) joins it.
#[derive(Clone)]
pub struct Session {
    coord: Arc<Coordinator>,
}

/// The client-facing name of [`Session`].
pub type SgapClient = Session;

impl Session {
    /// Start a coordinator pool and wrap it.
    pub fn start(cfg: CoordinatorConfig) -> Result<Session> {
        Ok(Session { coord: Arc::new(Coordinator::start(cfg)?) })
    }

    /// Wrap an already-running pool (shared with other owners).
    pub fn with(coord: Arc<Coordinator>) -> Session {
        Session { coord }
    }

    /// The underlying pool (metrics, plan cache, lifecycle).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Register a CSR matrix: runs the fingerprint pass once, here, and
    /// returns a zero-copy handle for any number of submits.
    pub fn register_matrix(&self, a: Csr) -> SparseHandle {
        let h = SparseHandle::matrix(a);
        let _ = h.matrix_stats(); // prime the fingerprint at registration
        h
    }

    /// Register an order-3 COO tensor (see [`SparseHandle::tensor`]).
    pub fn register_tensor(&self, a: Coo3) -> SparseHandle {
        SparseHandle::tensor(a)
    }

    /// Register a dense operand.
    pub fn register_dense(&self, v: Vec<f32>) -> DenseHandle {
        DenseHandle::new(v)
    }

    /// Submit any [`Op`] (or a legacy `Request`) through the one generic
    /// serving path.
    pub fn submit(&self, op: impl Into<Op>) -> Ticket {
        self.coord.submit(op)
    }

    /// Admission-controlled submit: never blocks on a full queue.
    /// Returns [`OpError::Overloaded`](super::OpError::Overloaded) —
    /// with the observed queue depth and cap — when the coordinator is
    /// saturated, so callers can shed load instead of queueing behind
    /// it. See [`Coordinator::try_submit`].
    pub fn try_submit(&self, op: impl Into<Op>) -> Result<Ticket, super::OpError> {
        self.coord.try_submit(op)
    }

    /// Build and submit an SpMM op against registered handles.
    pub fn spmm(&self, a: &SparseHandle, b: &DenseHandle, n: usize) -> Ticket {
        self.submit(Op::spmm(a, b, n))
    }

    /// Build and submit an SDDMM op against registered handles.
    pub fn sddmm(
        &self,
        a: &SparseHandle,
        x1: &DenseHandle,
        x2: &DenseHandle,
        j_dim: usize,
    ) -> Ticket {
        self.submit(Op::sddmm(a, x1, x2, j_dim))
    }

    /// Build and submit an MTTKRP op against registered handles.
    pub fn mttkrp(
        &self,
        a: &SparseHandle,
        x1: &DenseHandle,
        x2: &DenseHandle,
        j_dim: usize,
    ) -> Ticket {
        self.submit(Op::mttkrp(a, x1, x2, j_dim))
    }

    /// Build and submit a TTM op against registered handles.
    pub fn ttm(&self, a: &SparseHandle, x1: &DenseHandle, l_dim: usize) -> Ticket {
        self.submit(Op::ttm(a, x1, l_dim))
    }

    /// Build and submit a fused SDDMM→SpMM op against registered handles —
    /// the attention chain `C = (A ⊙ X1·X2) · B` as one kernel, no
    /// materialized intermediate (see [`Op::fused`] for operand layouts).
    pub fn fused_sddmm_spmm(
        &self,
        a: &SparseHandle,
        x1: &DenseHandle,
        x2: &DenseHandle,
        b: &DenseHandle,
        j_dim: usize,
        n: usize,
    ) -> Ticket {
        self.submit(Op::fused(a, x1, x2, b, j_dim, n))
    }

    /// Stop accepting new work; in-flight and queued ops are still served.
    pub fn close(&self) {
        self.coord.close();
    }

    /// Stop accepting new work and — when this is the last handle on the
    /// pool — drain accepted jobs and join every worker (and the
    /// background tuner) before returning. Returns `true` when the pool
    /// was joined; `false` when other `Session` clones (or
    /// [`Session::with`] sharers) still hold it — the queue is closed
    /// either way, so the pool stops accepting work deterministically.
    pub fn shutdown(self) -> bool {
        self.coord.close();
        match Arc::try_unwrap(self.coord) {
            Ok(coord) => {
                coord.shutdown();
                true
            }
            Err(_) => false,
        }
    }
}
