//! The coordinator service: accepts SpMM/GCN jobs, batches them by
//! artifact route, executes on the PJRT runtime (CPU fallback when no
//! bucket admits a request), and reports metrics.
//!
//! Architecture: callers `submit()` onto an MPSC channel and receive a
//! one-shot response channel. A single worker thread owns the PJRT client
//! (executables stay hot in its cache), drains the queue into a
//! [`Batcher`] keyed by artifact name, and serves batches FIFO-fairly.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::algos::cpu_ref::spmm_serial;
use crate::runtime::{ArtifactKind, Runtime};
use crate::sparse::Csr;

use super::batcher::Batcher;
use super::metrics::Metrics;

/// An SpMM job: `C = A · B` with `B` row-major `[a.cols × n]`.
#[derive(Debug, Clone)]
pub struct Request {
    pub a: Csr,
    pub b: Vec<f32>,
    pub n: usize,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct Response {
    pub c: Vec<f32>,
    /// Which path served it: the artifact name, or "cpu-fallback".
    pub backend: String,
    pub latency_us: u64,
}

struct Job {
    req: Request,
    submitted: Instant,
    resp: Sender<Result<Response, String>>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<Job>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

const MAX_BATCH: usize = 16;

impl Coordinator {
    /// Start the worker. `artifacts_dir = None` forces the CPU fallback
    /// path (useful in tests without built artifacts).
    ///
    /// The PJRT client is `!Send`, so the [`Runtime`] is constructed
    /// *inside* the worker thread; startup errors are reported back over
    /// a one-shot channel before the worker enters its loop.
    pub fn start(artifacts_dir: Option<PathBuf>) -> Result<Coordinator> {
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("sgap-coordinator".into())
            .spawn(move || {
                let mut runtime = match &artifacts_dir {
                    Some(dir) => match Runtime::load(dir) {
                        Ok(rt) => {
                            let _ = ready_tx.send(Ok(()));
                            Some(rt)
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.to_string()));
                            return;
                        }
                    },
                    None => {
                        let _ = ready_tx.send(Ok(()));
                        None
                    }
                };
                worker_loop(rx, &mut runtime, &m)
            })
            .expect("spawn coordinator");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("runtime load failed: {e}"))?;
        Ok(Coordinator { tx: Some(tx), worker: Some(worker), metrics })
    }

    /// Submit a job; the returned channel yields the response.
    pub fn submit(&self, req: Request) -> Receiver<Result<Response, String>> {
        let (rtx, rrx) = channel();
        self.metrics.on_submit();
        let job = Job { req, submitted: Instant::now(), resp: rtx };
        if let Some(tx) = &self.tx {
            // a send error means the worker died; the caller sees a
            // disconnected receiver
            let _ = tx.send(job);
        }
        rrx
    }

    /// Convenience: submit and wait.
    pub fn spmm_blocking(&self, a: Csr, b: Vec<f32>, n: usize) -> Result<Response> {
        let rx = self.submit(Request { a, b, n });
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Stop accepting work and join the worker.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Routing key: the artifact that will serve a request.
fn route(runtime: &Option<Runtime>, req: &Request) -> String {
    if let Some(rt) = runtime {
        if let Some(spec) =
            rt.registry.route(ArtifactKind::SpmmNnzSr, req.a.rows, req.a.cols, req.a.nnz())
        {
            if spec.n == req.n {
                return spec.name.clone();
            }
        }
    }
    "cpu-fallback".to_string()
}

fn worker_loop(rx: Receiver<Job>, runtime: &mut Option<Runtime>, metrics: &Metrics) {
    let mut batcher: Batcher<String, Job> = Batcher::new(MAX_BATCH);
    loop {
        // Block for one job, then opportunistically drain the queue —
        // micro-batching under load, low latency when idle.
        match rx.recv() {
            Ok(job) => {
                let key = route(runtime, &job.req);
                batcher.push(key, job);
            }
            Err(_) => break, // all senders dropped: shut down
        }
        while let Ok(job) = rx.try_recv() {
            let key = route(runtime, &job.req);
            batcher.push(key, job);
        }
        while let Some((key, jobs)) = batcher.next_batch() {
            metrics.on_batch();
            for job in jobs {
                serve_one(&key, job, runtime, metrics);
            }
        }
    }
}

fn serve_one(key: &str, job: Job, runtime: &mut Option<Runtime>, metrics: &Metrics) {
    let result = if key == "cpu-fallback" {
        Ok(spmm_serial(&job.req.a, &job.req.b, job.req.n))
    } else {
        runtime
            .as_mut()
            .expect("routed to artifact without runtime")
            .run_spmm_nnz(key, &job.req.a, &job.req.b)
            .map_err(|e| e.to_string())
    };
    let latency = job.submitted.elapsed();
    match result {
        Ok(c) => {
            metrics.on_complete(latency);
            let _ = job.resp.send(Ok(Response {
                c,
                backend: key.to_string(),
                latency_us: latency.as_micros() as u64,
            }));
        }
        Err(e) => {
            metrics.on_error();
            let _ = job.resp.send(Err(e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::sparse::{erdos_renyi, SplitMix64};

    #[test]
    fn serves_on_cpu_fallback() {
        let coord = Coordinator::start(None).unwrap();
        let a = erdos_renyi(64, 64, 300, 4).to_csr();
        let mut rng = SplitMix64::new(5);
        let b: Vec<f32> = (0..64 * 4).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, 4);
        let resp = coord.spmm_blocking(a, b, 4).unwrap();
        assert_eq!(resp.backend, "cpu-fallback");
        assert!(max_rel_err(&resp.c, &want) < 1e-6);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let coord = Coordinator::start(None).unwrap();
        let mut rxs = Vec::new();
        for seed in 0..20u64 {
            let a = erdos_renyi(32, 32, 100, seed).to_csr();
            let mut rng = SplitMix64::new(seed);
            let b: Vec<f32> = (0..32 * 2).map(|_| rng.value()).collect();
            rxs.push((seed, coord.submit(Request { a, b, n: 2 })));
        }
        for (seed, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.c.len(), 32 * 2, "seed {seed}");
        }
        assert_eq!(coord.metrics.snapshot().completed, 20);
        coord.shutdown();
    }

    #[test]
    fn shutdown_is_clean() {
        let coord = Coordinator::start(None).unwrap();
        coord.shutdown(); // no panic, worker joined
    }
}
