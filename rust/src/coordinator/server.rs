//! The coordinator service: a pool of worker threads serving the full
//! §2.1 quartet — SpMM, SDDMM, MTTKRP, and TTM ops — with tuner-aware
//! kernel selection through a shared [`PlanCache`].
//!
//! Architecture (see DESIGN.md §serving and §serving-at-scale):
//!
//! ```text
//! callers ── submit(Op) / try_submit ──▶ bounded JobQueue ──▶ N workers
//!             (blocking)  (Overloaded)                          │
//!                 ┌──────────────────────────────────────────────┤
//!                 ▼                                              ▼
//!          PlanCache (sharded; ShapeKey → Algo)   shared Batcher (ShapeKey):
//!                 │ miss: Selector (model argmin)  cross-session coalescing
//!                 │ async: tuner upgrades the plan              │
//!                 │ warm start: PlanCatalog                     ▼
//!                 ▼                                     Executor stack:
//!          background tuner thread                      PJRT ▸ sim ▸ CPU
//! ```
//!
//! Callers `submit()` a generic [`Op`] — built from `Arc`-backed operand
//! handles, so a submit moves pointers, never operand data — and receive
//! a [`Ticket`]; `try_submit()` is the non-blocking admission-controlled
//! variant that answers a saturated queue with a typed
//! [`OpError::Overloaded`] instead of applying backpressure. Workers
//! drain the shared queue into one pool-wide [`Batcher`] keyed by the
//! plan-cache [`ShapeKey`](super::plan_cache::ShapeKey), so same-shape
//! ops **coalesce across sessions** into a single launch batch (the
//! `Arc`-backed operands make that routing, not copying); an age bound
//! keeps a half-full bucket from waiting forever behind hot shapes.
//! Each batch is then admitted per-op against the worker's [`Executor`]
//! stack and served. The first sight of a shape runs the DA-SpMM-style
//! [`Selector`] inside the sim executor's cache consult; repeats are
//! served with the cached plan at zero selection cost. When
//! `background_tune` is on, every cache miss also enqueues a grid-search
//! refinement that later *upgrades* the cached plan to the sweep's
//! winner, so sustained traffic converges on the tuned kernel. A
//! [`PlanCatalog`] passed in [`CoordinatorConfig::plans`] pre-warms the
//! cache so a restarted coordinator skips the selector on day-one
//! traffic (hits on preloaded entries count `warm_hits`).
//!
//! The legacy per-algebra surface (`Request`, `spmm_blocking`,
//! `submit_mttkrp`, …) is kept as thin shims over the one generic
//! `submit(Op)` path; prefer [`Session`](super::Session) + handles in
//! new code.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::compiler::DialectKind;
use crate::runtime::{DevicePool, Registry};
use crate::sim::{HwProfile, Machine};
use crate::sparse::coo3::Coo3;
use crate::sparse::{Csr, SplitMix64};
use crate::tuner::calibrate::Calibration;
use crate::tuner::{self, Selector};

use super::batcher::Batcher;
use super::calibrate::{CalibConfig, OnlineCalibrator};
use super::catalog::PlanCatalog;
use super::executor::{BackendKind, Executor, ExecutorEnv, ExecutorRegistry, TuneTask};
use super::metrics::Metrics;
use super::op::{Op, OpError, OpKind, Request, SparseData};
use super::plan_cache::{Plan, PlanCache, ShapeKey};
use super::pool::JobQueue;
use super::session::Ticket;

/// The served result.
#[derive(Debug, Clone)]
pub struct Response {
    /// SpMM: row-major `[rows × n]`; SDDMM: one value per non-zero;
    /// MTTKRP: row-major `[dim0 × j]`; TTM: row-major `[(dim0·dim1) × l]`.
    pub c: Vec<f32>,
    /// Which path served it. `Display` keeps the legacy label strings
    /// (`pjrt:<artifact>`, `sim:<family>`, `cpu-serial`, `cpu-fallback`),
    /// so logs and metrics are unchanged.
    pub backend: BackendKind,
    /// The plan-cache choice that routed this op (`None` on the PJRT and
    /// degenerate-input paths, which bypass the cache).
    pub plan: Option<Plan>,
    /// Whether the plan came from a cache hit (vs a fresh selection).
    pub cache_hit: bool,
    pub latency_us: u64,
}

impl Response {
    /// Human-readable label of the routed plan (the `Algo` name), when a
    /// plan routed this op.
    pub fn plan_label(&self) -> Option<String> {
        self.plan.map(|p| p.kind.name())
    }
}

struct Job {
    op: Op,
    submitted: Instant,
    resp: Sender<Result<Response, String>>,
}

/// The cross-session coalescing key. Ops with a plan-cache fingerprint
/// share a bucket — no matter which session submitted them — so one drain
/// serves them as a single batch; keyless ops (degenerate inputs whose
/// fingerprint is undefined) get a unique `Solo` id and batch alone.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum CoalesceKey {
    Shape(ShapeKey),
    Solo(u64),
}

/// The pool-wide coalescing state: one [`Batcher`] shared by every
/// worker (same-shape jobs from different sessions and different queue
/// drains meet here), plus the `Solo` id well. The mutex is held only to
/// stage or drain — never while a batch is served.
struct Coalescer {
    batcher: Mutex<Batcher<CoalesceKey, Job>>,
    solo_seq: AtomicU64,
}

/// Tuning parameters of the serving layer.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads in the pool (>= 1).
    pub workers: usize,
    /// Micro-batch bound per queue drain (the batch window).
    pub max_batch: usize,
    /// Job-queue bound; `submit` blocks (backpressure) when full.
    pub queue_cap: usize,
    /// PJRT artifacts directory; `None` disables artifact routing.
    pub artifacts_dir: Option<PathBuf>,
    /// Refine cache misses with a background grid-search tuner.
    pub background_tune: bool,
    /// Plan-cache entry bound (FIFO eviction per shard).
    pub plan_cache_capacity: usize,
    /// Plan-cache shard count: the key space is hash-partitioned over
    /// this many independently locked shards so concurrent sessions
    /// don't serialize on one mutex. `1` reproduces the single-lock
    /// cache exactly.
    pub plan_shards: usize,
    /// Warm-start plan catalog (yesterday's plans, via
    /// [`PlanCatalog::load`]). Preloaded entries serve without a
    /// selector run and count [`Metrics`] `warm_hits` when traffic
    /// finds them.
    pub plans: Option<PlanCatalog>,
    /// Hardware profile for the simulator backend.
    pub hw: HwProfile,
    /// The input-dynamics selector (fast-path plan choice).
    pub selector: Selector,
    /// Shortlist size the background tuner prunes candidate grids to with
    /// the analytic cost model before simulating; `0` is the escape hatch
    /// to exhaustive grid search.
    pub tune_top_k: usize,
    /// Route cache-miss plan selection through the analytic model's
    /// argmin (still O(stats), no simulation) instead of the bare
    /// decision tree.
    pub model_select: bool,
    /// The execution backends, in admission-priority order. Defaults to
    /// the standard PJRT ▸ simulator ▸ CPU stack; push a custom
    /// [`Executor`] factory to plug in a new backend.
    pub executors: ExecutorRegistry,
    /// Warm-start calibration (yesterday's fit, via `Calibration::load`).
    /// Applied to the sim executors' machine and cost model whether or
    /// not online calibration is enabled.
    pub calibration: Option<Calibration>,
    /// Online drift-tracking policy. Disabled by default — enable to let
    /// served latencies refit `CostParams` live.
    pub calib: CalibConfig,
    /// Byte budget of the device-buffer pool that keeps staged operand
    /// images resident across submits (resubmitting a registered handle
    /// skips the padded-buffer rebuild and re-upload). `0` disables
    /// pooling entirely.
    pub pool_budget_bytes: usize,
    /// Codegen dialect this coordinator serves under. Non-CUDA dialects
    /// surface in the simulator backend labels (`sim:<dialect>:<family>`);
    /// the CUDA default keeps the legacy `sim:<family>` labels.
    pub dialect: DialectKind,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 4);
        CoordinatorConfig {
            workers,
            max_batch: 16,
            queue_cap: 256,
            artifacts_dir: None,
            background_tune: false,
            plan_cache_capacity: 1024,
            plan_shards: 8,
            plans: None,
            hw: HwProfile::rtx3090(),
            selector: Selector::default(),
            tune_top_k: tuner::DEFAULT_TOP_K,
            model_select: true,
            executors: ExecutorRegistry::standard(),
            calibration: None,
            calib: CalibConfig::default(),
            pool_budget_bytes: 64 << 20,
            dialect: DialectKind::default(),
        }
    }
}

struct WorkerCtx {
    queue: Arc<JobQueue<Job>>,
    /// Shared context the worker hands its executors; the worker's own
    /// metrics writes go through `env.metrics` too (one sink, one wire).
    env: ExecutorEnv,
    registry: ExecutorRegistry,
    max_batch: usize,
    coalescer: Arc<Coalescer>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: Arc<JobQueue<Job>>,
    queue_cap: usize,
    workers: Vec<JoinHandle<()>>,
    tune_tx: Option<SyncSender<TuneTask>>,
    tuner: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub plan_cache: Arc<PlanCache>,
    /// The online calibration loop (drift tracker + refitter). Present
    /// even when `calib.enabled` is false, so warm-start fits apply and
    /// `calibrator.current()` can be saved at shutdown either way.
    pub calibrator: Arc<OnlineCalibrator>,
    /// The device-buffer pool shared by every worker's executors
    /// (`None` when `pool_budget_bytes` was 0).
    pub pool: Option<Arc<DevicePool>>,
}

impl Coordinator {
    /// Start the worker pool.
    ///
    /// The artifacts manifest (if configured) is validated here so a bad
    /// directory fails fast; the PJRT clients themselves are `!Send` and
    /// are constructed inside each worker thread by the executor
    /// factories. A worker whose client fails to come up degrades to the
    /// rest of its executor stack.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        if let Some(dir) = &cfg.artifacts_dir {
            Registry::load(dir)?; // fail fast on a broken manifest
        }
        let queue_cap = cfg.queue_cap.max(1);
        let queue = Arc::new(JobQueue::new(queue_cap));
        let metrics = Arc::new(Metrics::new());
        let plan_cache = Arc::new(PlanCache::with_shards(
            cfg.plan_cache_capacity.max(1),
            cfg.plan_shards.max(1),
        ));
        if let Some(catalog) = &cfg.plans {
            catalog.warm(&plan_cache);
        }
        // One batcher for the whole pool: same-shape jobs coalesce no
        // matter which worker staged them. The age bound keeps a
        // half-full bucket from starving behind a stream of hot shapes.
        let coalescer = Arc::new(Coalescer {
            batcher: Mutex::new(Batcher::with_age_bound(
                cfg.max_batch,
                (cfg.max_batch as u64).saturating_mul(4),
            )),
            solo_seq: AtomicU64::new(0),
        });
        let calibrator = Arc::new(OnlineCalibrator::new(
            Machine::new(cfg.hw),
            cfg.calibration.clone(),
            cfg.calib,
        ));
        // One pool for the whole worker pool: operands staged by one
        // worker hit from every worker (the simulated device is shared).
        let pool =
            (cfg.pool_budget_bytes > 0).then(|| Arc::new(DevicePool::new(cfg.pool_budget_bytes)));

        let (tune_tx, tuner) = if cfg.background_tune {
            let (tx, rx) = std::sync::mpsc::sync_channel::<TuneTask>(32);
            let cache = plan_cache.clone();
            let tuner_metrics = metrics.clone();
            // Snapshot the calibrated machine at startup: warm-start fits
            // reach the background tuner; later online refits reach only
            // the per-worker sim executors (which refresh per admit).
            let machine = calibrator.machine();
            let top_k = cfg.tune_top_k;
            let handle = std::thread::Builder::new()
                .name("sgap-tuner".into())
                .spawn(move || tuner_loop(rx, &machine, &cache, &tuner_metrics, top_k))
                .expect("spawn tuner");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };

        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let ctx = WorkerCtx {
                queue: queue.clone(),
                env: ExecutorEnv {
                    hw: cfg.hw,
                    selector: cfg.selector,
                    model_select: cfg.model_select,
                    plan_cache: plan_cache.clone(),
                    metrics: metrics.clone(),
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    tune_tx: tune_tx.clone(),
                    calibrator: Some(calibrator.clone()),
                    pool: pool.clone(),
                    dialect: cfg.dialect,
                },
                registry: cfg.executors.clone(),
                max_batch: cfg.max_batch,
                coalescer: coalescer.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sgap-worker-{i}"))
                    .spawn(move || worker_loop(ctx))
                    .expect("spawn coordinator worker"),
            );
        }
        Ok(Coordinator {
            queue,
            queue_cap,
            workers,
            tune_tx,
            tuner,
            metrics,
            plan_cache,
            calibrator,
            pool,
        })
    }

    /// Submit through the one generic serving path: any [`Op`] (or a
    /// legacy [`Request`], which converts by moving its operands into
    /// fresh handles). Blocks while the job queue is full (backpressure);
    /// the returned [`Ticket`] yields the response.
    pub fn submit(&self, op: impl Into<Op>) -> Ticket {
        let (rtx, rrx) = channel();
        let job = Job { op: op.into(), submitted: Instant::now(), resp: rtx };
        // a push error means the pool is shut down; dropping the job drops
        // its response sender, so the caller sees a disconnected ticket.
        // Only accepted jobs count as submitted — that keeps the metrics
        // identity `completed + errors == submitted` true across close().
        if self.queue.push(job).is_ok() {
            self.metrics.on_submit();
        }
        Ticket::new(rrx)
    }

    /// Admission-controlled submit: never blocks. A saturated queue
    /// answers with the typed [`OpError::Overloaded`] — carrying the
    /// observed depth and the configured cap, so callers can shed or
    /// retry with context — and counts [`Metrics`] `rejected` (rejected
    /// ops are *not* `submitted`, preserving the identity
    /// `completed + errors == submitted`). A closed pool yields a
    /// disconnected ticket, exactly like [`Coordinator::submit`].
    pub fn try_submit(&self, op: impl Into<Op>) -> Result<Ticket, OpError> {
        let (rtx, rrx) = channel();
        let job = Job { op: op.into(), submitted: Instant::now(), resp: rtx };
        match self.queue.try_push(job) {
            Ok(()) => {
                self.metrics.on_submit();
                Ok(Ticket::new(rrx))
            }
            // the rejected job (and its response sender) drops here; on a
            // closed pool the caller sees a disconnected ticket instead
            // of an error, mirroring the blocking path
            Err(_job) if self.queue.is_closed() => Ok(Ticket::new(rrx)),
            Err(_job) => {
                self.metrics.on_rejected();
                Err(OpError::Overloaded { depth: self.queue.len(), cap: self.queue_cap })
            }
        }
    }

    /// Jobs currently waiting in the bounded queue (staged-but-unserved
    /// batcher jobs not included). `queue_depth() <= queue_cap` always.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Legacy shim: submit an SpMM job and wait. Prefer
    /// [`Session::spmm`](super::Session::spmm) with registered handles.
    pub fn spmm_blocking(&self, a: Csr, b: Vec<f32>, n: usize) -> Result<Response> {
        self.submit(Request::Spmm { a, b, n }).wait()
    }

    /// Legacy shim: submit an SDDMM job and wait.
    pub fn sddmm_blocking(
        &self,
        a: Csr,
        x1: Vec<f32>,
        x2: Vec<f32>,
        j_dim: usize,
    ) -> Result<Response> {
        self.submit(Request::Sddmm { a, x1, x2, j_dim }).wait()
    }

    /// Legacy shim: submit an MTTKRP job; the ticket yields the response.
    pub fn submit_mttkrp(&self, a: Coo3, x1: Vec<f32>, x2: Vec<f32>, j_dim: usize) -> Ticket {
        self.submit(Request::Mttkrp { a, x1, x2, j_dim })
    }

    /// Legacy shim: submit an MTTKRP job and wait.
    pub fn mttkrp_blocking(
        &self,
        a: Coo3,
        x1: Vec<f32>,
        x2: Vec<f32>,
        j_dim: usize,
    ) -> Result<Response> {
        self.submit_mttkrp(a, x1, x2, j_dim).wait()
    }

    /// Legacy shim: submit a TTM job; the ticket yields the response.
    pub fn submit_ttm(&self, a: Coo3, x1: Vec<f32>, l_dim: usize) -> Ticket {
        self.submit(Request::Ttm { a, x1, l_dim })
    }

    /// Legacy shim: submit a TTM job and wait.
    pub fn ttm_blocking(&self, a: Coo3, x1: Vec<f32>, l_dim: usize) -> Result<Response> {
        self.submit_ttm(a, x1, l_dim).wait()
    }

    /// Stop accepting new work without joining: in-flight and queued jobs
    /// are still served. Subsequent `submit` calls yield a disconnected
    /// ticket. Call [`Coordinator::shutdown`] (or drop) to join.
    pub fn close(&self) {
        self.queue.close();
    }

    fn shutdown_inner(&mut self) {
        // stop accepting work; workers drain what was already accepted
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // workers (and their tune_tx clones) are gone: disconnect and join
        // the tuner so pending upgrades land before shutdown returns
        self.tune_tx.take();
        if let Some(t) = self.tuner.take() {
            let _ = t.join();
        }
    }

    /// Stop accepting work, drain accepted jobs, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

// ---- worker ---------------------------------------------------------------

fn worker_loop(ctx: WorkerCtx) {
    // Each worker instantiates its own executor stack (the PJRT client is
    // !Send, and per-worker executors keep their caches hot). Batching
    // state, by contrast, is pool-wide: staged jobs live in the shared
    // coalescer, so same-shape traffic from different sessions — and
    // different workers' drains — lands in one bucket.
    let mut executors = ctx.registry.build(&ctx.env);
    while let Some(job) = ctx.queue.pop() {
        let mut drained = 1usize;
        stage(job, &ctx);
        // opportunistic micro-batch: grab whatever else is queued, up to
        // the batch window, without blocking
        while drained < ctx.max_batch {
            match ctx.queue.try_pop() {
                Some(job) => {
                    stage(job, &ctx);
                    drained += 1;
                }
                None => break,
            }
        }
        // serve every ripe bucket: full ones, and ones whose oldest job
        // has aged past the coalescing window
        loop {
            let batch = ctx.coalescer.batcher.lock().unwrap().next_ready();
            let Some((key, jobs)) = batch else { break };
            serve_batch(key, jobs, &mut executors, &ctx);
        }
        // Nothing left upstream: flush young buckets rather than strand
        // them (the age bound only advances with new pushes). Every
        // staged job is drained either here by its stager or by whichever
        // worker consumed the queue's last item — no job outlives the
        // traffic that could have coalesced with it.
        if ctx.queue.is_empty() {
            flush(&mut executors, &ctx);
        }
    }
    // shutdown: the queue is closed and drained; flush residual batches
    flush(&mut executors, &ctx);
}

/// Validate and stage one job into the shared coalescer. Invalid ops are
/// answered immediately and never enter a bucket.
fn stage(job: Job, ctx: &WorkerCtx) {
    if let Err(e) = job.op.validate() {
        ctx.env.metrics.on_error();
        let _ = job.resp.send(Err(e.to_string()));
        return;
    }
    let key = match job.op.shape_key() {
        Some(k) => CoalesceKey::Shape(k),
        None => CoalesceKey::Solo(ctx.coalescer.solo_seq.fetch_add(1, Ordering::Relaxed)),
    };
    ctx.coalescer.batcher.lock().unwrap().push(key, job);
}

/// Unconditionally drain the shared batcher, serving batch by batch (the
/// lock is released while serving, so other workers stage and drain
/// concurrently; `next_batch` hands each bucket to exactly one worker).
fn flush(executors: &mut [Box<dyn Executor>], ctx: &WorkerCtx) {
    loop {
        let batch = ctx.coalescer.batcher.lock().unwrap().next_batch();
        let Some((key, jobs)) = batch else { break };
        serve_batch(key, jobs, &mut executors[..], ctx);
    }
}

/// Serve one coalesced bucket. A multi-job `Shape` bucket is the payoff:
/// `len - 1` ops rode along with the first (same plan, warm executor
/// state) and are counted [`Metrics`] `coalesced`.
fn serve_batch(
    key: CoalesceKey,
    jobs: Vec<Job>,
    executors: &mut [Box<dyn Executor>],
    ctx: &WorkerCtx,
) {
    ctx.env.metrics.on_batch();
    if matches!(key, CoalesceKey::Shape(_)) && jobs.len() > 1 {
        ctx.env.metrics.on_coalesced(jobs.len() as u64 - 1);
    }
    for job in jobs {
        serve_one(job, executors, ctx);
    }
}

/// Admit (priority scan over the executor stack) and run one staged job.
/// An executor failure (or an incompatible cached plan) drops to the
/// serial CPU oracle — an op can lose latency, never its response.
fn serve_one(job: Job, executors: &mut [Box<dyn Executor>], ctx: &WorkerCtx) {
    let admitted = executors.iter_mut().enumerate().find_map(|(exec, ex)| {
        let adm = ex.admit(&job.op)?;
        Some((adm, exec))
    });
    let Some((adm, exec)) = admitted else {
        // unreachable with the standard stack (the CPU executor admits all)
        ctx.env.metrics.on_error();
        let _ = job.resp.send(Err(format!("no executor admitted this {} op", job.op.kind)));
        return;
    };
    let (c, backend) = match executors[exec].execute(&job.op, &adm) {
        Ok(c) => (c, adm.backend),
        Err(_) => {
            ctx.env.metrics.on_fallback();
            (job.op.run_serial(), BackendKind::CpuFallback)
        }
    };
    let latency = job.submitted.elapsed();
    ctx.env.metrics.on_complete(&backend.to_string(), job.op.kind.label(), latency);
    let _ = job.resp.send(Ok(Response {
        c,
        backend,
        plan: adm.plan,
        cache_hit: adm.cache_hit,
        latency_us: latency.as_micros() as u64,
    }));
}

// ---- background tuner ------------------------------------------------------

/// Drain refinement tasks; each winning sweep upgrades the cached plan.
/// Exits when every sender (the workers' executor envs) is gone.
///
/// Tasks carry a zero-copy [`SparseHandle`](super::SparseHandle) on the
/// operand. Sweeps go through the model-pruned entry points
/// (`tuner::search::tune*_pruned`; SpMM via `tune_banded`, which also
/// competes the selector's per-band composite candidate when the model
/// gates it in): the analytic model prices the whole
/// grid in O(stats) and only `top_k` survivors are interpreted warp-by-
/// warp — the dominant cost of this hot path before the model existed.
/// `top_k = 0` is the exhaustive escape hatch. Every sweep records its
/// grid/survivor sizes and whether the model's top-1 pick won
/// ([`Metrics::on_tune`]), so prune accuracy is observable in production.
fn tuner_loop(
    rx: std::sync::mpsc::Receiver<TuneTask>,
    machine: &Machine,
    cache: &PlanCache,
    metrics: &Metrics,
    top_k: usize,
) {
    use super::plan_cache::PlanOrigin;
    while let Ok(task) = rx.recv() {
        // The cache itself is the dedupe state: skip shapes already tuned
        // (duplicate queued tasks land here after the first upgrade) and
        // shapes that were evicted meanwhile (the upgrade would be dropped
        // anyway; a future miss re-enqueues them).
        match cache.get(&task.key) {
            Some(plan) if plan.origin == PlanOrigin::Tuned => continue,
            Some(_) => {}
            None => continue,
        }
        // deterministic dense operands: only the timing matters
        let seed = (task.key.rows as u64) ^ ((task.key.nnz as u64) << 20) ^ task.width as u64;
        let mut rng = SplitMix64::new(seed);
        let pruned = match (task.key.scenario, task.handle.data()) {
            (OpKind::Spmm, SparseData::Matrix(a)) => {
                let cands = tuner::space::sgap_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let b: Vec<f32> =
                    (0..a.cols * task.width as usize).map(|_| rng.value()).collect();
                // banded variant: skewed shapes also get the selector's
                // composite candidate in the shortlist, so a sweep can
                // upgrade the key to a per-band hybrid plan
                tuner::search::tune_banded(machine, &cands, a, &b, task.width, top_k)
            }
            (OpKind::Sddmm, SparseData::Matrix(a)) => {
                let j = task.width as usize;
                let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
                let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
                let cands = tuner::space::sddmm_candidates(task.width);
                tuner::search::tune_sddmm_pruned(machine, &cands, a, &x1, &x2, top_k)
            }
            (OpKind::FusedSddmmSpmm, SparseData::Matrix(a)) => {
                // the fused width packs both dense extents: (j_dim << 16) | n
                let (jw, nw) = (task.width >> 16, task.width & 0xFFFF);
                let cands = tuner::space::fused_candidates(jw, nw);
                if cands.is_empty() {
                    continue;
                }
                let (j, n) = (jw as usize, nw as usize);
                let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
                let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
                let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();
                tuner::search::tune_fused_pruned(machine, &cands, a, &x1, &x2, &b, top_k)
            }
            (OpKind::Mttkrp, SparseData::Tensor(a)) => {
                let cands = tuner::space::mttkrp_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let j = task.width as usize;
                let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
                let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
                tuner::search::tune_mttkrp_pruned(machine, &cands, a, &x1, &x2, top_k)
            }
            (OpKind::Ttm, SparseData::Tensor(a)) => {
                let cands = tuner::space::ttm_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let l = task.width as usize;
                let x1: Vec<f32> = (0..a.dim2 * l).map(|_| rng.value()).collect();
                tuner::search::tune_ttm_pruned(machine, &cands, a, &x1, top_k)
            }
            // a scenario/operand mismatch cannot be produced by admission;
            // drop rather than guess
            _ => continue,
        };
        if let Ok(out) = pruned {
            if let Some((best, _)) = out.best() {
                metrics.on_tune(out.grid, out.survivors, out.model_rank_agree);
                cache.upgrade(task.key, best);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::{max_rel_err, spmm_serial};
    use crate::algos::mttkrp::mttkrp_serial;
    use crate::algos::sddmm::sddmm_serial;
    use crate::coordinator::plan_cache::{PlanOrigin, ShapeKey};
    use crate::sparse::{erdos_renyi, MatrixStats};

    fn small_cfg() -> CoordinatorConfig {
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() }
    }

    #[test]
    fn serves_spmm_through_plan_cache() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(64, 64, 300, 4).to_csr();
        let mut rng = SplitMix64::new(5);
        let b: Vec<f32> = (0..64 * 4).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, 4);
        let resp = coord.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
        assert!(resp.backend.is_sim(), "backend {}", resp.backend);
        assert!(!resp.cache_hit, "first sight must be a miss");
        assert!(resp.plan.is_some() && resp.plan_label().is_some());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        // repeat: identical shape hits the cache and matches bit-for-bit
        let resp2 = coord.spmm_blocking(a, b, 4).unwrap();
        assert!(resp2.cache_hit);
        assert_eq!(resp2.plan, resp.plan);
        assert_eq!(resp2.c, resp.c, "cached plan must reproduce the result exactly");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        coord.shutdown();
    }

    #[test]
    fn serves_sddmm() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(48, 40, 300, 9).to_csr();
        let mut rng = SplitMix64::new(1);
        let j = 16usize;
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let want = sddmm_serial(&a, &x1, &x2, j);
        let resp = coord.sddmm_blocking(a, x1, x2, j).unwrap();
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        assert_eq!(
            resp.backend,
            BackendKind::Sim { family: "sddmm-group" },
            "backend {}",
            resp.backend
        );
        coord.shutdown();
    }

    #[test]
    fn serves_mttkrp_and_ttm_through_plan_cache() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = Coo3::random((32, 24, 16), 500, 3);
        let mut rng = SplitMix64::new(8);
        let j = 8usize;
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let want = mttkrp_serial(&a, &x1, &x2, j);
        let resp = coord.mttkrp_blocking(a.clone(), x1.clone(), x2.clone(), j).unwrap();
        assert_eq!(
            resp.backend,
            BackendKind::Sim { family: "mttkrp-group" },
            "backend {}",
            resp.backend
        );
        assert!(!resp.cache_hit && resp.plan.is_some());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        // repeat: identical tensor hits the cache and reproduces exactly
        let resp2 = coord.mttkrp_blocking(a.clone(), x1, x2, j).unwrap();
        assert!(resp2.cache_hit);
        assert_eq!(resp2.c, resp.c);

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let want = crate::algos::mttkrp::ttm_serial(&a, &lx1, 4);
        let resp = coord.ttm_blocking(a.clone(), lx1.clone(), 4).unwrap();
        assert_eq!(
            resp.backend,
            BackendKind::Sim { family: "ttm-group" },
            "backend {}",
            resp.backend
        );
        assert!(max_rel_err(&resp.c, &want) < 5e-4);

        // a width no kernel launch shape covers is served on the CPU,
        // correctly, without touching the plan cache
        let jx1: Vec<f32> = (0..a.dim1 * 20).map(|_| rng.value()).collect();
        let jx2: Vec<f32> = (0..a.dim2 * 20).map(|_| rng.value()).collect();
        let want = mttkrp_serial(&a, &jx1, &jx2, 20);
        let resp = coord.mttkrp_blocking(a, jx1, jx2, 20).unwrap();
        assert_eq!(resp.backend, BackendKind::CpuSerial);
        assert!(resp.plan.is_none());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        coord.shutdown();
    }

    #[test]
    fn background_tuner_upgrades_tensor_plans() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            background_tune: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = Coo3::random((24, 16, 12), 300, 5);
        let j = 4usize;
        let x1 = vec![1.0f32; a.dim1 * j];
        let x2 = vec![0.5f32; a.dim2 * j];
        coord.mttkrp_blocking(a.clone(), x1, x2, j).unwrap();
        let key = ShapeKey::mttkrp(&a, j as u32);
        let cache = coord.plan_cache.clone();
        coord.shutdown(); // joins the tuner: the upgrade has landed
        let plan = cache.get(&key).expect("plan still cached");
        assert_eq!(plan.origin, PlanOrigin::Tuned);
        assert!(plan.kind.is_mttkrp(), "tuned plan {} changed scenario", plan.kind.name());
    }

    #[test]
    fn serves_fused_through_plan_cache_and_tuner() {
        use crate::algos::fused::fused_serial;
        use crate::coordinator::op::{DenseHandle, SparseHandle};
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            background_tune: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = erdos_renyi(48, 40, 300, 21).to_csr();
        let (j, n) = (8usize, 4usize);
        let mut rng = SplitMix64::new(2);
        let x1 = DenseHandle::new((0..a.rows * j).map(|_| rng.value()).collect());
        let x2 = DenseHandle::new((0..j * a.cols).map(|_| rng.value()).collect());
        let b = DenseHandle::new((0..a.cols * n).map(|_| rng.value()).collect());
        let h = SparseHandle::matrix(a.clone());
        let want = fused_serial(&a, &x1, &x2, &b, j, n);
        let op = Op::fused(&h, &x1, &x2, &b, j, n);
        let resp = coord.submit(op.clone()).wait().unwrap();
        assert_eq!(
            resp.backend,
            BackendKind::Sim { family: "fused-sddmm-spmm" },
            "backend {}",
            resp.backend
        );
        assert!(!resp.cache_hit && resp.plan.is_some());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        // repeat: same registration hits the cache (the concurrent tuner
        // may have upgraded the plan, so only accuracy is asserted)
        let resp2 = coord.submit(op).wait().unwrap();
        assert!(resp2.cache_hit);
        assert!(max_rel_err(&resp2.c, &want) < 5e-4);
        let key = ShapeKey::fused(&MatrixStats::of(&a), ((j << 16) | n) as u32);
        let cache = coord.plan_cache.clone();
        coord.shutdown(); // joins the tuner: the upgrade has landed
        let plan = cache.get(&key).expect("plan still cached");
        assert_eq!(plan.origin, PlanOrigin::Tuned);
        assert!(plan.kind.is_fused(), "tuned plan {} changed scenario", plan.kind.name());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let mut rxs = Vec::new();
        for seed in 0..20u64 {
            let a = erdos_renyi(32, 32, 100, seed).to_csr();
            let mut rng = SplitMix64::new(seed);
            let b: Vec<f32> = (0..32 * 2).map(|_| rng.value()).collect();
            rxs.push((seed, coord.submit(Request::Spmm { a, b, n: 2 })));
        }
        for (seed, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.c.len(), 32 * 2, "seed {seed}");
        }
        assert_eq!(coord.metrics.snapshot().completed, 20);
        coord.shutdown();
    }

    #[test]
    fn invalid_request_is_an_error_not_a_panic() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(16, 16, 40, 1).to_csr();
        let err = coord.spmm_blocking(a.clone(), vec![0.0; 3], 2).unwrap_err();
        assert!(err.to_string().contains("spmm"), "{err}");
        let err = coord.sddmm_blocking(a, vec![], vec![], 0).unwrap_err();
        assert!(err.to_string().contains("j_dim"), "{err}");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.errors, 2);
        coord.shutdown();
    }

    #[test]
    fn empty_matrix_served_on_cpu() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = crate::sparse::Coo::new(8, 8, vec![]).to_csr();
        let resp = coord.spmm_blocking(a, vec![1.0; 8 * 2], 2).unwrap();
        assert_eq!(resp.backend, BackendKind::CpuSerial);
        assert!(resp.plan.is_none() && resp.plan_label().is_none());
        assert!(resp.c.iter().all(|&v| v == 0.0));
        coord.shutdown();
    }

    #[test]
    fn background_tuner_upgrades_plan() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            background_tune: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = erdos_renyi(48, 48, 250, 7).to_csr();
        let b = vec![1.0f32; 48 * 4];
        coord.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
        let key = ShapeKey::spmm(&MatrixStats::of(&a), 4);
        let cache = coord.plan_cache.clone();
        let metrics = coord.metrics.clone();
        coord.shutdown(); // joins the tuner: the upgrade has landed
        let plan = cache.get(&key).expect("plan still cached");
        assert_eq!(plan.origin, PlanOrigin::Tuned);
        assert!(cache.stats().upgrades >= 1);
        // the sweep went through the model-pruned path and was recorded
        let s = metrics.snapshot();
        assert!(s.tunes >= 1, "no tune recorded");
        assert!(s.tune_survivors <= s.tune_grid);
        assert!(
            s.tune_survivors <= s.tunes * crate::tuner::DEFAULT_TOP_K as u64,
            "pruning did not bound the simulated candidates: {} sweeps, {} survivors",
            s.tunes,
            s.tune_survivors
        );
    }

    #[test]
    fn shutdown_is_clean() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        coord.shutdown(); // no panic, workers joined
    }

    use crate::coordinator::executor::{factory, Admission};

    /// Parks in `execute` until the test feeds the gate — lets tests hold
    /// the (single) worker busy at a deterministic point.
    struct GateExec {
        entered: Arc<Mutex<Sender<()>>>,
        gate: Arc<Mutex<std::sync::mpsc::Receiver<()>>>,
    }

    impl Executor for GateExec {
        fn name(&self) -> &'static str {
            "gate"
        }

        fn admit(&mut self, _op: &Op) -> Option<Admission> {
            Some(Admission {
                backend: BackendKind::Custom("gate".into()),
                plan: None,
                cache_hit: false,
            })
        }

        fn execute(&mut self, op: &Op, _adm: &Admission) -> Result<Vec<f32>, String> {
            let _ = self.entered.lock().unwrap().send(());
            let _ = self.gate.lock().unwrap().recv();
            Ok(op.run_serial())
        }
    }

    fn gated_registry(
        entered: &Arc<Mutex<Sender<()>>>,
        gate: &Arc<Mutex<std::sync::mpsc::Receiver<()>>>,
    ) -> ExecutorRegistry {
        let (e, g) = (entered.clone(), gate.clone());
        let mut reg = ExecutorRegistry::empty();
        reg.push(factory(move |_| {
            Some(Box::new(GateExec { entered: e.clone(), gate: g.clone() }) as Box<dyn Executor>)
        }));
        reg
    }

    #[test]
    fn try_submit_rejects_with_typed_overload_when_saturated() {
        let (entered_tx, entered_rx) = channel::<()>();
        let (gate_tx, gate_rx) = channel::<()>();
        let entered = Arc::new(Mutex::new(entered_tx));
        let gate = Arc::new(Mutex::new(gate_rx));
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_cap: 1,
            executors: gated_registry(&entered, &gate),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = erdos_renyi(16, 16, 40, 3).to_csr();
        let b = vec![1.0f32; 16 * 2];
        let t1 = coord.submit(Request::Spmm { a: a.clone(), b: b.clone(), n: 2 });
        // once `entered` fires, the worker has drained t1 and is parked
        // inside execute — the queue holds exactly what we put there next
        entered_rx.recv().unwrap();
        let t2 = coord
            .try_submit(Request::Spmm { a: a.clone(), b: b.clone(), n: 2 })
            .expect("one free slot");
        let err = coord.try_submit(Request::Spmm { a, b, n: 2 }).unwrap_err();
        assert!(matches!(err, OpError::Overloaded { depth: 1, cap: 1 }), "{err}");
        assert_eq!(coord.queue_depth(), 1);
        // release the gate twice: t1 finishes, then the worker serves t2
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(t1.wait().unwrap().c.len(), 16 * 2);
        assert_eq!(t2.wait().unwrap().c.len(), 16 * 2);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.submitted, 2, "rejected ops are not submitted");
        assert_eq!(snap.completed, 2);
        coord.shutdown();
    }

    #[test]
    fn same_shape_jobs_coalesce_into_one_batch() {
        let (entered_tx, entered_rx) = channel::<()>();
        let (gate_tx, gate_rx) = channel::<()>();
        let entered = Arc::new(Mutex::new(entered_tx));
        let gate = Arc::new(Mutex::new(gate_rx));
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            executors: gated_registry(&entered, &gate),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = erdos_renyi(24, 24, 80, 9).to_csr();
        let b = vec![0.5f32; 24 * 2];
        // park the worker on a sacrificial op, then queue two same-shape
        // ops behind it: the worker's next drain stages both into one
        // ShapeKey bucket and serves them as a single coalesced batch
        let warmup = coord.submit(Request::Spmm { a: a.clone(), b: b.clone(), n: 2 });
        entered_rx.recv().unwrap();
        let t1 = coord.submit(Request::Spmm { a: a.clone(), b: b.clone(), n: 2 });
        let t2 = coord.submit(Request::Spmm { a, b, n: 2 });
        for _ in 0..3 {
            gate_tx.send(()).unwrap();
        }
        warmup.wait().unwrap();
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        assert_eq!(r1.c, r2.c, "coalesced twins must agree");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.coalesced, 1, "t2 rode along with t1");
        assert_eq!(snap.completed, 3);
        coord.shutdown();
    }

    #[test]
    fn plan_catalog_warm_starts_a_fresh_coordinator() {
        let a = erdos_renyi(48, 48, 260, 11).to_csr();
        let b = vec![1.0f32; 48 * 4];
        let first = Coordinator::start(small_cfg()).unwrap();
        first.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
        let catalog = PlanCatalog::from_cache(&first.plan_cache);
        assert_eq!(catalog.len(), 1);
        first.shutdown();

        let second = Coordinator::start(CoordinatorConfig {
            workers: 2,
            plans: Some(catalog),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let resp = second.spmm_blocking(a, b, 4).unwrap();
        assert!(resp.cache_hit, "preloaded plan must serve the first request");
        let snap = second.metrics.snapshot();
        assert_eq!(snap.warm_hits, 1);
        assert_eq!(snap.cache_misses, 0, "no selector run on replayed traffic");
        assert_eq!(snap.cache_hits, 1);
        second.shutdown();
    }
}
