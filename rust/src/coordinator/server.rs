//! The coordinator service: a pool of worker threads serving the full
//! §2.1 quartet — SpMM, SDDMM, MTTKRP, and TTM jobs — with tuner-aware
//! kernel selection through a shared [`PlanCache`].
//!
//! Architecture (see DESIGN.md §serving):
//!
//! ```text
//! callers ── submit() ──▶ bounded JobQueue (backpressure) ──▶ N workers
//!                                                              │
//!                 ┌────────────────────────────────────────────┤
//!                 ▼                                            ▼
//!          PlanCache (ShapeKey → Algo, any kernel kind) Batcher per worker
//!                 │ miss: Selector::select (fast)              │
//!                 │ async: tuner::tune upgrades the plan       ▼
//!                 ▼                                   PJRT / simulator /
//!          background tuner thread                    CPU-serial backends
//! ```
//!
//! Callers `submit()` a [`Request`] and receive a one-shot response
//! channel. Workers drain the shared queue (micro-batching under load via
//! the [`Batcher`]), fingerprint each matrix, and consult the plan cache:
//! the first sight of a shape runs the DA-SpMM-style [`Selector`] (a few
//! float comparisons); repeats are served with the cached plan at zero
//! selection cost. When `background_tune` is on, every cache miss also
//! enqueues a grid-search refinement that later *upgrades* the cached plan
//! to the sweep's winner, so sustained traffic converges on the tuned
//! kernel. PJRT artifacts (when compiled in and present) serve admitted
//! SpMM requests on the numeric hot path; everything else runs the chosen
//! kernel on the SIMT simulator, with the serial CPU path as the
//! last-resort fallback.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::algos::catalog::Algo;
use crate::algos::cpu_ref::spmm_serial;
use crate::algos::mttkrp::{mttkrp_serial, ttm_serial};
use crate::algos::sddmm::sddmm_serial;
use crate::runtime::{ArtifactKind, Registry, Runtime};
use crate::sim::{HwProfile, Machine};
use crate::sparse::coo3::Coo3;
use crate::sparse::{Csr, MatrixStats, SplitMix64};
use crate::tuner::{self, CostModel, Selector};

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::plan_cache::{Plan, PlanCache, Scenario, ShapeKey};
use super::pool::JobQueue;

/// A serving job — one variant per algebra of the §2.1 quartet: SpMM,
/// SDDMM (`Y = A ⊙ (X1 · X2)`, one output per non-zero of `A`), MTTKRP,
/// and TTM (order-3 COO tensor contractions).
#[derive(Debug, Clone)]
pub enum Request {
    /// `C = A · B` with `B` row-major `[a.cols × n]`.
    Spmm { a: Csr, b: Vec<f32>, n: usize },
    /// `Y(pos) = A_vals(pos) · dot(X1[i,:], X2[:,k])` with `x1` row-major
    /// `[a.rows × j_dim]` and `x2` row-major `[j_dim × a.cols]`.
    Sddmm { a: Csr, x1: Vec<f32>, x2: Vec<f32>, j_dim: usize },
    /// `Y(i,j) = Σ A(i,k,l)·X1(k,j)·X2(l,j)` with `x1` row-major
    /// `[a.dim1 × j_dim]`, `x2` row-major `[a.dim2 × j_dim]`; the response
    /// is row-major `[a.dim0 × j_dim]`.
    Mttkrp { a: Coo3, x1: Vec<f32>, x2: Vec<f32>, j_dim: usize },
    /// `Y(i,j,l) = Σ A(i,j,k)·X1(k,l)` with `x1` row-major
    /// `[a.dim2 × l_dim]`; the response is row-major
    /// `[(a.dim0·a.dim1) × l_dim]`.
    Ttm { a: Coo3, x1: Vec<f32>, l_dim: usize },
}

impl Request {
    fn validate(&self) -> Result<(), String> {
        match self {
            Request::Spmm { a, b, n } => {
                if *n == 0 {
                    return Err("spmm: n must be >= 1".into());
                }
                if b.len() != a.cols * n {
                    return Err(format!(
                        "spmm: B has {} elements, want cols x n = {} x {}",
                        b.len(),
                        a.cols,
                        n
                    ));
                }
                Ok(())
            }
            Request::Sddmm { a, x1, x2, j_dim } => {
                if *j_dim == 0 {
                    return Err("sddmm: j_dim must be >= 1".into());
                }
                if x1.len() != a.rows * j_dim {
                    return Err(format!(
                        "sddmm: X1 has {} elements, want rows x j = {} x {}",
                        x1.len(),
                        a.rows,
                        j_dim
                    ));
                }
                if x2.len() != j_dim * a.cols {
                    return Err(format!(
                        "sddmm: X2 has {} elements, want j x cols = {} x {}",
                        x2.len(),
                        j_dim,
                        a.cols
                    ));
                }
                Ok(())
            }
            Request::Mttkrp { a, x1, x2, j_dim } => {
                if *j_dim == 0 {
                    return Err("mttkrp: j_dim must be >= 1".into());
                }
                if x1.len() != a.dim1 * j_dim {
                    return Err(format!(
                        "mttkrp: X1 has {} elements, want dim1 x j = {} x {}",
                        x1.len(),
                        a.dim1,
                        j_dim
                    ));
                }
                if x2.len() != a.dim2 * j_dim {
                    return Err(format!(
                        "mttkrp: X2 has {} elements, want dim2 x j = {} x {}",
                        x2.len(),
                        a.dim2,
                        j_dim
                    ));
                }
                Ok(())
            }
            Request::Ttm { a, x1, l_dim } => {
                if *l_dim == 0 {
                    return Err("ttm: l_dim must be >= 1".into());
                }
                if x1.len() != a.dim2 * l_dim {
                    return Err(format!(
                        "ttm: X1 has {} elements, want dim2 x l = {} x {}",
                        x1.len(),
                        a.dim2,
                        l_dim
                    ));
                }
                Ok(())
            }
        }
    }

    /// Inputs the kernels do not cover (served straight on the CPU path).
    fn degenerate(&self) -> bool {
        match self {
            Request::Spmm { a, .. } | Request::Sddmm { a, .. } => a.nnz() == 0 || a.rows == 0,
            Request::Mttkrp { a, .. } | Request::Ttm { a, .. } => a.nnz() == 0 || a.dim0 == 0,
        }
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct Response {
    /// SpMM: row-major `[rows × n]`; SDDMM: one value per non-zero;
    /// MTTKRP: row-major `[dim0 × j]`; TTM: row-major `[(dim0·dim1) × l]`.
    pub c: Vec<f32>,
    /// Which path served it: `pjrt:<artifact>`, `sim:<family>`,
    /// `cpu-serial`, or `cpu-fallback`.
    pub backend: String,
    /// The plan-cache choice that routed this request (None on the PJRT
    /// and degenerate-input paths, which bypass the cache).
    pub plan: Option<String>,
    /// Whether the plan came from a cache hit (vs a fresh selection).
    pub cache_hit: bool,
    pub latency_us: u64,
}

struct Job {
    req: Request,
    submitted: Instant,
    resp: Sender<Result<Response, String>>,
}

/// Where a routed job executes.
enum Backend {
    /// PJRT artifact by name (numeric hot path).
    Pjrt(String),
    /// Simulator execution of a plan-cache choice.
    Sim(Plan, bool),
    /// Serial CPU path (degenerate inputs the kernels don't cover).
    Cpu,
}

struct Routed {
    job: Job,
    backend: Backend,
}

/// Tuning parameters of the serving layer.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads in the pool (>= 1).
    pub workers: usize,
    /// Micro-batch bound per queue drain (the batch window).
    pub max_batch: usize,
    /// Job-queue bound; `submit` blocks (backpressure) when full.
    pub queue_cap: usize,
    /// PJRT artifacts directory; `None` disables artifact routing.
    pub artifacts_dir: Option<PathBuf>,
    /// Refine cache misses with a background grid-search tuner.
    pub background_tune: bool,
    /// Plan-cache entry bound (FIFO eviction).
    pub plan_cache_capacity: usize,
    /// Hardware profile for the simulator backend.
    pub hw: HwProfile,
    /// The input-dynamics selector (fast-path plan choice).
    pub selector: Selector,
    /// Shortlist size the background tuner prunes candidate grids to with
    /// the analytic cost model before simulating; `0` is the escape hatch
    /// to exhaustive grid search.
    pub tune_top_k: usize,
    /// Route cache-miss plan selection through the analytic model's
    /// argmin (still O(stats), no simulation) instead of the bare
    /// decision tree.
    pub model_select: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 4);
        CoordinatorConfig {
            workers,
            max_batch: 16,
            queue_cap: 256,
            artifacts_dir: None,
            background_tune: false,
            plan_cache_capacity: 1024,
            hw: HwProfile::rtx3090(),
            selector: Selector::default(),
            tune_top_k: tuner::DEFAULT_TOP_K,
            model_select: true,
        }
    }
}

/// What the background tuner sweeps over: the request's sparse operand.
enum TuneInput {
    Matrix(Csr),
    Tensor(Coo3),
}

struct TuneTask {
    key: ShapeKey,
    input: TuneInput,
    width: u32,
}

struct WorkerCtx {
    queue: Arc<JobQueue<Job>>,
    metrics: Arc<Metrics>,
    plan_cache: Arc<PlanCache>,
    selector: Selector,
    /// `Some` when miss-path selection goes through the analytic model.
    model: Option<CostModel>,
    machine: Machine,
    artifacts_dir: Option<PathBuf>,
    max_batch: usize,
    tune_tx: Option<SyncSender<TuneTask>>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: Arc<JobQueue<Job>>,
    workers: Vec<JoinHandle<()>>,
    tune_tx: Option<SyncSender<TuneTask>>,
    tuner: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub plan_cache: Arc<PlanCache>,
}

impl Coordinator {
    /// Start the worker pool.
    ///
    /// The artifacts manifest (if configured) is validated here so a bad
    /// directory fails fast; the PJRT clients themselves are `!Send` and
    /// are constructed inside each worker thread. A worker whose client
    /// fails to come up degrades to the simulator/CPU backends.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        if let Some(dir) = &cfg.artifacts_dir {
            Registry::load(dir)?; // fail fast on a broken manifest
        }
        let queue = Arc::new(JobQueue::new(cfg.queue_cap.max(1)));
        let metrics = Arc::new(Metrics::new());
        let plan_cache = Arc::new(PlanCache::new(cfg.plan_cache_capacity.max(1)));

        let (tune_tx, tuner) = if cfg.background_tune {
            let (tx, rx) = std::sync::mpsc::sync_channel::<TuneTask>(32);
            let cache = plan_cache.clone();
            let tuner_metrics = metrics.clone();
            let machine = Machine::new(cfg.hw);
            let top_k = cfg.tune_top_k;
            let handle = std::thread::Builder::new()
                .name("sgap-tuner".into())
                .spawn(move || tuner_loop(rx, &machine, &cache, &tuner_metrics, top_k))
                .expect("spawn tuner");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };

        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let machine = Machine::new(cfg.hw);
            let ctx = WorkerCtx {
                queue: queue.clone(),
                metrics: metrics.clone(),
                plan_cache: plan_cache.clone(),
                selector: cfg.selector,
                model: cfg.model_select.then(|| CostModel::new(&machine)),
                machine,
                artifacts_dir: cfg.artifacts_dir.clone(),
                max_batch: cfg.max_batch,
                tune_tx: tune_tx.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sgap-worker-{i}"))
                    .spawn(move || worker_loop(ctx))
                    .expect("spawn coordinator worker"),
            );
        }
        Ok(Coordinator { queue, workers, tune_tx, tuner, metrics, plan_cache })
    }

    /// Submit a job; the returned channel yields the response. Blocks while
    /// the job queue is full (backpressure).
    pub fn submit(&self, req: Request) -> Receiver<Result<Response, String>> {
        let (rtx, rrx) = channel();
        let job = Job { req, submitted: Instant::now(), resp: rtx };
        // a push error means the pool is shut down; dropping the job drops
        // its response sender, so the caller sees a disconnected receiver.
        // Only accepted jobs count as submitted — that keeps the metrics
        // identity `completed + errors == submitted` true across close().
        if self.queue.push(job).is_ok() {
            self.metrics.on_submit();
        }
        rrx
    }

    /// Convenience: submit an SpMM job and wait.
    pub fn spmm_blocking(&self, a: Csr, b: Vec<f32>, n: usize) -> Result<Response> {
        let rx = self.submit(Request::Spmm { a, b, n });
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Convenience: submit an SDDMM job and wait.
    pub fn sddmm_blocking(
        &self,
        a: Csr,
        x1: Vec<f32>,
        x2: Vec<f32>,
        j_dim: usize,
    ) -> Result<Response> {
        let rx = self.submit(Request::Sddmm { a, x1, x2, j_dim });
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit an MTTKRP job; the returned channel yields the response.
    pub fn submit_mttkrp(
        &self,
        a: Coo3,
        x1: Vec<f32>,
        x2: Vec<f32>,
        j_dim: usize,
    ) -> Receiver<Result<Response, String>> {
        self.submit(Request::Mttkrp { a, x1, x2, j_dim })
    }

    /// Convenience: submit an MTTKRP job and wait.
    pub fn mttkrp_blocking(
        &self,
        a: Coo3,
        x1: Vec<f32>,
        x2: Vec<f32>,
        j_dim: usize,
    ) -> Result<Response> {
        let rx = self.submit_mttkrp(a, x1, x2, j_dim);
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a TTM job; the returned channel yields the response.
    pub fn submit_ttm(
        &self,
        a: Coo3,
        x1: Vec<f32>,
        l_dim: usize,
    ) -> Receiver<Result<Response, String>> {
        self.submit(Request::Ttm { a, x1, l_dim })
    }

    /// Convenience: submit a TTM job and wait.
    pub fn ttm_blocking(&self, a: Coo3, x1: Vec<f32>, l_dim: usize) -> Result<Response> {
        let rx = self.submit_ttm(a, x1, l_dim);
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Stop accepting new work without joining: in-flight and queued jobs
    /// are still served. Subsequent `submit` calls yield a disconnected
    /// receiver. Call [`Coordinator::shutdown`] (or drop) to join.
    pub fn close(&self) {
        self.queue.close();
    }

    fn shutdown_inner(&mut self) {
        // stop accepting work; workers drain what was already accepted
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // workers (and their tune_tx clones) are gone: disconnect and join
        // the tuner so pending upgrades land before shutdown returns
        self.tune_tx.take();
        if let Some(t) = self.tuner.take() {
            let _ = t.join();
        }
    }

    /// Stop accepting work, drain accepted jobs, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

// ---- worker ---------------------------------------------------------------

/// Batcher key for a routed job: one bucket per backend family.
fn batch_label(backend: &Backend) -> String {
    match backend {
        Backend::Pjrt(name) => format!("pjrt:{name}"),
        Backend::Sim(plan, _) => format!("sim:{}", plan.kind.family_label()),
        Backend::Cpu => "cpu-serial".to_string(),
    }
}

fn worker_loop(ctx: WorkerCtx) {
    // The PJRT client is !Send, so each worker owns its own Runtime (the
    // executable cache stays hot per worker). In builds without the `pjrt`
    // feature `Runtime::available()` is false and this stays `None`.
    let mut runtime: Option<Runtime> = if Runtime::available() {
        ctx.artifacts_dir.as_ref().and_then(|d| Runtime::load(d).ok())
    } else {
        None
    };

    let mut batcher: Batcher<String, Routed> = Batcher::new(ctx.max_batch);
    while let Some(job) = ctx.queue.pop() {
        let mut drained = 1usize;
        enqueue(job, &ctx, &runtime, &mut batcher);
        // opportunistic micro-batch: grab whatever else is queued, up to
        // the batch window, without blocking
        while drained < ctx.max_batch {
            match ctx.queue.try_pop() {
                Some(job) => {
                    enqueue(job, &ctx, &runtime, &mut batcher);
                    drained += 1;
                }
                None => break,
            }
        }
        while let Some((label, jobs)) = batcher.next_batch() {
            ctx.metrics.on_batch();
            for routed in jobs {
                serve_one(&label, routed, &mut runtime, &ctx);
            }
        }
    }
}

/// Validate, route (plan-cache consult), and stage a job for batching.
/// Invalid requests are answered immediately and never enter a batch.
fn enqueue(job: Job, ctx: &WorkerCtx, runtime: &Option<Runtime>, batcher: &mut Batcher<String, Routed>) {
    if let Err(e) = job.req.validate() {
        ctx.metrics.on_error();
        let _ = job.resp.send(Err(e));
        return;
    }
    let backend = route(&job.req, ctx, runtime);
    let label = batch_label(&backend);
    batcher.push(label, Routed { job, backend });
}

/// Pick the backend for a request. PJRT admission wins (it is the numeric
/// hot path); otherwise the plan cache decides which kernel the simulator
/// runs; degenerate inputs — and tensor widths no kernel launch shape
/// covers — go straight to the serial CPU path.
fn route(req: &Request, ctx: &WorkerCtx, runtime: &Option<Runtime>) -> Backend {
    if req.degenerate() {
        return Backend::Cpu;
    }
    match req {
        Request::Spmm { a, n, .. } => {
            if let Some(rt) = runtime {
                if let Some(spec) =
                    rt.registry.route(ArtifactKind::SpmmNnzSr, a.rows, a.cols, a.nnz())
                {
                    if spec.n == *n {
                        return Backend::Pjrt(spec.name.clone());
                    }
                }
            }
            let stats = MatrixStats::of(a);
            let key = ShapeKey::spmm(&stats, *n as u32);
            let (plan, hit) = ctx.plan_cache.get_or_insert_with(key, || match &ctx.model {
                Some(model) => ctx.selector.select_model(model, &stats, *n as u32),
                None => ctx.selector.select(&stats, *n as u32),
            });
            note_cache(ctx, hit);
            if !hit {
                request_tune(ctx, key, || TuneInput::Matrix(a.clone()), *n as u32);
            }
            Backend::Sim(plan, hit)
        }
        Request::Sddmm { a, j_dim, .. } => {
            let stats = MatrixStats::of(a);
            let key = ShapeKey::sddmm(&stats, *j_dim as u32);
            let (plan, hit) = ctx.plan_cache.get_or_insert_with(key, || match &ctx.model {
                Some(model) => ctx.selector.select_sddmm_model(model, &stats, *j_dim as u32),
                None => ctx.selector.select_sddmm(&stats, *j_dim as u32),
            });
            note_cache(ctx, hit);
            if !hit {
                request_tune(ctx, key, || TuneInput::Matrix(a.clone()), *j_dim as u32);
            }
            Backend::Sim(plan, hit)
        }
        Request::Mttkrp { a, j_dim, .. } => {
            let fresh = match &ctx.model {
                Some(model) => ctx.selector.select_mttkrp_model(model, a, *j_dim as u32),
                None => ctx.selector.select_mttkrp(a, *j_dim as u32),
            };
            match fresh {
                Some(fresh) => {
                    let key = ShapeKey::mttkrp(a, *j_dim as u32);
                    let (plan, hit) = ctx.plan_cache.get_or_insert_with(key, || fresh);
                    note_cache(ctx, hit);
                    if !hit {
                        request_tune(ctx, key, || TuneInput::Tensor(a.clone()), *j_dim as u32);
                    }
                    Backend::Sim(plan, hit)
                }
                None => Backend::Cpu,
            }
        }
        Request::Ttm { a, l_dim, .. } => {
            let fresh = match &ctx.model {
                Some(model) => ctx.selector.select_ttm_model(model, a, *l_dim as u32),
                None => ctx.selector.select_ttm(a, *l_dim as u32),
            };
            match fresh {
                Some(fresh) => {
                    let key = ShapeKey::ttm(a, *l_dim as u32);
                    let (plan, hit) = ctx.plan_cache.get_or_insert_with(key, || fresh);
                    note_cache(ctx, hit);
                    if !hit {
                        request_tune(ctx, key, || TuneInput::Tensor(a.clone()), *l_dim as u32);
                    }
                    Backend::Sim(plan, hit)
                }
                None => Backend::Cpu,
            }
        }
    }
}

fn note_cache(ctx: &WorkerCtx, hit: bool) {
    if hit {
        ctx.metrics.on_cache_hit();
    } else {
        ctx.metrics.on_cache_miss();
    }
}

/// Hand a cache miss to the background tuner (best-effort: a full refine
/// queue just means this shape keeps its selector plan a little longer).
/// The operand clone happens lazily, only when a tuner thread exists.
fn request_tune(ctx: &WorkerCtx, key: ShapeKey, input: impl FnOnce() -> TuneInput, width: u32) {
    if let Some(tx) = &ctx.tune_tx {
        match tx.try_send(TuneTask { key, input: input(), width }) {
            Ok(()) | Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {}
        }
    }
}

fn serve_one(label: &str, routed: Routed, runtime: &mut Option<Runtime>, ctx: &WorkerCtx) {
    let Routed { job, backend } = routed;
    let (plan_desc, cache_hit) = match &backend {
        Backend::Sim(plan, hit) => (Some(plan.kind.name()), *hit),
        _ => (None, false),
    };
    // (result, backend label actually used)
    let outcome: (Result<Vec<f32>, String>, String) = match (&backend, &job.req) {
        (Backend::Pjrt(name), Request::Spmm { a, b, n }) => {
            let rt = runtime.as_mut().expect("routed to artifact without runtime");
            match rt.run_spmm_nnz(name, a, b) {
                Ok(c) => (Ok(c), label.to_string()),
                Err(_) => {
                    ctx.metrics.on_fallback();
                    (Ok(spmm_serial(a, b, *n)), "cpu-fallback".to_string())
                }
            }
        }
        (Backend::Sim(plan, _), Request::Spmm { a, b, n }) => match plan.kind {
            // a colliding fingerprint can hand an SpMM job an SDDMM plan;
            // serve it correctly on the CPU rather than guessing a kernel
            Algo::Sddmm(_) => {
                ctx.metrics.on_fallback();
                (Ok(spmm_serial(a, b, *n)), "cpu-fallback".to_string())
            }
            algo => match algo.run(&ctx.machine, a, b, *n as u32) {
                Ok(res) => (Ok(res.run.c), label.to_string()),
                Err(_) => {
                    ctx.metrics.on_fallback();
                    (Ok(spmm_serial(a, b, *n)), "cpu-fallback".to_string())
                }
            },
        },
        (Backend::Sim(plan, _), Request::Sddmm { a, x1, x2, j_dim }) => match plan.kind {
            algo @ Algo::Sddmm(_) => match algo.run_sddmm(&ctx.machine, a, x1, x2) {
                Ok(res) => (Ok(res.run.c), label.to_string()),
                Err(_) => {
                    ctx.metrics.on_fallback();
                    (Ok(sddmm_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
                }
            },
            _ => {
                ctx.metrics.on_fallback();
                (Ok(sddmm_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
            }
        },
        (Backend::Sim(plan, _), Request::Mttkrp { a, x1, x2, j_dim }) => match plan.kind {
            algo @ Algo::Mttkrp(_) => match algo.run_mttkrp(&ctx.machine, a, x1, x2) {
                Ok(res) => (Ok(res.run.c), label.to_string()),
                Err(_) => {
                    ctx.metrics.on_fallback();
                    (Ok(mttkrp_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
                }
            },
            _ => {
                ctx.metrics.on_fallback();
                (Ok(mttkrp_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
            }
        },
        (Backend::Sim(plan, _), Request::Ttm { a, x1, l_dim }) => match plan.kind {
            algo @ Algo::Ttm(_) => match algo.run_ttm(&ctx.machine, a, x1) {
                Ok(res) => (Ok(res.run.c), label.to_string()),
                Err(_) => {
                    ctx.metrics.on_fallback();
                    (Ok(ttm_serial(a, x1, *l_dim)), "cpu-fallback".to_string())
                }
            },
            _ => {
                ctx.metrics.on_fallback();
                (Ok(ttm_serial(a, x1, *l_dim)), "cpu-fallback".to_string())
            }
        },
        (Backend::Cpu, Request::Spmm { a, b, n }) => {
            (Ok(spmm_serial(a, b, *n)), "cpu-serial".to_string())
        }
        (Backend::Cpu, Request::Sddmm { a, x1, x2, j_dim }) => {
            (Ok(sddmm_serial(a, x1, x2, *j_dim)), "cpu-serial".to_string())
        }
        (Backend::Cpu, Request::Mttkrp { a, x1, x2, j_dim }) => {
            (Ok(mttkrp_serial(a, x1, x2, *j_dim)), "cpu-serial".to_string())
        }
        (Backend::Cpu, Request::Ttm { a, x1, l_dim }) => {
            (Ok(ttm_serial(a, x1, *l_dim)), "cpu-serial".to_string())
        }
        // route() never pairs Pjrt with the non-SpMM scenarios
        (Backend::Pjrt(_), Request::Sddmm { a, x1, x2, j_dim }) => {
            (Ok(sddmm_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
        }
        (Backend::Pjrt(_), Request::Mttkrp { a, x1, x2, j_dim }) => {
            (Ok(mttkrp_serial(a, x1, x2, *j_dim)), "cpu-fallback".to_string())
        }
        (Backend::Pjrt(_), Request::Ttm { a, x1, l_dim }) => {
            (Ok(ttm_serial(a, x1, *l_dim)), "cpu-fallback".to_string())
        }
    };
    let latency = job.submitted.elapsed();
    match outcome {
        (Ok(c), served_by) => {
            ctx.metrics.on_complete(&served_by, latency);
            let _ = job.resp.send(Ok(Response {
                c,
                backend: served_by,
                plan: plan_desc,
                cache_hit,
                latency_us: latency.as_micros() as u64,
            }));
        }
        (Err(e), _) => {
            ctx.metrics.on_error();
            let _ = job.resp.send(Err(e));
        }
    }
}

// ---- background tuner ------------------------------------------------------

/// Drain refinement tasks; each winning sweep upgrades the cached plan.
/// Exits when every sender (the workers) is gone.
///
/// Sweeps go through the model-pruned entry points
/// (`tuner::search::tune*_pruned`): the analytic model prices the whole
/// grid in O(stats) and only `top_k` survivors are interpreted warp-by-
/// warp — the dominant cost of this hot path before the model existed.
/// `top_k = 0` is the exhaustive escape hatch. Every sweep records its
/// grid/survivor sizes and whether the model's top-1 pick won
/// ([`Metrics::on_tune`]), so prune accuracy is observable in production.
fn tuner_loop(
    rx: std::sync::mpsc::Receiver<TuneTask>,
    machine: &Machine,
    cache: &PlanCache,
    metrics: &Metrics,
    top_k: usize,
) {
    use super::plan_cache::PlanOrigin;
    while let Ok(task) = rx.recv() {
        // The cache itself is the dedupe state: skip shapes already tuned
        // (duplicate queued tasks land here after the first upgrade) and
        // shapes that were evicted meanwhile (the upgrade would be dropped
        // anyway; a future miss re-enqueues them).
        match cache.get(&task.key) {
            Some(plan) if plan.origin == PlanOrigin::Tuned => continue,
            Some(_) => {}
            None => continue,
        }
        // deterministic dense operands: only the timing matters
        let seed = (task.key.rows as u64) ^ ((task.key.nnz as u64) << 20) ^ task.width as u64;
        let mut rng = SplitMix64::new(seed);
        let pruned = match (task.key.scenario, &task.input) {
            (Scenario::Spmm, TuneInput::Matrix(a)) => {
                let cands = tuner::space::sgap_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let b: Vec<f32> =
                    (0..a.cols * task.width as usize).map(|_| rng.value()).collect();
                tuner::search::tune_pruned(machine, &cands, a, &b, task.width, top_k)
            }
            (Scenario::Sddmm, TuneInput::Matrix(a)) => {
                let j = task.width as usize;
                let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
                let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
                let cands = tuner::space::sddmm_candidates(task.width);
                tuner::search::tune_sddmm_pruned(machine, &cands, a, &x1, &x2, top_k)
            }
            (Scenario::Mttkrp, TuneInput::Tensor(a)) => {
                let cands = tuner::space::mttkrp_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let j = task.width as usize;
                let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
                let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
                tuner::search::tune_mttkrp_pruned(machine, &cands, a, &x1, &x2, top_k)
            }
            (Scenario::Ttm, TuneInput::Tensor(a)) => {
                let cands = tuner::space::ttm_candidates(task.width);
                if cands.is_empty() {
                    continue;
                }
                let l = task.width as usize;
                let x1: Vec<f32> = (0..a.dim2 * l).map(|_| rng.value()).collect();
                tuner::search::tune_ttm_pruned(machine, &cands, a, &x1, top_k)
            }
            // a scenario/operand mismatch cannot be produced by route();
            // drop rather than guess
            _ => continue,
        };
        if let Ok(out) = pruned {
            if let Some((best, _)) = out.best() {
                metrics.on_tune(out.grid, out.survivors, out.model_rank_agree);
                cache.upgrade(task.key, best);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cpu_ref::max_rel_err;
    use crate::coordinator::plan_cache::PlanOrigin;
    use crate::sparse::{erdos_renyi, SplitMix64};

    fn small_cfg() -> CoordinatorConfig {
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() }
    }

    #[test]
    fn serves_spmm_through_plan_cache() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(64, 64, 300, 4).to_csr();
        let mut rng = SplitMix64::new(5);
        let b: Vec<f32> = (0..64 * 4).map(|_| rng.value()).collect();
        let want = spmm_serial(&a, &b, 4);
        let resp = coord.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
        assert!(resp.backend.starts_with("sim:"), "backend {}", resp.backend);
        assert!(!resp.cache_hit, "first sight must be a miss");
        assert!(resp.plan.is_some());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        // repeat: identical shape hits the cache and matches bit-for-bit
        let resp2 = coord.spmm_blocking(a, b, 4).unwrap();
        assert!(resp2.cache_hit);
        assert_eq!(resp2.plan, resp.plan);
        assert_eq!(resp2.c, resp.c, "cached plan must reproduce the result exactly");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        coord.shutdown();
    }

    #[test]
    fn serves_sddmm() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(48, 40, 300, 9).to_csr();
        let mut rng = SplitMix64::new(1);
        let j = 16usize;
        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
        let want = sddmm_serial(&a, &x1, &x2, j);
        let resp = coord.sddmm_blocking(a, x1, x2, j).unwrap();
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        assert!(resp.backend.starts_with("sim:sddmm"), "backend {}", resp.backend);
        coord.shutdown();
    }

    #[test]
    fn serves_mttkrp_and_ttm_through_plan_cache() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = Coo3::random((32, 24, 16), 500, 3);
        let mut rng = SplitMix64::new(8);
        let j = 8usize;
        let x1: Vec<f32> = (0..a.dim1 * j).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..a.dim2 * j).map(|_| rng.value()).collect();
        let want = mttkrp_serial(&a, &x1, &x2, j);
        let resp = coord.mttkrp_blocking(a.clone(), x1.clone(), x2.clone(), j).unwrap();
        assert!(resp.backend.starts_with("sim:mttkrp"), "backend {}", resp.backend);
        assert!(!resp.cache_hit && resp.plan.is_some());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        // repeat: identical tensor hits the cache and reproduces exactly
        let resp2 = coord.mttkrp_blocking(a.clone(), x1, x2, j).unwrap();
        assert!(resp2.cache_hit);
        assert_eq!(resp2.c, resp.c);

        let lx1: Vec<f32> = (0..a.dim2 * 4).map(|_| rng.value()).collect();
        let want = ttm_serial(&a, &lx1, 4);
        let resp = coord.ttm_blocking(a.clone(), lx1.clone(), 4).unwrap();
        assert!(resp.backend.starts_with("sim:ttm"), "backend {}", resp.backend);
        assert!(max_rel_err(&resp.c, &want) < 5e-4);

        // a width no kernel launch shape covers is served on the CPU,
        // correctly, without touching the plan cache
        let jx1: Vec<f32> = (0..a.dim1 * 20).map(|_| rng.value()).collect();
        let jx2: Vec<f32> = (0..a.dim2 * 20).map(|_| rng.value()).collect();
        let want = mttkrp_serial(&a, &jx1, &jx2, 20);
        let resp = coord.mttkrp_blocking(a, jx1, jx2, 20).unwrap();
        assert_eq!(resp.backend, "cpu-serial");
        assert!(resp.plan.is_none());
        assert!(max_rel_err(&resp.c, &want) < 5e-4);
        coord.shutdown();
    }

    #[test]
    fn background_tuner_upgrades_tensor_plans() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            background_tune: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = Coo3::random((24, 16, 12), 300, 5);
        let j = 4usize;
        let x1 = vec![1.0f32; a.dim1 * j];
        let x2 = vec![0.5f32; a.dim2 * j];
        coord.mttkrp_blocking(a.clone(), x1, x2, j).unwrap();
        let key = ShapeKey::mttkrp(&a, j as u32);
        let cache = coord.plan_cache.clone();
        coord.shutdown(); // joins the tuner: the upgrade has landed
        let plan = cache.get(&key).expect("plan still cached");
        assert_eq!(plan.origin, PlanOrigin::Tuned);
        assert!(plan.kind.is_mttkrp(), "tuned plan {} changed scenario", plan.kind.name());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let mut rxs = Vec::new();
        for seed in 0..20u64 {
            let a = erdos_renyi(32, 32, 100, seed).to_csr();
            let mut rng = SplitMix64::new(seed);
            let b: Vec<f32> = (0..32 * 2).map(|_| rng.value()).collect();
            rxs.push((seed, coord.submit(Request::Spmm { a, b, n: 2 })));
        }
        for (seed, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.c.len(), 32 * 2, "seed {seed}");
        }
        assert_eq!(coord.metrics.snapshot().completed, 20);
        coord.shutdown();
    }

    #[test]
    fn invalid_request_is_an_error_not_a_panic() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = erdos_renyi(16, 16, 40, 1).to_csr();
        let err = coord.spmm_blocking(a.clone(), vec![0.0; 3], 2).unwrap_err();
        assert!(err.to_string().contains("spmm"), "{err}");
        let err = coord.sddmm_blocking(a, vec![], vec![], 0).unwrap_err();
        assert!(err.to_string().contains("j_dim"), "{err}");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.errors, 2);
        coord.shutdown();
    }

    #[test]
    fn empty_matrix_served_on_cpu() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        let a = crate::sparse::Coo::new(8, 8, vec![]).to_csr();
        let resp = coord.spmm_blocking(a, vec![1.0; 8 * 2], 2).unwrap();
        assert_eq!(resp.backend, "cpu-serial");
        assert!(resp.plan.is_none());
        assert!(resp.c.iter().all(|&v| v == 0.0));
        coord.shutdown();
    }

    #[test]
    fn background_tuner_upgrades_plan() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            background_tune: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let a = erdos_renyi(48, 48, 250, 7).to_csr();
        let b = vec![1.0f32; 48 * 4];
        coord.spmm_blocking(a.clone(), b.clone(), 4).unwrap();
        let key = ShapeKey::spmm(&MatrixStats::of(&a), 4);
        let cache = coord.plan_cache.clone();
        let metrics = coord.metrics.clone();
        coord.shutdown(); // joins the tuner: the upgrade has landed
        let plan = cache.get(&key).expect("plan still cached");
        assert_eq!(plan.origin, PlanOrigin::Tuned);
        assert!(cache.stats().upgrades >= 1);
        // the sweep went through the model-pruned path and was recorded
        let s = metrics.snapshot();
        assert!(s.tunes >= 1, "no tune recorded");
        assert!(s.tune_survivors <= s.tune_grid);
        assert!(
            s.tune_survivors <= s.tunes * crate::tuner::DEFAULT_TOP_K as u64,
            "pruning did not bound the simulated candidates: {} sweeps, {} survivors",
            s.tunes,
            s.tune_survivors
        );
    }

    #[test]
    fn shutdown_is_clean() {
        let coord = Coordinator::start(small_cfg()).unwrap();
        coord.shutdown(); // no panic, workers joined
    }
}
