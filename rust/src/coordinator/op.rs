//! The typed serving vocabulary: operand **handles** and the generic
//! [`Op`] descriptor — one value for every §2.1 algebra.
//!
//! The previous API took a `Request` variant per algebra, each owning its
//! sparse and dense operands by value: serving the same matrix twice —
//! the exact case the plan cache exists for — re-cloned the whole operand
//! set into the job queue, and every new algebra needed its own variant,
//! validator, submit pair, batching key, and routing arm. This module
//! replaces that with three ideas (Senanayake et al.'s argument at the
//! compiler level, applied to the serving level — one generic vocabulary
//! over algebras beats N parallel special cases):
//!
//! * [`SparseHandle`] / [`DenseHandle`] — `Arc`-backed operand handles.
//!   Registering an operand runs the [`MatrixStats`]/[`SegStats`]
//!   fingerprint pass **once** per operand and caches it inside the
//!   handle, so repeat submits are zero-copy (an `Arc` bump) and skip
//!   re-fingerprinting entirely.
//! * [`Op`] — `{ kind, sparse operand, dense operands, width }`.
//!   Validation (with `checked_mul` on every extent × width product),
//!   degeneracy checks, [`ShapeKey`] derivation, selector dispatch, and
//!   the serial oracle are all generic over [`OpKind`]: algebra #5 is a
//!   new `OpKind` row in each small `match` below, not a parallel
//!   plumbing stack.
//! * [`Request`] — the legacy per-algebra enum, kept as a deprecated shim
//!   that converts into an [`Op`] (moving its operands into fresh
//!   handles, never cloning them).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::algos::catalog::Algo;
use crate::algos::cpu_ref::spmm_serial;
use crate::algos::fused::fused_serial;
use crate::algos::mttkrp::{mttkrp_serial, ttm_serial};
use crate::algos::sddmm::sddmm_serial;
use crate::runtime::pool::{fnv_mix, PoolKey};
use crate::sparse::coo3::Coo3;
use crate::sparse::{Csr, MatrixStats, SegStats};
use crate::tuner::{CostModel, Selector};

use super::plan_cache::ShapeKey;

/// The served algebra of an [`Op`] — one tag per §2.1 quartet member.
///
/// This is also the plan cache's scenario tag
/// ([`Scenario`](super::Scenario) is an alias), so ops, cache keys, and
/// the background tuner all speak the same vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// `C = A · B` (CSR × row-major dense `[cols × n]`).
    Spmm,
    /// `Y(pos) = A_vals(pos) · dot(X1[i,:], X2[:,k])`.
    Sddmm,
    /// `Y(i,j) = Σ A(i,k,l)·X1(k,j)·X2(l,j)` over an order-3 COO tensor.
    Mttkrp,
    /// `Y(i,j,l) = Σ A(i,j,k)·X1(k,l)` over an order-3 COO tensor.
    Ttm,
    /// Fused SDDMM→SpMM: `C = (A ⊙ X1·X2) · B` as one kernel, no
    /// materialized intermediate. Two widths ride in one packed
    /// `width = (j_dim << 16) | n` (see [`Op::fused`]).
    FusedSddmmSpmm,
}

impl OpKind {
    /// Every algebra the serving layer knows: the §2.1 quartet plus the
    /// fused SDDMM→SpMM chain.
    pub const ALL: [OpKind; 5] =
        [OpKind::Spmm, OpKind::Sddmm, OpKind::Mttkrp, OpKind::Ttm, OpKind::FusedSddmmSpmm];

    /// Stable lowercase label (log/error prefix).
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Spmm => "spmm",
            OpKind::Sddmm => "sddmm",
            OpKind::Mttkrp => "mttkrp",
            OpKind::Ttm => "ttm",
            OpKind::FusedSddmmSpmm => "fused",
        }
    }

    /// Inverse of [`OpKind::label`] — the plan-catalog load path, where
    /// persisted scenario tags must round-trip exactly.
    pub fn from_label(label: &str) -> Option<OpKind> {
        OpKind::ALL.into_iter().find(|k| k.label() == label)
    }

    /// The name of the dense-width dimension in this algebra's signature.
    pub fn width_name(self) -> &'static str {
        match self {
            OpKind::Spmm => "n",
            OpKind::Sddmm | OpKind::Mttkrp => "j_dim",
            OpKind::Ttm => "l_dim",
            OpKind::FusedSddmmSpmm => "j_dim/n",
        }
    }

    /// How many dense operands the algebra takes.
    pub fn dense_arity(self) -> usize {
        match self {
            OpKind::Spmm | OpKind::Ttm => 1,
            OpKind::Sddmm | OpKind::Mttkrp => 2,
            OpKind::FusedSddmmSpmm => 3,
        }
    }

    /// Whether the sparse operand is an order-3 tensor (vs a CSR matrix).
    pub fn wants_tensor(self) -> bool {
        matches!(self, OpKind::Mttkrp | OpKind::Ttm)
    }

    /// Whether `plan` is a kernel of this algebra. Guards fingerprint
    /// collisions: an incompatible cached plan is served on the CPU
    /// fallback rather than guessing a kernel.
    pub fn compatible(self, plan: &Algo) -> bool {
        match self {
            OpKind::Spmm => {
                !(plan.is_sddmm() || plan.is_mttkrp() || plan.is_ttm() || plan.is_fused())
            }
            OpKind::Sddmm => plan.is_sddmm(),
            OpKind::Mttkrp => plan.is_mttkrp(),
            OpKind::Ttm => plan.is_ttm(),
            OpKind::FusedSddmmSpmm => plan.is_fused(),
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// The sparse payload behind a [`SparseHandle`].
#[derive(Debug, Clone)]
pub enum SparseData {
    Matrix(Csr),
    Tensor(Coo3),
}

impl SparseData {
    /// Lowercase tag for error messages.
    pub fn label(&self) -> &'static str {
        match self {
            SparseData::Matrix(_) => "matrix",
            SparseData::Tensor(_) => "tensor",
        }
    }
}

/// Registration uids for the device pool's [`PoolKey`]s. Monotonic and
/// never reused — unlike `Arc` addresses, which the allocator recycles
/// (a recycled address could alias a dead handle's staged device image).
static NEXT_OPERAND_UID: AtomicU64 = AtomicU64::new(1);

fn next_operand_uid() -> u64 {
    NEXT_OPERAND_UID.fetch_add(1, Ordering::Relaxed)
}

/// Sampled FNV-1a content fingerprint: dimensions and nnz always mix in;
/// values/indices are strided down to ≤ 64 probes so registration stays
/// O(1)-ish on huge operands. The pool pairs this with the uid, so it
/// only has to catch *mutation behind a uid*, not global uniqueness.
fn sampled_fp(dims: &[u64], ints: &[u32], floats: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &d in dims {
        h = fnv_mix(h, d);
    }
    let stride = |len: usize| (len / 64).max(1);
    let s = stride(ints.len());
    for &v in ints.iter().step_by(s) {
        h = fnv_mix(h, v as u64);
    }
    let s = stride(floats.len());
    for &v in floats.iter().step_by(s) {
        h = fnv_mix(h, v.to_bits() as u64);
    }
    h
}

#[derive(Debug)]
struct SparseInner {
    data: SparseData,
    /// Pool identity: never-reused registration uid + sampled content
    /// fingerprint (see [`SparseHandle::pool_key`]).
    uid: u64,
    pool_fp: u64,
    /// Matrix fingerprint — computed on first use (primed eagerly by
    /// `Session::register_matrix`), then cached for the handle's life.
    stats: OnceLock<MatrixStats>,
    /// Tensor segment fingerprints, one per segmentation (row segments
    /// for MTTKRP, leading `(i,j)` fibers for TTM) — computed on first
    /// use, then cached for the handle's lifetime.
    seg_mttkrp: OnceLock<SegStats>,
    seg_ttm: OnceLock<SegStats>,
}

/// A registered sparse operand: a cheap, clonable `Arc`-backed handle.
///
/// The fingerprint pass ([`MatrixStats`] for matrices, [`SegStats`] for
/// tensors) runs once per handle and is cached, so every [`Op`] built
/// from the handle derives its plan-cache [`ShapeKey`] in O(1) and every
/// submit moves only the `Arc` — never the operand data.
#[derive(Debug, Clone)]
pub struct SparseHandle {
    inner: Arc<SparseInner>,
}

impl SparseHandle {
    /// Wrap a CSR matrix in a handle. The [`MatrixStats`] fingerprint
    /// pass runs lazily on first use and is then cached — so the legacy
    /// `Request` shim pays it only on the paths that actually consult the
    /// plan cache (exactly like the pre-handle API), while
    /// [`Session::register_matrix`](super::Session::register_matrix)
    /// primes it eagerly at registration time.
    pub fn matrix(a: Csr) -> SparseHandle {
        let fp = sampled_fp(&[a.rows as u64, a.cols as u64, a.nnz() as u64], &a.indices, &a.data);
        SparseHandle {
            inner: Arc::new(SparseInner {
                data: SparseData::Matrix(a),
                uid: next_operand_uid(),
                pool_fp: fp,
                stats: OnceLock::new(),
                seg_mttkrp: OnceLock::new(),
                seg_ttm: OnceLock::new(),
            }),
        }
    }

    /// Register an order-3 COO tensor. The per-scenario [`SegStats`]
    /// passes run lazily, on the first MTTKRP/TTM op using the handle.
    pub fn tensor(a: Coo3) -> SparseHandle {
        let dims = [a.dim0 as u64, a.dim1 as u64, a.dim2 as u64, a.nnz() as u64];
        let fp = sampled_fp(&dims, &a.idx0, &a.vals);
        SparseHandle {
            inner: Arc::new(SparseInner {
                data: SparseData::Tensor(a),
                uid: next_operand_uid(),
                pool_fp: fp,
                stats: OnceLock::new(),
                seg_mttkrp: OnceLock::new(),
                seg_ttm: OnceLock::new(),
            }),
        }
    }

    pub fn data(&self) -> &SparseData {
        &self.inner.data
    }

    pub fn as_matrix(&self) -> Option<&Csr> {
        match &self.inner.data {
            SparseData::Matrix(m) => Some(m),
            SparseData::Tensor(_) => None,
        }
    }

    pub fn as_tensor(&self) -> Option<&Coo3> {
        match &self.inner.data {
            SparseData::Matrix(_) => None,
            SparseData::Tensor(t) => Some(t),
        }
    }

    /// Cached matrix fingerprint (`None` when the handle holds a tensor).
    pub fn matrix_stats(&self) -> Option<&MatrixStats> {
        match &self.inner.data {
            SparseData::Matrix(m) => Some(self.inner.stats.get_or_init(|| MatrixStats::of(m))),
            SparseData::Tensor(_) => None,
        }
    }

    /// Cached segment fingerprint for a tensor algebra (`None` when the
    /// handle holds a matrix or `kind` is a matrix algebra).
    pub fn seg_stats(&self, kind: OpKind) -> Option<&SegStats> {
        let t = self.as_tensor()?;
        match kind {
            OpKind::Mttkrp => Some(self.inner.seg_mttkrp.get_or_init(|| SegStats::mttkrp(t))),
            OpKind::Ttm => Some(self.inner.seg_ttm.get_or_init(|| SegStats::ttm(t))),
            OpKind::Spmm | OpKind::Sddmm | OpKind::FusedSddmmSpmm => None,
        }
    }

    pub fn nnz(&self) -> usize {
        match &self.inner.data {
            SparseData::Matrix(m) => m.nnz(),
            SparseData::Tensor(t) => t.nnz(),
        }
    }

    /// Whether two handles share the same registration (pointer identity,
    /// not structural equality).
    pub fn ptr_eq(&self, other: &SparseHandle) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Live references to this registration — observability for the
    /// zero-copy contract (each in-flight op holds exactly one).
    pub fn strong_count(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Registration uid — monotonic, never reused, shared by clones of
    /// this handle. The address for
    /// [`DevicePool::invalidate`](crate::runtime::pool::DevicePool::invalidate).
    pub fn uid(&self) -> u64 {
        self.inner.uid
    }

    /// The handle's device-pool identity: uid + sampled content
    /// fingerprint. Every clone stages (and hits) the same pool page.
    pub fn pool_key(&self) -> PoolKey {
        PoolKey { uid: self.inner.uid, fp: self.inner.pool_fp }
    }
}

impl From<Csr> for SparseHandle {
    fn from(a: Csr) -> SparseHandle {
        SparseHandle::matrix(a)
    }
}

impl From<Coo3> for SparseHandle {
    fn from(a: Coo3) -> SparseHandle {
        SparseHandle::tensor(a)
    }
}

/// A registered dense operand: a cheap, clonable `Arc<[f32]>`-style
/// handle (derefs to the slice).
#[derive(Debug, Clone)]
pub struct DenseHandle {
    data: Arc<Vec<f32>>,
    /// Pool identity (see [`SparseHandle::pool_key`]); clones share it.
    uid: u64,
    pool_fp: u64,
}

impl DenseHandle {
    pub fn new(v: Vec<f32>) -> DenseHandle {
        let fp = sampled_fp(&[v.len() as u64], &[], &v);
        DenseHandle { data: Arc::new(v), uid: next_operand_uid(), pool_fp: fp }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// See [`SparseHandle::ptr_eq`].
    pub fn ptr_eq(&self, other: &DenseHandle) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// See [`SparseHandle::strong_count`].
    pub fn strong_count(&self) -> usize {
        Arc::strong_count(&self.data)
    }

    /// See [`SparseHandle::uid`].
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// See [`SparseHandle::pool_key`].
    pub fn pool_key(&self) -> PoolKey {
        PoolKey { uid: self.uid, fp: self.pool_fp }
    }
}

impl std::ops::Deref for DenseHandle {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl From<Vec<f32>> for DenseHandle {
    fn from(v: Vec<f32>) -> DenseHandle {
        DenseHandle::new(v)
    }
}

/// Typed validation error of an [`Op`] — what the serving layer reports
/// (as its `Display` string) instead of executing a malformed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpError {
    /// The dense width (`n`/`j_dim`/`l_dim`) is zero.
    ZeroWidth { kind: OpKind },
    /// The sparse handle holds the wrong operand class for the algebra
    /// (e.g. a tensor handed to SpMM).
    OperandKind { kind: OpKind, got: &'static str },
    /// Wrong number of dense operands.
    DenseArity { kind: OpKind, want: usize, got: usize },
    /// A dense operand's length disagrees with `extent × width`.
    DenseShape { kind: OpKind, operand: &'static str, got: usize, extent: usize, width: usize },
    /// `extent × width` overflows `usize` — absurd dims are rejected here
    /// instead of overflowing (and panicking) in debug builds.
    DimOverflow { kind: OpKind, operand: &'static str, extent: usize, width: usize },
    /// Admission control refused the op: the job queue already holds
    /// `depth` of its `cap` jobs. The op never entered the queue — retry
    /// with backoff, shed load, or use the blocking submit path.
    Overloaded { depth: usize, cap: usize },
}

impl fmt::Display for OpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpError::ZeroWidth { kind } => {
                write!(f, "{kind}: {} must be >= 1", kind.width_name())
            }
            OpError::OperandKind { kind, got } => {
                let want = if kind.wants_tensor() { "tensor" } else { "matrix" };
                write!(f, "{kind}: expects a {want} operand, the handle holds a {got}")
            }
            OpError::DenseArity { kind, want, got } => {
                write!(f, "{kind}: takes {want} dense operand(s), got {got}")
            }
            OpError::DenseShape { kind, operand, got, extent, width } => {
                write!(
                    f,
                    "{kind}: {operand} has {got} elements, want extent x {} = {extent} x {width}",
                    kind.width_name()
                )
            }
            OpError::DimOverflow { kind, operand, extent, width } => {
                write!(
                    f,
                    "{kind}: {operand} extent {extent} x {} {width} overflows usize",
                    kind.width_name(),
                )
            }
            OpError::Overloaded { depth, cap } => {
                write!(f, "overloaded: job queue at {depth}/{cap}, submission rejected")
            }
        }
    }
}

impl std::error::Error for OpError {}

/// A serving job: one generic descriptor for every algebra of the §2.1
/// quartet. Built from registered handles, so constructing and
/// submitting an `Op` never copies operand data.
#[derive(Debug, Clone)]
pub struct Op {
    pub kind: OpKind,
    /// The sparse operand (matrix for SpMM/SDDMM, tensor for MTTKRP/TTM).
    pub a: SparseHandle,
    /// Dense operands in kernel order (`B`; `X1, X2`; `X1, X2`; `X1`).
    pub dense: Vec<DenseHandle>,
    /// Dense width: `n` (SpMM), `j_dim` (SDDMM/MTTKRP), `l_dim` (TTM).
    pub width: usize,
}

impl Op {
    /// `C = A · B` with `b` row-major `[a.cols × n]`.
    pub fn spmm(a: &SparseHandle, b: &DenseHandle, n: usize) -> Op {
        Op { kind: OpKind::Spmm, a: a.clone(), dense: vec![b.clone()], width: n }
    }

    /// `Y(pos) = A_vals(pos) · dot(X1[i,:], X2[:,k])` with `x1` row-major
    /// `[a.rows × j_dim]` and `x2` row-major `[j_dim × a.cols]`.
    pub fn sddmm(a: &SparseHandle, x1: &DenseHandle, x2: &DenseHandle, j_dim: usize) -> Op {
        Op { kind: OpKind::Sddmm, a: a.clone(), dense: vec![x1.clone(), x2.clone()], width: j_dim }
    }

    /// `Y(i,j) = Σ A(i,k,l)·X1(k,j)·X2(l,j)` with `x1` row-major
    /// `[a.dim1 × j_dim]` and `x2` row-major `[a.dim2 × j_dim]`.
    pub fn mttkrp(a: &SparseHandle, x1: &DenseHandle, x2: &DenseHandle, j_dim: usize) -> Op {
        Op { kind: OpKind::Mttkrp, a: a.clone(), dense: vec![x1.clone(), x2.clone()], width: j_dim }
    }

    /// `Y(i,j,l) = Σ A(i,j,k)·X1(k,l)` with `x1` row-major
    /// `[a.dim2 × l_dim]`.
    pub fn ttm(a: &SparseHandle, x1: &DenseHandle, l_dim: usize) -> Op {
        Op { kind: OpKind::Ttm, a: a.clone(), dense: vec![x1.clone()], width: l_dim }
    }

    /// Fused SDDMM→SpMM `C = (A ⊙ X1·X2) · B` with `x1` row-major
    /// `[a.rows × j_dim]`, `x2` row-major `[j_dim × a.cols]`, and `b`
    /// row-major `[a.cols × n]`. The chain has *two* dense widths, so both
    /// ride in the one generic width field packed as
    /// `(j_dim << 16) | n` — the plan cache, batching keys, and tuner
    /// requests stay single-field, and [`Op::fused_widths`] unpacks.
    ///
    /// # Panics
    /// When either width does not fit in 16 bits.
    pub fn fused(
        a: &SparseHandle,
        x1: &DenseHandle,
        x2: &DenseHandle,
        b: &DenseHandle,
        j_dim: usize,
        n: usize,
    ) -> Op {
        assert!(j_dim < (1 << 16) && n < (1 << 16), "fused widths must fit in 16 bits");
        Op {
            kind: OpKind::FusedSddmmSpmm,
            a: a.clone(),
            dense: vec![x1.clone(), x2.clone(), b.clone()],
            width: (j_dim << 16) | n,
        }
    }

    /// The fused op's `(j_dim, n)` pair, unpacked from the packed width.
    /// Meaningful only when `kind` is [`OpKind::FusedSddmmSpmm`].
    pub fn fused_widths(&self) -> (usize, usize) {
        (self.width >> 16, self.width & 0xFFFF)
    }

    /// Expected dense operands: `(name, extent, width)` triples — operand
    /// `i` must hold `extent_i × width_i` elements. Every algebra uses the
    /// op's single width except the fused chain, whose operands split
    /// across its two packed widths. Errs when the handle's operand class
    /// doesn't match the algebra.
    fn dense_specs(&self) -> Result<Vec<(&'static str, usize, usize)>, OpError> {
        let w = self.width;
        match (self.kind, self.a.data()) {
            (OpKind::Spmm, SparseData::Matrix(a)) => Ok(vec![("B", a.cols, w)]),
            (OpKind::Sddmm, SparseData::Matrix(a)) => {
                Ok(vec![("X1", a.rows, w), ("X2", a.cols, w)])
            }
            (OpKind::FusedSddmmSpmm, SparseData::Matrix(a)) => {
                let (j, n) = self.fused_widths();
                Ok(vec![("X1", a.rows, j), ("X2", j, a.cols), ("B", a.cols, n)])
            }
            (OpKind::Mttkrp, SparseData::Tensor(a)) => {
                Ok(vec![("X1", a.dim1, w), ("X2", a.dim2, w)])
            }
            (OpKind::Ttm, SparseData::Tensor(a)) => Ok(vec![("X1", a.dim2, w)]),
            (kind, data) => Err(OpError::OperandKind { kind, got: data.label() }),
        }
    }

    /// The single generic validator: width, operand class, dense arity,
    /// and every dense length against `extent × width` (with
    /// `checked_mul`, so absurd dims are a typed error, not a debug-build
    /// overflow panic). The fused chain checks *both* packed widths for
    /// zero.
    pub fn validate(&self) -> Result<(), OpError> {
        let kind = self.kind;
        let zero_width = match kind {
            OpKind::FusedSddmmSpmm => {
                let (j, n) = self.fused_widths();
                j == 0 || n == 0
            }
            _ => self.width == 0,
        };
        if zero_width {
            return Err(OpError::ZeroWidth { kind });
        }
        let specs = self.dense_specs()?;
        if self.dense.len() != specs.len() {
            return Err(OpError::DenseArity { kind, want: specs.len(), got: self.dense.len() });
        }
        for (&(operand, extent, width), d) in specs.iter().zip(&self.dense) {
            let want = extent.checked_mul(width).ok_or(OpError::DimOverflow {
                kind,
                operand,
                extent,
                width,
            })?;
            if d.len() != want {
                return Err(OpError::DenseShape { kind, operand, got: d.len(), extent, width });
            }
        }
        Ok(())
    }

    /// Inputs the kernels do not cover (served straight on the CPU path).
    pub fn degenerate(&self) -> bool {
        match self.a.data() {
            SparseData::Matrix(a) => a.nnz() == 0 || a.rows == 0,
            SparseData::Tensor(a) => a.nnz() == 0 || a.dim0 == 0,
        }
    }

    /// Output element count (`None` on an operand-class mismatch or
    /// overflow — [`Op::validate`] reports those as typed errors).
    pub fn output_len(&self) -> Option<usize> {
        match (self.kind, self.a.data()) {
            (OpKind::Spmm, SparseData::Matrix(a)) => a.rows.checked_mul(self.width),
            (OpKind::Sddmm, SparseData::Matrix(a)) => Some(a.nnz()),
            (OpKind::FusedSddmmSpmm, SparseData::Matrix(a)) => {
                a.rows.checked_mul(self.fused_widths().1)
            }
            (OpKind::Mttkrp, SparseData::Tensor(a)) => a.dim0.checked_mul(self.width),
            (OpKind::Ttm, SparseData::Tensor(a)) => {
                a.dim0.checked_mul(a.dim1)?.checked_mul(self.width)
            }
            _ => None,
        }
    }

    /// Plan-cache fingerprint, derived from the handle's **cached** stats
    /// — repeat submits of a registered operand never re-run the
    /// fingerprint pass. `None` on an operand-class mismatch.
    pub fn shape_key(&self) -> Option<ShapeKey> {
        let w = self.width as u32;
        match self.kind {
            OpKind::Spmm => Some(ShapeKey::spmm(self.a.matrix_stats()?, w)),
            OpKind::Sddmm => Some(ShapeKey::sddmm(self.a.matrix_stats()?, w)),
            OpKind::FusedSddmmSpmm => Some(ShapeKey::fused(self.a.matrix_stats()?, w)),
            OpKind::Mttkrp => {
                let t = self.a.as_tensor()?;
                let seg = self.a.seg_stats(OpKind::Mttkrp)?;
                Some(ShapeKey::mttkrp_stats(seg, t.dim1.saturating_mul(t.dim2), w))
            }
            OpKind::Ttm => {
                let t = self.a.as_tensor()?;
                Some(ShapeKey::ttm_stats(self.a.seg_stats(OpKind::Ttm)?, t.dim2, w))
            }
        }
    }

    /// The selector's fast-path plan for this op — through the analytic
    /// model's argmin when `model` is given, the decision tree otherwise.
    /// `None` when no legal launch shape covers the width (the serving
    /// layer routes such ops to the CPU) or on an operand-class mismatch.
    pub fn select(&self, selector: &Selector, model: Option<&CostModel>) -> Option<Algo> {
        let w = self.width as u32;
        match self.kind {
            OpKind::Spmm => {
                let stats = self.a.matrix_stats()?;
                Some(match model {
                    // skewed inputs may warrant a per-band composite; the
                    // selector returns None (fall through to the single
                    // plan) when the CV gate or the pricing says banding
                    // doesn't pay
                    Some(m) => selector
                        .select_banded(m, stats, w)
                        .unwrap_or_else(|| selector.select_model(m, stats, w)),
                    None => selector.select(stats, w),
                })
            }
            OpKind::Sddmm => {
                let stats = self.a.matrix_stats()?;
                Some(match model {
                    Some(m) => selector.select_sddmm_model(m, stats, w),
                    None => selector.select_sddmm(stats, w),
                })
            }
            OpKind::FusedSddmmSpmm => {
                let stats = self.a.matrix_stats()?;
                let (j, n) = self.fused_widths();
                match model {
                    Some(m) => selector.select_fused_model(m, stats, j as u32, n as u32),
                    None => selector.select_fused(stats, j as u32, n as u32),
                }
            }
            OpKind::Mttkrp => {
                let seg = self.a.seg_stats(OpKind::Mttkrp)?;
                match model {
                    Some(m) => selector.select_mttkrp_model_stats(m, seg, w),
                    None => selector.select_mttkrp_stats(seg, w),
                }
            }
            OpKind::Ttm => {
                let seg = self.a.seg_stats(OpKind::Ttm)?;
                match model {
                    Some(m) => selector.select_ttm_model_stats(m, seg, w),
                    None => selector.select_ttm_stats(seg, w),
                }
            }
        }
    }

    /// Serve the op on the serial CPU oracle — the reference the
    /// differential tests compare against, and every backend's fallback.
    ///
    /// # Panics
    /// On an operand-class mismatch; the serving path runs
    /// [`Op::validate`] first.
    pub fn run_serial(&self) -> Vec<f32> {
        match (self.kind, self.a.data()) {
            (OpKind::Spmm, SparseData::Matrix(a)) => spmm_serial(a, &self.dense[0], self.width),
            (OpKind::Sddmm, SparseData::Matrix(a)) => {
                sddmm_serial(a, &self.dense[0], &self.dense[1], self.width)
            }
            (OpKind::FusedSddmmSpmm, SparseData::Matrix(a)) => {
                let (j, n) = self.fused_widths();
                fused_serial(a, &self.dense[0], &self.dense[1], &self.dense[2], j, n)
            }
            (OpKind::Mttkrp, SparseData::Tensor(a)) => {
                mttkrp_serial(a, &self.dense[0], &self.dense[1], self.width)
            }
            (OpKind::Ttm, SparseData::Tensor(a)) => ttm_serial(a, &self.dense[0], self.width),
            (kind, data) => panic!("{kind} op holds a {} operand: validate() first", data.label()),
        }
    }
}

/// The legacy per-algebra request enum — a deprecated shim kept so
/// existing callers compile: it converts into the generic [`Op`]
/// (operands are *moved* into fresh handles, never cloned). New code
/// should register operands once ([`Session`](super::Session)) and build
/// [`Op`]s, which makes repeat submits zero-copy.
#[derive(Debug, Clone)]
pub enum Request {
    /// `C = A · B` with `B` row-major `[a.cols × n]`.
    Spmm { a: Csr, b: Vec<f32>, n: usize },
    /// `Y(pos) = A_vals(pos) · dot(X1[i,:], X2[:,k])` with `x1` row-major
    /// `[a.rows × j_dim]` and `x2` row-major `[j_dim × a.cols]`.
    Sddmm { a: Csr, x1: Vec<f32>, x2: Vec<f32>, j_dim: usize },
    /// `Y(i,j) = Σ A(i,k,l)·X1(k,j)·X2(l,j)` with `x1` row-major
    /// `[a.dim1 × j_dim]`, `x2` row-major `[a.dim2 × j_dim]`.
    Mttkrp { a: Coo3, x1: Vec<f32>, x2: Vec<f32>, j_dim: usize },
    /// `Y(i,j,l) = Σ A(i,j,k)·X1(k,l)` with `x1` row-major
    /// `[a.dim2 × l_dim]`.
    Ttm { a: Coo3, x1: Vec<f32>, l_dim: usize },
}

impl From<Request> for Op {
    fn from(req: Request) -> Op {
        match req {
            Request::Spmm { a, b, n } => {
                Op::spmm(&SparseHandle::matrix(a), &DenseHandle::new(b), n)
            }
            Request::Sddmm { a, x1, x2, j_dim } => Op::sddmm(
                &SparseHandle::matrix(a),
                &DenseHandle::new(x1),
                &DenseHandle::new(x2),
                j_dim,
            ),
            Request::Mttkrp { a, x1, x2, j_dim } => Op::mttkrp(
                &SparseHandle::tensor(a),
                &DenseHandle::new(x1),
                &DenseHandle::new(x2),
                j_dim,
            ),
            Request::Ttm { a, x1, l_dim } => {
                Op::ttm(&SparseHandle::tensor(a), &DenseHandle::new(x1), l_dim)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::erdos_renyi;

    fn mat_handle() -> SparseHandle {
        SparseHandle::matrix(erdos_renyi(16, 12, 40, 1).to_csr())
    }

    #[test]
    fn handles_are_zero_copy_and_fingerprint_once() {
        let h = mat_handle();
        assert_eq!(h.strong_count(), 1);
        let stats = h.matrix_stats().expect("matrix handle has stats").clone();
        let b = DenseHandle::new(vec![0.0; 12 * 4]);
        let op = Op::spmm(&h, &b, 4);
        // the op shares the registration: pointer-identical, no copy
        assert!(op.a.ptr_eq(&h));
        assert!(op.dense[0].ptr_eq(&b));
        assert_eq!(h.strong_count(), 2);
        assert_eq!(b.strong_count(), 2);
        // fingerprints are cached: the same &MatrixStats is handed back
        assert_eq!(*op.a.matrix_stats().unwrap(), stats);
        drop(op);
        assert_eq!(h.strong_count(), 1);
    }

    #[test]
    fn tensor_handles_cache_both_segmentations() {
        let t = SparseHandle::tensor(Coo3::random((8, 6, 5), 40, 3));
        let m1 = t.seg_stats(OpKind::Mttkrp).unwrap() as *const SegStats;
        let m2 = t.seg_stats(OpKind::Mttkrp).unwrap() as *const SegStats;
        assert_eq!(m1, m2, "segment stats computed once per handle");
        assert!(t.seg_stats(OpKind::Ttm).is_some());
        assert!(t.seg_stats(OpKind::Spmm).is_none());
        assert!(t.matrix_stats().is_none());
    }

    #[test]
    fn validation_is_generic_and_typed() {
        let h = mat_handle();
        let good = Op::spmm(&h, &DenseHandle::new(vec![0.0; 12 * 4]), 4);
        good.validate().unwrap();
        assert_eq!(good.output_len(), Some(16 * 4));

        let zero = Op::spmm(&h, &DenseHandle::new(vec![]), 0);
        assert_eq!(zero.validate(), Err(OpError::ZeroWidth { kind: OpKind::Spmm }));
        assert!(zero.validate().unwrap_err().to_string().contains("n must be >= 1"));

        let short = Op::spmm(&h, &DenseHandle::new(vec![0.0; 3]), 4);
        let err = short.validate().unwrap_err();
        assert!(matches!(err, OpError::DenseShape { operand: "B", got: 3, .. }), "{err}");
        assert!(err.to_string().starts_with("spmm:"), "{err}");

        // absurd dims: typed overflow error, not a debug-build panic
        let huge = Op::spmm(&h, &DenseHandle::new(vec![0.0; 8]), usize::MAX / 2);
        assert!(matches!(huge.validate(), Err(OpError::DimOverflow { operand: "B", .. })));
        assert!(huge.validate().unwrap_err().to_string().contains("overflows"));

        // admission-control rejection renders its depth/cap pair
        let over = OpError::Overloaded { depth: 256, cap: 256 };
        assert_eq!(over.to_string(), "overloaded: job queue at 256/256, submission rejected");

        // operand-class mismatch is typed too
        let t = SparseHandle::tensor(Coo3::random((8, 6, 5), 30, 2));
        let cross = Op { kind: OpKind::Spmm, a: t, dense: vec![], width: 4 };
        assert!(matches!(cross.validate(), Err(OpError::OperandKind { got: "tensor", .. })));
        assert!(cross.shape_key().is_none());
    }

    #[test]
    fn quartet_arity_and_width_names() {
        for kind in OpKind::ALL {
            assert!(!kind.label().is_empty());
            assert!(kind.dense_arity() >= 1 && kind.dense_arity() <= 3);
            assert_eq!(OpKind::from_label(kind.label()), Some(kind), "labels round-trip");
        }
        assert_eq!(OpKind::from_label("spmm2"), None);
        assert_eq!(OpKind::from_label(""), None);
        assert_eq!(OpKind::Sddmm.width_name(), "j_dim");
        assert_eq!(OpKind::Ttm.to_string(), "ttm");
        assert!(OpKind::Mttkrp.wants_tensor() && !OpKind::Spmm.wants_tensor());
        assert!(!OpKind::FusedSddmmSpmm.wants_tensor());
        assert_eq!(OpKind::FusedSddmmSpmm.dense_arity(), 3);
    }

    #[test]
    fn fused_ops_pack_two_widths_and_validate_each_operand() {
        let h = mat_handle(); // 16 x 12
        let x1 = DenseHandle::new(vec![0.0; 16 * 8]);
        let x2 = DenseHandle::new(vec![0.0; 8 * 12]);
        let b = DenseHandle::new(vec![0.0; 12 * 4]);
        let op = Op::fused(&h, &x1, &x2, &b, 8, 4);
        op.validate().unwrap();
        assert_eq!(op.fused_widths(), (8, 4));
        assert_eq!(op.width, (8 << 16) | 4);
        assert_eq!(op.output_len(), Some(16 * 4), "output is rows x n, not rows x j");
        assert_eq!(
            op.shape_key(),
            Some(ShapeKey::fused(op.a.matrix_stats().unwrap(), op.width as u32))
        );
        // the oracle is the two-stage chain
        let a = op.a.as_matrix().unwrap();
        let want = fused_serial(a, &x1, &x2, &b, 8, 4);
        assert_eq!(op.run_serial(), want);
        // each operand is checked against its own width
        let bad = Op::fused(&h, &x1, &DenseHandle::new(vec![0.0; 7]), &b, 8, 4);
        let err = bad.validate().unwrap_err();
        assert!(matches!(err, OpError::DenseShape { operand: "X2", got: 7, .. }), "{err}");
        // either packed width at zero is a typed zero-width error
        for (j, n) in [(0usize, 4usize), (8, 0)] {
            let z = Op::fused(&h, &x1, &x2, &b, j, n);
            assert_eq!(z.validate(), Err(OpError::ZeroWidth { kind: OpKind::FusedSddmmSpmm }));
        }
        // plan compatibility keys on the fused family, both directions
        let plan = crate::algos::FusedConfig::new(8, 4, 4, 8);
        let fused_plan = Algo::FusedSddmmSpmm(plan);
        assert!(OpKind::FusedSddmmSpmm.compatible(&fused_plan));
        assert!(!OpKind::Spmm.compatible(&fused_plan));
        assert!(!OpKind::FusedSddmmSpmm.compatible(&Algo::TacoRowSerial { x: 1, c: 1 }));
    }

    #[test]
    fn legacy_request_converts_without_cloning_payloads() {
        let a = erdos_renyi(10, 10, 20, 2).to_csr();
        let nnz = a.nnz();
        let op: Op = Request::Spmm { a, b: vec![1.0; 10 * 2], n: 2 }.into();
        assert_eq!(op.kind, OpKind::Spmm);
        assert_eq!(op.a.nnz(), nnz);
        assert_eq!(op.a.strong_count(), 1, "conversion moves the operand into one handle");
        op.validate().unwrap();
        // oracle agrees with the serial SpMM on the same data
        let want = spmm_serial(op.a.as_matrix().unwrap(), &op.dense[0], 2);
        assert_eq!(op.run_serial(), want);
    }

    #[test]
    fn shape_keys_match_the_legacy_constructors() {
        let a = erdos_renyi(32, 24, 90, 5).to_csr();
        let stats = MatrixStats::of(&a);
        let h = SparseHandle::matrix(a);
        assert_eq!(
            Op::spmm(&h, &DenseHandle::new(vec![0.0; 24 * 4]), 4).shape_key(),
            Some(ShapeKey::spmm(&stats, 4))
        );
        let t = Coo3::random((16, 12, 10), 120, 7);
        let th = SparseHandle::tensor(t.clone());
        let x1 = DenseHandle::new(vec![0.0; 12 * 8]);
        let x2 = DenseHandle::new(vec![0.0; 10 * 8]);
        assert_eq!(
            Op::mttkrp(&th, &x1, &x2, 8).shape_key(),
            Some(ShapeKey::mttkrp(&t, 8)),
            "handle-derived tensor keys agree with the Coo3 constructors"
        );
        let lx = DenseHandle::new(vec![0.0; 10 * 4]);
        assert_eq!(Op::ttm(&th, &lx, 4).shape_key(), Some(ShapeKey::ttm(&t, 4)));
    }
}
