//! Online calibration: per-`OpKind` drift tracking over served latencies,
//! refit on threshold, plan-cache invalidation.
//!
//! Every sim-served op yields a `(plan, stats, measured seconds)` triple
//! plus the analytic model's predicted price. [`OnlineCalibrator`] keeps,
//! per [`OpKind`], an exponentially-weighted moving average of the
//! absolute log-ratio residual `|ln(measured / predicted)|` — a
//! dimensionless "how wrong is the model, multiplicatively" gauge that is
//! robust to the µs↔s scale spread across ops. When the worst per-op EWMA
//! crosses [`CalibConfig::drift_threshold`] (and at least
//! [`CalibConfig::min_samples`] observations arrived since the last fit),
//! the calibrator refits `CostParams` + `launch_overhead_s` on its sample
//! ring via [`tuner::calibrate::fit`], bumps its generation (executors
//! rebuild their cached [`CostModel`](crate::tuner::CostModel)s lazily),
//! and invalidates the [`PlanCache`] entries of every op kind it saw —
//! stale selector/tuner picks re-select under the refit model on next
//! sight. `Metrics::{calib_samples, calib_refits, calib_residual}` track
//! the loop.
//!
//! [`tuner::calibrate::fit`]: crate::tuner::calibrate::fit

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::Machine;
use crate::tuner::calibrate::{fit, Calibration, Sample};

use super::metrics::Metrics;
use super::op::OpKind;
use super::plan_cache::PlanCache;

/// Online-calibration policy knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibConfig {
    /// Master switch. Off by default: the sim executor then only serves,
    /// never observes, and the coordinator behaves exactly as before the
    /// calibration subsystem existed.
    pub enabled: bool,
    /// Refit when any per-op EWMA residual reaches this (compared with
    /// `>=`, so `0.0` means "refit as soon as `min_samples` arrive" —
    /// what the drift-injection test uses).
    pub drift_threshold: f64,
    /// Observations required between refits (thrash guard).
    pub min_samples: usize,
    /// EWMA smoothing factor in `(0, 1]`; the tracker starts at 0, so
    /// after `k` samples of constant residual `r` it reads
    /// `r·(1 − (1−α)^k)`.
    pub alpha: f64,
    /// Sample ring capacity (oldest observations fall off first).
    pub capacity: usize,
}

impl Default for CalibConfig {
    fn default() -> CalibConfig {
        CalibConfig {
            enabled: false,
            drift_threshold: 0.25,
            min_samples: 64,
            alpha: 0.25,
            capacity: 512,
        }
    }
}

#[derive(Debug)]
struct CalibState {
    ring: VecDeque<(OpKind, Sample)>,
    /// Per-op EWMA residual, indexed like [`OpKind::ALL`].
    ewma: [f64; OpKind::ALL.len()],
    since_refit: usize,
}

/// Shared drift tracker + refitter. One per coordinator; executors hold
/// it behind an `Arc` through their `ExecutorEnv`.
#[derive(Debug)]
pub struct OnlineCalibrator {
    cfg: CalibConfig,
    /// The hand-seeded baseline (hw + default params) fits start from
    /// when no calibration is live.
    base: Machine,
    current: Mutex<Calibration>,
    /// Bumped on every applied fit (including a warm start); executors
    /// compare against their cached model's `calib_generation`.
    generation: AtomicU64,
    state: Mutex<CalibState>,
}

impl OnlineCalibrator {
    /// `warm` is yesterday's fit (from `Calibration::load`); applying it
    /// counts as generation 1 so freshly built executors pick it up.
    pub fn new(base: Machine, warm: Option<Calibration>, cfg: CalibConfig) -> OnlineCalibrator {
        let (current, generation) = match warm {
            Some(c) => (c, 1),
            None => (Calibration::identity(&base), 0),
        };
        OnlineCalibrator {
            cfg,
            base,
            current: Mutex::new(current),
            generation: AtomicU64::new(generation),
            state: Mutex::new(CalibState {
                ring: VecDeque::new(),
                ewma: [0.0; OpKind::ALL.len()],
                since_refit: 0,
            }),
        }
    }

    pub fn config(&self) -> CalibConfig {
        self.cfg
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// The calibration currently applied (identity before any fit).
    pub fn current(&self) -> Calibration {
        self.current.lock().unwrap().clone()
    }

    /// The base machine with the current calibration applied — what
    /// executors should simulate and price with.
    pub fn machine(&self) -> Machine {
        let mut m = self.base.clone();
        self.current.lock().unwrap().apply(&mut m);
        m
    }

    /// Worst per-op EWMA residual right now.
    pub fn residual(&self) -> f64 {
        let st = self.state.lock().unwrap();
        st.ewma.iter().cloned().fold(0.0, f64::max)
    }

    /// Feed one served op: `measured_s` from the executor, `predicted_s`
    /// from the model that routed it. Returns `true` when this
    /// observation tripped a refit (new constants live, affected
    /// [`PlanCache`] scenarios dropped, metrics bumped). Non-finite or
    /// non-positive times are ignored — a degenerate measurement must
    /// not poison the tracker.
    pub fn observe(
        &self,
        kind: OpKind,
        sample: Sample,
        predicted_s: f64,
        metrics: &Metrics,
        plan_cache: &PlanCache,
    ) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        let measured = sample.measured_s;
        if !(measured.is_finite() && measured > 0.0 && predicted_s.is_finite() && predicted_s > 0.0)
        {
            return false;
        }
        let residual = (measured / predicted_s).ln().abs();

        let mut st = self.state.lock().unwrap();
        let slot = OpKind::ALL.iter().position(|k| *k == kind).expect("OpKind::ALL is total");
        st.ewma[slot] = self.cfg.alpha * residual + (1.0 - self.cfg.alpha) * st.ewma[slot];
        if st.ring.len() >= self.cfg.capacity.max(1) {
            st.ring.pop_front();
        }
        st.ring.push_back((kind, sample));
        st.since_refit += 1;
        let worst = st.ewma.iter().cloned().fold(0.0, f64::max);
        metrics.on_calib_sample(worst);

        if worst < self.cfg.drift_threshold || st.since_refit < self.cfg.min_samples.max(1) {
            return false;
        }

        // Refit on the ring, warm-starting from the current constants so
        // successive fits refine rather than restart.
        let machine = {
            let mut m = self.base.clone();
            self.current.lock().unwrap().apply(&mut m);
            m
        };
        let samples: Vec<Sample> = st.ring.iter().map(|(_, s)| s.clone()).collect();
        let fitted = fit(&machine, &samples);
        if fitted.samples == 0 {
            // nothing usable in the ring; don't burn the counters
            return false;
        }
        let mut kinds: Vec<OpKind> = st.ring.iter().map(|(k, _)| *k).collect();
        kinds.sort_by_key(|k| OpKind::ALL.iter().position(|a| a == k));
        kinds.dedup();

        *self.current.lock().unwrap() = fitted;
        self.generation.fetch_add(1, Ordering::Release);
        st.ewma = [0.0; OpKind::ALL.len()];
        st.since_refit = 0;
        drop(st);

        for k in kinds {
            plan_cache.invalidate_scenario(k);
        }
        metrics.on_calib_refit();
        true
    }
}

/// Convenience alias for the shared handle executors carry.
pub type SharedCalibrator = Arc<OnlineCalibrator>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::catalog::Algo;
    use crate::sim::HwProfile;
    use crate::sparse::{erdos_renyi, MatrixStats};
    use crate::tuner::calibrate::WorkloadSpec;

    fn sample(measured: f64) -> Sample {
        let a = erdos_renyi(64, 64, 400, 9).to_csr();
        let stats = MatrixStats::of(&a);
        Sample::new(
            Algo::SgapNnzGroup { c: 4, r: 8 },
            WorkloadSpec::Spmm { stats, n: 4 },
            measured,
        )
    }

    #[test]
    fn ewma_crosses_the_threshold_at_the_closed_form_step() {
        // constant ratio 1.5 → residual ln 1.5 ≈ 0.4055; with α = 0.25
        // the EWMA reads 0.4055·(1 − 0.75^k): below 0.25 through k = 3,
        // above at k = 4. min_samples = 1 isolates the threshold logic.
        let cfg = CalibConfig {
            enabled: true,
            drift_threshold: 0.25,
            min_samples: 1,
            alpha: 0.25,
            capacity: 16,
        };
        let machine = Machine::new(HwProfile::rtx3090());
        let cal = OnlineCalibrator::new(machine, None, cfg);
        let metrics = Metrics::new();
        let cache = PlanCache::new(8);
        let mut tripped_at = None;
        for k in 1..=6 {
            // predicted 1.0, measured 1.5 — model price of this sample's
            // own workload doesn't matter for the tracker math
            if cal.observe(OpKind::Spmm, sample(1.5e-6), 1.0e-6, &metrics, &cache) {
                tripped_at = Some(k);
                break;
            }
        }
        assert_eq!(tripped_at, Some(4), "EWMA must cross 0.25 exactly at the 4th sample");
        assert_eq!(metrics.snapshot().calib_refits, 1);
        assert_eq!(metrics.snapshot().calib_samples, 4);
        assert_eq!(cal.generation(), 1);
        // the refit resets the tracker
        assert_eq!(cal.residual(), 0.0);
    }

    #[test]
    fn disabled_calibrator_observes_nothing() {
        let machine = Machine::new(HwProfile::rtx3090());
        let cal = OnlineCalibrator::new(machine, None, CalibConfig::default());
        let metrics = Metrics::new();
        let cache = PlanCache::new(8);
        assert!(!cal.observe(OpKind::Spmm, sample(1.0e-6), 2.0e-6, &metrics, &cache));
        assert_eq!(metrics.snapshot().calib_samples, 0);
        assert_eq!(cal.generation(), 0);
    }

    #[test]
    fn degenerate_measurements_are_ignored() {
        let cfg = CalibConfig {
            enabled: true,
            min_samples: 1,
            drift_threshold: 0.0,
            ..CalibConfig::default()
        };
        let machine = Machine::new(HwProfile::rtx3090());
        let cal = OnlineCalibrator::new(machine, None, cfg);
        let metrics = Metrics::new();
        let cache = PlanCache::new(8);
        assert!(!cal.observe(OpKind::Spmm, sample(0.0), 1.0e-6, &metrics, &cache));
        assert!(!cal.observe(OpKind::Spmm, sample(f64::NAN), 1.0e-6, &metrics, &cache));
        assert!(!cal.observe(OpKind::Spmm, sample(1.0e-6), f64::INFINITY, &metrics, &cache));
        assert_eq!(metrics.snapshot().calib_samples, 0);
    }

    #[test]
    fn warm_start_counts_as_a_generation() {
        let machine = Machine::new(HwProfile::rtx3090());
        let mut warm = Calibration::identity(&machine);
        warm.params.alu = 1.5;
        let cal = OnlineCalibrator::new(machine.clone(), Some(warm), CalibConfig::default());
        assert_eq!(cal.generation(), 1);
        assert_eq!(cal.machine().params.alu, 1.5);
        let cold = OnlineCalibrator::new(machine, None, CalibConfig::default());
        assert_eq!(cold.generation(), 0);
    }
}
