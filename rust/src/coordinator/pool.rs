//! Bounded MPMC job queue — the spine of the coordinator's worker pool.
//!
//! `std::sync::mpsc` is single-consumer, so a pool of N workers needs its
//! own queue. This is the simplest correct one: a `Mutex<VecDeque>` with
//! two condvars (not-empty for workers, not-full for submitters). Pushing
//! onto a full queue **blocks** — that is the coordinator's backpressure:
//! submitters slow down to the service rate instead of growing an
//! unbounded backlog.
//!
//! Shutdown is graceful: `close()` stops new pushes immediately, but
//! workers keep draining (`pop` keeps returning items) until the queue is
//! empty, so no accepted job is ever dropped.
//!
//! Invariants (unit-tested below, stress-tested through the coordinator in
//! `rust/tests/coordinator_props.rs`):
//! * every pushed item is popped exactly once (across all consumers);
//! * `len() <= capacity` at all times;
//! * `close()` wakes all blocked pushers (they get their item back) and
//!   all blocked poppers (they see `None` once the queue is drained).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer queue with blocking push/pop.
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    pub fn new(capacity: usize) -> JobQueue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        JobQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Push, blocking while the queue is full (backpressure). Returns the
    /// item back if the queue has been closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        // Closed is checked FIRST on every wakeup: a pusher woken by
        // `close()` must hand its item back even if a concurrent pop just
        // opened a slot, otherwise a pusher that loses the race to the
        // `not_full` signal can re-sleep on a closed queue and wedge
        // shutdown (nobody signals `not_full` again after the drain).
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.capacity {
                break;
            }
            inner = self.not_full.wait(inner).unwrap();
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push. `Err` returns the item when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.items.len() >= self.capacity {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop, blocking while the queue is empty. Returns `None` only once the
    /// queue is closed **and** fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Non-blocking pop (used by workers to opportunistically micro-batch).
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front();
        drop(inner);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Stop accepting pushes and wake everyone. Idempotent.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_bounds() {
        let q = JobQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.try_push(3).is_err(), "full queue rejects try_push");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_none() {
        let q = JobQueue::new(4);
        q.push("a").unwrap();
        q.close();
        assert!(q.push("b").is_err(), "closed queue rejects pushes");
        assert_eq!(q.pop(), Some("a"), "items survive close");
        assert_eq!(q.pop(), None);
        q.close(); // idempotent
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(JobQueue::new(1));
        q.push(0u32).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push(1).is_ok());
        // give the pusher time to block on the full queue
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(0));
        assert!(pusher.join().unwrap(), "blocked push completes after pop");
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_wakes_blocked_pusher_and_popper() {
        let q = Arc::new(JobQueue::new(1));
        q.push(7u8).unwrap();
        let qp = q.clone();
        let pusher = std::thread::spawn(move || qp.push(8));
        let qe = Arc::new(JobQueue::<u8>::new(1));
        let qe2 = qe.clone();
        let popper = std::thread::spawn(move || qe2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        qe.close();
        assert_eq!(pusher.join().unwrap(), Err(8), "pusher got its item back");
        assert_eq!(popper.join().unwrap(), None);
    }

    #[test]
    fn close_with_many_blocked_pushers_and_concurrent_drain_does_not_wedge() {
        // Regression: several pushers block on a full queue while a
        // consumer drains it and the owner closes concurrently. Every
        // pusher must return (Ok if it won a slot before close, Err with
        // its item back otherwise) — none may re-sleep past `close()`.
        for round in 0..20u32 {
            let q = Arc::new(JobQueue::new(1));
            q.push(usize::MAX).unwrap();
            let mut pushers = Vec::new();
            for p in 0..4usize {
                let q = q.clone();
                pushers.push(std::thread::spawn(move || q.push(p)));
            }
            // let the pushers reach the wait loop, then race drain + close
            std::thread::sleep(Duration::from_millis(5));
            let qd = q.clone();
            let drainer = std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = qd.pop() {
                    got.push(v);
                }
                got
            });
            q.close();
            let mut accepted = 0usize;
            let mut returned = 0usize;
            for (p, h) in pushers.into_iter().enumerate() {
                // join() hanging here is the wedge this test guards against
                match h.join().unwrap() {
                    Ok(()) => accepted += 1,
                    Err(item) => {
                        assert_eq!(item, p, "pusher got someone else's item back");
                        returned += 1;
                    }
                }
            }
            assert_eq!(accepted + returned, 4, "round {round}: a pusher vanished");
            let drained = drainer.join().unwrap();
            assert_eq!(drained.len(), 1 + accepted, "round {round}: accepted items were lost");
        }
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let q = Arc::new(JobQueue::new(8));
        let popped = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            let popped = popped.clone();
            let sum = sum.clone();
            consumers.push(std::thread::spawn(move || {
                while let Some(v) = q.pop() {
                    popped.fetch_add(1, Ordering::Relaxed);
                    sum.fetch_add(v, Ordering::Relaxed);
                }
            }));
        }
        let mut producers = Vec::new();
        for p in 0..4usize {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..50usize {
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(popped.load(Ordering::Relaxed), 200);
        let want: usize = (0..4).map(|p| (0..50).map(|i| p * 1000 + i).sum::<usize>()).sum();
        assert_eq!(sum.load(Ordering::Relaxed), want);
    }
}
