//! Plan cache — the serving layer's memory of which kernel to run.
//!
//! The paper's headline is that the right algorithm point depends on the
//! *input dynamics* (Table 5, DA-SpMM): the serving layer therefore keys a
//! cache on a fingerprint of [`MatrixStats`] + the dense width, so the
//! first sight of a matrix shape pays one [`Selector`] decision (fast
//! path) and repeat traffic gets the chosen kernel at zero selection
//! cost. An optional background tuner (`tuner::tune` over the sgap grid)
//! later *upgrades* the cached plan from `Selector` to `Tuned`.
//!
//! Correctness does not depend on the fingerprint: every plan in the
//! catalog computes the same SpMM/SDDMM (property-tested in
//! `rust/tests/spmm_differential.rs`), so a fingerprint collision can only
//! cost performance, never accuracy. That includes composite (per-band
//! hybrid) plans: their cuts are log2 degree-bucket indices, not row
//! boundaries of the matrix they were selected for, so `Algo::run`
//! re-derives the band partition from whatever matrix actually arrives —
//! a collision serves a differently-tuned but still-correct hybrid.
//!
//! [`Selector`]: crate::tuner::Selector

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::algos::catalog::Algo;
use crate::sparse::coo3::Coo3;
use crate::sparse::{MatrixStats, SegStats};

use super::op::OpKind;

/// Which kernel scenario a plan serves — the same vocabulary the serving
/// API tags its ops with, so cache keys and [`Op`](super::Op)s never
/// disagree about the algebra.
pub type Scenario = OpKind;

/// Fingerprint of a request's input dynamics: exact shape plus quantized
/// structure statistics (skew, mean degree, empty rows) — the features the
/// DA-SpMM-style selector keys on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    pub scenario: Scenario,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Dense column count N (SpMM) or inner dimension J (SDDMM).
    pub width: u32,
    /// Row-degree CV in eighths, saturated at 8.0.
    cv_q: u16,
    /// Mean row degree, log2-bucketed.
    mean_q: u16,
    /// Empty-row fraction in sixteenths.
    empty_q: u16,
}

impl ShapeKey {
    fn quantized(scenario: Scenario, stats: &MatrixStats, width: u32) -> ShapeKey {
        ShapeKey {
            scenario,
            rows: stats.rows,
            cols: stats.cols,
            nnz: stats.nnz,
            width,
            cv_q: (stats.row_degree_cv.clamp(0.0, 8.0) * 8.0).round() as u16,
            mean_q: (stats.row_degree_mean + 1.0).log2().floor().clamp(0.0, 64.0) as u16,
            empty_q: (stats.empty_row_frac.clamp(0.0, 1.0) * 16.0).round() as u16,
        }
    }

    pub fn spmm(stats: &MatrixStats, n: u32) -> ShapeKey {
        Self::quantized(Scenario::Spmm, stats, n)
    }

    pub fn sddmm(stats: &MatrixStats, j_dim: u32) -> ShapeKey {
        Self::quantized(Scenario::Sddmm, stats, j_dim)
    }

    /// Fused SDDMM→SpMM key. `packed_width` is the op's packed
    /// `(j_dim << 16) | n` pair — both widths shape the fused kernel's
    /// cost, so both belong in the fingerprint. As with every key, a
    /// collision can only cost performance: the fused run path re-derives
    /// the actual extents from the operands that arrive.
    pub fn fused(stats: &MatrixStats, packed_width: u32) -> ShapeKey {
        Self::quantized(Scenario::FusedSddmmSpmm, stats, packed_width)
    }

    /// Fingerprint of an order-3 tensor request: exact output-segment
    /// count (`rows`) / trailing extent / nnz plus the same quantized skew
    /// features as the matrix keys, computed over the scenario's output
    /// segments (rows for MTTKRP, leading `(i,j)` fibers for TTM) — the
    /// dynamics the COO-3 group-size choice *and* the analytic cost model
    /// key on. The statistics come from the shared [`SegStats`] run-length
    /// pass, so the cache key and `tuner::model` see the same features.
    fn tensor_quantized(scenario: Scenario, cols: usize, width: u32, seg: &SegStats) -> ShapeKey {
        ShapeKey {
            scenario,
            rows: seg.segments,
            cols,
            nnz: seg.nnz,
            width,
            cv_q: (seg.cv.clamp(0.0, 8.0) * 8.0).round() as u16,
            mean_q: (seg.mean_len + 1.0).log2().floor().clamp(0.0, 64.0) as u16,
            empty_q: (seg.empty_frac.clamp(0.0, 1.0) * 16.0).round() as u16,
        }
    }

    /// MTTKRP key from an already-computed segment fingerprint (the
    /// handle path: registration ran the [`SegStats`] pass once).
    /// `inner_cols` is the tensor's `dim1 · dim2`.
    pub fn mttkrp_stats(seg: &SegStats, inner_cols: usize, j_dim: u32) -> ShapeKey {
        Self::tensor_quantized(Scenario::Mttkrp, inner_cols, j_dim, seg)
    }

    /// TTM key from an already-computed fiber fingerprint; `cols` is the
    /// tensor's `dim2`.
    pub fn ttm_stats(seg: &SegStats, cols: usize, l_dim: u32) -> ShapeKey {
        Self::tensor_quantized(Scenario::Ttm, cols, l_dim, seg)
    }

    pub fn mttkrp(a: &Coo3, j_dim: u32) -> ShapeKey {
        Self::mttkrp_stats(&SegStats::mttkrp(a), a.dim1 * a.dim2, j_dim)
    }

    pub fn ttm(a: &Coo3, l_dim: u32) -> ShapeKey {
        Self::ttm_stats(&SegStats::ttm(a), a.dim2, l_dim)
    }

    /// Rebuild a key from its serialized parts — the plan-catalog load
    /// path ([`PlanCatalog`](super::PlanCatalog)), where the quantized
    /// features were persisted verbatim and must not be re-derived.
    pub fn from_parts(
        scenario: Scenario,
        rows: usize,
        cols: usize,
        nnz: usize,
        width: u32,
        cv_q: u16,
        mean_q: u16,
        empty_q: u16,
    ) -> ShapeKey {
        ShapeKey { scenario, rows, cols, nnz, width, cv_q, mean_q, empty_q }
    }

    /// The quantized structure features `(cv_q, mean_q, empty_q)` — what
    /// the plan catalog persists alongside the exact-shape fields.
    pub fn quantized_features(&self) -> (u16, u16, u16) {
        (self.cv_q, self.mean_q, self.empty_q)
    }
}

/// How the cached plan was chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanOrigin {
    /// Fast path: the input-dynamics decision tree.
    Selector,
    /// Upgraded by the background grid-search tuner.
    Tuned,
}

/// A cached serving plan: a compiled-plan point from the unified catalog
/// vocabulary ([`Algo`] — SpMM families, dgSPARSE, SDDMM alike) plus how
/// it was chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    pub kind: Algo,
    pub origin: PlanOrigin,
}

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub entries: usize,
    pub hits: u64,
    pub misses: u64,
    pub upgrades: u64,
    pub evictions: u64,
    /// Entries dropped by [`PlanCache::invalidate_scenario`] (calibration
    /// refits, not capacity pressure — those are `evictions`).
    pub invalidations: u64,
    /// Hits on entries preloaded from a persisted plan catalog
    /// ([`PlanCache::preload`]) — the warm-start payoff counter.
    pub warm_hits: u64,
}

/// One cached entry: the served plan plus whether it arrived via
/// [`PlanCache::preload`] (a persisted catalog) — hits on warm entries
/// are counted separately so warm-start effectiveness is observable.
#[derive(Clone, Copy)]
struct Entry {
    plan: Plan,
    warm: bool,
}

struct Inner {
    map: HashMap<ShapeKey, Entry>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<ShapeKey>,
}

impl Inner {
    fn empty() -> Inner {
        Inner { map: HashMap::new(), order: VecDeque::new() }
    }
}

/// Bounded, thread-safe plan cache: N key-hashed shards, each a FIFO
/// bounded map behind its own lock, so concurrent sessions hitting
/// disjoint shapes never serialize on one mutex. Hit/miss/upgrade
/// counters stay cache-global (one `stats()` surface); eviction is FIFO
/// *per shard* with a per-shard bound of `ceil(capacity / shards)`, so
/// total entries never exceed `capacity + shards - 1`.
/// [`PlanCache::new`] builds a single shard, which preserves the exact
/// pre-sharding semantics (global FIFO order, global capacity).
pub struct PlanCache {
    shards: Vec<Mutex<Inner>>,
    shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    upgrades: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
    warm_hits: AtomicU64,
}

impl PlanCache {
    /// A single-shard cache — exact global FIFO semantics. The
    /// coordinator builds the sharded variant via
    /// [`PlanCache::with_shards`].
    pub fn new(capacity: usize) -> PlanCache {
        Self::with_shards(capacity, 1)
    }

    /// A cache of `shards` key-hashed shards sharing `capacity` entries
    /// (each shard bounds `ceil(capacity / shards)`, FIFO per shard).
    pub fn with_shards(capacity: usize, shards: usize) -> PlanCache {
        assert!(capacity > 0, "plan cache capacity must be positive");
        assert!(shards > 0, "plan cache needs at least one shard");
        PlanCache {
            shards: (0..shards).map(|_| Mutex::new(Inner::empty())).collect(),
            shard_capacity: capacity.div_ceil(shards),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            upgrades: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard holding `key` — a hash of the full key, so all lookups,
    /// inserts, upgrades, and preloads of one shape agree on the lock.
    /// `DefaultHasher::new()` uses fixed keys, so routing is deterministic
    /// within a build (shard tests and differential traces reproduce).
    fn shard(&self, key: &ShapeKey) -> &Mutex<Inner> {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look up `key`; on a miss run `select` (under the shard lock —
    /// selection is a few float comparisons) and cache its choice with
    /// [`PlanOrigin::Selector`]. Returns the plan and whether it was a hit.
    pub fn get_or_insert_with(
        &self,
        key: ShapeKey,
        select: impl FnOnce() -> Algo,
    ) -> (Plan, bool) {
        self.try_get_or_insert_with(key, || Some(select()))
            .expect("infallible selector yielded no plan")
    }

    /// [`PlanCache::get_or_insert_with`] for fallible selection — the
    /// generic serving path, where `select` returning `None` means no
    /// legal launch shape covers the op's width. In that case nothing is
    /// inserted, **no miss is recorded** (the op never consulted a plan),
    /// and the caller routes the op to the CPU.
    pub fn try_get_or_insert_with(
        &self,
        key: ShapeKey,
        select: impl FnOnce() -> Option<Algo>,
    ) -> Option<(Plan, bool)> {
        self.try_get_or_insert_traced(key, select).map(|(plan, hit, _)| (plan, hit))
    }

    /// [`PlanCache::try_get_or_insert_with`] that also reports whether a
    /// hit landed on a warm (catalog-preloaded) entry — the serving path
    /// uses the third flag to drive `Metrics::warm_hits`.
    pub fn try_get_or_insert_traced(
        &self,
        key: ShapeKey,
        select: impl FnOnce() -> Option<Algo>,
    ) -> Option<(Plan, bool, bool)> {
        let mut inner = self.shard(&key).lock().unwrap();
        if let Some(entry) = inner.map.get(&key) {
            let entry = *entry;
            drop(inner);
            self.hits.fetch_add(1, Ordering::Relaxed);
            if entry.warm {
                self.warm_hits.fetch_add(1, Ordering::Relaxed);
            }
            return Some((entry.plan, true, entry.warm));
        }
        let kind = select()?;
        self.evict_to_fit(&mut inner);
        let plan = Plan { kind, origin: PlanOrigin::Selector };
        inner.map.insert(key, Entry { plan, warm: false });
        inner.order.push_back(key);
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Some((plan, false, false))
    }

    /// FIFO-evict until the shard has room for one more entry.
    fn evict_to_fit(&self, inner: &mut Inner) {
        while inner.map.len() >= self.shard_capacity {
            match inner.order.pop_front() {
                Some(old) => {
                    inner.map.remove(&old);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break, // map/order drifted; never expected, but don't spin
            }
        }
    }

    /// Install a persisted catalog entry (warm start). Keeps the plan's
    /// persisted origin, marks the entry warm, and respects the shard
    /// bound (FIFO eviction). Returns `false` — and changes nothing —
    /// when the key is already cached: live traffic outranks yesterday's
    /// catalog. Records neither a hit nor a miss (no op consulted a plan).
    pub fn preload(&self, key: ShapeKey, plan: Plan) -> bool {
        let mut inner = self.shard(&key).lock().unwrap();
        if inner.map.contains_key(&key) {
            return false;
        }
        self.evict_to_fit(&mut inner);
        inner.map.insert(key, Entry { plan, warm: true });
        inner.order.push_back(key);
        true
    }

    pub fn get(&self, key: &ShapeKey) -> Option<Plan> {
        self.shard(key).lock().unwrap().map.get(key).map(|e| e.plan)
    }

    /// Replace an existing entry with a tuner-chosen plan. Returns false if
    /// the entry was evicted in the meantime (the upgrade is dropped — the
    /// next miss re-selects and may be re-tuned). A warm entry stays warm:
    /// its key still came from the catalog.
    pub fn upgrade(&self, key: ShapeKey, kind: Algo) -> bool {
        let mut inner = self.shard(&key).lock().unwrap();
        match inner.map.get_mut(&key) {
            Some(entry) => {
                entry.plan = Plan { kind, origin: PlanOrigin::Tuned };
                drop(inner);
                self.upgrades.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Drop every entry whose key serves `scenario`. The calibration
    /// refit path: a new `CostParams` fit can reorder candidates for the
    /// op kinds it was fitted on, so their cached selector/tuner picks
    /// are stale — the next miss re-selects under the refit model.
    /// Each shard is swept atomically under its own lock (a concurrent
    /// lookup sees either all of a shard's stale entries or none of
    /// them); shards are swept in order. Returns how many entries were
    /// dropped.
    pub fn invalidate_scenario(&self, scenario: Scenario) -> usize {
        let mut dropped = 0usize;
        for shard in &self.shards {
            let mut inner = shard.lock().unwrap();
            let before = inner.map.len();
            inner.map.retain(|k, _| k.scenario != scenario);
            inner.order.retain(|k| k.scenario != scenario);
            dropped += before - inner.map.len();
        }
        self.invalidations.fetch_add(dropped as u64, Ordering::Relaxed);
        dropped
    }

    /// Snapshot every cached `(key, plan)` pair, shard by shard in FIFO
    /// order — the plan catalog's save path. (Canonical catalog order is
    /// imposed by the catalog itself, not by shard layout.)
    pub fn entries(&self) -> Vec<(ShapeKey, Plan)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let inner = shard.lock().unwrap();
            for key in &inner.order {
                if let Some(entry) = inner.map.get(key) {
                    out.push((*key, entry.plan));
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            entries: self.len(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            upgrades: self.upgrades.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{banded, erdos_renyi, power_law};
    use crate::tuner::Selector;

    fn key_of(m: &crate::sparse::Csr, n: u32) -> ShapeKey {
        ShapeKey::spmm(&MatrixStats::of(m), n)
    }

    #[test]
    fn same_matrix_same_key_different_structure_different_key() {
        let er = erdos_renyi(128, 128, 1024, 1).to_csr();
        let er2 = erdos_renyi(128, 128, 1024, 1).to_csr();
        let pl = power_law(128, 128, 1024, 2.0, 1).to_csr();
        assert_eq!(key_of(&er, 4), key_of(&er2, 4));
        assert_ne!(key_of(&er, 4), key_of(&er, 8), "width is part of the key");
        assert_ne!(key_of(&er, 4), key_of(&pl, 4), "skew separates ER from power-law");
        let stats = MatrixStats::of(&er);
        assert_ne!(ShapeKey::spmm(&stats, 4), ShapeKey::sddmm(&stats, 4));
        // the fused scenario is its own key space, and both packed widths
        // separate entries
        let fused = ShapeKey::fused(&stats, (16 << 16) | 4);
        assert_ne!(fused, ShapeKey::spmm(&stats, (16 << 16) | 4));
        assert_ne!(fused, ShapeKey::fused(&stats, (16 << 16) | 8), "n separates");
        assert_ne!(fused, ShapeKey::fused(&stats, (32 << 16) | 4), "j_dim separates");
    }

    #[test]
    fn tensor_keys_separate_scenarios_and_structures() {
        use crate::sparse::coo3::Coo3;
        let t = Coo3::random((32, 24, 16), 400, 1);
        let t2 = Coo3::random((32, 24, 16), 400, 1);
        // deterministic + width/scenario separation
        assert_eq!(ShapeKey::mttkrp(&t, 8), ShapeKey::mttkrp(&t2, 8));
        assert_ne!(ShapeKey::mttkrp(&t, 8), ShapeKey::mttkrp(&t, 16));
        assert_ne!(ShapeKey::mttkrp(&t, 8), ShapeKey::ttm(&t, 8));
        // a hub tensor (every nnz in one row) is separated from uniform
        let hub = Coo3::new(
            (32, 24, 16),
            (0..200u32).map(|p| (0, p % 24, (p * 7) % 16, 1.0f32)).collect(),
        );
        assert_ne!(ShapeKey::mttkrp(&hub, 8), ShapeKey::mttkrp(&t, 8), "skew must separate");
    }

    #[test]
    fn miss_then_hit_returns_same_plan() {
        let cache = PlanCache::new(8);
        let a = banded(256, 5, 3).to_csr();
        let stats = MatrixStats::of(&a);
        let key = ShapeKey::spmm(&stats, 4);
        let sel = Selector::default();
        let (p1, hit1) = cache.get_or_insert_with(key, || sel.select(&stats, 4));
        let (p2, hit2) =
            cache.get_or_insert_with(key, || panic!("selector must not run on a hit"));
        assert!(!hit1 && hit2);
        assert_eq!(p1, p2);
        assert_eq!(p1.origin, PlanOrigin::Selector);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn upgrade_marks_tuned_and_survives_hits() {
        let cache = PlanCache::new(8);
        let a = erdos_renyi(64, 64, 400, 9).to_csr();
        let stats = MatrixStats::of(&a);
        let key = ShapeKey::spmm(&stats, 4);
        let sel = Selector::default();
        cache.get_or_insert_with(key, || sel.select(&stats, 4));
        let tuned = Algo::SgapNnzGroup { c: 4, r: 8 };
        assert!(cache.upgrade(key, tuned));
        let (p, hit) = cache.get_or_insert_with(key, || panic!("must hit"));
        assert!(hit);
        assert_eq!(p.origin, PlanOrigin::Tuned);
        assert_eq!(p.kind, tuned);
        assert_eq!(cache.stats().upgrades, 1);
    }

    #[test]
    fn capacity_bounds_entries_fifo() {
        let cache = PlanCache::new(2);
        let keys: Vec<ShapeKey> = (0..3usize)
            .map(|i| key_of(&erdos_renyi(32 + i, 32, 64, i as u64).to_csr(), 4))
            .collect();
        for k in &keys {
            cache.get_or_insert_with(*k, || Algo::TacoRowSerial { x: 1, c: 1 });
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&keys[0]).is_none(), "oldest entry evicted");
        assert!(cache.get(&keys[2]).is_some());
        // upgrading an evicted key is a no-op
        assert!(!cache.upgrade(keys[0], Algo::SgapNnzGroup { c: 1, r: 2 }));
    }

    #[test]
    fn invalidate_scenario_drops_only_that_op_kind() {
        let cache = PlanCache::new(8);
        let a = erdos_renyi(64, 64, 400, 9).to_csr();
        let stats = MatrixStats::of(&a);
        let plan = || Algo::SgapNnzGroup { c: 4, r: 8 };
        let spmm4 = ShapeKey::spmm(&stats, 4);
        let spmm8 = ShapeKey::spmm(&stats, 8);
        let sddmm = ShapeKey::sddmm(&stats, 16);
        cache.get_or_insert_with(spmm4, plan);
        cache.get_or_insert_with(spmm8, plan);
        let sddmm_plan = Algo::Sddmm(crate::algos::sddmm::SddmmConfig::new(16, 8, 4));
        cache.get_or_insert_with(sddmm, || sddmm_plan);
        assert_eq!(cache.len(), 3);

        assert_eq!(cache.invalidate_scenario(Scenario::Spmm), 2);
        assert_eq!(cache.len(), 1);
        assert!(cache.get(&spmm4).is_none() && cache.get(&spmm8).is_none());
        assert!(cache.get(&sddmm).is_some(), "other scenarios survive");
        assert_eq!(cache.stats().invalidations, 2);
        // idempotent: nothing left to drop, counters don't move
        assert_eq!(cache.invalidate_scenario(Scenario::Spmm), 0);
        assert_eq!(cache.stats().invalidations, 2);
        // the FIFO order list shrank with the map: filling to capacity
        // still evicts cleanly instead of popping stale keys
        let (_, hit) = cache.get_or_insert_with(spmm4, plan);
        assert!(!hit, "invalidated keys re-select on next sight");
    }

    #[test]
    fn sharded_cache_serves_like_single_shard_without_eviction_pressure() {
        let sharded = PlanCache::with_shards(64, 8);
        assert_eq!(sharded.shard_count(), 8);
        let keys: Vec<ShapeKey> = (0..16usize)
            .map(|i| key_of(&erdos_renyi(32 + i, 32, 64 + 4 * i, i as u64).to_csr(), 4))
            .collect();
        for k in &keys {
            sharded.get_or_insert_with(*k, || Algo::TacoRowSerial { x: 1, c: 1 });
            sharded.get_or_insert_with(*k, || panic!("second sight must hit"));
        }
        let s = sharded.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.evictions), (16, 16, 16, 0));
        for k in &keys {
            assert!(sharded.get(k).is_some(), "every key lands on its routing shard");
        }
        // upgrades route to the same shard as the original insert
        assert!(sharded.upgrade(keys[3], Algo::SgapNnzGroup { c: 2, r: 4 }));
        assert_eq!(sharded.get(&keys[3]).unwrap().origin, PlanOrigin::Tuned);
        // scenario invalidation sweeps every shard
        assert_eq!(sharded.invalidate_scenario(Scenario::Spmm), 16);
        assert!(sharded.is_empty());
    }

    #[test]
    fn preload_marks_entries_warm_and_hits_count_separately() {
        let cache = PlanCache::with_shards(16, 4);
        let key = key_of(&erdos_renyi(64, 64, 400, 9).to_csr(), 4);
        let plan = Plan { kind: Algo::SgapNnzGroup { c: 4, r: 8 }, origin: PlanOrigin::Tuned };
        assert!(cache.preload(key, plan));
        // preloading records neither a hit nor a miss
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.warm_hits, s.entries), (0, 0, 0, 1));
        // a live lookup hits the warm entry without re-selecting, keeping
        // the persisted Tuned origin, and bumps both hit counters
        let (p, hit, warm) = cache
            .try_get_or_insert_traced(key, || panic!("warm entry must not re-select"))
            .unwrap();
        assert!(hit && warm);
        assert_eq!(p, plan);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.warm_hits), (1, 0, 1));
        // live traffic outranks the catalog: preload refuses to overwrite
        let other = Plan { kind: Algo::TacoNnzSerial { g: 2, c: 1 }, origin: PlanOrigin::Selector };
        assert!(!cache.preload(key, other));
        assert_eq!(cache.get(&key).unwrap(), plan);
        // a tuner upgrade keeps the entry warm (its key came from the catalog)
        assert!(cache.upgrade(key, Algo::SgapRowGroup { g: 2, c: 2, r: 4 }));
        let (_, _, still_warm) = cache.try_get_or_insert_traced(key, || None).unwrap();
        assert!(still_warm);
        // cold entries report warm = false on hits
        let cold = key_of(&erdos_renyi(32, 32, 100, 3).to_csr(), 8);
        cache.get_or_insert_with(cold, || Algo::TacoRowSerial { x: 1, c: 1 });
        let (_, hit, warm) = cache.try_get_or_insert_traced(cold, || None).unwrap();
        assert!(hit && !warm);
        assert_eq!(cache.stats().warm_hits, 2, "cold hits don't move warm_hits");
    }

    #[test]
    fn entries_snapshot_matches_cache_contents() {
        let cache = PlanCache::with_shards(32, 4);
        let keys: Vec<ShapeKey> = (0..6usize)
            .map(|i| key_of(&erdos_renyi(48 + i, 48, 200, i as u64).to_csr(), 4))
            .collect();
        for k in &keys {
            cache.get_or_insert_with(*k, || Algo::SgapNnzGroup { c: 4, r: 8 });
        }
        let snap = cache.entries();
        assert_eq!(snap.len(), keys.len());
        for k in &keys {
            let (_, plan) = snap.iter().find(|(sk, _)| sk == k).expect("key snapshotted");
            assert_eq!(*plan, cache.get(k).unwrap());
        }
    }

    #[test]
    fn preload_respects_shard_capacity() {
        // single shard, capacity 2: the third preload FIFO-evicts the first
        let cache = PlanCache::new(2);
        let keys: Vec<ShapeKey> = (0..3usize)
            .map(|i| key_of(&erdos_renyi(32 + i, 32, 64, i as u64).to_csr(), 4))
            .collect();
        let plan = Plan { kind: Algo::SgapNnzGroup { c: 4, r: 8 }, origin: PlanOrigin::Tuned };
        for k in &keys {
            assert!(cache.preload(*k, plan));
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&keys[0]).is_none(), "oldest preloaded entry evicted");
    }

    #[test]
    fn fallible_selection_leaves_no_trace() {
        let cache = PlanCache::new(4);
        let key = key_of(&erdos_renyi(16, 16, 30, 2).to_csr(), 4);
        // an uncovered width: no insert, no miss recorded
        assert!(cache.try_get_or_insert_with(key, || None).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        // a later legal selection for the same key proceeds normally
        let (p, hit) =
            cache.try_get_or_insert_with(key, || Some(Algo::SgapNnzGroup { c: 4, r: 8 })).unwrap();
        assert!(!hit);
        assert_eq!(p.origin, PlanOrigin::Selector);
        // and hits do not run the selector at all
        let (p2, hit2) =
            cache.try_get_or_insert_with(key, || panic!("selector must not run on a hit")).unwrap();
        assert!(hit2);
        assert_eq!(p, p2);
    }
}
