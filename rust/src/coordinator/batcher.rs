//! Shape-bucket batcher: groups queued requests by routing key (the
//! typed [`BackendKind`](super::BackendKind) an executor admission
//! resolves to) so a worker amortizes executable lookup/dispatch over a
//! batch.
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! * FIFO within a bucket — requests to the same key keep arrival order;
//! * fairness across buckets — `next_batch` serves the bucket whose head
//!   arrived earliest;
//! * no loss — every pushed item is drained exactly once;
//! * batch bound — a batch never exceeds `max_batch`.

use std::collections::VecDeque;

/// A keyed FIFO batcher.
#[derive(Debug)]
pub struct Batcher<K: Eq + Clone, T> {
    /// (key, queue, arrival counter of head)
    buckets: Vec<(K, VecDeque<(u64, T)>)>,
    counter: u64,
    max_batch: usize,
}

impl<K: Eq + Clone, T> Batcher<K, T> {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch > 0);
        Batcher { buckets: Vec::new(), counter: 0, max_batch }
    }

    pub fn push(&mut self, key: K, item: T) {
        let seq = self.counter;
        self.counter += 1;
        if let Some((_, q)) = self.buckets.iter_mut().find(|(k, _)| *k == key) {
            q.push_back((seq, item));
        } else {
            let mut q = VecDeque::new();
            q.push_back((seq, item));
            self.buckets.push((key, q));
        }
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|(_, q)| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop the next batch: up to `max_batch` items from the bucket whose
    /// head request arrived earliest. Empty buckets are pruned.
    pub fn next_batch(&mut self) -> Option<(K, Vec<T>)> {
        let idx = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, (_, q))| !q.is_empty())
            .min_by_key(|(_, (_, q))| q.front().map(|(s, _)| *s).unwrap_or(u64::MAX))
            .map(|(i, _)| i)?;
        let key = self.buckets[idx].0.clone();
        let q = &mut self.buckets[idx].1;
        let take = q.len().min(self.max_batch);
        let items: Vec<T> = q.drain(..take).map(|(_, t)| t).collect();
        if q.is_empty() {
            self.buckets.remove(idx);
        }
        Some((key, items))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_bucket() {
        let mut b = Batcher::new(10);
        b.push("a", 1);
        b.push("a", 2);
        b.push("a", 3);
        let (_, items) = b.next_batch().unwrap();
        assert_eq!(items, vec![1, 2, 3]);
    }

    #[test]
    fn earliest_head_served_first() {
        let mut b = Batcher::new(10);
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        let (k1, _) = b.next_batch().unwrap();
        assert_eq!(k1, "a");
        let (k2, v2) = b.next_batch().unwrap();
        assert_eq!((k2, v2), ("b", vec![2]));
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn batch_bound_respected() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push("a", i);
        }
        assert_eq!(b.next_batch().unwrap().1, vec![0, 1]);
        assert_eq!(b.next_batch().unwrap().1, vec![2, 3]);
        assert_eq!(b.next_batch().unwrap().1, vec![4]);
    }

    #[test]
    fn len_tracks() {
        let mut b = Batcher::new(4);
        assert!(b.is_empty());
        b.push(1u32, "x");
        b.push(2u32, "y");
        assert_eq!(b.len(), 2);
        b.next_batch();
        assert_eq!(b.len(), 1);
    }
}
