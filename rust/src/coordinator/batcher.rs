//! Shape-bucket batcher: groups queued requests by a coalescing key (the
//! coordinator keys on the op's plan-cache `ShapeKey`, so same-shape ops
//! from *different* sessions ride one launch) so a worker amortizes
//! plan lookup/dispatch over a batch.
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! * FIFO within a bucket — requests to the same key keep arrival order;
//! * fairness across buckets — `next_batch` serves the bucket whose head
//!   arrived earliest, so from the moment an item becomes its bucket's
//!   head at most `live buckets` drains (≤ `buckets × max_batch` pops)
//!   pass before its bucket is served — no bucket starves;
//! * no loss — every pushed item is drained exactly once;
//! * batch bound — a batch never exceeds `max_batch`;
//! * age bound — under [`Batcher::next_ready`], a bucket is held back to
//!   coalesce only while it is neither full nor older than `age_bound`
//!   arrivals, so coalescing never adds unbounded latency.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

/// A keyed FIFO batcher. `push` is O(1): a `HashMap` index maps each live
/// key to its bucket slot, instead of the linear scan the submit path
/// used to pay per request (a real cost under diverse routing keys).
#[derive(Debug)]
pub struct Batcher<K: Eq + Hash + Clone, T> {
    /// (key, queue, arrival counter of head)
    buckets: Vec<(K, VecDeque<(u64, T)>)>,
    /// key → index into `buckets`; maintained across `swap_remove`.
    index: HashMap<K, usize>,
    counter: u64,
    max_batch: usize,
    /// Coalescing window for [`Batcher::next_ready`], in arrivals: a
    /// bucket is ripe once full or once `counter - head_seq >= age_bound`.
    /// `0` (the [`Batcher::new`] default) makes every bucket instantly
    /// ripe, i.e. no coalescing window.
    age_bound: u64,
}

impl<K: Eq + Hash + Clone, T> Batcher<K, T> {
    pub fn new(max_batch: usize) -> Self {
        Self::with_age_bound(max_batch, 0)
    }

    /// A batcher whose [`Batcher::next_ready`] holds partially-filled
    /// buckets back for up to `age_bound` subsequent arrivals, waiting
    /// for same-key traffic to coalesce.
    pub fn with_age_bound(max_batch: usize, age_bound: u64) -> Self {
        assert!(max_batch > 0);
        Batcher {
            buckets: Vec::new(),
            index: HashMap::new(),
            counter: 0,
            max_batch,
            age_bound,
        }
    }

    pub fn age_bound(&self) -> u64 {
        self.age_bound
    }

    pub fn push(&mut self, key: K, item: T) {
        let seq = self.counter;
        self.counter += 1;
        if let Some(&i) = self.index.get(&key) {
            self.buckets[i].1.push_back((seq, item));
        } else {
            let mut q = VecDeque::new();
            q.push_back((seq, item));
            self.index.insert(key.clone(), self.buckets.len());
            self.buckets.push((key, q));
        }
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|(_, q)| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop the next batch: up to `max_batch` items from the bucket whose
    /// head request arrived earliest. Empty buckets are pruned.
    pub fn next_batch(&mut self) -> Option<(K, Vec<T>)> {
        let idx = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, (_, q))| !q.is_empty())
            .min_by_key(|(_, (_, q))| q.front().map(|(s, _)| *s).unwrap_or(u64::MAX))
            .map(|(i, _)| i)?;
        self.drain_bucket(idx)
    }

    /// Pop the next *ripe* batch — the oldest-head bucket among those that
    /// are full (`len >= max_batch`) or whose head has waited `age_bound`
    /// or more arrivals. Returns `None` while every bucket is still
    /// inside its coalescing window (the caller flushes those with
    /// [`Batcher::next_batch`] once no more traffic is imminent).
    pub fn next_ready(&mut self) -> Option<(K, Vec<T>)> {
        let counter = self.counter;
        let (max_batch, age_bound) = (self.max_batch, self.age_bound);
        let idx = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, (_, q))| {
                q.len() >= max_batch
                    || q.front().is_some_and(|(s, _)| counter - s >= age_bound)
            })
            .min_by_key(|(_, (_, q))| q.front().map(|(s, _)| *s).unwrap_or(u64::MAX))
            .map(|(i, _)| i)?;
        self.drain_bucket(idx)
    }

    fn drain_bucket(&mut self, idx: usize) -> Option<(K, Vec<T>)> {
        let key = self.buckets[idx].0.clone();
        let q = &mut self.buckets[idx].1;
        let take = q.len().min(self.max_batch);
        let items: Vec<T> = q.drain(..take).map(|(_, t)| t).collect();
        if q.is_empty() {
            self.buckets.swap_remove(idx);
            self.index.remove(&key);
            // the swapped-in bucket (if any) moved to `idx`: re-point it
            if idx < self.buckets.len() {
                self.index.insert(self.buckets[idx].0.clone(), idx);
            }
        }
        Some((key, items))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_bucket() {
        let mut b = Batcher::new(10);
        b.push("a", 1);
        b.push("a", 2);
        b.push("a", 3);
        let (_, items) = b.next_batch().unwrap();
        assert_eq!(items, vec![1, 2, 3]);
    }

    #[test]
    fn earliest_head_served_first() {
        let mut b = Batcher::new(10);
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        let (k1, _) = b.next_batch().unwrap();
        assert_eq!(k1, "a");
        let (k2, v2) = b.next_batch().unwrap();
        assert_eq!((k2, v2), ("b", vec![2]));
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn batch_bound_respected() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push("a", i);
        }
        assert_eq!(b.next_batch().unwrap().1, vec![0, 1]);
        assert_eq!(b.next_batch().unwrap().1, vec![2, 3]);
        assert_eq!(b.next_batch().unwrap().1, vec![4]);
    }

    #[test]
    fn index_survives_bucket_removal() {
        let mut b = Batcher::new(10);
        b.push("a", 1);
        b.push("b", 2);
        b.push("c", 3);
        // draining "a" swap-removes its bucket, moving "c" into its slot
        assert_eq!(b.next_batch().unwrap(), ("a", vec![1]));
        b.push("c", 4); // must land in c's moved bucket, FIFO preserved
        b.push("a", 5); // a reused key gets a fresh bucket
        assert_eq!(b.next_batch().unwrap(), ("b", vec![2]));
        assert_eq!(b.next_batch().unwrap(), ("c", vec![3, 4]));
        assert_eq!(b.next_batch().unwrap(), ("a", vec![5]));
        assert!(b.next_batch().is_none() && b.is_empty());
    }

    #[test]
    fn next_ready_holds_young_buckets_and_releases_full_or_aged_ones() {
        let mut b = Batcher::with_age_bound(2, 4);
        assert_eq!(b.age_bound(), 4);
        b.push("a", 1);
        // one item, head age 1 < 4: still inside the coalescing window
        assert!(b.next_ready().is_none());
        b.push("a", 2);
        // full bucket is ripe regardless of age
        assert_eq!(b.next_ready().unwrap(), ("a", vec![1, 2]));
        // ageing out: a lone item becomes ripe after `age_bound` arrivals
        b.push("b", 3);
        assert!(b.next_ready().is_none());
        b.push("c", 4);
        b.push("c", 5);
        b.push("c", 6);
        // "b"'s head (seq 2) has now waited counter(6) - 2 = 4 arrivals,
        // and it is the oldest ripe head — served before the full "c"
        assert_eq!(b.next_ready().unwrap(), ("b", vec![3]));
        assert_eq!(b.next_ready().unwrap(), ("c", vec![4, 5]));
        // the "c" remainder (seq 6) is young and under-filled again
        assert!(b.next_ready().is_none());
        // next_batch flushes the window unconditionally
        assert_eq!(b.next_batch().unwrap(), ("c", vec![6]));
        assert!(b.is_empty());
    }

    #[test]
    fn zero_age_bound_makes_next_ready_eager() {
        let mut b = Batcher::new(4);
        b.push("a", 1);
        assert_eq!(b.next_ready().unwrap(), ("a", vec![1]), "no window by default");
        assert!(b.next_ready().is_none());
    }

    #[test]
    fn len_tracks() {
        let mut b = Batcher::new(4);
        assert!(b.is_empty());
        b.push(1u32, "x");
        b.push(2u32, "y");
        assert_eq!(b.len(), 2);
        b.next_batch();
        assert_eq!(b.len(), 1);
    }
}
