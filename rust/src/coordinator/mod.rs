//! The serving coordinator — L3's request path.
//!
//! A production-shaped front end over the paper's machinery, built from
//! three concepts ([`op`], [`executor`], [`session`]):
//!
//! * **Operand handles** ([`SparseHandle`], [`DenseHandle`]): callers
//!   register sparse and dense operands once; registration runs the
//!   `MatrixStats`/`SegStats` fingerprint pass a single time and caches
//!   it, so repeat submits are zero-copy (`Arc` bumps) and derive their
//!   plan-cache keys in O(1).
//! * **One generic op** ([`Op`], [`OpKind`]): a single typed descriptor
//!   replaces the per-algebra request variants — validation (overflow-
//!   checked), degeneracy, cache keys, selector dispatch, batching, and
//!   the serial oracle are each one `match` over [`OpKind`], so a new
//!   algebra is data, not a parallel plumbing stack. `submit(Op)` returns
//!   a [`Ticket`] future; the legacy `Request`/`*_blocking` surface
//!   remains as thin shims.
//! * **Pluggable executors** ([`Executor`], [`ExecutorRegistry`]): the
//!   execution backends are a priority-ordered trait-object stack
//!   (admission predicate + execute) built per worker — PJRT artifacts,
//!   the plan-cache SIMT simulator, and the serial CPU by default; custom
//!   backends plug in through the registry.
//!
//! Mechanically: a **pool** of worker threads ([`pool`]) drains a bounded
//! job queue (blocking backpressure on `submit`, typed
//! `OpError::Overloaded` rejection on `try_submit`), coalesces
//! same-shape traffic **across sessions** in one shared [`batcher`]
//! keyed by plan-cache [`ShapeKey`] (operands are `Arc`-backed, so a
//! cross-session batch is routing, not copying), and serves the full
//! §2.1 quartet. Kernel choice is **tuner-aware**: each operand
//! fingerprint is looked up in the [`plan_cache`] — N key-hashed shards,
//! so 64 concurrent sessions don't serialize on one mutex — where a miss
//! runs the DA-SpMM-style [`Selector`](crate::tuner::Selector) fast path
//! (by default the analytic cost-model argmin), and an optional
//! background thread refines hot shapes with the model-pruned
//! `tuner::tune*_pruned` sweep, upgrading the cached plan in place.
//! Tuned plans persist across runs via the versioned [`catalog`]
//! artifact (`serve --plans FILE` warm-starts from it). [`metrics`]
//! keeps global quantiles, per-backend and per-op latency histograms,
//! cache hit/miss counters, and the serving-at-scale trio
//! (`coalesced`/`rejected`/`warm_hits`).
//!
//! Thread-based throughout (the offline dependency set has no async
//! runtime); callers get a [`Ticket`] future per op.

pub mod batcher;
pub mod calibrate;
pub mod catalog;
pub mod executor;
pub mod metrics;
pub mod op;
pub mod plan_cache;
pub mod pool;
pub mod server;
pub mod session;

pub use batcher::Batcher;
pub use calibrate::{CalibConfig, OnlineCalibrator};
pub use catalog::{CatalogEntry, PlanCatalog, PLAN_CATALOG_SCHEMA_VERSION};
pub use executor::{
    cpu_factory, factory, pjrt_factory, sim_factory, Admission, BackendKind, CpuExecutor,
    Executor, ExecutorEnv, ExecutorFactory, ExecutorRegistry, PjrtExecutor, SimExecutor,
};
pub use metrics::{BackendSnapshot, Metrics, MetricsSnapshot, OpSnapshot};
pub use op::{DenseHandle, Op, OpError, OpKind, Request, SparseData, SparseHandle};
pub use plan_cache::{Plan, PlanCache, PlanCacheStats, PlanOrigin, Scenario, ShapeKey};
pub use pool::JobQueue;
pub use server::{Coordinator, CoordinatorConfig, Response};
pub use session::{Session, SgapClient, Ticket};
