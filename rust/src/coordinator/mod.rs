//! The serving coordinator — L3's request path.
//!
//! The paper's contribution lives in the compiler (L2/L1-adjacent), so per
//! DESIGN.md the coordinator is a focused service: an SpMM/GCN request
//! queue with shape-bucket **batching**, artifact **routing** (PJRT
//! executables compiled once and kept hot), a CPU fallback for requests no
//! bucket admits, and metrics. Thread-based (the offline dependency set
//! has no async runtime); one worker owns the PJRT client, callers get a
//! channel future.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, Request, Response};
