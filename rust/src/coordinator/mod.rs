//! The serving coordinator — L3's request path.
//!
//! A production-shaped front end over the paper's machinery: a **pool** of
//! worker threads ([`pool`]) drains a bounded job queue (backpressure on
//! submit), micro-batches by backend ([`batcher`]), and serves the full
//! §2.1 quartet — SpMM, SDDMM, MTTKRP, and TTM requests. Kernel choice is **tuner-aware**: each matrix shape
//! is fingerprinted and looked up in the [`plan_cache`] — a miss runs the
//! DA-SpMM-style [`Selector`](crate::tuner::Selector) fast path (by
//! default the analytic cost-model argmin), and an optional background
//! thread refines hot shapes with the model-pruned `tuner::tune*_pruned`
//! sweep (O(stats) pricing over the grid, simulation only for the top-K
//! survivors), upgrading the cached plan in place. Execution goes
//! to PJRT artifacts (when compiled in and admitted), the SIMT simulator
//! (running the plan's kernel), or the serial CPU fallback; [`metrics`]
//! keeps global quantiles, per-backend latency histograms, and cache
//! hit/miss counters.
//!
//! Thread-based throughout (the offline dependency set has no async
//! runtime); callers get a channel future per request.

pub mod batcher;
pub mod metrics;
pub mod plan_cache;
pub mod pool;
pub mod server;

pub use batcher::Batcher;
pub use metrics::{BackendSnapshot, Metrics, MetricsSnapshot};
pub use plan_cache::{Plan, PlanCache, PlanCacheStats, PlanOrigin, Scenario, ShapeKey};
pub use pool::JobQueue;
pub use server::{Coordinator, CoordinatorConfig, Request, Response};
