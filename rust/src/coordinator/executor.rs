//! Pluggable execution backends — the `Executor` trait and registry that
//! replace the coordinator's hardcoded `Backend::{Pjrt, Sim, Cpu}` enum.
//!
//! Each worker thread builds its own executor stack from the registry's
//! factories (the PJRT client is `!Send`, so executors cannot be shared
//! across workers). Admission is a priority scan: for every validated
//! [`Op`] the worker asks each executor in order, and the first
//! [`Executor::admit`] that returns an [`Admission`] claims the op —
//! which also yields the typed [`BackendKind`] used as the batching key
//! and metrics label. If [`Executor::execute`] later fails, the worker
//! serves the op on the serial CPU oracle and labels it
//! [`BackendKind::CpuFallback`], so an executor error can cost latency
//! but never a wrong (or lost) response.
//!
//! The standard stack mirrors the old routing exactly:
//!
//! 1. [`PjrtExecutor`] — admits SpMM ops whose shape matches a loaded
//!    artifact (the numeric hot path; absent without artifacts).
//! 2. [`SimExecutor`] — consults the [`PlanCache`] (selector/model on a
//!    miss, background-tune enqueue) and runs the plan's kernel on the
//!    SIMT simulator.
//! 3. [`CpuExecutor`] — admits everything; the serial last resort that
//!    serves degenerate inputs and widths no launch shape covers.

use std::fmt;
use std::path::PathBuf;
use std::sync::mpsc::SyncSender;
use std::sync::Arc;

use crate::compiler::DialectKind;
use crate::runtime::artifact::{pad_coo, pad_dense};
use crate::runtime::pool::{DeviceImage, DevicePool, PoolRef};
use crate::runtime::{ArtifactKind, Runtime};
use crate::sim::{HwProfile, Machine};
use crate::tuner::calibrate::{Sample, WorkloadSpec};
use crate::tuner::{CostModel, Selector};

use super::calibrate::SharedCalibrator;
use super::metrics::Metrics;
use super::op::{Op, OpKind, SparseData, SparseHandle};
use super::plan_cache::{Plan, PlanCache, ShapeKey};

/// Typed backend tag of a served response. Its `Display` form is the
/// stable metrics/batching label (`pjrt:<artifact>`, `sim:<family>`,
/// `cpu-serial`, `cpu-fallback`), unchanged from the stringly-typed API
/// so logs, dashboards, and scrape targets keep working.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// PJRT artifact by name (the numeric hot path).
    Pjrt { artifact: String },
    /// A plan-cache kernel on the SIMT simulator, by family label.
    Sim { family: &'static str },
    /// A plan-cache kernel served under a non-CUDA codegen dialect
    /// (`sim:<dialect>:<family>`). The default CUDA dialect keeps the
    /// bare [`BackendKind::Sim`] label, so existing dashboards and the
    /// pinned label tests read on unchanged.
    SimDialect { family: &'static str, dialect: DialectKind },
    /// Serial CPU path (degenerate inputs / uncovered widths).
    CpuSerial,
    /// Serial CPU path after the admitted backend failed.
    CpuFallback,
    /// A user-registered [`Executor`]'s own label.
    Custom(String),
}

impl BackendKind {
    pub fn is_pjrt(&self) -> bool {
        matches!(self, BackendKind::Pjrt { .. })
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, BackendKind::Sim { .. } | BackendKind::SimDialect { .. })
    }

    /// Either CPU path (serial or fallback).
    pub fn is_cpu(&self) -> bool {
        matches!(self, BackendKind::CpuSerial | BackendKind::CpuFallback)
    }

    pub fn is_fallback(&self) -> bool {
        matches!(self, BackendKind::CpuFallback)
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendKind::Pjrt { artifact } => write!(f, "pjrt:{artifact}"),
            BackendKind::Sim { family } => write!(f, "sim:{family}"),
            BackendKind::SimDialect { family, dialect } => write!(f, "sim:{dialect}:{family}"),
            BackendKind::CpuSerial => f.write_str("cpu-serial"),
            BackendKind::CpuFallback => f.write_str("cpu-fallback"),
            BackendKind::Custom(label) => f.write_str(label),
        }
    }
}

/// An executor's claim on an op: the typed backend tag (batching key and
/// metrics label) plus the plan-cache outcome, which the response echoes.
#[derive(Debug, Clone)]
pub struct Admission {
    pub backend: BackendKind,
    /// The plan-cache choice that routed this op (`None` for executors
    /// that bypass the cache, e.g. PJRT and the CPU paths).
    pub plan: Option<Plan>,
    /// Whether `plan` came from a cache hit (vs a fresh selection).
    pub cache_hit: bool,
}

/// A pluggable execution backend. Workers own a stack of executors in
/// priority order; the first [`Executor::admit`] wins and
/// [`Executor::execute`] serves the op. An `Err` from `execute` drops the
/// op to the serial CPU fallback — executors can fail without losing or
/// corrupting a response.
pub trait Executor {
    /// Diagnostic name (not the metrics label — that is the admission's
    /// [`BackendKind`]).
    fn name(&self) -> &'static str;

    /// Admission predicate: `Some` to claim `op` (already validated and
    /// non-null), `None` to pass it down the stack.
    fn admit(&mut self, op: &Op) -> Option<Admission>;

    /// Run an admitted op, returning the flat output values.
    fn execute(&mut self, op: &Op, adm: &Admission) -> Result<Vec<f32>, String>;
}

/// A queued background-tune request: the shape to refine and a zero-copy
/// handle on its sparse operand.
pub(crate) struct TuneTask {
    pub(crate) key: ShapeKey,
    pub(crate) handle: SparseHandle,
    pub(crate) width: u32,
}

/// Everything a worker offers its executors at construction time.
/// Factories receive `&ExecutorEnv` and may keep (cheap, `Arc`-backed)
/// clones of whatever they need.
#[derive(Clone)]
pub struct ExecutorEnv {
    pub(crate) hw: HwProfile,
    pub(crate) selector: Selector,
    pub(crate) model_select: bool,
    pub(crate) plan_cache: Arc<PlanCache>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) artifacts_dir: Option<PathBuf>,
    pub(crate) tune_tx: Option<SyncSender<TuneTask>>,
    /// The coordinator's online calibrator. Always present (the
    /// coordinator builds one even when calibration is disabled, so warm
    /// starts apply uniformly); `None` only in hand-built test envs.
    pub(crate) calibrator: Option<SharedCalibrator>,
    /// The device-buffer pool staging operand images across submits.
    /// `None` when pooling is disabled (`pool_budget_bytes: 0`) —
    /// executors then rebuild and "re-upload" per run, the pre-pool
    /// behavior.
    pub(crate) pool: Option<Arc<DevicePool>>,
    /// The codegen dialect this coordinator serves under; non-CUDA
    /// dialects surface in the simulator's backend labels.
    pub(crate) dialect: DialectKind,
}

impl ExecutorEnv {
    pub fn hw(&self) -> HwProfile {
        self.hw
    }

    pub fn selector(&self) -> Selector {
        self.selector
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn artifacts_dir(&self) -> Option<&PathBuf> {
        self.artifacts_dir.as_ref()
    }

    pub fn calibrator(&self) -> Option<&SharedCalibrator> {
        self.calibrator.as_ref()
    }

    pub fn pool(&self) -> Option<&Arc<DevicePool>> {
        self.pool.as_ref()
    }

    pub fn dialect(&self) -> DialectKind {
        self.dialect
    }

    /// Hand a shape to the background tuner (best-effort: a full refine
    /// queue just means the shape keeps its selector plan a little
    /// longer). The handle is an `Arc` bump — no operand clone.
    pub fn request_tune(&self, key: ShapeKey, handle: SparseHandle, width: u32) {
        if let Some(tx) = &self.tune_tx {
            let _ = tx.try_send(TuneTask { key, handle, width });
        }
    }
}

/// Builds one executor for a worker, or `None` when the backend is
/// unavailable in this environment (e.g. PJRT without artifacts).
pub type ExecutorFactory = Arc<dyn Fn(&ExecutorEnv) -> Option<Box<dyn Executor>> + Send + Sync>;

/// Wrap a closure as an [`ExecutorFactory`] (saves the `Arc`/`dyn`
/// annotations at call sites).
pub fn factory(
    f: impl Fn(&ExecutorEnv) -> Option<Box<dyn Executor>> + Send + Sync + 'static,
) -> ExecutorFactory {
    Arc::new(f)
}

/// An ordered set of executor factories — the coordinator's pluggable
/// backend configuration. Earlier entries have admission priority.
#[derive(Clone)]
pub struct ExecutorRegistry {
    factories: Vec<ExecutorFactory>,
}

impl ExecutorRegistry {
    /// The standard stack: PJRT (when artifacts are configured), the
    /// plan-cache simulator, then the serial CPU catch-all.
    pub fn standard() -> ExecutorRegistry {
        ExecutorRegistry { factories: vec![pjrt_factory(), sim_factory(), cpu_factory()] }
    }

    /// No backends at all — for fully custom stacks. An op no executor
    /// admits is answered with an error, so most stacks should end with
    /// [`cpu_factory`].
    pub fn empty() -> ExecutorRegistry {
        ExecutorRegistry { factories: Vec::new() }
    }

    /// Append a factory at the lowest priority.
    pub fn push(&mut self, f: ExecutorFactory) {
        self.factories.push(f);
    }

    /// A copy of this registry with `f` at the *highest* priority — how a
    /// custom backend outbids the standard stack.
    pub fn with_front(mut self, f: ExecutorFactory) -> ExecutorRegistry {
        self.factories.insert(0, f);
        self
    }

    pub fn len(&self) -> usize {
        self.factories.len()
    }

    pub fn is_empty(&self) -> bool {
        self.factories.is_empty()
    }

    /// Instantiate the stack for one worker.
    pub(crate) fn build(&self, env: &ExecutorEnv) -> Vec<Box<dyn Executor>> {
        self.factories.iter().filter_map(|f| f(env)).collect()
    }
}

impl Default for ExecutorRegistry {
    fn default() -> ExecutorRegistry {
        ExecutorRegistry::standard()
    }
}

impl fmt::Debug for ExecutorRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ExecutorRegistry({} factories)", self.factories.len())
    }
}

/// Factory for [`PjrtExecutor`] — yields `None` (worker degrades to the
/// rest of the stack) when the `pjrt` feature is off, no artifacts
/// directory is configured, or the runtime fails to come up.
pub fn pjrt_factory() -> ExecutorFactory {
    factory(|env| {
        if !Runtime::available() {
            return None;
        }
        let dir = env.artifacts_dir.as_ref()?;
        let rt = Runtime::load(dir).ok()?;
        let exec = PjrtExecutor { rt, pool: env.pool.clone(), metrics: env.metrics.clone() };
        Some(Box::new(exec) as Box<dyn Executor>)
    })
}

/// Factory for [`SimExecutor`].
pub fn sim_factory() -> ExecutorFactory {
    factory(|env| Some(Box::new(SimExecutor::new(env)) as Box<dyn Executor>))
}

/// Factory for [`CpuExecutor`].
pub fn cpu_factory() -> ExecutorFactory {
    factory(|_| Some(Box::new(CpuExecutor) as Box<dyn Executor>))
}

/// PJRT artifact execution (the numeric hot path). Each worker owns its
/// own [`Runtime`] — the client is `!Send` and the executable cache
/// stays hot per worker. With a device pool configured, the padded
/// COO/dense images are staged once per (handle, bucket) and repeats
/// skip the `pad_coo`/`pad_dense` rebuild and re-upload entirely.
pub struct PjrtExecutor {
    rt: Runtime,
    pool: Option<Arc<DevicePool>>,
    metrics: Arc<Metrics>,
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn admit(&mut self, op: &Op) -> Option<Admission> {
        if op.kind != OpKind::Spmm || op.degenerate() {
            return None;
        }
        let a = op.a.as_matrix()?;
        let spec = self.rt.registry.route(ArtifactKind::SpmmNnzSr, a.rows, a.cols, a.nnz())?;
        if spec.n != op.width {
            return None;
        }
        Some(Admission {
            backend: BackendKind::Pjrt { artifact: spec.name.clone() },
            plan: None,
            cache_hit: false,
        })
    }

    fn execute(&mut self, op: &Op, adm: &Admission) -> Result<Vec<f32>, String> {
        let BackendKind::Pjrt { artifact } = &adm.backend else {
            return Err("pjrt executor given a non-pjrt admission".into());
        };
        let a = op.a.as_matrix().ok_or("pjrt admitted a non-matrix op")?;
        let Some(pool) = self.pool.clone() else {
            return self.rt.run_spmm_nnz(artifact, a, &op.dense[0]).map_err(|e| e.to_string());
        };
        // Stage the padded images under keys salted with the bucket name:
        // the same handle served by two buckets pads differently, so each
        // (handle, bucket) pairing gets its own page. Resubmits hit.
        let spec = self.rt.registry.get(artifact).map_err(|e| e.to_string())?.clone();
        let salt = fnv_str(artifact);
        let sref = pool
            .try_acquire(op.a.pool_key().salted(salt), || Ok(DeviceImage::Coo(pad_coo(a, &spec)?)))
            .map_err(|e| e.to_string())?;
        let b = &op.dense[0];
        let bref = pool
            .try_acquire(b.pool_key().salted(salt), || {
                Ok(DeviceImage::Dense(pad_dense(b, a.cols, spec.n, spec.cols)))
            })
            .map_err(|e| e.to_string())?;
        for r in [&sref, &bref] {
            if r.hit() {
                self.metrics.on_pool_hit();
            } else {
                self.metrics.on_pool_miss();
            }
        }
        self.metrics.set_pool_bytes(pool.stats().bytes_resident as u64);
        let (DeviceImage::Coo(coo), DeviceImage::Dense(bp)) = (sref.image(), bref.image()) else {
            return Err("pjrt staged image kind mismatch".into());
        };
        self.rt.run_spmm_nnz_staged(artifact, coo, bp, a.rows).map_err(|e| e.to_string())
    }
}

/// FNV-1a over a label — the salt distinguishing per-bucket stagings.
fn fnv_str(s: &str) -> u64 {
    s.bytes().fold(0xcbf2_9ce4_8422_2325, |h, b| crate::runtime::pool::fnv_mix(h, b as u64))
}

/// Plan-cache + SIMT-simulator execution: the tuner-aware default path.
/// Admission consults the cache — a miss runs the selector (the analytic
/// model argmin when configured) and enqueues a background refinement; a
/// hit reuses the cached plan at zero selection cost.
///
/// This executor is also the calibration loop's sensor: every simulated
/// run hands its measured time, the plan, and the op's cached stats to
/// the coordinator's [`OnlineCalibrator`](super::OnlineCalibrator), and
/// the cached machine/model are rebuilt whenever the calibrator's
/// generation moves (a refit or warm start elsewhere).
pub struct SimExecutor {
    machine: Machine,
    model: Option<CostModel>,
    /// Calibrator generation `machine`/`model` were built from.
    generation: u64,
    env: ExecutorEnv,
}

impl SimExecutor {
    pub fn new(env: &ExecutorEnv) -> SimExecutor {
        let (machine, generation) = match &env.calibrator {
            Some(c) => (c.machine(), c.generation()),
            None => (Machine::new(env.hw), 0),
        };
        let model = make_model(env.model_select, &machine, generation);
        SimExecutor { machine, model, generation, env: env.clone() }
    }

    /// Pick up a refit: rebuild the cached machine + model when the
    /// calibrator's generation has moved since ours were built.
    fn refresh(&mut self) {
        if let Some(c) = &self.env.calibrator {
            let g = c.generation();
            if g != self.generation {
                self.machine = c.machine();
                self.model = make_model(self.env.model_select, &self.machine, g);
                self.generation = g;
            }
        }
    }

    /// Feed one served op into the drift tracker (no-op without a
    /// calibrator or with calibration disabled).
    fn note_latency(&self, op: &Op, algo: crate::algos::catalog::Algo, measured_s: f64) {
        let Some(cal) = &self.env.calibrator else { return };
        if !cal.config().enabled {
            return;
        }
        let Some(spec) = workload_spec(op) else { return };
        let model = self.model.unwrap_or_else(|| CostModel::new(&self.machine));
        let Some(predicted) = model.price(&algo, &spec.workload()) else { return };
        cal.observe(
            op.kind,
            Sample::new(algo, spec, measured_s),
            predicted,
            &self.env.metrics,
            &self.env.plan_cache,
        );
    }

    /// Pin the op's operand images in the device pool for the run —
    /// repeats of the same handles hit and skip the "upload" (the clone
    /// into a [`DeviceImage`]). Returns `None` when pooling is disabled;
    /// the refs are held across the simulated launch the way real device
    /// buffers stay resident, then released on drop.
    fn stage(&self, op: &Op) -> Option<Vec<PoolRef>> {
        let pool = self.env.pool.as_ref()?;
        let mut refs = Vec::with_capacity(1 + op.dense.len());
        refs.push(pool.acquire(op.a.pool_key(), || match op.a.data() {
            SparseData::Matrix(m) => DeviceImage::of_matrix(m),
            SparseData::Tensor(t) => DeviceImage::of_tensor(t),
        }));
        for d in &op.dense {
            refs.push(pool.acquire(d.pool_key(), || DeviceImage::Dense(d.as_slice().to_vec())));
        }
        for r in &refs {
            if r.hit() {
                self.env.metrics.on_pool_hit();
            } else {
                self.env.metrics.on_pool_miss();
            }
        }
        self.env.metrics.set_pool_bytes(pool.stats().bytes_resident as u64);
        Some(refs)
    }
}

fn make_model(model_select: bool, machine: &Machine, generation: u64) -> Option<CostModel> {
    if !model_select {
        return None;
    }
    let mut m = CostModel::new(machine);
    m.calib_generation = generation;
    Some(m)
}

/// The op's features as an owned [`WorkloadSpec`] — cloned from the
/// handle's cached stats, so no fingerprint pass re-runs here.
fn workload_spec(op: &Op) -> Option<WorkloadSpec> {
    let w = op.width as u32;
    match op.kind {
        OpKind::Spmm => Some(WorkloadSpec::Spmm { stats: op.a.matrix_stats()?.clone(), n: w }),
        OpKind::Sddmm => Some(WorkloadSpec::Sddmm { stats: op.a.matrix_stats()?.clone(), j: w }),
        OpKind::FusedSddmmSpmm => {
            let (j, n) = op.fused_widths();
            Some(WorkloadSpec::Fused {
                stats: op.a.matrix_stats()?.clone(),
                j: j as u32,
                n: n as u32,
            })
        }
        OpKind::Mttkrp => {
            Some(WorkloadSpec::Mttkrp { seg: *op.a.seg_stats(OpKind::Mttkrp)?, j: w })
        }
        OpKind::Ttm => Some(WorkloadSpec::Ttm { seg: *op.a.seg_stats(OpKind::Ttm)?, l: w }),
    }
}

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn admit(&mut self, op: &Op) -> Option<Admission> {
        if op.degenerate() {
            return None;
        }
        self.refresh();
        let key = op.shape_key()?;
        // One generic cache consult for the whole quartet. The selector
        // closure only runs on a miss (repeats cost a hash lookup); a
        // `None` selection means no legal launch shape covers the width —
        // the op is declined, untouched by cache statistics, and falls to
        // the CPU executor.
        let (plan, hit, warm) = self
            .env
            .plan_cache
            .try_get_or_insert_traced(key, || op.select(&self.env.selector, self.model.as_ref()))?;
        if hit {
            self.env.metrics.on_cache_hit();
            if warm {
                self.env.metrics.on_warm_hit();
            }
        } else {
            self.env.metrics.on_cache_miss();
            self.env.request_tune(key, op.a.clone(), op.width as u32);
        }
        if plan.kind.is_composite() {
            self.env.metrics.on_banded();
        }
        let family = plan.kind.family_label();
        let backend = match self.env.dialect {
            DialectKind::Cuda => BackendKind::Sim { family },
            d => BackendKind::SimDialect { family, dialect: d },
        };
        Some(Admission { backend, plan: Some(plan), cache_hit: hit })
    }

    fn execute(&mut self, op: &Op, adm: &Admission) -> Result<Vec<f32>, String> {
        let plan = adm.plan.ok_or("sim executor needs an admitted plan")?;
        let algo = plan.kind;
        // A colliding fingerprint could hand an op a plan from another
        // algebra; decline (→ CPU fallback) rather than guess a kernel.
        if !op.kind.compatible(&algo) {
            return Err(format!("plan {} cannot serve a {} op", algo.name(), op.kind));
        }
        let _staged = self.stage(op);
        let res = match op.kind {
            OpKind::Spmm => {
                let a = op.a.as_matrix().ok_or("sim admitted a non-matrix spmm op")?;
                algo.run(&self.machine, a, &op.dense[0], op.width as u32)
            }
            OpKind::Sddmm => {
                let a = op.a.as_matrix().ok_or("sim admitted a non-matrix sddmm op")?;
                algo.run_sddmm(&self.machine, a, &op.dense[0], &op.dense[1])
            }
            OpKind::Mttkrp => {
                let a = op.a.as_tensor().ok_or("sim admitted a non-tensor mttkrp op")?;
                algo.run_mttkrp(&self.machine, a, &op.dense[0], &op.dense[1])
            }
            OpKind::Ttm => {
                let a = op.a.as_tensor().ok_or("sim admitted a non-tensor ttm op")?;
                algo.run_ttm(&self.machine, a, &op.dense[0])
            }
            OpKind::FusedSddmmSpmm => {
                let a = op.a.as_matrix().ok_or("sim admitted a non-matrix fused op")?;
                algo.run_fused(&self.machine, a, &op.dense[0], &op.dense[1], &op.dense[2])
            }
        };
        let res = res.map_err(|e| e.to_string())?;
        // Close the loop: the simulated time is this backend's measured
        // latency — feed it to the drift tracker before answering.
        self.note_latency(op, algo, res.time_s);
        Ok(res.run.c)
    }
}

/// The serial last resort: admits every op and runs the CPU oracle.
/// Degenerate inputs and widths no kernel launch shape covers land here
/// — correctly, without touching the plan cache.
pub struct CpuExecutor;

impl Executor for CpuExecutor {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn admit(&mut self, _op: &Op) -> Option<Admission> {
        Some(Admission { backend: BackendKind::CpuSerial, plan: None, cache_hit: false })
    }

    fn execute(&mut self, op: &Op, _adm: &Admission) -> Result<Vec<f32>, String> {
        Ok(op.run_serial())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_labels_are_stable() {
        assert_eq!(BackendKind::Pjrt { artifact: "spmm_a".into() }.to_string(), "pjrt:spmm_a");
        assert_eq!(BackendKind::Sim { family: "sgap-nnz-group" }.to_string(), "sim:sgap-nnz-group");
        let hip = BackendKind::SimDialect { family: "sgap-nnz-group", dialect: DialectKind::Hip };
        assert_eq!(hip.to_string(), "sim:hip:sgap-nnz-group");
        assert!(hip.is_sim() && !hip.is_cpu());
        assert_eq!(BackendKind::CpuSerial.to_string(), "cpu-serial");
        assert_eq!(BackendKind::CpuFallback.to_string(), "cpu-fallback");
        assert_eq!(BackendKind::Custom("fpga:v1".into()).to_string(), "fpga:v1");
    }

    #[test]
    fn backend_predicates() {
        let sim = BackendKind::Sim { family: "sddmm-group" };
        assert!(sim.is_sim() && !sim.is_cpu() && !sim.is_pjrt());
        assert!(BackendKind::CpuSerial.is_cpu() && !BackendKind::CpuSerial.is_fallback());
        assert!(BackendKind::CpuFallback.is_cpu() && BackendKind::CpuFallback.is_fallback());
        assert!(BackendKind::Pjrt { artifact: "x".into() }.is_pjrt());
    }

    #[test]
    fn registry_default_is_the_standard_stack() {
        let reg = ExecutorRegistry::default();
        assert_eq!(reg.len(), 3);
        assert!(!reg.is_empty());
        let reg = reg.with_front(cpu_factory());
        assert_eq!(reg.len(), 4);
        assert!(ExecutorRegistry::empty().is_empty());
        assert_eq!(format!("{:?}", ExecutorRegistry::standard()), "ExecutorRegistry(3 factories)");
    }
}
