//! Dialect-parameterized back ends — one LLIR walk, three targets.
//!
//! The §5.3 macro instructions (`atomicAddGroup`/`segReduceGroup`) are
//! *semantic* reduction primitives; what varies per GPU target is only
//! their **spelling**: which shuffle intrinsic implements the tree
//! reduce / segmented scan, how a float atomic add is written, what the
//! kernel signature and qualifiers look like, and which helper prologue
//! the translation unit needs. Following the `WarpInstruction<D: Dialect>`
//! idiom from kubecl's `cubecl-cpp` (see SNIPPETS.md), every such
//! spelling lives behind the [`Dialect`] trait, and the single generic
//! walk in [`emit`] turns a [`Kernel`](crate::compiler::llir::Kernel)
//! into source text for any of the three implementations:
//!
//! * [`Cuda`] — the original back end, byte-identical to what
//!   `codegen_cuda` emitted before this module existed (the committed
//!   `.cu` goldens pin this).
//! * [`Hip`] — same C++ body; the helper templates drop the lane-mask
//!   (`__activemask`/`_sync`) forms, which AMD wavefronts don't have.
//! * [`Wgsl`] — structurally different spellings: storage-buffer
//!   bindings instead of pointer parameters, `override` scalars,
//!   CAS-loop float atomics, and lane-guarded subgroup shuffles (WGSL
//!   subgroup ops take no width argument — see DESIGN.md §dialects).
//!
//! [`DialectKind`] is the runtime tag for CLI/config dispatch
//! (`sgap codegen --dialect cuda|hip|wgsl`).

pub mod cuda;
pub mod emit;
pub mod hip;
pub mod wgsl;

use std::fmt;

pub use cuda::Cuda;
pub use emit::EmitCtx;
pub use hip::Hip;
pub use wgsl::Wgsl;

use super::llir::Kernel;

/// Every target-specific spelling the generic emitter consults. The
/// loop/branch structure, expression nesting, operators, indentation,
/// and comments are shared by the walk in [`emit`]; a dialect only
/// decides how declarations, stores, reductions, builtins, and the
/// surrounding translation unit are written.
pub trait Dialect {
    /// Lowercase dialect name — the `--dialect` CLI value and the
    /// dialect-qualified backend label suffix.
    const NAME: &'static str;
    /// Source-file extension for emitted kernels (`cu`, `hip`, `wgsl`).
    const FILE_EXT: &'static str;

    /// Translation-unit prologue: includes/directives plus the helper
    /// definitions `cx` says the kernel actually references — only those
    /// (a pure-store kernel gets no reduction templates at all). Empty
    /// means the translation unit is the bare kernel.
    fn prologue(cx: &EmitCtx) -> String;

    /// Kernel signature up to and including the opening `{` (multi-line
    /// for targets that declare bindings at module scope).
    fn kernel_open(k: &Kernel, cx: &EmitCtx) -> String;

    /// The final token closing the kernel body.
    fn kernel_close() -> &'static str {
        "}"
    }

    /// `int`/`float` declaration-with-initializer statement.
    fn decl(var: &str, float: bool, init: &str) -> String;

    /// Plain global store.
    fn store(array: &str, idx: &str, val: &str) -> String {
        format!("{array}[{idx}] = {val};")
    }

    /// Plain (non-grouped) float atomic add.
    fn atomic_add(array: &str, idx: &str, val: &str) -> String;

    /// §5.3 `atomicAddGroup` call site.
    fn atomic_add_group(array: &str, idx: &str, val: &str, group: u32) -> String;

    /// §5.3 `segReduceGroup` call site.
    fn seg_reduce_group(array: &str, idx: &str, val: &str, group: u32) -> String;

    /// Counted-loop header up to and including the opening `{`.
    fn for_open(var: &str, lo: &str, hi: &str, step: &str) -> String;

    /// Typed float literal.
    fn const_f32(c: f32) -> String;

    /// The lane id within the workgroup/block (TACO's `threadIdx.x`).
    fn thread_idx() -> &'static str;

    /// The workgroup/block id (TACO's `blockIdx.x`).
    fn block_idx() -> &'static str;

    /// TACO's `taco_binarySearchBefore` row-search call site.
    fn binary_search(array: &str, lo: &str, hi: &str, target: &str) -> String {
        format!("taco_binarySearchBefore({array}, {lo}, {hi}, {target})")
    }
}

/// Runtime dialect tag — the value-level mirror of the [`Dialect`]
/// type parameter, for CLI flags, config fields, and backend labels.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum DialectKind {
    #[default]
    Cuda,
    Hip,
    Wgsl,
}

impl DialectKind {
    /// Every dialect the emitter speaks, in CLI/docs order.
    pub const ALL: [DialectKind; 3] = [DialectKind::Cuda, DialectKind::Hip, DialectKind::Wgsl];

    /// Parse a `--dialect` flag value (case-insensitive).
    pub fn parse(s: &str) -> Option<DialectKind> {
        DialectKind::ALL.into_iter().find(|d| d.name().eq_ignore_ascii_case(s))
    }

    pub fn name(self) -> &'static str {
        match self {
            DialectKind::Cuda => Cuda::NAME,
            DialectKind::Hip => Hip::NAME,
            DialectKind::Wgsl => Wgsl::NAME,
        }
    }

    pub fn file_ext(self) -> &'static str {
        match self {
            DialectKind::Cuda => Cuda::FILE_EXT,
            DialectKind::Hip => Hip::FILE_EXT,
            DialectKind::Wgsl => Wgsl::FILE_EXT,
        }
    }

    /// Emit the bare kernel in this dialect.
    pub fn emit_kernel(self, k: &Kernel) -> String {
        match self {
            DialectKind::Cuda => emit::emit_kernel::<Cuda>(k),
            DialectKind::Hip => emit::emit_kernel::<Hip>(k),
            DialectKind::Wgsl => emit::emit_kernel::<Wgsl>(k),
        }
    }

    /// Emit prologue + kernel in this dialect.
    pub fn emit_translation_unit(self, k: &Kernel) -> String {
        match self {
            DialectKind::Cuda => emit::emit_translation_unit::<Cuda>(k),
            DialectKind::Hip => emit::emit_translation_unit::<Hip>(k),
            DialectKind::Wgsl => emit::emit_translation_unit::<Wgsl>(k),
        }
    }
}

impl fmt::Display for DialectKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_names() {
        for d in DialectKind::ALL {
            assert_eq!(DialectKind::parse(d.name()), Some(d));
            assert_eq!(DialectKind::parse(&d.name().to_uppercase()), Some(d));
        }
        assert_eq!(DialectKind::parse("metal"), None);
        assert_eq!(DialectKind::default(), DialectKind::Cuda);
        assert_eq!(DialectKind::Hip.to_string(), "hip");
        assert_eq!(DialectKind::ALL.map(DialectKind::file_ext), ["cu", "hip", "wgsl"]);
    }
}
