//! The CUDA spelling — the original back end, now one [`Dialect`].
//!
//! Byte-compatibility contract: `emit_kernel::<Cuda>` reproduces the
//! pre-dialect `codegen_cuda::emit_kernel` output exactly (the committed
//! `rust/tests/golden/*.cu` files pin it), and [`macro_header`] is the
//! unchanged §5.3 header literal (pinned by `macro_header.cu`). The
//! header is decomposed into [`BANNER`]/[`ATOMIC_ADD_GROUP_DEF`]/
//! [`SEG_REDUCE_GROUP_DEF`]/[`FOOTER`] so the prologue can emit only the
//! helper a kernel actually references; a unit test asserts the parts
//! reassemble into the literal.

use super::super::llir::{Kernel, Param, ParamKind};
use super::emit::EmitCtx;
use super::Dialect;

/// Header banner line shared by every non-empty CUDA prologue.
pub(crate) const BANNER: &str =
    "// --- sgap macro instructions (§5.3) ------------------------------------\n";

/// The `atomicAddGroup<T,G>` device-function template (§5.3).
pub(crate) const ATOMIC_ADD_GROUP_DEF: &str = r#"// atomicAddGroup<T,G>: tree-reduce `value` over each aligned G-lane group
// with __shfl_down_sync, then lane 0 of the group issues one atomicAdd.
template <typename T, int G>
__device__ __forceinline__ void atomicAddGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  #pragma unroll
  for (int offset = G / 2; offset > 0; offset /= 2)
    value += __shfl_down_sync(mask, value, offset, G);
  if ((threadIdx.x % G) == 0) atomicAdd(&array[idx], value);
}
"#;

/// The `segReduceGroup<T,G>` device-function template (§5.3).
pub(crate) const SEG_REDUCE_GROUP_DEF: &str = r#"// segReduceGroup<T,G>: segmented inclusive scan over each aligned G-lane
// group keyed by `idx`; segment-end lanes write back (runtime-decided
// writeback threads — segment reduction).
template <typename T, int G>
__device__ __forceinline__ void segReduceGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  int lane = threadIdx.x % G;
  #pragma unroll
  for (int offset = 1; offset < G; offset *= 2) {
    T up = __shfl_up_sync(mask, value, offset, G);
    int upIdx = __shfl_up_sync(mask, idx, offset, G);
    if (lane >= offset && upIdx == idx) value += up;
  }
  int dnIdx = __shfl_down_sync(mask, idx, 1, G);
  if (lane == G - 1 || dnIdx != idx) atomicAdd(&array[idx], value);
}
"#;

/// Header footer line.
pub(crate) const FOOTER: &str =
    "// ------------------------------------------------------------------------\n";

/// The full §5.3 macro-instruction header (cooperative-groups
/// implementation) — both templates, unconditionally. Kept for the
/// `sgap macros` subcommand and the `macro_header.cu` golden; the
/// translation-unit prologue instead emits only the referenced subset.
pub fn macro_header() -> &'static str {
    r#"// --- sgap macro instructions (§5.3) ------------------------------------
// atomicAddGroup<T,G>: tree-reduce `value` over each aligned G-lane group
// with __shfl_down_sync, then lane 0 of the group issues one atomicAdd.
template <typename T, int G>
__device__ __forceinline__ void atomicAddGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  #pragma unroll
  for (int offset = G / 2; offset > 0; offset /= 2)
    value += __shfl_down_sync(mask, value, offset, G);
  if ((threadIdx.x % G) == 0) atomicAdd(&array[idx], value);
}

// segReduceGroup<T,G>: segmented inclusive scan over each aligned G-lane
// group keyed by `idx`; segment-end lanes write back (runtime-decided
// writeback threads — segment reduction).
template <typename T, int G>
__device__ __forceinline__ void segReduceGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  int lane = threadIdx.x % G;
  #pragma unroll
  for (int offset = 1; offset < G; offset *= 2) {
    T up = __shfl_up_sync(mask, value, offset, G);
    int upIdx = __shfl_up_sync(mask, idx, offset, G);
    if (lane >= offset && upIdx == idx) value += up;
  }
  int dnIdx = __shfl_down_sync(mask, idx, 1, G);
  if (lane == G - 1 || dnIdx != idx) atomicAdd(&array[idx], value);
}
// ------------------------------------------------------------------------
"#
}

pub(crate) fn param_decl(p: &Param) -> String {
    match p.kind {
        ParamKind::ArrayF32 => format!("float* __restrict__ {}", p.name),
        ParamKind::ArrayI32 => format!("int* __restrict__ {}", p.name),
        ParamKind::ScalarI32 => format!("int {}", p.name),
    }
}

/// The CUDA dialect (NVIDIA warp intrinsics, `_sync` + lane-mask forms).
pub struct Cuda;

impl Dialect for Cuda {
    const NAME: &'static str = "cuda";
    const FILE_EXT: &'static str = "cu";

    fn prologue(cx: &EmitCtx) -> String {
        let atomic = !cx.atomic_groups.is_empty();
        let seg = !cx.seg_groups.is_empty();
        if !atomic && !seg {
            return String::new();
        }
        let mut s = String::from(BANNER);
        if atomic {
            s.push_str(ATOMIC_ADD_GROUP_DEF);
        }
        if atomic && seg {
            s.push('\n');
        }
        if seg {
            s.push_str(SEG_REDUCE_GROUP_DEF);
        }
        s.push_str(FOOTER);
        s
    }

    fn kernel_open(k: &Kernel, _cx: &EmitCtx) -> String {
        let params: Vec<String> = k.params.iter().map(param_decl).collect();
        format!("__global__ void {}({}) {{", k.name, params.join(", "))
    }

    fn decl(var: &str, float: bool, init: &str) -> String {
        let ty = if float { "float" } else { "int" };
        format!("{ty} {var} = {init};")
    }

    fn atomic_add(array: &str, idx: &str, val: &str) -> String {
        format!("atomicAdd(&{array}[{idx}], {val});")
    }

    fn atomic_add_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        format!("atomicAddGroup<float,{group}>({array}, {idx}, {val});")
    }

    fn seg_reduce_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        format!("segReduceGroup<float,{group}>({array}, {idx}, {val});")
    }

    fn for_open(var: &str, lo: &str, hi: &str, step: &str) -> String {
        format!("for (int {var} = {lo}; {var} < {hi}; {var} += {step}) {{")
    }

    fn const_f32(c: f32) -> String {
        format!("{c:?}f")
    }

    fn thread_idx() -> &'static str {
        "threadIdx.x"
    }

    fn block_idx() -> &'static str {
        "blockIdx.x"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The conditional prologue is a decomposition of the pinned header
    /// literal — with both helpers referenced, the parts reassemble into
    /// `macro_header()` byte-for-byte.
    #[test]
    fn header_parts_reassemble() {
        let both = [BANNER, ATOMIC_ADD_GROUP_DEF, "\n", SEG_REDUCE_GROUP_DEF, FOOTER].concat();
        assert_eq!(both, macro_header());

        let mut cx = EmitCtx::default();
        cx.atomic_groups.insert(8);
        cx.seg_groups.insert(32);
        assert_eq!(Cuda::prologue(&cx), macro_header());
    }

    #[test]
    fn prologue_is_conditional_per_helper() {
        let mut seg_only = EmitCtx::default();
        seg_only.seg_groups.insert(32);
        let p = Cuda::prologue(&seg_only);
        assert!(p.contains("segReduceGroup") && !p.contains("atomicAddGroup"));
        assert!(p.starts_with(BANNER) && p.ends_with(FOOTER));

        let mut atomic_only = EmitCtx::default();
        atomic_only.atomic_groups.insert(8);
        let p = Cuda::prologue(&atomic_only);
        assert!(p.contains("atomicAddGroup") && !p.contains("segReduceGroup"));

        // Plain atomicAdd is a native CUDA builtin — no helper needed.
        let plain = EmitCtx { uses_atomic_add: true, ..EmitCtx::default() };
        assert!(Cuda::prologue(&plain).is_empty());
    }
}
