//! The HIP/ROCm spelling. The kernel body is source-compatible C++ —
//! identical bytes to the CUDA emission — so this dialect delegates
//! every body hook to [`Cuda`] and differs only in the translation-unit
//! prologue: the `<hip/hip_runtime.h>` include, and §5.3 helper
//! templates built on the maskless `__shfl_up`/`__shfl_down` width
//! forms. AMD wavefronts (64-wide on CDNA/GCN) have no independent
//! per-lane-mask synchronization, so HIP has no `__activemask()` /
//! `*_sync` shuffle variants; the width argument `G` windows the
//! shuffle exactly as on NVIDIA.

use super::super::llir::Kernel;
use super::cuda::Cuda;
use super::emit::EmitCtx;
use super::Dialect;

const INCLUDE: &str = "#include <hip/hip_runtime.h>\n";

const BANNER: &str =
    "// --- sgap macro instructions (§5.3), HIP spelling -----------------------\n";

const ATOMIC_ADD_GROUP_DEF: &str = r#"// atomicAddGroup<T,G>: tree-reduce `value` over each aligned G-lane group
// with __shfl_down, then lane 0 of the group issues one atomicAdd. No
// lane mask: AMD wavefronts have no independent per-lane-mask sync.
template <typename T, int G>
__device__ __forceinline__ void atomicAddGroup(T* array, int idx, T value) {
  #pragma unroll
  for (int offset = G / 2; offset > 0; offset /= 2)
    value += __shfl_down(value, offset, G);
  if ((threadIdx.x % G) == 0) atomicAdd(&array[idx], value);
}
"#;

const SEG_REDUCE_GROUP_DEF: &str = r#"// segReduceGroup<T,G>: segmented inclusive scan over each aligned G-lane
// group keyed by `idx`; segment-end lanes write back (runtime-decided
// writeback threads — segment reduction).
template <typename T, int G>
__device__ __forceinline__ void segReduceGroup(T* array, int idx, T value) {
  int lane = threadIdx.x % G;
  #pragma unroll
  for (int offset = 1; offset < G; offset *= 2) {
    T up = __shfl_up(value, offset, G);
    int upIdx = __shfl_up(idx, offset, G);
    if (lane >= offset && upIdx == idx) value += up;
  }
  int dnIdx = __shfl_down(idx, 1, G);
  if (lane == G - 1 || dnIdx != idx) atomicAdd(&array[idx], value);
}
"#;

const FOOTER: &str =
    "// ------------------------------------------------------------------------\n";

/// The HIP dialect (AMD ROCm; maskless width-windowed shuffles).
pub struct Hip;

impl Dialect for Hip {
    const NAME: &'static str = "hip";
    const FILE_EXT: &'static str = "hip";

    fn prologue(cx: &EmitCtx) -> String {
        let atomic = !cx.atomic_groups.is_empty();
        let seg = !cx.seg_groups.is_empty();
        let mut s = String::from(INCLUDE);
        if !atomic && !seg {
            return s;
        }
        s.push('\n');
        s.push_str(BANNER);
        if atomic {
            s.push_str(ATOMIC_ADD_GROUP_DEF);
        }
        if atomic && seg {
            s.push('\n');
        }
        if seg {
            s.push_str(SEG_REDUCE_GROUP_DEF);
        }
        s.push_str(FOOTER);
        s
    }

    fn kernel_open(k: &Kernel, cx: &EmitCtx) -> String {
        Cuda::kernel_open(k, cx)
    }

    fn decl(var: &str, float: bool, init: &str) -> String {
        Cuda::decl(var, float, init)
    }

    fn atomic_add(array: &str, idx: &str, val: &str) -> String {
        Cuda::atomic_add(array, idx, val)
    }

    fn atomic_add_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        Cuda::atomic_add_group(array, idx, val, group)
    }

    fn seg_reduce_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        Cuda::seg_reduce_group(array, idx, val, group)
    }

    fn for_open(var: &str, lo: &str, hi: &str, step: &str) -> String {
        Cuda::for_open(var, lo, hi, step)
    }

    fn const_f32(c: f32) -> String {
        Cuda::const_f32(c)
    }

    fn thread_idx() -> &'static str {
        Cuda::thread_idx()
    }

    fn block_idx() -> &'static str {
        Cuda::block_idx()
    }
}

#[cfg(test)]
mod tests {
    use super::super::emit::emit_kernel;
    use super::*;

    #[test]
    fn hip_body_is_byte_identical_to_cuda() {
        use crate::compiler::schedule::{Schedule, SpmmConfig};
        let k = crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap();
        assert_eq!(emit_kernel::<Hip>(&k), emit_kernel::<Cuda>(&k));
    }

    #[test]
    fn hip_prologue_has_no_mask_forms() {
        let mut cx = EmitCtx::default();
        cx.atomic_groups.insert(8);
        cx.seg_groups.insert(32);
        let p = Hip::prologue(&cx);
        assert!(p.starts_with(INCLUDE));
        assert!(p.contains("__shfl_down(value, offset, G)"));
        assert!(p.contains("__shfl_up(value, offset, G)"));
        assert!(!p.contains("_sync") && !p.contains("__activemask"));

        // Helper-free kernels still get the runtime include, nothing else.
        assert_eq!(Hip::prologue(&EmitCtx::default()), INCLUDE);
    }
}
