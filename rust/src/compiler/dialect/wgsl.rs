//! The WGSL (WebGPU) spelling — the target that actually exercises the
//! dialect abstraction, because almost every spelling differs:
//!
//! * Kernels take no pointer parameters. Array params become module-scope
//!   `@group(0) @binding(n)` storage buffers (access mode derived from
//!   the [`EmitCtx`] write classification) and scalar params become
//!   `override` pipeline constants.
//! * WGSL has no `f32` atomics: reduction outputs bind as
//!   `array<atomic<u32>>` and every float atomic add goes through a
//!   bitcast CAS loop (`atomicAddF32`).
//! * WGSL subgroup shuffles (`enable subgroups;`) take **no width
//!   argument** and there is no independent sub-warp synchronization or
//!   lane mask (`__activemask` has no analogue). The §5.3 group
//!   primitives therefore window the full-subgroup shuffles with lane
//!   guards computed from the thread id — correct exactly when the
//!   subgroup size is a multiple of the group size `G`, which holds for
//!   the paper's `G ∈ {2,4,8,16,32}` on 32/64-wide hardware. This is why
//!   the segment-scan emission *changes shape* here rather than merely
//!   renaming an intrinsic — see DESIGN.md §dialects.
//! * Helpers take `ptr<storage, ...>` parameters, which needs the
//!   `unrestricted_pointer_parameters` language extension.

use std::fmt::Write as _;

use super::super::llir::{Kernel, ParamKind};
use super::emit::EmitCtx;
use super::Dialect;

const BANNER: &str =
    "// --- sgap macro instructions (§5.3), WGSL spelling ----------------------\n";

const FOOTER: &str =
    "// ------------------------------------------------------------------------\n";

const ATOMIC_ADD_F32_DEF: &str = r#"// atomicAddF32: WGSL has no float atomics — emulate atomicAdd on an
// f32 cell stored as atomic<u32> with a bitcast compare-exchange loop.
fn atomicAddF32(a: ptr<storage, array<atomic<u32>>, read_write>, idx: i32, value: f32) {
  var bits: u32 = atomicLoad(&(*a)[idx]);
  loop {
    let updated: u32 = bitcast<u32>(bitcast<f32>(bits) + value);
    let r = atomicCompareExchangeWeak(&(*a)[idx], bits, updated);
    if (r.exchanged) { break; }
    bits = r.old_value;
  }
}
"#;

const BINARY_SEARCH_DEF: &str = r#"// taco_binarySearchBefore: largest i in [lo, hi] with a[i] <= target
// (TACO's device helper, Listing 1's row search).
fn taco_binarySearchBefore(a: ptr<storage, array<i32>, read>, lo: i32, hi: i32, target: i32) -> i32 {
  if ((*a)[hi] <= target) { return hi; }
  var lowerBound: i32 = lo;
  var upperBound: i32 = hi;
  while (upperBound - lowerBound > 1) {
    let mid: i32 = (upperBound + lowerBound) / 2;
    let midValue: i32 = (*a)[mid];
    if (midValue < target) { lowerBound = mid; }
    else if (midValue > target) { upperBound = mid; }
    else { return mid; }
  }
  return lowerBound;
}
"#;

/// Monomorphized `atomicAddGroup` for one group size (WGSL has no
/// templates, so each referenced `G` gets its own function).
fn atomic_add_group_def(g: u32) -> String {
    format!(
        r#"// atomicAddGroup_{g}: tree-reduce `value` over each aligned {g}-lane group,
// then lane 0 of the group issues one atomic add. WGSL subgroup shuffles
// have no width window, so lane guards confine the reduction to the
// group (requires subgroup_size % {g} == 0).
fn atomicAddGroup_{g}(a: ptr<storage, array<atomic<u32>>, read_write>, idx: i32, value: f32, tid: i32) {{
  let lane: i32 = tid % {g};
  var v: f32 = value;
  for (var offset: i32 = {g} / 2; offset > 0; offset /= 2) {{
    let dn: f32 = subgroupShuffleDown(v, u32(offset));
    if (lane < {g} - offset) {{ v += dn; }}
  }}
  if (lane == 0) {{ atomicAddF32(a, idx, v); }}
}}
"#
    )
}

/// Monomorphized `segReduceGroup` for one group size.
fn seg_reduce_group_def(g: u32) -> String {
    format!(
        r#"// segReduceGroup_{g}: segmented inclusive scan over each aligned {g}-lane
// group keyed by `idx`; segment-end lanes write back. Lane guards window
// the un-widthed subgroup shuffles (requires subgroup_size % {g} == 0).
fn segReduceGroup_{g}(a: ptr<storage, array<atomic<u32>>, read_write>, idx: i32, value: f32, tid: i32) {{
  let lane: i32 = tid % {g};
  var v: f32 = value;
  for (var offset: i32 = 1; offset < {g}; offset *= 2) {{
    let up: f32 = subgroupShuffleUp(v, u32(offset));
    let upIdx: i32 = subgroupShuffleUp(idx, u32(offset));
    if (lane >= offset && upIdx == idx) {{ v += up; }}
  }}
  let dnIdx: i32 = subgroupShuffleDown(idx, 1u);
  if (lane == {g} - 1 || dnIdx != idx) {{ atomicAddF32(a, idx, v); }}
}}
"#
    )
}

/// The WGSL dialect (WebGPU compute; storage bindings + subgroup ops).
pub struct Wgsl;

impl Dialect for Wgsl {
    const NAME: &'static str = "wgsl";
    const FILE_EXT: &'static str = "wgsl";

    fn prologue(cx: &EmitCtx) -> String {
        let groups = cx.uses_group_macros();
        let atomics = groups || cx.uses_atomic_add;
        if !atomics && !cx.uses_binary_search {
            return String::new();
        }
        let mut s = String::new();
        if groups {
            s.push_str("enable subgroups;\n");
        }
        s.push_str("requires unrestricted_pointer_parameters;\n");
        s.push('\n');
        s.push_str(BANNER);
        let mut defs: Vec<String> = Vec::new();
        if atomics {
            defs.push(ATOMIC_ADD_F32_DEF.into());
        }
        for g in &cx.atomic_groups {
            defs.push(atomic_add_group_def(*g));
        }
        for g in &cx.seg_groups {
            defs.push(seg_reduce_group_def(*g));
        }
        if cx.uses_binary_search {
            defs.push(BINARY_SEARCH_DEF.into());
        }
        s.push_str(&defs.join("\n"));
        s.push_str(FOOTER);
        s
    }

    fn kernel_open(k: &Kernel, cx: &EmitCtx) -> String {
        let mut s = String::new();
        let mut binding = 0;
        for p in &k.params {
            match p.kind {
                ParamKind::ArrayF32 | ParamKind::ArrayI32 => {
                    let base = if p.kind == ParamKind::ArrayF32 { "f32" } else { "i32" };
                    let (access, elem) = if cx.atomic_arrays.contains(&p.name) {
                        ("read_write", "atomic<u32>".to_string())
                    } else if cx.stored_arrays.contains(&p.name) {
                        ("read_write", base.to_string())
                    } else {
                        ("read", base.to_string())
                    };
                    let name = &p.name;
                    writeln!(
                        s,
                        "@group(0) @binding({binding}) var<storage, {access}> {name}: array<{elem}>;"
                    )
                    .unwrap();
                    binding += 1;
                }
                ParamKind::ScalarI32 => writeln!(s, "override {}: i32;", p.name).unwrap(),
            }
        }
        s.push('\n');
        writeln!(s, "@compute @workgroup_size({})", k.block_dim).unwrap();
        write!(
            s,
            "fn {}(@builtin(workgroup_id) wgid: vec3<u32>, @builtin(local_invocation_id) lid: vec3<u32>) {{",
            k.name
        )
        .unwrap();
        s
    }

    fn decl(var: &str, float: bool, init: &str) -> String {
        let ty = if float { "f32" } else { "i32" };
        format!("var {var}: {ty} = {init};")
    }

    fn atomic_add(array: &str, idx: &str, val: &str) -> String {
        format!("atomicAddF32(&{array}, {idx}, {val});")
    }

    fn atomic_add_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        format!("atomicAddGroup_{group}(&{array}, {idx}, {val}, i32(lid.x));")
    }

    fn seg_reduce_group(array: &str, idx: &str, val: &str, group: u32) -> String {
        format!("segReduceGroup_{group}(&{array}, {idx}, {val}, i32(lid.x));")
    }

    fn for_open(var: &str, lo: &str, hi: &str, step: &str) -> String {
        format!("for (var {var}: i32 = {lo}; {var} < {hi}; {var} += {step}) {{")
    }

    fn const_f32(c: f32) -> String {
        format!("{c:?}")
    }

    fn thread_idx() -> &'static str {
        "i32(lid.x)"
    }

    fn block_idx() -> &'static str {
        "i32(wgid.x)"
    }

    fn binary_search(array: &str, lo: &str, hi: &str, target: &str) -> String {
        format!("taco_binarySearchBefore(&{array}, {lo}, {hi}, {target})")
    }
}

#[cfg(test)]
mod tests {
    use super::super::emit::{emit_kernel, emit_translation_unit};
    use super::*;
    use crate::compiler::schedule::{Schedule, SpmmConfig};

    #[test]
    fn wgsl_spellings_differ_structurally() {
        let k = crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap();
        let src = emit_kernel::<Wgsl>(&k);
        // Bindings replace pointer params; the reduction target is atomic.
        assert!(src.contains("@group(0) @binding(0) var<storage, read> i_blockStarts: array<i32>;"));
        assert!(src.contains("var<storage, read_write> C_vals: array<atomic<u32>>;"));
        assert!(src.contains("override A1_dimension: i32;"));
        // Builtins replace threadIdx/blockIdx, declarations are typed vars.
        assert!(src.contains("var fpos1: i32 = (i32(lid.x) % 256);"));
        assert!(!src.contains("threadIdx") && !src.contains("__global__"));
        // The macro call passes the lane id explicitly (no implicit mask).
        assert!(src.contains("segReduceGroup_32(&C_vals, kC, val, i32(lid.x));"));
        assert!(src.contains("taco_binarySearchBefore(&A2_pos, pA2_begin, pA2_end, fposA)"));
        // No stray `0.0f` CUDA literals.
        assert!(src.contains("var val: f32 = 0.0;"));
    }

    #[test]
    fn wgsl_prologue_defines_only_referenced_helpers() {
        let k = crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap();
        let tu = emit_translation_unit::<Wgsl>(&k);
        assert!(tu.starts_with("enable subgroups;\nrequires unrestricted_pointer_parameters;\n"));
        assert!(tu.contains("fn segReduceGroup_32(") && tu.contains("fn atomicAddF32("));
        assert!(tu.contains("fn taco_binarySearchBefore("));
        assert!(!tu.contains("atomicAddGroup_"));

        // A store-only kernel needs no helpers and no directives at all.
        let row = crate::compiler::lower(&Schedule::taco_row_serial(SpmmConfig::default())).unwrap();
        let tu = emit_translation_unit::<Wgsl>(&row);
        assert!(!tu.contains("enable subgroups"));
        assert!(!tu.contains("requires"));
        assert!(tu.contains("var<storage, read_write> C_vals: array<f32>;"));
    }
}
