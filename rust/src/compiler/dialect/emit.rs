//! The dialect-generic LLIR walk: one traversal emits every target.
//!
//! `emit_kernel::<Cuda>` reproduces the pre-dialect `codegen_cuda`
//! output byte-for-byte (the committed `.cu` goldens enforce this);
//! `emit_kernel::<Hip>` / `emit_kernel::<Wgsl>` reuse the identical
//! traversal and differ only in the [`Dialect`] spelling hooks.
//!
//! [`EmitCtx`] is the per-kernel analysis the hooks consult: which
//! arrays are written (and how), which §5.3 macro instructions — and
//! which group sizes — the body references, and whether the TACO row
//! binary search appears. Dialects use it to emit only the helper
//! definitions a kernel actually needs, and WGSL additionally derives
//! each storage binding's access mode and element type from it.

use std::collections::BTreeSet;

use super::super::llir::{BinOp, Kernel, Stmt, Val};
use super::Dialect;

/// What one kernel body references — computed once per emission by a
/// single pass over the statement tree and every value expression.
#[derive(Debug, Default, Clone)]
pub struct EmitCtx {
    /// Arrays written by an atomic form (`AtomicAdd`, `AtomicAddGroup`,
    /// `SegReduceGroup`) — WGSL binds these as `array<atomic<u32>>`.
    pub atomic_arrays: BTreeSet<String>,
    /// Arrays written by a plain `Store`.
    pub stored_arrays: BTreeSet<String>,
    /// Group sizes used by `AtomicAddGroup` call sites.
    pub atomic_groups: BTreeSet<u32>,
    /// Group sizes used by `SegReduceGroup` call sites.
    pub seg_groups: BTreeSet<u32>,
    /// Whether a plain (non-grouped) `AtomicAdd` appears.
    pub uses_atomic_add: bool,
    /// Whether `taco_binarySearchBefore` appears in any expression.
    pub uses_binary_search: bool,
}

impl EmitCtx {
    /// Scan `k` once, depth-first.
    pub fn analyze(k: &Kernel) -> EmitCtx {
        let mut cx = EmitCtx::default();
        for s in k.walk() {
            match s {
                Stmt::Store { array, .. } => {
                    cx.stored_arrays.insert(array.clone());
                }
                Stmt::AtomicAdd { array, .. } => {
                    cx.uses_atomic_add = true;
                    cx.atomic_arrays.insert(array.clone());
                }
                Stmt::AtomicAddGroup { array, group, .. } => {
                    cx.atomic_groups.insert(*group);
                    cx.atomic_arrays.insert(array.clone());
                }
                Stmt::SegReduceGroup { array, group, .. } => {
                    cx.seg_groups.insert(*group);
                    cx.atomic_arrays.insert(array.clone());
                }
                _ => {}
            }
            for_each_val(s, &mut |v| {
                if matches!(v, Val::BinarySearchBefore { .. }) {
                    cx.uses_binary_search = true;
                }
            });
        }
        cx
    }

    /// Whether any §5.3 macro instruction (either group reduction)
    /// appears — i.e. whether a group-reduce helper prologue is needed.
    pub fn uses_group_macros(&self) -> bool {
        !self.atomic_groups.is_empty() || !self.seg_groups.is_empty()
    }

    /// Whether `array` is written at all (any store or atomic form).
    pub fn writes(&self, array: &str) -> bool {
        self.stored_arrays.contains(array) || self.atomic_arrays.contains(array)
    }
}

/// Visit the value expressions directly owned by `s` (block statements'
/// bodies are covered by `Kernel::walk`), recursing into sub-values.
fn for_each_val(s: &Stmt, f: &mut impl FnMut(&Val)) {
    fn go(v: &Val, f: &mut impl FnMut(&Val)) {
        f(v);
        match v {
            Val::Bin(_, a, b) => {
                go(a, f);
                go(b, f);
            }
            Val::Load(_, i) => go(i, f),
            Val::BinarySearchBefore { lo, hi, target, .. } => {
                go(lo, f);
                go(hi, f);
                go(target, f);
            }
            _ => {}
        }
    }
    match s {
        Stmt::Decl { init: v, .. } | Stmt::Assign { val: v, .. } | Stmt::While { cond: v, .. } => {
            go(v, f)
        }
        Stmt::Store { idx, val, .. }
        | Stmt::AtomicAdd { idx, val, .. }
        | Stmt::AtomicAddGroup { idx, val, .. }
        | Stmt::SegReduceGroup { idx, val, .. } => {
            go(idx, f);
            go(val, f);
        }
        Stmt::For { lo, hi, step, .. } => {
            go(lo, f);
            go(hi, f);
            go(step, f);
        }
        Stmt::If { cond, .. } => go(cond, f),
        Stmt::Break | Stmt::Comment(_) => {}
    }
}

/// Render one value expression in dialect `D`. Operator symbols,
/// parenthesization, and `min()` are shared; literals, builtins, and the
/// binary-search call go through the dialect hooks.
pub fn fmt_val<D: Dialect>(v: &Val) -> String {
    match v {
        Val::Var(n) | Val::Param(n) => n.clone(),
        Val::ConstI(c) => c.to_string(),
        Val::ConstF(c) => D::const_f32(*c),
        Val::Bin(op, a, b) => {
            let (a, b) = (fmt_val::<D>(a), fmt_val::<D>(b));
            let sym = match op {
                BinOp::Add => "+",
                BinOp::Sub => "-",
                BinOp::Mul => "*",
                BinOp::Div => "/",
                BinOp::Mod => "%",
                BinOp::Min => return format!("min({a}, {b})"),
                BinOp::Lt => "<",
                BinOp::Le => "<=",
                BinOp::Eq => "==",
                BinOp::Ne => "!=",
                BinOp::Ge => ">=",
                BinOp::Gt => ">",
                BinOp::And => "&&",
                BinOp::Or => "||",
            };
            format!("({a} {sym} {b})")
        }
        Val::Load(a, i) => format!("{a}[{}]", fmt_val::<D>(i)),
        Val::BinarySearchBefore { array, lo, hi, target } => {
            let (lo, hi, t) = (fmt_val::<D>(lo), fmt_val::<D>(hi), fmt_val::<D>(target));
            D::binary_search(array, &lo, &hi, &t)
        }
        Val::BlockIdx => D::block_idx().to_string(),
        Val::ThreadIdx => D::thread_idx().to_string(),
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn line(out: &mut String, s: &str) {
    out.push_str(s);
    out.push('\n');
}

fn emit_stmts<D: Dialect>(out: &mut String, stmts: &[Stmt], depth: usize) {
    for s in stmts {
        emit_stmt::<D>(out, s, depth);
    }
}

fn emit_stmt<D: Dialect>(out: &mut String, s: &Stmt, depth: usize) {
    indent(out, depth);
    match s {
        Stmt::Decl { var, init, float } => line(out, &D::decl(var, *float, &fmt_val::<D>(init))),
        Stmt::Assign { var, val } => line(out, &format!("{var} = {};", fmt_val::<D>(val))),
        Stmt::Store { array, idx, val } => {
            line(out, &D::store(array, &fmt_val::<D>(idx), &fmt_val::<D>(val)))
        }
        Stmt::AtomicAdd { array, idx, val } => {
            line(out, &D::atomic_add(array, &fmt_val::<D>(idx), &fmt_val::<D>(val)))
        }
        Stmt::AtomicAddGroup { array, idx, val, group } => {
            let (i, v) = (fmt_val::<D>(idx), fmt_val::<D>(val));
            line(out, &D::atomic_add_group(array, &i, &v, *group));
        }
        Stmt::SegReduceGroup { array, idx, val, group } => {
            let (i, v) = (fmt_val::<D>(idx), fmt_val::<D>(val));
            line(out, &D::seg_reduce_group(array, &i, &v, *group));
        }
        Stmt::For { var, lo, hi, step, body } => {
            let (lo, hi, step) = (fmt_val::<D>(lo), fmt_val::<D>(hi), fmt_val::<D>(step));
            line(out, &D::for_open(var, &lo, &hi, &step));
            emit_stmts::<D>(out, body, depth + 1);
            indent(out, depth);
            line(out, "}");
        }
        Stmt::While { cond, body } => {
            line(out, &format!("while ({}) {{", fmt_val::<D>(cond)));
            emit_stmts::<D>(out, body, depth + 1);
            indent(out, depth);
            line(out, "}");
        }
        Stmt::If { cond, then, els } => {
            line(out, &format!("if ({}) {{", fmt_val::<D>(cond)));
            emit_stmts::<D>(out, then, depth + 1);
            indent(out, depth);
            if els.is_empty() {
                line(out, "}");
            } else {
                line(out, "} else {");
                emit_stmts::<D>(out, els, depth + 1);
                indent(out, depth);
                line(out, "}");
            }
        }
        Stmt::Break => line(out, "break;"),
        Stmt::Comment(c) => line(out, &format!("// {c}")),
    }
}

/// Emit the bare kernel (no prologue) in dialect `D`.
pub fn emit_kernel<D: Dialect>(k: &Kernel) -> String {
    let cx = EmitCtx::analyze(k);
    let mut out = String::new();
    line(&mut out, &D::kernel_open(k, &cx));
    emit_stmts::<D>(&mut out, &k.body, 1);
    line(&mut out, D::kernel_close());
    out
}

/// Full translation unit: the dialect prologue (only the helpers the
/// kernel references — possibly nothing) plus the kernel.
pub fn emit_translation_unit<D: Dialect>(k: &Kernel) -> String {
    let cx = EmitCtx::analyze(k);
    let pro = D::prologue(&cx);
    let kernel = emit_kernel::<D>(k);
    if pro.is_empty() {
        kernel
    } else {
        format!("{pro}\n{kernel}")
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::llir::Param;
    use super::super::Cuda;
    use super::*;

    fn kernel_with(body: Vec<Stmt>) -> Kernel {
        Kernel {
            name: "k".into(),
            params: vec![Param::f32_array("C_vals"), Param::i32_scalar("n")],
            body,
            block_dim: 256,
        }
    }

    #[test]
    fn ctx_classifies_writes_and_helpers() {
        let k = kernel_with(vec![
            Stmt::Store { array: "C_vals".into(), idx: Val::ConstI(0), val: Val::ConstF(1.0) },
            Stmt::SegReduceGroup {
                array: "Y_vals".into(),
                idx: Val::ConstI(0),
                val: Val::ConstF(0.0),
                group: 16,
            },
            Stmt::Decl {
                var: "p".into(),
                float: false,
                init: Val::BinarySearchBefore {
                    array: "A2_pos".into(),
                    lo: Box::new(Val::ConstI(0)),
                    hi: Box::new(Val::ConstI(4)),
                    target: Box::new(Val::ThreadIdx),
                },
            },
        ]);
        let cx = EmitCtx::analyze(&k);
        assert!(cx.stored_arrays.contains("C_vals"));
        assert!(cx.atomic_arrays.contains("Y_vals"));
        assert_eq!(cx.seg_groups.iter().copied().collect::<Vec<_>>(), vec![16]);
        assert!(cx.atomic_groups.is_empty());
        assert!(cx.uses_binary_search && !cx.uses_atomic_add);
        assert!(cx.uses_group_macros());
        assert!(cx.writes("C_vals") && cx.writes("Y_vals") && !cx.writes("A2_pos"));
    }

    #[test]
    fn generic_val_matches_display() {
        // The generic formatter instantiated at Cuda must agree with
        // `Val`'s own Display (the pre-dialect emission path).
        let vals = [
            Val::add(Val::mul(Val::BlockIdx, Val::ConstI(256)), Val::ThreadIdx),
            Val::min(Val::var("a"), Val::ConstF(0.5)),
            Val::and(Val::ge(Val::var("x"), Val::ConstI(1)), Val::ne(Val::var("y"), Val::var("z"))),
            Val::lt(Val::div(Val::var("p"), Val::ConstI(2)), Val::ConstI(9)),
            Val::load("A_vals", Val::rem(Val::ThreadIdx, Val::ConstI(32))),
            Val::BinarySearchBefore {
                array: "A2_pos".into(),
                lo: Box::new(Val::var("lo")),
                hi: Box::new(Val::var("hi")),
                target: Box::new(Val::var("t")),
            },
        ];
        for v in &vals {
            assert_eq!(fmt_val::<Cuda>(v), v.to_string());
        }
    }

    #[test]
    fn empty_prologue_means_bare_translation_unit() {
        let k = kernel_with(vec![Stmt::Store {
            array: "C_vals".into(),
            idx: Val::ConstI(0),
            val: Val::ConstF(1.0),
        }]);
        let tu = emit_translation_unit::<Cuda>(&k);
        assert_eq!(tu, emit_kernel::<Cuda>(&k));
        assert!(tu.starts_with("__global__ void k("));
    }
}
