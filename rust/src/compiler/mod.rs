//! The mini-TACO sparse compiler with **segment group** support.
//!
//! Pipeline (mirrors Fig. 6 + Fig. 10 of the paper):
//!
//! ```text
//! tensor algebra expression (expr)         — front-end input
//!   └─ compile(&TensorAlgebra, &Schedule)  — the front door (compile)
//!        │  ScheduleBuilder derives legal families per algebra and
//!        │  rejects schedule/expression mismatches with typed errors
//!        └─ concretize → concrete index notation (cin)
//!             └─ schedule commands transform the CIN (schedule)
//!                  fuse / split / pos / bound / reorder / parallelize
//!                  — parallelize now accepts GPUGroup{size, strategy} and
//!                    GPUWarp carries *tiling-only* semantics (§5.1)
//!             └─ lower → imperative LLIR (lower, llir)
//!                  — segment-reduction lowering + zero extension (§5.2–5.3)
//!             └─ codegen → dialect-parameterized text (dialect)
//!                  — one generic LLIR walk emits CUDA, HIP, or WGSL;
//!                    codegen_cuda is the CUDA instantiation (goldens)
//!                        → simulator launch (the LLIR itself runs on `sim`)
//! ```
//!
//! Every served kernel — the four SpMM families, SDDMM, the dgSPARSE
//! RB+PR shape, MTTKRP, TTM (the full §2.1 quartet), and the fused
//! SDDMM→SpMM chain — enters through [`compile()`]: an algebra in, a
//! kernel out, with the grouped reduction provably bound to one of the
//! expression's `reduction_dims()`. Producer→consumer pairs enter as a
//! [`FusedAlgebra`] whose legality ([`flatten_fused`]) is checked before
//! any schedule runs: the consumer may read the producer's output only
//! at the nnz coordinates the producer wrote.
//!
//! The optimization space the schedules draw from is formalized in
//! [`spaces`] (atomic parallelism, §3).

pub mod cin;
pub mod codegen_cuda;
pub mod compile;
pub mod dialect;
pub mod expr;
pub mod llir;
pub mod lower;
pub mod schedule;
pub mod spaces;

pub use cin::{
    Cin, GroupSpec, OutputRaceStrategy, ParallelUnit, ReductionPlan, ReductionStrategy, Writeback,
};
pub use compile::{compile, flatten_fused, CompileError, ScheduleBuilder};
pub use dialect::{Cuda, Dialect, DialectKind, EmitCtx, Hip, Wgsl};
pub use expr::{Access, Expr, FusedAlgebra, IndexVar, LevelFormat, TensorAlgebra, TensorVar};
pub use llir::{Kernel, LaunchConfig, Stmt, Val};
pub use lower::{lower, LowerError};
pub use schedule::{
    DgConfig, Family, FusedConfig, KernelConfig, MttkrpConfig, Schedule, ScheduleCmd, SddmmConfig,
    SpmmConfig, TtmConfig,
};
pub use spaces::{AtomicPoint, DataKind, Factor};
