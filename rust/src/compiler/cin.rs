//! Concrete index notation (CIN) — the middle-end language (§2.4.1).
//!
//! A CIN tree describes loop structure, parallel bindings, and workspaces
//! for a tensor algebra statement. The segment-group extension lives here:
//! [`ParallelUnit::GPUGroup`] carries a [`GroupSpec`] with a *group size*
//! (reduction parallelism `r`) and a *reduction strategy* — the two
//! degrees of freedom the paper adds over stock TACO (§5.1).

use std::fmt;

use super::expr::{Access, Expr, IndexVar};

/// Where a forall's iterations run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelUnit {
    /// Serial CPU loop.
    Serial,
    /// CUDA blockIdx.x.
    GPUBlock,
    /// CUDA warp index — after the Sgap change this is **tiling-only**
    /// semantics: outer sub-tile of threadIdx.x, no synchronization implied.
    GPUWarp,
    /// CUDA threadIdx.x (inner tile).
    GPUThread,
    /// The new unit: a synchronizing thread group (§5.1).
    GPUGroup,
}

impl fmt::Display for ParallelUnit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ParallelUnit::Serial => "Serial",
            ParallelUnit::GPUBlock => "GPUBlock",
            ParallelUnit::GPUWarp => "GPUWarp",
            ParallelUnit::GPUThread => "GPUThread",
            ParallelUnit::GPUGroup => "GPUGroup",
        };
        write!(f, "{s}")
    }
}

/// TACO's data-race declaration for parallel reductions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputRaceStrategy {
    NoRaces,
    IgnoreRaces,
    Atomics,
}

impl fmt::Display for OutputRaceStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            OutputRaceStrategy::NoRaces => "NoRaces",
            OutputRaceStrategy::IgnoreRaces => "IgnoreRaces",
            OutputRaceStrategy::Atomics => "Atomics",
        };
        write!(f, "{s}")
    }
}

/// How a GPUGroup synchronizes its lanes (§4.2, §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReductionStrategy {
    /// Tree reduction; exactly one writeback thread per group
    /// (`atomicAddGroup<T,G>`).
    ParallelReduction,
    /// Segmented reduction; writeback threads decided at runtime by
    /// segment boundaries (`segReduceGroup<T,G>`).
    SegmentReduction,
}

impl fmt::Display for ReductionStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ReductionStrategy::ParallelReduction => "ParallelReduction",
            ReductionStrategy::SegmentReduction => "Segment",
        };
        write!(f, "{s}")
    }
}

/// The attributes of a GPUGroup binding: reduction parallelism (`GroupSize`,
/// the paper's `r`) and the reduction strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSpec {
    pub size: u32,
    pub strategy: ReductionStrategy,
}

impl GroupSpec {
    pub fn new(size: u32, strategy: ReductionStrategy) -> Self {
        assert!(size.is_power_of_two() && size <= 32, "group size must be a power of 2 ≤ 32");
        GroupSpec { size, strategy }
    }
}

/// A CIN statement tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Cin {
    /// `forall(var, body, unit, race[, group])`.
    Forall {
        var: IndexVar,
        body: Box<Cin>,
        unit: ParallelUnit,
        race: OutputRaceStrategy,
        /// Present iff `unit == GPUGroup`.
        group: Option<GroupSpec>,
    },
    /// `where(consumer, producer)` — workspace introduction (§5.3's
    /// *scalar workspace*; the relaxed rule allows the producer's
    /// assignment in a different basic block than its declaration).
    Where { consumer: Box<Cin>, producer: Box<Cin> },
    /// `lhs op= rhs`. `reduce == true` renders `+=`.
    Assign { lhs: Access, reduce: bool, rhs: Expr },
}

impl Cin {
    pub fn forall(var: &str, unit: ParallelUnit, race: OutputRaceStrategy, body: Cin) -> Cin {
        Cin::Forall { var: IndexVar::new(var), body: Box::new(body), unit, race, group: None }
    }

    pub fn forall_group(var: &str, spec: GroupSpec, race: OutputRaceStrategy, body: Cin) -> Cin {
        Cin::Forall {
            var: IndexVar::new(var),
            body: Box::new(body),
            unit: ParallelUnit::GPUGroup,
            race,
            group: Some(spec),
        }
    }

    /// Depth-first search for the forall binding `var`.
    pub fn find_forall(&self, var: &IndexVar) -> Option<&Cin> {
        match self {
            Cin::Forall { var: v, body, .. } => {
                if v == var {
                    Some(self)
                } else {
                    body.find_forall(var)
                }
            }
            Cin::Where { consumer, producer } => {
                consumer.find_forall(var).or_else(|| producer.find_forall(var))
            }
            Cin::Assign { .. } => None,
        }
    }

    /// All forall vars in tree order (outermost first).
    pub fn loop_order(&self) -> Vec<IndexVar> {
        let mut out = Vec::new();
        self.collect_loops(&mut out);
        out
    }

    fn collect_loops(&self, out: &mut Vec<IndexVar>) {
        match self {
            Cin::Forall { var, body, .. } => {
                out.push(var.clone());
                body.collect_loops(out);
            }
            Cin::Where { consumer, producer } => {
                consumer.collect_loops(out);
                producer.collect_loops(out);
            }
            Cin::Assign { .. } => {}
        }
    }

    /// The GPUGroup spec, if any forall in the tree carries one.
    pub fn group_spec(&self) -> Option<GroupSpec> {
        match self {
            Cin::Forall { unit, group, body, .. } => {
                if *unit == ParallelUnit::GPUGroup {
                    *group
                } else {
                    body.group_spec()
                }
            }
            Cin::Where { consumer, producer } => {
                consumer.group_spec().or_else(|| producer.group_spec())
            }
            Cin::Assign { .. } => None,
        }
    }
}

impl fmt::Display for Cin {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Cin::Forall { var, body, unit, race, group } => match group {
                Some(g) => write!(
                    f,
                    "forall({var}, {body}, {unit}[{},{}], {race})",
                    g.size, g.strategy
                ),
                None => write!(f, "forall({var}, {body}, {unit}, {race})"),
            },
            Cin::Where { consumer, producer } => write!(f, "where({consumer}, {producer})"),
            Cin::Assign { lhs, reduce, rhs } => {
                write!(f, "{lhs}{}{rhs}", if *reduce { "+=" } else { "=" })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::expr::Access;

    fn assign() -> Cin {
        Cin::Assign {
            lhs: Access::new("C", &["i", "k"]),
            reduce: true,
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
            ),
        }
    }

    #[test]
    fn display_matches_listing_style() {
        let cin = Cin::forall(
            "block",
            ParallelUnit::GPUBlock,
            OutputRaceStrategy::IgnoreRaces,
            Cin::forall("fpos1", ParallelUnit::GPUThread, OutputRaceStrategy::Atomics, assign()),
        );
        let s = cin.to_string();
        assert!(s.starts_with("forall(block,"));
        assert!(s.contains("GPUThread, Atomics"));
        assert!(s.contains("C(i,k)+=A(i,j)*B(j,k)"));
    }

    #[test]
    fn group_spec_found_in_nest() {
        let spec = GroupSpec::new(8, ReductionStrategy::SegmentReduction);
        let cin = Cin::forall(
            "block",
            ParallelUnit::GPUBlock,
            OutputRaceStrategy::NoRaces,
            Cin::forall_group("jpos1", spec, OutputRaceStrategy::Atomics, assign()),
        );
        assert_eq!(cin.group_spec(), Some(spec));
        assert_eq!(
            cin.loop_order(),
            vec![IndexVar::new("block"), IndexVar::new("jpos1")]
        );
    }

    #[test]
    fn find_forall_descends() {
        let cin = Cin::forall(
            "a",
            ParallelUnit::Serial,
            OutputRaceStrategy::NoRaces,
            Cin::forall("b", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, assign()),
        );
        assert!(cin.find_forall(&IndexVar::new("b")).is_some());
        assert!(cin.find_forall(&IndexVar::new("zz")).is_none());
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn group_size_must_be_pow2() {
        GroupSpec::new(6, ReductionStrategy::ParallelReduction);
    }

    #[test]
    fn where_displays() {
        let w = Cin::Where {
            consumer: Box::new(assign()),
            producer: Box::new(Cin::Assign {
                lhs: Access::new("tmp", &[]),
                reduce: false,
                rhs: Expr::Access(Access::new("A", &["i", "j"])),
            }),
        };
        assert!(w.to_string().starts_with("where("));
    }
}
