//! Concrete index notation (CIN) — the middle-end language (§2.4.1).
//!
//! A CIN tree describes loop structure, parallel bindings, and workspaces
//! for a tensor algebra statement. The segment-group extension lives here:
//! [`ParallelUnit::GPUGroup`] carries a [`GroupSpec`] with a *group size*
//! (reduction parallelism `r`) and a *reduction strategy* — the two
//! degrees of freedom the paper adds over stock TACO (§5.1).

use std::fmt;

use super::expr::{Access, Expr, IndexVar};

/// Where a forall's iterations run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelUnit {
    /// Serial CPU loop.
    Serial,
    /// CUDA blockIdx.x.
    GPUBlock,
    /// CUDA warp index — after the Sgap change this is **tiling-only**
    /// semantics: outer sub-tile of threadIdx.x, no synchronization implied.
    GPUWarp,
    /// CUDA threadIdx.x (inner tile).
    GPUThread,
    /// The new unit: a synchronizing thread group (§5.1).
    GPUGroup,
}

impl fmt::Display for ParallelUnit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ParallelUnit::Serial => "Serial",
            ParallelUnit::GPUBlock => "GPUBlock",
            ParallelUnit::GPUWarp => "GPUWarp",
            ParallelUnit::GPUThread => "GPUThread",
            ParallelUnit::GPUGroup => "GPUGroup",
        };
        write!(f, "{s}")
    }
}

/// TACO's data-race declaration for parallel reductions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputRaceStrategy {
    NoRaces,
    IgnoreRaces,
    Atomics,
}

impl fmt::Display for OutputRaceStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            OutputRaceStrategy::NoRaces => "NoRaces",
            OutputRaceStrategy::IgnoreRaces => "IgnoreRaces",
            OutputRaceStrategy::Atomics => "Atomics",
        };
        write!(f, "{s}")
    }
}

/// The writeback discipline of a reduction: how a reduced value reaches
/// global memory. This is the axis of a [`ReductionPlan`] the lowerer
/// actually consumes — every kernel family, compiler-scheduled or
/// library-shaped, ends in exactly one of these four instructions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Writeback {
    /// Plain store — the lane owns the output exclusively (NoRaces).
    Store,
    /// Plain per-lane `atomicAdd` (serial reduction over shared outputs).
    Atomic,
    /// `atomicAddGroup<T,G>`: tree reduction across the group, lane 0
    /// writes back once (compile-time-decided writeback thread).
    LaneZeroAtomic,
    /// `segReduceGroup<T,G>`: segmented scan keyed by the output index,
    /// segment-boundary lanes write back (runtime-decided writeback
    /// threads).
    SegmentBoundary,
}

impl Writeback {
    /// Whether this discipline synchronizes a lane group (the two macro
    /// instructions) as opposed to a single-lane store/atomic.
    pub fn is_grouped(self) -> bool {
        matches!(self, Writeback::LaneZeroAtomic | Writeback::SegmentBoundary)
    }
}

impl fmt::Display for Writeback {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Writeback::Store => "Store",
            Writeback::Atomic => "Atomic",
            Writeback::LaneZeroAtomic => "LaneZeroAtomic",
            Writeback::SegmentBoundary => "SegmentBoundary",
        };
        write!(f, "{s}")
    }
}

/// How a GPUGroup synchronizes its lanes (§4.2, §5.1).
///
/// The paper's claim is that the strategy is *user-defined* — segment
/// group fixes the synchronization width but not the reduction discipline.
/// Beyond the two built-in strategies of §5.1, [`RowBalancedPartial`]
/// captures dgSPARSE's RB+PR kernel (partial results per row visit under a
/// strided row loop), and [`Custom`] admits any caller-defined strategy by
/// naming its writeback discipline — new strategies need no lowerer edits
/// because [`crate::compiler::lower`](mod@crate::compiler::lower) consumes only the [`Writeback`].
///
/// [`RowBalancedPartial`]: ReductionStrategy::RowBalancedPartial
/// [`Custom`]: ReductionStrategy::Custom
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReductionStrategy {
    /// Tree reduction; exactly one writeback thread per group
    /// (`atomicAddGroup<T,G>`).
    ParallelReduction,
    /// Segmented reduction; writeback threads decided at runtime by
    /// segment boundaries (`segReduceGroup<T,G>`).
    SegmentReduction,
    /// dgSPARSE's RB+PR discipline: a grouped tree reduction whose
    /// owning loop strides *rows* (row balance), writing back a partial
    /// result per row visit — same macro instruction as
    /// [`ParallelReduction`](ReductionStrategy::ParallelReduction), but a
    /// different loop structure above it.
    RowBalancedPartial,
    /// A user-defined strategy: a display name plus the writeback
    /// discipline it reduces to.
    Custom { name: &'static str, writeback: Writeback },
}

impl ReductionStrategy {
    /// The writeback discipline this strategy lowers to — the single
    /// point the emission pipeline consults.
    pub fn writeback(self) -> Writeback {
        match self {
            ReductionStrategy::ParallelReduction | ReductionStrategy::RowBalancedPartial => {
                Writeback::LaneZeroAtomic
            }
            ReductionStrategy::SegmentReduction => Writeback::SegmentBoundary,
            ReductionStrategy::Custom { writeback, .. } => writeback,
        }
    }
}

impl fmt::Display for ReductionStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match *self {
            ReductionStrategy::ParallelReduction => "ParallelReduction",
            ReductionStrategy::SegmentReduction => "Segment",
            ReductionStrategy::RowBalancedPartial => "RowBalancedPartial",
            ReductionStrategy::Custom { name, .. } => name,
        };
        write!(f, "{s}")
    }
}

/// The attributes of a GPUGroup binding: reduction parallelism (`GroupSize`,
/// the paper's `r`) and the reduction strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSpec {
    pub size: u32,
    pub strategy: ReductionStrategy,
}

impl GroupSpec {
    pub fn new(size: u32, strategy: ReductionStrategy) -> Self {
        assert!(size.is_power_of_two() && size <= 32, "group size must be a power of 2 ≤ 32");
        GroupSpec { size, strategy }
    }

    /// The reduction recipe this binding implies.
    pub fn plan(self) -> ReductionPlan {
        ReductionPlan::grouped(self)
    }
}

/// The complete reduction recipe threaded from scheduling into lowering:
/// strategy × group size × writeback discipline. Constructed from a
/// [`GroupSpec`] (grouped families) or [`ReductionPlan::serial`] (the
/// stock TACO families); consumed by the family-agnostic emission
/// pipeline in [`crate::compiler::lower`](mod@crate::compiler::lower).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReductionPlan {
    /// Reduction parallelism (the paper's `r`); 1 for serial reductions.
    pub group: u32,
    /// `None` for serial (ungrouped) reductions.
    pub strategy: Option<ReductionStrategy>,
    /// The instruction the reduction's writeback lowers to.
    pub writeback: Writeback,
}

impl ReductionPlan {
    /// A serial reduction: one lane accumulates, writing back with a
    /// plain store ([`Writeback::Store`]) or per-lane atomics
    /// ([`Writeback::Atomic`]).
    pub fn serial(writeback: Writeback) -> ReductionPlan {
        assert!(
            matches!(writeback, Writeback::Store | Writeback::Atomic),
            "serial reductions write back with Store or Atomic, got {writeback}"
        );
        ReductionPlan { group: 1, strategy: None, writeback }
    }

    /// The grouped reduction a [`GroupSpec`] describes.
    pub fn grouped(spec: GroupSpec) -> ReductionPlan {
        ReductionPlan {
            group: spec.size,
            strategy: Some(spec.strategy),
            writeback: spec.strategy.writeback(),
        }
    }

    /// Whether the plan synchronizes lanes (any grouped strategy).
    pub fn is_grouped(&self) -> bool {
        self.strategy.is_some()
    }
}

impl fmt::Display for ReductionPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.strategy {
            Some(s) => write!(f, "{{r={}, {s}, {}}}", self.group, self.writeback),
            None => write!(f, "{{serial, {}}}", self.writeback),
        }
    }
}

/// A CIN statement tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Cin {
    /// `forall(var, body, unit, race[, group])`.
    Forall {
        var: IndexVar,
        body: Box<Cin>,
        unit: ParallelUnit,
        race: OutputRaceStrategy,
        /// Present iff `unit == GPUGroup`.
        group: Option<GroupSpec>,
    },
    /// `where(consumer, producer)` — workspace introduction (§5.3's
    /// *scalar workspace*; the relaxed rule allows the producer's
    /// assignment in a different basic block than its declaration).
    Where { consumer: Box<Cin>, producer: Box<Cin> },
    /// `lhs op= rhs`. `reduce == true` renders `+=`.
    Assign { lhs: Access, reduce: bool, rhs: Expr },
}

impl Cin {
    pub fn forall(var: &str, unit: ParallelUnit, race: OutputRaceStrategy, body: Cin) -> Cin {
        Cin::Forall { var: IndexVar::new(var), body: Box::new(body), unit, race, group: None }
    }

    pub fn forall_group(var: &str, spec: GroupSpec, race: OutputRaceStrategy, body: Cin) -> Cin {
        Cin::Forall {
            var: IndexVar::new(var),
            body: Box::new(body),
            unit: ParallelUnit::GPUGroup,
            race,
            group: Some(spec),
        }
    }

    /// Depth-first search for the forall binding `var`.
    pub fn find_forall(&self, var: &IndexVar) -> Option<&Cin> {
        match self {
            Cin::Forall { var: v, body, .. } => {
                if v == var {
                    Some(self)
                } else {
                    body.find_forall(var)
                }
            }
            Cin::Where { consumer, producer } => {
                consumer.find_forall(var).or_else(|| producer.find_forall(var))
            }
            Cin::Assign { .. } => None,
        }
    }

    /// All forall vars in tree order (outermost first).
    pub fn loop_order(&self) -> Vec<IndexVar> {
        let mut out = Vec::new();
        self.collect_loops(&mut out);
        out
    }

    fn collect_loops(&self, out: &mut Vec<IndexVar>) {
        match self {
            Cin::Forall { var, body, .. } => {
                out.push(var.clone());
                body.collect_loops(out);
            }
            Cin::Where { consumer, producer } => {
                consumer.collect_loops(out);
                producer.collect_loops(out);
            }
            Cin::Assign { .. } => {}
        }
    }

    /// The GPUGroup spec, if any forall in the tree carries one.
    pub fn group_spec(&self) -> Option<GroupSpec> {
        match self {
            Cin::Forall { unit, group, body, .. } => {
                if *unit == ParallelUnit::GPUGroup {
                    *group
                } else {
                    body.group_spec()
                }
            }
            Cin::Where { consumer, producer } => {
                consumer.group_spec().or_else(|| producer.group_spec())
            }
            Cin::Assign { .. } => None,
        }
    }
}

impl fmt::Display for Cin {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Cin::Forall { var, body, unit, race, group } => match group {
                Some(g) => write!(
                    f,
                    "forall({var}, {body}, {unit}[{},{}], {race})",
                    g.size, g.strategy
                ),
                None => write!(f, "forall({var}, {body}, {unit}, {race})"),
            },
            Cin::Where { consumer, producer } => write!(f, "where({consumer}, {producer})"),
            Cin::Assign { lhs, reduce, rhs } => {
                write!(f, "{lhs}{}{rhs}", if *reduce { "+=" } else { "=" })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::expr::Access;

    fn assign() -> Cin {
        Cin::Assign {
            lhs: Access::new("C", &["i", "k"]),
            reduce: true,
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
            ),
        }
    }

    #[test]
    fn display_matches_listing_style() {
        let cin = Cin::forall(
            "block",
            ParallelUnit::GPUBlock,
            OutputRaceStrategy::IgnoreRaces,
            Cin::forall("fpos1", ParallelUnit::GPUThread, OutputRaceStrategy::Atomics, assign()),
        );
        let s = cin.to_string();
        assert!(s.starts_with("forall(block,"));
        assert!(s.contains("GPUThread, Atomics"));
        assert!(s.contains("C(i,k)+=A(i,j)*B(j,k)"));
    }

    #[test]
    fn group_spec_found_in_nest() {
        let spec = GroupSpec::new(8, ReductionStrategy::SegmentReduction);
        let cin = Cin::forall(
            "block",
            ParallelUnit::GPUBlock,
            OutputRaceStrategy::NoRaces,
            Cin::forall_group("jpos1", spec, OutputRaceStrategy::Atomics, assign()),
        );
        assert_eq!(cin.group_spec(), Some(spec));
        assert_eq!(
            cin.loop_order(),
            vec![IndexVar::new("block"), IndexVar::new("jpos1")]
        );
    }

    #[test]
    fn find_forall_descends() {
        let cin = Cin::forall(
            "a",
            ParallelUnit::Serial,
            OutputRaceStrategy::NoRaces,
            Cin::forall("b", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, assign()),
        );
        assert!(cin.find_forall(&IndexVar::new("b")).is_some());
        assert!(cin.find_forall(&IndexVar::new("zz")).is_none());
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn group_size_must_be_pow2() {
        GroupSpec::new(6, ReductionStrategy::ParallelReduction);
    }

    #[test]
    fn strategies_map_to_writebacks() {
        assert_eq!(ReductionStrategy::ParallelReduction.writeback(), Writeback::LaneZeroAtomic);
        assert_eq!(ReductionStrategy::SegmentReduction.writeback(), Writeback::SegmentBoundary);
        assert_eq!(ReductionStrategy::RowBalancedPartial.writeback(), Writeback::LaneZeroAtomic);
        let custom =
            ReductionStrategy::Custom { name: "maxPool", writeback: Writeback::SegmentBoundary };
        assert_eq!(custom.writeback(), Writeback::SegmentBoundary);
        assert_eq!(custom.to_string(), "maxPool");
    }

    #[test]
    fn reduction_plans_from_specs_and_serial() {
        let p = GroupSpec::new(8, ReductionStrategy::SegmentReduction).plan();
        assert_eq!(p.group, 8);
        assert!(p.is_grouped());
        assert_eq!(p.writeback, Writeback::SegmentBoundary);
        let rb = GroupSpec::new(4, ReductionStrategy::RowBalancedPartial).plan();
        assert_eq!(rb.writeback, Writeback::LaneZeroAtomic);
        let s = ReductionPlan::serial(Writeback::Atomic);
        assert_eq!((s.group, s.strategy, s.writeback), (1, None, Writeback::Atomic));
        assert!(!s.is_grouped());
        assert!(s.to_string().contains("serial"));
    }

    #[test]
    #[should_panic(expected = "serial reductions")]
    fn serial_plan_rejects_grouped_writeback() {
        ReductionPlan::serial(Writeback::LaneZeroAtomic);
    }

    #[test]
    fn where_displays() {
        let w = Cin::Where {
            consumer: Box::new(assign()),
            producer: Box::new(Cin::Assign {
                lhs: Access::new("tmp", &[]),
                reduce: false,
                rhs: Expr::Access(Access::new("A", &["i", "j"])),
            }),
        };
        assert!(w.to_string().starts_with("where("));
    }
}
