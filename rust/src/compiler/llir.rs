//! LLIR — the imperative low-level IR the middle-end lowers CIN into
//! (§2.4.2). It is "almost executable code": basic blocks, for/while/if,
//! loads/stores, atomics, and the two segment-group **macro instructions**
//! of §5.3 (`atomicAddGroup<T,G>` and `segReduceGroup<T,G>`).
//!
//! One producer: [`crate::compiler::lower`](mod@crate::compiler::lower)'s emission pipeline — every
//! kernel the catalog serves (SpMM families, SDDMM, dgSPARSE) arrives
//! here from a `Schedule`, with each reduction writeback chosen by a
//! [`crate::compiler::cin::ReductionPlan`]. Two consumers:
//! * [`crate::compiler::codegen_cuda`] pretty-prints it as CUDA-like text
//!   (for inspection + golden tests against the paper's Listings 1/2),
//! * [`crate::sim`] executes it warp-by-warp with lane masks and charges
//!   cycles — the stand-in for running the CUDA on a real GPU.

use std::fmt;

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
    Lt,
    Le,
    Eq,
    Ne,
    Ge,
    Gt,
    And,
    Or,
}

impl BinOp {
    pub fn is_compare(self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Le | BinOp::Eq | BinOp::Ne | BinOp::Ge | BinOp::Gt)
    }
}

/// Value expressions (pure, per-lane).
#[derive(Debug, Clone, PartialEq)]
pub enum Val {
    /// Local scalar variable.
    Var(String),
    ConstI(i64),
    ConstF(f32),
    Bin(BinOp, Box<Val>, Box<Val>),
    /// `array[idx]` — global memory load (either element type).
    Load(String, Box<Val>),
    /// `taco_binarySearchBefore(array, lo, hi, target)`: largest `i` in
    /// `[lo, hi]` with `array[i] <= target` (Listing 1's row search).
    BinarySearchBefore { array: String, lo: Box<Val>, hi: Box<Val>, target: Box<Val> },
    /// blockIdx.x
    BlockIdx,
    /// threadIdx.x
    ThreadIdx,
    /// Kernel scalar parameter (grid-uniform), e.g. `B2_dimension`.
    Param(String),
}

impl Val {
    pub fn var(name: &str) -> Val {
        Val::Var(name.into())
    }
    pub fn param(name: &str) -> Val {
        Val::Param(name.into())
    }
    pub fn bin(op: BinOp, a: Val, b: Val) -> Val {
        Val::Bin(op, Box::new(a), Box::new(b))
    }
    pub fn add(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Add, a, b)
    }
    pub fn sub(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Sub, a, b)
    }
    pub fn mul(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Mul, a, b)
    }
    pub fn div(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Div, a, b)
    }
    pub fn rem(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Mod, a, b)
    }
    pub fn min(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Min, a, b)
    }
    pub fn lt(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Lt, a, b)
    }
    pub fn le(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Le, a, b)
    }
    pub fn ge(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Ge, a, b)
    }
    pub fn eq(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Eq, a, b)
    }
    pub fn ne(a: Val, b: Val) -> Val {
        Val::bin(BinOp::Ne, a, b)
    }
    pub fn and(a: Val, b: Val) -> Val {
        Val::bin(BinOp::And, a, b)
    }
    pub fn load(array: &str, idx: Val) -> Val {
        Val::Load(array.into(), Box::new(idx))
    }
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `int/float name = init;` — declaration + init (type inferred).
    Decl { var: String, init: Val, float: bool },
    /// `name = val;`
    Assign { var: String, val: Val },
    /// `array[idx] = val;` (global store)
    Store { array: String, idx: Val, val: Val },
    /// `atomicAdd(&array[idx], val);` — plain CUDA atomic.
    AtomicAdd { array: String, idx: Val, val: Val },
    /// `atomicAddGroup<float,G>(array, idx, val);` — tree-reduce `val`
    /// over each aligned G-lane group, lane 0 of the group does one
    /// atomicAdd (macro instruction, §5.3). `idx` must be group-uniform.
    AtomicAddGroup { array: String, idx: Val, val: Val, group: u32 },
    /// `segReduceGroup<float,G>(array, idx, val);` — segmented scan over
    /// each aligned G-lane group keyed by `idx`; segment-end lanes do the
    /// atomic writeback (macro instruction, §5.3).
    SegReduceGroup { array: String, idx: Val, val: Val, group: u32 },
    /// `for (var = lo; var < hi; var += step) body`
    For { var: String, lo: Val, hi: Val, step: Val, body: Vec<Stmt> },
    /// `while (cond) body`
    While { cond: Val, body: Vec<Stmt> },
    If { cond: Val, then: Vec<Stmt>, els: Vec<Stmt> },
    Break,
    Comment(String),
}

/// Kernel parameter kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    ArrayF32,
    ArrayI32,
    ScalarI32,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub kind: ParamKind,
}

impl Param {
    pub fn f32_array(name: &str) -> Param {
        Param { name: name.into(), kind: ParamKind::ArrayF32 }
    }
    pub fn i32_array(name: &str) -> Param {
        Param { name: name.into(), kind: ParamKind::ArrayI32 }
    }
    pub fn i32_scalar(name: &str) -> Param {
        Param { name: name.into(), kind: ParamKind::ScalarI32 }
    }
}

/// Launch shape: `grid` blocks × `block` threads (1-D, as TACO emits —
/// §2.4.3: "it only generates one dimension of block and thread").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub grid: u32,
    pub block: u32,
}

/// A complete GPU kernel in LLIR.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    /// Threads per block (grid size is input-dependent, fixed at launch).
    pub block_dim: u32,
}

impl Kernel {
    /// All statements, depth-first (for structural asserts in tests).
    pub fn walk(&self) -> Vec<&Stmt> {
        fn go<'a>(stmts: &'a [Stmt], out: &mut Vec<&'a Stmt>) {
            for s in stmts {
                out.push(s);
                match s {
                    Stmt::For { body, .. } | Stmt::While { body, .. } => go(body, out),
                    Stmt::If { then, els, .. } => {
                        go(then, out);
                        go(els, out);
                    }
                    _ => {}
                }
            }
        }
        let mut out = Vec::new();
        go(&self.body, &mut out);
        out
    }

    pub fn count_matching(&self, pred: impl Fn(&Stmt) -> bool) -> usize {
        self.walk().into_iter().filter(|s| pred(s)).count()
    }
}

impl fmt::Display for Val {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Val::Var(n) | Val::Param(n) => write!(f, "{n}"),
            Val::ConstI(c) => write!(f, "{c}"),
            Val::ConstF(c) => write!(f, "{c:?}f"),
            Val::Bin(op, a, b) => {
                let sym = match op {
                    BinOp::Add => "+",
                    BinOp::Sub => "-",
                    BinOp::Mul => "*",
                    BinOp::Div => "/",
                    BinOp::Mod => "%",
                    BinOp::Min => return write!(f, "min({a}, {b})"),
                    BinOp::Lt => "<",
                    BinOp::Le => "<=",
                    BinOp::Eq => "==",
                    BinOp::Ne => "!=",
                    BinOp::Ge => ">=",
                    BinOp::Gt => ">",
                    BinOp::And => "&&",
                    BinOp::Or => "||",
                };
                write!(f, "({a} {sym} {b})")
            }
            Val::Load(a, i) => write!(f, "{a}[{i}]"),
            Val::BinarySearchBefore { array, lo, hi, target } => {
                write!(f, "taco_binarySearchBefore({array}, {lo}, {hi}, {target})")
            }
            Val::BlockIdx => write!(f, "blockIdx.x"),
            Val::ThreadIdx => write!(f, "threadIdx.x"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn val_display() {
        let v = Val::add(Val::mul(Val::BlockIdx, Val::ConstI(256)), Val::ThreadIdx);
        assert_eq!(v.to_string(), "((blockIdx.x * 256) + threadIdx.x)");
    }

    #[test]
    fn binary_search_display() {
        let v = Val::BinarySearchBefore {
            array: "A2_pos".into(),
            lo: Box::new(Val::var("pA2_begin")),
            hi: Box::new(Val::var("pA2_end")),
            target: Box::new(Val::var("fposA")),
        };
        assert_eq!(v.to_string(), "taco_binarySearchBefore(A2_pos, pA2_begin, pA2_end, fposA)");
    }

    #[test]
    fn walk_counts_nested() {
        let k = Kernel {
            name: "k".into(),
            params: vec![],
            block_dim: 256,
            body: vec![Stmt::For {
                var: "i".into(),
                lo: Val::ConstI(0),
                hi: Val::ConstI(4),
                step: Val::ConstI(1),
                body: vec![
                    Stmt::If {
                        cond: Val::lt(Val::var("i"), Val::ConstI(2)),
                        then: vec![Stmt::Break],
                        els: vec![],
                    },
                    Stmt::Comment("x".into()),
                ],
            }],
        };
        assert_eq!(k.walk().len(), 4);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::Break)), 1);
    }
}
