//! The compiler's front door: `compile(&TensorAlgebra, &Schedule)`.
//!
//! Every kernel the system serves is a lowering of a *stated* tensor
//! algebra (§2.1's quartet — SpMM, SDDMM, MTTKRP, TTM). This module makes
//! that provable at the API boundary: [`compile`] takes the algebra
//! expression **and** the schedule, checks that they agree, and only then
//! hands the schedule to [`lower`]. Mismatches — a schedule built for a
//! different algebra, or a grouped reduction bound to a dimension that is
//! not one of the expression's `reduction_dims()` — are typed
//! [`CompileError`]s, not silent miscompiles.
//!
//! [`ScheduleBuilder`] is the discovery side of the same contract: given
//! an algebra it names the legal schedule [`Family`]s and constructs
//! validated schedules from a [`KernelConfig`], so callers start from the
//! expression rather than from per-family constructor functions.

use thiserror::Error;

use super::expr::{FusedAlgebra, IndexVar, TensorAlgebra};
use super::llir::Kernel;
use super::lower::{lower, LowerError};
use super::schedule::{Family, KernelConfig, Schedule};

/// Typed front-door failures: the schedule/expression contract violations
/// [`compile`] rejects before any lowering happens.
#[derive(Debug, Error)]
pub enum CompileError {
    /// The schedule was built for a different algebra than the one the
    /// caller asked to compile.
    #[error("schedule compiles `{scheduled}`, not the requested `{requested}`")]
    AlgebraMismatch { requested: String, scheduled: String },
    /// The grouped reduction is bound to a schedule variable none of whose
    /// source dimensions is a reduction dimension of the expression — the
    /// group would "optimize" a dimension that is never reduced.
    #[error(
        "grouped reduction bound to `{var}` (derived from [{roots}]), but the \
         reduction dims of `{algebra}` are [{reduction}]"
    )]
    GroupOnNonReductionDim { var: String, roots: String, algebra: String, reduction: String },
    /// The expression is not a sparse-dense hybrid (Eq. 1: exactly one
    /// sparse operand) — nothing in the §3 space applies to it.
    #[error("`{algebra}` is not a sparse-dense hybrid (exactly one sparse operand required)")]
    NotHybrid { algebra: String },
    /// The requested family does not lower the given algebra.
    #[error("family `{family}` is not a legal schedule family for `{algebra}`")]
    IllegalFamily { family: Family, algebra: String },
    /// The family and the config kind disagree (e.g. an SpMM family with
    /// an SDDMM config).
    #[error("family `{family}` cannot be built from a {config} config")]
    ConfigMismatch { family: Family, config: &'static str },
    /// The producer→consumer pair violates the fusion legality rule: the
    /// consumer must read the producer's output only at the nnz
    /// coordinates the producer wrote (same index order, same level
    /// formats), or the fused single-pass traversal would read values the
    /// producer never stored.
    #[error("illegal fusion of `{pair}`: {reason}")]
    IllegalFusion { pair: String, reason: String },
    /// The schedule agreed with its algebra but failed to lower
    /// (unsupported shape or invalid tuning config).
    #[error(transparent)]
    Lower(#[from] LowerError),
}

/// Compile a tensor algebra expression under a schedule.
///
/// The single public entry point of the middle-end: validates that
/// `schedule` actually lowers `algebra` (same statement, grouped
/// reduction on a genuine reduction dimension), then runs the
/// classification → [`Schedule::reduction_plan`] → emission pipeline of
/// [`lower`]. Returns the LLIR kernel, or a typed [`CompileError`].
pub fn compile(algebra: &TensorAlgebra, schedule: &Schedule) -> Result<Kernel, CompileError> {
    let scheduled = schedule.algebra();
    if &scheduled != algebra {
        return Err(CompileError::AlgebraMismatch {
            requested: algebra.to_string(),
            scheduled: scheduled.to_string(),
        });
    }
    check_group_dims(algebra, schedule)?;
    Ok(lower(schedule)?)
}

/// The schedule/expression agreement check on the reduction axis: the
/// grouped variable's provenance roots must intersect the expression's
/// reduction dimensions.
fn check_group_dims(algebra: &TensorAlgebra, schedule: &Schedule) -> Result<(), CompileError> {
    if let Some((var, _)) = schedule.group_binding() {
        let roots = schedule.roots_of(&var);
        let reduction = algebra.reduction_dims();
        if !roots.iter().any(|r| reduction.contains(r)) {
            return Err(CompileError::GroupOnNonReductionDim {
                var: var.to_string(),
                roots: join(&roots),
                algebra: algebra.to_string(),
                reduction: join(&reduction),
            });
        }
    }
    Ok(())
}

fn join(vars: &[IndexVar]) -> String {
    vars.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
}

/// Verify a producer→consumer pair's fusion legality and flatten it into
/// the single statement the fused families lower. This is the typed front
/// door for fusion: an illegal pair (consumer reading coordinates the
/// producer never wrote, mismatched level formats, a missing or
/// double-read intermediate) is a [`CompileError::IllegalFusion`] naming
/// the broken rule — never a panic, never a silent miscompile.
pub fn flatten_fused(pair: &FusedAlgebra) -> Result<TensorAlgebra, CompileError> {
    pair.flatten().map_err(|reason| CompileError::IllegalFusion { pair: pair.to_string(), reason })
}

/// Expression-first schedule construction: derives the legal schedule
/// families of a tensor algebra and builds validated [`Schedule`]s from a
/// [`KernelConfig`], so group sizes are always checked against the
/// expression's `reduction_dims()` before anything lowers.
pub struct ScheduleBuilder {
    algebra: TensorAlgebra,
}

impl ScheduleBuilder {
    /// Start from an algebra. Rejects expressions outside Eq. 1's
    /// sparse-dense hybrid class — the only inputs the §3 space covers.
    pub fn new(algebra: &TensorAlgebra) -> Result<ScheduleBuilder, CompileError> {
        if !algebra.is_sparse_dense_hybrid() {
            return Err(CompileError::NotHybrid { algebra: algebra.to_string() });
        }
        Ok(ScheduleBuilder { algebra: algebra.clone() })
    }

    pub fn algebra(&self) -> &TensorAlgebra {
        &self.algebra
    }

    /// The schedule families that lower this algebra. The quartet maps to:
    /// SpMM → the four §6 families plus the dgSPARSE RB+PR library shape;
    /// SDDMM → the §4.3 grouped dot reduction; MTTKRP/TTM → the COO-3
    /// nnz-split segment reductions. Unknown (but hybrid) algebras have no
    /// families yet — an empty list, not a guess.
    pub fn legal_families(&self) -> Vec<Family> {
        if self.algebra == TensorAlgebra::spmm() {
            vec![
                Family::NnzSerial,
                Family::RowSerial,
                Family::RowGroup,
                Family::NnzGroup,
                Family::DgRowBalanced,
            ]
        } else if self.algebra == TensorAlgebra::sddmm() {
            vec![Family::SddmmGroup]
        } else if self.algebra == TensorAlgebra::mttkrp() {
            vec![Family::MttkrpGroup]
        } else if self.algebra == TensorAlgebra::ttm() {
            vec![Family::TtmGroup]
        } else if self.algebra == TensorAlgebra::fused_sddmm_spmm() {
            vec![Family::FusedSddmmSpmm]
        } else {
            vec![]
        }
    }

    /// Build the schedule of `family` from `config`, validated against
    /// this builder's algebra (family legality, config kind, and the
    /// grouped-reduction dimension check).
    pub fn schedule(&self, family: Family, config: KernelConfig) -> Result<Schedule, CompileError> {
        if !self.legal_families().contains(&family) {
            return Err(CompileError::IllegalFamily { family, algebra: self.algebra.to_string() });
        }
        let schedule = match (family, config) {
            (Family::NnzSerial, KernelConfig::Spmm(c)) => Schedule::taco_nnz_serial(c),
            (Family::RowSerial, KernelConfig::Spmm(c)) => Schedule::taco_row_serial(c),
            (Family::RowGroup, KernelConfig::Spmm(c)) => Schedule::sgap_row_group(c, c.r),
            (Family::NnzGroup, KernelConfig::Spmm(c)) => Schedule::sgap_nnz_group(c, c.r),
            (Family::SddmmGroup, KernelConfig::Sddmm(c)) => Schedule::sddmm_group(c),
            (Family::DgRowBalanced, KernelConfig::Dg(c)) => Schedule::dgsparse_rb_pr(c),
            (Family::MttkrpGroup, KernelConfig::Mttkrp(c)) => Schedule::mttkrp_group(c),
            (Family::TtmGroup, KernelConfig::Ttm(c)) => Schedule::ttm_group(c),
            (Family::FusedSddmmSpmm, KernelConfig::Fused(c)) => Schedule::fused_sddmm_spmm(c),
            (family, config) => {
                return Err(CompileError::ConfigMismatch { family, config: config.kind() })
            }
        };
        check_group_dims(&self.algebra, &schedule)?;
        Ok(schedule)
    }

    /// Convenience: build the schedule and compile it in one step.
    pub fn compile(&self, family: Family, config: KernelConfig) -> Result<Kernel, CompileError> {
        let schedule = self.schedule(family, config)?;
        compile(&self.algebra, &schedule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::expr::{Access, Expr, TensorVar};
    use crate::compiler::schedule::{
        DgConfig, FusedConfig, MttkrpConfig, ScheduleCmd, SddmmConfig, SpmmConfig, TtmConfig,
    };

    #[test]
    fn the_quartet_compiles_through_the_front_door() {
        let cases: Vec<(TensorAlgebra, Schedule)> = vec![
            (TensorAlgebra::spmm(), Schedule::sgap_nnz_group(SpmmConfig::default(), 32)),
            (TensorAlgebra::spmm(), Schedule::taco_row_serial(SpmmConfig::default())),
            (TensorAlgebra::spmm(), Schedule::dgsparse_rb_pr(DgConfig::stock(16))),
            (TensorAlgebra::sddmm(), Schedule::sddmm_group(SddmmConfig::new(64, 16, 8))),
            (TensorAlgebra::mttkrp(), Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16))),
            (TensorAlgebra::ttm(), Schedule::ttm_group(TtmConfig::new(4, 4, 8))),
        ];
        for (algebra, schedule) in cases {
            compile(&algebra, &schedule)
                .unwrap_or_else(|e| panic!("`{algebra}` failed to compile: {e}"));
        }
    }

    #[test]
    fn algebra_mismatch_is_a_typed_error() {
        // an SDDMM schedule cannot claim to compile SpMM
        let err = compile(
            &TensorAlgebra::spmm(),
            &Schedule::sddmm_group(SddmmConfig::new(64, 16, 8)),
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::AlgebraMismatch { .. }), "{err}");
        // ... nor can a TTM schedule compile MTTKRP, even though both
        // lower the same COO-3 segment shape
        let err = compile(
            &TensorAlgebra::mttkrp(),
            &Schedule::ttm_group(TtmConfig::new(4, 4, 8)),
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::AlgebraMismatch { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("Y(i,j,l)") && msg.contains("Y(i,j)"), "{msg}");
    }

    #[test]
    fn group_on_a_non_reduction_dim_is_a_typed_error() {
        // sabotage Listing 5: move the grouped reduction from jpos1 (roots
        // to j, the reduction dim) onto kii (roots to the fused output
        // dims i,k) — stock lowering would silently emit the RowGroup
        // kernel anyway; compile refuses
        let mut s = Schedule::sgap_row_group(SpmmConfig::default(), 8);
        for cmd in &mut s.cmds {
            if let ScheduleCmd::ParallelizeGroup { var, .. } = cmd {
                *var = IndexVar::new("kii");
            }
        }
        let err = compile(&TensorAlgebra::spmm(), &s).unwrap_err();
        assert!(matches!(err, CompileError::GroupOnNonReductionDim { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("kii") && msg.contains('j'), "{msg}");
    }

    #[test]
    fn builder_derives_legal_families_per_algebra() {
        let spmm = ScheduleBuilder::new(&TensorAlgebra::spmm()).unwrap();
        let fams = spmm.legal_families();
        assert_eq!(fams.len(), 5);
        assert!(fams.contains(&Family::NnzGroup) && fams.contains(&Family::DgRowBalanced));
        assert_eq!(
            ScheduleBuilder::new(&TensorAlgebra::mttkrp()).unwrap().legal_families(),
            vec![Family::MttkrpGroup]
        );
        assert_eq!(
            ScheduleBuilder::new(&TensorAlgebra::ttm()).unwrap().legal_families(),
            vec![Family::TtmGroup]
        );
        assert_eq!(
            ScheduleBuilder::new(&TensorAlgebra::sddmm()).unwrap().legal_families(),
            vec![Family::SddmmGroup]
        );
    }

    #[test]
    fn builder_compiles_every_family_it_names() {
        let statements = [
            TensorAlgebra::spmm(),
            TensorAlgebra::sddmm(),
            TensorAlgebra::mttkrp(),
            TensorAlgebra::ttm(),
            TensorAlgebra::fused_sddmm_spmm(),
        ];
        for algebra in statements {
            let b = ScheduleBuilder::new(&algebra).unwrap();
            for family in b.legal_families() {
                let config = match family {
                    Family::NnzSerial | Family::RowSerial | Family::RowGroup | Family::NnzGroup => {
                        KernelConfig::Spmm(SpmmConfig { r: 8, ..SpmmConfig::default() })
                    }
                    Family::DgRowBalanced => KernelConfig::Dg(DgConfig::stock(16)),
                    Family::SddmmGroup => KernelConfig::Sddmm(SddmmConfig::new(32, 16, 8)),
                    Family::MttkrpGroup => KernelConfig::Mttkrp(MttkrpConfig::new(8, 4, 16)),
                    Family::TtmGroup => KernelConfig::Ttm(TtmConfig::new(4, 4, 8)),
                    Family::FusedSddmmSpmm => KernelConfig::Fused(FusedConfig::new(32, 4, 4, 8)),
                };
                b.compile(family, config)
                    .unwrap_or_else(|e| panic!("`{algebra}` family {family}: {e}"));
            }
        }
    }

    #[test]
    fn builder_rejects_illegal_family_and_mismatched_config() {
        let b = ScheduleBuilder::new(&TensorAlgebra::mttkrp()).unwrap();
        let err = b
            .schedule(Family::NnzGroup, KernelConfig::Spmm(SpmmConfig::default()))
            .unwrap_err();
        assert!(matches!(err, CompileError::IllegalFamily { .. }), "{err}");
        let spmm = ScheduleBuilder::new(&TensorAlgebra::spmm()).unwrap();
        let err = spmm
            .schedule(Family::NnzGroup, KernelConfig::Sddmm(SddmmConfig::new(16, 8, 4)))
            .unwrap_err();
        assert!(matches!(err, CompileError::ConfigMismatch { .. }), "{err}");
    }

    #[test]
    fn fused_pair_compiles_through_the_front_door() {
        let pair = FusedAlgebra::sddmm_spmm();
        let algebra = flatten_fused(&pair).unwrap();
        let b = ScheduleBuilder::new(&algebra).unwrap();
        assert_eq!(b.legal_families(), vec![Family::FusedSddmmSpmm]);
        let kernel = b
            .compile(Family::FusedSddmmSpmm, KernelConfig::Fused(FusedConfig::new(32, 4, 4, 16)))
            .unwrap();
        assert!(kernel.name.starts_with("fused_sddmm_spmm"), "{}", kernel.name);
    }

    #[test]
    fn illegal_fusion_is_a_typed_error() {
        // sabotage the consumer: read the intermediate transposed, i.e. at
        // coordinates the producer never wrote
        let mut pair = FusedAlgebra::sddmm_spmm();
        pair.consumer.rhs = Expr::Mul(
            Box::new(Expr::Access(Access::new("Y", &["j", "i"]))),
            Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
        );
        let err = flatten_fused(&pair).unwrap_err();
        assert!(matches!(err, CompileError::IllegalFusion { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("illegal fusion") && msg.contains("Y(j,i)"), "{msg}");
    }

    #[test]
    fn non_hybrid_expressions_are_rejected() {
        // two sparse operands: outside Eq. 1's class
        let alg = TensorAlgebra {
            lhs: Access::new("C", &["i", "k"]),
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
            ),
            tensors: vec![TensorVar::csr("A", 2), TensorVar::csr("B", 2)],
        };
        let err = ScheduleBuilder::new(&alg).unwrap_err();
        assert!(matches!(err, CompileError::NotHybrid { .. }), "{err}");
    }

    #[test]
    fn invalid_configs_surface_as_lower_errors() {
        // the front door forwards config validation as a typed Lower error
        let err = compile(
            &TensorAlgebra::mttkrp(),
            &Schedule::mttkrp_group(MttkrpConfig::new(8, 3, 16)),
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::Lower(LowerError::InvalidConfig(_))), "{err}");
    }
}
