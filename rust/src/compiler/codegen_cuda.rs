//! CUDA-like text emission from LLIR (§2.4.3 back-end).
//!
//! Produces compilable-looking CUDA C for inspection, docs, and the golden
//! tests that check the Listing 1 → Listing 2 transformation (and, since
//! SDDMM/dgSPARSE lower through the shared pipeline, their generated
//! kernels too — see `rust/tests/golden/`). The two macro instructions
//! are emitted as calls to the §5.3 template device functions
//! `atomicAddGroup<T,G>` / `segReduceGroup<T,G>`, whose definitions are
//! emitted in a header prologue.

use std::fmt::Write;

use super::llir::{Kernel, Param, ParamKind, Stmt};

/// The §5.3 macro-instruction header (cooperative-groups implementation).
pub fn macro_header() -> &'static str {
    r#"// --- sgap macro instructions (§5.3) ------------------------------------
// atomicAddGroup<T,G>: tree-reduce `value` over each aligned G-lane group
// with __shfl_down_sync, then lane 0 of the group issues one atomicAdd.
template <typename T, int G>
__device__ __forceinline__ void atomicAddGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  #pragma unroll
  for (int offset = G / 2; offset > 0; offset /= 2)
    value += __shfl_down_sync(mask, value, offset, G);
  if ((threadIdx.x % G) == 0) atomicAdd(&array[idx], value);
}

// segReduceGroup<T,G>: segmented inclusive scan over each aligned G-lane
// group keyed by `idx`; segment-end lanes write back (runtime-decided
// writeback threads — segment reduction).
template <typename T, int G>
__device__ __forceinline__ void segReduceGroup(T* array, int idx, T value) {
  unsigned mask = __activemask();
  int lane = threadIdx.x % G;
  #pragma unroll
  for (int offset = 1; offset < G; offset *= 2) {
    T up = __shfl_up_sync(mask, value, offset, G);
    int upIdx = __shfl_up_sync(mask, idx, offset, G);
    if (lane >= offset && upIdx == idx) value += up;
  }
  int dnIdx = __shfl_down_sync(mask, idx, 1, G);
  if (lane == G - 1 || dnIdx != idx) atomicAdd(&array[idx], value);
}
// ------------------------------------------------------------------------
"#
}

fn param_decl(p: &Param) -> String {
    match p.kind {
        ParamKind::ArrayF32 => format!("float* __restrict__ {}", p.name),
        ParamKind::ArrayI32 => format!("int* __restrict__ {}", p.name),
        ParamKind::ScalarI32 => format!("int {}", p.name),
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn emit_stmts(out: &mut String, stmts: &[Stmt], depth: usize) {
    for s in stmts {
        emit_stmt(out, s, depth);
    }
}

fn emit_stmt(out: &mut String, s: &Stmt, depth: usize) {
    indent(out, depth);
    match s {
        Stmt::Decl { var, init, float } => {
            let ty = if *float { "float" } else { "int" };
            writeln!(out, "{ty} {var} = {init};").unwrap();
        }
        Stmt::Assign { var, val } => writeln!(out, "{var} = {val};").unwrap(),
        Stmt::Store { array, idx, val } => writeln!(out, "{array}[{idx}] = {val};").unwrap(),
        Stmt::AtomicAdd { array, idx, val } => {
            writeln!(out, "atomicAdd(&{array}[{idx}], {val});").unwrap()
        }
        Stmt::AtomicAddGroup { array, idx, val, group } => {
            writeln!(out, "atomicAddGroup<float,{group}>({array}, {idx}, {val});").unwrap()
        }
        Stmt::SegReduceGroup { array, idx, val, group } => {
            writeln!(out, "segReduceGroup<float,{group}>({array}, {idx}, {val});").unwrap()
        }
        Stmt::For { var, lo, hi, step, body } => {
            writeln!(out, "for (int {var} = {lo}; {var} < {hi}; {var} += {step}) {{").unwrap();
            emit_stmts(out, body, depth + 1);
            indent(out, depth);
            writeln!(out, "}}").unwrap();
        }
        Stmt::While { cond, body } => {
            writeln!(out, "while ({cond}) {{").unwrap();
            emit_stmts(out, body, depth + 1);
            indent(out, depth);
            writeln!(out, "}}").unwrap();
        }
        Stmt::If { cond, then, els } => {
            writeln!(out, "if ({cond}) {{").unwrap();
            emit_stmts(out, then, depth + 1);
            indent(out, depth);
            if els.is_empty() {
                writeln!(out, "}}").unwrap();
            } else {
                writeln!(out, "}} else {{").unwrap();
                emit_stmts(out, els, depth + 1);
                indent(out, depth);
                writeln!(out, "}}").unwrap();
            }
        }
        Stmt::Break => writeln!(out, "break;").unwrap(),
        Stmt::Comment(c) => writeln!(out, "// {c}").unwrap(),
    }
}

/// Emit the kernel as CUDA-like source text (without the macro header).
pub fn emit_kernel(k: &Kernel) -> String {
    let mut out = String::new();
    let params: Vec<String> = k.params.iter().map(param_decl).collect();
    writeln!(out, "__global__ void {}({}) {{", k.name, params.join(", ")).unwrap();
    emit_stmts(&mut out, &k.body, 1);
    writeln!(out, "}}").unwrap();
    out
}

/// Full translation unit: header + kernel.
pub fn emit_translation_unit(k: &Kernel) -> String {
    format!("{}\n{}", macro_header(), emit_kernel(k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::{Schedule, SpmmConfig};

    /// Golden check for the Listing 1 → Listing 2 transformation: stock
    /// lowering uses `atomicAdd` inside the loop; segment-group lowering
    /// replaces it with `segReduceGroup` and adds the zero-extension
    /// if/else around the workspace assignment.
    #[test]
    fn listing1_vs_listing2() {
        let orig = emit_kernel(&crate::compiler::lower(&Schedule::taco_nnz_serial(SpmmConfig::default())).unwrap());
        let seg = emit_kernel(&crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap());

        assert!(orig.contains("atomicAdd(&C_vals["));
        assert!(!orig.contains("segReduceGroup"));

        assert!(seg.contains("segReduceGroup<float,32>(C_vals, kC, val);"));
        assert!(!seg.contains("atomicAdd(&C_vals["));
        assert!(seg.contains("taco_binarySearchBefore(A2_pos, pA2_begin, pA2_end, fposA)"));
        // zero extension: val assigned 0 in the then-branch
        assert!(seg.contains("if ((fposA >= A2_pos[A1_dimension])) {"), "{seg}");
        assert!(seg.contains("float val = 0.0f;"));
    }

    #[test]
    fn row_group_emits_atomic_add_group_call() {
        let k = crate::compiler::lower(&Schedule::sgap_row_group(SpmmConfig::default(), 8)).unwrap();
        let src = emit_kernel(&k);
        assert!(src.contains("atomicAddGroup<float,8>(C_vals,"));
        assert!(src.contains("__global__ void spmm_row_group_g32_c4_r8"));
    }

    #[test]
    fn header_defines_both_macros() {
        let h = macro_header();
        assert!(h.contains("atomicAddGroup"));
        assert!(h.contains("segReduceGroup"));
        assert!(h.contains("__shfl_down_sync"));
        assert!(h.contains("__shfl_up_sync"));
    }

    #[test]
    fn translation_unit_composes() {
        let k = crate::compiler::lower(&Schedule::taco_row_serial(SpmmConfig::default())).unwrap();
        let tu = emit_translation_unit(&k);
        assert!(tu.contains("template <typename T, int G>"));
        assert!(tu.contains("__global__ void spmm_row_serial"));
    }
}
