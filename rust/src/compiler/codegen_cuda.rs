//! CUDA text emission from LLIR (§2.4.3 back-end) — the [`Cuda`]
//! instantiation of the dialect-generic walk in
//! [`dialect::emit`](super::dialect::emit).
//!
//! Produces compilable-looking CUDA C for inspection, docs, and the
//! golden tests that check the Listing 1 → Listing 2 transformation
//! (and, since SDDMM/dgSPARSE lower through the shared pipeline, their
//! generated kernels too — see `rust/tests/golden/`). The two macro
//! instructions are emitted as calls to the §5.3 template device
//! functions `atomicAddGroup<T,G>` / `segReduceGroup<T,G>`; the
//! translation-unit prologue defines exactly the templates the kernel
//! references (none for pure-store or plain-atomic lowerings).
//!
//! This module is a byte-compatibility shim: its output is pinned by the
//! committed `.cu` goldens, and `emit_kernel` here must stay identical
//! to `dialect::emit::emit_kernel::<Cuda>` — which it now simply calls.

pub use super::dialect::cuda::macro_header;

use super::dialect::{emit, Cuda};
use super::llir::Kernel;

/// Emit the kernel as CUDA-like source text (without the macro header).
pub fn emit_kernel(k: &Kernel) -> String {
    emit::emit_kernel::<Cuda>(k)
}

/// Full translation unit: the §5.3 helpers the kernel references (if
/// any), then the kernel.
pub fn emit_translation_unit(k: &Kernel) -> String {
    emit::emit_translation_unit::<Cuda>(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::{Schedule, SpmmConfig};

    /// Golden check for the Listing 1 → Listing 2 transformation: stock
    /// lowering uses `atomicAdd` inside the loop; segment-group lowering
    /// replaces it with `segReduceGroup` and adds the zero-extension
    /// if/else around the workspace assignment.
    #[test]
    fn listing1_vs_listing2() {
        let orig = emit_kernel(&crate::compiler::lower(&Schedule::taco_nnz_serial(SpmmConfig::default())).unwrap());
        let seg = emit_kernel(&crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap());

        assert!(orig.contains("atomicAdd(&C_vals["));
        assert!(!orig.contains("segReduceGroup"));

        assert!(seg.contains("segReduceGroup<float,32>(C_vals, kC, val);"));
        assert!(!seg.contains("atomicAdd(&C_vals["));
        assert!(seg.contains("taco_binarySearchBefore(A2_pos, pA2_begin, pA2_end, fposA)"));
        // zero extension: val assigned 0 in the then-branch
        assert!(seg.contains("if ((fposA >= A2_pos[A1_dimension])) {"), "{seg}");
        assert!(seg.contains("float val = 0.0f;"));
    }

    #[test]
    fn row_group_emits_atomic_add_group_call() {
        let k = crate::compiler::lower(&Schedule::sgap_row_group(SpmmConfig::default(), 8)).unwrap();
        let src = emit_kernel(&k);
        assert!(src.contains("atomicAddGroup<float,8>(C_vals,"));
        assert!(src.contains("__global__ void spmm_row_group_g32_c4_r8"));
    }

    #[test]
    fn header_defines_both_macros() {
        let h = macro_header();
        assert!(h.contains("atomicAddGroup"));
        assert!(h.contains("segReduceGroup"));
        assert!(h.contains("__shfl_down_sync"));
        assert!(h.contains("__shfl_up_sync"));
    }

    /// The translation unit defines only the referenced helpers: none
    /// for a store-only kernel, exactly one template for each grouped
    /// family (no dead `atomicAddGroup` next to a segment reduction).
    #[test]
    fn translation_unit_emits_only_referenced_helpers() {
        let row = crate::compiler::lower(&Schedule::taco_row_serial(SpmmConfig::default())).unwrap();
        let tu = emit_translation_unit(&row);
        assert!(!tu.contains("template <typename T, int G>"));
        assert!(tu.starts_with("__global__ void spmm_row_serial"));

        let seg = crate::compiler::lower(&Schedule::sgap_nnz_group(SpmmConfig::default(), 32)).unwrap();
        let tu = emit_translation_unit(&seg);
        assert!(tu.contains("void segReduceGroup") && !tu.contains("void atomicAddGroup"));
        assert!(tu.contains("segReduceGroup<float,32>(C_vals, kC, val);"));

        let grp = crate::compiler::lower(&Schedule::sgap_row_group(SpmmConfig::default(), 8)).unwrap();
        let tu = emit_translation_unit(&grp);
        assert!(tu.contains("void atomicAddGroup") && !tu.contains("void segReduceGroup"));
    }
}
