//! Tensor algebra expressions — the compiler front-end input (§2.1).
//!
//! An expression in Einstein notation, e.g. SpMM `C(i,k) = A(i,j) * B(j,k)`,
//! plus per-tensor level formats. The reduction analysis here is what makes
//! atomic parallelism general: the *reduction dimensions* (index vars on the
//! right not appearing on the left) are the objects segment group optimizes,
//! for any sparse-dense hybrid algebra (SpMM, SDDMM, MTTKRP, TTM).

use std::fmt;

/// A named index variable (`i`, `j`, `jpos1`, …).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IndexVar(pub String);

impl IndexVar {
    pub fn new(s: &str) -> Self {
        IndexVar(s.to_string())
    }
}

impl fmt::Display for IndexVar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Per-dimension storage format (TACO level formats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LevelFormat {
    Dense,
    Compressed,
    /// One coordinate stored per parent position (COO trailing levels).
    Singleton,
}

impl LevelFormat {
    /// Whether the level stores coordinates (vs a dense range) — any such
    /// level makes the tensor sparse.
    pub fn is_sparse(self) -> bool {
        matches!(self, LevelFormat::Compressed | LevelFormat::Singleton)
    }
}

/// A tensor variable with its per-level formats.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorVar {
    pub name: String,
    pub formats: Vec<LevelFormat>,
}

impl TensorVar {
    pub fn dense(name: &str, order: usize) -> Self {
        TensorVar { name: name.into(), formats: vec![LevelFormat::Dense; order] }
    }

    /// CSR-like: first level dense, rest compressed.
    pub fn csr(name: &str, order: usize) -> Self {
        let mut formats = vec![LevelFormat::Compressed; order];
        formats[0] = LevelFormat::Dense;
        TensorVar { name: name.into(), formats }
    }

    /// Coordinate format: a compressed leading level with singleton
    /// trailing levels — what the runtime's `Coo3` actually stores for the
    /// MTTKRP/TTM operand (every level holds coordinates; no level is a
    /// dense range).
    pub fn coo(name: &str, order: usize) -> Self {
        let mut formats = vec![LevelFormat::Singleton; order];
        formats[0] = LevelFormat::Compressed;
        TensorVar { name: name.into(), formats }
    }

    pub fn order(&self) -> usize {
        self.formats.len()
    }

    pub fn is_sparse(&self) -> bool {
        self.formats.iter().any(|f| f.is_sparse())
    }
}

/// A tensor access like `A(i,j)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Access {
    pub tensor: String,
    pub indices: Vec<IndexVar>,
}

impl Access {
    pub fn new(tensor: &str, indices: &[&str]) -> Self {
        Access { tensor: tensor.into(), indices: indices.iter().map(|s| IndexVar::new(s)).collect() }
    }
}

impl fmt::Display for Access {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.indices.is_empty() {
            // scalar workspace access, e.g. `tmp`
            return write!(f, "{}", self.tensor);
        }
        let idx: Vec<String> = self.indices.iter().map(|i| i.to_string()).collect();
        write!(f, "{}({})", self.tensor, idx.join(","))
    }
}

/// Right-hand-side expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Access(Access),
    Mul(Box<Expr>, Box<Expr>),
    Add(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn accesses(&self) -> Vec<&Access> {
        match self {
            Expr::Access(a) => vec![a],
            Expr::Mul(l, r) | Expr::Add(l, r) => {
                let mut v = l.accesses();
                v.extend(r.accesses());
                v
            }
        }
    }

    pub fn index_vars(&self) -> Vec<IndexVar> {
        let mut vars: Vec<IndexVar> = Vec::new();
        for a in self.accesses() {
            for i in &a.indices {
                if !vars.contains(i) {
                    vars.push(i.clone());
                }
            }
        }
        vars
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Access(a) => write!(f, "{a}"),
            Expr::Mul(l, r) => write!(f, "{l}*{r}"),
            Expr::Add(l, r) => write!(f, "{l}+{r}"),
        }
    }
}

/// A full tensor algebra statement `lhs = rhs` with tensor declarations.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorAlgebra {
    pub lhs: Access,
    pub rhs: Expr,
    pub tensors: Vec<TensorVar>,
}

impl TensorAlgebra {
    /// Reduction dimensions: index vars of the rhs absent from the lhs —
    /// the `⊕` dimensions of Eq. 3, and segment group's target.
    pub fn reduction_dims(&self) -> Vec<IndexVar> {
        self.rhs.index_vars().into_iter().filter(|v| !self.lhs.indices.contains(v)).collect()
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorVar> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Sparse-dense hybrid check: exactly one sparse operand, rest dense
    /// (Eq. 1's definition).
    pub fn is_sparse_dense_hybrid(&self) -> bool {
        let rhs_tensors: Vec<&str> =
            self.rhs.accesses().iter().map(|a| a.tensor.as_str()).collect();
        let sparse = rhs_tensors
            .iter()
            .filter(|n| self.tensor(n).map(|t| t.is_sparse()).unwrap_or(false))
            .count();
        sparse == 1
    }

    // ---- the four algebras of Eq. 2 -------------------------------------

    /// SpMM (Eq. 2d): `C(i,k) = A(i,j) * B(j,k)`, A CSR, B/C dense row-major.
    pub fn spmm() -> Self {
        TensorAlgebra {
            lhs: Access::new("C", &["i", "k"]),
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
            ),
            tensors: vec![TensorVar::csr("A", 2), TensorVar::dense("B", 2), TensorVar::dense("C", 2)],
        }
    }

    /// SDDMM (Eq. 2c): `Y(i,k) = A(i,k) * X1(i,j) * X2(j,k)`.
    pub fn sddmm() -> Self {
        TensorAlgebra {
            lhs: Access::new("Y", &["i", "k"]),
            rhs: Expr::Mul(
                Box::new(Expr::Mul(
                    Box::new(Expr::Access(Access::new("A", &["i", "k"]))),
                    Box::new(Expr::Access(Access::new("X1", &["i", "j"]))),
                )),
                Box::new(Expr::Access(Access::new("X2", &["j", "k"]))),
            ),
            tensors: vec![
                TensorVar::csr("A", 2),
                TensorVar::dense("X1", 2),
                TensorVar::dense("X2", 2),
                TensorVar::csr("Y", 2),
            ],
        }
    }

    /// MTTKRP (Eq. 2a): `Y(i,j) = A(i,k,l) * X1(k,j) * X2(l,j)`, A in
    /// coordinate format (the runtime stores it as `sparse::coo3::Coo3`).
    pub fn mttkrp() -> Self {
        TensorAlgebra {
            lhs: Access::new("Y", &["i", "j"]),
            rhs: Expr::Mul(
                Box::new(Expr::Mul(
                    Box::new(Expr::Access(Access::new("A", &["i", "k", "l"]))),
                    Box::new(Expr::Access(Access::new("X1", &["k", "j"]))),
                )),
                Box::new(Expr::Access(Access::new("X2", &["l", "j"]))),
            ),
            tensors: vec![
                TensorVar::coo("A", 3),
                TensorVar::dense("X1", 2),
                TensorVar::dense("X2", 2),
                TensorVar::dense("Y", 2),
            ],
        }
    }

    /// TTM (Eq. 2b): `Y(i,j,l) = A(i,j,k) * X1(k,l)`, A in coordinate
    /// format (the runtime stores it as `sparse::coo3::Coo3`).
    pub fn ttm() -> Self {
        TensorAlgebra {
            lhs: Access::new("Y", &["i", "j", "l"]),
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("A", &["i", "j", "k"]))),
                Box::new(Expr::Access(Access::new("X1", &["k", "l"]))),
            ),
            tensors: vec![TensorVar::coo("A", 3), TensorVar::dense("X1", 2), TensorVar::dense("Y", 3)],
        }
    }

    /// The flattened fused attention algebra (SDDMM→SpMM, one statement):
    /// `C(i,k) = A(i,j) * X1(i,l) * X2(l,j) * B(j,k)` — the result of
    /// [`FusedAlgebra::sddmm_spmm`]'s producer substituted into its
    /// consumer. One sparse operand (`A`, CSR), reduction dims `[j, l]`.
    pub fn fused_sddmm_spmm() -> Self {
        FusedAlgebra::sddmm_spmm()
            .flatten()
            .expect("the canonical attention pair is fusion-legal by construction")
    }
}

impl fmt::Display for TensorAlgebra {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} = {}", self.lhs, self.rhs)
    }
}

/// A producer→consumer pair of tensor algebras sharing index variables —
/// the fusion candidate of SparseLNR-style loop-nest restructuring. The
/// producer writes an intermediate tensor (e.g. SDDMM's `Y`); the consumer
/// reads it as its sparse operand (e.g. SpMM over `Y`). When the pair is
/// [legal](FusedAlgebra::check_legal), [`FusedAlgebra::flatten`]
/// substitutes the producer's expression into the consumer, yielding one
/// statement the scheduler can lower as a *single* kernel: the producer's
/// reduction computed in-register per nonzero and consumed immediately,
/// with no materialized intermediate.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedAlgebra {
    pub producer: TensorAlgebra,
    pub consumer: TensorAlgebra,
}

impl FusedAlgebra {
    pub fn new(producer: TensorAlgebra, consumer: TensorAlgebra) -> Self {
        FusedAlgebra { producer, consumer }
    }

    /// The canonical graph-attention pair: SDDMM producer
    /// `Y(i,j) = A(i,j) * X1(i,l) * X2(l,j)` feeding SpMM consumer
    /// `C(i,k) = Y(i,j) * B(j,k)`, with `Y` inheriting `A`'s CSR
    /// structure (the SDDMM output is written only at `A`'s nonzeros).
    pub fn sddmm_spmm() -> Self {
        let producer = TensorAlgebra {
            lhs: Access::new("Y", &["i", "j"]),
            rhs: Expr::Mul(
                Box::new(Expr::Mul(
                    Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                    Box::new(Expr::Access(Access::new("X1", &["i", "l"]))),
                )),
                Box::new(Expr::Access(Access::new("X2", &["l", "j"]))),
            ),
            tensors: vec![
                TensorVar::csr("A", 2),
                TensorVar::dense("X1", 2),
                TensorVar::dense("X2", 2),
                TensorVar::csr("Y", 2),
            ],
        };
        let consumer = TensorAlgebra {
            lhs: Access::new("C", &["i", "k"]),
            rhs: Expr::Mul(
                Box::new(Expr::Access(Access::new("Y", &["i", "j"]))),
                Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
            ),
            tensors: vec![
                TensorVar::csr("Y", 2),
                TensorVar::dense("B", 2),
                TensorVar::dense("C", 2),
            ],
        };
        FusedAlgebra { producer, consumer }
    }

    /// The dependence check fusion legality rests on (WingSpan's
    /// question): the consumer may read the producer's output **only at
    /// the nonzero coordinates the producer wrote**. Concretely:
    ///
    /// 1. the producer is a sparse-dense hybrid whose output access uses
    ///    exactly its sparse operand's index variables (so it writes one
    ///    value per stored nonzero, nothing else),
    /// 2. the producer's output is declared with its sparse operand's
    ///    level formats (same stored coordinate set),
    /// 3. the consumer reads the output tensor exactly once, at exactly
    ///    the producer's written indices (no transpose, no re-indexing),
    ///    and declares it with the same formats.
    ///
    /// Violations return a description of the broken rule; `compile`
    /// wraps them as `CompileError::IllegalFusion`.
    pub fn check_legal(&self) -> Result<(), String> {
        let out = &self.producer.lhs;
        if !self.producer.is_sparse_dense_hybrid() {
            return Err(format!(
                "producer `{}` is not a sparse-dense hybrid; its output has no \
                 single nnz coordinate set to fuse over",
                self.producer
            ));
        }
        let sparse_access = self
            .producer
            .rhs
            .accesses()
            .into_iter()
            .find(|a| self.producer.tensor(&a.tensor).map(|t| t.is_sparse()).unwrap_or(false))
            .expect("hybrid algebras have a sparse operand");
        if out.indices != sparse_access.indices {
            return Err(format!(
                "producer writes `{out}` but its sparse operand is `{sparse_access}`: \
                 the output is not confined to the operand's nnz coordinates"
            ));
        }
        let sparse_formats =
            &self.producer.tensor(&sparse_access.tensor).expect("declared operand").formats;
        match self.producer.tensor(&out.tensor) {
            Some(t) if &t.formats == sparse_formats => {}
            Some(_) => {
                return Err(format!(
                    "producer output `{}` is not stored with its sparse operand \
                     `{}`'s level formats — the written coordinate sets differ",
                    out.tensor, sparse_access.tensor
                ))
            }
            None => return Err(format!("producer never declares its output `{}`", out.tensor)),
        }
        let reads: Vec<&Access> = self
            .consumer
            .rhs
            .accesses()
            .into_iter()
            .filter(|a| a.tensor == out.tensor)
            .collect();
        let read = match reads.as_slice() {
            [one] => *one,
            [] => {
                return Err(format!(
                    "consumer `{}` never reads the producer's output `{}` — \
                     nothing to fuse",
                    self.consumer, out.tensor
                ))
            }
            _ => {
                return Err(format!(
                    "consumer reads the producer's output `{}` more than once; \
                     a single in-register value cannot serve multiple accesses",
                    out.tensor
                ))
            }
        };
        if read.indices != out.indices {
            return Err(format!(
                "consumer reads `{read}` but the producer writes `{out}`: the \
                 read coordinates are not the written nnz coordinates"
            ));
        }
        match self.consumer.tensor(&out.tensor) {
            Some(t) if t.formats == *sparse_formats => {}
            Some(_) => {
                return Err(format!(
                    "consumer declares `{}` with different level formats than \
                     the producer stores — the traversed coordinate sets differ",
                    out.tensor
                ))
            }
            None => {
                return Err(format!("consumer never declares the intermediate `{}`", out.tensor))
            }
        }
        Ok(())
    }

    /// Substitute the producer's expression for the consumer's read of the
    /// intermediate, yielding the single flattened statement a fused
    /// kernel lowers. Fails (with the violated rule) when the pair is not
    /// [legal](FusedAlgebra::check_legal).
    pub fn flatten(&self) -> Result<TensorAlgebra, String> {
        self.check_legal()?;
        let out = &self.producer.lhs;
        let rhs = substitute(&self.consumer.rhs, &out.tensor, &self.producer.rhs);
        let mut tensors: Vec<TensorVar> = Vec::new();
        for t in self.producer.tensors.iter().chain(self.consumer.tensors.iter()) {
            if t.name != out.tensor && !tensors.iter().any(|u| u.name == t.name) {
                tensors.push(t.clone());
            }
        }
        Ok(TensorAlgebra { lhs: self.consumer.lhs.clone(), rhs, tensors })
    }
}

/// Replace every access to `tensor` in `e` with `with`.
fn substitute(e: &Expr, tensor: &str, with: &Expr) -> Expr {
    match e {
        Expr::Access(a) if a.tensor == tensor => with.clone(),
        Expr::Access(a) => Expr::Access(a.clone()),
        Expr::Mul(l, r) => Expr::Mul(
            Box::new(substitute(l, tensor, with)),
            Box::new(substitute(r, tensor, with)),
        ),
        Expr::Add(l, r) => Expr::Add(
            Box::new(substitute(l, tensor, with)),
            Box::new(substitute(r, tensor, with)),
        ),
    }
}

impl fmt::Display for FusedAlgebra {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} where {}", self.consumer, self.producer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmm_reduces_over_j() {
        let a = TensorAlgebra::spmm();
        assert_eq!(a.reduction_dims(), vec![IndexVar::new("j")]);
        assert!(a.is_sparse_dense_hybrid());
        assert_eq!(a.to_string(), "C(i,k) = A(i,j)*B(j,k)");
    }

    #[test]
    fn sddmm_reduces_over_j() {
        let a = TensorAlgebra::sddmm();
        assert_eq!(a.reduction_dims(), vec![IndexVar::new("j")]);
    }

    #[test]
    fn mttkrp_reduces_over_k_l() {
        let a = TensorAlgebra::mttkrp();
        let dims = a.reduction_dims();
        assert!(dims.contains(&IndexVar::new("k")) && dims.contains(&IndexVar::new("l")));
        assert_eq!(dims.len(), 2);
        assert!(a.is_sparse_dense_hybrid());
    }

    #[test]
    fn ttm_reduces_over_k() {
        let a = TensorAlgebra::ttm();
        assert_eq!(a.reduction_dims(), vec![IndexVar::new("k")]);
    }

    #[test]
    fn csr_format_is_sparse() {
        assert!(TensorVar::csr("A", 2).is_sparse());
        assert!(!TensorVar::dense("B", 2).is_sparse());
    }

    #[test]
    fn fused_pair_flattens_to_one_statement() {
        let pair = FusedAlgebra::sddmm_spmm();
        pair.check_legal().unwrap();
        let flat = pair.flatten().unwrap();
        assert_eq!(flat, TensorAlgebra::fused_sddmm_spmm());
        assert_eq!(flat.to_string(), "C(i,k) = A(i,j)*X1(i,l)*X2(l,j)*B(j,k)");
        // one sparse operand, reduction over the shared j and the dot's l
        assert!(flat.is_sparse_dense_hybrid());
        assert_eq!(flat.reduction_dims(), vec![IndexVar::new("j"), IndexVar::new("l")]);
        // the intermediate is gone; the operands survive once each
        assert!(flat.tensor("Y").is_none());
        for t in ["A", "X1", "X2", "B", "C"] {
            assert!(flat.tensor(t).is_some(), "missing {t}");
        }
        assert!(pair.to_string().contains("where"));
    }

    #[test]
    fn illegal_fusions_name_the_broken_rule() {
        // transposed read: consumer asks for Y(j,i)
        let mut pair = FusedAlgebra::sddmm_spmm();
        pair.consumer.rhs = Expr::Mul(
            Box::new(Expr::Access(Access::new("Y", &["j", "i"]))),
            Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
        );
        let err = pair.check_legal().unwrap_err();
        assert!(err.contains("Y(j,i)"), "{err}");
        assert!(pair.flatten().is_err());

        // format mismatch: consumer declares the intermediate dense
        let mut pair = FusedAlgebra::sddmm_spmm();
        for t in &mut pair.consumer.tensors {
            if t.name == "Y" {
                *t = TensorVar::dense("Y", 2);
            }
        }
        let err = pair.check_legal().unwrap_err();
        assert!(err.contains("formats"), "{err}");

        // consumer never touches the producer's output
        let mut pair = FusedAlgebra::sddmm_spmm();
        pair.producer.lhs = Access::new("Z", &["i", "j"]);
        pair.producer.tensors.push(TensorVar::csr("Z", 2));
        let err = pair.check_legal().unwrap_err();
        assert!(err.contains("never reads"), "{err}");

        // producer writing outside its sparse operand's coordinates
        let mut pair = FusedAlgebra::sddmm_spmm();
        pair.producer.lhs = Access::new("Y", &["j", "i"]);
        let err = pair.check_legal().unwrap_err();
        assert!(err.contains("nnz coordinates"), "{err}");
    }

    #[test]
    fn coo_format_matches_the_runtime_storage() {
        // the MTTKRP/TTM operand is stored as Coo3: every level holds
        // coordinates, so no level may claim to be a dense range
        let a = TensorVar::coo("A", 3);
        assert_eq!(
            a.formats,
            vec![LevelFormat::Compressed, LevelFormat::Singleton, LevelFormat::Singleton]
        );
        assert!(a.is_sparse());
        assert!(!a.formats.contains(&LevelFormat::Dense));
        for alg in [TensorAlgebra::mttkrp(), TensorAlgebra::ttm()] {
            let t = alg.tensor("A").unwrap();
            assert_eq!(t.formats, TensorVar::coo("A", 3).formats, "{alg}");
            assert!(alg.is_sparse_dense_hybrid(), "{alg}");
        }
    }
}
