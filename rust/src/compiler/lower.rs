//! CIN → LLIR lowering (§5.2–5.3).
//!
//! The lowerer emits one GPU kernel per scheduled SpMM. It implements the
//! paper's two lowering changes:
//!
//! * **Zero extension** (§5.2): for the nnz-group family, out-of-bound
//!   lanes are *not* guarded out of the reduction — they compute
//!   `val = 0` and flow through `segReduceGroup` branch-free, exactly the
//!   Listing 1 → Listing 2 transformation.
//! * **Relaxed scalar workspace** (§5.3): the workspace `val` is declared
//!   in the loop scope but assigned inside an `else` basic block —
//!   the pattern stock TACO's one-basic-block assumption cannot express.
//!
//! Array-name conventions match TACO's generated code (Listing 1/2):
//! `A2_pos` (CSR indptr), `A2_crd` (column ids), `A_vals`, `B_vals`,
//! `C_vals`, `i_blockStarts` (per-block row search windows), scalars
//! `A1_dimension` (rows) and `B2_dimension`/`C2_dimension` (N).
//!
//! Note on the paper's Rule 2: we require `r <= g` in the row-group
//! family so that every aligned r-lane subgroup maps to a single row
//! (group-uniform writeback index) — Table 1's `g = 32, r ∈ {4, 8}`
//! configurations satisfy this.

use thiserror::Error;

use super::llir::{Kernel, Param, Stmt, Val};
use super::schedule::{Family, Schedule};

#[derive(Debug, Error)]
pub enum LowerError {
    #[error("unsupported schedule shape: {0}")]
    Unsupported(String),
    #[error("invalid config: {0}")]
    InvalidConfig(String),
}

/// Lower a scheduled SpMM to an LLIR kernel.
pub fn lower(schedule: &Schedule) -> Result<Kernel, LowerError> {
    schedule.config.validate().map_err(LowerError::InvalidConfig)?;
    let family = schedule.classify().map_err(LowerError::Unsupported)?;
    let cfg = schedule.config;
    match family {
        Family::NnzGroup => {
            if cfg.r > cfg.p {
                return Err(LowerError::InvalidConfig("r must be <= threads per block".into()));
            }
            Ok(lower_nnz_group(cfg.n, cfg.c, cfg.p, cfg.r))
        }
        Family::NnzSerial => Ok(lower_nnz_serial(cfg.n, cfg.c, cfg.p, cfg.g)),
        Family::RowSerial => Ok(lower_row_serial(cfg.n, cfg.c, cfg.p, cfg.x)),
        Family::RowGroup => {
            if cfg.r > cfg.g {
                return Err(LowerError::InvalidConfig(format!(
                    "row-group family needs r <= g (got r={}, g={}): an r-subgroup must not straddle rows",
                    cfg.r, cfg.g
                )));
            }
            Ok(lower_row_group(cfg.n, cfg.c, cfg.p, cfg.g, cfg.r))
        }
    }
}

fn i(v: i64) -> Val {
    Val::ConstI(v)
}

fn spmm_params(with_block_starts: bool) -> Vec<Param> {
    let mut p = Vec::new();
    if with_block_starts {
        p.push(Param::i32_array("i_blockStarts"));
    }
    p.extend([
        Param::i32_array("A2_pos"),
        Param::i32_array("A2_crd"),
        Param::f32_array("A_vals"),
        Param::f32_array("B_vals"),
        Param::f32_array("C_vals"),
        Param::i32_scalar("A1_dimension"),
        Param::i32_scalar("B2_dimension"),
    ]);
    p
}

/// Total nnz expressed as `A2_pos[A1_dimension]` (as the Listings do).
fn nnz_total() -> Val {
    Val::load("A2_pos", Val::param("A1_dimension"))
}

/// Listing 6 / Listing 2: `{<1 nnz, c col>, r}` with segment reduction.
///
/// Layout: `nnzb = p / (N/c)` non-zeros per block; thread covers
/// `(ko, fpos1)` with `fpos1 = tid % nnzb` (consecutive lanes own
/// consecutive non-zeros, so an r-lane group sees a contiguous nnz range —
/// the precondition for segmented scan).
fn lower_nnz_group(n: u32, c: u32, p: u32, r: u32) -> Kernel {
    let kchunks = (n / c) as i64;
    let nnzb = p as i64 / kchunks;
    let body = vec![
        Stmt::Comment(format!("{{<1 nnz, {c} col>, {r}}} — grouped segment reduction")),
        Stmt::Decl { var: "fpos1".into(), init: Val::rem(Val::ThreadIdx, i(nnzb)), float: false },
        Stmt::Decl { var: "ko".into(), init: Val::div(Val::ThreadIdx, i(nnzb)), float: false },
        Stmt::Decl {
            var: "fposA".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(nnzb)), Val::var("fpos1")),
            float: false,
        },
        Stmt::Decl { var: "pA2_begin".into(), init: Val::load("i_blockStarts", Val::BlockIdx), float: false },
        Stmt::Decl {
            var: "pA2_end".into(),
            init: Val::load("i_blockStarts", Val::add(Val::BlockIdx, i(1))),
            float: false,
        },
        Stmt::Decl {
            var: "i_pos".into(),
            init: Val::BinarySearchBefore {
                array: "A2_pos".into(),
                lo: Box::new(Val::var("pA2_begin")),
                hi: Box::new(Val::var("pA2_end")),
                target: Box::new(Val::var("fposA")),
            },
            float: false,
        },
        Stmt::Decl { var: "i".into(), init: Val::var("i_pos"), float: false },
        Stmt::For {
            var: "ki".into(),
            lo: i(0),
            hi: i(c as i64),
            step: i(1),
            body: vec![
                Stmt::Decl {
                    var: "k".into(),
                    init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
                    float: false,
                },
                // relaxed scalar workspace: declared here, assigned in the
                // else branch below (§5.3)
                Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                Stmt::If {
                    // zero extension (§5.2): out-of-bound lanes keep val = 0
                    // (and skip the row advance — exactly Listing 2's shape)
                    cond: Val::ge(Val::var("fposA"), nnz_total()),
                    then: vec![Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) }],
                    els: vec![
                        Stmt::Decl { var: "f".into(), init: Val::load("A2_crd", Val::var("fposA")), float: false },
                        Stmt::Decl {
                            var: "kB".into(),
                            init: Val::add(Val::mul(Val::var("f"), Val::param("B2_dimension")), Val::var("k")),
                            float: false,
                        },
                        // row advance: skip row starts equal to fposA
                        // (handles empty rows; idempotent across ki)
                        Stmt::While {
                            cond: Val::eq(
                                Val::var("fposA"),
                                Val::load("A2_pos", Val::add(Val::var("i_pos"), i(1))),
                            ),
                            body: vec![
                                Stmt::Assign { var: "i_pos".into(), val: Val::add(Val::var("i_pos"), i(1)) },
                                Stmt::Assign { var: "i".into(), val: Val::var("i_pos") },
                            ],
                        },
                        Stmt::Assign {
                            var: "val".into(),
                            val: Val::mul(Val::load("A_vals", Val::var("fposA")), Val::load("B_vals", Val::var("kB"))),
                        },
                    ],
                },
                Stmt::Decl {
                    var: "kC".into(),
                    init: Val::add(Val::mul(Val::var("i"), Val::param("B2_dimension")), Val::var("k")),
                    float: false,
                },
                Stmt::SegReduceGroup { array: "C_vals".into(), idx: Val::var("kC"), val: Val::var("val"), group: r },
            ],
        },
    ];
    Kernel { name: format!("spmm_nnz_group_c{c}_r{r}"), params: spmm_params(true), body, block_dim: p }
}

/// Listing 3 / Listing 1: `{<g nnz, c col>, 1}` — serial accumulation over
/// `g` consecutive non-zeros per thread, `atomicAdd` at row boundaries.
fn lower_nnz_serial(n: u32, c: u32, p: u32, g: u32) -> Kernel {
    let kchunks = (n / c) as i64;
    let nnzt = p as i64 / kchunks; // nnz-owning threads per block
    let g = g as i64;
    let flush = |ip: &str, k: &str| Stmt::AtomicAdd {
        array: "C_vals".into(),
        idx: Val::add(Val::mul(Val::var(ip), Val::param("B2_dimension")), Val::var(k)),
        val: Val::var("val"),
    };
    let body = vec![
        Stmt::Comment(format!("{{<{g} nnz, {c} col>, 1}} — serial reduction (stock TACO)")),
        Stmt::Decl { var: "fpos1".into(), init: Val::rem(Val::ThreadIdx, i(nnzt)), float: false },
        Stmt::Decl { var: "ko".into(), init: Val::div(Val::ThreadIdx, i(nnzt)), float: false },
        Stmt::Decl {
            var: "fposStart".into(),
            init: Val::add(
                Val::mul(Val::BlockIdx, i(g * nnzt)),
                Val::mul(Val::var("fpos1"), i(g)),
            ),
            float: false,
        },
        Stmt::Decl { var: "pA2_begin".into(), init: Val::load("i_blockStarts", Val::BlockIdx), float: false },
        Stmt::Decl {
            var: "pA2_end".into(),
            init: Val::load("i_blockStarts", Val::add(Val::BlockIdx, i(1))),
            float: false,
        },
        Stmt::Decl {
            var: "i_pos0".into(),
            init: Val::BinarySearchBefore {
                array: "A2_pos".into(),
                lo: Box::new(Val::var("pA2_begin")),
                hi: Box::new(Val::var("pA2_end")),
                target: Box::new(Val::var("fposStart")),
            },
            float: false,
        },
        Stmt::For {
            var: "ki".into(),
            lo: i(0),
            hi: i(c as i64),
            step: i(1),
            body: vec![
                Stmt::Decl {
                    var: "k".into(),
                    init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
                    float: false,
                },
                Stmt::Decl { var: "i_pos".into(), init: Val::var("i_pos0"), float: false },
                Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                Stmt::For {
                    var: "fi".into(),
                    lo: i(0),
                    hi: i(g),
                    step: i(1),
                    body: vec![
                        Stmt::Decl {
                            var: "fposA".into(),
                            init: Val::add(Val::var("fposStart"), Val::var("fi")),
                            float: false,
                        },
                        Stmt::If {
                            cond: Val::ge(Val::var("fposA"), nnz_total()),
                            then: vec![Stmt::Break],
                            els: vec![],
                        },
                        // flush accumulated value at each row boundary
                        Stmt::While {
                            cond: Val::eq(
                                Val::var("fposA"),
                                Val::load("A2_pos", Val::add(Val::var("i_pos"), i(1))),
                            ),
                            body: vec![
                                flush("i_pos", "k"),
                                Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) },
                                Stmt::Assign { var: "i_pos".into(), val: Val::add(Val::var("i_pos"), i(1)) },
                            ],
                        },
                        Stmt::Assign {
                            var: "val".into(),
                            val: Val::add(
                                Val::var("val"),
                                Val::mul(
                                    Val::load("A_vals", Val::var("fposA")),
                                    Val::load(
                                        "B_vals",
                                        Val::add(
                                            Val::mul(
                                                Val::load("A2_crd", Val::var("fposA")),
                                                Val::param("B2_dimension"),
                                            ),
                                            Val::var("k"),
                                        ),
                                    ),
                                ),
                            ),
                        },
                    ],
                },
                flush("i_pos", "k"),
            ],
        },
    ];
    Kernel {
        name: format!("spmm_nnz_serial_g{g}_c{c}"),
        params: spmm_params(true),
        body,
        block_dim: p,
    }
}

/// Listing 4: `{<x row, c col>, 1}` — one thread per row (×x), serial over
/// the row's non-zeros, plain store (no races).
fn lower_row_serial(n: u32, c: u32, p: u32, x: u32) -> Kernel {
    let kchunks = (n / c) as i64;
    let rowt = p as i64 / kchunks; // row-owning thread slots per block
    let body = vec![
        Stmt::Comment(format!("{{<{x} row, {c} col>, 1}} — row split, serial reduction (stock TACO)")),
        Stmt::Decl { var: "rowslot".into(), init: Val::rem(Val::ThreadIdx, i(rowt)), float: false },
        Stmt::Decl { var: "ko".into(), init: Val::div(Val::ThreadIdx, i(rowt)), float: false },
        Stmt::For {
            var: "xi".into(),
            lo: i(0),
            hi: i(x as i64),
            step: i(1),
            body: vec![
                Stmt::Decl {
                    var: "i".into(),
                    init: Val::add(
                        Val::mul(Val::BlockIdx, i(x as i64 * rowt)),
                        Val::add(Val::mul(Val::var("xi"), i(rowt)), Val::var("rowslot")),
                    ),
                    float: false,
                },
                Stmt::If {
                    cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
                    then: vec![Stmt::For {
                        var: "ki".into(),
                        lo: i(0),
                        hi: i(c as i64),
                        step: i(1),
                        body: vec![
                            Stmt::Decl {
                                var: "k".into(),
                                init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
                                float: false,
                            },
                            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                            Stmt::For {
                                var: "jj".into(),
                                lo: Val::load("A2_pos", Val::var("i")),
                                hi: Val::load("A2_pos", Val::add(Val::var("i"), i(1))),
                                step: i(1),
                                body: vec![Stmt::Assign {
                                    var: "val".into(),
                                    val: Val::add(
                                        Val::var("val"),
                                        Val::mul(
                                            Val::load("A_vals", Val::var("jj")),
                                            Val::load(
                                                "B_vals",
                                                Val::add(
                                                    Val::mul(
                                                        Val::load("A2_crd", Val::var("jj")),
                                                        Val::param("B2_dimension"),
                                                    ),
                                                    Val::var("k"),
                                                ),
                                            ),
                                        ),
                                    ),
                                }],
                            },
                            Stmt::Store {
                                array: "C_vals".into(),
                                idx: Val::add(Val::mul(Val::var("i"), Val::param("B2_dimension")), Val::var("k")),
                                val: Val::var("val"),
                            },
                        ],
                    }],
                    els: vec![],
                },
            ],
        },
    ];
    Kernel { name: format!("spmm_row_serial_x{x}_c{c}"), params: spmm_params(false), body, block_dim: p }
}

/// Listing 5: `{<1/g row, c col>, r}` — `g` threads cooperate per row,
/// grouped parallel reduction with `atomicAddGroup<float, r>`.
fn lower_row_group(n: u32, c: u32, p: u32, g: u32, r: u32) -> Kernel {
    let kchunks = (n / c) as i64;
    let g64 = g as i64;
    let rpb = p as i64 / (g64 * kchunks); // rows per block
    assert!(rpb >= 1, "p too small for g and N/c");
    let body = vec![
        Stmt::Comment(format!("{{<1/{g} row, {c} col>, {r}}} — grouped parallel reduction")),
        Stmt::Decl { var: "jpos1".into(), init: Val::rem(Val::ThreadIdx, i(g64)), float: false },
        Stmt::Decl {
            var: "ko".into(),
            init: Val::rem(Val::div(Val::ThreadIdx, i(g64)), i(kchunks)),
            float: false,
        },
        Stmt::Decl {
            var: "rowb".into(),
            init: Val::div(Val::ThreadIdx, i(g64 * kchunks)),
            float: false,
        },
        Stmt::Decl {
            var: "i".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(rpb)), Val::var("rowb")),
            float: false,
        },
        Stmt::If {
            cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
            then: vec![Stmt::For {
                var: "ki".into(),
                lo: i(0),
                hi: i(c as i64),
                step: i(1),
                body: vec![
                    Stmt::Decl {
                        var: "k".into(),
                        init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
                        float: false,
                    },
                    Stmt::Decl { var: "tjpos1C".into(), init: Val::ConstF(0.0), float: true },
                    Stmt::Decl {
                        var: "jpos".into(),
                        init: Val::add(Val::load("A2_pos", Val::var("i")), Val::var("jpos1")),
                        float: false,
                    },
                    Stmt::While {
                        cond: Val::lt(Val::var("jpos"), Val::load("A2_pos", Val::add(Val::var("i"), i(1)))),
                        body: vec![
                            Stmt::Assign {
                                var: "tjpos1C".into(),
                                val: Val::add(
                                    Val::var("tjpos1C"),
                                    Val::mul(
                                        Val::load("A_vals", Val::var("jpos")),
                                        Val::load(
                                            "B_vals",
                                            Val::add(
                                                Val::mul(
                                                    Val::load("A2_crd", Val::var("jpos")),
                                                    Val::param("B2_dimension"),
                                                ),
                                                Val::var("k"),
                                            ),
                                        ),
                                    ),
                                ),
                            },
                            Stmt::Assign { var: "jpos".into(), val: Val::add(Val::var("jpos"), i(g64)) },
                        ],
                    },
                    Stmt::AtomicAddGroup {
                        array: "C_vals".into(),
                        idx: Val::add(Val::mul(Val::var("i"), Val::param("B2_dimension")), Val::var("k")),
                        val: Val::var("tjpos1C"),
                        group: r,
                    },
                ],
            }],
            els: vec![],
        },
    ];
    Kernel {
        name: format!("spmm_row_group_g{g}_c{c}_r{r}"),
        params: spmm_params(false),
        body,
        block_dim: p,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::SpmmConfig;

    fn cfg() -> SpmmConfig {
        SpmmConfig::default()
    }

    #[test]
    fn lowers_all_families() {
        lower(&Schedule::taco_nnz_serial(cfg())).unwrap();
        lower(&Schedule::taco_row_serial(cfg())).unwrap();
        lower(&Schedule::sgap_row_group(cfg(), 8)).unwrap();
        lower(&Schedule::sgap_nnz_group(cfg(), 32)).unwrap();
    }

    #[test]
    fn nnz_group_emits_seg_reduce_and_zero_extension() {
        let k = lower(&Schedule::sgap_nnz_group(cfg(), 16)).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 16, .. })), 1);
        // zero extension: an if whose then-branch zeroes the workspace
        let zero_ext = k.count_matching(|s| {
            matches!(s, Stmt::If { then, .. }
                if matches!(then.first(), Some(Stmt::Assign { var, val: Val::ConstF(f) })
                    if var == "val" && *f == 0.0))
        });
        assert_eq!(zero_ext, 1, "zero-extension branch missing");
        // no plain atomicAdd in the segment-reduction kernel
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
    }

    #[test]
    fn row_group_emits_atomic_add_group() {
        let k = lower(&Schedule::sgap_row_group(cfg(), 4)).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { group: 4, .. })), 1);
        assert_eq!(k.block_dim, 256);
    }

    #[test]
    fn row_group_rejects_r_gt_g() {
        let mut c = cfg();
        c.g = 8;
        let err = lower(&Schedule::sgap_row_group(c, 32)).unwrap_err();
        assert!(matches!(err, LowerError::InvalidConfig(_)));
    }

    #[test]
    fn nnz_serial_uses_plain_atomics() {
        let k = lower(&Schedule::taco_nnz_serial(cfg())).unwrap();
        assert!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })) >= 2);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { .. })), 0);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { .. })), 0);
    }

    #[test]
    fn row_serial_has_no_atomics() {
        let k = lower(&Schedule::taco_row_serial(cfg())).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
        assert!(k.count_matching(|s| matches!(s, Stmt::Store { .. })) >= 1);
    }

    #[test]
    fn invalid_config_rejected() {
        let mut c = cfg();
        c.c = 3; // does not divide N=4
        assert!(lower(&Schedule::taco_row_serial(c)).is_err());
    }
}
