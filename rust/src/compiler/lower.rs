//! CIN → LLIR lowering (§5.2–5.3): the composable emission pipeline.
//!
//! One entry point, [`lower`], serves every kernel family the catalog
//! exposes — the four SpMM families of §6, the grouped SDDMM of §4.3, and
//! the dgSPARSE RB+PR library shape. Each family emitter is assembled
//! from shared, family-agnostic loop-structure builders (thread-tile
//! decomposition, column coarsening, row search, strided row dots) and a
//! single reusable reduction emitter, [`emit_reduction`], which consumes
//! the [`ReductionPlan`] threaded in from the schedule — strategy × group
//! size × writeback discipline. Adding a reduction strategy (even a
//! user-defined [`ReductionStrategy::Custom`]) requires no emitter edits:
//! the plan's [`Writeback`] picks the instruction.
//!
//! The paper's two lowering changes live here:
//!
//! * **Zero extension** (§5.2): for the segment-reduction families,
//!   out-of-bound lanes are *not* guarded out of the reduction — they
//!   compute `val = 0` and flow through `segReduceGroup` branch-free,
//!   exactly the Listing 1 → Listing 2 transformation.
//! * **Relaxed scalar workspace** (§5.3): the workspace `val` is declared
//!   in the loop scope but assigned inside an `else` basic block —
//!   the pattern stock TACO's one-basic-block assumption cannot express.
//!
//! Array-name conventions match TACO's generated code (Listing 1/2):
//! `A2_pos` (CSR indptr), `A2_crd` (column ids), `A_vals`, `B_vals`,
//! `C_vals`, `i_blockStarts` (per-block row search windows), scalars
//! `A1_dimension` (rows) and `B2_dimension`/`C2_dimension` (N).
//!
//! Note on the paper's Rule 2: we require `r <= g` in the row-group
//! family so that every aligned r-lane subgroup maps to a single row
//! (group-uniform writeback index) — Table 1's `g = 32, r ∈ {4, 8}`
//! configurations satisfy this.

use thiserror::Error;

#[allow(unused_imports)] // ReductionStrategy referenced by the module docs
use super::cin::{ReductionPlan, ReductionStrategy, Writeback};
use super::llir::{Kernel, Param, Stmt, Val};
use super::schedule::{DgConfig, Family, FusedConfig, KernelConfig, Schedule, SddmmConfig};

#[derive(Debug, Error)]
pub enum LowerError {
    #[error("unsupported schedule shape: {0}")]
    Unsupported(String),
    #[error("invalid config: {0}")]
    InvalidConfig(String),
}

/// Lower a schedule to an LLIR kernel.
///
/// Classification picks the family, [`Schedule::reduction_plan`] supplies
/// the reduction recipe, and the family emitter builds the loop structure
/// around it.
pub fn lower(schedule: &Schedule) -> Result<Kernel, LowerError> {
    schedule.config.validate().map_err(LowerError::InvalidConfig)?;
    let family = schedule.classify().map_err(LowerError::Unsupported)?;
    let plan = schedule.reduction_plan().map_err(LowerError::Unsupported)?;
    match (family, schedule.config) {
        (Family::NnzGroup, KernelConfig::Spmm(cfg)) => {
            if plan.group > cfg.p {
                return Err(LowerError::InvalidConfig("r must be <= threads per block".into()));
            }
            Ok(lower_nnz_group(cfg.n, cfg.c, cfg.p, &plan))
        }
        (Family::NnzSerial, KernelConfig::Spmm(cfg)) => {
            Ok(lower_nnz_serial(cfg.n, cfg.c, cfg.p, cfg.g, &plan))
        }
        (Family::RowSerial, KernelConfig::Spmm(cfg)) => {
            Ok(lower_row_serial(cfg.n, cfg.c, cfg.p, cfg.x, &plan))
        }
        (Family::RowGroup, KernelConfig::Spmm(cfg)) => {
            if plan.group > cfg.g {
                return Err(LowerError::InvalidConfig(format!(
                    "row-group family needs r <= g (got r={}, g={}): an r-subgroup must not straddle rows",
                    plan.group, cfg.g
                )));
            }
            Ok(lower_row_group(cfg.n, cfg.c, cfg.p, cfg.g, &plan))
        }
        (Family::SddmmGroup, KernelConfig::Sddmm(cfg)) => Ok(lower_sddmm_group(&cfg, &plan)),
        (Family::DgRowBalanced, KernelConfig::Dg(cfg)) => Ok(lower_dg_row_balanced(&cfg, &plan)),
        (Family::MttkrpGroup, KernelConfig::Mttkrp(cfg)) => {
            Ok(lower_coo3_seg("mttkrp", true, cfg.j_dim, cfg.c, cfg.p, &plan))
        }
        (Family::TtmGroup, KernelConfig::Ttm(cfg)) => {
            Ok(lower_coo3_seg("ttm", false, cfg.l_dim, cfg.c, cfg.p, &plan))
        }
        (Family::FusedSddmmSpmm, KernelConfig::Fused(cfg)) => {
            if plan.group > cfg.p {
                return Err(LowerError::InvalidConfig("r must be <= threads per block".into()));
            }
            Ok(lower_fused(&cfg, &plan))
        }
        (family, _) => Err(LowerError::Unsupported(format!(
            "family {family:?} does not match the schedule's kernel config"
        ))),
    }
}

fn i(v: i64) -> Val {
    Val::ConstI(v)
}

// ---------------------------------------------------------------------------
// the reduction emitter — the single writeback point of every family
// ---------------------------------------------------------------------------

/// Emit the writeback a [`ReductionPlan`] prescribes for `array[idx] ⊕= val`.
///
/// This is the one place reduction strategies meet instructions; every
/// family emitter funnels its reduction through here, so a new strategy
/// (or a [`ReductionStrategy::Custom`] writeback) lands in every kernel
/// family at once.
fn emit_reduction(plan: &ReductionPlan, array: &str, idx: Val, val: Val) -> Stmt {
    match plan.writeback {
        Writeback::Store => Stmt::Store { array: array.into(), idx, val },
        Writeback::Atomic => Stmt::AtomicAdd { array: array.into(), idx, val },
        Writeback::LaneZeroAtomic => {
            Stmt::AtomicAddGroup { array: array.into(), idx, val, group: plan.group }
        }
        Writeback::SegmentBoundary => {
            Stmt::SegReduceGroup { array: array.into(), idx, val, group: plan.group }
        }
    }
}

// ---------------------------------------------------------------------------
// family-agnostic loop-structure builders
// ---------------------------------------------------------------------------

/// Split `threadIdx.x` into an inner tile position and an outer chunk id:
/// `inner = tid % width; outer = tid / width`.
fn tile_decomp(inner: &str, outer: &str, width: i64) -> [Stmt; 2] {
    [
        Stmt::Decl { var: inner.into(), init: Val::rem(Val::ThreadIdx, i(width)), float: false },
        Stmt::Decl { var: outer.into(), init: Val::div(Val::ThreadIdx, i(width)), float: false },
    ]
}

/// The per-block row-search window `[pA2_begin, pA2_end]` read from the
/// precomputed `i_blockStarts` array.
fn block_window() -> [Stmt; 2] {
    [
        Stmt::Decl {
            var: "pA2_begin".into(),
            init: Val::load("i_blockStarts", Val::BlockIdx),
            float: false,
        },
        Stmt::Decl {
            var: "pA2_end".into(),
            init: Val::load("i_blockStarts", Val::add(Val::BlockIdx, i(1))),
            float: false,
        },
    ]
}

/// Binary-search the CSR `A2_pos` for the row owning position `target`
/// within the block window (Listing 1's row search).
fn row_search(var: &str, target: &str) -> Stmt {
    Stmt::Decl {
        var: var.into(),
        init: Val::BinarySearchBefore {
            array: "A2_pos".into(),
            lo: Box::new(Val::var("pA2_begin")),
            hi: Box::new(Val::var("pA2_end")),
            target: Box::new(Val::var(target)),
        },
        float: false,
    }
}

/// The column-coarsening loop `for (ki = 0; ki < c; ki++)` every family
/// tiles its dense columns with.
fn coarsen_loop(c: u32, body: Vec<Stmt>) -> Stmt {
    Stmt::For { var: "ki".into(), lo: i(0), hi: i(c as i64), step: i(1), body }
}

/// The coarsened column index `k = ko * c + ki`.
fn col_index(c: u32) -> Stmt {
    Stmt::Decl {
        var: "k".into(),
        init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
        float: false,
    }
}

/// The SpMM product at sparse position `pos` and dense column `k`:
/// `A_vals[pos] * B_vals[A2_crd[pos] * B2_dimension + k]`.
fn spmm_product(pos: Val) -> Val {
    Val::mul(
        Val::load("A_vals", pos.clone()),
        Val::load(
            "B_vals",
            Val::add(
                Val::mul(Val::load("A2_crd", pos), Val::param("B2_dimension")),
                Val::var("k"),
            ),
        ),
    )
}

/// `acc += product` on a scalar workspace.
fn accumulate(acc: &str, product: Val) -> Stmt {
    Stmt::Assign { var: acc.into(), val: Val::add(Val::var(acc), product) }
}

/// Cooperative row dot: `while (pos < end) { acc += A·B; pos += stride }`
/// — `stride` lanes interleave over one row's non-zeros. Shared by the
/// row-group family and the dgSPARSE row-balanced shape.
fn strided_row_dot(acc: &str, pos_var: &str, end: Val, stride: i64) -> Stmt {
    Stmt::While {
        cond: Val::lt(Val::var(pos_var), end),
        body: vec![
            accumulate(acc, spmm_product(Val::var(pos_var))),
            Stmt::Assign {
                var: pos_var.into(),
                val: Val::add(Val::var(pos_var), i(stride)),
            },
        ],
    }
}

/// `while (target == A2_pos[i_pos + 1]) { body }` — the row-boundary scan
/// the nnz-split families run to advance (or flush) across row starts.
fn row_boundary_scan(i_pos: &str, target: &str, body: Vec<Stmt>) -> Stmt {
    Stmt::While {
        cond: Val::eq(
            Val::var(target),
            Val::load("A2_pos", Val::add(Val::var(i_pos), i(1))),
        ),
        body,
    }
}

/// The output index `row * B2_dimension + k`.
fn c_index(row: &str) -> Val {
    Val::add(Val::mul(Val::var(row), Val::param("B2_dimension")), Val::var("k"))
}

fn spmm_params(with_block_starts: bool) -> Vec<Param> {
    let mut p = Vec::new();
    if with_block_starts {
        p.push(Param::i32_array("i_blockStarts"));
    }
    p.extend([
        Param::i32_array("A2_pos"),
        Param::i32_array("A2_crd"),
        Param::f32_array("A_vals"),
        Param::f32_array("B_vals"),
        Param::f32_array("C_vals"),
        Param::i32_scalar("A1_dimension"),
        Param::i32_scalar("B2_dimension"),
    ]);
    p
}

/// Total nnz expressed as `A2_pos[A1_dimension]` (as the Listings do).
fn nnz_total() -> Val {
    Val::load("A2_pos", Val::param("A1_dimension"))
}

// ---------------------------------------------------------------------------
// family emitters
// ---------------------------------------------------------------------------

/// Listing 6 / Listing 2: `{<1 nnz, c col>, r}` with segment reduction.
///
/// Layout: `nnzb = p / (N/c)` non-zeros per block; thread covers
/// `(ko, fpos1)` with `fpos1 = tid % nnzb` (consecutive lanes own
/// consecutive non-zeros, so an r-lane group sees a contiguous nnz range —
/// the precondition for segmented scan).
fn lower_nnz_group(n: u32, c: u32, p: u32, plan: &ReductionPlan) -> Kernel {
    let kchunks = (n / c) as i64;
    let nnzb = p as i64 / kchunks;
    let r = plan.group;
    let mut body = vec![Stmt::Comment(format!(
        "{{<1 nnz, {c} col>, {r}}} — grouped segment reduction"
    ))];
    body.extend(tile_decomp("fpos1", "ko", nnzb));
    body.push(Stmt::Decl {
        var: "fposA".into(),
        init: Val::add(Val::mul(Val::BlockIdx, i(nnzb)), Val::var("fpos1")),
        float: false,
    });
    body.extend(block_window());
    body.push(row_search("i_pos", "fposA"));
    body.push(Stmt::Decl { var: "i".into(), init: Val::var("i_pos"), float: false });
    body.push(coarsen_loop(
        c,
        vec![
            col_index(c),
            // relaxed scalar workspace: declared here, assigned in the
            // else branch below (§5.3)
            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
            Stmt::If {
                // zero extension (§5.2): out-of-bound lanes keep val = 0
                // (and skip the row advance — exactly Listing 2's shape)
                cond: Val::ge(Val::var("fposA"), nnz_total()),
                then: vec![Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) }],
                els: vec![
                    Stmt::Decl {
                        var: "f".into(),
                        init: Val::load("A2_crd", Val::var("fposA")),
                        float: false,
                    },
                    Stmt::Decl {
                        var: "kB".into(),
                        init: Val::add(
                            Val::mul(Val::var("f"), Val::param("B2_dimension")),
                            Val::var("k"),
                        ),
                        float: false,
                    },
                    // row advance: skip row starts equal to fposA
                    // (handles empty rows; idempotent across ki)
                    row_boundary_scan(
                        "i_pos",
                        "fposA",
                        vec![
                            Stmt::Assign {
                                var: "i_pos".into(),
                                val: Val::add(Val::var("i_pos"), i(1)),
                            },
                            Stmt::Assign { var: "i".into(), val: Val::var("i_pos") },
                        ],
                    ),
                    Stmt::Assign {
                        var: "val".into(),
                        val: Val::mul(
                            Val::load("A_vals", Val::var("fposA")),
                            Val::load("B_vals", Val::var("kB")),
                        ),
                    },
                ],
            },
            Stmt::Decl { var: "kC".into(), init: c_index("i"), float: false },
            emit_reduction(plan, "C_vals", Val::var("kC"), Val::var("val")),
        ],
    ));
    Kernel {
        name: format!("spmm_nnz_group_c{c}_r{r}"),
        params: spmm_params(true),
        body,
        block_dim: p,
    }
}

/// Listing 3 / Listing 1: `{<g nnz, c col>, 1}` — serial accumulation over
/// `g` consecutive non-zeros per thread, `atomicAdd` at row boundaries.
fn lower_nnz_serial(n: u32, c: u32, p: u32, g: u32, plan: &ReductionPlan) -> Kernel {
    let kchunks = (n / c) as i64;
    let nnzt = p as i64 / kchunks; // nnz-owning threads per block
    let g = g as i64;
    let flush = |ip: &str| emit_reduction(plan, "C_vals", c_index(ip), Val::var("val"));
    let mut body = vec![Stmt::Comment(format!(
        "{{<{g} nnz, {c} col>, 1}} — serial reduction (stock TACO)"
    ))];
    body.extend(tile_decomp("fpos1", "ko", nnzt));
    body.push(Stmt::Decl {
        var: "fposStart".into(),
        init: Val::add(
            Val::mul(Val::BlockIdx, i(g * nnzt)),
            Val::mul(Val::var("fpos1"), i(g)),
        ),
        float: false,
    });
    body.extend(block_window());
    body.push(row_search("i_pos0", "fposStart"));
    body.push(coarsen_loop(
        c,
        vec![
            col_index(c),
            Stmt::Decl { var: "i_pos".into(), init: Val::var("i_pos0"), float: false },
            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
            Stmt::For {
                var: "fi".into(),
                lo: i(0),
                hi: i(g),
                step: i(1),
                body: vec![
                    Stmt::Decl {
                        var: "fposA".into(),
                        init: Val::add(Val::var("fposStart"), Val::var("fi")),
                        float: false,
                    },
                    Stmt::If {
                        cond: Val::ge(Val::var("fposA"), nnz_total()),
                        then: vec![Stmt::Break],
                        els: vec![],
                    },
                    // flush accumulated value at each row boundary
                    row_boundary_scan(
                        "i_pos",
                        "fposA",
                        vec![
                            flush("i_pos"),
                            Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) },
                            Stmt::Assign {
                                var: "i_pos".into(),
                                val: Val::add(Val::var("i_pos"), i(1)),
                            },
                        ],
                    ),
                    accumulate("val", spmm_product(Val::var("fposA"))),
                ],
            },
            flush("i_pos"),
        ],
    ));
    Kernel {
        name: format!("spmm_nnz_serial_g{g}_c{c}"),
        params: spmm_params(true),
        body,
        block_dim: p,
    }
}

/// Listing 4: `{<x row, c col>, 1}` — one thread per row (×x), serial over
/// the row's non-zeros, plain store (no races).
fn lower_row_serial(n: u32, c: u32, p: u32, x: u32, plan: &ReductionPlan) -> Kernel {
    let kchunks = (n / c) as i64;
    let rowt = p as i64 / kchunks; // row-owning thread slots per block
    let mut body = vec![Stmt::Comment(format!(
        "{{<{x} row, {c} col>, 1}} — row split, serial reduction (stock TACO)"
    ))];
    body.extend(tile_decomp("rowslot", "ko", rowt));
    body.push(Stmt::For {
        var: "xi".into(),
        lo: i(0),
        hi: i(x as i64),
        step: i(1),
        body: vec![
            Stmt::Decl {
                var: "i".into(),
                init: Val::add(
                    Val::mul(Val::BlockIdx, i(x as i64 * rowt)),
                    Val::add(Val::mul(Val::var("xi"), i(rowt)), Val::var("rowslot")),
                ),
                float: false,
            },
            Stmt::If {
                cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
                then: vec![coarsen_loop(
                    c,
                    vec![
                        col_index(c),
                        Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                        Stmt::For {
                            var: "jj".into(),
                            lo: Val::load("A2_pos", Val::var("i")),
                            hi: Val::load("A2_pos", Val::add(Val::var("i"), i(1))),
                            step: i(1),
                            body: vec![accumulate("val", spmm_product(Val::var("jj")))],
                        },
                        emit_reduction(plan, "C_vals", c_index("i"), Val::var("val")),
                    ],
                )],
                els: vec![],
            },
        ],
    });
    Kernel {
        name: format!("spmm_row_serial_x{x}_c{c}"),
        params: spmm_params(false),
        body,
        block_dim: p,
    }
}

/// Listing 5: `{<1/g row, c col>, r}` — `g` threads cooperate per row,
/// grouped parallel reduction with `atomicAddGroup<float, r>`.
fn lower_row_group(n: u32, c: u32, p: u32, g: u32, plan: &ReductionPlan) -> Kernel {
    let kchunks = (n / c) as i64;
    let g64 = g as i64;
    let r = plan.group;
    let rpb = p as i64 / (g64 * kchunks); // rows per block
    assert!(rpb >= 1, "p too small for g and N/c");
    let body = vec![
        Stmt::Comment(format!("{{<1/{g} row, {c} col>, {r}}} — grouped parallel reduction")),
        Stmt::Decl { var: "jpos1".into(), init: Val::rem(Val::ThreadIdx, i(g64)), float: false },
        Stmt::Decl {
            var: "ko".into(),
            init: Val::rem(Val::div(Val::ThreadIdx, i(g64)), i(kchunks)),
            float: false,
        },
        Stmt::Decl {
            var: "rowb".into(),
            init: Val::div(Val::ThreadIdx, i(g64 * kchunks)),
            float: false,
        },
        Stmt::Decl {
            var: "i".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(rpb)), Val::var("rowb")),
            float: false,
        },
        Stmt::If {
            cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
            then: vec![coarsen_loop(
                c,
                vec![
                    col_index(c),
                    Stmt::Decl { var: "tjpos1C".into(), init: Val::ConstF(0.0), float: true },
                    Stmt::Decl {
                        var: "jpos".into(),
                        init: Val::add(Val::load("A2_pos", Val::var("i")), Val::var("jpos1")),
                        float: false,
                    },
                    strided_row_dot(
                        "tjpos1C",
                        "jpos",
                        Val::load("A2_pos", Val::add(Val::var("i"), i(1))),
                        g64,
                    ),
                    emit_reduction(plan, "C_vals", c_index("i"), Val::var("tjpos1C")),
                ],
            )],
            els: vec![],
        },
    ];
    Kernel {
        name: format!("spmm_row_group_g{g}_c{c}_r{r}"),
        params: spmm_params(false),
        body,
        block_dim: p,
    }
}

/// §4.3 SDDMM `{<1/g nnz>, r}` — grouped dot-product reduction.
///
/// `g` lanes cooperate on one non-zero; each lane strides the dense `j`
/// dimension by `g`; the plan's grouped reduction combines the partial
/// dot products (one output slot per nnz, group-uniform index).
///
/// Buffers: `A2_pos/A2_crd/A_vals` (CSR), `A_rowidx` (COO row per nnz),
/// `X1_vals`, `X2_vals`, `Y_vals` (one slot per nnz); scalars
/// `A1_dimension` (rows), `A2_dimension` (cols), `J_dimension`, `A_nnz`.
fn lower_sddmm_group(cfg: &SddmmConfig, plan: &ReductionPlan) -> Kernel {
    let g = cfg.g as i64;
    let npb = cfg.npb() as i64;
    let mut body = vec![Stmt::Comment(format!(
        "sddmm {{<1/{g} nnz>, {}}} — grouped dot-product reduction",
        plan.group
    ))];
    body.extend(tile_decomp("lane", "e", g));
    body.push(Stmt::Decl {
        var: "pos".into(),
        init: Val::add(Val::mul(Val::BlockIdx, i(npb)), Val::var("e")),
        float: false,
    });
    body.push(Stmt::If {
        cond: Val::lt(Val::var("pos"), Val::param("A_nnz")),
        then: vec![
            Stmt::Decl { var: "i".into(), init: Val::load("A_rowidx", Val::var("pos")), float: false },
            Stmt::Decl { var: "k".into(), init: Val::load("A2_crd", Val::var("pos")), float: false },
            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
            Stmt::Decl { var: "j".into(), init: Val::var("lane"), float: false },
            Stmt::While {
                cond: Val::lt(Val::var("j"), Val::param("J_dimension")),
                body: vec![
                    accumulate(
                        "val",
                        Val::mul(
                            Val::load(
                                "X1_vals",
                                Val::add(
                                    Val::mul(Val::var("i"), Val::param("J_dimension")),
                                    Val::var("j"),
                                ),
                            ),
                            Val::load(
                                "X2_vals",
                                Val::add(
                                    Val::mul(Val::var("j"), Val::param("A2_dimension")),
                                    Val::var("k"),
                                ),
                            ),
                        ),
                    ),
                    Stmt::Assign { var: "j".into(), val: Val::add(Val::var("j"), i(g)) },
                ],
            },
            // scale the partial by A's value up front (distributes over +)
            Stmt::Assign {
                var: "val".into(),
                val: Val::mul(Val::var("val"), Val::load("A_vals", Val::var("pos"))),
            },
            // the same macro instruction as SpMM's row kernel (§4.3):
            emit_reduction(plan, "Y_vals", Val::var("pos"), Val::var("val")),
        ],
        els: vec![],
    });
    Kernel {
        name: format!("sddmm_g{}_r{}", cfg.g, plan.group),
        params: vec![
            Param::i32_array("A2_pos"),
            Param::i32_array("A2_crd"),
            Param::i32_array("A_rowidx"),
            Param::f32_array("A_vals"),
            Param::f32_array("X1_vals"),
            Param::f32_array("X2_vals"),
            Param::f32_array("Y_vals"),
            Param::i32_scalar("A1_dimension"),
            Param::i32_scalar("A2_dimension"),
            Param::i32_scalar("J_dimension"),
            Param::i32_scalar("A_nnz"),
        ],
        body,
        block_dim: cfg.p,
    }
}

/// Fused SDDMM→SpMM `{<1 nnz, c col>, r}` — one pass over `pos/crd`.
///
/// The nnz-group SpMM skeleton with the SDDMM dot hoisted in front of the
/// coarsening loop: each nnz-owning lane binary-searches its row **once**,
/// computes the scaled attention score `tlaneY = A_vals[fposA] · Σ_l
/// X1[i,l]·X2[l,f]` **in registers**, then feeds `tlaneY · B[f,k]` straight
/// into the segment-group reduction for each of its `c` columns. No
/// `Y_vals` buffer exists — the producer's output never touches memory,
/// and the sparse structure is traversed exactly once (one
/// `BinarySearchBefore`, one row-boundary scan, hoisted out of the column
/// loop because the dot is column-invariant).
///
/// Zero extension (§5.2) carries over: out-of-bound lanes skip the dot,
/// keep `val = 0`, and still flow through `segReduceGroup` branch-free.
fn lower_fused(cfg: &FusedConfig, plan: &ReductionPlan) -> Kernel {
    let c = cfg.c;
    let nnzb = cfg.npb() as i64;
    let r = plan.group;
    let mut body = vec![Stmt::Comment(format!(
        "fused sddmm\u{2192}spmm {{<1 nnz, {c} col>, {r}}} — in-register dot, one pos/crd pass"
    ))];
    body.extend(tile_decomp("fpos1", "ko", nnzb));
    body.push(Stmt::Decl {
        var: "fposA".into(),
        init: Val::add(Val::mul(Val::BlockIdx, i(nnzb)), Val::var("fpos1")),
        float: false,
    });
    body.extend(block_window());
    body.push(row_search("i_pos", "fposA"));
    body.push(Stmt::Decl { var: "i".into(), init: Val::var("i_pos"), float: false });
    // the producer's value lives in a register for the lane's nonzero —
    // computed once, consumed by every coarsened column below
    body.push(Stmt::Decl { var: "tlaneY".into(), init: Val::ConstF(0.0), float: true });
    body.push(Stmt::If {
        cond: Val::lt(Val::var("fposA"), nnz_total()),
        then: vec![
            // row advance: skip row starts equal to fposA (empty rows)
            row_boundary_scan(
                "i_pos",
                "fposA",
                vec![
                    Stmt::Assign { var: "i_pos".into(), val: Val::add(Val::var("i_pos"), i(1)) },
                    Stmt::Assign { var: "i".into(), val: Val::var("i_pos") },
                ],
            ),
            Stmt::Decl {
                var: "f".into(),
                init: Val::load("A2_crd", Val::var("fposA")),
                float: false,
            },
            Stmt::Decl { var: "l".into(), init: i(0), float: false },
            Stmt::While {
                cond: Val::lt(Val::var("l"), Val::param("J_dimension")),
                body: vec![
                    accumulate(
                        "tlaneY",
                        Val::mul(
                            Val::load(
                                "X1_vals",
                                Val::add(
                                    Val::mul(Val::var("i"), Val::param("J_dimension")),
                                    Val::var("l"),
                                ),
                            ),
                            Val::load(
                                "X2_vals",
                                Val::add(
                                    Val::mul(Val::var("l"), Val::param("A2_dimension")),
                                    Val::var("f"),
                                ),
                            ),
                        ),
                    ),
                    Stmt::Assign { var: "l".into(), val: Val::add(Val::var("l"), i(1)) },
                ],
            },
            // scale by A's value once (distributes over the column loop)
            Stmt::Assign {
                var: "tlaneY".into(),
                val: Val::mul(Val::var("tlaneY"), Val::load("A_vals", Val::var("fposA"))),
            },
        ],
        els: vec![],
    });
    body.push(coarsen_loop(
        c,
        vec![
            col_index(c),
            // relaxed scalar workspace (§5.3), zero-extended (§5.2)
            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
            Stmt::If {
                cond: Val::ge(Val::var("fposA"), nnz_total()),
                then: vec![Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) }],
                els: vec![
                    Stmt::Decl {
                        var: "f".into(),
                        init: Val::load("A2_crd", Val::var("fposA")),
                        float: false,
                    },
                    Stmt::Decl {
                        var: "kB".into(),
                        init: Val::add(
                            Val::mul(Val::var("f"), Val::param("B2_dimension")),
                            Val::var("k"),
                        ),
                        float: false,
                    },
                    Stmt::Assign {
                        var: "val".into(),
                        val: Val::mul(Val::var("tlaneY"), Val::load("B_vals", Val::var("kB"))),
                    },
                ],
            },
            Stmt::Decl { var: "kC".into(), init: c_index("i"), float: false },
            emit_reduction(plan, "C_vals", Val::var("kC"), Val::var("val")),
        ],
    ));
    Kernel {
        name: format!("fused_sddmm_spmm_c{c}_r{r}"),
        params: vec![
            Param::i32_array("i_blockStarts"),
            Param::i32_array("A2_pos"),
            Param::i32_array("A2_crd"),
            Param::f32_array("A_vals"),
            Param::f32_array("X1_vals"),
            Param::f32_array("X2_vals"),
            Param::f32_array("B_vals"),
            Param::f32_array("C_vals"),
            Param::i32_scalar("A1_dimension"),
            Param::i32_scalar("A2_dimension"),
            Param::i32_scalar("B2_dimension"),
            Param::i32_scalar("J_dimension"),
        ],
        body,
        block_dim: cfg.p,
    }
}

/// dgSPARSE RB+PR+RM — the row-balanced/partial-result shape.
///
/// Thread decomposition (within a block of `blockSz` threads):
/// `lane = tid % workerSz`, `vcol = (tid / workerSz) % vcols`,
/// `rowb = tid / blockDim.x`. Block decomposition:
/// `col_block = blockIdx % colTiles`, `row_block = blockIdx / colTiles`.
/// Each worker strides its rows by the launch-bound `workerDimR` scalar
/// (RB = row balance) and its nnz by `workerSz`; writeback is the plan's
/// grouped parallel reduction of width `groupSz` (PR); B/C are row-major
/// (RM).
fn lower_dg_row_balanced(cfg: &DgConfig, plan: &ReductionPlan) -> Kernel {
    let vcols = cfg.vcols() as i64;
    let worker_sz = cfg.worker_sz as i64;
    let rpb = cfg.rows_per_block() as i64;
    let col_tiles = cfg.col_tiles() as i64;
    let coarsen = cfg.coarsen_sz as i64;
    let tile = cfg.tile_sz as i64;

    let mut body = vec![Stmt::Comment(format!(
        "dgSPARSE RB+PR+RM <groupSz={}, blockSz={}, tileSz={}, workerDimR={}x rows>",
        plan.group, cfg.block_sz, cfg.tile_sz, cfg.worker_dim_r_frac
    ))];
    body.push(Stmt::Decl {
        var: "lane".into(),
        init: Val::rem(Val::ThreadIdx, i(worker_sz)),
        float: false,
    });
    body.push(Stmt::Decl {
        var: "vcol".into(),
        init: Val::rem(Val::div(Val::ThreadIdx, i(worker_sz)), i(vcols)),
        float: false,
    });
    body.push(Stmt::Decl {
        var: "rowb".into(),
        init: Val::div(Val::ThreadIdx, i(worker_sz * vcols)),
        float: false,
    });
    body.push(Stmt::Decl {
        var: "col_block".into(),
        init: Val::rem(Val::BlockIdx, i(col_tiles)),
        float: false,
    });
    body.push(Stmt::Decl {
        var: "row_block".into(),
        init: Val::div(Val::BlockIdx, i(col_tiles)),
        float: false,
    });
    body.push(Stmt::Decl {
        var: "i".into(),
        init: Val::add(Val::mul(Val::var("row_block"), i(rpb)), Val::var("rowb")),
        float: false,
    });
    // RB: loop rows with stride workerDimR until exhausted
    body.push(Stmt::While {
        cond: Val::lt(Val::var("i"), Val::param("A1_dimension")),
        body: vec![
            Stmt::For {
                var: "cc".into(),
                lo: i(0),
                hi: i(coarsen),
                step: i(1),
                body: vec![
                    Stmt::Decl {
                        var: "k".into(),
                        init: Val::add(
                            Val::mul(Val::var("col_block"), i(tile)),
                            Val::add(Val::mul(Val::var("vcol"), i(coarsen)), Val::var("cc")),
                        ),
                        float: false,
                    },
                    Stmt::If {
                        cond: Val::lt(Val::var("k"), Val::param("B2_dimension")),
                        then: vec![
                            Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
                            Stmt::Decl {
                                var: "jpos".into(),
                                init: Val::add(
                                    Val::load("A2_pos", Val::var("i")),
                                    Val::var("lane"),
                                ),
                                float: false,
                            },
                            strided_row_dot(
                                "val",
                                "jpos",
                                Val::load("A2_pos", Val::add(Val::var("i"), i(1))),
                                worker_sz,
                            ),
                            emit_reduction(plan, "C_vals", c_index("i"), Val::var("val")),
                        ],
                        els: vec![],
                    },
                ],
            },
            Stmt::Assign {
                var: "i".into(),
                val: Val::add(Val::var("i"), Val::param("workerDimR")),
            },
        ],
    });

    // encode the fraction's decimal point as `p` (0.5 → 0p5): the kernel
    // name becomes a C identifier in the emitted `__global__` signature
    let frac = cfg.worker_dim_r_frac.to_string().replace('.', "p");
    Kernel {
        name: format!("dg_rb_pr_rm_g{}_b{}_t{}_w{frac}", plan.group, cfg.block_sz, cfg.tile_sz),
        params: vec![
            Param::i32_array("A2_pos"),
            Param::i32_array("A2_crd"),
            Param::f32_array("A_vals"),
            Param::f32_array("B_vals"),
            Param::f32_array("C_vals"),
            Param::i32_scalar("A1_dimension"),
            Param::i32_scalar("B2_dimension"),
            Param::i32_scalar("workerDimR"),
        ],
        body,
        block_dim: cfg.block_sz,
    }
}

/// COO-3 nnz-split grouped segment reduction — the shared MTTKRP/TTM
/// shape (Eq. 2a/2b) that completes the §2.1 quartet.
///
/// Each thread owns one non-zero × `c` dense columns; an r-wide
/// `segReduceGroup` keyed by the output segment (row for MTTKRP, leading
/// `(i,j)` fiber for TTM) combines contributions exactly like SpMM's
/// Listing-6 kernel. Out-of-range lanes flow through with `val = 0`
/// (zero extension, §5.2) and read the padded segment id, so the
/// reduction stays branch-free.
///
/// Buffers: `seg_ids[p]` (output segment per nnz, one pad entry),
/// `f1_idx[p]` / `f2_idx[p]` (factor-row gathers; `f2` only when
/// `with_x2`), `A_vals`, `X1_vals`, `X2_vals`, `Y_vals`; scalars
/// `N_dimension` (dense columns), `A_nnz`, `A_nnz_pad`.
fn lower_coo3_seg(name: &str, with_x2: bool, n: u32, c: u32, p: u32, plan: &ReductionPlan) -> Kernel {
    let kchunks = (n / c) as i64;
    let npb = p as i64 / kchunks;
    let r = plan.group;
    let mut inner = vec![
        Stmt::Decl {
            var: "jcol".into(),
            init: Val::add(Val::mul(Val::var("ko"), i(c as i64)), Val::var("ki")),
            float: false,
        },
        // relaxed scalar workspace, assigned in the else branch (§5.3)
        Stmt::Decl { var: "val".into(), init: Val::ConstF(0.0), float: true },
        Stmt::If {
            // zero extension: out-of-range lanes keep val = 0
            cond: Val::ge(Val::var("pos"), Val::param("A_nnz")),
            then: vec![Stmt::Assign { var: "val".into(), val: Val::ConstF(0.0) }],
            els: {
                let x1 = Val::load(
                    "X1_vals",
                    Val::add(
                        Val::mul(Val::load("f1_idx", Val::var("pos")), Val::param("N_dimension")),
                        Val::var("jcol"),
                    ),
                );
                let base = Val::mul(Val::load("A_vals", Val::var("pos")), x1);
                let product = if with_x2 {
                    Val::mul(
                        base,
                        Val::load(
                            "X2_vals",
                            Val::add(
                                Val::mul(
                                    Val::load("f2_idx", Val::var("pos")),
                                    Val::param("N_dimension"),
                                ),
                                Val::var("jcol"),
                            ),
                        ),
                    )
                } else {
                    base
                };
                vec![Stmt::Assign { var: "val".into(), val: product }]
            },
        },
        Stmt::Decl {
            var: "out".into(),
            init: Val::add(
                Val::mul(Val::var("seg"), Val::param("N_dimension")),
                Val::var("jcol"),
            ),
            float: false,
        },
        // the same macro instruction as SpMM's Listing-6 kernel (§2.1)
        emit_reduction(plan, "Y_vals", Val::var("out"), Val::var("val")),
    ];
    let body = vec![
        Stmt::Comment(format!("{name} {{<1 nnz, {c} col>, {r}}} — COO-3 grouped segment reduction")),
        Stmt::Decl { var: "e".into(), init: Val::rem(Val::ThreadIdx, i(npb)), float: false },
        Stmt::Decl { var: "ko".into(), init: Val::div(Val::ThreadIdx, i(npb)), float: false },
        Stmt::Decl {
            var: "pos".into(),
            init: Val::add(Val::mul(Val::BlockIdx, i(npb)), Val::var("e")),
            float: false,
        },
        Stmt::Decl {
            var: "seg".into(),
            init: Val::load(
                "seg_ids",
                Val::min(Val::var("pos"), Val::sub(Val::param("A_nnz_pad"), i(1))),
            ),
            float: false,
        },
        Stmt::For { var: "ki".into(), lo: i(0), hi: i(c as i64), step: i(1), body: std::mem::take(&mut inner) },
    ];
    let mut params = vec![
        Param::i32_array("seg_ids"),
        Param::i32_array("f1_idx"),
        Param::f32_array("A_vals"),
        Param::f32_array("X1_vals"),
        Param::f32_array("Y_vals"),
        Param::i32_scalar("N_dimension"),
        Param::i32_scalar("A_nnz"),
        Param::i32_scalar("A_nnz_pad"),
    ];
    if with_x2 {
        params.insert(2, Param::i32_array("f2_idx"));
        params.insert(5, Param::f32_array("X2_vals"));
    }
    Kernel { name: format!("{name}_c{c}_r{r}"), params, body, block_dim: p }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::schedule::SpmmConfig;

    fn cfg() -> SpmmConfig {
        SpmmConfig::default()
    }

    #[test]
    fn lowers_all_families() {
        use crate::compiler::schedule::{MttkrpConfig, TtmConfig};
        lower(&Schedule::taco_nnz_serial(cfg())).unwrap();
        lower(&Schedule::taco_row_serial(cfg())).unwrap();
        lower(&Schedule::sgap_row_group(cfg(), 8)).unwrap();
        lower(&Schedule::sgap_nnz_group(cfg(), 32)).unwrap();
        lower(&Schedule::sddmm_group(SddmmConfig::new(64, 16, 8))).unwrap();
        lower(&Schedule::dgsparse_rb_pr(DgConfig::stock(16))).unwrap();
        lower(&Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16))).unwrap();
        lower(&Schedule::ttm_group(TtmConfig::new(4, 4, 8))).unwrap();
        lower(&Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16))).unwrap();
    }

    #[test]
    fn fused_lowers_to_one_sparse_traversal_with_no_intermediate() {
        let k = lower(&Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16))).unwrap();
        assert_eq!(k.name, "fused_sddmm_spmm_c4_r16");
        // one pass over pos/crd: a single row search and a single
        // row-boundary scan, both hoisted out of the column loop
        let searches = k.count_matching(|s| {
            matches!(s, Stmt::Decl { init: Val::BinarySearchBefore { .. }, .. })
        });
        assert_eq!(searches, 1, "fused kernel must search the row exactly once");
        // one ReductionPlan, one segment macro — and never an atomic pair
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 16, .. })), 1);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
        // no intermediate nnz buffer anywhere in the LLIR: the producer's
        // value lives in the tlaneY register
        assert!(!k.params.iter().any(|p| p.name == "Y_vals"));
        let touches_y = k.walk().iter().any(|s| format!("{s:?}").contains("Y_vals"));
        assert!(!touches_y, "fused kernel must not touch a materialized Y");
        // zero extension survives fusion: out-of-bound lanes zero the
        // workspace and still reach the segment reduction
        let zero_ext = k.count_matching(|s| {
            matches!(s, Stmt::If { then, .. }
                if matches!(then.first(), Some(Stmt::Assign { var, val: Val::ConstF(f) })
                    if var == "val" && *f == 0.0))
        });
        assert_eq!(zero_ext, 1, "zero-extension branch missing");
        // both dense factors of the producer's dot are bound
        assert!(k.params.iter().any(|p| p.name == "X1_vals"));
        assert!(k.params.iter().any(|p| p.name == "X2_vals"));
    }

    #[test]
    fn fused_rejects_oversized_groups() {
        // r wider than the contiguous nnz lanes per block (N/c = 64
        // chunks leave only 4 nnz lanes)
        assert!(matches!(
            lower(&Schedule::fused_sddmm_spmm(FusedConfig::new(32, 64, 1, 8))),
            Err(LowerError::InvalidConfig(_))
        ));
    }

    #[test]
    fn mttkrp_lowers_to_the_segment_macro_with_zero_extension() {
        use crate::compiler::schedule::MttkrpConfig;
        let k = lower(&Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16))).unwrap();
        assert_eq!(k.name, "mttkrp_c4_r16");
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 16, .. })), 1);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
        // zero extension: the then-branch zeroes the workspace
        let zero_ext = k.count_matching(|s| {
            matches!(s, Stmt::If { then, .. }
                if matches!(then.first(), Some(Stmt::Assign { var, val: Val::ConstF(f) })
                    if var == "val" && *f == 0.0))
        });
        assert_eq!(zero_ext, 1, "zero-extension branch missing");
        // the Khatri-Rao gather reads both factor matrices
        assert!(k.params.iter().any(|p| p.name == "X2_vals"));
    }

    #[test]
    fn ttm_lowers_without_the_second_factor() {
        use crate::compiler::schedule::TtmConfig;
        let k = lower(&Schedule::ttm_group(TtmConfig::new(4, 4, 8))).unwrap();
        assert_eq!(k.name, "ttm_c4_r8");
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 8, .. })), 1);
        assert!(!k.params.iter().any(|p| p.name == "X2_vals" || p.name == "f2_idx"));
    }

    #[test]
    fn coo3_invalid_configs_rejected() {
        use crate::compiler::schedule::{MttkrpConfig, TtmConfig};
        // c does not divide J
        assert!(matches!(
            lower(&Schedule::mttkrp_group(MttkrpConfig::new(8, 3, 16))),
            Err(LowerError::InvalidConfig(_))
        ));
        // r wider than the contiguous nnz range per block (J/c = 64 chunks
        // leave only 4 nnz lanes)
        assert!(matches!(
            lower(&Schedule::ttm_group(TtmConfig::new(64, 1, 8))),
            Err(LowerError::InvalidConfig(_))
        ));
    }

    #[test]
    fn nnz_group_emits_seg_reduce_and_zero_extension() {
        let k = lower(&Schedule::sgap_nnz_group(cfg(), 16)).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 16, .. })), 1);
        // zero extension: an if whose then-branch zeroes the workspace
        let zero_ext = k.count_matching(|s| {
            matches!(s, Stmt::If { then, .. }
                if matches!(then.first(), Some(Stmt::Assign { var, val: Val::ConstF(f) })
                    if var == "val" && *f == 0.0))
        });
        assert_eq!(zero_ext, 1, "zero-extension branch missing");
        // no plain atomicAdd in the segment-reduction kernel
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
    }

    #[test]
    fn row_group_emits_atomic_add_group() {
        let k = lower(&Schedule::sgap_row_group(cfg(), 4)).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { group: 4, .. })), 1);
        assert_eq!(k.block_dim, 256);
    }

    #[test]
    fn row_group_rejects_r_gt_g() {
        let mut c = cfg();
        c.g = 8;
        let err = lower(&Schedule::sgap_row_group(c, 32)).unwrap_err();
        assert!(matches!(err, LowerError::InvalidConfig(_)));
    }

    #[test]
    fn nnz_serial_uses_plain_atomics() {
        let k = lower(&Schedule::taco_nnz_serial(cfg())).unwrap();
        assert!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })) >= 2);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { .. })), 0);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { .. })), 0);
    }

    #[test]
    fn row_serial_has_no_atomics() {
        let k = lower(&Schedule::taco_row_serial(cfg())).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAdd { .. })), 0);
        assert!(k.count_matching(|s| matches!(s, Stmt::Store { .. })) >= 1);
    }

    #[test]
    fn invalid_config_rejected() {
        let mut c = cfg();
        c.c = 3; // does not divide N=4
        assert!(lower(&Schedule::taco_row_serial(c)).is_err());
    }

    #[test]
    fn sddmm_lowers_through_the_shared_emitter() {
        let k = lower(&Schedule::sddmm_group(SddmmConfig::new(64, 32, 8))).unwrap();
        assert_eq!(k.name, "sddmm_g32_r8");
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { group: 8, .. })), 1);
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { .. })), 0);
        assert_eq!(k.block_dim, 256);
    }

    #[test]
    fn dgsparse_lowers_with_row_balanced_strategy() {
        let dg = DgConfig { group_sz: 8, tile_sz: 8, ..DgConfig::stock(16) };
        let k = lower(&Schedule::dgsparse_rb_pr(dg)).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { group: 8, .. })), 1);
        // the row-balance loop strides by the launch-bound workerDimR param
        let strided = k.count_matching(|s| {
            matches!(s, Stmt::Assign { var, val }
                if var == "i"
                    && matches!(val, Val::Bin(_, _, b) if **b == Val::Param("workerDimR".into())))
        });
        assert_eq!(strided, 1, "workerDimR stride missing");
        assert!(k.params.iter().any(|p| p.name == "workerDimR"));
    }

    #[test]
    fn custom_strategy_reaches_every_family_through_the_plan() {
        // a user-defined strategy only has to name its writeback; the
        // shared emitter routes it without family-specific code
        use crate::compiler::cin::{GroupSpec, ReductionStrategy, Writeback};
        let spec = GroupSpec::new(
            4,
            ReductionStrategy::Custom { name: "userSeg", writeback: Writeback::SegmentBoundary },
        );
        let stmt = emit_reduction(&spec.plan(), "C_vals", Val::var("kC"), Val::var("val"));
        assert!(matches!(stmt, Stmt::SegReduceGroup { group: 4, .. }));
    }

    /// A user-defined strategy lowers through the *whole* pipeline —
    /// classification routes it by writeback, no emitter edits needed.
    #[test]
    fn custom_strategy_lowers_end_to_end() {
        use crate::compiler::cin::{ReductionStrategy, Writeback};
        use crate::compiler::schedule::ScheduleCmd;
        let swap_strategy = |sched: &mut Schedule, strategy: ReductionStrategy| {
            for cmd in &mut sched.cmds {
                if let ScheduleCmd::ParallelizeGroup { spec, .. } = cmd {
                    spec.strategy = strategy;
                }
            }
        };

        let mut sddmm = Schedule::sddmm_group(SddmmConfig::new(64, 16, 8));
        swap_strategy(
            &mut sddmm,
            ReductionStrategy::Custom { name: "userLane", writeback: Writeback::LaneZeroAtomic },
        );
        assert_eq!(sddmm.classify().unwrap(), Family::SddmmGroup);
        let k = lower(&sddmm).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::AtomicAddGroup { group: 8, .. })), 1);

        // an SpMM schedule with a custom segment-boundary strategy routes
        // to the nnz-group family purely by its writeback
        let mut spmm = Schedule::sgap_nnz_group(SpmmConfig::default(), 16);
        swap_strategy(
            &mut spmm,
            ReductionStrategy::Custom { name: "userSeg", writeback: Writeback::SegmentBoundary },
        );
        assert_eq!(spmm.classify().unwrap(), Family::NnzGroup);
        let k = lower(&spmm).unwrap();
        assert_eq!(k.count_matching(|s| matches!(s, Stmt::SegReduceGroup { group: 16, .. })), 1);
    }
}
